"""Build-time compile path: JAX/Pallas authoring + AOT lowering to HLO text.

Nothing in this package is imported at runtime; the Rust coordinator only
consumes ``artifacts/*.hlo.txt`` produced by ``python -m compile.aot``.

All numerics are float64: the PIC PRK correctness property (horizontal
displacement of exactly ``2k+1`` grid cells per step) is verified to an
epsilon of 1e-6 over hundreds of steps, which f32 cannot hold.
"""

import jax

jax.config.update("jax_enable_x64", True)
