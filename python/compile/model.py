"""Layer-2: the JAX compute graphs lowered to AOT artifacts.

Each public function here is a jit-able graph over concrete shapes that
``aot.py`` lowers to HLO text for the Rust runtime. They compose the
Layer-1 Pallas kernels; nothing here runs at serving time.

Entry-point calling convention (mirrored by rust/src/runtime/):
  pic_push_step   : (x, y, vx, vy, q : f64[n], lq : f64[2])        -> 4-tuple
  pic_push_epoch  : same operands, STEPS fused iterations          -> 4-tuple
  stencil_step    : (grid : f64[r,c], alpha : f64[1])              -> 1-tuple
All artifacts are lowered with return_tuple=True, so Rust unwraps an
N-tuple from a single output literal.
"""

from __future__ import annotations

from .kernels import particle_push, stencil


def pic_push_step(x, y, vx, vy, q, lq):
    """One PIC PRK time step (Layer-1 kernel pass-through)."""
    return particle_push.pic_push(x, y, vx, vy, q, lq)


def make_pic_push_epoch(steps):
    """A graph running ``steps`` fused PIC steps per invocation.

    Used by the Rust hot path to amortize PJRT dispatch over an LB epoch
    (e.g. steps = the load-balancing period).
    """

    def pic_push_epoch(x, y, vx, vy, q, lq):
        return particle_push.pic_push_steps(x, y, vx, vy, q, lq, steps)

    pic_push_epoch.__name__ = f"pic_push_epoch{steps}"
    return pic_push_epoch


def make_pic_push_block(block):
    """Single-step push with an explicit particle-tile size.

    The TPU-shaped tile is 8192 (VMEM sizing, see particle_push.py); the
    CPU PJRT artifacts for large batches use one flat tile instead —
    interpret-mode tiling only adds per-tile loop overhead on CPU
    (EXPERIMENTS.md §Perf).
    """

    def pic_push_block(x, y, vx, vy, q, lq):
        return particle_push.pic_push(x, y, vx, vy, q, lq, block=block)

    pic_push_block.__name__ = f"pic_push_block{block}"
    return pic_push_block


def stencil_step(grid, alpha):
    """One periodic 5-point Jacobi sweep (Layer-1 kernel pass-through)."""
    return (stencil.stencil_sweep(grid, alpha),)
