"""Layer-1 Pallas kernels (interpret=True) and their pure-jnp oracles."""

from . import particle_push, ref, stencil  # noqa: F401
