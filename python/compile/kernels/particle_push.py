"""Layer-1 Pallas kernel: the PIC PRK particle push.

TPU adaptation of the PRK hot loop (see DESIGN.md §Hardware-Adaptation):
the reference ``pic.c`` walks particles with a scalar loop and reads the
four corner charges of the containing cell. Because the PRK charge grid
is *analytic* (sign alternates by column parity), the kernel computes
corner charges from ``floor(x)`` parity with pure vector ops — no gather,
no charge array in memory. Particles stream through VMEM in
``(BLOCK,)``-shaped tiles; everything is elementwise VPU work.

``interpret=True`` everywhere: the CPU PJRT plugin (which the Rust
coordinator embeds) cannot execute Mosaic custom-calls, so the kernel is
lowered to plain HLO. The BlockSpec structure is unchanged for a real
TPU build.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DT = 1.0
MASS_INV = 1.0

# Default particle-tile size. 8 f64 streams (4 in, 4 out) of 8192 lanes
# = 512 KiB of VMEM per tile — comfortably double-bufferable in 16 MiB.
BLOCK = 8192


def _push_kernel(x_ref, y_ref, vx_ref, vy_ref, q_ref, lq_ref,
                 xo_ref, yo_ref, vxo_ref, vyo_ref):
    """Pallas body: one PIC step for one particle tile.

    ``lq_ref`` is a 2-element SMEM-like operand holding (L, Q) so a single
    compiled artifact serves every grid size / charge magnitude.
    """
    L = lq_ref[0]
    Q = lq_ref[1]
    x = x_ref[...]
    y = y_ref[...]
    vx = vx_ref[...]
    vy = vy_ref[...]
    q = q_ref[...]

    cx = jnp.floor(x)
    cy = jnp.floor(y)
    rel_x = x - cx
    rel_y = y - cy

    # Analytic corner charges: +Q in even columns, -Q in odd columns.
    q_left = Q * (1.0 - 2.0 * jnp.mod(cx, 2.0))
    q_right = -q_left

    # Coulomb contributions from the four corners. Shared subexpressions
    # (r^2 per corner) are spelled once so XLA fuses a single elementwise
    # pipeline per tile.
    def corner(xd, yd, qg):
        r2 = xd * xd + yd * yd
        inv_r3 = jax.lax.rsqrt(r2) / r2  # 1/r^3, one rsqrt + one div
        f = q * qg * inv_r3
        return f * xd, f * yd

    fx_tl, fy_tl = corner(rel_x, rel_y, q_left)
    fx_bl, fy_bl = corner(rel_x, 1.0 - rel_y, q_left)
    fx_tr, fy_tr = corner(1.0 - rel_x, rel_y, q_right)
    fx_br, fy_br = corner(1.0 - rel_x, 1.0 - rel_y, q_right)

    ax = (fx_tl + fx_bl - fx_tr - fx_br) * MASS_INV
    ay = (fy_tl - fy_bl + fy_tr - fy_br) * MASS_INV

    xo_ref[...] = jnp.mod(x + vx * DT + 0.5 * ax * (DT * DT) + L, L)
    yo_ref[...] = jnp.mod(y + vy * DT + 0.5 * ay * (DT * DT) + L, L)
    vxo_ref[...] = vx + ax * DT
    vyo_ref[...] = vy + ay * DT


@functools.partial(jax.jit, static_argnames=("block",))
def pic_push(x, y, vx, vy, q, lq, block=BLOCK):
    """One PIC PRK step for ``n`` particles via the Pallas kernel.

    Args:
      x, y, vx, vy, q: ``(n,)`` float64 state; ``n`` must be a multiple of
        ``block`` (the Rust runtime pads with inert particles).
      lq: ``(2,)`` float64 array ``[L, Q]``.
      block: particle-tile size (static).

    Returns:
      Tuple ``(x', y', vx', vy')``.
    """
    n = x.shape[0]
    block = min(block, n)
    assert n % block == 0, f"n={n} must be a multiple of block={block}"
    grid = (n // block,)
    tile = pl.BlockSpec((block,), lambda i: (i,))
    scal = pl.BlockSpec((2,), lambda i: (0,))
    out = jax.ShapeDtypeStruct((n,), x.dtype)
    return pl.pallas_call(
        _push_kernel,
        grid=grid,
        in_specs=[tile, tile, tile, tile, tile, scal],
        out_specs=[tile, tile, tile, tile],
        out_shape=[out, out, out, out],
        interpret=True,
    )(x, y, vx, vy, q, lq)


def pic_push_steps(x, y, vx, vy, q, lq, steps, block=BLOCK):
    """``steps`` fused PIC steps in one executable (fori_loop over pushes).

    Amortizes PJRT dispatch + literal marshalling on the Rust hot path —
    the coordinator calls one executable per LB epoch instead of one per
    app iteration. ``steps`` is baked into the artifact.
    """

    def body(_, state):
        x, y, vx, vy = state
        return pic_push(x, y, vx, vy, q, lq, block=block)

    return jax.lax.fori_loop(0, steps, body, (x, y, vx, vy))
