"""Layer-1 Pallas kernel: 5-point Jacobi stencil sweep (periodic).

The synthetic stencil application of the paper (§I, Figs 1-2) is both
the load-balancing workload generator and a real compute kernel here:
each chare owns a tile of the global grid and sweeps it every iteration.

TPU mapping: the grid is tiled into ``(BR, BC)`` VMEM blocks. Rather
than halo-exchange between blocks (which BlockSpec cannot express for
periodic wrap-around), the kernel takes the four pre-shifted neighbor
planes as separate inputs — the L2 wrapper materializes them with
``jnp.roll``, which XLA lowers to two concats (cheap, fusable) — and the
kernel itself is a single fused elementwise pass per tile. This keeps
the hot loop in VMEM-resident vector ops, the Pallas analog of a CUDA
shared-memory stencil.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 256x256 f64 tile = 512 KiB; 6 operands => 3 MiB live per grid step.
BLOCK_R = 256
BLOCK_C = 256


def _stencil_kernel(c_ref, n_ref, s_ref, w_ref, e_ref, a_ref, o_ref):
    alpha = a_ref[0]
    c = c_ref[...]
    o_ref[...] = (1.0 - 4.0 * alpha) * c + alpha * (
        n_ref[...] + s_ref[...] + w_ref[...] + e_ref[...]
    )


@functools.partial(jax.jit, static_argnames=("block_r", "block_c"))
def stencil_sweep(grid, alpha_arr, block_r=BLOCK_R, block_c=BLOCK_C):
    """One periodic 5-point Jacobi sweep over ``grid``.

    Args:
      grid: ``(R, C)`` float64, with R % block_r == 0 and C % block_c == 0.
      alpha_arr: ``(1,)`` float64 ``[alpha]`` diffusion coefficient.

    Returns:
      The updated ``(R, C)`` grid.
    """
    r, c = grid.shape
    assert r % block_r == 0 and c % block_c == 0, (r, c, block_r, block_c)
    north = jnp.roll(grid, 1, axis=0)
    south = jnp.roll(grid, -1, axis=0)
    west = jnp.roll(grid, 1, axis=1)
    east = jnp.roll(grid, -1, axis=1)

    tile = pl.BlockSpec((block_r, block_c), lambda i, j: (i, j))
    scal = pl.BlockSpec((1,), lambda i, j: (0,))
    return pl.pallas_call(
        _stencil_kernel,
        grid=(r // block_r, c // block_c),
        in_specs=[tile, tile, tile, tile, tile, scal],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((r, c), grid.dtype),
        interpret=True,
    )(grid, north, south, west, east, alpha_arr)
