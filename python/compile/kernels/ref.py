"""Pure-jnp oracles for the Layer-1 Pallas kernels.

These implement the PIC PRK particle push (Van der Wijngaart & Mattson,
HPEC'14; Georganas et al., IPDPS'16) and a 5-point Jacobi stencil sweep,
with no Pallas involved. ``pytest python/tests`` asserts the Pallas
kernels match these to tight tolerances, and the Rust fallback path is
validated against the same semantics (see rust/src/apps/pic/).

PIC PRK semantics (mirrors the reference ``pic.c``):

* The grid has unit spacing and a fixed charge at every grid point whose
  sign alternates by **column parity**: ``QG(x) = Q * (1 - 2*(x & 1))``.
  Charges are analytic — no charge array is ever materialized, which is
  also the TPU adaptation story (no gather; see DESIGN.md).
* A particle at position ``(x, y)`` inside cell ``(floor(x), floor(y))``
  feels the Coulomb force of the cell's four corner charges:
  ``f = q1*q2/r^2`` along the separation direction, accumulated with the
  PRK sign convention (left charges push +x when attractive, etc.).
* Leapfrog-style update with DT = 1 and unit mass, periodic wrap at L.
"""

from __future__ import annotations

import jax.numpy as jnp

DT = 1.0
MASS_INV = 1.0


def grid_charge(x_index, Q):
    """Charge at any grid point in column ``x_index``: +Q even, -Q odd."""
    return Q * (1.0 - 2.0 * jnp.mod(x_index, 2.0))


def coulomb(x_dist, y_dist, q1, q2):
    """PRK computeCoulomb: force components between charges q1, q2.

    ``f = q1*q2 / r^2`` decomposed along (x_dist, y_dist).
    """
    r2 = x_dist * x_dist + y_dist * y_dist
    r = jnp.sqrt(r2)
    f = q1 * q2 / r2
    return f * x_dist / r, f * y_dist / r


def total_force(x, y, q, Q):
    """PRK computeTotalForce: net force from the 4 corners of the cell.

    Corner charges depend only on column parity, so both left corners
    share ``QG(cx)`` and both right corners share ``QG(cx+1)``.
    """
    cx = jnp.floor(x)
    cy = jnp.floor(y)
    rel_x = x - cx
    rel_y = y - cy
    q_left = grid_charge(cx, Q)
    q_right = grid_charge(cx + 1.0, Q)

    fx_tl, fy_tl = coulomb(rel_x, rel_y, q, q_left)
    fx_bl, fy_bl = coulomb(rel_x, 1.0 - rel_y, q, q_left)
    fx_tr, fy_tr = coulomb(1.0 - rel_x, rel_y, q, q_right)
    fx_br, fy_br = coulomb(1.0 - rel_x, 1.0 - rel_y, q, q_right)

    fx = fx_tl + fx_bl - fx_tr - fx_br
    fy = fy_tl - fy_bl + fy_tr - fy_br
    return fx, fy


def pic_push_ref(x, y, vx, vy, q, L, Q):
    """One PIC PRK time step for a batch of particles (pure jnp).

    Args:
      x, y, vx, vy, q: ``(n,)`` float64 particle state.
      L: grid size (scalar, float); positions live in ``[0, L)``.
      Q: base grid charge magnitude (scalar, float).

    Returns:
      ``(x', y', vx', vy')`` after one DT=1 step with periodic wrap.
    """
    fx, fy = total_force(x, y, q, Q)
    ax = fx * MASS_INV
    ay = fy * MASS_INV
    x_new = jnp.mod(x + vx * DT + 0.5 * ax * DT * DT + L, L)
    y_new = jnp.mod(y + vy * DT + 0.5 * ay * DT * DT + L, L)
    return x_new, y_new, vx + ax * DT, vy + ay * DT


def pic_push_ref_steps(x, y, vx, vy, q, L, Q, steps):
    """``steps`` successive reference pushes (python loop; oracle only)."""
    for _ in range(steps):
        x, y, vx, vy = pic_push_ref(x, y, vx, vy, q, L, Q)
    return x, y, vx, vy


def base_charge(rel_x, rel_y, Q):
    """PRK charge calibration constant for a particle at (rel_x, rel_y).

    Chosen so that, for a particle at rest at cell-relative position
    (rel_x, rel_y=0.5) in an even column, carrying ``(2k+1)*base_charge``,
    the first-step displacement is exactly ``2k+1`` cells. The vertical
    symmetry at rel_y=0.5 doubles the x-force (two rows of corners) and
    the kinematics halve it (0.5*a*DT^2), which cancel.
    """
    r1_sq = rel_y * rel_y + rel_x * rel_x
    r2_sq = rel_y * rel_y + (1.0 - rel_x) * (1.0 - rel_x)
    cos_theta = rel_x / jnp.sqrt(r1_sq)
    cos_phi = (1.0 - rel_x) / jnp.sqrt(r2_sq)
    return 1.0 / ((DT * DT) * Q * (cos_theta / r1_sq + cos_phi / r2_sq))


def calibrated_charge(x, y, k, Q):
    """Per-particle charge giving deterministic +x motion of 2k+1 cells.

    Mirrors PRK ``finish_particle_initialization``: particles in even
    columns get positive charge (attracted rightward past the +Q column),
    odd columns negative, so *all* particles drift in +x.
    """
    cx = jnp.floor(x)
    rel_x = x - cx
    rel_y = y - jnp.floor(y)
    bc = base_charge(rel_x, rel_y, Q)
    sign = 1.0 - 2.0 * jnp.mod(cx, 2.0)
    return sign * (2.0 * k + 1.0) * bc


def stencil_sweep_ref(grid, alpha=0.25):
    """5-point Jacobi sweep with periodic boundaries (pure jnp).

    ``out = (1-4*alpha)*c + alpha*(n+s+e+w)`` — the synthetic stencil
    app's per-object compute kernel (paper §I / Fig 1-2 workload).
    """
    n = jnp.roll(grid, 1, axis=0)
    s = jnp.roll(grid, -1, axis=0)
    w = jnp.roll(grid, 1, axis=1)
    e = jnp.roll(grid, -1, axis=1)
    return (1.0 - 4.0 * alpha) * grid + alpha * (n + s + e + w)
