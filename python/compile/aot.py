"""AOT driver: lower Layer-2 graphs to HLO **text** artifacts.

Usage (from ``python/``):
    python -m compile.aot --out-dir ../artifacts [--only NAME] [--list]

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the HLO text
parser reassigns ids, so text round-trips cleanly. Everything is lowered
with ``return_tuple=True`` and unwrapped as a tuple literal in Rust.

A ``manifest.txt`` (one ``key=value`` record per line) is written next to
the artifacts; ``rust/src/runtime/`` uses it to pick executables by
logical name + shape instead of hard-coding file names.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F64 = jnp.float64


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F64)


def _pic_args(n):
    p = _spec(n)
    return (p, p, p, p, p, _spec(2))


# name -> (callable, example_args, metadata-dict)
def registry():
    arts = {}

    def add(name, fn, args, **meta):
        arts[name] = (fn, args, meta)

    for n in (1024, 8192):
        add(f"pic_push_n{n}", model.pic_push_step, _pic_args(n),
            kind="pic_push", n=n, steps=1)
    # large-batch artifact: flat single tile (CPU-tuned; see model.py)
    add("pic_push_n65536", model.make_pic_push_block(65536), _pic_args(65536),
        kind="pic_push", n=65536, steps=1)
    for steps, n in ((5, 65536), (10, 65536)):
        add(f"pic_push_epoch{steps}_n{n}", model.make_pic_push_epoch(steps),
            _pic_args(n), kind="pic_push", n=n, steps=steps)
    for r, c in ((256, 256), (512, 512)):
        add(f"stencil_{r}x{c}", model.stencil_step, (_spec(r, c), _spec(1)),
            kind="stencil", rows=r, cols=c)
    return arts


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(name, fn, args):
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single artifact")
    ap.add_argument("--list", action="store_true", help="list artifact names")
    ns = ap.parse_args()

    arts = registry()
    if ns.list:
        for name in arts:
            print(name)
        return

    os.makedirs(ns.out_dir, exist_ok=True)
    manifest = []
    for name, (fn, args, meta) in arts.items():
        if ns.only and name != ns.only:
            continue
        text = lower_one(name, fn, args)
        fname = f"{name}.hlo.txt"
        path = os.path.join(ns.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        fields = " ".join(f"{k}={v}" for k, v in meta.items())
        manifest.append(f"name={name} file={fname} {fields}")
        print(f"wrote {path} ({len(text)} chars)")

    if not ns.only:
        with open(os.path.join(ns.out_dir, "manifest.txt"), "w") as f:
            f.write("\n".join(manifest) + "\n")
        print(f"wrote {ns.out_dir}/manifest.txt ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
