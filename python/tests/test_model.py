"""Layer-2 graph tests: model-level composition, charge calibration,
and physics invariants that the Rust side relies on."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def centered_particles(rng, n, L, k, m, Q):
    x = rng.integers(0, L, n).astype(np.float64) + 0.5
    y = rng.integers(0, L, n).astype(np.float64) + 0.5
    q = np.asarray(ref.calibrated_charge(x, y, float(k), Q))
    return x, y, np.zeros(n), np.full(n, float(m)), q


@settings(max_examples=15, deadline=None)
@given(
    k=st.integers(0, 5),
    m=st.integers(1, 3),
    Q=st.floats(0.5, 3.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_calibrated_charge_gives_exact_displacement(k, m, Q, seed):
    """The determinism property holds for arbitrary k, m, Q."""
    rng = np.random.default_rng(seed)
    L = 256.0
    n = 64
    x, y, vx, vy, q = centered_particles(rng, n, int(L), k, m, Q)
    lq = jnp.array([L, Q])
    xs, ys = jnp.asarray(x), jnp.asarray(y)
    vxs, vys = jnp.asarray(vx), jnp.asarray(vy)
    qs = jnp.asarray(q)
    steps = 4
    for _ in range(steps):
        xs, ys, vxs, vys = model.pic_push_step(xs, ys, vxs, vys, qs, lq)
    np.testing.assert_allclose(
        np.asarray(xs), np.mod(x + steps * (2 * k + 1), L), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(ys), np.mod(y + steps * m, L), atol=1e-6)


def test_energy_sign_structure():
    """Charge sign by column parity ⇒ all particles drift +x."""
    rng = np.random.default_rng(1)
    L = 128.0
    x, y, vx, vy, q = centered_particles(rng, 128, int(L), 1, 1, 1.0)
    lq = jnp.array([L, 1.0])
    out = model.pic_push_step(*map(jnp.asarray, (x, y, vx, vy, q)), lq)
    dx = np.mod(np.asarray(out[0]) - x, L)
    np.testing.assert_allclose(dx, 3.0, atol=1e-9)


def test_flat_block_variant_matches_default():
    """The CPU-tuned single-tile artifact computes the same numbers."""
    rng = np.random.default_rng(2)
    n, L = 2048, 64.0
    x, y, vx, vy, q = centered_particles(rng, n, int(L), 2, 1, 1.0)
    lq = jnp.array([L, 1.0])
    args = tuple(map(jnp.asarray, (x, y, vx, vy, q))) + (lq,)
    default = model.pic_push_step(*args)
    flat = model.make_pic_push_block(n)(*args)
    for d, f in zip(default, flat):
        np.testing.assert_array_equal(np.asarray(d), np.asarray(f))


def test_grid_charge_parity():
    cols = jnp.arange(10.0)
    charges = np.asarray(ref.grid_charge(cols, 2.0))
    np.testing.assert_allclose(charges, [2, -2] * 5)


@settings(max_examples=10, deadline=None)
@given(alpha=st.floats(0.05, 0.24), seed=st.integers(0, 2**31 - 1))
def test_stencil_step_tuple_contract(alpha, seed):
    """stencil_step returns a 1-tuple (the AOT return_tuple contract)."""
    rng = np.random.default_rng(seed)
    grid = jnp.asarray(rng.standard_normal((256, 256)))
    out = model.stencil_step(grid, jnp.array([alpha]))
    assert isinstance(out, tuple) and len(out) == 1
    np.testing.assert_allclose(
        np.asarray(out[0]),
        np.asarray(ref.stencil_sweep_ref(grid, alpha)),
        rtol=1e-12,
        atol=1e-13,
    )


def test_vx_oscillation_period_two():
    """v_x alternates 0 → a → 0: parity flip each (2k+1)-cell hop."""
    rng = np.random.default_rng(3)
    L = 64.0
    x, y, vx, vy, q = centered_particles(rng, 32, int(L), 1, 1, 1.0)
    lq = jnp.array([L, 1.0])
    s = tuple(map(jnp.asarray, (x, y, vx, vy)))
    qs = jnp.asarray(q)
    vx_hist = []
    for _ in range(6):
        s = model.pic_push_step(s[0], s[1], s[2], s[3], qs, lq)
        vx_hist.append(np.asarray(s[2]).copy())
    for i, v in enumerate(vx_hist):
        if i % 2 == 1:  # after even number of steps
            np.testing.assert_allclose(v, 0.0, atol=1e-9)
        else:
            assert np.all(np.abs(v) > 1e-12)
