"""Kernel-vs-oracle correctness: the CORE numeric signal of the repo.

The Pallas kernels (interpret=True) must match the pure-jnp oracles in
``compile.kernels.ref`` to near-f64 precision across shapes, parameters,
and step counts (hypothesis sweeps), and must satisfy the PIC PRK
determinism property: a calibrated particle at a cell center moves
exactly ``2k+1`` grid cells per step in +x and ``m`` in +y.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import particle_push, ref, stencil

jax.config.update("jax_enable_x64", True)


def make_particles(rng, n, L, Q, cell_centered=False):
    """Random particle batch; optionally snapped to cell centers + calibrated."""
    if cell_centered:
        x = rng.integers(0, L, n).astype(np.float64) + 0.5
        y = rng.integers(0, L, n).astype(np.float64) + 0.5
        k = rng.integers(0, 4, n).astype(np.float64)
        m = rng.integers(1, 3, n).astype(np.float64)
        q = np.asarray(ref.calibrated_charge(x, y, k, Q))
        vx = np.zeros(n)
        vy = m / ref.DT
        return x, y, vx, vy, q, k, m
    # Generic (non-deterministic-property) particles: keep away from grid
    # lines so 1/r^2 stays finite and comparable.
    x = rng.uniform(0.1, 0.9, n) + rng.integers(0, L, n)
    y = rng.uniform(0.1, 0.9, n) + rng.integers(0, L, n)
    vx = rng.uniform(-1, 1, n)
    vy = rng.uniform(-1, 1, n)
    q = rng.uniform(-5, 5, n)
    return x, y, vx, vy, q, None, None


@pytest.mark.parametrize("n,block", [(64, 64), (256, 64), (1024, 256)])
def test_pic_push_matches_ref(n, block):
    rng = np.random.default_rng(7)
    L, Q = 64.0, 1.0
    x, y, vx, vy, q, _, _ = make_particles(rng, n, int(L), Q)
    lq = jnp.array([L, Q])
    got = particle_push.pic_push(*map(jnp.asarray, (x, y, vx, vy, q)), lq,
                                 block=block)
    want = ref.pic_push_ref(*map(jnp.asarray, (x, y, vx, vy, q)), L, Q)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-12, atol=1e-12)


@settings(max_examples=20, deadline=None)
@given(
    n_tiles=st.integers(1, 4),
    block=st.sampled_from([32, 64, 128]),
    L=st.sampled_from([16.0, 100.0, 1000.0]),
    Q=st.floats(0.25, 4.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_pic_push_property_sweep(n_tiles, block, L, Q, seed):
    """Hypothesis sweep over shapes/params: kernel == oracle."""
    rng = np.random.default_rng(seed)
    n = n_tiles * block
    x, y, vx, vy, q, _, _ = make_particles(rng, n, int(L), Q)
    lq = jnp.array([L, Q])
    got = particle_push.pic_push(*map(jnp.asarray, (x, y, vx, vy, q)), lq,
                                 block=block)
    want = ref.pic_push_ref(*map(jnp.asarray, (x, y, vx, vy, q)), L, Q)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-11, atol=1e-11)


@settings(max_examples=10, deadline=None)
@given(steps=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_pic_push_steps_matches_iterated_ref(steps, seed):
    rng = np.random.default_rng(seed)
    n, block, L, Q = 128, 64, 32.0, 1.0
    x, y, vx, vy, q, _, _ = make_particles(rng, n, int(L), Q,
                                           cell_centered=True)
    lq = jnp.array([L, Q])
    got = particle_push.pic_push_steps(
        *map(jnp.asarray, (x, y, vx, vy, q)), lq, steps, block=block)
    want = ref.pic_push_ref_steps(
        *map(jnp.asarray, (x, y, vx, vy, q)), L, Q, steps)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("steps", [1, 3, 10, 50])
def test_prk_determinism_property(steps):
    """Calibrated particles move exactly (2k+1) cells/step in +x, m in +y."""
    rng = np.random.default_rng(3)
    n, L, Q = 256, 1000.0, 1.0
    x, y, vx, vy, q, k, m = make_particles(rng, n, int(L), Q,
                                           cell_centered=True)
    lq = jnp.array([L, Q])
    xs, ys, vxs, vys = map(jnp.asarray, (x, y, vx, vy))
    qs = jnp.asarray(q)
    for _ in range(steps):
        xs, ys, vxs, vys = particle_push.pic_push(xs, ys, vxs, vys, qs, lq,
                                                  block=64)
    expect_x = np.mod(x + steps * (2 * k + 1), L)
    expect_y = np.mod(y + steps * m, L)
    np.testing.assert_allclose(np.asarray(xs), expect_x, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ys), expect_y, atol=1e-6)
    # velocity oscillation: after an even number of steps vx returns to 0
    if steps % 2 == 0:
        np.testing.assert_allclose(np.asarray(vxs), 0.0, atol=1e-6)


def test_padding_particles_are_inert():
    """q=0 padding particles (used by the Rust runtime) never move."""
    n, block = 64, 64
    x = jnp.full((n,), 0.5)
    y = jnp.full((n,), 0.5)
    z = jnp.zeros((n,))
    lq = jnp.array([64.0, 1.0])
    xo, yo, vxo, vyo = particle_push.pic_push(x, y, z, z, z, lq, block=block)
    np.testing.assert_allclose(np.asarray(xo), 0.5)
    np.testing.assert_allclose(np.asarray(yo), 0.5)
    np.testing.assert_allclose(np.asarray(vxo), 0.0)
    np.testing.assert_allclose(np.asarray(vyo), 0.0)


@pytest.mark.parametrize("r,c,br,bc", [(64, 64, 64, 64), (128, 64, 64, 64),
                                       (128, 128, 64, 64)])
def test_stencil_matches_ref(r, c, br, bc):
    rng = np.random.default_rng(11)
    grid = jnp.asarray(rng.standard_normal((r, c)))
    alpha = jnp.array([0.25])
    got = stencil.stencil_sweep(grid, alpha, block_r=br, block_c=bc)
    want = ref.stencil_sweep_ref(grid, 0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-13, atol=1e-13)


@settings(max_examples=15, deadline=None)
@given(
    tiles_r=st.integers(1, 3),
    tiles_c=st.integers(1, 3),
    alpha=st.floats(0.01, 0.24),
    seed=st.integers(0, 2**31 - 1),
)
def test_stencil_property_sweep(tiles_r, tiles_c, alpha, seed):
    rng = np.random.default_rng(seed)
    br = bc = 32
    grid = jnp.asarray(rng.standard_normal((tiles_r * br, tiles_c * bc)))
    got = stencil.stencil_sweep(grid, jnp.array([alpha]), block_r=br,
                                block_c=bc)
    want = ref.stencil_sweep_ref(grid, alpha)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-12, atol=1e-12)


def test_stencil_conserves_mean():
    """Jacobi with periodic boundaries conserves the grid mean exactly-ish."""
    rng = np.random.default_rng(5)
    grid = jnp.asarray(rng.standard_normal((64, 64)))
    out = stencil.stencil_sweep(grid, jnp.array([0.2]), block_r=32,
                                block_c=32)
    assert abs(float(jnp.mean(out)) - float(jnp.mean(grid))) < 1e-12
