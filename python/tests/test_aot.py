"""AOT path smoke tests: lowering works, HLO text parses, manifest sane.

These guard the python→rust interchange contract: HLO *text* with
``return_tuple=True``, f64 operands, and the entry signature the Rust
runtime (rust/src/runtime/) expects.
"""

import re

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_registry_names_unique_and_nonempty():
    arts = aot.registry()
    assert len(arts) >= 7
    assert len(set(arts)) == len(arts)


@pytest.mark.parametrize("name", ["pic_push_n1024", "stencil_256x256"])
def test_lower_to_hlo_text(name):
    fn, args, _meta = aot.registry()[name]
    text = aot.lower_one(name, fn, args)
    assert "HloModule" in text
    assert "ROOT" in text
    # f64 operands present; interchange is double precision end-to-end.
    assert "f64" in text


def _entry_block(text):
    m = re.search(r"ENTRY [^\{]+\{(?P<body>.*?)^\}", text,
                  re.MULTILINE | re.DOTALL)
    assert m, "no ENTRY block in HLO text"
    return m.group("body")


def test_pic_entry_signature():
    """Entry computation takes 6 params (x y vx vy q lq) returns 4-tuple."""
    fn, args, _ = aot.registry()["pic_push_n1024"]
    text = aot.lower_one("pic_push_n1024", fn, args)
    body = _entry_block(text)
    params = re.findall(r"= f64\[[\d,]*\]\{?\d*\}? parameter\(\d\)", body)
    assert len(params) == 6
    root = re.search(r"ROOT \S+ = (?P<ret>\([^)]*\)) tuple", body)
    assert root and root.group("ret").count("f64[1024]") == 4


def test_stencil_entry_signature():
    fn, args, _ = aot.registry()["stencil_256x256"]
    text = aot.lower_one("stencil_256x256", fn, args)
    body = _entry_block(text)
    params = re.findall(r"parameter\(\d\)", body)
    assert len(params) == 2
    root = re.search(r"ROOT \S+ = (?P<ret>\([^)]*\)) tuple", body)
    assert root and "f64[256,256]" in root.group("ret")


def test_epoch_graph_equals_repeated_single_steps():
    """The fused-epoch artifact computes exactly N single steps."""
    rng = np.random.default_rng(0)
    n = 64
    x = rng.integers(0, 32, n) + 0.5
    y = rng.integers(0, 32, n) + 0.5
    vx = np.zeros(n)
    vy = np.ones(n)
    from compile.kernels import ref
    q = np.asarray(ref.calibrated_charge(x, y, np.ones(n), 1.0))
    lq = jnp.array([32.0, 1.0])
    args = tuple(map(jnp.asarray, (x, y, vx, vy, q))) + (lq,)

    epoch = model.make_pic_push_epoch(3)
    got = epoch(*args)
    state = args[:5]
    for _ in range(3):
        out = model.pic_push_step(*state, lq)
        state = out + (args[4],)
    for g, w in zip(got, state[:4]):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-12, atol=1e-12)
