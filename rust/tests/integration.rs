//! Cross-module integration tests: the full LB pipeline over realistic
//! workloads, determinism, instance round-trips, and the coordinator's
//! config-driven assembly.

use difflb::apps::stencil::{self, Decomposition};
use difflb::coordinator::Coordinator;
use difflb::model::{evaluate_mapping, Instance};
use difflb::strategies::{make, StrategyParams, AVAILABLE};
use difflb::util::config::Config;
use difflb::util::prop;

fn workloads() -> Vec<(&'static str, Instance)> {
    let mut w = Vec::new();
    let mut a = stencil::stencil_2d(24, 4, 4, Decomposition::Tiled);
    stencil::inject_noise(&mut a, 0.4, 1);
    w.push(("2d-noise", a));
    let mut b = stencil::stencil_3d(8, 8);
    stencil::inject_mod7(&mut b, 3.0, 0.3);
    w.push(("3d-mod7", b));
    let mut c = stencil::ring(10, 16);
    stencil::overload_pe(&mut c, 0, 10.0);
    w.push(("ring-hotspot", c));
    let d = stencil::stencil_2d(16, 8, 2, Decomposition::Striped);
    w.push(("2d-striped", d));
    w
}

#[test]
fn every_strategy_on_every_workload() {
    for (wname, inst) in workloads() {
        for name in AVAILABLE {
            let lb = make(name, StrategyParams::default()).unwrap();
            let asg = lb.rebalance(&inst);
            assert_eq!(asg.mapping.len(), inst.n_objects(), "{name}/{wname}");
            let m = evaluate_mapping(&inst, &asg.mapping);
            assert!(m.max_avg_pe.is_finite(), "{name}/{wname}");
            // no strategy may lose objects to out-of-range PEs
            assert!(
                asg.mapping.iter().all(|&pe| (pe as usize) < inst.topo.n_pes()),
                "{name}/{wname}"
            );
        }
    }
}

#[test]
fn balancers_improve_or_preserve_balance() {
    for (wname, inst) in workloads() {
        let before = evaluate_mapping(&inst, &inst.mapping);
        for name in ["diff-comm", "diff-coord", "greedy", "greedy-refine", "metis", "parmetis"] {
            let lb = make(name, StrategyParams::default()).unwrap();
            let m = evaluate_mapping(&inst, &lb.rebalance(&inst).mapping);
            assert!(
                m.max_avg_pe <= before.max_avg_pe * 1.05 + 0.05,
                "{name}/{wname}: {} -> {}",
                before.max_avg_pe,
                m.max_avg_pe
            );
        }
    }
}

#[test]
fn determinism_across_runs() {
    for (wname, inst) in workloads() {
        for name in AVAILABLE {
            let a = make(name, StrategyParams::default()).unwrap().rebalance(&inst);
            let b = make(name, StrategyParams::default()).unwrap().rebalance(&inst);
            assert_eq!(a.mapping, b.mapping, "{name}/{wname} nondeterministic");
        }
    }
}

#[test]
fn lbi_round_trip_preserves_metrics() {
    for (_, inst) in workloads() {
        let text = inst.to_lbi();
        let back = Instance::from_lbi(&text).unwrap();
        let m1 = evaluate_mapping(&inst, &inst.mapping);
        let m2 = evaluate_mapping(&back, &back.mapping);
        assert!((m1.max_avg_pe - m2.max_avg_pe).abs() < 1e-12);
        assert!((m1.comm_nodes.ratio() - m2.comm_nodes.ratio()).abs() < 1e-12);
    }
}

#[test]
fn coordinator_full_cycle_from_config() {
    let cfg = Config::from_str(
        "[lb]\nstrategy = diff-comm\nneighbors = 4\n[run]\niters = 8\nlb_period = 4\n\
         [pic]\ngrid = 48\nparticles = 1200\nchares_x = 6\nchares_y = 6\nbackend = native\nthreads = 2\n\
         [topo]\nnodes = 3",
    )
    .unwrap();
    let coord = Coordinator::from_config(&cfg).unwrap();
    let rep = coord.run(&cfg).unwrap();
    assert!(rep.verified);
    assert_eq!(rep.records.len(), 8);
    assert!(rep.records.iter().any(|r| r.migrations > 0 || r.lb_s >= 0.0));
}

#[test]
fn hierarchical_topology_end_to_end() {
    // 2 nodes x 4 PEs: diffusion balances nodes, hierarchical spreads
    // within each node.
    let mut inst = stencil::stencil_2d(16, 4, 2, Decomposition::Tiled);
    // re-home onto a hierarchical topology
    let inst = Instance::new(
        {
            stencil::inject_noise(&mut inst, 0.4, 3);
            inst.loads.clone()
        },
        inst.coords.clone(),
        inst.graph.clone(),
        inst.mapping.clone(),
        difflb::model::Topology::new(2, 4),
    );
    let lb = make("diff-comm", StrategyParams::default()).unwrap();
    let asg = lb.rebalance(&inst);
    let m = evaluate_mapping(&inst, &asg.mapping);
    let before = evaluate_mapping(&inst, &inst.mapping);
    assert!(m.max_avg_node <= before.max_avg_node + 1e-9);
    // every PE in range and each node nonempty
    let pe_loads = inst.pe_loads(&asg.mapping);
    assert_eq!(pe_loads.len(), 8);
}

#[test]
fn diffusion_single_hop_and_conservation_property() {
    prop::check("pipeline invariants", 20, |g| {
        let side = 12 + 4 * g.usize_in(0, 3);
        let mut inst = stencil::stencil_2d(side, 4, 4, Decomposition::Tiled);
        stencil::inject_noise(&mut inst, 0.6, g.seed);
        let lb = difflb::strategies::diffusion::Diffusion::communication(
            StrategyParams::default(),
        );
        let (neigh, quotas) = lb.plan(&inst);
        // quotas conserve load
        let node_loads = inst.node_loads(&inst.mapping);
        let after = quotas.apply(&node_loads);
        prop::assert_close(after.iter().sum(), node_loads.iter().sum(), 1e-9)?;
        // migrations stay single-hop
        use difflb::strategies::LoadBalancer;
        let asg = lb.rebalance(&inst);
        for o in 0..inst.n_objects() {
            let from = inst.topo.node_of_pe(inst.mapping[o]);
            let to = inst.topo.node_of_pe(asg.mapping[o]);
            if from != to && !neigh.adj[from as usize].contains(&to) {
                return Err(format!("object {o} hopped {from}->{to}"));
            }
        }
        Ok(())
    });
}

#[test]
fn cli_binary_help_and_strategies() {
    // the built binary responds to basic invocations
    let bin = env!("CARGO_BIN_EXE_difflb");
    let out = std::process::Command::new(bin).arg("strategies").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for s in AVAILABLE {
        assert!(text.contains(s), "missing {s}");
    }
}

#[test]
fn cli_balance_round_trip() {
    let dir = std::env::temp_dir().join("difflb_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let lbi = dir.join("w.lbi");
    let out = dir.join("w_balanced.lbi");
    let mut inst = stencil::stencil_2d(16, 4, 4, Decomposition::Tiled);
    stencil::inject_noise(&mut inst, 0.4, 9);
    inst.save(&lbi).unwrap();

    let bin = env!("CARGO_BIN_EXE_difflb");
    let res = std::process::Command::new(bin)
        .args([
            "balance",
            lbi.to_str().unwrap(),
            "--strategy",
            "diff-comm",
            "--out",
            out.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(res.status.success(), "{}", String::from_utf8_lossy(&res.stderr));
    let rebalanced = Instance::load(&out).unwrap();
    assert_eq!(rebalanced.n_objects(), inst.n_objects());
}
