//! End-to-end PIC PRK: the full three-layer stack (PJRT kernel →
//! chare runtime → diffusion LB) must keep physics exact under every
//! strategy, and both backends must produce identical trajectories.

use std::sync::Arc;

use difflb::apps::driver::{run_app, DriverConfig};
use difflb::apps::pic::{Backend, InitMode, PicApp, PicConfig};
use difflb::apps::step_once;
use difflb::apps::stencil::Decomposition;
use difflb::model::Topology;
use difflb::runtime::{Engine, Manifest};
use difflb::strategies::{make, StrategyParams};

fn cfg(n_particles: usize, nodes: usize) -> PicConfig {
    PicConfig {
        grid: 96,
        n_particles,
        k: 2,
        m: 1,
        init: InitMode::Geometric { rho: 0.9 },
        chares_x: 8,
        chares_y: 8,
        decomp: Decomposition::Striped,
        topo: Topology::flat(nodes),
        q: 1.0,
        seed: 0xE2E,
        particle_bytes: 48.0,
        threads: 4,
    }
}

fn pjrt_backend() -> Option<Backend> {
    // also skips builds without the `pjrt` feature (stub engine)
    match Manifest::load_default().and_then(Engine::with_manifest) {
        Ok(engine) => Some(Backend::Pjrt(Arc::new(engine))),
        Err(e) => {
            eprintln!("SKIP pjrt: {e:#}");
            None
        }
    }
}

#[test]
fn verified_under_every_strategy_native() {
    for name in ["none", "greedy-refine", "diff-comm", "diff-coord", "metis", "parmetis"] {
        let mut app = PicApp::new(cfg(2_500, 4), Backend::Native).unwrap();
        let strat = make(name, StrategyParams::default()).unwrap();
        let driver = DriverConfig { iters: 12, lb_period: 4, ..Default::default() };
        let rep = run_app(&mut app, strat.as_ref(), &driver).unwrap();
        assert!(rep.verified, "verification failed under {name}");
    }
}

#[test]
fn verified_with_pjrt_backend_and_lb() {
    let Some(backend) = pjrt_backend() else { return };
    let mut app = PicApp::new(cfg(2_000, 4), backend).unwrap();
    let strat = make("diff-comm", StrategyParams::default()).unwrap();
    let driver = DriverConfig { iters: 10, lb_period: 5, ..Default::default() };
    let rep = run_app(&mut app, strat.as_ref(), &driver).unwrap();
    assert!(rep.verified);
    assert!(rep.total_migrations > 0, "expected some migrations");
}

#[test]
fn backends_agree_on_trajectories() {
    let Some(backend) = pjrt_backend() else { return };
    let mut native = PicApp::new(cfg(1_200, 2), Backend::Native).unwrap();
    let mut pjrt = PicApp::new(cfg(1_200, 2), backend).unwrap();
    for _ in 0..6 {
        step_once(&mut native).unwrap();
        step_once(&mut pjrt).unwrap();
    }
    for i in 0..native.state.len() {
        assert!((native.state.x[i] - pjrt.state.x[i]).abs() < 1e-9, "i={i}");
        assert!((native.state.y[i] - pjrt.state.y[i]).abs() < 1e-9, "i={i}");
    }
    // chare occupancy identical too
    assert_eq!(native.chare_particle_counts(), pjrt.chare_particle_counts());
}

#[test]
fn imbalance_wave_moves_across_pes() {
    // Fig 3's phenomenon: the particle mass sweeps rightward through
    // striped PEs over time.
    let mut app = PicApp::new(cfg(4_000, 4), Backend::Native).unwrap();
    let first_owner = {
        let counts = app.pe_particle_counts();
        counts.iter().enumerate().max_by_key(|(_, &c)| c).unwrap().0
    };
    // displacement is 5 cells/step; PE stripe width = 96/4 = 24 cells:
    // after ~8 steps the hotspot crosses into the next stripe
    for _ in 0..10 {
        step_once(&mut app).unwrap();
    }
    let later_owner = {
        let counts = app.pe_particle_counts();
        counts.iter().enumerate().max_by_key(|(_, &c)| c).unwrap().0
    };
    assert!(later_owner >= first_owner, "hotspot moved {first_owner} -> {later_owner}");
    assert_ne!(first_owner, later_owner, "hotspot should have crossed a stripe");
}

#[test]
fn diffusion_beats_no_lb_on_particle_balance() {
    let driver = DriverConfig { iters: 40, lb_period: 10, ..Default::default() };
    let avg_ratio = |strategy: &str| {
        let mut app = PicApp::new(cfg(4_000, 4), Backend::Native).unwrap();
        let s = make(strategy, StrategyParams::default()).unwrap();
        let rep = run_app(&mut app, s.as_ref(), &driver).unwrap();
        assert!(rep.verified);
        rep.records.iter().map(|r| r.work_max_avg).sum::<f64>() / rep.records.len() as f64
    };
    let none = avg_ratio("none");
    let diff = avg_ratio("diff-comm");
    assert!(diff < none, "diffusion {diff} !< none {none}");
}
