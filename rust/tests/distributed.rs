//! Distributed-protocol validation: the threaded handshake
//! (simnet::protocol) must produce exactly the pairings of the
//! round-synchronous sequential model used inside the strategies, over
//! randomized candidate structures — the evidence that the strategy's
//! stage 1 faithfully models a real distributed execution.

use difflb::simnet::protocol::distributed_select_neighbors;
use difflb::strategies::diffusion::neighbor::{select_neighbors, Candidates};
use difflb::util::rng::Rng;

fn random_candidates(n: usize, rng: &mut Rng) -> Candidates {
    (0..n)
        .map(|i| {
            let mut peers: Vec<u32> = (0..n as u32).filter(|&j| j != i as u32).collect();
            rng.shuffle(&mut peers);
            // some nodes only see a subset (sparse comm graphs)
            let keep = rng.range(1, peers.len().max(2));
            peers.truncate(keep);
            peers
        })
        .collect()
}

#[test]
fn equivalence_on_random_candidate_sets() {
    let mut rng = Rng::new(0xD157);
    for trial in 0..25 {
        let n = rng.range(2, 14);
        let k = rng.range(1, 6);
        let cands = random_candidates(n, &mut rng);
        let seq = select_neighbors(&cands, k, 24);
        let dist = distributed_select_neighbors(&cands, k, 24);
        assert_eq!(seq.adj, dist.adj, "trial {trial} n={n} k={k} cands={cands:?}");
    }
}

#[test]
fn equivalence_under_comm_derived_candidates() {
    use difflb::apps::stencil::{self, Decomposition};
    use difflb::strategies::diffusion::neighbor::comm_candidates;
    let mut inst = stencil::stencil_2d(24, 4, 4, Decomposition::Tiled);
    stencil::inject_noise(&mut inst, 0.4, 5);
    let node_map = inst.node_mapping();
    let cands = comm_candidates(&inst, &node_map);
    for k in [2, 4, 8] {
        let seq = select_neighbors(&cands, k, 32);
        let dist = distributed_select_neighbors(&cands, k, 32);
        assert_eq!(seq.adj, dist.adj, "k={k}");
        assert!(dist.is_symmetric());
        assert!(dist.max_degree() <= k);
    }
}

#[test]
fn larger_cluster_terminates_quickly() {
    let mut rng = Rng::new(7);
    let cands = random_candidates(32, &mut rng);
    let t = std::time::Instant::now();
    let g = distributed_select_neighbors(&cands, 4, 32);
    assert!(g.is_symmetric());
    assert!(t.elapsed().as_secs_f64() < 10.0, "protocol too slow");
}
