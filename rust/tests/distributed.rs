//! Distributed-protocol validation.
//!
//! Stage 1: the threaded handshake (simnet::protocol) must produce
//! exactly the pairings of the round-synchronous sequential model used
//! inside the strategies, over randomized candidate structures.
//!
//! Full pipeline: `distributed::DistDiffusion` — stages 1–3 plus
//! hierarchical refinement, every decision made per-node over real
//! messages — must produce **bit-identical** `Assignment`s to the
//! sequential `Diffusion` strategy across seeds, node counts and both
//! variants; and the node-partitioned distributed PIC driver must
//! report the same migration counts and modeled communication seconds
//! as the sequential driver. Together these validate that the
//! sequential implementation is a faithful model of the distributed
//! execution (the paper's strategy runs inside Charm++ this way).
//!
//! Set `DIFFLB_TEST_NODES` to re-run the pipeline equivalence at a
//! specific cluster size (CI sweeps {4, 8, 16}).

use difflb::apps::driver::{run_app, DriverConfig};
use difflb::apps::hotspot::{Hotspot, HotspotConfig};
use difflb::apps::pic::{Backend, InitMode, PicApp, PicConfig};
use difflb::apps::stencil::{self, Decomposition, StencilSim};
use difflb::apps::{App, StepCtx};
use difflb::distributed::driver::{run_hotspot_distributed, run_pic_distributed};
use difflb::distributed::DistDiffusion;
use difflb::model::{Instance, Topology};
use difflb::simnet::protocol::distributed_select_neighbors;
use difflb::simnet::{Cluster, Comm};
use difflb::strategies::diffusion::neighbor::{comm_candidates, select_neighbors, Candidates};
use difflb::strategies::diffusion::{Diffusion, Variant};
use difflb::strategies::{LoadBalancer, StrategyParams};
use difflb::util::rng::Rng;

fn random_candidates(n: usize, rng: &mut Rng) -> Candidates {
    (0..n)
        .map(|i| {
            let mut peers: Vec<u32> = (0..n as u32).filter(|&j| j != i as u32).collect();
            rng.shuffle(&mut peers);
            // some nodes only see a subset (sparse comm graphs)
            let keep = rng.range(1, peers.len().max(2));
            peers.truncate(keep);
            peers
        })
        .collect()
}

#[test]
fn equivalence_on_random_candidate_sets() {
    let mut rng = Rng::new(0xD157);
    for trial in 0..25 {
        let n = rng.range(2, 14);
        let k = rng.range(1, 6);
        let cands = random_candidates(n, &mut rng);
        let seq = select_neighbors(&cands, k, 24);
        let dist = distributed_select_neighbors(&cands, k, 24);
        assert_eq!(seq.adj, dist.adj, "trial {trial} n={n} k={k} cands={cands:?}");
    }
}

#[test]
fn equivalence_under_comm_derived_candidates() {
    let mut inst = stencil::stencil_2d(24, 4, 4, Decomposition::Tiled);
    stencil::inject_noise(&mut inst, 0.4, 5);
    let node_map = inst.node_mapping();
    let cands = comm_candidates(&inst, &node_map);
    for k in [2, 4, 8] {
        let seq = select_neighbors(&cands, k, 32);
        let dist = distributed_select_neighbors(&cands, k, 32);
        assert_eq!(seq.adj, dist.adj, "k={k}");
        assert!(dist.is_symmetric());
        assert!(dist.max_degree() <= k);
    }
}

#[test]
fn larger_cluster_terminates_quickly() {
    let mut rng = Rng::new(7);
    let cands = random_candidates(32, &mut rng);
    let t = std::time::Instant::now();
    let g = distributed_select_neighbors(&cands, 4, 32);
    assert!(g.is_symmetric());
    assert!(t.elapsed().as_secs_f64() < 10.0, "protocol too slow");
}

// ---------------------------------------------------------------------
// Full pipeline: bit-identical assignments to the sequential strategy.

fn noisy_stencil(px: usize, py: usize, seed: u64) -> Instance {
    let mut inst = stencil::stencil_2d(24, px, py, Decomposition::Tiled);
    stencil::inject_noise(&mut inst, 0.4, seed);
    inst
}

fn assert_pipeline_matches(inst: &Instance, variant: Variant, ctx: &str) {
    let params = StrategyParams::default();
    let (seq, dist): (Box<dyn LoadBalancer>, DistDiffusion) = match variant {
        Variant::Communication => (
            Box::new(Diffusion::communication(params)),
            DistDiffusion::communication(params),
        ),
        Variant::Coordinate => (
            Box::new(Diffusion::coordinate(params)),
            DistDiffusion::coordinate(params),
        ),
    };
    let s = seq.rebalance(inst);
    let d = dist.rebalance(inst);
    assert_eq!(s.mapping, d.mapping, "{ctx}: distributed pipeline diverged");
}

#[test]
fn pipeline_bit_identical_across_seeds_nodes_variants() {
    for &(px, py) in &[(2usize, 2usize), (4, 2), (4, 4)] {
        for seed in [11u64, 12, 13] {
            let inst = noisy_stencil(px, py, seed);
            for variant in [Variant::Communication, Variant::Coordinate] {
                assert_pipeline_matches(
                    &inst,
                    variant,
                    &format!("nodes={} seed={seed} {variant:?}", px * py),
                );
            }
        }
    }
}

/// Deterministic heterogeneous speed vector: cycles a fixed palette so
/// every test site perturbs the same way.
fn hetero_speeds(n_pes: usize, salt: u64) -> Vec<f64> {
    const PALETTE: [f64; 5] = [1.0, 2.0, 0.5, 1.5, 0.25];
    (0..n_pes)
        .map(|pe| PALETTE[(pe + salt as usize) % PALETTE.len()])
        .collect()
}

#[test]
fn pipeline_bit_identical_hetero_speeds() {
    // ISSUE 5: the seq-vs-dist per-iteration equality matrix extended
    // with heterogeneous speed vectors — seeds x node counts x both
    // diffusion variants, each node normalizing its own load scalar by
    // its locally derived capacity.
    for &(px, py) in &[(2usize, 2usize), (4, 2), (4, 4)] {
        for seed in [31u64, 32, 33] {
            let mut inst = noisy_stencil(px, py, seed);
            inst.topo =
                inst.topo.clone().with_pe_speeds(hetero_speeds(px * py, seed));
            for variant in [Variant::Communication, Variant::Coordinate] {
                assert_pipeline_matches(
                    &inst,
                    variant,
                    &format!("hetero nodes={} seed={seed} {variant:?}", px * py),
                );
            }
        }
    }
}

#[test]
fn pipeline_bit_identical_hetero_with_pes_per_node() {
    // heterogeneous speeds + §III-D refinement: 8 nodes x 2 PEs with
    // per-PE speeds, exercising weighted capacities AND weighted
    // PE-time refinement through the PE-assignment exchange.
    for seed in [41u64, 42] {
        let base = noisy_stencil(4, 4, seed);
        let inst = Instance::new(
            base.loads.clone(),
            base.coords.clone(),
            base.graph.clone(),
            base.mapping.clone(),
            Topology::new(8, 2).with_pe_speeds(hetero_speeds(16, seed)),
        );
        for variant in [Variant::Communication, Variant::Coordinate] {
            assert_pipeline_matches(
                &inst,
                variant,
                &format!("hetero 8x2 seed={seed} {variant:?}"),
            );
        }
    }
}

#[test]
fn pipeline_bit_identical_hetero_at_env_node_count() {
    // CI sweeps DIFFLB_TEST_NODES over {4, 8, 16} — heterogeneous twin
    // of pipeline_bit_identical_at_env_node_count.
    let n: usize = std::env::var("DIFFLB_TEST_NODES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let mut inst = stencil::stencil_2d(48, n, 1, Decomposition::Tiled);
    stencil::inject_noise(&mut inst, 0.5, 0xBE7 + n as u64);
    inst.topo = inst.topo.clone().with_pe_speeds(hetero_speeds(n, n as u64));
    for variant in [Variant::Communication, Variant::Coordinate] {
        assert_pipeline_matches(&inst, variant, &format!("hetero env nodes={n} {variant:?}"));
    }
}

#[test]
fn pipeline_bit_identical_with_pes_per_node() {
    // Hierarchical topology: 8 nodes x 2 PEs — exercises the §III-D
    // refinement + PE-assignment exchange.
    for seed in [21u64, 22] {
        let base = noisy_stencil(4, 4, seed);
        let inst = Instance::new(
            base.loads.clone(),
            base.coords.clone(),
            base.graph.clone(),
            base.mapping.clone(),
            Topology::new(8, 2),
        );
        for variant in [Variant::Communication, Variant::Coordinate] {
            assert_pipeline_matches(&inst, variant, &format!("8x2 seed={seed} {variant:?}"));
        }
    }
}

#[test]
fn pipeline_bit_identical_at_env_node_count() {
    // CI sweeps DIFFLB_TEST_NODES over {4, 8, 16}.
    let n: usize = std::env::var("DIFFLB_TEST_NODES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let mut inst = stencil::stencil_2d(48, n, 1, Decomposition::Tiled);
    stencil::inject_noise(&mut inst, 0.5, 0xE27 + n as u64);
    for variant in [Variant::Communication, Variant::Coordinate] {
        assert_pipeline_matches(&inst, variant, &format!("env nodes={n} {variant:?}"));
    }
}

#[test]
fn pipeline_plan_matches_sequential_intermediates() {
    let inst = noisy_stencil(4, 2, 31);
    let params = StrategyParams::default();
    let (sneigh, squotas) = Diffusion::communication(params).plan(&inst);
    let (dneigh, dquotas) = DistDiffusion::communication(params).plan(&inst);
    assert_eq!(sneigh.adj, dneigh.adj, "stage-1 pairings diverged");
    assert_eq!(squotas, dquotas, "stage-2 quotas diverged");
}

#[test]
fn pipeline_tracks_sequential_over_stencil_rounds() {
    // Multi-round agreement on an evolving workload: apply the
    // (identical) assignment each round and re-noise the loads. The
    // stencil steps through its App-trait surface — the same one the
    // generic driver uses.
    let mut sim = StencilSim::new(24, 4, 2, Decomposition::Tiled, 0.4, 77);
    let params = StrategyParams::default();
    let seq = Diffusion::communication(params);
    let dist = DistDiffusion::communication(params);
    let mut ctx = StepCtx::default();
    for round in 0..3 {
        ctx.moved.clear();
        sim.step(&mut ctx).unwrap();
        let inst = sim.build_instance();
        let s = seq.rebalance(&inst);
        let d = dist.rebalance(&inst);
        assert_eq!(s.mapping, d.mapping, "round {round}");
        sim.apply(&s);
    }
}

// ---------------------------------------------------------------------
// End-to-end distributed PIC: same migrations + modeled comm seconds.

fn pic_cfg(topo: Topology) -> PicConfig {
    PicConfig {
        grid: 64,
        n_particles: 2_000,
        k: 1,
        m: 1,
        init: InitMode::Geometric { rho: 0.9 },
        chares_x: 4,
        chares_y: 4,
        decomp: Decomposition::Striped,
        topo,
        q: 1.0,
        seed: 11,
        particle_bytes: 48.0,
        threads: 2,
    }
}

fn assert_driver_equivalence_with(topo: Topology, driver: &DriverConfig) {
    let cfg = pic_cfg(topo);
    let params = StrategyParams::default();
    let seq = {
        let mut app = PicApp::new(cfg.clone(), Backend::Native).unwrap();
        let strat = Diffusion::communication(params);
        run_app(&mut app, &strat, driver).unwrap()
    };
    let dist = run_pic_distributed(&cfg, Variant::Communication, params, driver).unwrap();
    assert!(seq.verified, "sequential physics failed");
    assert!(dist.verified, "distributed physics failed");
    assert_eq!(seq.records.len(), dist.records.len());
    assert_eq!(seq.total_migrations, dist.total_migrations, "migration totals diverged");
    for (s, d) in seq.records.iter().zip(&dist.records) {
        assert_eq!(s.migrations, d.migrations, "iter {}: migrations", s.iter);
        assert_eq!(s.work_max_avg, d.work_max_avg, "iter {}: imbalance", s.iter);
        assert_eq!(s.time_max_avg, d.time_max_avg, "iter {}: time imbalance", s.iter);
        assert_eq!(s.comm_max_s, d.comm_max_s, "iter {}: modeled comm max", s.iter);
        assert_eq!(s.comm_avg_s, d.comm_avg_s, "iter {}: modeled comm avg", s.iter);
        assert_eq!(s.node_work, d.node_work, "iter {}: node work", s.iter);
    }
}

fn assert_driver_equivalence(topo: Topology) {
    let driver = DriverConfig {
        iters: 12,
        lb_period: 4,
        deterministic_loads: true,
        ..Default::default()
    };
    assert_driver_equivalence_with(topo, &driver);
}

#[test]
fn distributed_pic_matches_sequential_driver_flat() {
    assert_driver_equivalence(Topology::flat(4));
}

#[test]
fn distributed_pic_matches_sequential_driver_hierarchical() {
    assert_driver_equivalence(Topology::new(2, 2));
}

#[test]
fn distributed_pic_matches_sequential_driver_hetero() {
    assert_driver_equivalence(Topology::flat(4).with_pe_speeds(vec![1.0, 2.0, 0.5, 1.5]));
    assert_driver_equivalence(
        Topology::new(2, 2).with_pe_speeds(vec![2.0, 1.0, 1.0, 0.5]),
    );
}

#[test]
fn distributed_pic_matches_sequential_driver_under_speed_noise() {
    // Time-varying speed schedule: the root evaluates the same pure
    // (seed, iter, pe) perturbation the sequential driver does and
    // ships the effective speeds inside the .lbi broadcast — every
    // per-iteration record, including time imbalance, must still match
    // bit for bit.
    use difflb::model::SpeedSchedule;
    let driver = DriverConfig {
        iters: 12,
        lb_period: 4,
        deterministic_loads: true,
        speed_schedule: SpeedSchedule { noise: 0.3, period: 2, seed: 77 },
        ..Default::default()
    };
    assert_driver_equivalence_with(
        Topology::flat(4).with_pe_speeds(vec![1.0, 2.0, 0.5, 1.5]),
        &driver,
    );
}

// ---------------------------------------------------------------------
// End-to-end distributed hotspot: the driver generalizes beyond PIC —
// the second node-partitionable app must match the sequential driver
// the same way (migrations, imbalance, modeled comm seconds).

fn assert_hotspot_driver_equivalence(topo: Topology) {
    let cfg = HotspotConfig { topo, ..Default::default() };
    let driver = DriverConfig {
        iters: 12,
        lb_period: 4,
        deterministic_loads: true,
        ..Default::default()
    };
    let params = StrategyParams::default();
    let seq = {
        let mut app = Hotspot::new(cfg.clone()).unwrap();
        let strat = Diffusion::communication(params);
        run_app(&mut app, &strat, &driver).unwrap()
    };
    let dist = run_hotspot_distributed(&cfg, Variant::Communication, params, &driver).unwrap();
    assert!(seq.verified && dist.verified);
    assert_eq!(seq.records.len(), dist.records.len());
    assert_eq!(seq.total_migrations, dist.total_migrations, "migration totals diverged");
    for (s, d) in seq.records.iter().zip(&dist.records) {
        assert_eq!(s.migrations, d.migrations, "iter {}: migrations", s.iter);
        assert_eq!(s.work_max_avg, d.work_max_avg, "iter {}: imbalance", s.iter);
        assert_eq!(s.time_max_avg, d.time_max_avg, "iter {}: time imbalance", s.iter);
        assert_eq!(s.comm_max_s, d.comm_max_s, "iter {}: modeled comm max", s.iter);
        assert_eq!(s.comm_avg_s, d.comm_avg_s, "iter {}: modeled comm avg", s.iter);
        assert_eq!(s.node_work, d.node_work, "iter {}: node work", s.iter);
    }
}

#[test]
fn distributed_hotspot_matches_sequential_driver_flat() {
    assert_hotspot_driver_equivalence(Topology::flat(4));
}

#[test]
fn distributed_hotspot_matches_sequential_driver_hierarchical() {
    assert_hotspot_driver_equivalence(Topology::new(2, 2));
}

#[test]
fn distributed_hotspot_matches_sequential_driver_hetero() {
    assert_hotspot_driver_equivalence(
        Topology::flat(4).with_pe_speeds(vec![0.5, 1.0, 2.0, 1.0]),
    );
}

#[test]
fn distributed_pic_verifies_without_lb() {
    // lb_period 0: pure distributed stepping + exchange, no pipeline.
    let cfg = pic_cfg(Topology::flat(4));
    let driver = DriverConfig { iters: 10, lb_period: 0, ..Default::default() };
    let rep =
        run_pic_distributed(&cfg, Variant::Communication, StrategyParams::default(), &driver)
            .unwrap();
    assert!(rep.verified);
    assert_eq!(rep.total_migrations, 0);
    assert_eq!(rep.records.len(), 10);
}

// ---------------------------------------------------------------------
// simnet semantics: out-of-phase buffering, barrier, termination.

#[test]
fn recv_tagged_survives_randomized_interleavings() {
    // Each rank sends every peer one message per phase, in a
    // rank-seeded shuffled phase order; receivers drain phases in
    // canonical order. The pending buffer must deliver every message to
    // its phase regardless of the interleaving. Multiple seeds.
    const PHASES: u32 = 5;
    for seed in [1u64, 2, 3, 4] {
        let ok = Cluster::run(4, move |rank, mut comm| {
            let mut rng = Rng::new(seed * 1000 + rank as u64);
            let mut order: Vec<u32> = (0..PHASES).collect();
            rng.shuffle(&mut order);
            for &ph in &order {
                for to in 0..4u32 {
                    if to != rank {
                        comm.send(to, 0x0900_0000 | ph, vec![rank as u8, ph as u8]);
                    }
                }
            }
            for ph in 0..PHASES {
                let msgs = comm
                    .recv_tagged(0x0900_0000 | ph, 3, Comm::TIMEOUT)
                    .expect("phase exchange complete");
                if msgs.len() != 3 || msgs.iter().any(|m| m.data[1] != ph as u8) {
                    return false;
                }
            }
            true
        });
        assert!(ok.iter().all(|&b| b), "seed {seed}");
    }
}

#[test]
fn barrier_separates_phases() {
    // After barrier i completes, every rank's phase-i token must
    // already be deliverable — the barrier is a true synchronization
    // point, not advisory.
    let ok = Cluster::run(3, |rank, mut comm| {
        for phase in 0..3u32 {
            for to in 0..3u32 {
                if to != rank {
                    comm.send(to, 0x0A00_0000 | phase, vec![phase as u8]);
                }
            }
            comm.barrier(0x0B00_0000 | phase).expect("barrier survives");
            // mpsc preserves per-sender order: each peer's token was
            // sent before its barrier announcement, so both are already
            // queued (or parked) once the barrier completes.
            let msgs = comm
                .recv_tagged(0x0A00_0000 | phase, 2, std::time::Duration::from_secs(5))
                .expect("tokens arrive");
            if msgs.len() != 2 {
                return false;
            }
        }
        true
    });
    assert!(ok.iter().all(|&b| b));
}

#[test]
fn cluster_returns_results_in_rank_order() {
    let r = Cluster::run(6, |rank, _comm| rank * 10);
    assert_eq!(r, vec![0, 10, 20, 30, 40, 50]);
}

#[test]
#[should_panic(expected = "simnode panicked")]
fn cluster_propagates_worker_panics() {
    Cluster::run(3, |rank, _comm| {
        if rank == 1 {
            panic!("worker died");
        }
        rank
    });
}
