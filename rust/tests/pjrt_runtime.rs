//! PJRT runtime integration: the AOT HLO artifacts must load, compile,
//! and agree numerically with the native Rust implementation of the
//! same math (which pytest separately validates against the pure-jnp
//! oracle — closing the three-way loop kernel ⇄ oracle ⇄ rust).
//!
//! Requires `make artifacts` to have run (skips gracefully otherwise,
//! but `make test` always builds artifacts first).

use difflb::apps::pic::init::{initialize, InitMode};
use difflb::apps::pic::push::native_push;
use difflb::runtime::{Engine, Manifest, PicBatch};

fn engine_or_skip() -> Option<Engine> {
    match Manifest::load_default().and_then(Engine::with_manifest) {
        Ok(engine) => Some(engine),
        // also skips builds without the `pjrt` feature (stub engine)
        Err(e) => {
            eprintln!("SKIP: PJRT unavailable ({e:#}); run `make artifacts` and build with --features pjrt");
            None
        }
    }
}

fn batch(n: usize, seed: u64) -> PicBatch {
    let pop = initialize(InitMode::Geometric { rho: 0.9 }, n, 64, 2, 1, 1.0, seed);
    PicBatch { x: pop.x, y: pop.y, vx: pop.vx, vy: pop.vy, q: pop.q }
}

#[test]
fn pjrt_matches_native_exactly_one_step() {
    let Some(engine) = engine_or_skip() else { return };
    let mut a = batch(1024, 1);
    let mut b = a.clone();
    engine.pic_push(&mut a, 64.0, 1.0).unwrap();
    native_push(&mut b, 64.0, 1.0, 4);
    for i in 0..a.len() {
        assert!((a.x[i] - b.x[i]).abs() < 1e-12, "x[{i}] {} vs {}", a.x[i], b.x[i]);
        assert!((a.y[i] - b.y[i]).abs() < 1e-12);
        assert!((a.vx[i] - b.vx[i]).abs() < 1e-12);
        assert!((a.vy[i] - b.vy[i]).abs() < 1e-12);
    }
}

#[test]
fn pjrt_handles_unaligned_batches_with_padding() {
    let Some(engine) = engine_or_skip() else { return };
    // 1500 particles: not a multiple of any artifact batch size
    let mut a = batch(1500, 2);
    let mut b = a.clone();
    engine.pic_push(&mut a, 64.0, 1.0).unwrap();
    native_push(&mut b, 64.0, 1.0, 4);
    assert_eq!(a.len(), 1500);
    for i in 0..a.len() {
        assert!((a.x[i] - b.x[i]).abs() < 1e-12, "i={i}");
        assert!((a.y[i] - b.y[i]).abs() < 1e-12, "i={i}");
    }
}

#[test]
fn pjrt_multi_step_deterministic_displacement() {
    let Some(engine) = engine_or_skip() else { return };
    let (k, m, l) = (2u32, 1u32, 64.0);
    let pop = initialize(InitMode::Geometric { rho: 0.9 }, 2048, 64, k, m, 1.0, 3);
    let x0 = pop.x.clone();
    let y0 = pop.y.clone();
    let mut b = PicBatch { x: pop.x, y: pop.y, vx: pop.vx, vy: pop.vy, q: pop.q };
    let steps = 5;
    for _ in 0..steps {
        engine.pic_push(&mut b, l, 1.0).unwrap();
    }
    for i in 0..b.len() {
        let ex = (x0[i] + steps as f64 * (2 * k + 1) as f64).rem_euclid(l);
        let ey = (y0[i] + steps as f64 * m as f64).rem_euclid(l);
        assert!((b.x[i] - ex).abs() < 1e-6, "x[{i}] {} vs {ex}", b.x[i]);
        assert!((b.y[i] - ey).abs() < 1e-6);
    }
}

#[test]
fn stencil_artifact_matches_reference() {
    let Some(engine) = engine_or_skip() else { return };
    let (r, c) = (256usize, 256usize);
    let grid: Vec<f64> = (0..r * c).map(|i| ((i * 2654435761) % 1000) as f64 / 1000.0).collect();
    let alpha = 0.2;
    let out = engine.stencil_step(&grid, r, c, alpha).unwrap();
    // rust reference: periodic 5-point jacobi
    for row in [0usize, 1, r / 2, r - 1] {
        for col in [0usize, 1, c / 2, c - 1] {
            let at = |rr: usize, cc: usize| grid[(rr % r) * c + (cc % c)];
            let expect = (1.0 - 4.0 * alpha) * at(row, col)
                + alpha
                    * (at(row + r - 1, col)
                        + at(row + 1, col)
                        + at(row, col + c - 1)
                        + at(row, col + 1));
            let got = out[row * c + col];
            assert!((got - expect).abs() < 1e-12, "({row},{col}): {got} vs {expect}");
        }
    }
    // mean conservation
    let mean_in: f64 = grid.iter().sum::<f64>() / grid.len() as f64;
    let mean_out: f64 = out.iter().sum::<f64>() / out.len() as f64;
    assert!((mean_in - mean_out).abs() < 1e-12);
}

#[test]
fn manifest_covers_expected_artifacts() {
    let Some(engine) = engine_or_skip() else { return };
    let m = engine.manifest();
    assert!(m.pic_batch_sizes().len() >= 2, "want multiple pic batch sizes");
    assert!(m.stencil_for(256, 256).is_some());
    assert!(m.pic_epoch(5).is_some(), "fused-epoch artifact missing");
}
