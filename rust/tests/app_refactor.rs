//! Golden-assignment lock for the App-trait/driver redesign: the
//! generic `run_app` loop must reproduce the **pre-refactor** drivers
//! bit for bit.
//!
//! `legacy_run_pic` below is a frozen transliteration of the old
//! PIC-only `run_pic` loop (usize per-PE particle counts gathered by
//! iterating particles, per-PE node aggregation, count-based
//! deterministic loads, the app-side crossing merge) written against
//! `PicApp`'s public surface; `legacy_stencil_rounds` freezes the old
//! `StencilSim::advance` + manual-rebalance loop. The tests assert the
//! generic driver's generalized arithmetic (f64 work units accumulated
//! per object) produces identical modeled communication seconds,
//! imbalance ratios, migration counts, and final assignments for both
//! workloads and both diffusion variants — so the refactor changed the
//! shape of the code, not one bit of its decisions.

use difflb::apps::driver::{account_step_comm, run_app, DriverConfig};
use difflb::apps::pic::{Backend, InitMode, PicApp, PicConfig};
use difflb::apps::stencil::{self, Decomposition, StencilSim, HALO_BYTES};
use difflb::apps::{App, StepCtx};
use difflb::model::graph::sort_sum_merge;
use difflb::model::{evaluate, Topology, TrafficRecorder};
use difflb::simnet::CostTracker;
use difflb::strategies::{make, LoadBalancer, StrategyParams};
use difflb::util::rng::Rng;
use difflb::util::stats::Summary;

fn pic_cfg() -> PicConfig {
    PicConfig {
        grid: 64,
        n_particles: 2_500,
        k: 1,
        m: 1,
        init: InitMode::Geometric { rho: 0.9 },
        chares_x: 8,
        chares_y: 8,
        decomp: Decomposition::Striped,
        topo: Topology::flat(4),
        q: 1.0,
        seed: 0x60D,
        particle_bytes: 48.0,
        threads: 2,
    }
}

/// One legacy iteration row (the timing-independent fields).
struct LegacyRecord {
    max_avg: f64,
    node_particles: Vec<usize>,
    comm_max_s: f64,
    comm_avg_s: f64,
    migrations: usize,
}

/// Frozen pre-refactor PIC driver loop (see module docs).
fn legacy_run_pic(
    app: &mut PicApp,
    strategy: &dyn LoadBalancer,
    cfg: &DriverConfig,
) -> (Vec<LegacyRecord>, usize) {
    let topo = app.cfg.topo.clone();
    let neighbor_pairs = app.chare_neighbor_pairs();
    let mut tracker = CostTracker::new(topo.n_nodes);
    let mut payload: Vec<(u32, u32, f64)> = Vec::new();
    let mut consumed: Vec<bool> = Vec::new();
    let mut records = Vec::new();
    let mut total_migrations = 0usize;
    let mut ctx = StepCtx::default();
    for iter in 0..cfg.iters {
        ctx.moved.clear();
        app.step(&mut ctx).unwrap();
        // the old PicApp::step returned the crossing log already merged
        // per directed pair (same stable sort-merge, same input order)
        sort_sum_merge(&mut ctx.moved);

        let pe_counts = app.pe_particle_counts();
        let mut node_particles = vec![0usize; topo.n_nodes];
        for (pe, &cnt) in pe_counts.iter().enumerate() {
            node_particles[topo.node_of_pe(pe as u32) as usize] += cnt;
        }
        account_step_comm(
            &topo,
            &app.chare_to_pe,
            &neighbor_pairs,
            &ctx.moved,
            &mut payload,
            &mut consumed,
            &mut tracker,
        );
        let comm_times = tracker.comm_times(&cfg.net);
        let pe_summary =
            Summary::of(&pe_counts.iter().map(|&c| c as f64).collect::<Vec<_>>());
        let mut rec = LegacyRecord {
            max_avg: pe_summary.max_avg_ratio(),
            node_particles,
            comm_max_s: comm_times.iter().cloned().fold(0.0, f64::max),
            comm_avg_s: comm_times.iter().sum::<f64>() / topo.n_nodes as f64,
            migrations: 0,
        };

        if cfg.lb_period > 0 && (iter + 1) % cfg.lb_period == 0 {
            let mut inst = app.build_instance();
            if cfg.deterministic_loads {
                inst.loads =
                    app.chare_particle_counts().iter().map(|&c| c as f64).collect();
            }
            let asg = strategy.rebalance(&inst);
            let metrics = evaluate(&inst, &asg);
            app.apply_assignment(&asg);
            rec.migrations = metrics.migrations;
            total_migrations += metrics.migrations;
        }
        records.push(rec);
    }
    (records, total_migrations)
}

fn assert_pic_golden(strategy_name: &str) {
    let driver = DriverConfig {
        iters: 12,
        lb_period: 4,
        deterministic_loads: true,
        ..Default::default()
    };
    let (legacy, legacy_migr, legacy_map) = {
        let mut app = PicApp::new(pic_cfg(), Backend::Native).unwrap();
        let strat = make(strategy_name, StrategyParams::default()).unwrap();
        let (recs, migr) = legacy_run_pic(&mut app, strat.as_ref(), &driver);
        (recs, migr, app.chare_to_pe.clone())
    };
    let (report, new_map) = {
        let mut app = PicApp::new(pic_cfg(), Backend::Native).unwrap();
        let strat = make(strategy_name, StrategyParams::default()).unwrap();
        let rep = run_app(&mut app, strat.as_ref(), &driver).unwrap();
        (rep, app.chare_to_pe.clone())
    };
    assert!(report.verified);
    assert_eq!(report.records.len(), legacy.len());
    for (l, n) in legacy.iter().zip(&report.records) {
        assert_eq!(l.max_avg, n.work_max_avg, "iter {}: imbalance", n.iter);
        assert_eq!(l.comm_max_s, n.comm_max_s, "iter {}: comm max", n.iter);
        assert_eq!(l.comm_avg_s, n.comm_avg_s, "iter {}: comm avg", n.iter);
        assert_eq!(l.migrations, n.migrations, "iter {}: migrations", n.iter);
        let legacy_work: Vec<f64> =
            l.node_particles.iter().map(|&c| c as f64).collect();
        assert_eq!(legacy_work, n.node_work, "iter {}: node work", n.iter);
    }
    assert_eq!(legacy_migr, report.total_migrations, "total migrations");
    assert_eq!(legacy_map, new_map, "final assignment diverged from pre-refactor");
}

#[test]
fn pic_golden_assignments_diff_comm() {
    assert_pic_golden("diff-comm");
}

#[test]
fn pic_golden_assignments_diff_coord() {
    assert_pic_golden("diff-coord");
}

/// Frozen pre-refactor stencil loop: `StencilSim::advance` (load
/// re-roll + halo record + incremental graph refresh) followed by a
/// manual rebalance each round.
fn legacy_stencil_rounds(
    strategy: &dyn LoadBalancer,
    rounds: usize,
) -> (Vec<usize>, Vec<u32>, Vec<f64>) {
    let (side, px, py, noise, seed) = (16usize, 2usize, 2usize, 0.4f64, 0x5EED_u64);
    let mut inst = stencil::stencil_2d(side, px, py, Decomposition::Tiled);
    let mut recorder = TrafficRecorder::new(inst.n_objects());
    let mut rng = Rng::new(seed);
    let mut migrations = Vec::new();
    for _ in 0..rounds {
        for l in inst.loads.iter_mut() {
            *l = 1.0 + noise * (2.0 * rng.f64() - 1.0);
        }
        {
            let (graph, rec) = (&inst.graph, &mut recorder);
            for a in 0..graph.n {
                for &b in graph.neighbors(a) {
                    if (a as u32) < b {
                        rec.record(a as u32, b, HALO_BYTES);
                    }
                }
            }
        }
        inst.graph.update_from_recorder(&mut recorder);
        let asg = strategy.rebalance(&inst);
        migrations.push(evaluate(&inst, &asg).migrations);
        inst.mapping.clone_from(&asg.mapping);
    }
    (migrations, inst.mapping.clone(), inst.loads.clone())
}

fn assert_stencil_golden(strategy_name: &str) {
    let rounds = 6;
    let legacy_strat = make(strategy_name, StrategyParams::default()).unwrap();
    let (legacy_migr, legacy_map, legacy_loads) =
        legacy_stencil_rounds(legacy_strat.as_ref(), rounds);

    let mut sim = StencilSim::new(16, 2, 2, Decomposition::Tiled, 0.4, 0x5EED);
    let strat = make(strategy_name, StrategyParams::default()).unwrap();
    let driver = DriverConfig { iters: rounds, lb_period: 1, ..Default::default() };
    let report = run_app(&mut sim, strat.as_ref(), &driver).unwrap();

    let new_migr: Vec<usize> = report.records.iter().map(|r| r.migrations).collect();
    assert_eq!(legacy_migr, new_migr, "per-round migrations diverged");
    assert_eq!(legacy_map, sim.inst.mapping, "final assignment diverged");
    assert_eq!(legacy_loads, sim.inst.loads, "rng stream diverged");
}

#[test]
fn stencil_golden_assignments_diff_comm() {
    assert_stencil_golden("diff-comm");
}

#[test]
fn stencil_golden_assignments_diff_coord() {
    assert_stencil_golden("diff-coord");
}
