//! Guard rails for the SIMD + SoA hot-path overhaul: the vectorized
//! particle push, the branchless stage-3 scoring kernels, the
//! sorted-by-node SoA candidate pools, and the binary `.lbi` codec must
//! all be **bit-identical** (or byte-identical, for the codec) to the
//! pre-PR scalar implementations. In the style of
//! `rust/tests/hetero_identity.rs`, the replaced decision bodies are
//! FROZEN below, verbatim — the `rem_euclid` grid charge, the scalar
//! sequential push loop, the branchy by-node stage-3 selection, the
//! scan-built §III-D member lists, the per-line `format!` text
//! serializer — and compared against the live implementations over
//! randomized instances across uniform, mixed-speed, and noisy-speed
//! topologies.
//!
//! The python twin `tools/crosscheck_simd.py` cross-simulates the same
//! arithmetic identities (mod-2 wrap, masked accumulation, counting
//! sort, varint/CSR round-trip) in-container where no Rust toolchain
//! exists.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use difflb::apps::pic::init::{initialize, InitMode, DT};
use difflb::apps::pic::push::{native_push, push_one};
use difflb::model::{decode_lbi, encode_lbi, CommGraph, Instance, Topology};
use difflb::runtime::PicBatch;
use difflb::strategies::diffusion::hierarchical::{assign_pes, assign_pes_node};
use difflb::strategies::diffusion::object_selection::{select_comm, select_coord};
use difflb::strategies::diffusion::virtual_lb::Quotas;
use difflb::util::rng::Rng;

// ===================================================== frozen legacy

/// Frozen pre-SIMD grid charge: `rem_euclid`-based mod-2 wrap.
fn legacy_grid_charge(x: f64, q: f64) -> f64 {
    q * (1.0 - 2.0 * (x.rem_euclid(2.0)))
}

/// Frozen pre-SIMD particle push (identical to the live [`push_one`]
/// except for the `rem_euclid` grid charge — the periodic position wrap
/// was already branchless in the seed).
#[allow(clippy::too_many_arguments)]
fn legacy_push_one(
    x: f64,
    y: f64,
    vx: f64,
    vy: f64,
    q: f64,
    l: f64,
    big_q: f64,
) -> (f64, f64, f64, f64) {
    const MASS_INV: f64 = 1.0;
    let cx = x.floor();
    let cy = y.floor();
    let rel_x = x - cx;
    let rel_y = y - cy;
    let q_left = legacy_grid_charge(cx, big_q);
    let q_right = -q_left;

    fn corner(xd: f64, yd: f64, qp: f64, qg: f64) -> (f64, f64) {
        let r2 = xd * xd + yd * yd;
        let f = (qp * qg) / (r2 * r2.sqrt());
        (f * xd, f * yd)
    }

    let (fx_tl, fy_tl) = corner(rel_x, rel_y, q, q_left);
    let (fx_bl, fy_bl) = corner(rel_x, 1.0 - rel_y, q, q_left);
    let (fx_tr, fy_tr) = corner(1.0 - rel_x, rel_y, q, q_right);
    let (fx_br, fy_br) = corner(1.0 - rel_x, 1.0 - rel_y, q, q_right);

    let ax = (fx_tl + fx_bl - fx_tr - fx_br) * MASS_INV;
    let ay = (fy_tl - fy_bl + fy_tr - fy_br) * MASS_INV;

    let xu = x + vx * DT + 0.5 * ax * (DT * DT);
    let yu = y + vy * DT + 0.5 * ay * (DT * DT);
    let xn = xu - l * (xu / l).floor();
    let yn = yu - l * (yu / l).floor();
    (xn, yn, vx + ax * DT, vy + ay * DT)
}

/// Frozen sequential whole-batch push (the seed's threads == 1 loop).
fn legacy_push_batch(b: &mut PicBatch, l: f64, big_q: f64) {
    for i in 0..b.len() {
        let (xn, yn, vxn, vyn) =
            legacy_push_one(b.x[i], b.y[i], b.vx[i], b.vy[i], b.q[i], l, big_q);
        b.x[i] = xn;
        b.y[i] = yn;
        b.vx[i] = vxn;
        b.vy[i] = vyn;
    }
}

/// Frozen max-heap entry — same total_cmp ordering as the live one.
#[derive(Debug, Clone, Copy)]
struct FEntry {
    key: f64,
    tie: f64,
    obj: u32,
}
impl PartialEq for FEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for FEntry {}
impl PartialOrd for FEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for FEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.key
            .total_cmp(&other.key)
            .then(other.tie.total_cmp(&self.tie))
            .then(other.obj.cmp(&self.obj))
    }
}

fn legacy_quota_floor(inst: &Instance) -> f64 {
    if inst.topo.is_uniform() {
        0.01 * inst.loads.iter().sum::<f64>() / inst.topo.n_nodes.max(1) as f64
    } else {
        let total_time: f64 = inst.node_times(&inst.mapping).iter().sum();
        0.01 * total_time / inst.topo.n_nodes.max(1) as f64
    }
}

fn legacy_eff_load(inst: &Instance, i: usize, load: f64) -> f64 {
    if inst.topo.is_uniform() {
        load
    } else {
        load / inst.topo.node_capacity(i as u32)
    }
}

fn legacy_sorted_quota(row: &[(u32, f64)], floor: f64) -> Vec<(u32, f64)> {
    let mut out: Vec<(u32, f64)> =
        row.iter().filter(|&&(_, a)| a >= floor).copied().collect();
    out.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    out
}

/// Frozen pre-SoA comm-variant selection: `Vec<Vec<u32>>` by-node pools
/// and the **branchy** sequential scoring loop (`if pn == j { bj += w }
/// else if pn == i { local += w }`) the branchless `w * mask` kernel
/// replaced.
fn legacy_select_comm(
    inst: &Instance,
    node_map: &mut [u32],
    quotas: &Quotas,
    overfill: f64,
) -> usize {
    let n_nodes = inst.topo.n_nodes;
    let n_objects = inst.n_objects();
    let floor = legacy_quota_floor(inst);
    let mut moved = vec![false; n_objects];
    let mut by_node: Vec<Vec<u32>> = vec![Vec::new(); n_nodes];
    for (o, &nm) in node_map.iter().enumerate() {
        by_node[nm as usize].push(o as u32);
    }
    let mut migrations = 0;
    for i in 0..n_nodes {
        let targets = legacy_sorted_quota(&quotas.flows[i], floor);
        if targets.is_empty() {
            continue;
        }
        let pool: Vec<u32> = by_node[i]
            .iter()
            .copied()
            .filter(|&o| node_map[o as usize] == i as u32 && !moved[o as usize])
            .collect();
        for &(j, quota) in &targets {
            let mut remaining = quota;
            let mut bytes_to_j = vec![0.0f64; n_objects];
            let mut scored = vec![false; n_objects];
            let mut heap: BinaryHeap<FEntry> = BinaryHeap::new();
            for &o in &pool {
                let o = o as usize;
                if moved[o] || node_map[o] != i as u32 {
                    continue;
                }
                let mut bj = 0.0;
                let mut local = 0.0;
                for (&p, &w) in inst.graph.neighbors(o).iter().zip(inst.graph.weights(o)) {
                    let pn = node_map[p as usize];
                    if pn == j {
                        bj += w;
                    } else if pn == i as u32 {
                        local += w;
                    }
                }
                bytes_to_j[o] = bj;
                scored[o] = true;
                heap.push(FEntry { key: bj, tie: local, obj: o as u32 });
            }
            while remaining > 1e-12 {
                let Some(top) = heap.pop() else { break };
                let o = top.obj as usize;
                if moved[o] || node_map[o] != i as u32 {
                    continue;
                }
                let cur = bytes_to_j[o];
                if (cur - top.key).abs() > 1e-9 {
                    heap.push(FEntry { key: cur, ..top });
                    continue;
                }
                let load = legacy_eff_load(inst, i, inst.loads[o]);
                if !(remaining > 0.0 && load * (1.0 - overfill) <= remaining) {
                    continue;
                }
                node_map[o] = j;
                moved[o] = true;
                migrations += 1;
                remaining -= load;
                for (&p, &w) in inst.graph.neighbors(o).iter().zip(inst.graph.weights(o)) {
                    let p = p as usize;
                    if node_map[p] == i as u32 && !moved[p] && scored[p] {
                        bytes_to_j[p] += w;
                        heap.push(FEntry { key: bytes_to_j[p], tie: 0.0, obj: p as u32 });
                    }
                }
            }
        }
    }
    migrations
}

fn legacy_centroid(sums: &[[f64; 2]], counts: &[usize], n: usize) -> [f64; 2] {
    if counts[n] == 0 {
        [0.0, 0.0]
    } else {
        [sums[n][0] / counts[n] as f64, sums[n][1] / counts[n] as f64]
    }
}

fn legacy_dist2(a: [f64; 2], b: [f64; 2]) -> f64 {
    let dx = a[0] - b[0];
    let dy = a[1] - b[1];
    dx * dx + dy * dy
}

/// Frozen pre-SoA coord-variant selection: by-node pools and the seed's
/// inline sequential heap-push scoring (the live path hoists scores into
/// per-position slots first).
fn legacy_select_coord(
    inst: &Instance,
    node_map: &mut [u32],
    quotas: &Quotas,
    overfill: f64,
) -> usize {
    let n_nodes = inst.topo.n_nodes;
    let floor = legacy_quota_floor(inst);
    let mut moved = vec![false; inst.n_objects()];
    let mut csums = vec![[0.0f64; 2]; n_nodes];
    let mut ccounts = vec![0usize; n_nodes];
    for (o, &node) in node_map.iter().enumerate() {
        csums[node as usize][0] += inst.coords[o][0];
        csums[node as usize][1] += inst.coords[o][1];
        ccounts[node as usize] += 1;
    }
    let mut by_node: Vec<Vec<u32>> = vec![Vec::new(); n_nodes];
    for (o, &nm) in node_map.iter().enumerate() {
        by_node[nm as usize].push(o as u32);
    }
    let mut migrations = 0;
    for i in 0..n_nodes {
        let targets = legacy_sorted_quota(&quotas.flows[i], floor);
        if targets.is_empty() {
            continue;
        }
        let pool: Vec<u32> = by_node[i]
            .iter()
            .copied()
            .filter(|&o| node_map[o as usize] == i as u32 && !moved[o as usize])
            .collect();
        for &(j, quota) in &targets {
            let mut remaining = quota;
            let mut heap: BinaryHeap<FEntry> = BinaryHeap::new();
            let cj = legacy_centroid(&csums, &ccounts, j as usize);
            for &o in &pool {
                if moved[o as usize] || node_map[o as usize] != i as u32 {
                    continue;
                }
                heap.push(FEntry {
                    key: -legacy_dist2(inst.coords[o as usize], cj),
                    tie: 0.0,
                    obj: o,
                });
            }
            let mut revalidations = 4 * pool.len() + 16;
            while remaining > 1e-12 {
                let Some(top) = heap.pop() else { break };
                let o = top.obj;
                if moved[o as usize] || node_map[o as usize] != i as u32 {
                    continue;
                }
                let cj = legacy_centroid(&csums, &ccounts, j as usize);
                let cur = -legacy_dist2(inst.coords[o as usize], cj);
                if revalidations > 0 && (cur - top.key).abs() > 1e-9 {
                    revalidations -= 1;
                    heap.push(FEntry { key: cur, ..top });
                    continue;
                }
                let load = legacy_eff_load(inst, i, inst.loads[o as usize]);
                if !(remaining > 0.0 && load * (1.0 - overfill) <= remaining) {
                    continue;
                }
                node_map[o as usize] = j;
                moved[o as usize] = true;
                migrations += 1;
                remaining -= load;
                let c = inst.coords[o as usize];
                csums[i][0] -= c[0];
                csums[i][1] -= c[1];
                ccounts[i] -= 1;
                csums[j as usize][0] += c[0];
                csums[j as usize][1] += c[1];
                ccounts[j as usize] += 1;
            }
        }
    }
    migrations
}

/// Frozen §III-D driver: per-node member lists built by the seed's
/// full-object scan (the SoA index replaced it with one counting sort),
/// feeding the **live** per-node refinement body.
fn legacy_assign_pes_scan(inst: &Instance, new_node_map: &[u32], tol: f64) -> Vec<u32> {
    let ppn = inst.topo.pes_per_node;
    if ppn == 1 {
        return new_node_map.to_vec();
    }
    let mut mapping = vec![0u32; inst.n_objects()];
    for node in 0..inst.topo.n_nodes as u32 {
        let members: Vec<u32> = (0..inst.n_objects() as u32)
            .filter(|&o| new_node_map[o as usize] == node)
            .collect();
        for (o, pe) in assign_pes_node(inst, node, &members, tol) {
            mapping[o as usize] = pe;
        }
    }
    mapping
}

/// Frozen pre-single-pass text serializer: one `format!` per line.
fn legacy_to_lbi(inst: &Instance) -> String {
    let mut s = String::new();
    s.push_str("# difflb instance v1\n");
    s.push_str(&format!(
        "header objects {} nodes {} pes_per_node {}\n",
        inst.n_objects(),
        inst.topo.n_nodes,
        inst.topo.pes_per_node
    ));
    if let Some(speeds) = inst.topo.pe_speeds() {
        s.push_str("speeds");
        for v in speeds {
            s.push_str(&format!(" {v}"));
        }
        s.push('\n');
    }
    for o in 0..inst.n_objects() {
        s.push_str(&format!(
            "object {o} load {} pe {} x {} y {} size {}\n",
            inst.loads[o], inst.mapping[o], inst.coords[o][0], inst.coords[o][1], inst.sizes[o]
        ));
    }
    for (a, b, w) in inst.graph.edges() {
        s.push_str(&format!("edge {a} {b} {w}\n"));
    }
    s
}

// ========================================================== fixtures

/// The three speed regimes every stage-3 identity test sweeps.
#[derive(Clone, Copy)]
enum SpeedKind {
    Uniform,
    Mixed,
    Noisy,
}

fn random_instance(rng: &mut Rng, n_nodes: usize, ppn: usize, kind: SpeedKind) -> Instance {
    let side = 6 + rng.range(0, 5);
    let n = side * side;
    let mut edges = Vec::with_capacity(2 * n);
    for r in 0..side {
        for c in 0..side {
            let o = (r * side + c) as u32;
            edges.push((o, (r * side + (c + 1) % side) as u32, 64.0));
            edges.push((o, (((r + 1) % side) * side + c) as u32, 64.0));
        }
    }
    let graph = CommGraph::from_edges(n, &edges);
    let loads: Vec<f64> = (0..n).map(|_| rng.uniform(0.2, 3.0)).collect();
    let coords: Vec<[f64; 2]> =
        (0..n).map(|i| [(i % side) as f64, (i / side) as f64]).collect();
    let mut topo = Topology::new(n_nodes, ppn);
    let n_pes = topo.n_pes();
    topo = match kind {
        SpeedKind::Uniform => topo,
        SpeedKind::Mixed => topo.with_pe_speeds(
            (0..n_pes).map(|_| *rng.choose(&[1.0, 2.0, 4.0])).collect(),
        ),
        SpeedKind::Noisy => {
            topo.with_pe_speeds((0..n_pes).map(|_| rng.uniform(0.5, 2.0)).collect())
        }
    };
    let mapping: Vec<u32> = (0..n).map(|_| rng.below(n_pes as u64) as u32).collect();
    Instance::new(loads, coords, graph, mapping, topo)
}

fn speed_kind(trial: usize) -> SpeedKind {
    match trial % 3 {
        0 => SpeedKind::Uniform,
        1 => SpeedKind::Mixed,
        _ => SpeedKind::Noisy,
    }
}

/// Random stage-2-shaped quota rows: a few outgoing flows per node.
fn random_quotas(rng: &mut Rng, n_nodes: usize) -> Quotas {
    let mut q = Quotas::empty(n_nodes);
    for i in 0..n_nodes {
        for j in 0..n_nodes as u32 {
            if j as usize != i && rng.chance(0.4) {
                q.flows[i].push((j, rng.uniform(0.05, 3.0)));
            }
        }
    }
    q
}

// ===================================================== identity tests

#[test]
fn grid_charge_branchless_bit_identical_to_rem_euclid_form() {
    use difflb::apps::pic::init::grid_charge;
    // pinned edges: negative even inputs are where the sign-of-zero
    // difference lives; huge magnitudes exercise the floor saturation
    for x in [
        0.0, -0.0, 1.0, -1.0, 2.0, -2.0, 4.0, -4.0, 0.5, -0.5, 1.5, -3.5, 1e15, -1e15,
        1e300, -1e300, f64::MIN_POSITIVE, -f64::MIN_POSITIVE,
    ] {
        for q in [1.0, -1.0, 2.5, 1e-3] {
            assert_eq!(
                grid_charge(x, q).to_bits(),
                legacy_grid_charge(x, q).to_bits(),
                "x={x} q={q}"
            );
        }
    }
    let mut rng = Rng::new(0x51D0_0001);
    for _ in 0..2000 {
        // mix of integer column coordinates (the real input domain) and
        // arbitrary reals at several scales, both signs
        let x = match rng.below(3) {
            0 => rng.uniform(-1e6, 1e6).floor(),
            1 => rng.uniform(-64.0, 64.0),
            _ => rng.uniform(-1.0, 1.0) * 10f64.powi(rng.range(0, 300) as i32),
        };
        let q = rng.uniform(-4.0, 4.0);
        assert_eq!(
            grid_charge(x, q).to_bits(),
            legacy_grid_charge(x, q).to_bits(),
            "x={x} q={q}"
        );
    }
}

#[test]
fn push_one_bit_identical_to_frozen_scalar() {
    let mut rng = Rng::new(0x51D0_0002);
    for trial in 0..2000 {
        let l = *rng.choose(&[16.0, 32.0, 64.0, 100.0]);
        let x = rng.uniform(0.0, l);
        let y = rng.uniform(0.0, l);
        let vx = rng.uniform(-3.0, 3.0);
        let vy = rng.uniform(-3.0, 3.0);
        let q = rng.uniform(-2.0, 2.0);
        let big_q = rng.uniform(0.5, 2.0);
        let live = push_one(x, y, vx, vy, q, l, big_q);
        let froz = legacy_push_one(x, y, vx, vy, q, l, big_q);
        assert_eq!(live.0.to_bits(), froz.0.to_bits(), "trial {trial} x");
        assert_eq!(live.1.to_bits(), froz.1.to_bits(), "trial {trial} y");
        assert_eq!(live.2.to_bits(), froz.2.to_bits(), "trial {trial} vx");
        assert_eq!(live.3.to_bits(), froz.3.to_bits(), "trial {trial} vy");
    }
}

#[test]
fn native_push_bit_identical_to_frozen_sequential_loop() {
    let modes = [
        InitMode::Geometric { rho: 0.9 },
        InitMode::Sinusoidal,
        InitMode::Linear { alpha: 0.5 },
    ];
    for (trial, &mode) in modes.iter().enumerate() {
        // deliberately not a multiple of LANES: exercises the scalar
        // remainder loop after the blocked body
        let n = 1003 + 17 * trial;
        let pop = initialize(mode, n, 64, 1 + trial as u32, 1, 1.0, 40 + trial as u64);
        let mk = |p: &difflb::apps::pic::init::Population| PicBatch {
            x: p.x.clone(),
            y: p.y.clone(),
            vx: p.vx.clone(),
            vy: p.vy.clone(),
            q: p.q.clone(),
        };
        let mut frozen = mk(&pop);
        for _ in 0..5 {
            legacy_push_batch(&mut frozen, 64.0, 1.0);
        }
        for threads in [1usize, 3, 8] {
            let mut live = mk(&pop);
            for _ in 0..5 {
                native_push(&mut live, 64.0, 1.0, threads);
            }
            let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&live.x), bits(&frozen.x), "x mode {trial} threads {threads}");
            assert_eq!(bits(&live.y), bits(&frozen.y), "y mode {trial} threads {threads}");
            assert_eq!(bits(&live.vx), bits(&frozen.vx), "vx mode {trial} threads {threads}");
            assert_eq!(bits(&live.vy), bits(&frozen.vy), "vy mode {trial} threads {threads}");
        }
    }
}

#[test]
fn select_comm_bit_identical_to_frozen_pre_soa_selection() {
    let mut rng = Rng::new(0x51D0_0003);
    for trial in 0..30 {
        let inst = random_instance(&mut rng, 2 + trial % 5, 1 + trial % 3, speed_kind(trial));
        let quotas = random_quotas(&mut rng, inst.topo.n_nodes);
        let overfill = *rng.choose(&[0.0, 0.2, 0.5]);
        let mut live_map = inst.node_mapping();
        let mut frozen_map = inst.node_mapping();
        let n_live = select_comm(&inst, &mut live_map, &quotas, overfill);
        let n_frozen = legacy_select_comm(&inst, &mut frozen_map, &quotas, overfill);
        assert_eq!(n_live, n_frozen, "trial {trial} migration count");
        assert_eq!(live_map, frozen_map, "trial {trial} node map");
    }
}

#[test]
fn select_coord_bit_identical_to_frozen_pre_soa_selection() {
    let mut rng = Rng::new(0x51D0_0004);
    for trial in 0..30 {
        let inst = random_instance(&mut rng, 2 + trial % 5, 1 + trial % 3, speed_kind(trial));
        let quotas = random_quotas(&mut rng, inst.topo.n_nodes);
        let overfill = *rng.choose(&[0.0, 0.2, 0.5]);
        let mut live_map = inst.node_mapping();
        let mut frozen_map = inst.node_mapping();
        let n_live = select_coord(&inst, &mut live_map, &quotas, overfill);
        let n_frozen = legacy_select_coord(&inst, &mut frozen_map, &quotas, overfill);
        assert_eq!(n_live, n_frozen, "trial {trial} migration count");
        assert_eq!(live_map, frozen_map, "trial {trial} node map");
    }
}

#[test]
fn assign_pes_bit_identical_to_frozen_scan_built_members() {
    let mut rng = Rng::new(0x51D0_0005);
    for trial in 0..30 {
        let inst = random_instance(&mut rng, 2 + trial % 4, 2 + trial % 3, speed_kind(trial));
        let mut node_map: Vec<u32> =
            inst.mapping.iter().map(|&pe| inst.topo.node_of_pe(pe)).collect();
        for nm in node_map.iter_mut() {
            if rng.chance(0.33) {
                *nm = rng.below(inst.topo.n_nodes as u64) as u32;
            }
        }
        let live = assign_pes(&inst, &node_map, 0.02);
        let frozen = legacy_assign_pes_scan(&inst, &node_map, 0.02);
        assert_eq!(live, frozen, "trial {trial}");
    }
}

#[test]
fn to_lbi_single_pass_byte_identical_to_frozen_per_line_format() {
    let mut rng = Rng::new(0x51D0_0006);
    for trial in 0..12 {
        let mut inst =
            random_instance(&mut rng, 2 + trial % 4, 1 + trial % 3, speed_kind(trial));
        for s in inst.sizes.iter_mut() {
            *s = rng.uniform(0.5, 8.0);
        }
        assert_eq!(inst.to_lbi(), legacy_to_lbi(&inst), "trial {trial}");
    }
}

// ============================================ binary codec properties

#[test]
fn lbi_binary_round_trip_is_exact_and_byte_stable() {
    let mut rng = Rng::new(0x51D0_0007);
    for trial in 0..30 {
        let mut inst =
            random_instance(&mut rng, 2 + trial % 4, 1 + trial % 3, speed_kind(trial));
        for s in inst.sizes.iter_mut() {
            *s = rng.uniform(0.5, 8.0);
        }
        // adversarial float payloads must survive the bit transport
        if rng.chance(0.5) {
            inst.loads[0] = f64::MIN_POSITIVE;
            inst.coords[1] = [-0.0, 1e-300];
            inst.sizes[2] = 1.0 / 3.0;
        }
        let bytes = encode_lbi(&inst);
        let back = decode_lbi(&bytes).unwrap();
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back.loads), bits(&inst.loads), "trial {trial} loads");
        assert_eq!(bits(&back.sizes), bits(&inst.sizes), "trial {trial} sizes");
        let cbits =
            |v: &[[f64; 2]]| v.iter().map(|c| [c[0].to_bits(), c[1].to_bits()]).collect::<Vec<_>>();
        assert_eq!(cbits(&back.coords), cbits(&inst.coords), "trial {trial} coords");
        assert_eq!(back.mapping, inst.mapping, "trial {trial} mapping");
        assert_eq!(back.graph, inst.graph, "trial {trial} graph");
        assert_eq!(back.topo, inst.topo, "trial {trial} topo");
        // encode ∘ decode is the identity on wire bytes
        assert_eq!(encode_lbi(&back), bytes, "trial {trial} re-encode");
    }
}

#[test]
fn lbi_binary_agrees_with_text_round_trip() {
    let mut rng = Rng::new(0x51D0_0008);
    for trial in 0..10 {
        let inst = random_instance(&mut rng, 2 + trial % 3, 1 + trial % 2, speed_kind(trial));
        let via_bin = decode_lbi(&encode_lbi(&inst)).unwrap();
        let via_text = Instance::from_lbi(&inst.to_lbi()).unwrap();
        assert_eq!(via_bin.loads, via_text.loads, "trial {trial}");
        assert_eq!(via_bin.graph, via_text.graph, "trial {trial}");
        assert_eq!(via_bin.mapping, via_text.mapping, "trial {trial}");
        assert_eq!(via_bin.topo, via_text.topo, "trial {trial}");
    }
}
