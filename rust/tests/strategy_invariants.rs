//! Property-based strategy-invariant suite (ISSUE 5 satellite): every
//! registered strategy (`strategies::AVAILABLE`, distributed variants
//! included) is run over randomized instances — varied object counts,
//! topologies (flat and hierarchical), and speed vectors (uniform and
//! heterogeneous) — and must uphold the invariants no balancer may
//! break:
//!
//! * every object maps to an in-range PE;
//! * total work is conserved (the per-PE load sums re-add to the
//!   instance's total — no object lost or duplicated);
//! * rebalance is deterministic for a fixed seed: the same strategy
//!   object re-run, and a freshly constructed one, produce identical
//!   mappings (scratch reuse must not leak state);
//! * `none` keeps `Assignment::unchanged` semantics exactly;
//! * the diffusion single-hop guarantee survives heterogeneous speeds.
//!
//! Uses the in-repo `util::prop` harness (proptest is unavailable
//! offline); replay failures with `DIFFLB_PROP_SEED=<seed>`.

use difflb::model::{CommGraph, Instance, Topology};
use difflb::strategies::diffusion::Diffusion;
use difflb::strategies::{make, LoadBalancer, StrategyParams, AVAILABLE};
use difflb::util::prop::{self, Gen};

/// Random instance: `side x side` objects with periodic 5-point stencil
/// edges, random loads, random (in-range) initial mapping, and a
/// randomly uniform or heterogeneous topology.
fn random_instance(g: &mut Gen) -> Instance {
    let side = 4 + g.usize_in(0, 5); // 16..=64 objects
    let n = side * side;
    let n_nodes = 2 + g.usize_in(0, 5); // 2..=7 nodes
    let ppn = 1 + g.usize_in(0, 2); // 1..=3 PEs per node
    let mut topo = Topology::new(n_nodes, ppn);
    if g.bool() {
        let speeds: Vec<f64> = (0..topo.n_pes())
            .map(|_| *g.rng.choose(&[0.25, 0.5, 1.0, 1.5, 2.0, 4.0]))
            .collect();
        topo = topo.with_pe_speeds(speeds);
    }
    let mut edges = Vec::with_capacity(2 * n);
    for r in 0..side {
        for c in 0..side {
            let o = (r * side + c) as u32;
            edges.push((o, (r * side + (c + 1) % side) as u32, 64.0));
            edges.push((o, (((r + 1) % side) * side + c) as u32, 64.0));
        }
    }
    let graph = CommGraph::from_edges(n, &edges);
    let loads: Vec<f64> = (0..n).map(|_| g.f64_in(0.2, 3.0)).collect();
    let coords: Vec<[f64; 2]> =
        (0..n).map(|i| [(i % side) as f64, (i / side) as f64]).collect();
    let n_pes = topo.n_pes() as u64;
    let mapping: Vec<u32> = (0..n).map(|_| g.rng.below(n_pes) as u32).collect();
    Instance::new(loads, coords, graph, mapping, topo)
}

fn check_strategy(inst: &Instance, name: &str) -> prop::CaseResult {
    let params = StrategyParams::default();
    let strat = make(name, params).map_err(|e| e.to_string())?;
    let asg = strat.rebalance(inst);

    // mapped, in range
    prop::assert_that(
        asg.mapping.len() == inst.n_objects(),
        format!("{name}: mapping length {} != {}", asg.mapping.len(), inst.n_objects()),
    )?;
    let n_pes = inst.topo.n_pes() as u32;
    prop::assert_that(
        asg.mapping.iter().all(|&pe| pe < n_pes),
        format!("{name}: out-of-range PE"),
    )?;

    // work conserved: regrouping the same loads must re-add to the total
    let total: f64 = inst.loads.iter().sum();
    let regrouped: f64 = inst.pe_loads(&asg.mapping).iter().sum();
    prop::assert_close(regrouped, total, 1e-9)
        .map_err(|e| format!("{name}: work not conserved: {e}"))?;

    // deterministic: same strategy object again, and a fresh one
    let again = strat.rebalance(inst);
    prop::assert_that(
        again.mapping == asg.mapping,
        format!("{name}: second rebalance diverged (scratch state leak)"),
    )?;
    let fresh = make(name, params).map_err(|e| e.to_string())?.rebalance(inst);
    prop::assert_that(
        fresh.mapping == asg.mapping,
        format!("{name}: fresh strategy diverged for the same seed"),
    )?;

    // the no-op strategy is exactly Assignment::unchanged
    if name == "none" {
        prop::assert_that(
            asg.mapping == inst.mapping,
            "none: mapping changed".to_string(),
        )?;
    }
    Ok(())
}

#[test]
fn every_strategy_upholds_invariants_on_random_instances() {
    // Strategies under test: all of AVAILABLE; optionally restricted
    // via DIFFLB_TEST_STRATEGY for debugging one.
    let only = std::env::var("DIFFLB_TEST_STRATEGY").ok();
    prop::check("strategy invariants", 8, |g| {
        let inst = random_instance(g);
        for &name in AVAILABLE {
            if let Some(want) = &only {
                if want != name {
                    continue;
                }
            }
            check_strategy(&inst, name)?;
        }
        Ok(())
    });
}

#[test]
fn single_hop_guarantee_survives_heterogeneous_speeds() {
    prop::check("hetero single-hop", 10, |g| {
        let mut inst = random_instance(g);
        // force a genuinely heterogeneous topology
        let speeds: Vec<f64> = (0..inst.topo.n_pes())
            .map(|pe| if pe % 3 == 0 { 2.0 } else { 0.5 })
            .collect();
        inst.topo = inst.topo.clone().with_pe_speeds(speeds);
        let lb = Diffusion::communication(StrategyParams::default());
        let (neigh, _) = lb.plan(&inst);
        let asg = lb.rebalance(&inst);
        for o in 0..inst.n_objects() {
            let from = inst.topo.node_of_pe(inst.mapping[o]);
            let to = inst.topo.node_of_pe(asg.mapping[o]);
            if from != to && !neigh.adj[from as usize].contains(&to) {
                return Err(format!("object {o} hopped {from}->{to} (not stage-1 neighbors)"));
            }
        }
        Ok(())
    });
}

#[test]
fn uniform_unit_speeds_are_the_same_topology() {
    // Attaching an explicit all-1.0 speed vector must not change any
    // strategy's decisions: with_pe_speeds canonicalizes it away.
    prop::check("unit speeds are identity", 6, |g| {
        let mut inst = random_instance(g);
        inst.topo = Topology::new(inst.topo.n_nodes, inst.topo.pes_per_node);
        let mut tagged = inst.clone();
        tagged.topo =
            tagged.topo.clone().with_pe_speeds(vec![1.0; inst.topo.n_pes()]);
        for &name in AVAILABLE {
            let params = StrategyParams::default();
            let a = make(name, params).map_err(|e| e.to_string())?.rebalance(&inst);
            let b = make(name, params).map_err(|e| e.to_string())?.rebalance(&tagged);
            prop::assert_that(
                a.mapping == b.mapping,
                format!("{name}: unit-speed vector changed the assignment"),
            )?;
        }
        Ok(())
    });
}
