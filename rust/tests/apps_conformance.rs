//! Generic conformance suite for the [`App`] trait, run over **every**
//! registered workload (`apps::AVAILABLE_APPS`): the trait contract
//! (valid instances, in-range crossing records, sane work vectors,
//! `apply` keeping mappings in range) plus the full
//! `strategies::AVAILABLE × AVAILABLE_APPS` cross-product through the
//! one generic driver.
//!
//! Set `DIFFLB_TEST_APP` to restrict the suite to a single app (the CI
//! matrix sweeps pic/stencil/advect/hotspot), and `DIFFLB_TEST_HETERO`
//! to run the whole suite on a heterogeneous cluster: `mixed` attaches
//! a fixed per-PE speed vector, `noisy` additionally turns on the
//! time-varying speed-noise schedule (the CI heterogeneity matrix
//! sweeps uniform/mixed/noisy).

use difflb::apps::driver::{run_app, DriverConfig};
use difflb::apps::{App, StepCtx, AVAILABLE_APPS};
use difflb::coordinator::app_from_config;
use difflb::model::SpeedSchedule;
use difflb::strategies::{make, StrategyParams, AVAILABLE};
use difflb::util::config::Config;

/// Heterogeneity mode for this run: "uniform" (default), "mixed"
/// (static per-PE speeds), or "noisy" (speeds + per-iteration noise).
fn hetero_mode() -> String {
    let mode = std::env::var("DIFFLB_TEST_HETERO").unwrap_or_else(|_| "uniform".into());
    assert!(
        matches!(mode.as_str(), "uniform" | "mixed" | "noisy"),
        "DIFFLB_TEST_HETERO={mode} (expected uniform|mixed|noisy)"
    );
    mode
}

/// Driver schedule for the current heterogeneity mode.
fn driver_config(iters: usize, lb_period: usize) -> DriverConfig {
    let speed_schedule = if hetero_mode() == "noisy" {
        SpeedSchedule { noise: 0.3, period: 2, seed: 0xA11 }
    } else {
        SpeedSchedule::none()
    };
    DriverConfig {
        iters,
        lb_period,
        deterministic_loads: true,
        speed_schedule,
        ..Default::default()
    }
}

/// Small-but-real configuration for each registered app.
fn small_config(kind: &str) -> Config {
    let mut cfg = Config::new();
    cfg.set("app.kind", kind);
    cfg.set("topo.nodes", 4);
    cfg.set("pic.grid", 32);
    cfg.set("pic.particles", 600);
    cfg.set("pic.chares_x", 4);
    cfg.set("pic.chares_y", 4);
    cfg.set("pic.backend", "native");
    cfg.set("pic.threads", 2);
    cfg.set("stencil.side", 16);
    cfg.set("stencil.px", 2);
    cfg.set("stencil.py", 2);
    cfg.set("advect.particles", 800);
    cfg.set("advect.blocks_x", 6);
    cfg.set("advect.blocks_y", 6);
    cfg.set("hotspot.nx", 8);
    cfg.set("hotspot.ny", 8);
    if hetero_mode() != "uniform" {
        // every app above resolves a 4-PE topology (topo.nodes = 4 /
        // stencil px*py = 4), so one vector serves them all
        cfg.set("topo.pe_speeds", "1.0, 2.0, 0.5, 1.5");
    }
    cfg
}

fn make_app(kind: &str) -> Box<dyn App> {
    app_from_config(&small_config(kind)).unwrap()
}

/// Apps under test: all registered, or just `DIFFLB_TEST_APP`.
fn apps_under_test() -> Vec<&'static str> {
    match std::env::var("DIFFLB_TEST_APP") {
        Ok(want) => {
            let picked: Vec<&'static str> =
                AVAILABLE_APPS.iter().copied().filter(|a| *a == want).collect();
            assert!(!picked.is_empty(), "DIFFLB_TEST_APP={want} is not a registered app");
            picked
        }
        Err(_) => AVAILABLE_APPS.to_vec(),
    }
}

#[test]
fn registry_covers_every_app_and_names_agree() {
    for kind in apps_under_test() {
        let app = make_app(kind);
        assert_eq!(app.name(), kind);
        assert!(app.n_objects() > 0, "{kind}: no objects");
        assert_eq!(app.mapping().len(), app.n_objects(), "{kind}: mapping length");
    }
}

#[test]
fn step_contract_in_range_records_and_work() {
    for kind in apps_under_test() {
        let mut app = make_app(kind);
        let n = app.n_objects() as u32;
        let n_pes = app.topo().n_pes() as u32;
        let pairs = app.neighbor_pairs();
        assert!(
            pairs.iter().all(|&(a, b)| a < b && b < n),
            "{kind}: malformed neighbor pairs"
        );
        let mut ctx = StepCtx::default();
        let mut work = Vec::new();
        for _step in 0..5 {
            ctx.moved.clear();
            let stats = app.step(&mut ctx).unwrap();
            assert!(stats.compute_s >= 0.0, "{kind}: negative compute time");
            for &(f, t, bytes) in &ctx.moved {
                assert!(f < n && t < n, "{kind}: crossing record out of range");
                assert!(bytes.is_finite() && bytes >= 0.0, "{kind}: bad crossing bytes");
            }
            app.work(&mut work);
            assert_eq!(work.len(), app.n_objects(), "{kind}: work length");
            assert!(
                work.iter().all(|w| w.is_finite() && *w >= 0.0),
                "{kind}: work must be finite and non-negative"
            );
            assert!(
                app.mapping().iter().all(|&pe| pe < n_pes),
                "{kind}: mapping out of range"
            );
        }
        app.verify().unwrap_or_else(|e| panic!("{kind}: verify failed: {e}"));
    }
}

#[test]
fn build_instance_is_valid_and_apply_keeps_range() {
    for kind in apps_under_test() {
        let mut app = make_app(kind);
        let mut ctx = StepCtx::default();
        for _ in 0..4 {
            ctx.moved.clear();
            app.step(&mut ctx).unwrap();
        }
        let inst = app.build_instance();
        assert_eq!(inst.n_objects(), app.n_objects(), "{kind}: instance size");
        inst.validate().unwrap_or_else(|e| panic!("{kind}: invalid instance: {e}"));
        assert!(inst.graph.edge_count() > 0, "{kind}: empty comm graph");
        // a deliberately disruptive assignment must round-trip
        let scatter = make("scatter", StrategyParams::default()).unwrap();
        let asg = scatter.rebalance(&inst);
        let bytes = app.apply(&asg);
        assert!(bytes >= 0.0 && bytes.is_finite(), "{kind}: bad migration bytes");
        assert_eq!(app.mapping(), &asg.mapping[..], "{kind}: apply didn't adopt mapping");
        // the app still steps and verifies after a migration storm
        ctx.moved.clear();
        app.step(&mut ctx).unwrap();
        app.verify().unwrap_or_else(|e| panic!("{kind}: verify after apply failed: {e}"));
    }
}

#[test]
fn crossing_records_agree_with_recorded_traffic() {
    // The records handed to the driver and the traffic folded into the
    // LB instance come from the same events: every instance edge weight
    // must be at least the bytes the step records claimed for it
    // (instances may add sync-message bytes on top).
    for kind in apps_under_test() {
        let mut app = make_app(kind);
        let mut ctx = StepCtx::default();
        let mut claimed = std::collections::BTreeMap::new();
        for _ in 0..3 {
            ctx.moved.clear();
            app.step(&mut ctx).unwrap();
            for &(f, t, bytes) in &ctx.moved {
                let key = (f.min(t), f.max(t));
                *claimed.entry(key).or_insert(0.0f64) += bytes;
            }
        }
        let inst = app.build_instance();
        let mut graph_bytes = std::collections::BTreeMap::new();
        for (a, b, w) in inst.graph.edges() {
            graph_bytes.insert((a, b), w);
        }
        for (key, bytes) in &claimed {
            let w = graph_bytes.get(key).copied().unwrap_or(0.0);
            assert!(
                w + 1e-9 >= *bytes,
                "{kind}: edge {key:?} carries {w} bytes but steps recorded {bytes}"
            );
        }
    }
}

#[test]
fn full_cross_product_runs_through_the_generic_driver() {
    // strategies::AVAILABLE × AVAILABLE_APPS, every combination through
    // run_app — the acceptance gate of the App-trait redesign.
    let driver = driver_config(4, 2);
    for kind in apps_under_test() {
        for strat_name in AVAILABLE {
            let mut app = make_app(kind);
            let strat = make(strat_name, StrategyParams::default()).unwrap();
            let rep = run_app(app.as_mut(), strat.as_ref(), &driver)
                .unwrap_or_else(|e| panic!("{kind} × {strat_name}: {e:#}"));
            assert_eq!(rep.records.len(), 4, "{kind} × {strat_name}");
            assert!(rep.verified, "{kind} × {strat_name}: verification failed");
            let n_pes = app.topo().n_pes() as u32;
            assert!(
                app.mapping().iter().all(|&pe| pe < n_pes),
                "{kind} × {strat_name}: out-of-range PE after run"
            );
        }
    }
}

#[test]
fn telemetry_on_off_runs_are_bit_identical() {
    // ISSUE 7 acceptance: spans and metrics observe the run, they must
    // never steer it. The same app + strategy + schedule with
    // collection fully on and fully off has to produce bit-identical
    // decision-bearing outputs — final mapping, migration counts, and
    // every modeled per-iteration metric. (Wall-clock fields like lb_s
    // are legitimately noisy and deliberately not compared.)
    let run = |kind: &str, strat_name: &str| {
        let mut app = make_app(kind);
        let strat = make(strat_name, StrategyParams::default()).unwrap();
        let driver = driver_config(6, 2);
        run_app(app.as_mut(), strat.as_ref(), &driver).unwrap()
    };
    for kind in apps_under_test() {
        for strat_name in ["diff-comm", "diff-coord", "greedy-refine"] {
            difflb::obs::set_tracing(false);
            difflb::obs::set_metrics(false);
            let off = run(kind, strat_name);
            difflb::obs::set_tracing(true);
            difflb::obs::set_metrics(true);
            let on = run(kind, strat_name);
            difflb::obs::set_tracing(false);
            difflb::obs::set_metrics(false);
            let ctx = format!("{kind} × {strat_name}");
            assert_eq!(off.final_mapping, on.final_mapping, "{ctx}: final mapping");
            assert_eq!(off.total_migrations, on.total_migrations, "{ctx}: migrations");
            assert_eq!(off.records.len(), on.records.len(), "{ctx}: record counts");
            for (x, y) in off.records.iter().zip(&on.records) {
                assert_eq!(x.migrations, y.migrations, "{ctx} iter {}: migrations", x.iter);
                assert_eq!(x.work_max_avg, y.work_max_avg, "{ctx} iter {}: imbalance", x.iter);
                assert_eq!(
                    x.time_max_avg, y.time_max_avg,
                    "{ctx} iter {}: time imbalance",
                    x.iter
                );
                assert_eq!(x.comm_max_s, y.comm_max_s, "{ctx} iter {}: comm max", x.iter);
                assert_eq!(x.comm_avg_s, y.comm_avg_s, "{ctx} iter {}: comm avg", x.iter);
                assert_eq!(x.node_work, y.node_work, "{ctx} iter {}: node work", x.iter);
            }
        }
    }
    // The traced halves really collected: this thread's buffer holds
    // driver spans for every combination run with tracing on.
    difflb::obs::trace::flush_local();
    let events = difflb::obs::trace::drain_merged();
    assert!(
        events.iter().any(|e| e.name == "lb.round"),
        "tracing-on runs recorded no lb.round spans"
    );
    assert!(
        events.iter().any(|e| e.name == "app.step"),
        "tracing-on runs recorded no app.step spans"
    );
    // and the metrics collector saw one row per LB round of the traced
    // halves (6 iters at period 2 → 3 rounds each)
    let rounds = difflb::obs::metrics::take_rounds();
    assert!(!rounds.is_empty(), "tracing-on runs recorded no metrics rounds");

    // The binary .lbi wire codec is telemetry-neutral too: the bytes
    // the distributed driver broadcasts — and the instance decoded from
    // them — must not depend on the collection flags, while the traced
    // half records its encode/decode spans and size histograms.
    let inst = {
        let mut app = make_app("stencil");
        let mut ctx = StepCtx::default();
        app.step(&mut ctx).unwrap();
        app.build_instance()
    };
    difflb::obs::set_tracing(false);
    difflb::obs::set_metrics(false);
    let off_bytes = difflb::model::encode_lbi(&inst);
    difflb::obs::set_tracing(true);
    difflb::obs::set_metrics(true);
    let on_bytes = difflb::model::encode_lbi(&inst);
    let decoded = difflb::model::decode_lbi(&on_bytes).unwrap();
    difflb::obs::set_tracing(false);
    difflb::obs::set_metrics(false);
    assert_eq!(off_bytes, on_bytes, "lbi encode must not depend on telemetry flags");
    assert_eq!(decoded.mapping, inst.mapping, "lbi decode under telemetry");
    assert_eq!(
        difflb::model::encode_lbi(&decoded),
        off_bytes,
        "lbi re-encode must be byte-stable regardless of telemetry"
    );
    difflb::obs::trace::flush_local();
    let events = difflb::obs::trace::drain_merged();
    assert!(
        events.iter().any(|e| e.name == "lbi.encode"),
        "traced encode recorded no lbi.encode span"
    );
    assert!(
        events.iter().any(|e| e.name == "lbi.decode"),
        "traced decode recorded no lbi.decode span"
    );
}

#[test]
fn deterministic_loads_make_runs_reproducible() {
    for kind in apps_under_test() {
        let run = || {
            let mut app = make_app(kind);
            let strat = make("diff-comm", StrategyParams::default()).unwrap();
            let driver = driver_config(6, 2);
            let rep = run_app(app.as_mut(), strat.as_ref(), &driver).unwrap();
            (rep.total_migrations, app.mapping().to_vec())
        };
        let (m1, map1) = run();
        let (m2, map2) = run();
        assert_eq!(m1, m2, "{kind}: migration totals diverged across identical runs");
        assert_eq!(map1, map2, "{kind}: final mappings diverged across identical runs");
    }
}
