//! Guard rails for the zero-allocation / thread-parallel refactor:
//! the perf work must not change a single strategy decision.
//!
//! * `group_traffic` (CSR) must agree exactly with
//!   `group_traffic_dense` on randomized graphs — both accumulate
//!   per-cell sums in edge-iteration order, so equality is exact, not
//!   approximate.
//! * `native_push` and the stage-3 selectors must produce bit-identical
//!   output for any thread/task count (deterministic chunking).
//! * A shared `LbScratch` reused across rounds must behave exactly like
//!   a fresh one.

use difflb::apps::pic::init::{initialize, InitMode};
use difflb::apps::pic::push::native_push;
use difflb::model::{CommGraph, Instance, Topology};
use difflb::runtime::PicBatch;
use difflb::strategies::diffusion::object_selection::{
    select_comm, select_comm_with, select_coord, select_coord_with,
};
use difflb::strategies::diffusion::scratch::LbScratch;
use difflb::strategies::diffusion::virtual_lb::Quotas;
use difflb::strategies::diffusion::Diffusion;
use difflb::strategies::{LoadBalancer, StrategyParams};
use difflb::util::rng::Rng;

fn random_graph(rng: &mut Rng, n: usize, extra_edges: usize) -> CommGraph {
    let mut edges: Vec<(u32, u32, f64)> = (0..n as u32)
        .map(|o| (o, (o + 1) % n as u32, rng.uniform(1.0, 100.0)))
        .collect();
    for _ in 0..extra_edges {
        let a = rng.below(n as u64) as u32;
        let b = rng.below(n as u64) as u32;
        edges.push((a, b, rng.uniform(1.0, 100.0)));
    }
    CommGraph::from_edges(n, &edges)
}

#[test]
fn group_traffic_sparse_matches_dense_on_random_graphs() {
    let mut rng = Rng::new(0x6A0B);
    for round in 0..25 {
        let n = rng.range(2, 400);
        let n_groups = rng.range(1, 24);
        let g = random_graph(&mut rng, n, n / 2);
        let group: Vec<u32> = (0..n).map(|_| rng.below(n_groups as u64) as u32).collect();
        let sparse = g.group_traffic(&group, n_groups);
        let dense = g.group_traffic_dense(&group, n_groups);
        for ga in 0..n_groups {
            for gb in 0..n_groups as u32 {
                assert_eq!(
                    sparse.get(ga, gb),
                    dense[ga * n_groups + gb as usize],
                    "round {round}: cell ({ga}, {gb})"
                );
            }
            // rows sorted, no duplicates
            let (peers, _) = sparse.row(ga);
            assert!(peers.windows(2).all(|w| w[0] < w[1]), "row {ga}: {peers:?}");
        }
        // symmetry of the off-diagonal
        for ga in 0..n_groups {
            for gb in 0..n_groups as u32 {
                assert_eq!(sparse.get(ga, gb), sparse.get(gb as usize, ga as u32));
            }
        }
    }
}

#[test]
fn native_push_bit_identical_across_thread_counts() {
    let pop = initialize(InitMode::Geometric { rho: 0.9 }, 100_000, 1000, 2, 1, 1.0, 42);
    let base = PicBatch { x: pop.x, y: pop.y, vx: pop.vx, vy: pop.vy, q: pop.q };
    let mut reference: Option<PicBatch> = None;
    for threads in [1usize, 4, 8] {
        let mut b = base.clone();
        for _ in 0..3 {
            native_push(&mut b, 1000.0, 1.0, threads);
        }
        match &reference {
            None => reference = Some(b),
            Some(r) => assert_eq!(r, &b, "threads={threads} diverged"),
        }
    }
}

/// Two-node instance big enough that stage-3 scoring takes the
/// pool-parallel path (pool > 4096 objects on node 0).
fn big_two_node_instance(seed: u64) -> Instance {
    let n = 12_000;
    let split = 8_000;
    let mut rng = Rng::new(seed);
    let graph = random_graph(&mut rng, n, n);
    let loads: Vec<f64> = (0..n).map(|_| rng.uniform(0.5, 2.0)).collect();
    let coords: Vec<[f64; 2]> = (0..n).map(|i| [(i % 200) as f64, (i / 200) as f64]).collect();
    let mapping: Vec<u32> = (0..n).map(|i| u32::from(i >= split)).collect();
    Instance::new(loads, coords, graph, mapping, Topology::flat(2))
}

fn quota_0_to_1(amount: f64) -> Quotas {
    let mut q = Quotas::empty(2);
    q.flows[0].push((1, amount));
    q
}

#[test]
fn select_comm_bit_identical_across_task_counts() {
    let inst = big_two_node_instance(7);
    let baseline = {
        let mut map = inst.node_mapping();
        let n = select_comm(&inst, &mut map, &quota_0_to_1(900.0), 0.5);
        (map, n)
    };
    for tasks in [1usize, 4, 8] {
        let mut scratch = LbScratch { par_tasks: Some(tasks), ..Default::default() };
        let mut map = inst.node_mapping();
        let n = select_comm_with(&inst, &mut map, &quota_0_to_1(900.0), 0.5, &mut scratch);
        assert_eq!(n, baseline.1, "tasks={tasks}: migration count");
        assert_eq!(map, baseline.0, "tasks={tasks}: mapping diverged");
    }
}

#[test]
fn select_coord_matches_with_shared_scratch() {
    let inst = big_two_node_instance(8);
    let mut shared = LbScratch::default();
    for amount in [50.0, 300.0, 900.0] {
        let q = quota_0_to_1(amount);
        let mut fresh_map = inst.node_mapping();
        let n_fresh = select_coord(&inst, &mut fresh_map, &q, 0.5);
        let mut reused_map = inst.node_mapping();
        let n_reused = select_coord_with(&inst, &mut reused_map, &q, 0.5, &mut shared);
        assert_eq!(n_fresh, n_reused, "amount={amount}");
        assert_eq!(fresh_map, reused_map, "amount={amount}");
    }
}

#[test]
fn full_rebalance_deterministic_and_scratch_stable() {
    // the strategy's internal scratch must not leak state across calls:
    // rebalancing the same instance twice (and interleaving a different
    // instance) yields identical mappings.
    let inst_a = big_two_node_instance(9);
    let mut small = difflb::apps::stencil::stencil_2d(
        24,
        4,
        4,
        difflb::apps::stencil::Decomposition::Tiled,
    );
    difflb::apps::stencil::inject_noise(&mut small, 0.4, 11);
    let lb = Diffusion::communication(StrategyParams::default());
    let first_a = lb.rebalance(&inst_a).mapping;
    let first_small = lb.rebalance(&small).mapping;
    let second_a = lb.rebalance(&inst_a).mapping;
    let second_small = lb.rebalance(&small).mapping;
    assert_eq!(first_a, second_a);
    assert_eq!(first_small, second_small);
    // and a completely fresh strategy agrees
    let fresh = Diffusion::communication(StrategyParams::default());
    assert_eq!(fresh.rebalance(&inst_a).mapping, first_a);
}
