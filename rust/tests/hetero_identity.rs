//! Guard rails for the heterogeneity (speed-aware) generalization: on
//! **uniform** topologies, nothing may change — the weighted code paths
//! divide by speeds that are exactly 1.0 (or gate off entirely), so
//! every strategy decision must be bit-identical to the
//! pre-heterogeneity algorithms. In the style of
//! `rust/tests/perf_refactor.rs`, the pre-PR decision bodies that now
//! contain speed arithmetic (GreedyLB, GreedyRefineLB, the §III-D
//! hierarchical refinement) are FROZEN below, verbatim, and compared
//! against the live implementations over randomized instances.
//!
//! The diffusion stages need no frozen copy: their weighted arithmetic
//! is gated on `Topology::is_uniform()` (structurally the old code on
//! uniform topologies), and `tools/crosscheck_hetero.py` cross-simulates
//! the gate in-container (stage-2 inputs, quota floors, stage-3 picks:
//! uniform == legacy, 200/200 trials bit-equal). What IS asserted here
//! for diffusion: an explicit all-1.0 speed vector changes nothing, and
//! heterogeneous speeds change time imbalance in the right direction.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use difflb::model::{evaluate_mapping, CommGraph, Instance, Topology};
use difflb::strategies::diffusion::Diffusion;
use difflb::strategies::greedy::Greedy;
use difflb::strategies::greedy_refine::GreedyRefine;
use difflb::strategies::{LoadBalancer, StrategyParams};
use difflb::util::rng::Rng;

// ===================================================== frozen legacy

/// Frozen pre-heterogeneity GreedyLB (raw-load min-heap).
fn legacy_greedy(inst: &Instance) -> Vec<u32> {
    #[derive(Clone, Copy)]
    struct PeEntry {
        load: f64,
        pe: u32,
    }
    impl PartialEq for PeEntry {
        fn eq(&self, other: &Self) -> bool {
            self.cmp(other) == Ordering::Equal
        }
    }
    impl Eq for PeEntry {}
    impl PartialOrd for PeEntry {
        fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for PeEntry {
        fn cmp(&self, other: &Self) -> Ordering {
            other
                .load
                .partial_cmp(&self.load)
                .unwrap_or(Ordering::Equal)
                .then(other.pe.cmp(&self.pe))
        }
    }
    let mut order: Vec<u32> = (0..inst.n_objects() as u32).collect();
    order.sort_by(|&a, &b| {
        inst.loads[b as usize]
            .partial_cmp(&inst.loads[a as usize])
            .unwrap()
            .then(a.cmp(&b))
    });
    let mut heap: BinaryHeap<PeEntry> =
        (0..inst.topo.n_pes() as u32).map(|pe| PeEntry { load: 0.0, pe }).collect();
    let mut mapping = vec![0u32; inst.n_objects()];
    for o in order {
        let mut top = heap.pop().unwrap();
        mapping[o as usize] = top.pe;
        top.load += inst.loads[o as usize];
        heap.push(top);
    }
    mapping
}

/// Frozen pre-heterogeneity GreedyRefineLB (raw-load shedding + LPT).
fn legacy_greedy_refine(inst: &Instance, refine_tolerance: f64) -> Vec<u32> {
    #[derive(Clone, Copy)]
    struct MinPe {
        load: f64,
        pe: u32,
    }
    impl PartialEq for MinPe {
        fn eq(&self, other: &Self) -> bool {
            self.cmp(other) == Ordering::Equal
        }
    }
    impl Eq for MinPe {}
    impl PartialOrd for MinPe {
        fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for MinPe {
        fn cmp(&self, other: &Self) -> Ordering {
            other
                .load
                .partial_cmp(&self.load)
                .unwrap_or(Ordering::Equal)
                .then(other.pe.cmp(&self.pe))
        }
    }
    let n_pes = inst.topo.n_pes();
    let mut mapping = inst.mapping.clone();
    let mut pe_loads = inst.pe_loads(&mapping);
    let avg: f64 = pe_loads.iter().sum::<f64>() / n_pes as f64;
    let threshold = avg * (1.0 + refine_tolerance);
    let mut per_pe: Vec<Vec<u32>> = vec![Vec::new(); n_pes];
    for (o, &pe) in mapping.iter().enumerate() {
        per_pe[pe as usize].push(o as u32);
    }
    for objs in &mut per_pe {
        objs.sort_by(|&a, &b| {
            inst.loads[a as usize]
                .partial_cmp(&inst.loads[b as usize])
                .unwrap()
                .then(a.cmp(&b))
        });
    }
    let mut pool: Vec<u32> = Vec::new();
    for pe in 0..n_pes {
        while pe_loads[pe] > threshold {
            let headroom = pe_loads[pe] - avg;
            let pos = per_pe[pe]
                .iter()
                .rposition(|&o| inst.loads[o as usize] <= headroom);
            let idx = match pos {
                Some(i) => i,
                None if !per_pe[pe].is_empty() => 0,
                None => break,
            };
            let o = per_pe[pe].remove(idx);
            pe_loads[pe] -= inst.loads[o as usize];
            pool.push(o);
        }
    }
    pool.sort_by(|&a, &b| {
        inst.loads[b as usize]
            .partial_cmp(&inst.loads[a as usize])
            .unwrap()
            .then(a.cmp(&b))
    });
    let mut heap: BinaryHeap<MinPe> = pe_loads
        .iter()
        .enumerate()
        .map(|(pe, &load)| MinPe { load, pe: pe as u32 })
        .collect();
    for o in pool {
        let mut top = heap.pop().unwrap();
        mapping[o as usize] = top.pe;
        top.load += inst.loads[o as usize];
        heap.push(top);
    }
    mapping
}

/// Frozen pre-heterogeneity §III-D refinement (raw-load PE balancing).
fn legacy_assign_pes(inst: &Instance, new_node_map: &[u32], tol: f64) -> Vec<u32> {
    fn refine_within(
        placed: &mut [(u32, usize)],
        pe_loads: &mut [f64],
        loads: &[f64],
        tol: f64,
    ) {
        let n_pes = pe_loads.len();
        if n_pes < 2 {
            return;
        }
        let avg: f64 = pe_loads.iter().sum::<f64>() / n_pes as f64;
        for _ in 0..64 {
            let (max_pe, &max_load) = pe_loads
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            let (min_pe, &min_load) = pe_loads
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            if max_load <= avg * (1.0 + tol) || max_pe == min_pe {
                break;
            }
            let gap = max_load - min_load;
            let mut best: Option<(usize, f64)> = None;
            for (idx, &(o, pe)) in placed.iter().enumerate() {
                if pe != max_pe {
                    continue;
                }
                let l = loads[o as usize];
                if l <= 0.0 || l >= gap {
                    continue;
                }
                let score = (l - gap / 2.0).abs();
                if best.map(|(_, s)| score < s).unwrap_or(true) {
                    best = Some((idx, score));
                }
            }
            let Some((idx, _)) = best else { break };
            let (o, _) = placed[idx];
            placed[idx] = (o, min_pe);
            pe_loads[max_pe] -= loads[o as usize];
            pe_loads[min_pe] += loads[o as usize];
        }
    }

    let ppn = inst.topo.pes_per_node;
    if ppn == 1 {
        return new_node_map.to_vec();
    }
    let mut mapping = vec![0u32; inst.n_objects()];
    for node in 0..inst.topo.n_nodes as u32 {
        let members: Vec<u32> = (0..inst.n_objects() as u32)
            .filter(|&o| new_node_map[o as usize] == node)
            .collect();
        let pe_range = inst.topo.pes_of_node(node);
        let pe_lo = pe_range.start;
        let mut pe_loads = vec![0.0f64; ppn];
        let mut placed: Vec<(u32, usize)> = Vec::with_capacity(members.len());
        let mut arrivals: Vec<u32> = Vec::new();
        for &o in &members {
            let old_pe = inst.mapping[o as usize];
            if inst.topo.node_of_pe(old_pe) == node {
                let local = (old_pe - pe_lo) as usize;
                pe_loads[local] += inst.loads[o as usize];
                placed.push((o, local));
            } else {
                arrivals.push(o);
            }
        }
        arrivals.sort_by(|&a, &b| {
            inst.loads[b as usize]
                .partial_cmp(&inst.loads[a as usize])
                .unwrap()
                .then(a.cmp(&b))
        });
        for o in arrivals {
            let (local, _) = pe_loads
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            pe_loads[local] += inst.loads[o as usize];
            placed.push((o, local));
        }
        refine_within(&mut placed, &mut pe_loads, &inst.loads, tol);
        for (o, local) in placed {
            mapping[o as usize] = pe_lo + local as u32;
        }
    }
    mapping
}

// ========================================================== fixtures

fn random_instance(rng: &mut Rng, n_nodes: usize, ppn: usize) -> Instance {
    let side = 6 + rng.range(0, 5);
    let n = side * side;
    let mut edges = Vec::with_capacity(2 * n);
    for r in 0..side {
        for c in 0..side {
            let o = (r * side + c) as u32;
            edges.push((o, (r * side + (c + 1) % side) as u32, 64.0));
            edges.push((o, (((r + 1) % side) * side + c) as u32, 64.0));
        }
    }
    let graph = CommGraph::from_edges(n, &edges);
    let loads: Vec<f64> = (0..n).map(|_| rng.uniform(0.2, 3.0)).collect();
    let coords: Vec<[f64; 2]> =
        (0..n).map(|i| [(i % side) as f64, (i / side) as f64]).collect();
    let topo = Topology::new(n_nodes, ppn);
    let n_pes = topo.n_pes() as u64;
    let mapping: Vec<u32> = (0..n).map(|_| rng.below(n_pes) as u32).collect();
    Instance::new(loads, coords, graph, mapping, topo)
}

// ===================================================== identity tests

#[test]
fn greedy_uniform_bit_identical_to_frozen_legacy() {
    let mut rng = Rng::new(0x6E7E_0001);
    for trial in 0..20 {
        let inst = random_instance(&mut rng, 2 + trial % 5, 1 + trial % 3);
        let live = Greedy.rebalance(&inst);
        assert_eq!(live.mapping, legacy_greedy(&inst), "trial {trial}");
    }
}

#[test]
fn greedy_refine_uniform_bit_identical_to_frozen_legacy() {
    let mut rng = Rng::new(0x6E7E_0002);
    for trial in 0..20 {
        let inst = random_instance(&mut rng, 2 + trial % 5, 1 + trial % 3);
        let params = StrategyParams::default();
        let live = GreedyRefine { params }.rebalance(&inst);
        assert_eq!(
            live.mapping,
            legacy_greedy_refine(&inst, params.refine_tolerance),
            "trial {trial}"
        );
    }
}

#[test]
fn hierarchical_refinement_uniform_bit_identical_to_frozen_legacy() {
    use difflb::strategies::diffusion::hierarchical::assign_pes;
    let mut rng = Rng::new(0x6E7E_0003);
    for trial in 0..20 {
        let inst = random_instance(&mut rng, 2 + trial % 4, 2 + trial % 3);
        // a plausible node-level decision: each object's current node,
        // a third of them reassigned to a random node
        let mut node_map: Vec<u32> =
            inst.mapping.iter().map(|&pe| inst.topo.node_of_pe(pe)).collect();
        for nm in node_map.iter_mut() {
            if rng.chance(0.33) {
                *nm = rng.below(inst.topo.n_nodes as u64) as u32;
            }
        }
        let live = assign_pes(&inst, &node_map, 0.02);
        assert_eq!(live, legacy_assign_pes(&inst, &node_map, 0.02), "trial {trial}");
    }
}

#[test]
fn full_diffusion_uniform_unaffected_by_explicit_unit_speeds() {
    let mut rng = Rng::new(0x6E7E_0004);
    for trial in 0..8 {
        let inst = random_instance(&mut rng, 4, 1 + trial % 2);
        let mut tagged = inst.clone();
        tagged.topo = tagged.topo.clone().with_pe_speeds(vec![1.0; inst.topo.n_pes()]);
        for mk in [Diffusion::communication, Diffusion::coordinate] {
            let a = mk(StrategyParams::default()).rebalance(&inst);
            let b = mk(StrategyParams::default()).rebalance(&tagged);
            assert_eq!(a.mapping, b.mapping, "trial {trial}");
        }
    }
}

// ================================================ behavioral (hetero)

/// 8x8 periodic stencil, unit loads, contiguous row-strip quarters —
/// raw work perfectly balanced at 16 per node by construction.
fn balanced_quarters() -> Instance {
    let side = 8;
    let n = side * side;
    let mut edges = Vec::with_capacity(2 * n);
    for r in 0..side {
        for c in 0..side {
            let o = (r * side + c) as u32;
            edges.push((o, (r * side + (c + 1) % side) as u32, 64.0));
            edges.push((o, (((r + 1) % side) * side + c) as u32, 64.0));
        }
    }
    let graph = CommGraph::from_edges(n, &edges);
    let coords: Vec<[f64; 2]> =
        (0..n).map(|i| [(i % side) as f64, (i / side) as f64]).collect();
    let mapping: Vec<u32> = (0..n).map(|o| (o * 4 / n) as u32).collect();
    Instance::new(vec![1.0; n], coords, graph, mapping, Topology::flat(4))
}

#[test]
fn diffusion_improves_time_imbalance_on_slow_node() {
    // Node 0 at half speed, equal raw work per node: the raw-work
    // picture is perfectly balanced, so only a speed-aware balancer has
    // any reason to migrate — and it must cut the time imbalance.
    let mut inst = balanced_quarters();
    inst.topo = Topology::flat(4).with_pe_speeds(vec![0.5, 1.0, 1.0, 1.0]);
    let before = evaluate_mapping(&inst, &inst.mapping);
    let asg = Diffusion::communication(StrategyParams::default()).rebalance(&inst);
    let after = evaluate_mapping(&inst, &asg.mapping);
    assert!(after.migrations > 0, "speed-aware diffusion must act");
    assert!(
        after.time_max_avg_node < before.time_max_avg_node,
        "time imbalance {} !< {}",
        after.time_max_avg_node,
        before.time_max_avg_node
    );
}

#[test]
fn uniform_diffusion_ignores_balanced_raw_work() {
    // The same instance WITHOUT speeds is already balanced (16 per
    // node exactly): the uniform balancer must leave it alone, proving
    // the migrations above are driven by the speed model and not noise.
    let inst = balanced_quarters();
    let asg = Diffusion::communication(StrategyParams::default()).rebalance(&inst);
    assert_eq!(asg.migrations(&inst), 0, "uniform run migrated on balanced work");
}
