//! Chaos & elasticity validation (ISSUE 6).
//!
//! Three pillars, all over the real message-passing runtime:
//!
//! 1. **Inertness** — a fault plan that never fires (scheduled beyond
//!    the run, or a sub-detection-window delay) leaves every
//!    per-iteration record and the final mapping bit-identical to a
//!    plain run. The fault-tolerant machinery must cost nothing in
//!    determinism when nothing goes wrong.
//! 2. **Recovery** — a mid-pipeline kill / hang / partition completes
//!    on the surviving quorum: the run verifies, total work is
//!    conserved (the per-round state checkpoint re-homes *exact*
//!    state, so physics match a fault-free run), and no object is ever
//!    mapped to a dead node afterwards.
//! 3. **Elasticity** — planned join/leave schedules produce the same
//!    records sequentially and distributed, and the departing /
//!    not-yet-joined node holds zero work outside its membership
//!    window.
//!
//! The seeded chaos matrix (kill × hang × partition across cluster
//! sizes) is gated behind `DIFFLB_TEST_FAULTS`; CI sweeps it with
//! `DIFFLB_TEST_NODES` ∈ {4, 8, 16}.

use std::sync::Arc;

use difflb::apps::driver::{run_app, DriverConfig, RunReport};
use difflb::apps::hotspot::HotspotConfig;
use difflb::apps::pic::{Backend, InitMode, PicApp, PicConfig};
use difflb::apps::stencil::Decomposition;
use difflb::distributed::driver::{run_hotspot_distributed, run_pic_distributed};
use difflb::model::{ResizeSchedule, Topology};
use difflb::simnet::{FaultKind, FaultPlan};
use difflb::strategies::diffusion::{Diffusion, Variant};
use difflb::strategies::StrategyParams;

fn pic_cfg(topo: Topology) -> PicConfig {
    PicConfig {
        grid: 64,
        n_particles: 2_000,
        k: 1,
        m: 1,
        init: InitMode::Geometric { rho: 0.9 },
        chares_x: 4,
        chares_y: 4,
        decomp: Decomposition::Striped,
        topo,
        q: 1.0,
        seed: 11,
        particle_bytes: 48.0,
        threads: 2,
    }
}

/// 12 iterations at period 4 → LB rounds 0/1/2 at iterations 3/7/11.
fn chaos_driver(plan: FaultPlan) -> DriverConfig {
    DriverConfig {
        iters: 12,
        lb_period: 4,
        deterministic_loads: true,
        fault_plan: Arc::new(plan),
        ..Default::default()
    }
}

fn run_chaos_pic(topo: Topology, driver: &DriverConfig) -> RunReport {
    run_pic_distributed(&pic_cfg(topo), Variant::Communication, StrategyParams::default(), driver)
        .unwrap()
}

fn assert_records_identical(a: &RunReport, b: &RunReport, ctx: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{ctx}: record counts");
    assert_eq!(a.total_migrations, b.total_migrations, "{ctx}: migration totals");
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.migrations, y.migrations, "{ctx} iter {}: migrations", x.iter);
        assert_eq!(x.work_max_avg, y.work_max_avg, "{ctx} iter {}: imbalance", x.iter);
        assert_eq!(x.time_max_avg, y.time_max_avg, "{ctx} iter {}: time imbalance", x.iter);
        assert_eq!(x.comm_max_s, y.comm_max_s, "{ctx} iter {}: comm max", x.iter);
        assert_eq!(x.comm_avg_s, y.comm_avg_s, "{ctx} iter {}: comm avg", x.iter);
        assert_eq!(x.node_work, y.node_work, "{ctx} iter {}: node work", x.iter);
    }
    assert_eq!(a.final_mapping, b.final_mapping, "{ctx}: final mapping");
}

/// No object on node `dead` in the final mapping, and zero recorded
/// work there from iteration `from_iter` on.
fn assert_evicted(rep: &RunReport, topo: &Topology, dead: u32, from_iter: usize, ctx: &str) {
    assert!(
        rep.final_mapping.iter().all(|&pe| topo.node_of_pe(pe) != dead),
        "{ctx}: final mapping still places objects on node {dead}"
    );
    for rec in rep.records.iter().filter(|r| r.iter >= from_iter) {
        assert_eq!(
            rec.node_work[dead as usize], 0.0,
            "{ctx} iter {}: dead node {dead} still accounted work",
            rec.iter
        );
    }
}

/// The checkpoint re-homes exact state, so each iteration's total work
/// must match a fault-free run's — only the *grouping* of chare loads
/// into nodes changes, which permits f64 summation-order slack.
fn assert_work_conserved(faulty: &RunReport, plain: &RunReport, ctx: &str) {
    for (f, p) in faulty.records.iter().zip(&plain.records) {
        let tf: f64 = f.node_work.iter().sum();
        let tp: f64 = p.node_work.iter().sum();
        assert!(
            (tf - tp).abs() <= 1e-9 * tp.abs().max(1.0),
            "{ctx} iter {}: total work {tf} != fault-free {tp}",
            f.iter
        );
    }
}

// ---------------------------------------------------------------------
// Pillar 1: inertness.

#[test]
fn never_firing_fault_plan_is_bit_identical() {
    // The plan is *active* (fault mode: detection patience, per-round
    // checkpoints, staged pipeline, fault-clocked partitions) but the
    // one event sits beyond the run's 3 LB rounds — every record must
    // still match the plain path bit for bit.
    let plain = run_chaos_pic(Topology::flat(4), &chaos_driver(FaultPlan::none()));
    assert!(plain.verified);
    let armed = run_chaos_pic(
        Topology::flat(4),
        &chaos_driver(FaultPlan::parse("kill:2@99").unwrap()),
    );
    assert!(armed.verified);
    assert_records_identical(&armed, &plain, "armed-but-idle plan");
    // Same bar for the heal machinery: a partition with a scheduled
    // heal, both beyond the run, must leave no trace either.
    let healing = run_chaos_pic(
        Topology::flat(4),
        &chaos_driver(FaultPlan::parse("part:3@90-99").unwrap()),
    );
    assert!(healing.verified);
    assert_records_identical(&healing, &plain, "armed-but-idle healing partition");
}

#[test]
fn sub_detection_delay_changes_nothing() {
    // A Delay victim stalls for less than the detection window: every
    // peer just waits it out, nobody is excluded, and the run is
    // bit-identical to a fault-free one.
    let plain = run_chaos_pic(Topology::flat(4), &chaos_driver(FaultPlan::none()));
    let delayed = run_chaos_pic(
        Topology::flat(4),
        &chaos_driver(FaultPlan::parse("delay:2@1:s2").unwrap()),
    );
    assert!(delayed.verified);
    assert_records_identical(&delayed, &plain, "sub-detection delay");
}

// ---------------------------------------------------------------------
// Pillar 2: recovery.

#[test]
fn mid_pipeline_kill_completes_on_surviving_quorum() {
    // ISSUE 6 acceptance: rank 2 dies inside LB round 1's stage-2
    // protocol (iteration 7). The surviving quorum detects it, declares
    // a new epoch, restarts the pipeline on 3 nodes, re-homes the dead
    // rank's checkpointed objects — and the physics still verify.
    let topo = Topology::flat(4);
    let plain = run_chaos_pic(topo.clone(), &chaos_driver(FaultPlan::none()));
    let rep = run_chaos_pic(topo.clone(), &chaos_driver(FaultPlan::parse("kill:2@1:s2").unwrap()));
    assert!(rep.verified, "physics failed after mid-pipeline kill");
    assert_eq!(rep.records.len(), 12);
    assert_work_conserved(&rep, &plain, "kill:2@1:s2");
    assert_evicted(&rep, &topo, 2, 8, "kill:2@1:s2");
}

#[test]
fn kill_recovers_at_every_stage_point() {
    // The fault gate sits at the entry of each of the three pipeline
    // stages; recovery must work from any of them. Round 0 (iteration
    // 3) is the earliest pipeline, so eviction holds from iteration 4.
    let topo = Topology::flat(4);
    for stage in ["s1", "s2", "s3"] {
        let spec = format!("kill:3@0:{stage}");
        let rep = run_chaos_pic(topo.clone(), &chaos_driver(FaultPlan::parse(&spec).unwrap()));
        assert!(rep.verified, "{spec}: physics failed");
        assert_evicted(&rep, &topo, 3, 4, &spec);
    }
}

#[test]
fn hang_victim_is_excluded_and_run_completes() {
    // The victim stalls past the detection window, is declared dead,
    // and on waking discovers its exclusion (stale-epoch drops + the
    // catch-up protocol) instead of corrupting the new epoch.
    let topo = Topology::flat(4);
    let plain = run_chaos_pic(topo.clone(), &chaos_driver(FaultPlan::none()));
    let rep = run_chaos_pic(topo.clone(), &chaos_driver(FaultPlan::parse("hang:1@1:s2").unwrap()));
    assert!(rep.verified, "physics failed after hang exclusion");
    assert_work_conserved(&rep, &plain, "hang:1@1:s2");
    assert_evicted(&rep, &topo, 1, 8, "hang:1@1:s2");
}

#[test]
fn partition_minority_is_excluded() {
    // A permanent cut strands rank 3 from the coordinator side at LB
    // round 1; the majority detects the silence and continues without
    // it. (The checkpoint taken at round entry predates the cut, so the
    // minority's objects are re-homed exactly.)
    let topo = Topology::flat(4);
    let plain = run_chaos_pic(topo.clone(), &chaos_driver(FaultPlan::none()));
    let rep = run_chaos_pic(topo.clone(), &chaos_driver(FaultPlan::parse("part:3@1").unwrap()));
    assert!(rep.verified, "physics failed after partition");
    assert_work_conserved(&rep, &plain, "part:3@1");
    assert_evicted(&rep, &topo, 3, 8, "part:3@1");
}

#[test]
fn kill_recovers_on_the_second_workload() {
    // The recovery path is app-generic: hotspot (analytic loads, no
    // checkpoint payload — ownership is re-derived) survives the same
    // mid-pipeline kill.
    let topo = Topology::flat(4);
    let cfg = HotspotConfig { topo: topo.clone(), ..Default::default() };
    let driver = chaos_driver(FaultPlan::parse("kill:2@1:s2").unwrap());
    let rep =
        run_hotspot_distributed(&cfg, Variant::Communication, StrategyParams::default(), &driver)
            .unwrap();
    assert!(rep.verified, "hotspot failed after mid-pipeline kill");
    assert_evicted(&rep, &topo, 2, 8, "hotspot kill:2@1:s2");
}

// ---------------------------------------------------------------------
// Telemetry: per-run resilience totals (ISSUE 7). The fault plan
// predicts these exactly, so they are asserted exactly — on the
// per-run `RunReport::obs` totals, which are scoped to one run. The
// process-global registry aggregates across every test in the binary,
// so it only ever gets monotonicity (>=) assertions.

#[test]
fn inert_runs_report_zero_resilience_totals() {
    // No fault fires → nothing is ever stale, parked, or timed out,
    // and no epoch is declared: all four totals must be exactly zero,
    // on the plain path and on the armed-but-idle fault-mode path
    // alike.
    let plain = run_chaos_pic(Topology::flat(4), &chaos_driver(FaultPlan::none()));
    assert_eq!(plain.obs, difflb::obs::ObsTotals::default(), "plain run");
    let armed = run_chaos_pic(
        Topology::flat(4),
        &chaos_driver(FaultPlan::parse("kill:2@99").unwrap()),
    );
    assert_eq!(armed.obs, difflb::obs::ObsTotals::default(), "armed-but-idle plan");
    let delayed = run_chaos_pic(
        Topology::flat(4),
        &chaos_driver(FaultPlan::parse("delay:2@1:s2").unwrap()),
    );
    assert_eq!(delayed.obs, difflb::obs::ObsTotals::default(), "sub-detection delay");
}

#[test]
fn kill_declares_exactly_one_epoch() {
    let rep = run_chaos_pic(
        Topology::flat(4),
        &chaos_driver(FaultPlan::parse("kill:2@1:s2").unwrap()),
    );
    assert!(rep.verified);
    assert_eq!(rep.obs.epochs, 1, "one kill → exactly one epoch declaration");
    // The recovery cycle left its marks in the process-global
    // registry: a declaration, a quorum restart, and the heartbeat
    // probes that preceded them.
    assert!(difflb::obs::registry::counter("epoch.declarations").get() >= 1);
    assert!(difflb::obs::registry::counter("epoch.quorum_restarts").get() >= 1);
    assert!(difflb::obs::registry::counter("epoch.heartbeats").get() >= 1);
}

#[test]
fn hang_exclusion_declares_exactly_one_epoch() {
    let rep = run_chaos_pic(
        Topology::flat(4),
        &chaos_driver(FaultPlan::parse("hang:1@1:s2").unwrap()),
    );
    assert!(rep.verified);
    assert_eq!(rep.obs.epochs, 1, "one hang exclusion → exactly one epoch");
}

#[test]
fn partition_declares_exactly_one_epoch() {
    let rep = run_chaos_pic(
        Topology::flat(4),
        &chaos_driver(FaultPlan::parse("part:3@1").unwrap()),
    );
    assert!(rep.verified);
    assert_eq!(rep.obs.epochs, 1, "one partition exclusion → exactly one epoch");
}

// ---------------------------------------------------------------------
// Pillar 3: elasticity.

fn assert_resize_equivalence(spec: &str) -> (RunReport, Topology) {
    let topo = Topology::flat(4);
    let driver = DriverConfig {
        iters: 12,
        lb_period: 4,
        deterministic_loads: true,
        resize: ResizeSchedule::parse(spec).unwrap(),
        ..Default::default()
    };
    let cfg = pic_cfg(topo.clone());
    let params = StrategyParams::default();
    let seq = {
        let mut app = PicApp::new(cfg.clone(), Backend::Native).unwrap();
        let strat = Diffusion::communication(params);
        run_app(&mut app, &strat, &driver).unwrap()
    };
    let dist = run_pic_distributed(&cfg, Variant::Communication, params, &driver).unwrap();
    assert!(seq.verified, "{spec}: sequential physics failed");
    assert!(dist.verified, "{spec}: distributed physics failed");
    assert_records_identical(&dist, &seq, spec);
    (dist, topo)
}

#[test]
fn resize_leave_matches_sequential_and_evicts() {
    // Drain-then-remove: node 3 leaves at LB round 1 (iteration 7). The
    // distributed leaver hands its objects to the new owners and exits;
    // the records must match the sequential restricted rebalance bit
    // for bit, and node 3 holds nothing afterwards.
    let (rep, topo) = assert_resize_equivalence("leave:3@1");
    assert_evicted(&rep, &topo, 3, 8, "leave:3@1");
}

#[test]
fn resize_join_matches_sequential_and_waits() {
    // Node 3 is absent from the initial membership and joins at LB
    // round 1: it must hold zero work through iteration 7 (the join
    // round's accounting predates the pipeline) and participate after.
    let (rep, _) = assert_resize_equivalence("join:3@1");
    for rec in rep.records.iter().filter(|r| r.iter <= 7) {
        assert_eq!(rec.node_work[3], 0.0, "iter {}: joiner already has work", rec.iter);
    }
    let late: f64 = rep.records.iter().filter(|r| r.iter > 7).map(|r| r.node_work[3]).sum();
    assert!(late > 0.0, "joiner never received work after joining");
}

#[test]
fn resize_leave_then_join_round_trips() {
    // A node leaves and a different node joins later in the same run —
    // the two halves of elasticity compose.
    let (rep, topo) = assert_resize_equivalence("leave:2@0,join:1@2");
    assert_evicted(&rep, &topo, 2, 4, "leave:2@0,join:1@2");
}

// ---------------------------------------------------------------------
// ISSUE 10: leader election, partition healing, faults during joins.

/// 20 iterations at period 4 → LB rounds 0..4 at iterations
/// 3/7/11/15/19 — long enough to watch an exiled minority idle through
/// an intermediate round, heal, and do useful work afterwards.
fn heal_driver(plan: FaultPlan) -> DriverConfig {
    DriverConfig {
        iters: 20,
        lb_period: 4,
        deterministic_loads: true,
        fault_plan: Arc::new(plan),
        ..Default::default()
    }
}

/// Work conservation keyed by iteration number — for runs whose root
/// died mid-run, where the successor's records only begin at its
/// takeover round.
fn assert_work_conserved_from(faulty: &RunReport, plain: &RunReport, from_iter: usize, ctx: &str) {
    let mut checked = 0;
    for f in faulty.records.iter().filter(|r| r.iter >= from_iter) {
        let p = plain
            .records
            .iter()
            .find(|r| r.iter == f.iter)
            .unwrap_or_else(|| panic!("{ctx}: fault-free run lacks iteration {}", f.iter));
        let tf: f64 = f.node_work.iter().sum();
        let tp: f64 = p.node_work.iter().sum();
        assert!(
            (tf - tp).abs() <= 1e-9 * tp.abs().max(1.0),
            "{ctx} iter {}: total work {tf} != fault-free {tp}",
            f.iter
        );
        checked += 1;
    }
    assert!(checked > 0, "{ctx}: no records at or past iteration {from_iter}");
}

#[test]
fn coordinator_kill_elects_successor_and_completes() {
    // Rank 0 — root, record keeper, checkpoint custodian — dies inside
    // LB round 1's stage-2 protocol. The survivors elect the lowest
    // alive rank (1), which declares the epoch, takes over roothood,
    // and re-homes the dead root's objects from its successor-mirrored
    // checkpoint copy. The records rank 0 took to its grave are gone;
    // everything from the takeover round on must be intact.
    let topo = Topology::flat(4);
    let plain = run_chaos_pic(topo.clone(), &chaos_driver(FaultPlan::none()));
    let rep = run_chaos_pic(topo.clone(), &chaos_driver(FaultPlan::parse("kill:0@1:s2").unwrap()));
    assert!(rep.verified, "physics failed after coordinator kill");
    assert_eq!(rep.obs.epochs, 1, "one kill → exactly one epoch declaration");
    assert_eq!(
        rep.records.first().map(|r| r.iter),
        Some(7),
        "successor's records must start at its takeover round"
    );
    assert_eq!(rep.records.len(), 5, "iterations 7..12 belong to the successor");
    assert_work_conserved_from(&rep, &plain, 8, "kill:0@1:s2");
    assert_evicted(&rep, &topo, 0, 8, "kill:0@1:s2");
    // The election cascade left its mark: the first coordinator
    // candidate (rank 0 itself) was silent, forcing a re-election.
    assert!(difflb::obs::registry::counter("epoch.elections").get() >= 1);
}

#[test]
fn partition_heals_and_minority_rejoins() {
    // Rank 3 is cut away at LB round 1, idles in exile through round 2,
    // and the cut lifts at round 3: the majority welcomes it back with
    // an epoch declaration, it re-enters through the joiner path, and
    // the next rebalance hands it real work again. Total work must be
    // conserved through the whole exile-and-return arc.
    let topo = Topology::flat(4);
    let plain = run_chaos_pic(topo.clone(), &heal_driver(FaultPlan::none()));
    let rep = run_chaos_pic(topo.clone(), &heal_driver(FaultPlan::parse("part:3@1-3").unwrap()));
    assert!(rep.verified, "physics failed across partition heal");
    assert_eq!(rep.records.len(), 20, "root survived; every iteration recorded");
    assert_eq!(rep.obs.epochs, 1, "the heal re-uses the majority's epoch");
    assert_work_conserved(&rep, &plain, "part:3@1-3");
    // Exiled: no work from the cut until the heal round's rebalance.
    for rec in rep.records.iter().filter(|r| (8..=15).contains(&r.iter)) {
        assert_eq!(
            rec.node_work[3], 0.0,
            "iter {}: exiled node still accounted work",
            rec.iter
        );
    }
    // Healed: the post-heal rounds rebalance onto the returned node.
    let late: f64 = rep.records.iter().filter(|r| r.iter > 15).map(|r| r.node_work[3]).sum();
    assert!(late > 0.0, "healed node never received work after rejoining");
    assert!(difflb::obs::registry::counter("epoch.exiles").get() >= 1);
    assert!(difflb::obs::registry::counter("epoch.heals").get() >= 1);
}

#[test]
fn rank0_minority_heal_promotes_successor_root() {
    // The hardest composition: the cut strands rank 0 — the original
    // root — in the minority. Rank 1 is elected, takes over roothood
    // (with the successor-mirrored checkpoints), and when the cut heals
    // rank 0 re-enters as an ordinary rejoiner: roothood does NOT
    // bounce back, so the run's state stays where it migrated.
    let topo = Topology::flat(4);
    let plain = run_chaos_pic(topo.clone(), &chaos_driver(FaultPlan::none()));
    let rep = run_chaos_pic(topo.clone(), &chaos_driver(FaultPlan::parse("part:0@1-2").unwrap()));
    assert!(rep.verified, "physics failed after root exile and heal");
    assert_eq!(
        rep.records.first().map(|r| r.iter),
        Some(7),
        "successor's records must start at its takeover round"
    );
    assert_work_conserved_from(&rep, &plain, 8, "part:0@1-2");
    for rec in rep.records.iter().filter(|r| (8..=11).contains(&r.iter)) {
        assert_eq!(rec.node_work[0], 0.0, "iter {}: exiled root accounted work", rec.iter);
    }
}

#[test]
fn fault_beside_join_spares_the_joiner() {
    // Rank 3 joins at LB round 1 — the same round rank 2 dies
    // mid-pipeline. The join handshake is decoupled from the failure
    // detector: the joiner rides through the epoch declaration as an
    // ordinary pipeline participant and still ends up with real work.
    let topo = Topology::flat(4);
    let mk = |plan: FaultPlan| DriverConfig {
        iters: 12,
        lb_period: 4,
        deterministic_loads: true,
        resize: ResizeSchedule::parse("join:3@1").unwrap(),
        fault_plan: Arc::new(plan),
        ..Default::default()
    };
    let plain = run_chaos_pic(topo.clone(), &mk(FaultPlan::none()));
    let rep = run_chaos_pic(topo.clone(), &mk(FaultPlan::parse("kill:2@1:s2").unwrap()));
    assert!(rep.verified, "physics failed when a fault landed beside a join");
    assert_eq!(rep.records.len(), 12);
    assert_work_conserved(&rep, &plain, "join:3@1 + kill:2@1:s2");
    assert_evicted(&rep, &topo, 2, 8, "join:3@1 + kill:2@1:s2");
    let late: f64 = rep.records.iter().filter(|r| r.iter > 7).map(|r| r.node_work[3]).sum();
    assert!(late > 0.0, "joiner never received work despite surviving the fault");
}

#[test]
fn joiner_killed_at_its_join_round_aborts_only_the_join() {
    // The joiner itself dies inside the pipeline it was joining. The
    // incumbent quorum declares it failed and restarts the round
    // without it — the join is aborted, nothing else is lost.
    let topo = Topology::flat(4);
    let mk = |plan: FaultPlan| DriverConfig {
        iters: 12,
        lb_period: 4,
        deterministic_loads: true,
        resize: ResizeSchedule::parse("join:3@1").unwrap(),
        fault_plan: Arc::new(plan),
        ..Default::default()
    };
    let plain = run_chaos_pic(topo.clone(), &mk(FaultPlan::none()));
    let rep = run_chaos_pic(topo.clone(), &mk(FaultPlan::parse("kill:3@1:s2").unwrap()));
    assert!(rep.verified, "physics failed after the joiner died mid-join");
    assert_eq!(rep.records.len(), 12);
    assert_eq!(rep.obs.epochs, 1, "one dead joiner → exactly one epoch");
    assert_work_conserved(&rep, &plain, "join:3@1 + kill:3@1:s2");
    assert_evicted(&rep, &topo, 3, 0, "join:3@1 + kill:3@1:s2");
}

// ---------------------------------------------------------------------
// Seeded chaos matrix (CI: DIFFLB_TEST_FAULTS=1, nodes ∈ {4, 8, 16}).

#[test]
fn chaos_matrix_from_seeds() {
    if std::env::var("DIFFLB_TEST_FAULTS").is_err() {
        eprintln!("chaos_matrix_from_seeds: skipped (set DIFFLB_TEST_FAULTS=1)");
        return;
    }
    let n: usize = std::env::var("DIFFLB_TEST_NODES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let topo = Topology::flat(n);
    for seed in 1..=6u64 {
        let plan = FaultPlan::from_seed(seed, n, 3);
        assert!(plan.is_active(), "seed {seed}: from_seed produced an inert plan");
        plan.validate(n).unwrap_or_else(|e| panic!("seed {seed}: invalid plan: {e}"));
        let rep = run_chaos_pic(topo.clone(), &chaos_driver(plan.clone()));
        assert!(rep.verified, "seed {seed} ({plan:?}): physics failed");
        assert_eq!(rep.records.len(), 12, "seed {seed}: run truncated");
        for e in plan.events.iter().filter(|e| e.kind != FaultKind::Delay) {
            assert!(
                rep.final_mapping.iter().all(|&pe| topo.node_of_pe(pe) != e.rank),
                "seed {seed}: objects left on dead rank {}",
                e.rank
            );
        }
        for p in &plan.partitions {
            for &v in &p.minority {
                assert!(
                    rep.final_mapping.iter().all(|&pe| topo.node_of_pe(pe) != v),
                    "seed {seed}: objects left on partitioned rank {v}"
                );
            }
        }
    }
    // ISSUE 10: rank 0 is no longer privileged — sweep the election and
    // heal paths at every matrix size too. chaos_driver runs LB rounds
    // 0..3, so a cut at round 1 healing at round 2 exercises the full
    // exile-welcome-rejoin arc.
    let specs =
        ["kill:0@1:s2".to_string(), "part:0@1-2".to_string(), format!("part:{}@1-2", n - 1)];
    for spec in &specs {
        let plan = FaultPlan::parse(spec).unwrap();
        plan.validate(n).unwrap_or_else(|e| panic!("{spec}: invalid plan: {e}"));
        let rep = run_chaos_pic(topo.clone(), &chaos_driver(plan));
        assert!(rep.verified, "{spec} at n={n}: physics failed");
        assert!(!rep.records.is_empty(), "{spec} at n={n}: run produced no records");
    }
}
