//! The leader/coordinator: resolves a [`Config`] into an application +
//! topology + strategy + schedule, runs it, and reports the paper's
//! metrics. This is the programmatic API behind the `difflb` CLI and
//! the examples; benches drive the pieces directly.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::apps::driver::{run_pic, DriverConfig, RunReport};
use crate::apps::pic::{Backend, InitMode, PicApp, PicConfig};
use crate::apps::stencil::Decomposition;
use crate::model::{evaluate, Instance, LbMetrics, Topology};
use crate::runtime::Engine;
use crate::simnet::NetModel;
use crate::strategies::{self, LoadBalancer, StrategyParams};
use crate::util::config::Config;

/// Everything a run needs, resolved from configuration.
pub struct Coordinator {
    pub strategy: Box<dyn LoadBalancer>,
    pub params: StrategyParams,
    pub driver: DriverConfig,
}

/// Strategy parameters from a config (section `lb`).
pub fn params_from_config(cfg: &Config) -> StrategyParams {
    let d = StrategyParams::default();
    StrategyParams {
        neighbor_count: cfg.get_or("lb.neighbors", d.neighbor_count),
        handshake_max_rounds: cfg.get_or("lb.handshake_rounds", d.handshake_max_rounds),
        vlb_tolerance: cfg.get_or("lb.vlb_tolerance", d.vlb_tolerance),
        vlb_max_iters: cfg.get_or("lb.vlb_max_iters", d.vlb_max_iters),
        overfill: cfg.get_or("lb.overfill", d.overfill),
        refine_tolerance: cfg.get_or("lb.refine_tolerance", d.refine_tolerance),
        balance_tolerance: cfg.get_or("lb.balance_tolerance", d.balance_tolerance),
        itr: cfg.get_or("lb.itr", d.itr),
        sfc_window: cfg.get_or("lb.sfc_window", d.sfc_window),
        reuse_neighbors: cfg.get_bool_or("lb.reuse_neighbors", d.reuse_neighbors),
        seed: cfg.get_or("lb.seed", d.seed),
    }
}

/// PIC app configuration from a config (section `pic` + `topo`).
pub fn pic_from_config(cfg: &Config) -> Result<PicConfig> {
    let d = PicConfig::default();
    let init = match cfg.get("pic.init").unwrap_or("geometric") {
        "geometric" => InitMode::Geometric { rho: cfg.get_or("pic.rho", 0.9) },
        "sinusoidal" => InitMode::Sinusoidal,
        "linear" => InitMode::Linear { alpha: cfg.get_or("pic.alpha", 1.0) },
        "patch" => InitMode::Patch {
            x0: cfg.get_or("pic.x0", 0.0),
            x1: cfg.get_or("pic.x1", 10.0),
            y0: cfg.get_or("pic.y0", 0.0),
            y1: cfg.get_or("pic.y1", 10.0),
        },
        other => bail!("unknown pic.init '{other}'"),
    };
    let decomp = match cfg.get("pic.decomp").unwrap_or("striped") {
        "striped" => Decomposition::Striped,
        "tiled" | "quad" => Decomposition::Tiled,
        other => bail!("unknown pic.decomp '{other}'"),
    };
    Ok(PicConfig {
        grid: cfg.get_or("pic.grid", d.grid),
        n_particles: cfg.get_or("pic.particles", d.n_particles),
        k: cfg.get_or("pic.k", d.k),
        m: cfg.get_or("pic.m", d.m),
        init,
        chares_x: cfg.get_or("pic.chares_x", d.chares_x),
        chares_y: cfg.get_or("pic.chares_y", d.chares_y),
        decomp,
        topo: Topology::new(
            cfg.get_or("topo.nodes", 4),
            cfg.get_or("topo.pes_per_node", 1),
        ),
        q: cfg.get_or("pic.q", d.q),
        seed: cfg.get_or("pic.seed", d.seed),
        particle_bytes: cfg.get_or("pic.particle_bytes", d.particle_bytes),
        threads: cfg.get_or("pic.threads", d.threads),
    })
}

/// Network model from a config (section `net`).
pub fn net_from_config(cfg: &Config) -> NetModel {
    let d = NetModel::default();
    NetModel {
        alpha: cfg.get_or("net.alpha", d.alpha),
        beta: cfg.get_or("net.beta", d.beta),
        intra_factor: cfg.get_or("net.intra_factor", d.intra_factor),
    }
}

impl Coordinator {
    /// Build from a layered config. `lb.mode = distributed` (or
    /// `run.mode = distributed`, which also switches the app driver)
    /// swaps the diffusion strategy for its message-passing-protocol
    /// execution (`dist-diff-*`, see `crate::distributed`).
    pub fn from_config(cfg: &Config) -> Result<Coordinator> {
        let params = params_from_config(cfg);
        for key in ["run.mode", "lb.mode"] {
            if let Some(v) = cfg.get(key) {
                if !matches!(v, "sequential" | "distributed") {
                    bail!("unknown {key} '{v}' (expected 'sequential' or 'distributed')");
                }
            }
        }
        let mut name = cfg.get("lb.strategy").unwrap_or("diff-comm").to_string();
        let distributed = matches!(cfg.get("lb.mode"), Some("distributed"))
            || matches!(cfg.get("run.mode"), Some("distributed"));
        if distributed && cfg.get_bool_or("lb.reuse_neighbors", false) {
            crate::warn!(
                "lb.reuse_neighbors has no effect in distributed mode: the handshake \
                 protocol re-runs every LB round"
            );
        }
        if distributed {
            name = match name.as_str() {
                "diff-comm" => "dist-diff-comm".to_string(),
                "diff-coord" => "dist-diff-coord".to_string(),
                n if n.starts_with("dist-diff-") => n.to_string(),
                other => bail!(
                    "distributed mode supports only the diffusion strategies \
                     (got '{other}'; use diff-comm or diff-coord)"
                ),
            };
        }
        let strategy = strategies::make(&name, params)?;
        let driver = DriverConfig {
            iters: cfg.get_or("run.iters", 100),
            lb_period: cfg.get_or("run.lb_period", 10),
            net: net_from_config(cfg),
            log_every: cfg.get_or("run.log_every", 0),
            deterministic_loads: cfg.get_bool_or("run.deterministic_loads", false),
        };
        Ok(Coordinator { strategy, params, driver })
    }

    /// Pick the PJRT backend when artifacts exist (or `pic.backend`
    /// forces one); fall back to the native push otherwise.
    pub fn backend(cfg: &Config) -> Result<Backend> {
        match cfg.get("pic.backend").unwrap_or("auto") {
            "native" => Ok(Backend::Native),
            "pjrt" => Ok(Backend::Pjrt(Arc::new(Engine::new()?))),
            "auto" => match Engine::new() {
                Ok(e) => Ok(Backend::Pjrt(Arc::new(e))),
                Err(err) => {
                    crate::warn!("PJRT unavailable ({err:#}); using native backend");
                    Ok(Backend::Native)
                }
            },
            other => bail!("unknown pic.backend '{other}'"),
        }
    }

    /// Run the PIC PRK app end to end. With `run.mode = distributed`
    /// the run executes on the node-partitioned distributed driver
    /// (`crate::distributed::driver`): one simulated node per topology
    /// node, real particle exchange, and the LB pipeline inline as
    /// message-passing protocols.
    pub fn run_pic(&self, cfg: &Config) -> Result<RunReport> {
        let pic_cfg = pic_from_config(cfg)?;
        if matches!(cfg.get("run.mode"), Some("distributed")) {
            if matches!(cfg.get("pic.backend"), Some("pjrt")) {
                bail!(
                    "run.mode = distributed is native-only: each simulated node \
                     pushes its own partition (pic.backend = pjrt is unsupported here)"
                );
            }
            let variant = match self.strategy.name() {
                "diff-comm" | "dist-diff-comm" => {
                    crate::strategies::diffusion::Variant::Communication
                }
                "diff-coord" | "dist-diff-coord" => {
                    crate::strategies::diffusion::Variant::Coordinate
                }
                other => bail!("run.mode = distributed requires a diffusion strategy (got '{other}')"),
            };
            return crate::distributed::driver::run_pic_distributed(
                &pic_cfg,
                variant,
                self.params,
                &self.driver,
            );
        }
        let backend = Self::backend(cfg)?;
        let mut app = PicApp::new(pic_cfg, backend).context("initializing PIC app")?;
        run_pic(&mut app, self.strategy.as_ref(), &self.driver)
    }

    /// Balance one instance and report paper metrics.
    pub fn balance_instance(&self, inst: &Instance) -> (crate::model::Assignment, LbMetrics) {
        let t = std::time::Instant::now();
        let asg = self.strategy.rebalance(inst);
        let mut m = evaluate(inst, &asg);
        m.strategy_s = t.elapsed().as_secs_f64();
        (asg, m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::stencil;

    #[test]
    fn config_round_trip() {
        let cfg = Config::from_str(
            "[lb]\nstrategy = diff-coord\nneighbors = 6\n[run]\niters = 5\nlb_period = 2\n\
             [pic]\ngrid = 64\nparticles = 500\nchares_x = 4\nchares_y = 4\nbackend = native\n\
             [topo]\nnodes = 2",
        )
        .unwrap();
        let coord = Coordinator::from_config(&cfg).unwrap();
        assert_eq!(coord.strategy.name(), "diff-coord");
        assert_eq!(coord.params.neighbor_count, 6);
        assert_eq!(coord.driver.iters, 5);
        let pic = pic_from_config(&cfg).unwrap();
        assert_eq!(pic.grid, 64);
        assert_eq!(pic.topo.n_nodes, 2);
    }

    #[test]
    fn tiny_pic_run_native() {
        let cfg = Config::from_str(
            "[lb]\nstrategy = diff-comm\n[run]\niters = 6\nlb_period = 3\n\
             [pic]\ngrid = 32\nparticles = 400\nchares_x = 4\nchares_y = 4\nbackend = native\nthreads = 2\n\
             [topo]\nnodes = 2",
        )
        .unwrap();
        let coord = Coordinator::from_config(&cfg).unwrap();
        let rep = coord.run_pic(&cfg).unwrap();
        assert_eq!(rep.records.len(), 6);
        assert!(rep.verified);
    }

    #[test]
    fn balance_instance_reports_metrics() {
        let cfg = Config::from_str("[lb]\nstrategy = greedy-refine").unwrap();
        let coord = Coordinator::from_config(&cfg).unwrap();
        let mut inst = stencil::stencil_2d(16, 4, 4, stencil::Decomposition::Tiled);
        stencil::inject_noise(&mut inst, 0.4, 1);
        let (_asg, m) = coord.balance_instance(&inst);
        assert!(m.max_avg_pe < 1.2);
        assert!(m.strategy_s >= 0.0);
    }

    #[test]
    fn bad_strategy_name_errors() {
        let cfg = Config::from_str("[lb]\nstrategy = nope").unwrap();
        assert!(Coordinator::from_config(&cfg).is_err());
    }
}
