//! The leader/coordinator: resolves a [`Config`] into an application +
//! topology + strategy + schedule, runs it through the generic driver,
//! and reports the paper's metrics. This is the programmatic API behind
//! the `difflb` CLI and the examples; benches drive the pieces
//! directly.
//!
//! Applications are resolved by the `app.kind` registry
//! ([`app_from_config`], names in
//! [`AVAILABLE_APPS`](crate::apps::AVAILABLE_APPS)) exactly like
//! strategies are by [`strategies::make`] — one `Config` fully
//! describes a run of any workload under any strategy, sequential or
//! distributed.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::apps::advect::{Advect, AdvectConfig};
use crate::apps::driver::{run_app, DriverConfig, RunReport};
use crate::apps::hotspot::{Hotspot, HotspotConfig};
use crate::apps::pic::{Backend, InitMode, PicApp, PicConfig};
use crate::apps::stencil::{Decomposition, StencilSim};
use crate::apps::{App, AVAILABLE_APPS};
use crate::model::{evaluate, Instance, LbMetrics, Topology};
use crate::runtime::Engine;
use crate::simnet::NetModel;
use crate::strategies::{self, LoadBalancer, StrategyParams};
use crate::util::config::Config;

/// Everything a run needs, resolved from configuration.
pub struct Coordinator {
    pub strategy: Box<dyn LoadBalancer>,
    pub params: StrategyParams,
    pub driver: DriverConfig,
    pub obs: ObsPaths,
}

/// Telemetry export targets from a config (section `obs`): setting
/// `obs.trace_path` turns on span collection for the run and writes a
/// Chrome trace-event JSON there; `obs.metrics_path` turns on
/// per-LB-round snapshots and writes them as JSONL. Absent keys leave
/// both collectors off — the zero-overhead default. The always-on
/// counters ([`crate::obs::registry`]) are unaffected either way.
#[derive(Debug, Clone, Default)]
pub struct ObsPaths {
    pub trace: Option<String>,
    pub metrics: Option<String>,
}

/// Resolve the `obs` section of a config.
pub fn obs_from_config(cfg: &Config) -> ObsPaths {
    ObsPaths {
        trace: cfg.get("obs.trace_path").map(str::to_string),
        metrics: cfg.get("obs.metrics_path").map(str::to_string),
    }
}

fn decomp_from(cfg: &Config, key: &str, default: &str) -> Result<Decomposition> {
    match cfg.get(key).unwrap_or(default) {
        "striped" => Ok(Decomposition::Striped),
        "tiled" | "quad" => Ok(Decomposition::Tiled),
        other => bail!("unknown {key} '{other}'"),
    }
}

/// Attach `topo.pe_speeds` (comma list, one factor per PE) to an
/// already-shaped topology, with friendly validation. Apps that derive
/// their topology from other knobs (the stencil's `px x py`) run
/// through this too, so every workload sees the configured speeds.
fn apply_pe_speeds(cfg: &Config, topo: Topology) -> Result<Topology> {
    match cfg.get("topo.pe_speeds") {
        None => Ok(topo),
        Some(_) => {
            let speeds: Vec<f64> =
                cfg.get_list("topo.pe_speeds").context("parsing topo.pe_speeds")?;
            if speeds.len() != topo.n_pes() {
                bail!(
                    "topo.pe_speeds has {} entries for {} PEs ({} nodes x {} pes_per_node)",
                    speeds.len(),
                    topo.n_pes(),
                    topo.n_nodes,
                    topo.pes_per_node
                );
            }
            if speeds.iter().any(|s| !s.is_finite() || *s <= 0.0) {
                bail!("topo.pe_speeds entries must be finite and positive");
            }
            Ok(topo.with_pe_speeds(speeds))
        }
    }
}

fn topo_from_config(cfg: &Config) -> Result<Topology> {
    let topo = Topology::new(cfg.get_or("topo.nodes", 4), cfg.get_or("topo.pes_per_node", 1));
    apply_pe_speeds(cfg, topo)
}

/// Speed-noise schedule from a config (section `topo`): amplitude
/// `topo.speed_noise` (0 = off), redraw period
/// `topo.speed_noise_period`, seed `topo.speed_seed`.
pub fn speed_schedule_from_config(cfg: &Config) -> Result<crate::model::SpeedSchedule> {
    let sched = crate::model::SpeedSchedule {
        noise: cfg.get_or("topo.speed_noise", 0.0),
        period: cfg.get_or("topo.speed_noise_period", 1),
        seed: cfg.get_or("topo.speed_seed", 0x5EED_u64),
    };
    if !sched.noise.is_finite() || sched.noise < 0.0 || sched.noise >= 1.0 {
        bail!("topo.speed_noise must be in [0, 1) (got {})", sched.noise);
    }
    if sched.period == 0 {
        bail!("topo.speed_noise_period must be >= 1");
    }
    Ok(sched)
}

/// Planned elasticity from a config (section `topo`): event spec
/// `topo.resize` (`leave:NODE@ROUND,join:NODE@ROUND`), drain window
/// `topo.resize_drain` (LB rounds of speed-scaled drain preceding a
/// leave).
pub fn resize_from_config(cfg: &Config) -> Result<crate::model::ResizeSchedule> {
    let mut sched = match cfg.get("topo.resize") {
        Some(spec) => crate::model::ResizeSchedule::parse(spec)?,
        None => crate::model::ResizeSchedule::none(),
    };
    sched.drain = cfg.get_or("topo.resize_drain", 1);
    Ok(sched)
}

/// Chaos schedule from a config (section `fault`): an explicit event
/// spec `fault.plan` (`kill:2@1:s2,part:1|3@4`) wins over a
/// seed-derived single fault `fault.seed` (victim, round, stage and
/// kind all pure functions of the seed and the run schedule).
/// `fault.detect_ms` overrides the failure-detection patience.
pub fn fault_plan_from_config(cfg: &Config) -> Result<crate::simnet::FaultPlan> {
    let mut plan = if let Some(spec) = cfg.get("fault.plan") {
        crate::simnet::FaultPlan::parse(spec)?
    } else if let Some(raw) = cfg.get("fault.seed") {
        let seed: u64 = raw.parse().map_err(|e| anyhow::anyhow!("fault.seed: {e}"))?;
        let n_nodes: usize = cfg.get_or("topo.nodes", 4);
        let lb_period: usize = cfg.get_or("run.lb_period", 10);
        let rounds = if lb_period == 0 {
            0
        } else {
            cfg.get_or("run.iters", 100_usize) / lb_period
        };
        crate::simnet::FaultPlan::from_seed(seed, n_nodes, rounds as u32)
    } else {
        return Ok(crate::simnet::FaultPlan::none());
    };
    if let Some(raw) = cfg.get("fault.detect_ms") {
        plan.detect_ms = raw.parse().map_err(|e| anyhow::anyhow!("fault.detect_ms: {e}"))?;
    }
    Ok(plan)
}

/// PIC app configuration from a config (section `pic` + `topo`).
pub fn pic_from_config(cfg: &Config) -> Result<PicConfig> {
    let d = PicConfig::default();
    let init = match cfg.get("pic.init").unwrap_or("geometric") {
        "geometric" => InitMode::Geometric { rho: cfg.get_or("pic.rho", 0.9) },
        "sinusoidal" => InitMode::Sinusoidal,
        "linear" => InitMode::Linear { alpha: cfg.get_or("pic.alpha", 1.0) },
        "patch" => InitMode::Patch {
            x0: cfg.get_or("pic.x0", 0.0),
            x1: cfg.get_or("pic.x1", 10.0),
            y0: cfg.get_or("pic.y0", 0.0),
            y1: cfg.get_or("pic.y1", 10.0),
        },
        other => bail!("unknown pic.init '{other}'"),
    };
    Ok(PicConfig {
        grid: cfg.get_or("pic.grid", d.grid),
        n_particles: cfg.get_or("pic.particles", d.n_particles),
        k: cfg.get_or("pic.k", d.k),
        m: cfg.get_or("pic.m", d.m),
        init,
        chares_x: cfg.get_or("pic.chares_x", d.chares_x),
        chares_y: cfg.get_or("pic.chares_y", d.chares_y),
        decomp: decomp_from(cfg, "pic.decomp", "striped")?,
        topo: topo_from_config(cfg)?,
        q: cfg.get_or("pic.q", d.q),
        seed: cfg.get_or("pic.seed", d.seed),
        particle_bytes: cfg.get_or("pic.particle_bytes", d.particle_bytes),
        threads: cfg.get_or("pic.threads", d.threads),
    })
}

/// Advection app configuration from a config (section `advect` + `topo`).
pub fn advect_from_config(cfg: &Config) -> Result<AdvectConfig> {
    let d = AdvectConfig::default();
    Ok(AdvectConfig {
        domain: cfg.get_or("advect.domain", d.domain),
        blocks_x: cfg.get_or("advect.blocks_x", d.blocks_x),
        blocks_y: cfg.get_or("advect.blocks_y", d.blocks_y),
        n_particles: cfg.get_or("advect.particles", d.n_particles),
        dt: cfg.get_or("advect.dt", d.dt),
        amplitude: cfg.get_or("advect.amplitude", d.amplitude),
        max_substeps: cfg.get_or("advect.max_substeps", d.max_substeps),
        decomp: decomp_from(cfg, "advect.decomp", "striped")?,
        topo: topo_from_config(cfg)?,
        seed: cfg.get_or("advect.seed", d.seed),
        particle_bytes: cfg.get_or("advect.particle_bytes", d.particle_bytes),
    })
}

/// Hotspot app configuration from a config (section `hotspot` + `topo`).
pub fn hotspot_from_config(cfg: &Config) -> Result<HotspotConfig> {
    let d = HotspotConfig::default();
    Ok(HotspotConfig {
        nx: cfg.get_or("hotspot.nx", d.nx),
        ny: cfg.get_or("hotspot.ny", d.ny),
        base: cfg.get_or("hotspot.base", d.base),
        amp: cfg.get_or("hotspot.amp", d.amp),
        sigma: cfg.get_or("hotspot.sigma", d.sigma),
        vx: cfg.get_or("hotspot.vx", d.vx),
        vy: cfg.get_or("hotspot.vy", d.vy),
        halo_bytes: cfg.get_or("hotspot.halo_bytes", d.halo_bytes),
        object_bytes: cfg.get_or("hotspot.object_bytes", d.object_bytes),
        decomp: decomp_from(cfg, "hotspot.decomp", "tiled")?,
        topo: topo_from_config(cfg)?,
    })
}

/// The application registry: resolve `app.kind` (default `pic`) into a
/// boxed [`App`] — the workload twin of [`strategies::make`]. Names in
/// [`AVAILABLE_APPS`].
pub fn app_from_config(cfg: &Config) -> Result<Box<dyn App>> {
    Ok(match cfg.get("app.kind").unwrap_or("pic") {
        "pic" => {
            let pic_cfg = pic_from_config(cfg)?;
            let backend = Coordinator::backend(cfg)?;
            Box::new(PicApp::new(pic_cfg, backend).context("initializing PIC app")?)
        }
        "stencil" => {
            let mut sim = StencilSim::new(
                cfg.get_or("stencil.side", 24),
                cfg.get_or("stencil.px", 2),
                cfg.get_or("stencil.py", 2),
                decomp_from(cfg, "stencil.decomp", "tiled")?,
                cfg.get_or("stencil.noise", 0.4),
                cfg.get_or("stencil.seed", 0x57E_u64),
            );
            // the stencil's flat topology comes from px x py, not
            // [topo]; configured PE speeds still apply to it
            sim.inst.topo = apply_pe_speeds(cfg, sim.inst.topo.clone())?;
            Box::new(sim)
        }
        "advect" => {
            Box::new(Advect::new(advect_from_config(cfg)?).context("initializing advect app")?)
        }
        "hotspot" => Box::new(
            Hotspot::new(hotspot_from_config(cfg)?).context("initializing hotspot app")?,
        ),
        other => bail!("unknown app.kind '{other}' (available: {AVAILABLE_APPS:?})"),
    })
}

/// Network model from a config (section `net`).
pub fn net_from_config(cfg: &Config) -> NetModel {
    let d = NetModel::default();
    NetModel {
        alpha: cfg.get_or("net.alpha", d.alpha),
        beta: cfg.get_or("net.beta", d.beta),
        intra_factor: cfg.get_or("net.intra_factor", d.intra_factor),
    }
}

/// Config-typo detection: every key that was set but never resolved by
/// a getter is reported — as an error under `run.strict_config`, as a
/// warning otherwise. Call after the run has resolved everything it
/// intends to read (`get_or` silently defaults, so a typo'd key is
/// invisible without this). Sections belonging to registered but
/// *inactive* apps are exempt: a shared config may legitimately carry
/// `[pic]` and `[hotspot]` at once, and each section's typos are
/// caught on the run that actually uses it.
pub fn check_config_read(cfg: &Config) -> Result<()> {
    let strict = cfg.get_bool_or("run.strict_config", false);
    let active = cfg.get("app.kind").unwrap_or("pic").to_string();
    let unread: Vec<String> = cfg
        .unread_keys()
        .into_iter()
        .filter(|k| {
            !AVAILABLE_APPS.iter().any(|app| {
                *app != active
                    && k.starts_with(app)
                    && k.as_bytes().get(app.len()) == Some(&b'.')
            })
        })
        .collect();
    if unread.is_empty() {
        return Ok(());
    }
    if strict {
        bail!(
            "config keys set but never read: {} (typo? run.strict_config=false downgrades \
             this to a warning)",
            unread.join(", ")
        );
    }
    crate::warn!("config keys set but never read (typo?): {}", unread.join(", "));
    Ok(())
}

impl Coordinator {
    /// Build from a layered config. `lb.mode = distributed` (or
    /// `run.mode = distributed`, which also switches the app driver)
    /// swaps the diffusion strategy for its message-passing-protocol
    /// execution (`dist-diff-*`, see `crate::distributed`).
    pub fn from_config(cfg: &Config) -> Result<Coordinator> {
        let params = StrategyParams::from_config(cfg);
        for key in ["run.mode", "lb.mode"] {
            if let Some(v) = cfg.get(key) {
                if !matches!(v, "sequential" | "distributed") {
                    bail!("unknown {key} '{v}' (expected 'sequential' or 'distributed')");
                }
            }
        }
        let mut name = cfg.get("lb.strategy").unwrap_or("diff-comm").to_string();
        let distributed = matches!(cfg.get("lb.mode"), Some("distributed"))
            || matches!(cfg.get("run.mode"), Some("distributed"));
        if distributed && cfg.get_bool_or("lb.reuse_neighbors", false) {
            crate::warn!(
                "lb.reuse_neighbors has no effect in distributed mode: the handshake \
                 protocol re-runs every LB round"
            );
        }
        if distributed {
            name = match name.as_str() {
                "diff-comm" => "dist-diff-comm".to_string(),
                "diff-coord" => "dist-diff-coord".to_string(),
                n if n.starts_with("dist-diff-") => n.to_string(),
                other => bail!(
                    "distributed mode supports only the diffusion strategies \
                     (got '{other}'; use diff-comm or diff-coord)"
                ),
            };
        }
        let strategy = strategies::make(&name, params)?;
        let driver = DriverConfig {
            iters: cfg.get_or("run.iters", 100),
            lb_period: cfg.get_or("run.lb_period", 10),
            net: net_from_config(cfg),
            log_every: cfg.get_or("run.log_every", 0),
            deterministic_loads: cfg.get_bool_or("run.deterministic_loads", false),
            speed_schedule: speed_schedule_from_config(cfg)?,
            resize: resize_from_config(cfg)?,
            fault_plan: Arc::new(fault_plan_from_config(cfg)?),
        };
        let obs = obs_from_config(cfg);
        Ok(Coordinator { strategy, params, driver, obs })
    }

    /// Pick the PJRT backend when artifacts exist (or `pic.backend`
    /// forces one); fall back to the native push otherwise.
    pub fn backend(cfg: &Config) -> Result<Backend> {
        match cfg.get("pic.backend").unwrap_or("auto") {
            "native" => Ok(Backend::Native),
            "pjrt" => Ok(Backend::Pjrt(Arc::new(Engine::new()?))),
            "auto" => match Engine::new() {
                Ok(e) => Ok(Backend::Pjrt(Arc::new(e))),
                Err(err) => {
                    crate::warn!("PJRT unavailable ({err:#}); using native backend");
                    Ok(Backend::Native)
                }
            },
            other => bail!("unknown pic.backend '{other}'"),
        }
    }

    /// Run the configured workload (`app.kind`) end to end through the
    /// generic driver. With `run.mode = distributed` the run executes
    /// on the node-partitioned distributed driver
    /// (`crate::distributed::driver`): one simulated node per topology
    /// node, real payload exchange, and the LB pipeline inline as
    /// message-passing protocols — supported for the node-partitionable
    /// apps (`pic`, `hotspot`). Finishes with the config-typo check
    /// ([`check_config_read`]).
    pub fn run(&self, cfg: &Config) -> Result<RunReport> {
        crate::obs::init();
        crate::obs::set_tracing(self.obs.trace.is_some());
        crate::obs::set_metrics(self.obs.metrics.is_some());
        let result = self.run_collected(cfg);
        // the collection flags are process-global: reset them so one
        // configured run cannot leak collection into the next in the
        // same process (tests, sweeps).
        crate::obs::set_tracing(false);
        crate::obs::set_metrics(false);
        result
    }

    fn run_collected(&self, cfg: &Config) -> Result<RunReport> {
        let kind = cfg.get("app.kind").unwrap_or("pic").to_string();
        let report = if matches!(cfg.get("run.mode"), Some("distributed")) {
            let variant = match self.strategy.name() {
                "diff-comm" | "dist-diff-comm" => {
                    crate::strategies::diffusion::Variant::Communication
                }
                "diff-coord" | "dist-diff-coord" => {
                    crate::strategies::diffusion::Variant::Coordinate
                }
                other => {
                    bail!("run.mode = distributed requires a diffusion strategy (got '{other}')")
                }
            };
            match kind.as_str() {
                "pic" => {
                    if matches!(cfg.get("pic.backend"), Some("pjrt")) {
                        bail!(
                            "run.mode = distributed is native-only: each simulated node \
                             pushes its own partition (pic.backend = pjrt is unsupported here)"
                        );
                    }
                    crate::distributed::driver::run_pic_distributed(
                        &pic_from_config(cfg)?,
                        variant,
                        self.params,
                        &self.driver,
                    )?
                }
                "hotspot" => crate::distributed::driver::run_hotspot_distributed(
                    &hotspot_from_config(cfg)?,
                    variant,
                    self.params,
                    &self.driver,
                )?,
                other => bail!(
                    "run.mode = distributed needs a node-partitionable app \
                     (pic, hotspot); got '{other}'"
                ),
            }
        } else {
            let mut app = app_from_config(cfg)?;
            run_app(app.as_mut(), self.strategy.as_ref(), &self.driver)?
        };
        // ---- telemetry export. Distributed runs already gathered the
        // member ranks' buffers at rank 0; flushing the calling thread
        // picks up any sequential-path spans, then the sink is merged
        // on virtual timestamps and written out.
        crate::obs::trace::flush_local();
        if let Some(path) = &self.obs.trace {
            let events = crate::obs::trace::drain_merged();
            crate::obs::trace::write_chrome_trace(path, &events)
                .with_context(|| format!("writing trace to {path}"))?;
            crate::info!("trace: {} events -> {path}", events.len());
        }
        if let Some(path) = &self.obs.metrics {
            let rounds = crate::obs::metrics::take_rounds();
            crate::obs::metrics::write_jsonl(path, &rounds)
                .with_context(|| format!("writing metrics to {path}"))?;
            crate::info!("metrics: {} rounds -> {path}", rounds.len());
        }
        check_config_read(cfg)?;
        Ok(report)
    }

    /// Balance one instance and report paper metrics.
    pub fn balance_instance(&self, inst: &Instance) -> (crate::model::Assignment, LbMetrics) {
        let t = std::time::Instant::now(); // difflb-lint: allow(wall-clock): strategy seconds for LbMetrics, not a decision input
        let asg = self.strategy.rebalance(inst);
        let mut m = evaluate(inst, &asg);
        m.strategy_s = t.elapsed().as_secs_f64();
        (asg, m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::stencil;

    #[test]
    fn config_round_trip() {
        let cfg = Config::from_str(
            "[lb]\nstrategy = diff-coord\nneighbors = 6\n[run]\niters = 5\nlb_period = 2\n\
             [pic]\ngrid = 64\nparticles = 500\nchares_x = 4\nchares_y = 4\nbackend = native\n\
             [topo]\nnodes = 2",
        )
        .unwrap();
        let coord = Coordinator::from_config(&cfg).unwrap();
        assert_eq!(coord.strategy.name(), "diff-coord");
        assert_eq!(coord.params.neighbor_count, 6);
        assert_eq!(coord.driver.iters, 5);
        let pic = pic_from_config(&cfg).unwrap();
        assert_eq!(pic.grid, 64);
        assert_eq!(pic.topo.n_nodes, 2);
    }

    #[test]
    fn pe_speeds_and_noise_resolve_from_config() {
        let cfg = Config::from_str(
            "[topo]\nnodes = 2\npes_per_node = 2\npe_speeds = 1.0, 2.0, 0.5, 1.5\n\
             speed_noise = 0.2\nspeed_noise_period = 3\nspeed_seed = 7",
        )
        .unwrap();
        let pic = pic_from_config(&cfg).unwrap();
        assert_eq!(pic.topo.pe_speeds().unwrap(), &[1.0, 2.0, 0.5, 1.5]);
        let coord = Coordinator::from_config(&cfg).unwrap();
        assert!(coord.driver.speed_schedule.is_active());
        assert_eq!(coord.driver.speed_schedule.period, 3);
        assert_eq!(coord.driver.speed_schedule.seed, 7);
        // all-1.0 canonicalizes to uniform
        let uni = Config::from_str("[topo]\nnodes = 4\npe_speeds = 1, 1, 1, 1").unwrap();
        assert!(pic_from_config(&uni).unwrap().topo.is_uniform());
    }

    #[test]
    fn resize_and_fault_configs_resolve() {
        let cfg = Config::from_str(
            "[topo]\nnodes = 4\nresize = leave:2@3\nresize_drain = 2\n\
             [fault]\nplan = kill:1@1:s2\ndetect_ms = 250",
        )
        .unwrap();
        let coord = Coordinator::from_config(&cfg).unwrap();
        assert!(coord.driver.resize.is_active());
        assert_eq!(coord.driver.resize.drain, 2);
        assert!(coord.driver.fault_plan.is_active());
        assert_eq!(coord.driver.fault_plan.detect_ms, 250);
        // seed-derived plans are pure functions of the seed + schedule
        let c2 = Config::from_str("[topo]\nnodes = 8\n[fault]\nseed = 5").unwrap();
        let p1 = Coordinator::from_config(&c2).unwrap().driver.fault_plan;
        let p2 = Coordinator::from_config(&c2).unwrap().driver.fault_plan;
        assert_eq!(*p1, *p2);
        assert!(p1.is_active());
        // no fault section at all: the inert plan
        let c3 = Config::from_str("[topo]\nnodes = 4").unwrap();
        assert!(!Coordinator::from_config(&c3).unwrap().driver.fault_plan.is_active());
    }

    #[test]
    fn bad_speed_configs_are_rejected() {
        for text in [
            "[topo]\nnodes = 4\npe_speeds = 1.0, 2.0",           // wrong length
            "[topo]\nnodes = 2\npe_speeds = 1.0, -1.0",          // non-positive
            "[topo]\nnodes = 2\npe_speeds = 1.0, bogus",         // unparsable
        ] {
            let cfg = Config::from_str(text).unwrap();
            assert!(pic_from_config(&cfg).is_err(), "{text}");
        }
        for text in [
            "[topo]\nspeed_noise = 1.5", // amplitude >= 1 could zero a speed
            "[topo]\nspeed_noise = -0.1",
            "[topo]\nspeed_noise = 0.2\nspeed_noise_period = 0",
        ] {
            let cfg = Config::from_str(text).unwrap();
            assert!(Coordinator::from_config(&cfg).is_err(), "{text}");
        }
    }

    #[test]
    fn stencil_app_receives_configured_speeds() {
        let cfg = Config::from_str(
            "[app]\nkind = stencil\n[stencil]\nside = 8\npx = 2\npy = 2\n\
             [topo]\npe_speeds = 1.0, 2.0, 1.0, 0.5",
        )
        .unwrap();
        let app = app_from_config(&cfg).unwrap();
        assert_eq!(app.topo().pe_speeds().unwrap(), &[1.0, 2.0, 1.0, 0.5]);
    }

    #[test]
    fn tiny_pic_run_native() {
        let cfg = Config::from_str(
            "[lb]\nstrategy = diff-comm\n[run]\niters = 6\nlb_period = 3\n\
             [pic]\ngrid = 32\nparticles = 400\nchares_x = 4\nchares_y = 4\nbackend = native\nthreads = 2\n\
             [topo]\nnodes = 2",
        )
        .unwrap();
        let coord = Coordinator::from_config(&cfg).unwrap();
        let rep = coord.run(&cfg).unwrap();
        assert_eq!(rep.records.len(), 6);
        assert!(rep.verified);
    }

    #[test]
    fn registry_builds_every_app() {
        for kind in AVAILABLE_APPS {
            let mut cfg = Config::new();
            cfg.set("app.kind", kind);
            // keep construction cheap across all kinds
            cfg.set("pic.grid", 32);
            cfg.set("pic.particles", 200);
            cfg.set("pic.chares_x", 4);
            cfg.set("pic.chares_y", 4);
            cfg.set("pic.backend", "native");
            cfg.set("advect.particles", 500);
            cfg.set("stencil.side", 8);
            let app = app_from_config(&cfg).unwrap();
            assert_eq!(&app.name(), kind);
            assert!(app.n_objects() > 0);
        }
        let mut bad = Config::new();
        bad.set("app.kind", "nope");
        assert!(app_from_config(&bad).is_err());
    }

    #[test]
    fn strict_config_rejects_typos() {
        let cfg = Config::from_str(
            "[lb]\nstrategy = diff-comm\nneighbours = 6\n[run]\nstrict_config = true\n\
             iters = 2\nlb_period = 0\n\
             [pic]\ngrid = 32\nparticles = 100\nchares_x = 4\nchares_y = 4\nbackend = native\nthreads = 1",
        )
        .unwrap();
        let coord = Coordinator::from_config(&cfg).unwrap();
        let err = coord.run(&cfg).unwrap_err().to_string();
        assert!(err.contains("lb.neighbours"), "{err}");
        // the same config without the typo'd key passes
        let ok = Config::from_str(
            "[lb]\nstrategy = diff-comm\nneighbors = 6\n[run]\nstrict_config = true\n\
             iters = 2\nlb_period = 0\n\
             [pic]\ngrid = 32\nparticles = 100\nchares_x = 4\nchares_y = 4\nbackend = native\nthreads = 1",
        )
        .unwrap();
        let coord = Coordinator::from_config(&ok).unwrap();
        assert!(coord.run(&ok).is_ok());
    }

    #[test]
    fn strict_config_tolerates_other_apps_sections() {
        // a shared config may describe several workloads at once; only
        // the active app's (and non-app) sections are typo-checked
        let cfg = Config::from_str(
            "[app]\nkind = hotspot\n[run]\nstrict_config = true\niters = 2\nlb_period = 0\n\
             [hotspot]\nnx = 8\nny = 8\n\
             [pic]\ngrid = 64\nparticles = 500\n[advect]\nparticles = 900",
        )
        .unwrap();
        let coord = Coordinator::from_config(&cfg).unwrap();
        coord.run(&cfg).expect("inactive [pic]/[advect] sections must not trip strict mode");
        // but a typo in the *active* app's section still errors
        let bad = Config::from_str(
            "[app]\nkind = hotspot\n[run]\nstrict_config = true\niters = 2\nlb_period = 0\n\
             [hotspot]\nnx = 8\nny = 8\nsigmaa = 3.0",
        )
        .unwrap();
        let coord = Coordinator::from_config(&bad).unwrap();
        let err = coord.run(&bad).unwrap_err().to_string();
        assert!(err.contains("hotspot.sigmaa"), "{err}");
    }

    #[test]
    fn balance_instance_reports_metrics() {
        let cfg = Config::from_str("[lb]\nstrategy = greedy-refine").unwrap();
        let coord = Coordinator::from_config(&cfg).unwrap();
        let mut inst = stencil::stencil_2d(16, 4, 4, stencil::Decomposition::Tiled);
        stencil::inject_noise(&mut inst, 0.4, 1);
        let (_asg, m) = coord.balance_instance(&inst);
        assert!(m.max_avg_pe < 1.2);
        assert!(m.strategy_s >= 0.0);
    }

    #[test]
    fn bad_strategy_name_errors() {
        let cfg = Config::from_str("[lb]\nstrategy = nope").unwrap();
        assert!(Coordinator::from_config(&cfg).is_err());
    }
}
