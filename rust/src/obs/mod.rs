//! Offline telemetry: counters, scoped spans, per-round metrics.
//!
//! Everything a production service would pull from `tracing` +
//! `metrics` + an OTLP exporter, rebuilt dependency-free (the build
//! environment is offline, same constraint as [`crate::util::logging`]):
//!
//! * [`registry`] — a process-global lock-free registry of
//!   counters/gauges/histograms. `obs::counter!("comm.stale_drops")`
//!   caches the registration per call site, so the steady-state cost of
//!   an increment is one relaxed atomic add — safe to leave in hot
//!   paths unconditionally.
//! * [`trace`] — hierarchical scoped spans ([`span`] returns an RAII
//!   guard) recorded into per-thread buffers and serialized as Chrome
//!   trace-event JSON (`chrome://tracing` / Perfetto). Each simulated
//!   rank is a `tid` lane; nesting is inferred from containment.
//! * [`metrics`] — one [`MetricsSnapshot`] per LB round (imbalance,
//!   migrations, modeled comm seconds, stage-2 iterations, recovery
//!   counters), emitted as JSONL for `tools/trace_report.py`.
//!
//! Both spans and snapshots are **disabled by default** and gated on
//! one relaxed atomic load; the disabled path allocates nothing and
//! calls no clock. Telemetry observes and never steers: with tracing
//! on or off, every strategy decision is bit-identical (locked by
//! `tests/apps_conformance.rs`).
//!
//! Timestamps: one process-wide epoch ([`epoch`]) shared with the
//! logger. On simnet every "rank" is a thread of this process, so
//! microseconds-since-epoch is a cluster-coherent virtual time — rank
//! buffers gathered at rank 0 merge into a single monotone timeline
//! without clock synchronization.

pub mod metrics;
pub mod registry;
pub mod trace;

pub use metrics::MetricsSnapshot;
pub use registry::{Counter, Gauge, Histogram};
pub use trace::{SpanGuard, TraceEvent};

// Macro re-exports so call sites read `obs::counter!("name")` (the
// macros themselves must live at the crate root, see registry.rs).
pub use crate::obs_counter as counter;
pub use crate::obs_gauge as gauge;
pub use crate::obs_histogram as histogram;

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

static EPOCH: OnceLock<Instant> = OnceLock::new();
static TRACING: AtomicBool = AtomicBool::new(false);
static METRICS: AtomicBool = AtomicBool::new(false);

thread_local! {
    /// Simnet rank of the current thread (set by `Cluster`), used to
    /// attribute log lines and trace events in interleaved output.
    static RANK: Cell<Option<u32>> = const { Cell::new(None) };
}

/// The shared process epoch: zero point for log timestamps and trace
/// virtual time. First caller wins; logger init and telemetry init
/// both funnel here so the two clocks agree.
pub fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since [`epoch`] — the virtual timestamp written into
/// trace events. Coherent across simulated ranks (one process, one
/// clock).
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// Initialize telemetry + logging with one shared epoch.
pub fn init() {
    epoch();
    crate::util::logging::init_from_env();
}

/// Globally enable/disable span recording.
pub fn set_tracing(on: bool) {
    TRACING.store(on, Ordering::Relaxed);
}

pub fn tracing_enabled() -> bool {
    TRACING.load(Ordering::Relaxed)
}

/// Globally enable/disable per-round metrics snapshots.
pub fn set_metrics(on: bool) {
    METRICS.store(on, Ordering::Relaxed);
}

pub fn metrics_enabled() -> bool {
    METRICS.load(Ordering::Relaxed)
}

/// Install the simnet rank for the current thread ([`crate::simnet`]'s
/// `Cluster` calls this in every node thread it spawns).
pub fn set_rank(rank: Option<u32>) {
    RANK.with(|r| r.set(rank));
}

/// The current thread's simnet rank, if it is a simulated node.
pub fn rank() -> Option<u32> {
    RANK.with(|r| r.get())
}

/// Open a scoped span: the returned guard records a Chrome "complete"
/// event covering its lifetime. When tracing is disabled this is one
/// relaxed load — no clock read, no allocation.
#[must_use = "a span measures the scope it is bound to; drop it where the scope ends"]
pub fn span(name: &'static str, cat: &'static str) -> SpanGuard {
    if !tracing_enabled() {
        return SpanGuard::disabled();
    }
    SpanGuard::open(name, cat)
}

/// Record an instant event (a point marker, e.g. an epoch declaration)
/// at the current virtual time. No-op when tracing is disabled.
pub fn mark(name: &'static str, cat: &'static str) {
    if !tracing_enabled() {
        return;
    }
    trace::push_event(TraceEvent {
        name: name.into(),
        cat: cat.into(),
        ph: b'i',
        ts_us: now_us(),
        dur_us: 0,
        tid: rank().unwrap_or(0),
    });
}

/// End-of-run communication/recovery totals, gathered by the
/// distributed driver from every surviving rank and surfaced on
/// `RunReport` (exact, per-run — unlike the process-global registry,
/// which aggregates across every run in the process). Sequential runs
/// leave it at the default (all zero).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ObsTotals {
    /// Wrong-epoch messages dropped, summed over surviving ranks.
    pub stale_drops: u64,
    /// Future-epoch messages parked before the local rank caught up.
    pub future_parks: u64,
    /// Barriers that timed out (each one is a recovery trigger).
    pub barrier_timeouts: u64,
    /// Final membership epoch = number of epoch declarations.
    pub epochs: u32,
}

/// Serializes unit tests that toggle the process-global tracing flag
/// (the parallel test runner would otherwise interleave them).
#[cfg(test)]
pub(crate) static TEST_FLAG_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_context_is_thread_local() {
        set_rank(Some(7));
        assert_eq!(rank(), Some(7));
        let other = std::thread::spawn(|| rank()).join().unwrap();
        assert_eq!(other, None, "rank must not leak across threads");
        set_rank(None);
        assert_eq!(rank(), None);
    }

    #[test]
    fn disabled_span_records_nothing() {
        let _guard = TEST_FLAG_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_tracing(false);
        {
            let _s = span("should-not-appear", "test");
            mark("also-not", "test");
        }
        assert!(trace::take_local().is_empty());
    }

    #[test]
    fn virtual_time_is_monotone() {
        let a = now_us();
        let b = now_us();
        assert!(b >= a);
    }
}
