//! Per-LB-round metrics snapshots, emitted as JSONL.
//!
//! One [`MetricsSnapshot`] is recorded per load-balancing round by
//! whichever driver runs it (the sequential `run_app` loop or rank 0
//! of the distributed driver) and written as one JSON object per line
//! — the structured numbers the perf-regression gate diffs against and
//! the input format of `tools/trace_report.py`.

use std::io::Write;
use std::sync::Mutex;

/// What one LB round did, in the paper's vocabulary.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MetricsSnapshot {
    /// LB round index (0-based, in firing order).
    pub round: u32,
    /// App iteration at which the round fired.
    pub iter: u32,
    /// Work imbalance `max/avg` before accounting for speeds.
    pub imbalance: f64,
    /// Speed-aware imbalance `time_max/time_avg` (the paper's metric).
    pub time_max_avg: f64,
    /// Objects migrated by this round.
    pub migrations: u32,
    /// Modeled communication seconds accumulated so far (α–β model).
    pub comm_s: f64,
    /// Measured wall seconds spent inside this LB round.
    pub lb_s: f64,
    /// Stage-2 diffusion iterations until convergence.
    pub stage2_iters: u32,
    /// Wrong-epoch messages dropped so far (driver rank's view; 0 in
    /// sequential runs).
    pub stale_drops: u64,
    /// Membership epochs declared so far (0 in sequential runs).
    pub epochs: u32,
}

static ROUNDS: Mutex<Vec<MetricsSnapshot>> = Mutex::new(Vec::new());

/// Record one round's snapshot. No-op unless metrics are enabled
/// ([`crate::obs::set_metrics`]), so the default path costs one
/// relaxed load.
pub fn record_round(snap: MetricsSnapshot) {
    if !crate::obs::metrics_enabled() {
        return;
    }
    ROUNDS.lock().unwrap_or_else(|e| e.into_inner()).push(snap);
}

/// Drain every recorded snapshot, in recording order.
pub fn take_rounds() -> Vec<MetricsSnapshot> {
    std::mem::take(&mut *ROUNDS.lock().unwrap_or_else(|e| e.into_inner()))
}

/// JSON number: finite floats print via Rust's shortest-roundtrip
/// formatting; non-finite values (never expected) become null rather
/// than corrupting the stream.
fn jnum(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

/// One snapshot as a JSON object (one JSONL line, no trailing newline).
pub fn to_json_line(s: &MetricsSnapshot) -> String {
    format!(
        "{{\"round\":{},\"iter\":{},\"imbalance\":{},\"time_max_avg\":{},\
         \"migrations\":{},\"comm_s\":{},\"lb_s\":{},\"stage2_iters\":{},\
         \"stale_drops\":{},\"epochs\":{}}}",
        s.round,
        s.iter,
        jnum(s.imbalance),
        jnum(s.time_max_avg),
        s.migrations,
        jnum(s.comm_s),
        jnum(s.lb_s),
        s.stage2_iters,
        s.stale_drops,
        s.epochs,
    )
}

/// Write snapshots as JSONL.
pub fn write_jsonl(path: &str, rounds: &[MetricsSnapshot]) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for s in rounds {
        writeln!(f, "{}", to_json_line(s))?;
    }
    f.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_line_has_every_field() {
        let s = MetricsSnapshot {
            round: 2,
            iter: 11,
            imbalance: 1.25,
            time_max_avg: 1.5,
            migrations: 42,
            comm_s: 0.001,
            lb_s: 0.25,
            stage2_iters: 17,
            stale_drops: 3,
            epochs: 1,
        };
        let line = to_json_line(&s);
        for key in [
            "\"round\":2",
            "\"iter\":11",
            "\"imbalance\":1.25",
            "\"time_max_avg\":1.5",
            "\"migrations\":42",
            "\"comm_s\":0.001",
            "\"lb_s\":0.25",
            "\"stage2_iters\":17",
            "\"stale_drops\":3",
            "\"epochs\":1",
        ] {
            assert!(line.contains(key), "missing {key} in {line}");
        }
        assert!(line.starts_with('{') && line.ends_with('}'));
    }

    #[test]
    fn non_finite_becomes_null() {
        let s = MetricsSnapshot { imbalance: f64::NAN, ..Default::default() };
        assert!(to_json_line(&s).contains("\"imbalance\":null"));
    }

    #[test]
    fn disabled_records_nothing() {
        // metrics default to off; other tests never enable them in the
        // unit suite, so the sink must stay empty for our snapshot
        let before = take_rounds();
        crate::obs::set_metrics(false);
        record_round(MetricsSnapshot::default());
        assert!(take_rounds().is_empty());
        // restore anything a concurrent test had buffered
        for s in before {
            ROUNDS.lock().unwrap_or_else(|e| e.into_inner()).push(s);
        }
    }
}
