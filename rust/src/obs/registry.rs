//! Process-global lock-free metric registry.
//!
//! Registration (first use of a name) takes a mutex once and leaks one
//! cell; the `obs::counter!` / `obs::gauge!` / `obs::histogram!`
//! macros cache the returned `&'static` in a per-call-site `OnceLock`,
//! so the steady-state cost of an update is a single relaxed atomic
//! operation — zero allocation, safe on hot paths. Names are flat
//! dotted strings (`"comm.stale_drops"`, `"epoch.declarations"`).
//!
//! The registry aggregates over the whole process lifetime (every run,
//! every rank thread). Per-run exact values travel on
//! [`crate::obs::ObsTotals`] instead; tests that predict exact counts
//! assert there and only monotonicity here.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Monotone event counter.
#[derive(Debug)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl Default for Counter {
    fn default() -> Counter {
        Counter::new()
    }
}

/// Last-write-wins instantaneous value (stored as f64 bits).
#[derive(Debug)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge::new()
    }
}

// `[ATOMIC_ZERO; N]` is the pre-inline-const idiom for initializing
// atomic arrays; the lint objects to interior-mutable consts in
// general, but this one is only ever used as an array seed.
#[allow(clippy::declare_interior_mutable_const)]
const ATOMIC_ZERO: AtomicU64 = AtomicU64::new(0);

/// Power-of-two-bucket histogram for non-negative integer samples
/// (bytes, iteration counts, microseconds). Bucket `i` counts samples
/// whose bit length is `i`, i.e. values in `[2^(i-1), 2^i)`.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; 65],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Histogram {
    pub const fn new() -> Histogram {
        Histogram {
            buckets: [ATOMIC_ZERO; 65],
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, v: u64) {
        let idx = (64 - v.leading_zeros()) as usize;
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Upper bound of the bucket containing the `q`-quantile sample
    /// (0 when the histogram is empty).
    pub fn quantile_upper(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return if i == 0 { 0 } else { (1u64 << i) - 1 };
            }
        }
        u64::MAX
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

enum Metric {
    Counter(&'static Counter),
    Gauge(&'static Gauge),
    Histogram(&'static Histogram),
}

/// A registered metric's current value, for dumps and tests.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MetricValue {
    Counter(u64),
    Gauge(f64),
    Histogram { count: u64, sum: u64 },
}

static TABLE: OnceLock<Mutex<Vec<(&'static str, Metric)>>> = OnceLock::new();

fn table() -> std::sync::MutexGuard<'static, Vec<(&'static str, Metric)>> {
    // Poison-tolerant: a panic mid-registration (e.g. the type-confusion
    // panic below) happens before any mutation, so the table is always
    // consistent and later callers can safely keep using it.
    let m = TABLE.get_or_init(|| Mutex::new(Vec::new()));
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn register<T>(
    name: &'static str,
    find: impl Fn(&Metric) -> Option<&'static T>,
    make: impl FnOnce() -> (&'static T, Metric),
) -> &'static T {
    let mut t = table();
    for (n, m) in t.iter() {
        if *n == name {
            return find(m).unwrap_or_else(|| {
                panic!("obs metric '{name}' already registered with a different type")
            });
        }
    }
    let (handle, metric) = make();
    t.push((name, metric));
    handle
}

/// Register (or look up) the counter called `name`. Prefer the
/// `obs::counter!` macro, which caches this lookup per call site.
pub fn counter(name: &'static str) -> &'static Counter {
    register(
        name,
        |m| match m {
            Metric::Counter(c) => Some(*c),
            _ => None,
        },
        || {
            let c: &'static Counter = Box::leak(Box::new(Counter::new()));
            (c, Metric::Counter(c))
        },
    )
}

/// Register (or look up) the gauge called `name`.
pub fn gauge(name: &'static str) -> &'static Gauge {
    register(
        name,
        |m| match m {
            Metric::Gauge(g) => Some(*g),
            _ => None,
        },
        || {
            let g: &'static Gauge = Box::leak(Box::new(Gauge::new()));
            (g, Metric::Gauge(g))
        },
    )
}

/// Register (or look up) the histogram called `name`.
pub fn histogram(name: &'static str) -> &'static Histogram {
    register(
        name,
        |m| match m {
            Metric::Histogram(h) => Some(*h),
            _ => None,
        },
        || {
            let h: &'static Histogram = Box::leak(Box::new(Histogram::new()));
            (h, Metric::Histogram(h))
        },
    )
}

/// Snapshot every registered metric, in registration order.
pub fn snapshot() -> Vec<(&'static str, MetricValue)> {
    let t = table();
    t.iter()
        .map(|(n, m)| {
            let v = match m {
                Metric::Counter(c) => MetricValue::Counter(c.get()),
                Metric::Gauge(g) => MetricValue::Gauge(g.get()),
                Metric::Histogram(h) => {
                    MetricValue::Histogram { count: h.count(), sum: h.sum() }
                }
            };
            (*n, v)
        })
        .collect()
}

// ---------------------------------------------------------------------
// Per-tag-namespace traffic counters. The simnet/distributed tag scheme
// reserves the top byte as a protocol namespace (0x01 handshake, 0x02
// stage 2, ... 0x7F control), so fixed 256-slot slabs make
// `Comm::send`/`recv` accounting two relaxed adds with no lookup at
// all — cheap enough to stay on unconditionally.

static SENT_MSGS: [AtomicU64; 256] = [ATOMIC_ZERO; 256];
static SENT_BYTES: [AtomicU64; 256] = [ATOMIC_ZERO; 256];
static RECV_MSGS: [AtomicU64; 256] = [ATOMIC_ZERO; 256];
static RECV_BYTES: [AtomicU64; 256] = [ATOMIC_ZERO; 256];

/// Account one `Comm::send` under `tag`'s namespace (top byte).
pub fn record_send(tag: u32, bytes: usize) {
    let ns = (tag >> 24) as usize;
    SENT_MSGS[ns].fetch_add(1, Ordering::Relaxed);
    SENT_BYTES[ns].fetch_add(bytes as u64, Ordering::Relaxed);
}

/// Account one message popped from a `Comm` inbox (counted once per
/// message at arrival, before any parking or stale-dropping).
pub fn record_recv(tag: u32, bytes: usize) {
    let ns = (tag >> 24) as usize;
    RECV_MSGS[ns].fetch_add(1, Ordering::Relaxed);
    RECV_BYTES[ns].fetch_add(bytes as u64, Ordering::Relaxed);
}

/// Traffic totals for one namespace:
/// `(sent_msgs, sent_bytes, recv_msgs, recv_bytes)`.
pub fn comm_namespace(ns: u8) -> (u64, u64, u64, u64) {
    let i = ns as usize;
    (
        SENT_MSGS[i].load(Ordering::Relaxed),
        SENT_BYTES[i].load(Ordering::Relaxed),
        RECV_MSGS[i].load(Ordering::Relaxed),
        RECV_BYTES[i].load(Ordering::Relaxed),
    )
}

/// Every namespace that has seen traffic, with its totals.
pub fn comm_namespaces() -> Vec<(u8, u64, u64, u64, u64)> {
    (0u16..256)
        .filter_map(|ns| {
            let (sm, sb, rm, rb) = comm_namespace(ns as u8);
            ((sm | sb | rm | rb) != 0).then_some((ns as u8, sm, sb, rm, rb))
        })
        .collect()
}

/// Human name of a protocol tag namespace (the distributed pipeline's
/// scheme; unknown bytes print as hex).
pub fn ns_name(ns: u8) -> &'static str {
    match ns {
        0x00 => "app",
        0x01 => "handshake",
        0x02 => "stage2",
        0x03 => "stage3",
        0x10 => "step",
        0x11 => "acct",
        0x12 => "lbc",
        0x13 => "lbx",
        0x14 => "mig",
        0x15 => "ckpt",
        0x16 => "obs",
        0x1F => "fin",
        0x7F => "ctrl",
        _ => "other",
    }
}

/// Register a counter once per call site, then increment in one relaxed
/// atomic add: `obs::counter!("comm.stale_drops").inc()`.
#[macro_export]
macro_rules! obs_counter {
    ($name:literal) => {{
        static CELL: ::std::sync::OnceLock<&'static $crate::obs::Counter> =
            ::std::sync::OnceLock::new();
        *CELL.get_or_init(|| $crate::obs::registry::counter($name))
    }};
}

/// Per-call-site cached gauge: `obs::gauge!("lb.stage2_iters").set(x)`.
#[macro_export]
macro_rules! obs_gauge {
    ($name:literal) => {{
        static CELL: ::std::sync::OnceLock<&'static $crate::obs::Gauge> =
            ::std::sync::OnceLock::new();
        *CELL.get_or_init(|| $crate::obs::registry::gauge($name))
    }};
}

/// Per-call-site cached histogram: `obs::histogram!("mig.bytes").observe(b)`.
#[macro_export]
macro_rules! obs_histogram {
    ($name:literal) => {{
        static CELL: ::std::sync::OnceLock<&'static $crate::obs::Histogram> =
            ::std::sync::OnceLock::new();
        *CELL.get_or_init(|| $crate::obs::registry::histogram($name))
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_macro_returns_one_instance() {
        let a = crate::obs::counter!("test.registry.counter_macro");
        let b = counter("test.registry.counter_macro");
        assert!(std::ptr::eq(a, b));
        let before = a.get();
        a.inc();
        b.add(2);
        assert_eq!(a.get(), before + 3);
    }

    #[test]
    fn gauge_stores_f64() {
        let g = gauge("test.registry.gauge");
        g.set(2.5);
        assert_eq!(g.get(), 2.5);
        g.set(-0.0);
        assert_eq!(g.get(), 0.0);
    }

    #[test]
    #[should_panic(expected = "different type")]
    fn type_confusion_panics() {
        let _ = counter("test.registry.confused");
        let _ = gauge("test.registry.confused");
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = Histogram::new();
        for v in [0u64, 1, 1, 3, 1000] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 1005);
        assert_eq!(h.quantile_upper(0.0), 0); // first sample is the 0
        assert!(h.quantile_upper(0.5) >= 1);
        assert!(h.quantile_upper(1.0) >= 1000);
    }

    #[test]
    fn snapshot_lists_registered_metrics() {
        counter("test.registry.snap").add(5);
        let snap = snapshot();
        let found = snap.iter().find(|(n, _)| *n == "test.registry.snap");
        match found {
            Some((_, MetricValue::Counter(v))) => assert!(*v >= 5),
            other => panic!("unexpected snapshot entry: {other:?}"),
        }
    }

    #[test]
    fn namespace_slabs_accumulate() {
        // namespace 0xEE is unused by any protocol — safe to assert
        // deltas even with parallel tests running.
        let (sm0, sb0, rm0, rb0) = comm_namespace(0xEE);
        record_send(0xEE00_0001, 10);
        record_send(0xEE00_0002, 5);
        record_recv(0xEE00_0001, 10);
        let (sm, sb, rm, rb) = comm_namespace(0xEE);
        assert_eq!((sm - sm0, sb - sb0, rm - rm0, rb - rb0), (2, 15, 1, 10));
        assert!(comm_namespaces().iter().any(|&(ns, ..)| ns == 0xEE));
        assert_eq!(ns_name(0x7F), "ctrl");
    }
}
