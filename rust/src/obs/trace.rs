//! Span recording and Chrome trace-event export.
//!
//! Every thread owns a local event buffer (no contention on the hot
//! path); buffers are merged into a process-global sink either at
//! thread exit (`Cluster` flushes automatically) or explicitly by the
//! distributed driver, whose rank 0 gathers the other ranks' buffers
//! over the wire ([`encode_events`]/[`decode_events`]) and absorbs
//! them. [`drain_merged`] then yields one timeline sorted by virtual
//! timestamp — valid because all simulated ranks share the process
//! clock ([`crate::obs::epoch`]).
//!
//! The output format is the Chrome trace-event JSON array understood
//! by `chrome://tracing` and Perfetto: `ph:"X"` complete events with
//! `ts`/`dur` in microseconds, one `tid` lane per rank.

use std::borrow::Cow;
use std::cell::RefCell;
use std::io::Write;
use std::sync::Mutex;

/// One trace event. `name`/`cat` are borrowed statics when recorded
/// in-process and owned strings when decoded from a gathered rank
/// buffer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    pub name: Cow<'static, str>,
    pub cat: Cow<'static, str>,
    /// Chrome phase: `b'X'` complete (duration) or `b'i'` instant.
    pub ph: u8,
    /// Virtual timestamp, µs since [`crate::obs::epoch`].
    pub ts_us: u64,
    pub dur_us: u64,
    /// Timeline lane: the simnet rank (0 for the sequential driver).
    pub tid: u32,
}

thread_local! {
    static BUF: RefCell<Vec<TraceEvent>> = const { RefCell::new(Vec::new()) };
}

static SINK: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());

/// RAII span: records a complete event covering its lifetime. Created
/// via [`crate::obs::span`]; a disabled guard is inert.
#[must_use = "a span measures the scope it is bound to; drop it where the scope ends"]
pub struct SpanGuard {
    live: Option<(&'static str, &'static str, u64)>,
}

impl SpanGuard {
    pub(crate) fn disabled() -> SpanGuard {
        SpanGuard { live: None }
    }

    pub(crate) fn open(name: &'static str, cat: &'static str) -> SpanGuard {
        SpanGuard { live: Some((name, cat, crate::obs::now_us())) }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some((name, cat, start)) = self.live.take() {
            let end = crate::obs::now_us();
            push_event(TraceEvent {
                name: name.into(),
                cat: cat.into(),
                ph: b'X',
                ts_us: start,
                dur_us: end.saturating_sub(start),
                tid: crate::obs::rank().unwrap_or(0),
            });
        }
    }
}

/// Append an event to the current thread's buffer.
pub fn push_event(ev: TraceEvent) {
    BUF.with(|b| b.borrow_mut().push(ev));
}

/// Move the current thread's buffer out (a rank shipping its events to
/// rank 0 drains here, so the thread-exit flush finds nothing to
/// double-count).
pub fn take_local() -> Vec<TraceEvent> {
    BUF.with(|b| std::mem::take(&mut *b.borrow_mut()))
}

/// Merge a batch of events (local or decoded from a gathered rank
/// buffer) into the process sink.
pub fn absorb(events: Vec<TraceEvent>) {
    if events.is_empty() {
        return;
    }
    SINK.lock().unwrap_or_else(|e| e.into_inner()).extend(events);
}

/// Flush the current thread's buffer into the sink. `Cluster` calls
/// this when a node thread finishes so no rank's events are lost.
pub fn flush_local() {
    absorb(take_local());
}

/// Merge per-rank buffers into one timeline ordered by virtual time
/// (ties broken by rank, then span length — outer spans first so
/// Chrome nesting renders correctly).
pub fn merge(buffers: Vec<Vec<TraceEvent>>) -> Vec<TraceEvent> {
    let mut all: Vec<TraceEvent> = buffers.into_iter().flatten().collect();
    all.sort_by(|a, b| {
        (a.ts_us, a.tid, std::cmp::Reverse(a.dur_us))
            .cmp(&(b.ts_us, b.tid, std::cmp::Reverse(b.dur_us)))
    });
    all
}

/// Drain the sink as one merged, time-ordered timeline.
pub fn drain_merged() -> Vec<TraceEvent> {
    let drained = std::mem::take(&mut *SINK.lock().unwrap_or_else(|e| e.into_inner()));
    merge(vec![drained])
}

// ---------------------------------------------------------------------
// Wire codec (little-endian, self-contained so `simnet` stays free of
// `distributed` dependencies): per event
//   u16 name_len, name bytes, u16 cat_len, cat bytes,
//   u8 ph, u64 ts_us, u64 dur_us, u32 tid
// prefixed by a u32 event count.

/// Serialize a rank's event buffer for the gather to rank 0.
pub fn encode_events(events: &[TraceEvent]) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + events.len() * 48);
    out.extend_from_slice(&(events.len() as u32).to_le_bytes());
    for e in events {
        let name = e.name.as_bytes();
        let cat = e.cat.as_bytes();
        out.extend_from_slice(&(name.len().min(u16::MAX as usize) as u16).to_le_bytes());
        out.extend_from_slice(&name[..name.len().min(u16::MAX as usize)]);
        out.extend_from_slice(&(cat.len().min(u16::MAX as usize) as u16).to_le_bytes());
        out.extend_from_slice(&cat[..cat.len().min(u16::MAX as usize)]);
        out.push(e.ph);
        out.extend_from_slice(&e.ts_us.to_le_bytes());
        out.extend_from_slice(&e.dur_us.to_le_bytes());
        out.extend_from_slice(&e.tid.to_le_bytes());
    }
    out
}

/// Decode a gathered rank buffer; `Err` on truncation or bad UTF-8.
pub fn decode_events(buf: &[u8]) -> Result<Vec<TraceEvent>, &'static str> {
    struct R<'a>(&'a [u8]);
    impl<'a> R<'a> {
        fn bytes(&mut self, n: usize) -> Result<&'a [u8], &'static str> {
            if self.0.len() < n {
                return Err("truncated trace buffer");
            }
            let (head, tail) = self.0.split_at(n);
            self.0 = tail;
            Ok(head)
        }
        fn u16(&mut self) -> Result<u16, &'static str> {
            Ok(u16::from_le_bytes(self.bytes(2)?.try_into().unwrap()))
        }
        fn u32(&mut self) -> Result<u32, &'static str> {
            Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
        }
        fn u64(&mut self) -> Result<u64, &'static str> {
            Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
        }
        fn str(&mut self) -> Result<String, &'static str> {
            let len = self.u16()? as usize;
            std::str::from_utf8(self.bytes(len)?)
                .map(str::to_owned)
                .map_err(|_| "bad UTF-8 in trace buffer")
        }
    }
    let mut r = R(buf);
    let count = r.u32()? as usize;
    let mut out = Vec::with_capacity(count.min(1 << 20));
    for _ in 0..count {
        let name = r.str()?;
        let cat = r.str()?;
        let ph = r.bytes(1)?[0];
        let ts_us = r.u64()?;
        let dur_us = r.u64()?;
        let tid = r.u32()?;
        out.push(TraceEvent {
            name: name.into(),
            cat: cat.into(),
            ph,
            ts_us,
            dur_us,
            tid,
        });
    }
    Ok(out)
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Write events as Chrome trace-event JSON (`chrome://tracing`,
/// Perfetto). Instant events get thread scope so they render as
/// markers in the owning lane.
pub fn write_chrome_trace(path: &str, events: &[TraceEvent]) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{{\"displayTimeUnit\":\"ms\",\"traceEvents\":[")?;
    for (i, e) in events.iter().enumerate() {
        let comma = if i + 1 == events.len() { "" } else { "," };
        let ph = if e.ph == b'i' { "i" } else { "X" };
        let scope = if e.ph == b'i' { ",\"s\":\"t\"" } else { "" };
        let dur = if e.ph == b'i' {
            String::new()
        } else {
            format!(",\"dur\":{}", e.dur_us)
        };
        writeln!(
            f,
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"{ph}\",\"ts\":{}{dur},\
             \"pid\":0,\"tid\":{}{scope}}}{comma}",
            json_escape(&e.name),
            json_escape(&e.cat),
            e.ts_us,
            e.tid,
        )?;
    }
    writeln!(f, "]}}")?;
    f.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: &'static str, ts: u64, dur: u64, tid: u32) -> TraceEvent {
        TraceEvent {
            name: name.into(),
            cat: "test".into(),
            ph: b'X',
            ts_us: ts,
            dur_us: dur,
            tid,
        }
    }

    #[test]
    fn span_guard_records_on_drop() {
        let _guard = crate::obs::TEST_FLAG_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        crate::obs::set_tracing(true);
        {
            let _outer = crate::obs::span("outer", "test");
            let _inner = crate::obs::span("inner", "test");
        }
        crate::obs::set_tracing(false);
        let events = take_local();
        // inner drops first, so it is recorded first
        let names: Vec<&str> = events.iter().map(|e| e.name.as_ref()).collect();
        assert_eq!(names, ["inner", "outer"]);
        // hierarchical: the outer span contains the inner one
        let inner = &events[0];
        let outer = &events[1];
        assert!(outer.ts_us <= inner.ts_us);
        assert!(outer.ts_us + outer.dur_us >= inner.ts_us + inner.dur_us);
    }

    #[test]
    fn merged_multi_rank_trace_is_monotone_in_virtual_time() {
        // Three "ranks" with interleaved, unsorted buffers — as the
        // driver's rank-0 gather produces them.
        let r0 = vec![ev("a", 40, 5, 0), ev("b", 10, 3, 0)];
        let r1 = vec![ev("c", 25, 10, 1), ev("d", 25, 2, 1)];
        let r2 = vec![ev("e", 5, 100, 2)];
        let merged = merge(vec![r0, r1, r2]);
        assert_eq!(merged.len(), 5);
        assert!(
            merged.windows(2).all(|w| w[0].ts_us <= w[1].ts_us),
            "merged trace must be monotone in virtual time: {merged:?}"
        );
        // equal timestamps on one lane: outer (longer) span first
        assert_eq!(merged[1].name, "c");
        assert_eq!(merged[2].name, "d");
    }

    #[test]
    fn wire_codec_roundtrips() {
        let events = vec![ev("stage2.virtual", 123, 456, 3), {
            let mut m = ev("epoch.declare", 999, 0, 1);
            m.ph = b'i';
            m
        }];
        let decoded = decode_events(&encode_events(&events)).expect("decode");
        assert_eq!(decoded, events);
        assert!(decode_events(&[1, 0, 0]).is_err(), "truncated must not decode");
    }

    #[test]
    fn chrome_json_is_wellformed() {
        let dir = std::env::temp_dir().join("difflb_obs_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        let mut m = ev("mark\"quote", 7, 0, 1);
        m.ph = b'i';
        let events = vec![ev("a", 1, 2, 0), m];
        write_chrome_trace(path.to_str().unwrap(), &events).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"traceEvents\""));
        assert!(text.contains("\\\"quote"));
        assert!(text.contains("\"ph\":\"i\""));
        // balanced braces/brackets is a cheap well-formedness proxy
        // (tools/trace_report.py --check does the full parse in CI)
        let opens = text.matches('{').count();
        let closes = text.matches('}').count();
        assert_eq!(opens, closes);
    }
}
