//! Generic iterative driver: runs any [`App`] for N iterations with a
//! load-balancing schedule, accounting compute time (measured, split
//! over nodes by work units), communication time (α–β model over the
//! step's crossing records + sync messages), and LB cost (measured
//! strategy time + modeled migration transfer) — the machinery behind
//! Figs 3–6, shared by every workload and strategy.

use std::sync::Arc;

use anyhow::Result;

use crate::apps::app::{App, StepCtx};
use crate::model::{
    evaluate, rehome_mapping, restrict_instance, Assignment, ResizeSchedule, SpeedSchedule,
    Topology,
};
use crate::simnet::{CostTracker, FaultPlan, NetModel};
use crate::strategies::LoadBalancer;
use crate::util::stats::Summary;

/// Node-granularity communication accounting for one app step: every
/// adjacent object pair exchanges one sync message per step (α even
/// when empty), carrying that step's crossing payload; non-adjacent
/// crossings (possible when a PIC displacement exceeds a chare) pay
/// their own message. `moved` holds the step's directed
/// `(from, to, bytes)` crossing records; they are canonicalized to
/// unordered pairs and sort-merged into the reused `payload` buffer.
/// Shared by the sequential and distributed drivers so both model
/// communication seconds with the same arithmetic over the same
/// aggregates (`tests/distributed.rs` asserts the outputs are equal).
pub fn account_step_comm(
    topo: &Topology,
    obj_to_pe: &[u32],
    neighbor_pairs: &[(u32, u32)],
    moved: &[(u32, u32, f64)],
    payload: &mut Vec<(u32, u32, f64)>,
    consumed: &mut Vec<bool>,
    tracker: &mut CostTracker,
) {
    payload.clear();
    payload.extend(moved.iter().map(|&(f, t, bytes)| (f.min(t), f.max(t), bytes)));
    crate::model::graph::sort_sum_merge(payload);
    consumed.clear();
    consumed.resize(payload.len(), false);
    tracker.reset();
    for &(a, b) in neighbor_pairs {
        let n_a = topo.node_of_pe(obj_to_pe[a as usize]);
        let n_b = topo.node_of_pe(obj_to_pe[b as usize]);
        let bytes = match payload.binary_search_by_key(&(a, b), |&(x, y, _)| (x, y)) {
            Ok(idx) => {
                consumed[idx] = true;
                payload[idx].2
            }
            Err(_) => 0.0,
        };
        tracker.record(n_a, n_b, bytes);
    }
    for (idx, &(a, b, bytes)) in payload.iter().enumerate() {
        if consumed[idx] {
            continue;
        }
        let n_a = topo.node_of_pe(obj_to_pe[a as usize]);
        let n_b = topo.node_of_pe(obj_to_pe[b as usize]);
        tracker.record(n_a, n_b, bytes);
    }
}

/// Driver schedule + accounting configuration.
#[derive(Clone)]
pub struct DriverConfig {
    pub iters: usize,
    /// Run the balancer every `lb_period` iterations (0 = never).
    pub lb_period: usize,
    pub net: NetModel,
    /// Print progress every `log_every` iterations (0 = quiet).
    pub log_every: usize,
    /// Use the app's work units instead of measured step seconds as the
    /// LB load signal. Measured time is the production signal but is
    /// wall-clock-noisy; work units make a run's LB decisions exactly
    /// reproducible — which is what lets `tests/distributed.rs` assert
    /// the distributed driver reports the *same* migration counts and
    /// modeled comm seconds as this sequential driver.
    pub deterministic_loads: bool,
    /// Time-varying PE speed noise (OS interference model). When
    /// active, the effective topology at iteration `i` perturbs the
    /// app's base PE speeds deterministically; the per-iteration
    /// time-imbalance metric and every LB instance see the perturbed
    /// speeds. The distributed driver evaluates the identical pure
    /// function at its root, so seq-vs-dist equivalence survives noise.
    pub speed_schedule: SpeedSchedule,
    /// Planned elasticity: node join/leave events keyed to LB rounds.
    /// Both drivers rebalance onto the surviving membership via
    /// [`restrict_instance`]; an inert schedule changes nothing.
    pub resize: ResizeSchedule,
    /// Chaos schedule for the *distributed* driver (node deaths, hangs,
    /// partitions — `run_app_distributed`). The sequential driver has
    /// no failure surface and ignores it; an inert plan keeps the
    /// distributed protocol paths bit-identical to a fault-unaware
    /// build.
    pub fault_plan: Arc<FaultPlan>,
}

impl Default for DriverConfig {
    fn default() -> Self {
        DriverConfig {
            iters: 100,
            lb_period: 10,
            net: NetModel::default(),
            log_every: 0,
            deterministic_loads: false,
            speed_schedule: SpeedSchedule::none(),
            resize: ResizeSchedule::none(),
            fault_plan: Arc::new(FaultPlan::none()),
        }
    }
}

/// Per-iteration record (one row of the Fig 3/4/6 series).
#[derive(Debug, Clone, Default)]
pub struct IterRecord {
    pub iter: usize,
    /// max/avg work units per PE (Fig 3/4 metric; particles for PIC).
    pub work_max_avg: f64,
    /// max/avg normalized time (`work / effective PE speed`) per PE —
    /// what heterogeneous runs actually balance. Equal to
    /// `work_max_avg` on uniform topologies without speed noise.
    pub time_max_avg: f64,
    /// work units on each node (Fig 3 series).
    pub node_work: Vec<f64>,
    /// modeled per-iteration compute time (max / avg over nodes).
    pub compute_max_s: f64,
    pub compute_avg_s: f64,
    /// modeled per-iteration communication time (max / avg over nodes).
    pub comm_max_s: f64,
    pub comm_avg_s: f64,
    /// strategy wall-clock + modeled migration transfer, when LB ran.
    pub lb_s: f64,
    pub migrations: usize,
}

/// Aggregates over a full run (the Fig 5 bars).
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    pub records: Vec<IterRecord>,
    pub total_s: f64,
    pub compute_s: f64,
    pub comm_s: f64,
    pub lb_s: f64,
    pub total_migrations: usize,
    pub verified: bool,
    /// Object→PE mapping at the end of the run. The chaos tests use
    /// this to assert no object is left on a dead or departed node.
    pub final_mapping: Vec<u32>,
    /// Per-run resilience totals (stale drops, parked future-epoch
    /// messages, barrier timeouts, epochs declared). Always zero for
    /// the sequential driver — it has no failure surface — and summed
    /// over surviving members by the distributed driver's end-of-run
    /// telemetry gather. Unlike the process-global `obs` registry,
    /// these are scoped to one run, so tests can assert exact values
    /// even under the parallel test runner.
    pub obs: crate::obs::ObsTotals,
}

impl RunReport {
    pub fn summary_line(&self, label: &str) -> String {
        format!(
            "{label:<14} total={:.3}s compute={:.3}s comm={:.3}s lb={:.3}s migr={} verified={}",
            self.total_s, self.compute_s, self.comm_s, self.lb_s, self.total_migrations,
            self.verified
        )
    }
}

/// Run any [`App`] under `strategy` and record the full time series —
/// the one iterate / record / rebalance / migrate / account loop every
/// workload shares. Accepts both concrete apps and `dyn App` (the
/// coordinator's registry hands out boxed apps).
pub fn run_app<A: App + ?Sized>(
    app: &mut A,
    strategy: &dyn LoadBalancer,
    cfg: &DriverConfig,
) -> Result<RunReport> {
    let topo = app.topo();
    let neighbor_pairs = app.neighbor_pairs();
    cfg.resize.validate(topo.n_nodes)?;
    // `cfg.fault_plan` is a distributed-runtime concern: the sequential
    // driver has no failure surface, so the plan is ignored here and
    // only `run_app_distributed` injects it.
    if cfg.resize.is_active() {
        // Nodes scheduled to join later must start empty: evict their
        // objects onto the initial membership before the first step.
        let alive0 = cfg.resize.initial_alive(topo.n_nodes);
        if alive0.iter().any(|&a| !a) {
            app.apply(&Assignment {
                mapping: rehome_mapping(app.mapping(), &topo, &alive0),
            });
        }
    }
    let mut lb_round: usize = 0;
    let mut report = RunReport::default();
    // Per-iteration accounting buffers, hoisted out of the loop (the
    // pre-trait driver already did this; the trait keeps it possible:
    // apps append crossings into the reused `ctx.moved`).
    let mut tracker = CostTracker::new(topo.n_nodes);
    let mut payload: Vec<(u32, u32, f64)> = Vec::new();
    let mut consumed: Vec<bool> = Vec::new();
    let mut ctx = StepCtx::default();
    let mut work: Vec<f64> = Vec::new();
    let mut pe_work = vec![0.0f64; topo.n_pes()];
    let mut node_work = vec![0.0f64; topo.n_nodes];
    let mut pe_time_buf: Vec<f64> = Vec::new();
    for iter in 0..cfg.iters {
        // Effective topology this iteration: the app's base speeds,
        // perturbed by the noise schedule when one is active.
        let eff_topo = cfg.speed_schedule.topo_at(&topo, iter);
        ctx.moved.clear();
        let stats = {
            let _s = crate::obs::span("app.step", "driver");
            app.step(&mut ctx)?
        };
        // Aggregate the raw crossing log per directed (from, to) pair —
        // the same stable sort-merge the apps' traffic recorders use,
        // so sums accumulate in crossing order.
        crate::model::graph::sort_sum_merge(&mut ctx.moved);

        // --- compute accounting: measured step time attributed to the
        // busiest node by work units (nodes run concurrently in the
        // real system).
        app.work(&mut work);
        debug_assert_eq!(work.len(), app.n_objects(), "{}: work vector length", app.name());
        let work_total: f64 = work.iter().sum();
        let per_unit = stats.compute_s / work_total.max(1.0);
        pe_work.iter_mut().for_each(|w| *w = 0.0);
        node_work.iter_mut().for_each(|w| *w = 0.0);
        {
            // --- comm accounting at node granularity (shared with the
            // distributed driver, which gathers the same crossing
            // records per node and runs the identical arithmetic at its
            // root).
            let mapping = app.mapping();
            for (o, &pe) in mapping.iter().enumerate() {
                pe_work[pe as usize] += work[o];
                node_work[topo.node_of_pe(pe) as usize] += work[o];
            }
            account_step_comm(
                &topo,
                mapping,
                &neighbor_pairs,
                &ctx.moved,
                &mut payload,
                &mut consumed,
                &mut tracker,
            );
        }
        let comm_times = tracker.comm_times(&cfg.net);

        let pe_summary = Summary::of(&pe_work);
        let mut rec = IterRecord {
            iter,
            work_max_avg: pe_summary.max_avg_ratio(),
            time_max_avg: time_imbalance(&pe_work, &eff_topo, &mut pe_time_buf),
            node_work: node_work.clone(),
            compute_max_s: node_work.iter().map(|&w| w * per_unit).fold(0.0, f64::max),
            compute_avg_s: node_work.iter().map(|&w| w * per_unit).sum::<f64>()
                / topo.n_nodes as f64,
            comm_max_s: comm_times.iter().cloned().fold(0.0, f64::max),
            comm_avg_s: comm_times.iter().sum::<f64>() / topo.n_nodes as f64,
            ..Default::default()
        };

        // --- load balancing step.
        if cfg.lb_period > 0 && (iter + 1) % cfg.lb_period == 0 {
            let _lb_span = crate::obs::span("lb.round", "driver");
            let mut inst = app.build_instance();
            if cfg.deterministic_loads {
                inst.loads = work.clone();
            }
            let lb_topo = if cfg.resize.is_active() {
                // leavers inside their drain window keep nominally-zero
                // speed so the balancer bleeds work off them gradually
                cfg.resize.drained_topo(&eff_topo, lb_round)
            } else {
                eff_topo.clone()
            };
            if cfg.speed_schedule.is_active() || cfg.resize.is_active() {
                // the balancer must see this iteration's perturbed
                // speeds, not the app's static base topology
                inst.topo = lb_topo;
            }
            let t = std::time::Instant::now(); // difflb-lint: allow(wall-clock): measured lb seconds feed the report, not the mapping
            let asg = if cfg.resize.is_active() {
                let alive = cfg.resize.alive_after(lb_round, topo.n_nodes);
                if alive.iter().all(|&a| a) {
                    strategy.rebalance(&inst)
                } else {
                    // Rebalance on the surviving membership only, then
                    // translate the dense sub-mapping back to world PEs
                    // — departed nodes can never be assigned work.
                    let r = restrict_instance(&inst, &alive);
                    Assignment {
                        mapping: r.expand_mapping(&strategy.rebalance(&r.inst).mapping),
                    }
                }
            } else {
                strategy.rebalance(&inst)
            };
            let strat_s = t.elapsed().as_secs_f64();
            let metrics = evaluate(&inst, &asg);
            let moved_bytes = app.apply(&asg);
            // migration transfer cost: modeled as one bulk inter-node
            // transfer of the moved bytes, split over nodes
            let transfer_s = cfg.net.inter_time(metrics.migrations as u64, moved_bytes)
                / topo.n_nodes.max(1) as f64;
            rec.lb_s = strat_s + transfer_s;
            rec.migrations = metrics.migrations;
            report.total_migrations += metrics.migrations;
            if crate::obs::metrics_enabled() {
                // One JSONL row per LB round. `stage2_iters` is set by
                // the strategy as it converges (zero for strategies
                // without a diffusion stage 2); the sequential driver
                // has no comm endpoint, so the resilience fields stay 0.
                crate::obs::metrics::record_round(crate::obs::MetricsSnapshot {
                    round: lb_round as u32,
                    iter: iter as u32,
                    imbalance: rec.work_max_avg,
                    time_max_avg: rec.time_max_avg,
                    migrations: metrics.migrations as u32,
                    comm_s: rec.comm_max_s,
                    lb_s: rec.lb_s,
                    stage2_iters: crate::obs::registry::gauge("lb.stage2_iters").get() as u32,
                    stale_drops: 0,
                    epochs: 0,
                });
            }
            lb_round += 1;
        }

        if cfg.log_every > 0 && iter % cfg.log_every == 0 {
            crate::info!(
                "iter {iter}: max/avg={:.3} comp={:.2}ms comm={:.2}ms lb={:.2}ms",
                rec.work_max_avg,
                rec.compute_max_s * 1e3,
                rec.comm_max_s * 1e3,
                rec.lb_s * 1e3
            );
        }
        report.compute_s += rec.compute_max_s;
        report.comm_s += rec.comm_max_s;
        report.lb_s += rec.lb_s;
        report.total_s += rec.compute_max_s + rec.comm_max_s + rec.lb_s;
        report.records.push(rec);
    }
    report.final_mapping = app.mapping().to_vec();
    report.verified = app.verify().is_ok();
    Ok(report)
}

/// Convenience: run the same workload configuration under several
/// strategies (fresh app per strategy) and return (name, report) pairs.
pub fn compare_strategies(
    mk_app: impl Fn() -> Result<Box<dyn App>>,
    strategies: &[(&str, Box<dyn LoadBalancer>)],
    cfg: &DriverConfig,
) -> Result<Vec<(String, RunReport)>> {
    let mut out = Vec::new();
    for (name, strat) in strategies {
        let mut app = mk_app()?;
        let report = run_app(app.as_mut(), strat.as_ref(), cfg)?;
        out.push((name.to_string(), report));
    }
    Ok(out)
}

/// max/avg of per-PE normalized time (`work / effective speed`) —
/// shared by the sequential and distributed drivers so the reported
/// time-imbalance is bit-identical between them. On uniform effective
/// topologies this is exactly the raw work ratio.
pub fn time_imbalance(pe_work: &[f64], eff_topo: &Topology, buf: &mut Vec<f64>) -> f64 {
    if eff_topo.is_uniform() {
        Summary::of(pe_work).max_avg_ratio()
    } else {
        buf.clear();
        buf.extend(
            pe_work
                .iter()
                .enumerate()
                .map(|(pe, w)| w / eff_topo.pe_speed(pe as u32)),
        );
        Summary::of(buf).max_avg_ratio()
    }
}

/// Assignment helper re-exported for bench code symmetry.
pub fn no_lb_assignment<A: App + ?Sized>(app: &A) -> Assignment {
    Assignment { mapping: app.mapping().to_vec() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::pic::{Backend, InitMode, PicApp, PicConfig};
    use crate::apps::stencil::{Decomposition, StencilSim};
    use crate::model::Topology;
    use crate::strategies::{make, StrategyParams};

    fn app() -> PicApp {
        PicApp::new(
            PicConfig {
                grid: 64,
                n_particles: 3_000,
                k: 1,
                m: 1,
                init: InitMode::Geometric { rho: 0.9 },
                chares_x: 8,
                chares_y: 8,
                decomp: Decomposition::Striped,
                topo: Topology::flat(4),
                q: 1.0,
                seed: 5,
                particle_bytes: 48.0,
                threads: 2,
            },
            Backend::Native,
        )
        .unwrap()
    }

    #[test]
    fn run_produces_full_series_and_verifies() {
        let mut a = app();
        let strat = make("diff-comm", StrategyParams::default()).unwrap();
        let cfg = DriverConfig { iters: 20, lb_period: 5, ..Default::default() };
        let rep = run_app(&mut a, strat.as_ref(), &cfg).unwrap();
        assert_eq!(rep.records.len(), 20);
        assert!(rep.verified, "physics corrupted by LB");
        assert!(rep.total_s > 0.0);
        // LB ran at iters 4, 9, 14, 19
        assert!(rep.records[4].lb_s >= 0.0);
        assert_eq!(rep.records[3].migrations, 0);
    }

    #[test]
    fn uniform_runs_report_time_equal_to_work_imbalance() {
        let mut a = app();
        let strat = make("diff-comm", StrategyParams::default()).unwrap();
        let cfg = DriverConfig { iters: 8, lb_period: 4, ..Default::default() };
        let rep = run_app(&mut a, strat.as_ref(), &cfg).unwrap();
        for r in &rep.records {
            assert_eq!(r.time_max_avg, r.work_max_avg, "iter {}", r.iter);
        }
    }

    #[test]
    fn noisy_speed_schedule_runs_end_to_end() {
        use crate::model::SpeedSchedule;
        let mut a = app();
        let strat = make("diff-comm", StrategyParams::default()).unwrap();
        let cfg = DriverConfig {
            iters: 10,
            lb_period: 5,
            deterministic_loads: true,
            speed_schedule: SpeedSchedule { noise: 0.4, period: 2, seed: 9 },
            ..Default::default()
        };
        let rep = run_app(&mut a, strat.as_ref(), &cfg).unwrap();
        assert_eq!(rep.records.len(), 10);
        assert!(rep.verified, "speed noise must not affect physics");
        assert!(rep.records.iter().all(|r| r.time_max_avg.is_finite()));
        // deterministic: the same schedule reproduces the same series
        let mut b = app();
        let strat2 = make("diff-comm", StrategyParams::default()).unwrap();
        let rep2 = run_app(&mut b, strat2.as_ref(), &cfg).unwrap();
        let t1: Vec<f64> = rep.records.iter().map(|r| r.time_max_avg).collect();
        let t2: Vec<f64> = rep2.records.iter().map(|r| r.time_max_avg).collect();
        assert_eq!(t1, t2);
        assert_eq!(rep.total_migrations, rep2.total_migrations);
    }

    #[test]
    fn lb_reduces_particle_imbalance_vs_none() {
        let cfg = DriverConfig { iters: 30, lb_period: 10, ..Default::default() };
        let none = {
            let mut a = app();
            let s = make("none", StrategyParams::default()).unwrap();
            run_app(&mut a, s.as_ref(), &cfg).unwrap()
        };
        let refine = {
            let mut a = app();
            let s = make("greedy-refine", StrategyParams::default()).unwrap();
            run_app(&mut a, s.as_ref(), &cfg).unwrap()
        };
        let avg = |r: &RunReport| {
            r.records.iter().map(|x| x.work_max_avg).sum::<f64>() / r.records.len() as f64
        };
        // margin: load attribution uses measured wall-clock, which is
        // noisy when the test host is contended
        assert!(
            avg(&refine) < avg(&none) * 1.05,
            "{} !< {}",
            avg(&refine),
            avg(&none)
        );
    }

    #[test]
    fn resize_leave_evicts_the_departing_node() {
        use crate::model::ResizeSchedule;
        let mut a = app();
        let strat = make("diff-comm", StrategyParams::default()).unwrap();
        let cfg = DriverConfig {
            iters: 20,
            lb_period: 5,
            deterministic_loads: true,
            resize: ResizeSchedule::parse("leave:3@2").unwrap(),
            ..Default::default()
        };
        let rep = run_app(&mut a, strat.as_ref(), &cfg).unwrap();
        assert!(rep.verified, "resize must not corrupt physics");
        let topo = Topology::flat(4);
        assert!(
            rep.final_mapping.iter().all(|&pe| topo.node_of_pe(pe) != 3),
            "object left on the departed node"
        );
    }

    #[test]
    fn resize_join_keeps_the_late_node_empty_until_it_joins() {
        use crate::model::ResizeSchedule;
        let mut a = app();
        let strat = make("diff-comm", StrategyParams::default()).unwrap();
        let cfg = DriverConfig {
            iters: 20,
            lb_period: 5,
            deterministic_loads: true,
            resize: ResizeSchedule::parse("join:3@1").unwrap(),
            ..Default::default()
        };
        let rep = run_app(&mut a, strat.as_ref(), &cfg).unwrap();
        assert!(rep.verified, "resize must not corrupt physics");
        // Records are written before each LB round fires, so every
        // iteration up to and including the join round's must show the
        // joiner empty (initial rehome evicted its objects).
        for r in &rep.records[..10] {
            assert_eq!(r.node_work[3], 0.0, "joiner held work at iter {}", r.iter);
        }
    }

    #[test]
    fn stencil_runs_through_the_generic_driver() {
        let mut sim = StencilSim::new(16, 2, 2, Decomposition::Tiled, 0.4, 7);
        let strat = make("diff-comm", StrategyParams::default()).unwrap();
        let cfg = DriverConfig { iters: 6, lb_period: 2, ..Default::default() };
        let rep = run_app(&mut sim, strat.as_ref(), &cfg).unwrap();
        assert_eq!(rep.records.len(), 6);
        assert!(rep.verified);
        // halo traffic is charged every step
        assert!(rep.records.iter().all(|r| r.comm_max_s > 0.0));
    }
}
