//! Iterative application driver: runs an app for N iterations with a
//! load-balancing schedule, accounting compute time (measured),
//! communication time (α–β model over the recorded traffic), and LB
//! cost (measured strategy time + modeled migration transfer) — the
//! machinery behind Figs 3–6.

use anyhow::Result;

use crate::apps::pic::PicApp;
use crate::model::{evaluate, Assignment, Topology};
use crate::simnet::{CostTracker, NetModel};
use crate::strategies::LoadBalancer;
use crate::util::stats::Summary;

/// Node-granularity communication accounting for one app step: every
/// adjacent chare pair exchanges one sync message per step (α even when
/// empty), carrying that step's migrated-particle payload; non-adjacent
/// crossings (possible when 2k+1 exceeds a chare) pay their own
/// message. `moved` holds the step's directed `(from, to, bytes)`
/// crossing records; they are canonicalized to unordered pairs and
/// sort-merged into the reused `payload` buffer. Shared by the
/// sequential and distributed drivers so both model communication
/// seconds with the same arithmetic over the same aggregates
/// (`tests/distributed.rs` asserts the outputs are equal).
pub fn account_step_comm(
    topo: &Topology,
    chare_to_pe: &[u32],
    neighbor_pairs: &[(u32, u32)],
    moved: &[(u32, u32, f64)],
    payload: &mut Vec<(u32, u32, f64)>,
    consumed: &mut Vec<bool>,
    tracker: &mut CostTracker,
) {
    payload.clear();
    payload.extend(moved.iter().map(|&(f, t, bytes)| (f.min(t), f.max(t), bytes)));
    crate::model::graph::sort_sum_merge(payload);
    consumed.clear();
    consumed.resize(payload.len(), false);
    tracker.reset();
    for &(a, b) in neighbor_pairs {
        let n_a = topo.node_of_pe(chare_to_pe[a as usize]);
        let n_b = topo.node_of_pe(chare_to_pe[b as usize]);
        let bytes = match payload.binary_search_by_key(&(a, b), |&(x, y, _)| (x, y)) {
            Ok(idx) => {
                consumed[idx] = true;
                payload[idx].2
            }
            Err(_) => 0.0,
        };
        tracker.record(n_a, n_b, bytes);
    }
    for (idx, &(a, b, bytes)) in payload.iter().enumerate() {
        if consumed[idx] {
            continue;
        }
        let n_a = topo.node_of_pe(chare_to_pe[a as usize]);
        let n_b = topo.node_of_pe(chare_to_pe[b as usize]);
        tracker.record(n_a, n_b, bytes);
    }
}

/// Driver schedule + accounting configuration.
#[derive(Clone)]
pub struct DriverConfig {
    pub iters: usize,
    /// Run the balancer every `lb_period` iterations (0 = never).
    pub lb_period: usize,
    pub net: NetModel,
    /// Print progress every `log_every` iterations (0 = quiet).
    pub log_every: usize,
    /// Use particle counts instead of measured push seconds as the LB
    /// load signal. Measured time is the production signal but is
    /// wall-clock-noisy; counts make a run's LB decisions exactly
    /// reproducible — which is what lets `tests/distributed.rs` assert
    /// the distributed driver reports the *same* migration counts and
    /// modeled comm seconds as this sequential driver.
    pub deterministic_loads: bool,
}

impl Default for DriverConfig {
    fn default() -> Self {
        DriverConfig {
            iters: 100,
            lb_period: 10,
            net: NetModel::default(),
            log_every: 0,
            deterministic_loads: false,
        }
    }
}

/// Per-iteration record (one row of the Fig 3/4/6 series).
#[derive(Debug, Clone, Default)]
pub struct IterRecord {
    pub iter: usize,
    /// max/avg particles per PE (Fig 3/4 metric).
    pub particles_max_avg: f64,
    /// particles on each node (Fig 3 series).
    pub node_particles: Vec<usize>,
    /// modeled per-iteration compute time (max / avg over nodes).
    pub compute_max_s: f64,
    pub compute_avg_s: f64,
    /// modeled per-iteration communication time (max / avg over nodes).
    pub comm_max_s: f64,
    pub comm_avg_s: f64,
    /// strategy wall-clock + modeled migration transfer, when LB ran.
    pub lb_s: f64,
    pub migrations: usize,
}

/// Aggregates over a full run (the Fig 5 bars).
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    pub records: Vec<IterRecord>,
    pub total_s: f64,
    pub compute_s: f64,
    pub comm_s: f64,
    pub lb_s: f64,
    pub total_migrations: usize,
    pub verified: bool,
}

impl RunReport {
    pub fn summary_line(&self, label: &str) -> String {
        format!(
            "{label:<14} total={:.3}s compute={:.3}s comm={:.3}s lb={:.3}s migr={} verified={}",
            self.total_s, self.compute_s, self.comm_s, self.lb_s, self.total_migrations,
            self.verified
        )
    }
}

/// Run the PIC app under `strategy` and record the full time series.
pub fn run_pic(
    app: &mut PicApp,
    strategy: &dyn LoadBalancer,
    cfg: &DriverConfig,
) -> Result<RunReport> {
    let topo = app.cfg.topo;
    let neighbor_pairs = app.chare_neighbor_pairs();
    let mut report = RunReport::default();
    // Per-iteration accounting buffers, hoisted out of the loop: the
    // seed rebuilt a payload HashMap and a CostTracker every step.
    let mut tracker = CostTracker::new(topo.n_nodes);
    let mut payload: Vec<(u32, u32, f64)> = Vec::new();
    let mut consumed: Vec<bool> = Vec::new();
    for iter in 0..cfg.iters {
        let stats = app.step()?;

        // --- compute accounting: measured push time attributed to the
        // busiest node (nodes run concurrently in the real system).
        let pe_counts = app.pe_particle_counts();
        let mut node_particles = vec![0usize; topo.n_nodes];
        for (pe, &cnt) in pe_counts.iter().enumerate() {
            node_particles[topo.node_of_pe(pe as u32) as usize] += cnt;
        }
        let per_particle = stats.push_s / app.state.len().max(1) as f64;
        let node_compute: Vec<f64> =
            node_particles.iter().map(|&c| c as f64 * per_particle).collect();

        // --- comm accounting at node granularity (shared with the
        // distributed driver, which gathers the same crossing records
        // per node and runs the identical arithmetic at its root).
        account_step_comm(
            &topo,
            &app.chare_to_pe,
            &neighbor_pairs,
            &stats.moved,
            &mut payload,
            &mut consumed,
            &mut tracker,
        );
        let comm_times = tracker.comm_times(&cfg.net);

        let pe_summary = Summary::of(&pe_counts.iter().map(|&c| c as f64).collect::<Vec<_>>());
        let mut rec = IterRecord {
            iter,
            particles_max_avg: pe_summary.max_avg_ratio(),
            node_particles,
            compute_max_s: node_compute.iter().cloned().fold(0.0, f64::max),
            compute_avg_s: node_compute.iter().sum::<f64>() / topo.n_nodes as f64,
            comm_max_s: comm_times.iter().cloned().fold(0.0, f64::max),
            comm_avg_s: comm_times.iter().sum::<f64>() / topo.n_nodes as f64,
            ..Default::default()
        };

        // --- load balancing step.
        if cfg.lb_period > 0 && (iter + 1) % cfg.lb_period == 0 {
            let mut inst = app.build_instance();
            if cfg.deterministic_loads {
                inst.loads =
                    app.chare_particle_counts().iter().map(|&c| c as f64).collect();
            }
            let t = std::time::Instant::now();
            let asg = strategy.rebalance(&inst);
            let strat_s = t.elapsed().as_secs_f64();
            let metrics = evaluate(&inst, &asg);
            let moved_bytes = app.apply_assignment(&asg);
            // migration transfer cost: modeled as one bulk inter-node
            // transfer of the moved bytes, split over nodes
            let transfer_s = cfg.net.inter_time(metrics.migrations as u64, moved_bytes)
                / topo.n_nodes.max(1) as f64;
            rec.lb_s = strat_s + transfer_s;
            rec.migrations = metrics.migrations;
            report.total_migrations += metrics.migrations;
        }

        if cfg.log_every > 0 && iter % cfg.log_every == 0 {
            crate::info!(
                "iter {iter}: max/avg={:.3} comp={:.2}ms comm={:.2}ms lb={:.2}ms",
                rec.particles_max_avg,
                rec.compute_max_s * 1e3,
                rec.comm_max_s * 1e3,
                rec.lb_s * 1e3
            );
        }
        report.compute_s += rec.compute_max_s;
        report.comm_s += rec.comm_max_s;
        report.lb_s += rec.lb_s;
        report.total_s += rec.compute_max_s + rec.comm_max_s + rec.lb_s;
        report.records.push(rec);
    }
    report.verified = app.verify().is_ok();
    Ok(report)
}

/// Convenience: run the same PIC configuration under several strategies
/// (fresh app per strategy) and return (name, report) pairs.
pub fn compare_strategies(
    mk_app: impl Fn() -> Result<PicApp>,
    strategies: &[(&str, Box<dyn LoadBalancer>)],
    cfg: &DriverConfig,
) -> Result<Vec<(String, RunReport)>> {
    let mut out = Vec::new();
    for (name, strat) in strategies {
        let mut app = mk_app()?;
        let report = run_pic(&mut app, strat.as_ref(), cfg)?;
        out.push((name.to_string(), report));
    }
    Ok(out)
}

/// Assignment helper re-exported for bench code symmetry.
pub fn no_lb_assignment(app: &PicApp) -> Assignment {
    Assignment { mapping: app.chare_to_pe.clone() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::pic::{Backend, InitMode, PicApp, PicConfig};
    use crate::apps::stencil::Decomposition;
    use crate::model::Topology;
    use crate::strategies::{make, StrategyParams};

    fn app() -> PicApp {
        PicApp::new(
            PicConfig {
                grid: 64,
                n_particles: 3_000,
                k: 1,
                m: 1,
                init: InitMode::Geometric { rho: 0.9 },
                chares_x: 8,
                chares_y: 8,
                decomp: Decomposition::Striped,
                topo: Topology::flat(4),
                q: 1.0,
                seed: 5,
                particle_bytes: 48.0,
                threads: 2,
            },
            Backend::Native,
        )
        .unwrap()
    }

    #[test]
    fn run_produces_full_series_and_verifies() {
        let mut a = app();
        let strat = make("diff-comm", StrategyParams::default()).unwrap();
        let cfg = DriverConfig { iters: 20, lb_period: 5, ..Default::default() };
        let rep = run_pic(&mut a, strat.as_ref(), &cfg).unwrap();
        assert_eq!(rep.records.len(), 20);
        assert!(rep.verified, "physics corrupted by LB");
        assert!(rep.total_s > 0.0);
        // LB ran at iters 4, 9, 14, 19
        assert!(rep.records[4].lb_s >= 0.0);
        assert_eq!(rep.records[3].migrations, 0);
    }

    #[test]
    fn lb_reduces_particle_imbalance_vs_none() {
        let cfg = DriverConfig { iters: 30, lb_period: 10, ..Default::default() };
        let none = {
            let mut a = app();
            let s = make("none", StrategyParams::default()).unwrap();
            run_pic(&mut a, s.as_ref(), &cfg).unwrap()
        };
        let refine = {
            let mut a = app();
            let s = make("greedy-refine", StrategyParams::default()).unwrap();
            run_pic(&mut a, s.as_ref(), &cfg).unwrap()
        };
        let avg = |r: &RunReport| {
            r.records.iter().map(|x| x.particles_max_avg).sum::<f64>() / r.records.len() as f64
        };
        // margin: load attribution uses measured wall-clock, which is
        // noisy when the test host is contended
        assert!(
            avg(&refine) < avg(&none) * 1.05,
            "{} !< {}",
            avg(&refine),
            avg(&none)
        );
    }
}
