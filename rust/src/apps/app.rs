//! The application/driver boundary: any workload with persistently
//! interacting objects plugs into the one LB loop through [`App`].
//!
//! The paper frames the diffusion pipeline as application-agnostic —
//! "easily generated for any Charm++ application" — and this trait is
//! that claim made structural: a workload exposes its objects (current
//! mapping, static sync adjacency, per-object work), advances one step
//! at a time while reporting measured compute seconds and the directed
//! `(from, to, bytes)` crossing records that
//! [`account_step_comm`](crate::apps::driver::account_step_comm)
//! consumes, snapshots itself into an LB [`Instance`] on demand, and
//! adopts [`Assignment`]s. Everything else — the iterate / record /
//! rebalance / migrate / account loop behind Figs 3–6 — lives once, in
//! [`run_app`](crate::apps::driver::run_app), for every workload and
//! every strategy.
//!
//! Implementations: [`PicApp`](crate::apps::pic::PicApp) (PIC PRK,
//! paper §VI), [`StencilSim`](crate::apps::stencil::StencilSim)
//! (noisy stencil rounds, §V), [`Advect`](crate::apps::advect::Advect)
//! (streamline particle advection with flow-dependent per-block cost,
//! after Demiralp et al., arXiv:2208.07553), and
//! [`Hotspot`](crate::apps::hotspot::Hotspot) (a load peak drifting
//! across the object graph — the adversarial case for stale
//! assignments, in the spirit of Boulmier et al., arXiv:1909.07168).
//! Adding a workload is implementing this trait and registering it in
//! [`AVAILABLE_APPS`](crate::apps::AVAILABLE_APPS) +
//! [`app_from_config`](crate::coordinator::app_from_config) — see
//! README "Adding a workload".

use anyhow::Result;

use crate::model::{Assignment, Instance, Topology};

/// Reused per-step context the driver hands to [`App::step`]. Owning
/// the crossing-record buffer here (instead of allocating a fresh
/// `Vec` inside every app step) keeps the loop allocation-free at
/// steady state; the driver clears `moved` before each step and
/// sort-merges it afterwards, so apps only ever append raw records.
#[derive(Debug, Default)]
pub struct StepCtx {
    /// Directed `(from_object, to_object, bytes)` crossing records of
    /// this step, appended by the app (one record per crossing event;
    /// the driver aggregates). These drive both the per-step modeled
    /// communication seconds and — via the app's own
    /// [`TrafficRecorder`](crate::model::TrafficRecorder) — the LB
    /// instance's communication graph.
    pub moved: Vec<(u32, u32, f64)>,
}

/// What one [`App::step`] reports back to the driver (the crossing
/// records travel in [`StepCtx::moved`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct StepStats {
    /// Measured wall-clock seconds of this step's compute phase.
    pub compute_s: f64,
    /// App-defined event count (PIC/advect: objects' payload items that
    /// crossed owners; stencil/hotspot: halo edges exchanged).
    pub events: usize,
}

/// A workload the generic driver can iterate, balance, and account.
///
/// Contract (checked by `tests/apps_conformance.rs` for every
/// registered app):
///
/// * [`App::mapping`] always has length [`App::n_objects`] with every
///   entry `< topo.n_pes()`;
/// * [`App::step`] appends only in-range, finite, non-negative crossing
///   records to `ctx.moved`;
/// * [`App::work`] fills one finite non-negative work unit per object —
///   the driver's load-attribution / imbalance signal, and the exact
///   loads used when `DriverConfig::deterministic_loads` is set;
/// * [`App::build_instance`] returns a valid [`Instance`] over the same
///   objects and **drains** accumulated traffic/measured load (it is
///   called once per LB round);
/// * [`App::apply`] adopts the assignment (mapping length must match)
///   and returns the modeled migration payload bytes.
pub trait App {
    /// Registry name (one of [`AVAILABLE_APPS`](crate::apps::AVAILABLE_APPS)).
    fn name(&self) -> &'static str;

    /// The node × PE topology the workload runs on.
    fn topo(&self) -> Topology;

    /// Number of migratable objects (chares / blocks / cells).
    fn n_objects(&self) -> usize;

    /// Current object → PE mapping.
    fn mapping(&self) -> &[u32];

    /// Static object adjacency: unordered `(a, b)` pairs with `a < b`,
    /// each exchanging one synchronization message per step (the
    /// Charm++ pattern: a chare must hear from all neighbors to know
    /// every incoming item arrived). The driver charges α per such
    /// message, so scattering neighbors across nodes shows up as
    /// communication time.
    fn neighbor_pairs(&self) -> Vec<(u32, u32)>;

    /// Advance one time step; append crossing records to `ctx.moved`.
    fn step(&mut self, ctx: &mut StepCtx) -> Result<StepStats>;

    /// Per-object work units of the latest step, into a reused buffer
    /// (cleared + filled here). PIC/advect: payload items (particles /
    /// integration substeps) per object; stencil/hotspot: the
    /// per-object loads themselves.
    fn work(&self, out: &mut Vec<f64>);

    /// Snapshot the LB problem: drains recorded traffic and measured
    /// loads accumulated since the previous LB round.
    fn build_instance(&mut self) -> Instance;

    /// Adopt a new object → PE mapping; returns migrated payload bytes.
    fn apply(&mut self, asg: &Assignment) -> f64;

    /// App-specific end-of-run correctness check (PIC: PRK analytic
    /// positions; advect: payload conservation). Default: trivially ok.
    fn verify(&self) -> std::result::Result<(), String> {
        Ok(())
    }
}

/// Drive one step with a throwaway context — convenience for tests and
/// benches that don't run the full driver loop.
pub fn step_once<A: App + ?Sized>(app: &mut A) -> Result<StepStats> {
    let mut ctx = StepCtx::default();
    app.step(&mut ctx)
}
