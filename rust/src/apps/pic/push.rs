//! Particle push backends.
//!
//! [`native_push`] is the pure-Rust hot path (thread-parallel,
//! identical math to the Pallas kernel — the integration tests assert
//! bitwise-level agreement with the PJRT artifact), used when artifacts
//! are absent or for baseline comparison. The PJRT path lives in
//! [`crate::runtime::Engine::pic_push`].
//!
//! The inner loop is written for **explicit chunked autovectorization**:
//! [`push_span`] walks fixed [`LANES`]-wide blocks whose bodies are
//! branch-free straight-line f64 arithmetic (the periodic wrap and
//! [`grid_charge`] were rewritten branchless for exactly this reason),
//! so LLVM unrolls and packs them into SIMD lanes. Per-element math is
//! [`push_one`] verbatim — vectorization only changes *how many*
//! elements an iteration handles, never the operation order within one
//! element, so results stay bit-identical to the scalar loop (locked by
//! `rust/tests/simd_soa_identity.rs` against a frozen scalar copy).

use crate::runtime::PicBatch;

use super::init::{grid_charge, DT};

pub const MASS_INV: f64 = 1.0;

/// One PIC step for particle `i` of `b` (PRK computeTotalForce + update).
#[inline]
pub fn push_one(
    x: f64,
    y: f64,
    vx: f64,
    vy: f64,
    q: f64,
    l: f64,
    big_q: f64,
) -> (f64, f64, f64, f64) {
    let cx = x.floor();
    let cy = y.floor();
    let rel_x = x - cx;
    let rel_y = y - cy;
    let q_left = grid_charge(cx, big_q);
    let q_right = -q_left;

    // NOTE: no f64::mul_add here — without -Ctarget-feature=+fma it
    // lowers to an fma() libcall and costs 1.3x (EXPERIMENTS.md §Perf).
    #[inline(always)]
    fn corner(xd: f64, yd: f64, qp: f64, qg: f64) -> (f64, f64) {
        let r2 = xd * xd + yd * yd;
        let f = (qp * qg) / (r2 * r2.sqrt());
        (f * xd, f * yd)
    }

    let (fx_tl, fy_tl) = corner(rel_x, rel_y, q, q_left);
    let (fx_bl, fy_bl) = corner(rel_x, 1.0 - rel_y, q, q_left);
    let (fx_tr, fy_tr) = corner(1.0 - rel_x, rel_y, q, q_right);
    let (fx_br, fy_br) = corner(1.0 - rel_x, 1.0 - rel_y, q, q_right);

    let ax = (fx_tl + fx_bl - fx_tr - fx_br) * MASS_INV;
    let ay = (fy_tl - fy_bl + fy_tr - fy_br) * MASS_INV;

    // branch-free periodic wrap (rem_euclid's sign branch blocks
    // autovectorization of the caller's loop)
    let xu = x + vx * DT + 0.5 * ax * (DT * DT);
    let yu = y + vy * DT + 0.5 * ay * (DT * DT);
    let xn = xu - l * (xu / l).floor();
    let yn = yu - l * (yu / l).floor();
    (xn, yn, vx + ax * DT, vy + ay * DT)
}

/// SIMD block width for [`push_span`]. Eight f64 lanes = one AVX-512
/// register or two AVX2 / four NEON registers after unrolling; the
/// value only shapes code generation, never results.
pub const LANES: usize = 8;

/// Push one contiguous span of particles in place: full [`LANES`]-wide
/// blocks first (a fixed-trip-count inner loop LLVM can unroll and
/// vectorize — no bounds checks survive, the slices are pre-sliced to
/// exactly `LANES`), then a scalar remainder loop with the identical
/// body. Both the sequential path and every pool-chunk task of
/// [`native_push`] funnel through here, so thread count cannot change
/// which code shape an element takes.
fn push_span(
    x: &mut [f64],
    y: &mut [f64],
    vx: &mut [f64],
    vy: &mut [f64],
    q: &[f64],
    l: f64,
    big_q: f64,
) {
    let n = x.len();
    debug_assert!(y.len() == n && vx.len() == n && vy.len() == n && q.len() == n);
    let blocks = n / LANES * LANES;
    let mut i = 0;
    while i < blocks {
        // Fixed-width re-slices: the compiler sees `LANES` exactly and
        // drops every bounds check in the k-loop.
        let (xb, yb) = (&mut x[i..i + LANES], &mut y[i..i + LANES]);
        let (vxb, vyb) = (&mut vx[i..i + LANES], &mut vy[i..i + LANES]);
        let qb = &q[i..i + LANES];
        for k in 0..LANES {
            let (xn, yn, vxn, vyn) = push_one(xb[k], yb[k], vxb[k], vyb[k], qb[k], l, big_q);
            xb[k] = xn;
            yb[k] = yn;
            vxb[k] = vxn;
            vyb[k] = vyn;
        }
        i += LANES;
    }
    for k in blocks..n {
        let (xn, yn, vxn, vyn) = push_one(x[k], y[k], vx[k], vy[k], q[k], l, big_q);
        x[k] = xn;
        y[k] = yn;
        vx[k] = vxn;
        vy[k] = vyn;
    }
}

/// One PIC step over the whole batch, parallelized over `threads`
/// chunks on the persistent [`crate::util::pool`] worker pool (the seed
/// spawned scoped OS threads per step — spawn/join dominated small
/// batches; see EXPERIMENTS.md §Perf). Chunk boundaries depend only on
/// `(n, threads)`, and each chunk runs the same [`push_span`] body, so
/// the result is bit-identical to the sequential path and to the old
/// per-step-spawn implementation for every thread count.
pub fn native_push(b: &mut PicBatch, l: f64, big_q: f64, threads: usize) {
    let n = b.len();
    if n == 0 {
        return;
    }
    // more threads than cores only adds scheduling overhead
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let threads = threads.clamp(1, n).min(cores);
    if threads == 1 {
        push_span(&mut b.x, &mut b.y, &mut b.vx, &mut b.vy, &b.q, l, big_q);
        return;
    }
    let chunk = n.div_ceil(threads);
    // Split all five arrays into matching chunks and push in parallel.
    let mut rest: (&mut [f64], &mut [f64], &mut [f64], &mut [f64], &mut [f64]) =
        (&mut b.x, &mut b.y, &mut b.vx, &mut b.vy, &mut b.q);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(threads);
    while !rest.0.is_empty() {
        let take = chunk.min(rest.0.len());
        let (x, xr) = rest.0.split_at_mut(take);
        let (y, yr) = rest.1.split_at_mut(take);
        let (vx, vxr) = rest.2.split_at_mut(take);
        let (vy, vyr) = rest.3.split_at_mut(take);
        let (q, qr) = rest.4.split_at_mut(take);
        rest = (xr, yr, vxr, vyr, qr);
        tasks.push(Box::new(move || push_span(x, y, vx, vy, q, l, big_q)));
    }
    crate::util::pool::global().scoped(tasks);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::pic::init::{base_charge, initialize, InitMode};

    fn batch_from(pop: crate::apps::pic::init::Population) -> PicBatch {
        PicBatch { x: pop.x, y: pop.y, vx: pop.vx, vy: pop.vy, q: pop.q }
    }

    #[test]
    fn determinism_property_native() {
        // calibrated particles move exactly (2k+1, m) per step
        let l = 64.0;
        let (k, m) = (2u32, 1u32);
        let pop = initialize(InitMode::Geometric { rho: 0.95 }, 512, 64, k, m, 1.0, 9);
        let x0 = pop.x.clone();
        let y0 = pop.y.clone();
        let mut b = batch_from(pop);
        let steps = 7;
        for _ in 0..steps {
            native_push(&mut b, l, 1.0, 4);
        }
        for i in 0..b.len() {
            let ex = (x0[i] + steps as f64 * (2 * k + 1) as f64).rem_euclid(l);
            let ey = (y0[i] + steps as f64 * m as f64).rem_euclid(l);
            assert!((b.x[i] - ex).abs() < 1e-6, "x[{i}]: {} vs {ex}", b.x[i]);
            assert!((b.y[i] - ey).abs() < 1e-6, "y[{i}]: {} vs {ey}", b.y[i]);
        }
    }

    #[test]
    fn vx_oscillates_to_zero_on_even_steps() {
        let pop = initialize(InitMode::Sinusoidal, 128, 32, 1, 1, 1.0, 2);
        let mut b = batch_from(pop);
        for _ in 0..4 {
            native_push(&mut b, 32.0, 1.0, 2);
        }
        for &v in &b.vx {
            assert!(v.abs() < 1e-9, "vx {v}");
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let pop = initialize(InitMode::Geometric { rho: 0.9 }, 300, 32, 1, 1, 1.0, 3);
        let mut b1 = batch_from(pop.clone());
        let mut b8 = batch_from(pop);
        native_push(&mut b1, 32.0, 1.0, 1);
        native_push(&mut b8, 32.0, 1.0, 8);
        assert_eq!(b1, b8);
    }

    #[test]
    fn inert_padding_particles() {
        let mut b = PicBatch::with_capacity(4);
        for _ in 0..4 {
            b.push_pad();
        }
        native_push(&mut b, 16.0, 1.0, 2);
        assert!(b.x.iter().all(|&x| x == 0.5));
        assert!(b.vx.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn single_particle_first_step_displacement_exact() {
        let q = (2.0 * 3.0 + 1.0) * base_charge(0.5, 0.5, 2.0);
        let (xn, yn, _, vyn) = push_one(4.5, 7.5, 0.0, 1.0, q, 1000.0, 2.0);
        assert!((xn - (4.5 + 7.0)).abs() < 1e-9, "xn {xn}");
        assert!((yn - 8.5).abs() < 1e-9, "yn {yn}");
        assert!((vyn - 1.0).abs() < 1e-9);
    }
}
