//! PIC PRK particle initialization (Georganas et al., IPDPS'16 §III).
//!
//! Particles are placed at cell centers and given a **calibrated
//! charge** such that each particle travels exactly `2k+1` cells in +x
//! per time step (column parity flips each step since `2k+1` is odd, so
//! the force alternates sign and `v_x` oscillates between 0 and `a·DT`),
//! and exactly `m` cells in +y (vertical force cancels at `rel_y = 0.5`).
//! This determinism is what makes the benchmark *verifiable* and its
//! load-imbalance evolution predictable (paper §VI-A).

use crate::util::rng::Rng;

pub const DT: f64 = 1.0;

/// Supported initial particle distributions (PRK modes).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InitMode {
    /// `A·rho^i` particles in grid column i (paper's mode; rho < 1 skews
    /// left, the paper uses rho = 0.9).
    Geometric { rho: f64 },
    /// Density ∝ 1 + cos(2πx/L): smooth periodic bunching.
    Sinusoidal,
    /// Density decreasing linearly with x: `1 - alpha·x/L`.
    Linear { alpha: f64 },
    /// Uniform density inside a rectangular patch, zero outside.
    Patch { x0: f64, x1: f64, y0: f64, y1: f64 },
}

/// Charge magnitude at grid column `x`: +Q even columns, −Q odd.
///
/// The mod-2 wrap is branch-free: `rem_euclid`'s negative-remainder
/// branch kept the particle-push loop from autovectorizing. For every
/// f64 input the two forms agree bitwise after the `1.0 - 2.0 * r`
/// fold: `x * 0.5` only shifts the exponent, `floor` is exact, and the
/// final subtraction is exact by Sterbenz's lemma, so `r` is the exact
/// mathematical `x mod 2` either way — the lone difference is the sign
/// of a zero `r` on negative even inputs, which `2.0 * r` erases.
/// Cross-checked exhaustively-at-random by `tools/crosscheck_simd.py`
/// and pinned against the `rem_euclid` form in
/// `rust/tests/simd_soa_identity.rs`.
#[inline]
pub fn grid_charge(x: f64, q: f64) -> f64 {
    let r = x - 2.0 * (x * 0.5).floor();
    q * (1.0 - 2.0 * r)
}

/// PRK charge calibration for a particle at cell-relative (rel_x, rel_y):
/// with charge `(2k+1)·base_charge`, first-step displacement is exactly
/// `2k+1` cells (see python/compile/kernels/ref.py::base_charge).
pub fn base_charge(rel_x: f64, rel_y: f64, q: f64) -> f64 {
    let r1_sq = rel_y * rel_y + rel_x * rel_x;
    let r2_sq = rel_y * rel_y + (1.0 - rel_x) * (1.0 - rel_x);
    let cos_theta = rel_x / r1_sq.sqrt();
    let cos_phi = (1.0 - rel_x) / r2_sq.sqrt();
    1.0 / ((DT * DT) * q * (cos_theta / r1_sq + cos_phi / r2_sq))
}

/// A freshly initialized particle population (structure of arrays).
#[derive(Debug, Clone, Default)]
pub struct Population {
    pub x: Vec<f64>,
    pub y: Vec<f64>,
    pub vx: Vec<f64>,
    pub vy: Vec<f64>,
    pub q: Vec<f64>,
}

impl Population {
    pub fn len(&self) -> usize {
        self.x.len()
    }

    pub fn is_empty(&self) -> bool {
        self.x.is_empty()
    }
}

/// Column weights for an init mode over `l` columns.
fn column_weights(mode: InitMode, l: usize) -> Vec<f64> {
    (0..l)
        .map(|i| match mode {
            InitMode::Geometric { rho } => rho.powi(i as i32),
            InitMode::Sinusoidal => {
                1.0 + (2.0 * std::f64::consts::PI * i as f64 / l as f64).cos()
            }
            InitMode::Linear { alpha } => (1.0 - alpha * i as f64 / l as f64).max(0.0),
            InitMode::Patch { x0, x1, .. } => {
                if (i as f64) >= x0 && (i as f64) < x1 {
                    1.0
                } else {
                    0.0
                }
            }
        })
        .collect()
}

/// Distribute `n` particles over columns by weight (largest remainder).
fn apportion(weights: &[f64], n: usize) -> Vec<usize> {
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "init mode places no particles");
    let ideal: Vec<f64> = weights.iter().map(|w| w / total * n as f64).collect();
    let mut counts: Vec<usize> = ideal.iter().map(|x| x.floor() as usize).collect();
    let mut short = n - counts.iter().sum::<usize>();
    let mut rema: Vec<(usize, f64)> =
        ideal.iter().enumerate().map(|(i, x)| (i, x - x.floor())).collect();
    rema.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    for (i, _) in rema {
        if short == 0 {
            break;
        }
        counts[i] += 1;
        short -= 1;
    }
    counts
}

/// Initialize `n` particles on an `l x l` grid.
pub fn initialize(mode: InitMode, n: usize, l: usize, k: u32, m: u32, q: f64, seed: u64) -> Population {
    let mut rng = Rng::new(seed);
    let counts = apportion(&column_weights(mode, l), n);
    let mut pop = Population::default();
    let bc = base_charge(0.5, 0.5, q);
    let row_span = match mode {
        InitMode::Patch { y0, y1, .. } => (y0.max(0.0) as usize, (y1 as usize).min(l)),
        _ => (0, l),
    };
    for (col, &count) in counts.iter().enumerate() {
        for _ in 0..count {
            let row = rng.range(row_span.0, row_span.1.max(row_span.0 + 1));
            let x = col as f64 + 0.5;
            let y = row as f64 + 0.5;
            // even column -> positive charge (drifts +x past the +Q
            // column), odd -> negative (also +x): PRK's sign trick.
            let sign = if col % 2 == 0 { 1.0 } else { -1.0 };
            pop.x.push(x);
            pop.y.push(y);
            pop.vx.push(0.0);
            pop.vy.push(m as f64 / DT);
            pop.q.push(sign * (2.0 * k as f64 + 1.0) * bc);
        }
    }
    pop
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometric_skews_left() {
        let pop = initialize(InitMode::Geometric { rho: 0.9 }, 10_000, 100, 1, 1, 1.0, 3);
        assert_eq!(pop.len(), 10_000);
        let left = pop.x.iter().filter(|&&x| x < 50.0).count();
        assert!(left > 6_000, "left {left}");
    }

    #[test]
    fn all_cell_centered() {
        let pop = initialize(InitMode::Sinusoidal, 1_000, 64, 2, 1, 1.0, 4);
        for (&x, &y) in pop.x.iter().zip(&pop.y) {
            assert!((x.fract() - 0.5).abs() < 1e-12);
            assert!((y.fract() - 0.5).abs() < 1e-12);
        }
    }

    #[test]
    fn patch_respects_bounds() {
        let mode = InitMode::Patch { x0: 10.0, x1: 20.0, y0: 5.0, y1: 15.0 };
        let pop = initialize(mode, 500, 64, 1, 1, 1.0, 5);
        assert_eq!(pop.len(), 500);
        for (&x, &y) in pop.x.iter().zip(&pop.y) {
            assert!((10.0..20.0).contains(&x), "x {x}");
            assert!((5.0..15.0).contains(&y), "y {y}");
        }
    }

    #[test]
    fn apportion_exact_total() {
        let counts = apportion(&[0.5, 0.25, 0.25], 101);
        assert_eq!(counts.iter().sum::<usize>(), 101);
        assert!(counts[0] >= 50);
    }

    #[test]
    fn charge_signs_alternate_by_column() {
        let pop = initialize(InitMode::Linear { alpha: 0.5 }, 2_000, 32, 0, 1, 1.0, 6);
        for (&x, &q) in pop.x.iter().zip(&pop.q) {
            let col = x.floor() as usize;
            assert_eq!(q > 0.0, col % 2 == 0, "col {col} q {q}");
        }
    }

    #[test]
    fn base_charge_matches_python_oracle() {
        // value cross-checked against compile/kernels/ref.py
        let bc = base_charge(0.5, 0.5, 1.0);
        // cos_theta = cos_phi = 0.5/sqrt(0.5); r^2 = 0.5
        let expect = 1.0 / (2.0 * (0.5 / 0.5f64.sqrt()) / 0.5);
        assert!((bc - expect).abs() < 1e-12);
    }
}
