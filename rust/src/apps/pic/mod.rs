//! The PIC PRK benchmark (paper §VI), as a Charm++-style
//! over-decomposed application: the `grid x grid` cell mesh is split
//! into `chares_x x chares_y` chares, particles live in the chare that
//! owns their cell, and each time step (1) pushes every particle
//! (PJRT-compiled Pallas kernel or the native Rust backend) and (2)
//! re-bins crossers, recording chare→chare traffic — which *is* the
//! communication graph the diffusion strategy consumes. Per-chare load
//! is the measured push time attributed by particle count, and the
//! deterministic (2k+1)-cells-per-step motion lets [`PicApp::verify`]
//! check the entire pipeline (including LB migrations) analytically.
//!
//! `PicApp` implements [`App`], so the generic
//! [`run_app`](crate::apps::driver::run_app) loop drives it like every
//! other workload.

pub mod init;
pub mod push;
pub mod verify;

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::apps::app::{App, StepCtx, StepStats};
use crate::apps::stencil::Decomposition;
use crate::model::{Assignment, CommGraph, Instance, Topology, TrafficRecorder};
use crate::runtime::{Engine, PicBatch};

pub use init::InitMode;

/// Bytes charged per chare-pair sync message per step.
pub const SYNC_BYTES: f64 = 16.0;

/// PIC PRK configuration (mirrors the PRK CLI parameters + the paper's
/// chare/processor additions).
#[derive(Debug, Clone)]
pub struct PicConfig {
    /// Grid side L (cells); positions live in [0, L).
    pub grid: usize,
    pub n_particles: usize,
    /// Horizontal speed parameter: displacement = 2k+1 cells/step.
    pub k: u32,
    /// Vertical speed: m cells/step.
    pub m: u32,
    pub init: InitMode,
    pub chares_x: usize,
    pub chares_y: usize,
    /// Initial chare → PE decomposition (striped/quad, paper §VI-A).
    pub decomp: Decomposition,
    pub topo: Topology,
    /// Base grid charge magnitude Q.
    pub q: f64,
    pub seed: u64,
    /// Bytes to move one particle between chares (comm accounting).
    pub particle_bytes: f64,
    /// Native-backend push threads.
    pub threads: usize,
}

impl Default for PicConfig {
    fn default() -> Self {
        PicConfig {
            grid: 1000,
            n_particles: 100_000,
            k: 2,
            m: 1,
            init: InitMode::Geometric { rho: 0.9 },
            chares_x: 12,
            chares_y: 12,
            decomp: Decomposition::Striped,
            topo: Topology::flat(4),
            q: 1.0,
            seed: 0x9C,
            particle_bytes: 48.0,
            threads: 8,
        }
    }
}

/// Which engine performs the particle push.
#[derive(Clone)]
pub enum Backend {
    /// Pure Rust (thread-parallel), always available.
    Native,
    /// AOT Pallas kernel through the PJRT CPU client.
    Pjrt(Arc<Engine>),
}

pub struct PicApp {
    pub cfg: PicConfig,
    pub state: PicBatch,
    /// Initial positions (for verification).
    x0: Vec<f64>,
    y0: Vec<f64>,
    /// Current chare of each particle.
    pub chare_of: Vec<u32>,
    /// Current chare → PE mapping.
    pub chare_to_pe: Vec<u32>,
    /// Chare↔chare traffic since the last LB step.
    traffic: TrafficRecorder,
    /// Communication graph refreshed incrementally from `traffic` each
    /// LB round ([`CommGraph::update_from_recorder`]): the chare
    /// adjacency persists across rounds, so the refresh usually only
    /// overwrites weights instead of rebuilding the CSR.
    comm_cache: CommGraph,
    /// Static chare adjacency (sync-message partners), cached.
    neighbor_pairs: Vec<(u32, u32)>,
    /// Steps since the last build_instance (sync-traffic accounting).
    steps_since_lb: usize,
    /// Per-chare accumulated load (seconds) since the last LB step.
    pub load_acc: Vec<f64>,
    pub steps_done: usize,
    backend: Backend,
}

impl PicApp {
    pub fn new(cfg: PicConfig, backend: Backend) -> Result<PicApp> {
        anyhow::ensure!(cfg.grid % cfg.chares_x == 0, "grid must divide chares_x");
        anyhow::ensure!(cfg.grid % cfg.chares_y == 0, "grid must divide chares_y");
        let pop = init::initialize(
            cfg.init,
            cfg.n_particles,
            cfg.grid,
            cfg.k,
            cfg.m,
            cfg.q,
            cfg.seed,
        );
        let state = PicBatch { x: pop.x, y: pop.y, vx: pop.vx, vy: pop.vy, q: pop.q };
        let n_chares = cfg.chares_x * cfg.chares_y;
        let chare_to_pe = initial_mapping(&cfg);
        let mut app = PicApp {
            x0: state.x.clone(),
            y0: state.y.clone(),
            chare_of: vec![0; state.len()],
            chare_to_pe,
            traffic: TrafficRecorder::new(n_chares),
            comm_cache: CommGraph::empty(n_chares),
            neighbor_pairs: Vec::new(),
            steps_since_lb: 0,
            load_acc: vec![0.0; n_chares],
            steps_done: 0,
            state,
            cfg,
            backend,
        };
        app.neighbor_pairs = chare_neighbor_pairs(&app.cfg);
        for i in 0..app.state.len() {
            app.chare_of[i] = app.chare_of_pos(app.state.x[i], app.state.y[i]);
        }
        Ok(app)
    }

    pub fn n_chares(&self) -> usize {
        self.cfg.chares_x * self.cfg.chares_y
    }

    /// Chare owning position (x, y).
    #[inline]
    pub fn chare_of_pos(&self, x: f64, y: f64) -> u32 {
        chare_of_pos(&self.cfg, x, y)
    }

    /// Adjacent chare pairs (8-neighborhood, periodic), each once with
    /// `a < b` — see [`chare_neighbor_pairs`].
    pub fn chare_neighbor_pairs(&self) -> Vec<(u32, u32)> {
        chare_neighbor_pairs(&self.cfg)
    }

    pub fn chare_particle_counts(&self) -> Vec<u32> {
        let mut counts = vec![0u32; self.n_chares()];
        for &c in &self.chare_of {
            counts[c as usize] += 1;
        }
        counts
    }

    /// Particles per PE under the current chare mapping.
    pub fn pe_particle_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.cfg.topo.n_pes()];
        for &c in &self.chare_of {
            counts[self.chare_to_pe[c as usize] as usize] += 1;
        }
        counts
    }

    /// Snapshot the LB problem: drains traffic and accumulated loads.
    pub fn build_instance(&mut self) -> Instance {
        let counts: Vec<f64> =
            self.chare_particle_counts().iter().map(|&c| c as f64).collect();
        let inst = assemble_instance(
            &self.cfg,
            &counts,
            &self.load_acc,
            self.chare_to_pe.clone(),
            self.steps_since_lb,
            &self.neighbor_pairs,
            &mut self.traffic,
            &mut self.comm_cache,
        );
        self.steps_since_lb = 0;
        self.load_acc.iter_mut().for_each(|l| *l = 0.0);
        inst
    }

    /// Adopt a new chare → PE mapping; returns migrated bytes.
    pub fn apply_assignment(&mut self, asg: &Assignment) -> f64 {
        assert_eq!(asg.mapping.len(), self.n_chares());
        let counts = self.chare_particle_counts();
        let mut bytes = 0.0;
        for (c, (&new_pe, old_pe)) in asg.mapping.iter().zip(&self.chare_to_pe).enumerate() {
            if new_pe != *old_pe {
                bytes += counts[c] as f64 * self.cfg.particle_bytes;
            }
        }
        self.chare_to_pe = asg.mapping.clone();
        bytes
    }

    /// PRK-style analytic verification of every particle's position.
    pub fn verify(&self) -> std::result::Result<(), String> {
        verify::verify_positions(
            &self.x0,
            &self.y0,
            &self.state.x,
            &self.state.y,
            self.steps_done,
            self.cfg.k,
            self.cfg.m,
            self.cfg.grid as f64,
        )
    }
}

impl App for PicApp {
    fn name(&self) -> &'static str {
        "pic"
    }

    fn topo(&self) -> Topology {
        self.cfg.topo.clone()
    }

    fn n_objects(&self) -> usize {
        self.n_chares()
    }

    fn mapping(&self) -> &[u32] {
        &self.chare_to_pe
    }

    fn neighbor_pairs(&self) -> Vec<(u32, u32)> {
        self.neighbor_pairs.clone()
    }

    /// One time step: push all particles, re-bin crossers, account
    /// traffic and load. Crossings go straight to the driver's reused
    /// `ctx.moved` log (no per-step allocation); the driver aggregates
    /// them with the same stable sort-merge the recorder uses.
    fn step(&mut self, ctx: &mut StepCtx) -> Result<StepStats> {
        let t = Instant::now(); // difflb-lint: allow(wall-clock): measured compute seconds feed the report, not the mapping
        match &self.backend {
            Backend::Native => {
                push::native_push(&mut self.state, self.cfg.grid as f64, self.cfg.q, self.cfg.threads)
            }
            Backend::Pjrt(engine) => {
                engine.pic_push(&mut self.state, self.cfg.grid as f64, self.cfg.q)?
            }
        }
        let compute_s = t.elapsed().as_secs_f64();

        let mut events = 0usize;
        for i in 0..self.state.len() {
            let nc = self.chare_of_pos(self.state.x[i], self.state.y[i]);
            let oc = self.chare_of[i];
            if nc != oc {
                events += 1;
                self.traffic.record(oc, nc, self.cfg.particle_bytes);
                ctx.moved.push((oc, nc, self.cfg.particle_bytes));
                self.chare_of[i] = nc;
            }
        }

        // Load attribution: measured push time split by particle count.
        let counts = self.chare_particle_counts();
        let per_particle = compute_s / self.state.len().max(1) as f64;
        for (c, &cnt) in counts.iter().enumerate() {
            self.load_acc[c] += cnt as f64 * per_particle;
        }
        self.steps_done += 1;
        self.steps_since_lb += 1;

        Ok(StepStats { compute_s, events })
    }

    fn work(&self, out: &mut Vec<f64>) {
        out.clear();
        out.resize(self.n_chares(), 0.0);
        for &c in &self.chare_of {
            out[c as usize] += 1.0;
        }
    }

    fn build_instance(&mut self) -> Instance {
        PicApp::build_instance(self)
    }

    fn apply(&mut self, asg: &Assignment) -> f64 {
        self.apply_assignment(asg)
    }

    fn verify(&self) -> std::result::Result<(), String> {
        PicApp::verify(self)
    }
}

/// Assemble the LB problem instance from per-chare particle counts (as
/// exact-integer f64 work units), accumulated (measured) loads, and the
/// traffic recorder — the **single definition** of the instance both
/// drivers balance. [`PicApp::build_instance`] calls this against the
/// app's state; the distributed driver's root calls it against its
/// gathered replicas. The sequential-vs-distributed bit-identity
/// guarantee depends on there being exactly one copy of this sequence
/// (sync-traffic record, incremental comm-graph refresh, load fallback,
/// coords, sizes). The caller owns resetting `steps_since_lb` / the
/// measured loads.
#[allow(clippy::too_many_arguments)]
pub fn assemble_instance(
    cfg: &PicConfig,
    counts: &[f64],
    measured_loads: &[f64],
    mapping: Vec<u32>,
    steps_since_lb: usize,
    neighbor_pairs: &[(u32, u32)],
    recorder: &mut TrafficRecorder,
    comm_cache: &mut CommGraph,
) -> Instance {
    let n_chares = cfg.chares_x * cfg.chares_y;
    // Sync messages are communication too: every adjacent chare pair
    // exchanges a small message each step (the Charm++ runtime records
    // these in the comm graph just like particle payloads), so the
    // balancer sees grid adjacency as well as particle flow.
    for &(a, b) in neighbor_pairs {
        recorder.record(a, b, SYNC_BYTES * steps_since_lb as f64);
    }
    // Incremental refresh: chare adjacency persists across LB rounds,
    // so this usually only overwrites CSR weights. The instance gets
    // its own copy (a flat memcpy — still far cheaper than the seed's
    // per-round HashMap freeze).
    comm_cache.update_from_recorder(recorder);
    let graph = comm_cache.clone();
    // If no load was measured yet (LB before first step), fall back to
    // particle counts as the load proxy.
    let measured: f64 = measured_loads.iter().sum();
    let loads: Vec<f64> = if measured > 0.0 {
        measured_loads.to_vec()
    } else {
        counts.to_vec()
    };
    let cw = (cfg.grid / cfg.chares_x) as f64;
    let ch = (cfg.grid / cfg.chares_y) as f64;
    let coords: Vec<[f64; 2]> = (0..n_chares)
        .map(|c| {
            let cx = (c % cfg.chares_x) as f64;
            let cy = (c / cfg.chares_x) as f64;
            [cx * cw + cw / 2.0, cy * ch + ch / 2.0]
        })
        .collect();
    let mut inst = Instance::new(loads, coords, graph, mapping, cfg.topo.clone());
    inst.sizes = counts.iter().map(|&c| c * cfg.particle_bytes).collect();
    inst
}

/// Chare owning position (x, y) under `cfg`'s decomposition — free
/// function so the distributed driver's node threads can bin particles
/// without a [`PicApp`].
#[inline]
pub fn chare_of_pos(cfg: &PicConfig, x: f64, y: f64) -> u32 {
    let cw = cfg.grid / cfg.chares_x;
    let ch = cfg.grid / cfg.chares_y;
    let cx = ((x as usize) / cw).min(cfg.chares_x - 1);
    let cy = ((y as usize) / ch).min(cfg.chares_y - 1);
    (cy * cfg.chares_x + cx) as u32
}

/// Adjacent chare pairs (8-neighborhood, periodic), each once with
/// `a < b`. Every time step each pair exchanges a synchronization
/// message (possibly empty) — the Charm++ PIC PRK pattern: a chare
/// must hear from all neighbors to know every incoming particle
/// arrived. The driver charges α per such message, so scattering
/// chares across nodes directly shows up as communication time.
pub fn chare_neighbor_pairs(cfg: &PicConfig) -> Vec<(u32, u32)> {
    crate::apps::grid_neighbor_pairs(cfg.chares_x, cfg.chares_y, true)
}

/// Initial chare→PE mapping per the paper's striped/quad modes (public
/// so the distributed driver seeds its replicas identically).
pub fn initial_mapping(cfg: &PicConfig) -> Vec<u32> {
    crate::apps::grid_mapping(cfg.chares_x, cfg.chares_y, cfg.topo.n_pes(), cfg.decomp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::app::step_once;

    fn small_cfg() -> PicConfig {
        PicConfig {
            grid: 64,
            n_particles: 2_000,
            k: 1,
            m: 1,
            init: InitMode::Geometric { rho: 0.9 },
            chares_x: 4,
            chares_y: 4,
            decomp: Decomposition::Striped,
            topo: Topology::flat(4),
            q: 1.0,
            seed: 11,
            particle_bytes: 48.0,
            threads: 4,
        }
    }

    #[test]
    fn init_and_binning() {
        let app = PicApp::new(small_cfg(), Backend::Native).unwrap();
        assert_eq!(app.state.len(), 2_000);
        let counts = app.chare_particle_counts();
        assert_eq!(counts.iter().sum::<u32>(), 2_000);
        // geometric: left column of chares holds the most
        let left: u32 = (0..4).map(|cy| counts[cy * 4]).sum();
        let right: u32 = (0..4).map(|cy| counts[cy * 4 + 3]).sum();
        assert!(left > right, "left {left} right {right}");
    }

    #[test]
    fn striped_mapping_is_column_major() {
        let app = PicApp::new(small_cfg(), Backend::Native).unwrap();
        // chares in column 0 (cx=0) map to the first PE(s)
        assert_eq!(app.chare_to_pe[0], 0);
        assert_eq!(app.chare_to_pe[4], 0); // (cx=0, cy=1)
        // last column maps to the last PE
        assert_eq!(app.chare_to_pe[15], 3);
    }

    #[test]
    fn steps_move_particles_and_record_traffic() {
        let mut app = PicApp::new(small_cfg(), Backend::Native).unwrap();
        let mut crossers = 0;
        for _ in 0..8 {
            crossers += step_once(&mut app).unwrap().events;
        }
        // displacement 3 cells/step, chare width 16 -> crossings happen
        assert!(crossers > 0);
        let inst = app.build_instance();
        assert!(inst.graph.edge_count() > 0);
        assert!(inst.validate().is_ok());
    }

    #[test]
    fn step_fills_crossing_records() {
        let mut app = PicApp::new(small_cfg(), Backend::Native).unwrap();
        let mut ctx = StepCtx::default();
        let mut records = 0usize;
        for _ in 0..6 {
            ctx.moved.clear();
            let stats = App::step(&mut app, &mut ctx).unwrap();
            assert_eq!(ctx.moved.len(), stats.events, "one record per crosser");
            records += ctx.moved.len();
            let n = app.n_chares() as u32;
            assert!(ctx.moved.iter().all(|&(f, t, b)| f < n && t < n && b == 48.0));
        }
        assert!(records > 0);
    }

    #[test]
    fn verification_through_lb_migrations() {
        let mut app = PicApp::new(small_cfg(), Backend::Native).unwrap();
        for i in 0..10 {
            step_once(&mut app).unwrap();
            if i % 3 == 2 {
                // shuffle chares across PEs; particle physics must be
                // unaffected by placement
                let inst = app.build_instance();
                let asg = crate::strategies::make(
                    "greedy-refine",
                    crate::strategies::StrategyParams::default(),
                )
                .unwrap()
                .rebalance(&inst);
                app.apply_assignment(&asg);
            }
        }
        PicApp::verify(&app).expect("verification failed");
    }

    #[test]
    fn quad_mapping_tiles() {
        let mut cfg = small_cfg();
        cfg.decomp = Decomposition::Tiled;
        let app = PicApp::new(cfg, Backend::Native).unwrap();
        // 2x2 PE grid over 4x4 chares: chare (0,0) and (1,1) same PE
        assert_eq!(app.chare_to_pe[0], app.chare_to_pe[5]);
        assert_ne!(app.chare_to_pe[0], app.chare_to_pe[3]);
    }

    #[test]
    fn instance_sizes_reflect_particles() {
        let mut app = PicApp::new(small_cfg(), Backend::Native).unwrap();
        step_once(&mut app).unwrap();
        let counts = app.chare_particle_counts();
        let inst = app.build_instance();
        for (c, &cnt) in counts.iter().enumerate() {
            assert_eq!(inst.sizes[c], cnt as f64 * 48.0);
        }
    }

    #[test]
    fn work_matches_particle_counts() {
        let mut app = PicApp::new(small_cfg(), Backend::Native).unwrap();
        step_once(&mut app).unwrap();
        let mut work = Vec::new();
        app.work(&mut work);
        let counts = app.chare_particle_counts();
        assert_eq!(work.len(), counts.len());
        for (w, &c) in work.iter().zip(&counts) {
            assert_eq!(*w, c as f64);
        }
    }
}
