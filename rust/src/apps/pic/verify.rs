//! PRK-style analytic verification (Georganas et al. §III): after `s`
//! steps every particle must sit at
//! `x0 + s·(2k+1) mod L`, `y0 + s·m mod L` within epsilon. Because the
//! check covers every particle, it catches any corruption introduced by
//! the chare/LB machinery (lost particles, double pushes, bad
//! migrations) — it is the paper-level end-to-end correctness signal.

const EPSILON: f64 = 1e-6;

/// Verify all particle positions; returns the first failure rendered.
#[allow(clippy::too_many_arguments)]
pub fn verify_positions(
    x0: &[f64],
    y0: &[f64],
    x: &[f64],
    y: &[f64],
    steps: usize,
    k: u32,
    m: u32,
    l: f64,
) -> Result<(), String> {
    if x0.len() != x.len() || y0.len() != y.len() || x.len() != y.len() {
        return Err(format!(
            "particle count changed: started {} now {}",
            x0.len(),
            x.len()
        ));
    }
    let dx = steps as f64 * (2 * k + 1) as f64;
    let dy = steps as f64 * m as f64;
    for i in 0..x.len() {
        let ex = (x0[i] + dx).rem_euclid(l);
        let ey = (y0[i] + dy).rem_euclid(l);
        // compare on the torus (wrap-around distance)
        let ddx = torus_dist(x[i], ex, l);
        let ddy = torus_dist(y[i], ey, l);
        if ddx > EPSILON || ddy > EPSILON {
            return Err(format!(
                "particle {i}: at ({}, {}) expected ({ex}, {ey}) after {steps} steps",
                x[i], y[i]
            ));
        }
    }
    Ok(())
}

#[inline]
fn torus_dist(a: f64, b: f64, l: f64) -> f64 {
    let d = (a - b).abs();
    d.min(l - d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepts_exact_motion() {
        let x0 = vec![1.5, 10.5];
        let y0 = vec![2.5, 3.5];
        let x = vec![(1.5f64 + 2.0 * 5.0).rem_euclid(16.0), (10.5f64 + 10.0).rem_euclid(16.0)];
        let y = vec![(2.5f64 + 2.0).rem_euclid(16.0), (3.5f64 + 2.0).rem_euclid(16.0)];
        verify_positions(&x0, &y0, &x, &y, 2, 2, 1, 16.0).unwrap();
    }

    #[test]
    fn rejects_wrong_position() {
        let r = verify_positions(&[1.5], &[1.5], &[3.0], &[2.5], 1, 0, 1, 16.0);
        assert!(r.is_err());
    }

    #[test]
    fn rejects_lost_particles() {
        let r = verify_positions(&[1.5, 2.5], &[1.5, 2.5], &[2.5], &[2.5], 1, 0, 1, 16.0);
        assert!(r.unwrap_err().contains("count changed"));
    }

    #[test]
    fn wraparound_compare() {
        // expected lands at 15.9999999 but particle reports 0.0000001-ish
        let r = verify_positions(&[15.5], &[0.5], &[0.49999999], &[1.5], 1, 0, 1, 16.0);
        // x0 + 1 = 0.5 (mod 16): torus distance tiny
        assert!(r.is_ok(), "{r:?}");
    }
}
