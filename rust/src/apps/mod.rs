//! Applications exercising the load balancer, all behind the [`App`]
//! trait: the synthetic stencil workload (paper §V), the PIC PRK
//! benchmark (paper §VI), streamline particle advection ([`advect`],
//! after Demiralp et al.), and a drifting load hotspot ([`hotspot`],
//! the adversarial case for stale assignments) — plus the generic
//! iterative driver ([`driver::run_app`]) that schedules LB and
//! accounts time for every one of them.

pub mod advect;
pub mod app;
pub mod driver;
pub mod hotspot;
pub mod pic;
pub mod stencil;

pub use app::{step_once, App, StepCtx, StepStats};

use self::stencil::Decomposition;

/// Workload names accepted by
/// [`app_from_config`](crate::coordinator::app_from_config) (and the
/// CLI's `--app` / config `app.kind`) — the application registry
/// mirroring [`strategies::AVAILABLE`](crate::strategies::AVAILABLE).
pub const AVAILABLE_APPS: &[&str] = &["pic", "stencil", "advect", "hotspot"];

/// Adjacent object pairs of an `nx x ny` grid (8-neighborhood), each
/// once with `a < b`. With `periodic` the grid wraps (the PIC PRK
/// chare mesh); without, boundary objects simply have fewer neighbors
/// (the advection block mesh — its flow never exits the domain).
pub fn grid_neighbor_pairs(nx: usize, ny: usize, periodic: bool) -> Vec<(u32, u32)> {
    let (cx, cy) = (nx as i64, ny as i64);
    let mut pairs = Vec::with_capacity((cx * cy * 4) as usize);
    for y in 0..cy {
        for x in 0..cx {
            let a = (y * cx + x) as u32;
            for (dx, dy) in [(1i64, 0i64), (0, 1), (1, 1), (1, -1)] {
                let (nxp, nyp) = if periodic {
                    ((x + dx).rem_euclid(cx), (y + dy).rem_euclid(cy))
                } else {
                    let (px, py) = (x + dx, y + dy);
                    if px < 0 || px >= cx || py < 0 || py >= cy {
                        continue;
                    }
                    (px, py)
                };
                let b = (nyp * cx + nxp) as u32;
                if a != b {
                    pairs.push((a.min(b), a.max(b)));
                }
            }
        }
    }
    pairs.sort_unstable();
    pairs.dedup();
    pairs
}

/// Initial object→PE mapping of an `nx x ny` object grid per the
/// paper's striped/quad modes — shared by PIC chares and advection
/// blocks (public so the distributed driver seeds its replicas
/// identically).
pub fn grid_mapping(nx: usize, ny: usize, n_pes: usize, decomp: Decomposition) -> Vec<u32> {
    let n_objs = nx * ny;
    match decomp {
        // column-major order striping: high inter-PE traffic as
        // particles sweep rightward (paper §VI-A)
        Decomposition::Striped => (0..n_objs)
            .map(|c| {
                let cx = c % nx;
                let cy = c / nx;
                let cm = cx * ny + cy;
                ((cm * n_pes) / n_objs) as u32
            })
            .collect(),
        Decomposition::Tiled => {
            // choose the px x py factorization of n_pes whose aspect
            // ratio best matches the object grid, then tile
            // proportionally (no divisibility requirement)
            let want = nx as f64 / ny as f64;
            let mut best = (n_pes, 1usize);
            let mut best_err = f64::INFINITY;
            for px in 1..=n_pes {
                if n_pes % px != 0 || px > nx {
                    continue;
                }
                let py = n_pes / px;
                if py > ny {
                    continue;
                }
                let err = ((px as f64 / py as f64).ln() - want.ln()).abs();
                if err < best_err {
                    best_err = err;
                    best = (px, py);
                }
            }
            let (px, py) = best;
            (0..n_objs)
                .map(|c| {
                    let cx = c % nx;
                    let cy = c / nx;
                    let tx = (cx * px / nx).min(px - 1);
                    let ty = (cy * py / ny).min(py - 1);
                    (ty * px + tx) as u32
                })
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn periodic_grid_pairs_match_expected_degree() {
        // 4x4 periodic 8-neighborhood: every object touches 8 others,
        // each pair once -> 16 * 8 / 2 = 64 pairs
        let pairs = grid_neighbor_pairs(4, 4, true);
        assert_eq!(pairs.len(), 64);
        assert!(pairs.iter().all(|&(a, b)| a < b && b < 16));
    }

    #[test]
    fn open_grid_pairs_drop_boundary_wraps() {
        let open = grid_neighbor_pairs(4, 4, false);
        let periodic = grid_neighbor_pairs(4, 4, true);
        assert!(open.len() < periodic.len());
        // corner object 0 has exactly 3 neighbors in an open grid
        let deg0 = open.iter().filter(|&&(a, b)| a == 0 || b == 0).count();
        assert_eq!(deg0, 3);
    }

    #[test]
    fn striped_mapping_covers_all_pes() {
        let m = grid_mapping(8, 8, 4, Decomposition::Striped);
        assert_eq!(m.len(), 64);
        for pe in 0..4u32 {
            assert!(m.contains(&pe), "PE {pe} empty");
        }
    }
}
