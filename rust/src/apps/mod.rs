//! Applications exercising the load balancer: the synthetic stencil
//! workload generators (paper §V) and the PIC PRK benchmark (paper
//! §VI), plus the iterative driver that schedules LB and accounts time.

pub mod driver;
pub mod pic;
pub mod stencil;
