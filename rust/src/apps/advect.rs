//! Streamline particle advection with flow-dependent per-block cost —
//! the distributed-particle-advection workload of Demiralp et al.
//! (arXiv:2208.07553) as an [`App`].
//!
//! A steady, incompressible double-gyre flow over the square domain
//! `[0, L)²` carries tracer particles along streamlines. The domain is
//! split into `blocks_x x blocks_y` blocks (the migratable objects);
//! a particle's integration cost depends on the local flow speed (fast
//! regions need more substeps — the adaptive step-size refinement real
//! tracers pay), so per-block cost is *flow-dependent*, not just a
//! particle count. Particles are seeded as a blob inside one gyre and
//! orbit it forever: the load peak circulates through the block grid,
//! blocks keep exchanging particles, and the communication graph stays
//! persistent — exactly the regime the diffusion balancer targets.
//!
//! The flow is tangent to every domain boundary (stream function
//! `ψ = A·sin(2πx/L)·sin(πy/L)` vanishes on the walls), so particles
//! never leave the domain; [`App::verify`] checks conservation.

use std::time::Instant;

use anyhow::Result;

use crate::apps::app::{App, StepCtx, StepStats};
use crate::apps::stencil::Decomposition;
use crate::model::{Assignment, CommGraph, Instance, Topology, TrafficRecorder};
use crate::util::rng::Rng;

/// Bytes charged per block-pair sync message per step.
pub const SYNC_BYTES: f64 = 16.0;

/// Advection workload configuration.
#[derive(Debug, Clone)]
pub struct AdvectConfig {
    /// Square domain side L; positions live in [0, L).
    pub domain: f64,
    pub blocks_x: usize,
    pub blocks_y: usize,
    pub n_particles: usize,
    /// Base integration step per app iteration.
    pub dt: f64,
    /// Flow amplitude A (peak speed is 2A).
    pub amplitude: f64,
    /// Cost cap: particles in the fastest flow integrate with this many
    /// substeps; slow regions use 1.
    pub max_substeps: u32,
    /// Initial block → PE decomposition.
    pub decomp: Decomposition,
    pub topo: Topology,
    pub seed: u64,
    /// Bytes to move one particle between blocks (comm accounting).
    pub particle_bytes: f64,
}

impl Default for AdvectConfig {
    fn default() -> Self {
        AdvectConfig {
            domain: 1.0,
            blocks_x: 8,
            blocks_y: 8,
            n_particles: 20_000,
            dt: 0.02,
            amplitude: 1.0,
            max_substeps: 4,
            decomp: Decomposition::Striped,
            topo: Topology::flat(4),
            seed: 0xADEC7,
            particle_bytes: 32.0,
        }
    }
}

/// Double-gyre velocity at (x, y): `u = ∂ψ/∂y`, `v = -∂ψ/∂x` for
/// `ψ = A·(L/π)·sin(2πx/L)·sin(πy/L)` (the L/π factor folded so speeds
/// are O(A)). Incompressible; tangent to all four walls.
#[inline]
pub fn velocity(l: f64, a: f64, x: f64, y: f64) -> (f64, f64) {
    let px = 2.0 * std::f64::consts::PI * x / l;
    let py = std::f64::consts::PI * y / l;
    (a * px.sin() * py.cos(), -2.0 * a * px.cos() * py.sin())
}

/// Streamline advection as a first-class [`App`].
pub struct Advect {
    pub cfg: AdvectConfig,
    /// Particle positions.
    x: Vec<f64>,
    y: Vec<f64>,
    /// Current block of each particle.
    block_of: Vec<u32>,
    /// Current block → PE mapping.
    pub block_to_pe: Vec<u32>,
    /// Block↔block traffic since the last LB step.
    traffic: TrafficRecorder,
    comm_cache: CommGraph,
    neighbor_pairs: Vec<(u32, u32)>,
    steps_since_lb: usize,
    /// Per-block integration substeps of the latest step (the
    /// flow-dependent work signal).
    step_work: Vec<f64>,
    /// Per-block accumulated measured seconds since the last LB step.
    load_acc: Vec<f64>,
    pub steps_done: usize,
}

impl Advect {
    pub fn new(cfg: AdvectConfig) -> Result<Advect> {
        anyhow::ensure!(cfg.domain > 0.0, "domain must be positive");
        anyhow::ensure!(cfg.amplitude > 0.0, "amplitude must be positive");
        anyhow::ensure!(cfg.max_substeps >= 1, "max_substeps must be >= 1");
        anyhow::ensure!(cfg.blocks_x >= 1 && cfg.blocks_y >= 1, "empty block grid");
        let n_blocks = cfg.blocks_x * cfg.blocks_y;
        // Seed a Gaussian blob inside the left gyre (center L/4, L/2):
        // it orbits the gyre forever, dragging the load peak through
        // the block grid.
        let mut rng = Rng::new(cfg.seed);
        let (cx, cy) = (0.25 * cfg.domain, 0.5 * cfg.domain);
        let sigma = 0.1 * cfg.domain;
        let mut x = Vec::with_capacity(cfg.n_particles);
        let mut y = Vec::with_capacity(cfg.n_particles);
        while x.len() < cfg.n_particles {
            let px = cx + sigma * rng.normal();
            let py = cy + sigma * rng.normal();
            if (0.0..cfg.domain).contains(&px) && (0.0..cfg.domain).contains(&py) {
                x.push(px);
                y.push(py);
            }
        }
        let block_of: Vec<u32> =
            x.iter().zip(&y).map(|(&px, &py)| block_of_pos(&cfg, px, py)).collect();
        let block_to_pe =
            crate::apps::grid_mapping(cfg.blocks_x, cfg.blocks_y, cfg.topo.n_pes(), cfg.decomp);
        let neighbor_pairs = crate::apps::grid_neighbor_pairs(cfg.blocks_x, cfg.blocks_y, false);
        Ok(Advect {
            x,
            y,
            block_of,
            block_to_pe,
            traffic: TrafficRecorder::new(n_blocks),
            comm_cache: CommGraph::empty(n_blocks),
            neighbor_pairs,
            steps_since_lb: 0,
            step_work: vec![0.0; n_blocks],
            load_acc: vec![0.0; n_blocks],
            steps_done: 0,
            cfg,
        })
    }

    pub fn n_blocks(&self) -> usize {
        self.cfg.blocks_x * self.cfg.blocks_y
    }

    /// Substep count for a particle at (x, y): 1 in still flow up to
    /// `max_substeps` at peak speed (2A) — deterministic in position.
    #[inline]
    fn substeps(&self, x: f64, y: f64) -> u32 {
        let (u, v) = velocity(self.cfg.domain, self.cfg.amplitude, x, y);
        let speed = (u * u + v * v).sqrt();
        let frac = (speed / (2.0 * self.cfg.amplitude)).min(1.0);
        1 + (frac * (self.cfg.max_substeps - 1) as f64).round() as u32
    }

    pub fn block_particle_counts(&self) -> Vec<u32> {
        let mut counts = vec![0u32; self.n_blocks()];
        for &b in &self.block_of {
            counts[b as usize] += 1;
        }
        counts
    }
}

/// Block owning position (x, y) under `cfg` — free function, mirroring
/// [`crate::apps::pic::chare_of_pos`].
#[inline]
pub fn block_of_pos(cfg: &AdvectConfig, x: f64, y: f64) -> u32 {
    let bw = cfg.domain / cfg.blocks_x as f64;
    let bh = cfg.domain / cfg.blocks_y as f64;
    let bx = ((x / bw) as usize).min(cfg.blocks_x - 1);
    let by = ((y / bh) as usize).min(cfg.blocks_y - 1);
    (by * cfg.blocks_x + bx) as u32
}

impl App for Advect {
    fn name(&self) -> &'static str {
        "advect"
    }

    fn topo(&self) -> Topology {
        self.cfg.topo.clone()
    }

    fn n_objects(&self) -> usize {
        self.n_blocks()
    }

    fn mapping(&self) -> &[u32] {
        &self.block_to_pe
    }

    fn neighbor_pairs(&self) -> Vec<(u32, u32)> {
        self.neighbor_pairs.clone()
    }

    /// Integrate every particle one `dt` along its streamline with
    /// speed-adaptive substeps, re-bin block crossers, and attribute
    /// the measured step time over blocks by substep units.
    fn step(&mut self, ctx: &mut StepCtx) -> Result<StepStats> {
        let t = Instant::now(); // difflb-lint: allow(wall-clock): measured compute seconds feed the report, not the mapping
        let l = self.cfg.domain;
        let a = self.cfg.amplitude;
        let pb = self.cfg.particle_bytes;
        // positions stay in [0, L): the flow is wall-tangent, the clamp
        // only guards floating-point rounding at the boundary
        let hi = l * (1.0 - 1e-12);
        self.step_work.iter_mut().for_each(|w| *w = 0.0);
        let mut events = 0usize;
        for i in 0..self.x.len() {
            let (mut px, mut py) = (self.x[i], self.y[i]);
            let n = self.substeps(px, py);
            let h = self.cfg.dt / n as f64;
            for _ in 0..n {
                let (u, v) = velocity(l, a, px, py);
                px += u * h;
                py += v * h;
            }
            px = px.clamp(0.0, hi);
            py = py.clamp(0.0, hi);
            self.x[i] = px;
            self.y[i] = py;
            let nb = block_of_pos(&self.cfg, px, py);
            let ob = self.block_of[i];
            if nb != ob {
                events += 1;
                self.traffic.record(ob, nb, pb);
                ctx.moved.push((ob, nb, pb));
                self.block_of[i] = nb;
            }
            self.step_work[nb as usize] += n as f64;
        }
        let compute_s = t.elapsed().as_secs_f64();

        // Load attribution: measured step time split by substep units.
        let total: f64 = self.step_work.iter().sum();
        let per_unit = compute_s / total.max(1.0);
        for (b, &w) in self.step_work.iter().enumerate() {
            if w > 0.0 {
                self.load_acc[b] += w * per_unit;
            }
        }
        self.steps_done += 1;
        self.steps_since_lb += 1;
        Ok(StepStats { compute_s, events })
    }

    fn work(&self, out: &mut Vec<f64>) {
        out.clear();
        out.extend_from_slice(&self.step_work);
    }

    /// Snapshot the LB problem: sync traffic for the elapsed steps,
    /// incremental comm-graph refresh, measured loads with a
    /// substep-unit fallback — the same sequence as the PIC instance
    /// assembly.
    fn build_instance(&mut self) -> Instance {
        let n_blocks = self.n_blocks();
        for &(a, b) in &self.neighbor_pairs {
            self.traffic.record(a, b, SYNC_BYTES * self.steps_since_lb as f64);
        }
        self.comm_cache.update_from_recorder(&mut self.traffic);
        let graph = self.comm_cache.clone();
        let measured: f64 = self.load_acc.iter().sum();
        let loads: Vec<f64> = if measured > 0.0 {
            self.load_acc.clone()
        } else {
            self.step_work.clone()
        };
        let bw = self.cfg.domain / self.cfg.blocks_x as f64;
        let bh = self.cfg.domain / self.cfg.blocks_y as f64;
        let coords: Vec<[f64; 2]> = (0..n_blocks)
            .map(|b| {
                let bx = (b % self.cfg.blocks_x) as f64;
                let by = (b / self.cfg.blocks_x) as f64;
                [bx * bw + bw / 2.0, by * bh + bh / 2.0]
            })
            .collect();
        let mut inst =
            Instance::new(loads, coords, graph, self.block_to_pe.clone(), self.cfg.topo.clone());
        inst.sizes = self
            .block_particle_counts()
            .iter()
            .map(|&c| c as f64 * self.cfg.particle_bytes)
            .collect();
        self.steps_since_lb = 0;
        self.load_acc.iter_mut().for_each(|l| *l = 0.0);
        inst
    }

    fn apply(&mut self, asg: &Assignment) -> f64 {
        assert_eq!(asg.mapping.len(), self.n_blocks());
        let counts = self.block_particle_counts();
        let mut bytes = 0.0;
        for (b, (&new_pe, old_pe)) in asg.mapping.iter().zip(&self.block_to_pe).enumerate() {
            if new_pe != *old_pe {
                bytes += counts[b] as f64 * self.cfg.particle_bytes;
            }
        }
        self.block_to_pe = asg.mapping.clone();
        bytes
    }

    /// Conservation check: every particle still inside the domain and
    /// binned to the block that owns its position.
    fn verify(&self) -> std::result::Result<(), String> {
        if self.x.len() != self.cfg.n_particles {
            return Err(format!(
                "particle count changed: {} != {}",
                self.x.len(),
                self.cfg.n_particles
            ));
        }
        for i in 0..self.x.len() {
            let (px, py) = (self.x[i], self.y[i]);
            if !(0.0..self.cfg.domain).contains(&px) || !(0.0..self.cfg.domain).contains(&py) {
                return Err(format!("particle {i} escaped the domain: ({px}, {py})"));
            }
            if self.block_of[i] != block_of_pos(&self.cfg, px, py) {
                return Err(format!("particle {i} mis-binned"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::app::step_once;
    use crate::apps::driver::{run_app, DriverConfig};
    use crate::strategies::{make, StrategyParams};

    fn small_cfg() -> AdvectConfig {
        AdvectConfig {
            n_particles: 3_000,
            blocks_x: 6,
            blocks_y: 6,
            topo: Topology::flat(4),
            seed: 3,
            ..Default::default()
        }
    }

    #[test]
    fn particles_stay_in_domain_and_cross_blocks() {
        let mut app = Advect::new(small_cfg()).unwrap();
        let mut crossings = 0;
        for _ in 0..30 {
            crossings += step_once(&mut app).unwrap().events;
        }
        assert!(crossings > 0, "blob never crossed a block boundary");
        App::verify(&app).expect("conservation violated");
    }

    #[test]
    fn cost_is_flow_dependent() {
        let mut app = Advect::new(small_cfg()).unwrap();
        step_once(&mut app).unwrap();
        let mut work = Vec::new();
        app.work(&mut work);
        let counts = app.block_particle_counts();
        // work units exceed raw counts wherever flow forces substeps
        let total_work: f64 = work.iter().sum();
        let total_counts: f64 = counts.iter().map(|&c| c as f64).sum();
        assert!(total_work > total_counts, "{total_work} !> {total_counts}");
        // and no empty block carries work
        for (b, &w) in work.iter().enumerate() {
            assert_eq!(w > 0.0, counts[b] > 0, "block {b}");
        }
    }

    #[test]
    fn instance_is_valid_and_lb_round_trips() {
        let mut app = Advect::new(small_cfg()).unwrap();
        for _ in 0..5 {
            step_once(&mut app).unwrap();
        }
        let inst = app.build_instance();
        assert!(inst.validate().is_ok());
        assert!(inst.graph.edge_count() > 0);
        let asg = make("greedy-refine", StrategyParams::default())
            .unwrap()
            .rebalance(&inst);
        let bytes = app.apply(&asg);
        assert!(bytes >= 0.0);
        App::verify(&app).expect("LB corrupted the particles");
    }

    #[test]
    fn runs_under_the_generic_driver() {
        let mut app = Advect::new(small_cfg()).unwrap();
        let strat = make("diff-comm", StrategyParams::default()).unwrap();
        let cfg = DriverConfig { iters: 8, lb_period: 4, ..Default::default() };
        let rep = run_app(&mut app, strat.as_ref(), &cfg).unwrap();
        assert_eq!(rep.records.len(), 8);
        assert!(rep.verified);
    }

    #[test]
    fn velocity_is_wall_tangent() {
        for t in 0..=10 {
            let s = t as f64 / 10.0;
            let (_, v0) = velocity(1.0, 1.0, s, 0.0);
            let (_, v1) = velocity(1.0, 1.0, s, 1.0);
            assert!(v0.abs() < 1e-12 && v1.abs() < 1e-12, "flow exits y-wall");
            let (u0, _) = velocity(1.0, 1.0, 0.0, s);
            let (u1, _) = velocity(1.0, 1.0, 1.0, s);
            assert!(u0.abs() < 1e-12 && u1.abs() < 1e-12, "flow exits x-wall");
        }
    }
}
