//! Synthetic stencil workload generators + imbalance injectors — the
//! paper's simulation-study workloads (§I Fig 1-2, §V Tables I-II).
//!
//! Generators produce [`Instance`]s: objects are stencil cells (2D
//! 5-point or 3D 7-point, periodic), edges carry per-iteration halo
//! bytes, coordinates are grid positions, and the initial mapping is a
//! tiled ("quad"), striped, or ring decomposition. Injectors then
//! perturb per-object loads the way each experiment prescribes.

use anyhow::Result;

use crate::apps::app::{App, StepCtx, StepStats};
use crate::model::{Assignment, CommGraph, Instance, Topology, TrafficRecorder};
use crate::util::rng::Rng;

/// Bytes exchanged per stencil edge per LB period (arbitrary but
/// consistent unit — the paper reports ratios).
pub const HALO_BYTES: f64 = 64.0;

/// How objects are initially laid out over PEs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decomposition {
    /// Contiguous 2D tiles (the paper's "quad"/tiled mapping).
    Tiled,
    /// Column-major stripes (the paper's striped mapping).
    Striped,
}

/// 2D periodic 5-point stencil over `side x side` objects mapped onto
/// `px x py` PEs.
pub fn stencil_2d(side: usize, px: usize, py: usize, decomp: Decomposition) -> Instance {
    assert!(side % px == 0 && side % py == 0, "side must divide PE grid");
    let n = side * side;
    let mut edges = Vec::with_capacity(2 * n);
    for r in 0..side {
        for c in 0..side {
            let o = (r * side + c) as u32;
            edges.push((o, (r * side + (c + 1) % side) as u32, HALO_BYTES));
            edges.push((o, (((r + 1) % side) * side + c) as u32, HALO_BYTES));
        }
    }
    let graph = CommGraph::from_edges(n, &edges);
    let coords: Vec<[f64; 2]> =
        (0..n).map(|i| [(i % side) as f64, (i / side) as f64]).collect();
    let tile_w = side / px;
    let tile_h = side / py;
    let mapping: Vec<u32> = (0..n)
        .map(|i| {
            let (c, r) = (i % side, i / side);
            match decomp {
                Decomposition::Tiled => ((r / tile_h) * px + c / tile_w) as u32,
                Decomposition::Striped => ((c * px * py) / side) as u32,
            }
        })
        .collect();
    Instance::new(vec![1.0; n], coords, graph, mapping, Topology::flat(px * py))
}

/// 3D periodic 7-point stencil over `side^3` objects on `n_pes` PEs
/// (slab decomposition along z) — Table II's workload.
pub fn stencil_3d(side: usize, n_pes: usize) -> Instance {
    let n = side * side * side;
    let idx = |x: usize, y: usize, z: usize| (z * side * side + y * side + x) as u32;
    let mut edges = Vec::with_capacity(3 * n);
    for z in 0..side {
        for y in 0..side {
            for x in 0..side {
                let o = idx(x, y, z);
                edges.push((o, idx((x + 1) % side, y, z), HALO_BYTES));
                edges.push((o, idx(x, (y + 1) % side, z), HALO_BYTES));
                edges.push((o, idx(x, y, (z + 1) % side), HALO_BYTES));
            }
        }
    }
    let graph = CommGraph::from_edges(n, &edges);
    // 2D coords for the coordinate variant: project (x + side*z_frac, y).
    let coords: Vec<[f64; 2]> = (0..n)
        .map(|i| {
            let x = i % side;
            let y = (i / side) % side;
            let z = i / (side * side);
            [x as f64 + (z as f64) * side as f64, y as f64]
        })
        .collect();
    let per_pe = n.div_ceil(n_pes);
    let mapping: Vec<u32> = (0..n).map(|i| (i / per_pe) as u32).collect();
    Instance::new(vec![1.0; n], coords, graph, mapping, Topology::flat(n_pes))
}

/// 1D ring of objects striped over a ring of PEs — Table I's setup.
pub fn ring(n_pes: usize, objs_per_pe: usize) -> Instance {
    let n = n_pes * objs_per_pe;
    let edges: Vec<(u32, u32, f64)> =
        (0..n as u32).map(|o| (o, (o + 1) % n as u32, HALO_BYTES)).collect();
    let graph = CommGraph::from_edges(n, &edges);
    let coords: Vec<[f64; 2]> = (0..n).map(|i| [i as f64, 0.0]).collect();
    let mapping: Vec<u32> = (0..n).map(|i| (i / objs_per_pe) as u32).collect();
    Instance::new(vec![1.0; n], coords, graph, mapping, Topology::flat(n_pes))
}

// ------------------------------------------------------- stepping sim

/// Round-based stencil workload as an [`App`]: each step re-rolls the
/// per-object load noise and re-records the halo traffic; each LB
/// round ([`App::build_instance`]) folds that traffic into the
/// instance's communication graph **incrementally**
/// ([`CommGraph::update_from_recorder`]). A stencil's adjacency is
/// static, so after the first round every refresh takes the
/// weights-only fast path — the "communication graph of persistently
/// interacting objects changes slowly" pattern the incremental rebuild
/// exists for, exercised here and measured in `benches/perf_hotpaths`.
/// The ad-hoc advance/rebalance loop this struct used to run privately
/// is gone: the generic driver owns the loop now.
pub struct StencilSim {
    pub inst: Instance,
    recorder: TrafficRecorder,
    rng: Rng,
    noise: f64,
    /// Steps taken (one load re-roll + halo record per step).
    pub rounds: usize,
    /// Whether the last graph refresh changed the CSR structure
    /// (always `false` for a static stencil after round one — the
    /// weights-only fast path under test).
    pub graph_changed: bool,
    /// Unordered (a < b) halo pairs, cached from the static adjacency.
    pairs: Vec<(u32, u32)>,
}

impl StencilSim {
    pub fn new(
        side: usize,
        px: usize,
        py: usize,
        decomp: Decomposition,
        noise: f64,
        seed: u64,
    ) -> StencilSim {
        let inst = stencil_2d(side, px, py, decomp);
        let pairs = halo_pairs(&inst.graph);
        StencilSim {
            recorder: TrafficRecorder::new(inst.n_objects()),
            inst,
            rng: Rng::new(seed),
            noise,
            rounds: 0,
            graph_changed: false,
            pairs,
        }
    }

    /// Fold the recorded halo traffic into the instance's graph in
    /// place (the incremental-refresh hot path, benched on its own in
    /// `perf_hotpaths`). Returns whether the structure changed.
    pub fn refresh_graph(&mut self) -> bool {
        self.graph_changed = self.inst.graph.update_from_recorder(&mut self.recorder);
        self.graph_changed
    }
}

/// Unordered (a < b) edge list of a static comm graph — the stencil's
/// sync-message partners.
fn halo_pairs(graph: &CommGraph) -> Vec<(u32, u32)> {
    let mut pairs = Vec::with_capacity(graph.edge_count());
    for a in 0..graph.n {
        for &b in graph.neighbors(a) {
            if (a as u32) < b {
                pairs.push((a as u32, b));
            }
        }
    }
    pairs
}

impl App for StencilSim {
    fn name(&self) -> &'static str {
        "stencil"
    }

    fn topo(&self) -> Topology {
        self.inst.topo.clone()
    }

    fn n_objects(&self) -> usize {
        self.inst.n_objects()
    }

    fn mapping(&self) -> &[u32] {
        &self.inst.mapping
    }

    fn neighbor_pairs(&self) -> Vec<(u32, u32)> {
        self.pairs.clone()
    }

    /// One stencil round: re-roll the per-object load noise (one
    /// deterministic rng draw per object, in object order) and exchange
    /// one halo payload per edge, recorded both for the LB instance's
    /// comm graph and as this step's crossing records.
    fn step(&mut self, ctx: &mut StepCtx) -> Result<StepStats> {
        let t = std::time::Instant::now(); // difflb-lint: allow(wall-clock): measured compute seconds feed the report, not the mapping
        for l in self.inst.loads.iter_mut() {
            *l = 1.0 + self.noise * (2.0 * self.rng.f64() - 1.0);
        }
        for &(a, b) in &self.pairs {
            self.recorder.record(a, b, HALO_BYTES);
            ctx.moved.push((a, b, HALO_BYTES));
        }
        self.rounds += 1;
        Ok(StepStats { compute_s: t.elapsed().as_secs_f64(), events: self.pairs.len() })
    }

    fn work(&self, out: &mut Vec<f64>) {
        out.clear();
        out.extend_from_slice(&self.inst.loads);
    }

    fn build_instance(&mut self) -> Instance {
        self.refresh_graph();
        // The owned return is a flat memcpy of the live instance — an
        // O(objects + edges) copy the pre-trait loop didn't pay, but
        // still far below the rebalance it feeds (the driver also
        // mutates `loads` under `deterministic_loads`, so it needs its
        // own copy). Revisit only if profiles ever show otherwise.
        self.inst.clone()
    }

    /// Adopt a strategy's assignment as the next round's mapping;
    /// migration payload is the instance's per-object sizes.
    fn apply(&mut self, asg: &Assignment) -> f64 {
        assert_eq!(asg.mapping.len(), self.inst.n_objects());
        let mut bytes = 0.0;
        for (o, (&new_pe, &old_pe)) in asg.mapping.iter().zip(&self.inst.mapping).enumerate() {
            if new_pe != old_pe {
                bytes += self.inst.sizes[o];
            }
        }
        self.inst.mapping.clone_from(&asg.mapping);
        bytes
    }
}

// ------------------------------------------------------- imbalance

/// Uniform ±`noise` multiplicative random perturbation per object.
pub fn inject_noise(inst: &mut Instance, noise: f64, seed: u64) {
    let mut rng = Rng::new(seed);
    for l in inst.loads.iter_mut() {
        *l *= 1.0 + noise * (2.0 * rng.f64() - 1.0);
    }
}

/// Fig 2's exact perturbation: each object's load is "randomly
/// increased or decreased by 40%" — a fair coin between `1+noise` and
/// `1-noise`.
pub fn inject_noise_binary(inst: &mut Instance, noise: f64, seed: u64) {
    let mut rng = Rng::new(seed);
    for l in inst.loads.iter_mut() {
        *l *= if rng.chance(0.5) { 1.0 + noise } else { 1.0 - noise };
    }
}

/// Table I's single heavily-overloaded processor: all objects on `pe`
/// get `factor`× load.
pub fn overload_pe(inst: &mut Instance, pe: u32, factor: f64) {
    for (o, l) in inst.loads.iter_mut().enumerate() {
        if inst.mapping[o] == pe {
            *l *= factor;
        }
    }
}

/// Table II's pattern: every 1st and 2nd PE (mod 7) overloaded, every
/// 3rd (mod 7) underloaded.
pub fn inject_mod7(inst: &mut Instance, over: f64, under: f64) {
    for (o, l) in inst.loads.iter_mut().enumerate() {
        match inst.mapping[o] % 7 {
            1 | 2 => *l *= over,
            3 => *l *= under,
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::metrics;
    use crate::util::stats::Summary;

    #[test]
    fn stencil_2d_shape() {
        let inst = stencil_2d(16, 4, 4, Decomposition::Tiled);
        assert_eq!(inst.n_objects(), 256);
        // periodic 5-point: every object has degree 4
        for o in 0..inst.n_objects() {
            assert_eq!(inst.graph.degree(o), 4, "object {o}");
        }
        // tiled: each PE holds a contiguous 4x4 tile = 16 objects
        let loads = inst.pe_loads(&inst.mapping);
        assert!(loads.iter().all(|&l| l == 16.0));
    }

    #[test]
    fn tiled_beats_striped_locality() {
        let tiled = stencil_2d(16, 4, 4, Decomposition::Tiled);
        let striped = stencil_2d(16, 4, 4, Decomposition::Striped);
        let rt = metrics::comm_split_nodes(&tiled, &tiled.mapping).ratio();
        let rs = metrics::comm_split_nodes(&striped, &striped.mapping).ratio();
        assert!(rt < rs, "tiled {rt} !< striped {rs}");
    }

    #[test]
    fn stencil_3d_shape() {
        let inst = stencil_3d(8, 8);
        assert_eq!(inst.n_objects(), 512);
        for o in 0..inst.n_objects() {
            assert_eq!(inst.graph.degree(o), 6, "object {o}");
        }
        let loads = inst.pe_loads(&inst.mapping);
        assert!(loads.iter().all(|&l| l == 64.0));
    }

    #[test]
    fn ring_matches_table1_setup() {
        let mut inst = ring(10, 16);
        overload_pe(&mut inst, 0, 10.0);
        let s = Summary::of(&inst.pe_loads(&inst.mapping));
        // 10x on one of 10 PEs: max/avg = 10 / 1.9 ≈ 5.26 ("approximately five")
        assert!((s.max_avg_ratio() - 5.26).abs() < 0.1, "{}", s.max_avg_ratio());
    }

    #[test]
    fn injectors_change_only_loads() {
        let mut inst = stencil_2d(8, 2, 2, Decomposition::Tiled);
        let before = inst.mapping.clone();
        inject_noise(&mut inst, 0.4, 1);
        inject_mod7(&mut inst, 3.0, 0.3);
        assert_eq!(inst.mapping, before);
        assert!(inst.loads.iter().all(|&l| l > 0.0));
        assert!(inst.validate().is_ok());
    }

    #[test]
    fn stencil_sim_refreshes_incrementally() {
        let mut sim = StencilSim::new(12, 2, 2, Decomposition::Tiled, 0.4, 9);
        let structure = sim.inst.graph.clone();
        let mut ctx = crate::apps::StepCtx::default();
        for round in 0..4 {
            ctx.moved.clear();
            sim.step(&mut ctx).unwrap();
            let inst = sim.build_instance();
            assert!(!sim.graph_changed, "static stencil rebuilt CSR in round {round}");
            // structure intact, weights refreshed to one period of halo
            assert_eq!(inst.graph, structure);
            assert!(inst.validate().is_ok());
            assert!(inst.loads.iter().all(|&l| (0.6..=1.4).contains(&l)));
            // one crossing record per halo edge
            assert_eq!(ctx.moved.len(), inst.graph.edge_count());
        }
        assert_eq!(sim.rounds, 4);
        // an assignment round-trips into the next instance
        let asg = Assignment { mapping: vec![0; sim.inst.n_objects()] };
        sim.apply(&asg);
        assert!(sim.inst.mapping.iter().all(|&pe| pe == 0));
    }

    #[test]
    fn noise_is_bounded() {
        let mut inst = stencil_2d(8, 2, 2, Decomposition::Tiled);
        inject_noise(&mut inst, 0.4, 7);
        assert!(inst.loads.iter().all(|&l| (0.6..=1.4).contains(&l)));
    }
}
