//! Trend-aware drifting-hotspot workload (in the spirit of Boulmier et
//! al., arXiv:1909.07168): a Gaussian load peak drifts across a
//! periodic `nx x ny` object grid at a configurable velocity — the
//! **adversarial case for stale assignments**, because any mapping
//! balanced for the peak's position at LB time is wrong a few steps
//! later, and the faster the drift the shorter an assignment's useful
//! life. Static halo traffic between grid neighbors keeps the
//! communication term honest: a balancer that scatters the peak's
//! objects wins on load and loses on comm, exactly the trade-off the
//! paper's strategy navigates.
//!
//! Per-object load is **analytic in (object, step)** ([`load_at`]), so
//! a distributed node can compute its partition's loads without any
//! payload exchange — which is what makes this the second
//! node-partitionable app of `distributed::driver` (bit-identity with
//! the sequential driver asserted in `tests/distributed.rs`).

use std::time::Instant;

use anyhow::Result;

use crate::apps::app::{App, StepCtx, StepStats};
use crate::apps::stencil::Decomposition;
use crate::model::{Assignment, CommGraph, Instance, Topology, TrafficRecorder};

/// Hotspot workload configuration.
#[derive(Debug, Clone)]
pub struct HotspotConfig {
    pub nx: usize,
    pub ny: usize,
    /// Baseline per-object load.
    pub base: f64,
    /// Peak amplitude on top of the baseline.
    pub amp: f64,
    /// Peak width in object units.
    pub sigma: f64,
    /// Drift velocity in objects per step (torus wrap).
    pub vx: f64,
    pub vy: f64,
    /// Bytes exchanged per halo edge per step.
    pub halo_bytes: f64,
    /// Migration payload bytes per object.
    pub object_bytes: f64,
    pub decomp: Decomposition,
    pub topo: Topology,
}

impl Default for HotspotConfig {
    fn default() -> Self {
        HotspotConfig {
            nx: 16,
            ny: 16,
            base: 1.0,
            amp: 8.0,
            sigma: 2.5,
            vx: 0.35,
            vy: 0.2,
            halo_bytes: 64.0,
            object_bytes: 4096.0,
            decomp: Decomposition::Tiled,
            topo: Topology::flat(4),
        }
    }
}

impl HotspotConfig {
    /// Shared validation — both the sequential [`Hotspot::new`] and the
    /// distributed `HotspotDistApp::new` call this, so the two
    /// constructors cannot drift apart on what they accept (a zero
    /// sigma would turn [`load_at`] into NaN at the peak center).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.nx >= 2 && self.ny >= 2, "hotspot grid too small");
        anyhow::ensure!(self.sigma > 0.0, "sigma must be positive");
        anyhow::ensure!(self.base > 0.0, "base load must be positive");
        Ok(())
    }
}

/// Minimum-image displacement on a ring of circumference `n`.
#[inline]
fn torus_delta(d: f64, n: f64) -> f64 {
    let d = d.rem_euclid(n);
    if d > n / 2.0 {
        d - n
    } else {
        d
    }
}

/// Analytic load of object `obj` at step `step` — a pure function, so
/// sequential and distributed executions compute bit-identical values
/// from (config, object, step) alone.
pub fn load_at(cfg: &HotspotConfig, obj: usize, step: usize) -> f64 {
    let x = (obj % cfg.nx) as f64;
    let y = (obj / cfg.nx) as f64;
    let t = step as f64;
    let cx = (cfg.vx * t).rem_euclid(cfg.nx as f64);
    let cy = (cfg.vy * t).rem_euclid(cfg.ny as f64);
    let dx = torus_delta(x - cx, cfg.nx as f64);
    let dy = torus_delta(y - cy, cfg.ny as f64);
    cfg.base + cfg.amp * (-(dx * dx + dy * dy) / (2.0 * cfg.sigma * cfg.sigma)).exp()
}

/// The drifting hotspot as a first-class [`App`].
pub struct Hotspot {
    pub cfg: HotspotConfig,
    /// Current object → PE mapping.
    pub obj_to_pe: Vec<u32>,
    /// Per-object analytic loads of the latest step.
    work: Vec<f64>,
    /// Per-object accumulated measured seconds since the last LB step.
    load_acc: Vec<f64>,
    traffic: TrafficRecorder,
    comm_cache: CommGraph,
    /// Unordered (a < b) halo pairs (8-neighborhood, periodic).
    pairs: Vec<(u32, u32)>,
    pub steps_done: usize,
}

impl Hotspot {
    pub fn new(cfg: HotspotConfig) -> Result<Hotspot> {
        cfg.validate()?;
        let n = cfg.nx * cfg.ny;
        let obj_to_pe = crate::apps::grid_mapping(cfg.nx, cfg.ny, cfg.topo.n_pes(), cfg.decomp);
        let pairs = crate::apps::grid_neighbor_pairs(cfg.nx, cfg.ny, true);
        Ok(Hotspot {
            obj_to_pe,
            work: vec![cfg.base; n],
            load_acc: vec![0.0; n],
            traffic: TrafficRecorder::new(n),
            comm_cache: CommGraph::empty(n),
            pairs,
            steps_done: 0,
            cfg,
        })
    }

    pub fn n_objs(&self) -> usize {
        self.cfg.nx * self.cfg.ny
    }
}

/// Assemble the LB instance from per-object analytic loads and measured
/// seconds — the **single definition** both the sequential
/// [`App::build_instance`] and the distributed driver's root use, so
/// their instances match bit for bit (mirrors
/// [`crate::apps::pic::assemble_instance`]). The caller owns resetting
/// the measured loads.
pub fn assemble_instance(
    cfg: &HotspotConfig,
    work: &[f64],
    measured: &[f64],
    mapping: Vec<u32>,
    recorder: &mut TrafficRecorder,
    comm_cache: &mut CommGraph,
) -> Instance {
    let n = cfg.nx * cfg.ny;
    comm_cache.update_from_recorder(recorder);
    let graph = comm_cache.clone();
    let measured_total: f64 = measured.iter().sum();
    let loads: Vec<f64> =
        if measured_total > 0.0 { measured.to_vec() } else { work.to_vec() };
    let coords: Vec<[f64; 2]> =
        (0..n).map(|o| [(o % cfg.nx) as f64, (o / cfg.nx) as f64]).collect();
    let mut inst = Instance::new(loads, coords, graph, mapping, cfg.topo.clone());
    inst.sizes = vec![cfg.object_bytes; n];
    inst
}

impl App for Hotspot {
    fn name(&self) -> &'static str {
        "hotspot"
    }

    fn topo(&self) -> Topology {
        self.cfg.topo.clone()
    }

    fn n_objects(&self) -> usize {
        self.n_objs()
    }

    fn mapping(&self) -> &[u32] {
        &self.obj_to_pe
    }

    fn neighbor_pairs(&self) -> Vec<(u32, u32)> {
        self.pairs.clone()
    }

    /// One step: evaluate the drifted peak's loads (the compute phase —
    /// measured), exchange one halo payload per edge.
    fn step(&mut self, ctx: &mut StepCtx) -> Result<StepStats> {
        let t = Instant::now(); // difflb-lint: allow(wall-clock): measured compute seconds feed the report, not the mapping
        let step = self.steps_done;
        let mut total = 0.0;
        for o in 0..self.work.len() {
            let w = load_at(&self.cfg, o, step);
            self.work[o] = w;
            total += w;
        }
        let compute_s = t.elapsed().as_secs_f64();
        for &(a, b) in &self.pairs {
            self.traffic.record(a, b, self.cfg.halo_bytes);
            ctx.moved.push((a, b, self.cfg.halo_bytes));
        }
        let per_unit = compute_s / total.max(1.0);
        for (o, &w) in self.work.iter().enumerate() {
            self.load_acc[o] += w * per_unit;
        }
        self.steps_done += 1;
        Ok(StepStats { compute_s, events: self.pairs.len() })
    }

    fn work(&self, out: &mut Vec<f64>) {
        out.clear();
        out.extend_from_slice(&self.work);
    }

    fn build_instance(&mut self) -> Instance {
        let inst = assemble_instance(
            &self.cfg,
            &self.work,
            &self.load_acc,
            self.obj_to_pe.clone(),
            &mut self.traffic,
            &mut self.comm_cache,
        );
        self.load_acc.iter_mut().for_each(|l| *l = 0.0);
        inst
    }

    fn apply(&mut self, asg: &Assignment) -> f64 {
        assert_eq!(asg.mapping.len(), self.n_objs());
        let mut bytes = 0.0;
        for (&new_pe, old_pe) in asg.mapping.iter().zip(&self.obj_to_pe) {
            if new_pe != *old_pe {
                bytes += self.cfg.object_bytes;
            }
        }
        self.obj_to_pe = asg.mapping.clone();
        bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::app::step_once;
    use crate::apps::driver::{run_app, DriverConfig};
    use crate::strategies::{make, StrategyParams};

    #[test]
    fn peak_drifts_across_objects() {
        let cfg = HotspotConfig::default();
        let peak_at = |step: usize| {
            (0..cfg.nx * cfg.ny)
                .map(|o| (o, load_at(&cfg, o, step)))
                .max_by(|a, b| a.1.total_cmp(&b.1))
                .unwrap()
                .0
        };
        let early = peak_at(0);
        let later = peak_at(20);
        assert_ne!(early, later, "peak never moved");
        // loads stay positive and bounded
        for step in [0usize, 7, 33] {
            for o in 0..cfg.nx * cfg.ny {
                let l = load_at(&cfg, o, step);
                assert!(l >= cfg.base && l <= cfg.base + cfg.amp, "load {l}");
            }
        }
    }

    #[test]
    fn stale_assignments_decay() {
        // Balance once at step 0, then let the peak drift: the frozen
        // mapping's imbalance must grow — the phenomenon this app
        // exists to produce.
        let mut app = Hotspot::new(HotspotConfig::default()).unwrap();
        step_once(&mut app).unwrap();
        let inst = app.build_instance();
        let asg = make("greedy-refine", StrategyParams::default())
            .unwrap()
            .rebalance(&inst);
        app.apply(&asg);
        let imbalance = |app: &Hotspot| {
            let mut pe = vec![0.0f64; app.cfg.topo.n_pes()];
            for (o, &p) in app.obj_to_pe.iter().enumerate() {
                pe[p as usize] += app.work[o];
            }
            let max = pe.iter().cloned().fold(0.0, f64::max);
            let avg = pe.iter().sum::<f64>() / pe.len() as f64;
            max / avg
        };
        let fresh = imbalance(&app);
        for _ in 0..40 {
            step_once(&mut app).unwrap();
        }
        let stale = imbalance(&app);
        assert!(stale > fresh, "stale {stale} !> fresh {fresh}");
    }

    #[test]
    fn runs_under_the_generic_driver() {
        let mut app = Hotspot::new(HotspotConfig::default()).unwrap();
        let strat = make("diff-comm", StrategyParams::default()).unwrap();
        let cfg = DriverConfig {
            iters: 12,
            lb_period: 4,
            deterministic_loads: true,
            ..Default::default()
        };
        let rep = run_app(&mut app, strat.as_ref(), &cfg).unwrap();
        assert_eq!(rep.records.len(), 12);
        assert!(rep.verified);
        assert!(rep.total_migrations > 0, "drifting peak should force migrations");
        // halo comm charged every step
        assert!(rep.records.iter().all(|r| r.comm_max_s > 0.0));
    }

    #[test]
    fn instance_assembly_is_deterministic() {
        let mk = || {
            let mut app = Hotspot::new(HotspotConfig::default()).unwrap();
            for _ in 0..3 {
                step_once(&mut app).unwrap();
            }
            let mut inst = app.build_instance();
            // strip the wall-clock part: deterministic runs overwrite
            // loads with the analytic work vector, as the driver does
            let mut work = Vec::new();
            app.work(&mut work);
            inst.loads = work;
            inst
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.loads, b.loads);
        assert_eq!(a.mapping, b.mapping);
        assert_eq!(a.sizes, b.sizes);
    }
}
