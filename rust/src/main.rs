//! `difflb` CLI — the runtime leader.
//!
//! Subcommands:
//!   run         run a workload (--app pic|stencil|advect|hotspot) under a strategy
//!   balance     load-balance a .lbi instance file, print paper metrics
//!   viz         render a .lbi instance (PPM + SVG) colored by PE
//!   check       verify PJRT artifacts load and execute correctly
//!   strategies  list available strategies
//!   apps        list available workloads

use anyhow::{Context, Result};
use difflb::coordinator::Coordinator;
use difflb::model::Instance;
use difflb::util::args::Parser;
use difflb::util::config::Config;
use difflb::{info, viz};

fn parser() -> Parser {
    Parser::new("difflb — communication-aware diffusion load balancing")
        .subcommand("run", "run a workload (--app) under a strategy")
        .subcommand("run-pic", "alias for `run --app pic` (kept for compatibility)")
        .subcommand("balance", "rebalance a .lbi instance file")
        .subcommand("viz", "render a .lbi instance to out/<name>.{ppm,svg}")
        .subcommand("check", "smoke-check the PJRT artifacts")
        .subcommand("strategies", "list available strategies")
        .subcommand("apps", "list available workloads")
        .opt("config", None, "config file (INI subset)")
        .opt("set", None, "override, e.g. --set lb.strategy=diff-coord (comma-separated)")
        .opt("strategy", None, "shorthand for --set lb.strategy=...")
        .opt("app", None, "workload to run: shorthand for --set app.kind=... \
             (see `difflb apps`)")
        .opt("mode", None, "execution mode: sequential (default) or distributed \
             (run the LB pipeline + the app as real message-passing protocols)")
        .opt("iters", None, "shorthand for --set run.iters=...")
        .opt("lb-period", None, "shorthand for --set run.lb_period=...")
        .opt("pe-speeds", None, "heterogeneous cluster: comma-separated per-PE speed \
             factors, e.g. --pe-speeds 1,2,1,0.5 (sets topo.pe_speeds)")
        .opt("speed-noise", None, "speed-noise amplitude in [0, 1): perturbs PE speeds \
             each iteration to model OS interference (sets topo.speed_noise)")
        .opt("resize", None, "planned elasticity: comma-separated node join/leave \
             events keyed to LB rounds, e.g. --resize leave:2@3,join:5@7 \
             (sets topo.resize)")
        .opt("fault", None, "chaos schedule: comma-separated kill/hang/delay/part \
             events, e.g. --fault kill:2@1:s2,part:1|3@4 (sets fault.plan; \
             distributed mode only)")
        .opt("fault-seed", None, "seed-derived single fault: victim, round, stage \
             and kind are pure functions of the seed (sets fault.seed)")
        .opt("trace", None, "write a Chrome trace-event JSON of the run's spans here \
             (sets obs.trace_path; open in chrome://tracing or Perfetto)")
        .opt("metrics", None, "write per-LB-round metrics as JSONL here \
             (sets obs.metrics_path)")
        .opt("scale", Some("8"), "viz: pixels per coordinate unit")
        .opt("out", None, "balance: write rebalanced instance here")
        .flag("strict-config", "error (instead of warn) on config keys that are set \
             but never read")
        .flag("verbose", "debug logging")
}

fn load_config(args: &difflb::util::args::Args) -> Result<Config> {
    let mut cfg = match args.get("config") {
        Some(path) => Config::from_file(path)?,
        None => Config::new(),
    };
    if let Some(s) = args.get("strategy") {
        cfg.set("lb.strategy", s);
    }
    if let Some(s) = args.get("app") {
        cfg.set("app.kind", s);
    }
    if let Some(s) = args.get("mode") {
        anyhow::ensure!(
            matches!(s, "sequential" | "distributed"),
            "unknown --mode '{s}' (expected 'sequential' or 'distributed')"
        );
        cfg.set("run.mode", s);
        cfg.set("lb.mode", s);
    }
    if let Some(s) = args.get("iters") {
        cfg.set("run.iters", s);
    }
    if let Some(s) = args.get("lb-period") {
        cfg.set("run.lb_period", s);
    }
    // dedicated option rather than --set: --set splits its value on
    // commas, which would shred a speed list
    if let Some(s) = args.get("pe-speeds") {
        cfg.set("topo.pe_speeds", s);
    }
    if let Some(s) = args.get("speed-noise") {
        cfg.set("topo.speed_noise", s);
    }
    if let Some(s) = args.get("resize") {
        cfg.set("topo.resize", s);
    }
    if let Some(s) = args.get("fault") {
        cfg.set("fault.plan", s);
    }
    if let Some(s) = args.get("fault-seed") {
        cfg.set("fault.seed", s);
    }
    if let Some(s) = args.get("trace") {
        cfg.set("obs.trace_path", s);
    }
    if let Some(s) = args.get("metrics") {
        cfg.set("obs.metrics_path", s);
    }
    if args.has_flag("strict-config") {
        cfg.set("run.strict_config", "true");
    }
    if let Some(sets) = args.get("set") {
        for kv in sets.split(',') {
            cfg.set_kv(kv)?;
        }
    }
    Ok(cfg)
}

fn main() -> Result<()> {
    let args = parser().parse_env();
    if args.has_flag("verbose") {
        difflb::util::logging::set_level(difflb::util::logging::Level::Debug);
    }
    let mut cfg = load_config(&args)?;

    match args.subcommand.as_deref() {
        Some("run") | Some("run-pic") => {
            if args.subcommand.as_deref() == Some("run-pic") && cfg.get("app.kind").is_none() {
                cfg.set("app.kind", "pic");
            }
            let coord = Coordinator::from_config(&cfg)?;
            let app_kind = cfg.get("app.kind").unwrap_or("pic").to_string();
            info!("app: {app_kind}, strategy: {}", coord.strategy.name());
            let report = coord.run(&cfg)?;
            println!("{}", report.summary_line(&format!("{app_kind}/{}", coord.strategy.name())));
            anyhow::ensure!(report.verified, "{app_kind} verification FAILED");
            println!("{app_kind} verification: SUCCESS");
        }
        Some("balance") => {
            let path = args.positional.first().context("usage: balance <file.lbi>")?;
            let inst = Instance::load(path)?;
            let coord = Coordinator::from_config(&cfg)?;
            let before = difflb::model::evaluate_mapping(&inst, &inst.mapping);
            let (asg, after) = coord.balance_instance(&inst);
            println!("before: {before}");
            println!("after : {after}");
            if let Some(out) = args.get("out") {
                let mut rebalanced = inst.clone();
                rebalanced.mapping = asg.mapping;
                rebalanced.save(out)?;
                println!("wrote {out}");
            }
        }
        Some("viz") => {
            let path = args.positional.first().context("usage: viz <file.lbi>")?;
            let inst = Instance::load(path)?;
            let scale: f64 = args.f64("scale");
            let stem = std::path::Path::new(path)
                .file_stem()
                .and_then(|s| s.to_str())
                .unwrap_or("instance");
            let ppm = difflb::util::io::out_path(&format!("{stem}.ppm"))?;
            let svg = difflb::util::io::out_path(&format!("{stem}.svg"))?;
            viz::render_ppm(&inst, &inst.mapping, scale, &ppm)?;
            viz::render_svg(&inst, &inst.mapping, scale, &svg)?;
            println!("wrote {} and {}", ppm.display(), svg.display());
        }
        Some("check") => {
            let engine = difflb::runtime::Engine::new()?;
            let mut batch = difflb::runtime::PicBatch::with_capacity(4);
            for _ in 0..4 {
                batch.push_pad();
            }
            engine.pic_push(&mut batch, 64.0, 1.0)?;
            anyhow::ensure!(batch.x.iter().all(|&x| x == 0.5), "inert check failed");
            println!(
                "artifacts OK: {} artifacts, pic batch sizes {:?}",
                engine.manifest().artifacts.len(),
                engine.manifest().pic_batch_sizes()
            );
        }
        Some("strategies") => {
            for s in difflb::strategies::AVAILABLE {
                println!("{s}");
            }
        }
        Some("apps") => {
            for a in difflb::apps::AVAILABLE_APPS {
                println!("{a}");
            }
        }
        _ => {
            print!("{}", parser().usage("difflb"));
        }
    }
    Ok(())
}
