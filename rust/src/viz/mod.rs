//! Partition visualizations (paper Figs 1-2): render an instance's
//! object layout colored by owning PE, as PPM (raster) and SVG
//! (vector). Objects are drawn as filled circles at their coordinates —
//! the same presentation the paper's simulator produces.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::model::Instance;

/// Distinct, stable color per PE: golden-angle hue walk in HSV.
pub fn pe_color(pe: u32) -> [u8; 3] {
    let hue = (pe as f64 * 137.507_764) % 360.0;
    let (s, v) = (0.65, 0.92);
    hsv_to_rgb(hue, s, v)
}

fn hsv_to_rgb(h: f64, s: f64, v: f64) -> [u8; 3] {
    let c = v * s;
    let hp = h / 60.0;
    let x = c * (1.0 - (hp % 2.0 - 1.0).abs());
    let (r, g, b) = match hp as u32 {
        0 => (c, x, 0.0),
        1 => (x, c, 0.0),
        2 => (0.0, c, x),
        3 => (0.0, x, c),
        4 => (x, 0.0, c),
        _ => (c, 0.0, x),
    };
    let m = v - c;
    [
        ((r + m) * 255.0) as u8,
        ((g + m) * 255.0) as u8,
        ((b + m) * 255.0) as u8,
    ]
}

/// A simple RGB raster canvas with a binary-PPM writer.
pub struct Canvas {
    pub w: usize,
    pub h: usize,
    pub pixels: Vec<u8>, // RGB8
}

impl Canvas {
    pub fn new(w: usize, h: usize) -> Canvas {
        Canvas { w, h, pixels: vec![255; w * h * 3] }
    }

    pub fn set(&mut self, x: i64, y: i64, c: [u8; 3]) {
        if x < 0 || y < 0 || x as usize >= self.w || y as usize >= self.h {
            return;
        }
        let i = (y as usize * self.w + x as usize) * 3;
        self.pixels[i..i + 3].copy_from_slice(&c);
    }

    pub fn fill_circle(&mut self, cx: f64, cy: f64, r: f64, c: [u8; 3]) {
        let (x0, x1) = ((cx - r).floor() as i64, (cx + r).ceil() as i64);
        let (y0, y1) = ((cy - r).floor() as i64, (cy + r).ceil() as i64);
        for y in y0..=y1 {
            for x in x0..=x1 {
                let dx = x as f64 + 0.5 - cx;
                let dy = y as f64 + 0.5 - cy;
                if dx * dx + dy * dy <= r * r {
                    self.set(x, y, c);
                }
            }
        }
    }

    /// Write binary PPM (P6).
    pub fn write_ppm(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path.as_ref())
                .with_context(|| format!("creating {}", path.as_ref().display()))?,
        );
        write!(f, "P6\n{} {}\n255\n", self.w, self.h)?;
        f.write_all(&self.pixels)?;
        Ok(())
    }
}

/// Bounding box of instance coordinates (min, max per axis).
fn bounds(inst: &Instance) -> ([f64; 2], [f64; 2]) {
    let mut lo = [f64::INFINITY; 2];
    let mut hi = [f64::NEG_INFINITY; 2];
    for c in &inst.coords {
        for d in 0..2 {
            lo[d] = lo[d].min(c[d]);
            hi[d] = hi[d].max(c[d]);
        }
    }
    if !lo[0].is_finite() {
        return ([0.0; 2], [1.0; 2]);
    }
    (lo, hi)
}

/// Render objects as PE-colored circles to a PPM file (`mapping` may be
/// the instance's own mapping or a strategy output).
pub fn render_ppm(
    inst: &Instance,
    mapping: &[u32],
    px_per_unit: f64,
    path: impl AsRef<Path>,
) -> Result<()> {
    let (lo, hi) = bounds(inst);
    let pad = 1.0;
    let w = (((hi[0] - lo[0]) + 2.0 * pad) * px_per_unit).ceil() as usize;
    let h = (((hi[1] - lo[1]) + 2.0 * pad) * px_per_unit).ceil() as usize;
    let mut canvas = Canvas::new(w.max(8), h.max(8));
    let r = (px_per_unit * 0.38).max(1.5);
    for (o, c) in inst.coords.iter().enumerate() {
        let x = (c[0] - lo[0] + pad) * px_per_unit;
        let y = (c[1] - lo[1] + pad) * px_per_unit;
        canvas.fill_circle(x, y, r, pe_color(mapping[o]));
    }
    canvas.write_ppm(path)
}

/// Render the same picture as SVG.
pub fn render_svg(
    inst: &Instance,
    mapping: &[u32],
    px_per_unit: f64,
    path: impl AsRef<Path>,
) -> Result<()> {
    let (lo, hi) = bounds(inst);
    let pad = 1.0;
    let w = ((hi[0] - lo[0]) + 2.0 * pad) * px_per_unit;
    let h = ((hi[1] - lo[1]) + 2.0 * pad) * px_per_unit;
    let r = (px_per_unit * 0.38).max(1.5);
    let mut s = format!(
        "<svg xmlns=\"http://www.w3.org/2000/svg\" width=\"{w:.0}\" height=\"{h:.0}\" \
         viewBox=\"0 0 {w:.2} {h:.2}\">\n<rect width=\"100%\" height=\"100%\" fill=\"white\"/>\n"
    );
    for (o, c) in inst.coords.iter().enumerate() {
        let x = (c[0] - lo[0] + pad) * px_per_unit;
        let y = (c[1] - lo[1] + pad) * px_per_unit;
        let [cr, cg, cb] = pe_color(mapping[o]);
        s.push_str(&format!(
            "<circle cx=\"{x:.2}\" cy=\"{y:.2}\" r=\"{r:.2}\" fill=\"rgb({cr},{cg},{cb})\"/>\n"
        ));
    }
    s.push_str("</svg>\n");
    std::fs::write(path.as_ref(), s)
        .with_context(|| format!("writing {}", path.as_ref().display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::stencil::{stencil_2d, Decomposition};

    #[test]
    fn colors_are_distinct_for_small_pe_counts() {
        let mut seen = std::collections::HashSet::new();
        for pe in 0..64 {
            assert!(seen.insert(pe_color(pe)), "duplicate color for pe {pe}");
        }
    }

    #[test]
    fn canvas_bounds_are_safe() {
        let mut c = Canvas::new(10, 10);
        c.set(-5, 3, [0, 0, 0]);
        c.set(100, 100, [0, 0, 0]);
        c.fill_circle(0.0, 0.0, 3.0, [10, 20, 30]);
        assert_eq!(c.pixels.len(), 300);
    }

    #[test]
    fn renders_both_formats() {
        let inst = stencil_2d(8, 2, 2, Decomposition::Tiled);
        let dir = std::env::temp_dir().join("difflb_viz_test");
        std::fs::create_dir_all(&dir).unwrap();
        let ppm = dir.join("t.ppm");
        let svg = dir.join("t.svg");
        render_ppm(&inst, &inst.mapping, 8.0, &ppm).unwrap();
        render_svg(&inst, &inst.mapping, 8.0, &svg).unwrap();
        let ppm_bytes = std::fs::read(&ppm).unwrap();
        assert!(ppm_bytes.starts_with(b"P6"));
        let svg_text = std::fs::read_to_string(&svg).unwrap();
        assert_eq!(svg_text.matches("<circle").count(), 64);
    }
}
