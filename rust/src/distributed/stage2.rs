//! Stage 2 as a real protocol — distributed first-order virtual load
//! balancing (paper §III-B executed the way Charm++ would run it).
//!
//! Each node holds two scalars of truly local state — `own` (load
//! originating here, still here) and `recv` (load received virtually,
//! never forwarded: the single-hop constraint) — and iterates:
//!
//! 1. **LOAD**: exchange the current load `own + recv` with every
//!    stage-1 neighbor.
//! 2. **DONE-bit reduction** (from sweep 1 on): each node reports
//!    whether its neighborhood's relative spread is within tolerance;
//!    rank 0 ANDs the bits, folds in the previous sweep's exact global
//!    `moved` sum, and broadcasts stop/continue.
//! 3. **XFER**: plan sends `α·(L_i − L_j)` capped at `own`, ship one
//!    transfer scalar to every neighbor (zeros included, so receive
//!    counts stay deterministic), apply incoming transfers in ascending
//!    sender order.
//!
//! Bit-identity with the sequential fixed-point
//! ([`virtual_balance_with`](crate::strategies::diffusion::virtual_lb::virtual_balance_with))
//! is engineered, not hoped for: every f64 accumulator here sees the
//! *same values in the same order* as its sequential counterpart —
//! `own` is only ever touched by this node's sends in adjacency order,
//! `recv` applies incoming transfers sorted by sender rank (the order
//! the sequential global sweep processes them), per-pair net flows are
//! tracked symmetrically (a node's view is the exact IEEE negation of
//! its peer's), and the early-exit `moved` sum is reconstructed at rank
//! 0 from the raw per-send amounts in global (rank, adjacency) order
//! rather than from per-node partial sums, which would round
//! differently. The integration tests assert the resulting quotas are
//! `==` to the sequential ones.

use crate::simnet::network::{Comm, CommError};

use super::wire;

/// Sub-phase tags within the caller's `tag_base` (low byte; bits 8..24
/// carry the sweep index).
const PH_LOAD: u32 = 0;
const PH_XFER: u32 = 1;
const PH_MOV: u32 = 2;
const PH_CONV: u32 = 3;
const PH_CTRL: u32 = 4;
/// Setup reduction (runs once, before sweep 0's phases).
const PH_SETUP_UP: u32 = 8;
const PH_SETUP_DOWN: u32 = 9;

/// One node's stage-2 result.
pub struct Stage2Out {
    /// This node's row of [`Quotas::flows`]
    /// (crate::strategies::diffusion::virtual_lb::Quotas): positive net
    /// sends to neighbors, sorted by neighbor rank.
    pub flow_row: Vec<(u32, f64)>,
    /// Sweeps executed — identical on every node (the stop decision is
    /// a broadcast), and equal to the sequential `Quotas::iterations`.
    pub iterations: usize,
}

/// Run the distributed virtual-LB fixed point for this node. `adj` is
/// the stage-1 neighbor set (sorted ascending; the graph is symmetric
/// by the handshake's contract), `my_load` this node's stage-2 load
/// scalar — raw work on uniform topologies, normalized time
/// (`work / capacity`, see `node_load` in the parent module) on heterogeneous
/// ones; the protocol itself is unit-agnostic. `tag_base` must leave
/// the low 24 bits clear. A peer failing mid-protocol surfaces as
/// `Err`; the epoch/restart layer owns the recovery decision.
pub fn virtual_balance_node(
    comm: &mut Comm,
    adj: &[u32],
    my_load: f64,
    tol: f64,
    max_iters: usize,
    tag_base: u32,
) -> Result<Stage2Out, CommError> {
    debug_assert_eq!(tag_base & 0x00FF_FFFF, 0, "tag_base clobbers sweep/phase bits");
    assert!(max_iters < (1 << 16), "vlb_max_iters exceeds the sweep tag space");
    let rank = comm.rank;
    let n = comm.n;
    let deg = adj.len();
    let t = |sweep: usize, phase: u32| tag_base | ((sweep as u32) << 8) | phase;

    // ---- Setup reduction: global average load and max degree → α.
    // Rank 0 sums the gathered loads in ascending rank order — the same
    // left-to-right order as the sequential `loads.iter().sum()` — so
    // the average is bit-equal.
    let (max_degree, global_avg) = if rank == 0 {
        let mut msgs = comm.recv_tagged(t(0, PH_SETUP_UP), n - 1, comm.patience())?;
        msgs.sort_by_key(|m| m.from);
        let mut sum = my_load;
        let mut maxd = deg as u32;
        for m in &msgs {
            let mut r = wire::Reader::new(&m.data);
            let corrupt = |_| CommError::Corrupt { tag: t(0, PH_SETUP_UP), from: m.from };
            maxd = maxd.max(r.u32().map_err(corrupt)?);
            sum += r.f64().map_err(corrupt)?;
        }
        let avg = sum / n.max(1) as f64;
        let mut down = Vec::with_capacity(12);
        wire::put_u32(&mut down, maxd);
        wire::put_f64(&mut down, avg);
        for p in 1..n as u32 {
            comm.send(p, t(0, PH_SETUP_DOWN), down.clone());
        }
        (maxd, avg)
    } else {
        let mut up = Vec::with_capacity(12);
        wire::put_u32(&mut up, deg as u32);
        wire::put_f64(&mut up, my_load);
        comm.send(0, t(0, PH_SETUP_UP), up);
        let msgs = comm.recv_tagged(t(0, PH_SETUP_DOWN), 1, comm.patience())?;
        let mut r = wire::Reader::new(&msgs[0].data);
        let corrupt = |_| CommError::Corrupt { tag: t(0, PH_SETUP_DOWN), from: msgs[0].from };
        (r.u32().map_err(corrupt)?, r.f64().map_err(corrupt)?)
    };

    if global_avg <= 0.0 {
        return Ok(Stage2Out { flow_row: Vec::new(), iterations: 0 });
    }
    // First-order scheme constant: 1/(max_degree + 1) guarantees
    // convergence on arbitrary neighbor graphs (Cybenko).
    let alpha = 1.0 / (max_degree as f64 + 1.0);

    // Truly local fixed-point state.
    let mut own = my_load;
    let mut recv_acc = 0.0f64;
    // Per-neighbor signed net flow, this node's sign convention:
    // positive = this node owes a net send to adj[idx].
    let mut net = vec![0.0f64; deg];
    let mut cur_j = vec![0.0f64; deg];
    let mut amts = vec![0.0f64; deg];
    let mut iterations = 0usize;
    // Root-only: the previous sweep's exact global moved sum.
    let mut moved_prev = 0.0f64;

    for sweep in 0..max_iters {
        // ---- LOAD: exchange current loads with stage-1 neighbors.
        let cur = own + recv_acc;
        for &j in adj {
            comm.send(j, t(sweep, PH_LOAD), cur.to_le_bytes().to_vec());
        }
        let mut loads_in = comm.recv_tagged(t(sweep, PH_LOAD), deg, comm.patience())?;
        loads_in.sort_by_key(|m| m.from);
        for (idx, m) in loads_in.iter().enumerate() {
            debug_assert_eq!(m.from, adj[idx], "asymmetric stage-1 graph");
            let Ok(b) = m.data.get(..8).unwrap_or_default().try_into() else {
                return Err(CommError::Corrupt { tag: t(sweep, PH_LOAD), from: m.from });
            };
            cur_j[idx] = f64::from_le_bytes(b);
        }

        // ---- DONE-bit reduction: did the PREVIOUS sweep converge?
        // The sequential loop checks after applying each sweep; the
        // post-sweep values it checks are exactly what this sweep's
        // load exchange just delivered.
        if sweep > 0 {
            let my_bit = neighborhood_converged(cur, &cur_j, global_avg, tol);
            let stop = if rank == 0 {
                let msgs = comm.recv_tagged(t(sweep, PH_CONV), n - 1, comm.patience())?;
                let all = my_bit && msgs.iter().all(|m| m.data == [1]);
                let stop = all || moved_prev <= tol * global_avg * 1e-3;
                for p in 1..n as u32 {
                    comm.send(p, t(sweep, PH_CTRL), vec![u8::from(stop)]);
                }
                stop
            } else {
                comm.send(0, t(sweep, PH_CONV), vec![u8::from(my_bit)]);
                let msgs = comm.recv_tagged(t(sweep, PH_CTRL), 1, comm.patience())?;
                msgs[0].data == [1]
            };
            if stop {
                break;
            }
        }
        iterations = sweep + 1;

        // ---- Plan this sweep's sends (single-hop: cap at `own`).
        let mut want = 0.0;
        for &cj in cur_j.iter() {
            let diff = cur - cj;
            if diff > 0.0 {
                want += alpha * diff;
            }
        }
        for a in amts.iter_mut() {
            *a = 0.0;
        }
        // Raw pushed amounts in adjacency order, for the exact moved
        // sum at the root.
        let mut mov: Vec<u8> = Vec::new();
        if want > 0.0 {
            let scale = if want > own { own / want } else { 1.0 };
            if scale > 0.0 {
                for idx in 0..deg {
                    let diff = cur - cur_j[idx];
                    if diff > 0.0 {
                        let amt = alpha * diff * scale;
                        amts[idx] = amt;
                        wire::put_f64(&mut mov, amt);
                    }
                }
            }
        }

        // ---- XFER: one transfer scalar to every neighbor, every sweep
        // (zeros included — receive counts stay deterministic, and
        // adding 0.0 to a non-negative accumulator is a bitwise no-op).
        for idx in 0..deg {
            comm.send(adj[idx], t(sweep, PH_XFER), amts[idx].to_le_bytes().to_vec());
        }
        if rank != 0 {
            comm.send(0, t(sweep, PH_MOV), mov.clone());
        }
        // Apply my sends: `own` and my half of the net flows see the
        // amounts in adjacency order, as in the sequential sweep.
        for idx in 0..deg {
            own -= amts[idx];
            net[idx] += amts[idx];
        }
        // Apply incoming transfers in ascending sender order — the
        // order the sequential global sweep (ranks 0..n) hits this
        // node's `recv` accumulator.
        let mut xfers = comm.recv_tagged(t(sweep, PH_XFER), deg, comm.patience())?;
        xfers.sort_by_key(|m| m.from);
        for (idx, m) in xfers.iter().enumerate() {
            debug_assert_eq!(m.from, adj[idx]);
            let Ok(b) = m.data.get(..8).unwrap_or_default().try_into() else {
                return Err(CommError::Corrupt { tag: t(sweep, PH_XFER), from: m.from });
            };
            let amt = f64::from_le_bytes(b);
            recv_acc += amt;
            net[idx] -= amt;
        }

        // ---- Root reconstructs the sequential running `moved` sum
        // from the raw amounts in global (rank, adjacency) order.
        if rank == 0 {
            let mut msgs = comm.recv_tagged(t(sweep, PH_MOV), n - 1, comm.patience())?;
            msgs.sort_by_key(|m| m.from);
            let mut moved = 0.0f64;
            for v in mov.chunks_exact(8) {
                moved += f64::from_le_bytes(v.try_into().unwrap());
            }
            for m in &msgs {
                for v in m.data.chunks_exact(8) {
                    moved += f64::from_le_bytes(v.try_into().unwrap());
                }
            }
            moved_prev = moved;
        }
    }

    // Fold the signed per-pair nets into this node's positive send
    // quotas. `adj` ascends, so the row is born sorted; the threshold
    // matches the sequential fold exactly (a peer's net is the exact
    // IEEE negation of ours, so the two sides agree on every edge).
    let mut flow_row = Vec::new();
    for idx in 0..deg {
        if net[idx] > 1e-12 {
            flow_row.push((adj[idx], net[idx]));
        }
    }
    Ok(Stage2Out { flow_row, iterations })
}

/// This node's neighborhood convergence bit: relative load spread over
/// {self} ∪ neighbors within `tol` (measured against the global average
/// so empty-ish neighborhoods don't divide by ~0). Nodes without
/// neighbors are vacuously converged, as in the sequential check.
fn neighborhood_converged(cur: f64, cur_j: &[f64], global_avg: f64, tol: f64) -> bool {
    if cur_j.is_empty() {
        return true;
    }
    let mut lo = cur;
    let mut hi = cur;
    for &c in cur_j {
        lo = lo.min(c);
        hi = hi.max(c);
    }
    (hi - lo) / global_avg <= tol
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::network::Cluster;
    use crate::strategies::diffusion::neighbor::NeighborGraph;
    use crate::strategies::diffusion::virtual_lb::virtual_balance;
    use crate::util::rng::Rng;

    fn ring(n: usize, h: usize) -> NeighborGraph {
        let adj = (0..n)
            .map(|i| {
                let mut a: Vec<u32> = Vec::new();
                for d in 1..=h {
                    a.push(((i + d) % n) as u32);
                    a.push(((i + n - d) % n) as u32);
                }
                a.sort_unstable();
                a.dedup();
                a
            })
            .collect();
        NeighborGraph { adj }
    }

    fn run_distributed(
        neigh: &NeighborGraph,
        loads: &[f64],
        tol: f64,
        max_iters: usize,
    ) -> (Vec<Vec<(u32, f64)>>, usize) {
        let n = loads.len();
        let adj = std::sync::Arc::new(neigh.adj.clone());
        let loads = std::sync::Arc::new(loads.to_vec());
        let outs = Cluster::run(n, move |rank, mut comm| {
            let out = virtual_balance_node(
                &mut comm,
                &adj[rank as usize],
                loads[rank as usize],
                tol,
                max_iters,
                0x0200_0000,
            )
            .expect("stage-2 protocol failed on a healthy cluster");
            (out.flow_row, out.iterations)
        });
        let iters = outs.iter().map(|o| o.1).max().unwrap_or(0);
        assert!(outs.iter().all(|o| o.1 == iters), "nodes disagree on sweep count");
        (outs.into_iter().map(|o| o.0).collect(), iters)
    }

    #[test]
    fn matches_sequential_on_hotspot() {
        let n = 16;
        let mut loads = vec![1.0; n];
        loads[0] = 10.0;
        let g = ring(n, 2);
        let seq = virtual_balance(&g, &loads, 0.05, 500);
        let (flows, iters) = run_distributed(&g, &loads, 0.05, 500);
        assert_eq!(seq.flows, flows);
        assert_eq!(seq.iterations, iters);
    }

    #[test]
    fn matches_sequential_on_random_loads() {
        let mut rng = Rng::new(0x57A6E2);
        for trial in 0..6usize {
            let n = 4 + 2 * (trial % 4);
            let loads: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 8.0)).collect();
            let g = ring(n, 1 + trial % 2);
            let seq = virtual_balance(&g, &loads, 0.05, 300);
            let (flows, iters) = run_distributed(&g, &loads, 0.05, 300);
            assert_eq!(seq.flows, flows, "trial {trial}");
            assert_eq!(seq.iterations, iters, "trial {trial}");
        }
    }

    #[test]
    fn zero_load_short_circuits() {
        let g = ring(4, 1);
        let (flows, iters) = run_distributed(&g, &[0.0; 4], 0.05, 100);
        assert_eq!(iters, 0);
        assert!(flows.iter().all(|f| f.is_empty()));
    }
}
