//! Failure detection and pipeline restart: the membership-epoch
//! protocol that turns a mid-pipeline [`CommError`] into a quorum
//! restart on the surviving ranks instead of an aborted run.
//!
//! The failure coordinator is **elected**, not fixed: every rank
//! computes [`elect`] — the lowest world rank it believes alive (and
//! not barred as a partition rejoiner) — from its cumulative failed
//! set, and the protocol is coordinator-relative. When the current
//! coordinator itself dies or is partitioned away, its followers time
//! out waiting for a declaration, mark it failed, and re-elect; the
//! deterministic rule means every survivor lands on the same successor
//! without any extra messages. The protocol is a standard
//! probe/declare/ack cycle over the control namespace ([`CTRL_NS`]
//! tags bypass epoch filtering, so recovery traffic is deliverable
//! from any epoch):
//!
//! 1. **Probe** — the coordinator pings every rank of the failed
//!    pipeline group. A healthy rank is either already in its own
//!    recovery loop (its stage receive errored too — the pipeline is
//!    globally synchronized, so one silent rank starves everyone within
//!    a patience window) and answers `PONG`, or its spontaneous `FAULT`
//!    report is already parked in the coordinator's pending buffer.
//!    Ranks silent for the whole probe window are declared failed.
//! 2. **Declare** — the coordinator bumps the epoch and broadcasts
//!    `EPOCH {epoch, failed set}` to every world rank (best effort:
//!    sends to dead endpoints are dropped, sends across a partition are
//!    cut). Stamping the *cumulative* failed set makes declarations
//!    self-contained: a rank that slept through three epochs catches up
//!    from the newest one alone.
//! 3. **Ack** — surviving group members adopt the epoch (draining their
//!    pending buffers of pre-fault traffic — see [`Comm::set_epoch`])
//!    and ack *the declaring rank*. A survivor dying *between* probe
//!    and ack re-enters the cycle; a rank that loses quorum on its side
//!    of a partition — follower or self-elected coordinator — exits
//!    dead after a bounded wait.
//!
//! The election cascade is race-free by timeout asymmetry: a follower
//! waits `8 × detect` for its coordinator while a coordinator's
//! probe/ack cycle spans `3 × detect` windows, so a live coordinator
//! always pings (resetting follower deadlines) or declares before any
//! follower gives up on it. Two coordinators can only coexist
//! transiently across a partition cut, where their declarations cannot
//! collide anyway.
//!
//! [`staged_pipeline`] wraps the plain
//! [`node_pipeline`](super::node_pipeline) with [`FaultPlan`] injection
//! gates at each stage entry; the fault-free driver path never calls it,
//! so inactive plans keep the bit-identical pipeline untouched.

use std::time::{Duration, Instant};

use crate::model::Instance;
use crate::simnet::fault::{FaultKind, FaultPlan, StagePoint};
use crate::simnet::network::{Comm, CommError, CTRL_NS};
use crate::simnet::protocol;
use crate::strategies::diffusion::Variant;
use crate::strategies::StrategyParams;

use super::{node_load, stage2, stage3, wire, NodeOutcome, TAG_HANDSHAKE, TAG_STAGE2, TAG_STAGE3};

/// Control-message kinds (low byte of a [`CTRL_NS`] tag).
const CT_PING: u32 = 1;
const CT_PONG: u32 = 2;
const CT_FAULT: u32 = 3;
const CT_EPOCH: u32 = 4;
const CT_EPOCH_ACK: u32 = 5;
const CT_MAP: u32 = 6;

const fn ctrl(kind: u32) -> u32 {
    CTRL_NS | kind
}

/// Control kinds occupy the low 4 bits of a [`CTRL_NS`] tag; the 20
/// bits above them carry [`map_tag`]'s LB round. difflb-lint's
/// `ctrl-kind-budget` rule locks every `CT_*` constant under 0x10.
const fn kind_of(tag: u32) -> u32 {
    tag & 0xF
}

/// The tag carrying the final world mapping to a scheduled leaver after
/// LB round `lb_round` — control-namespace so the leaver (which did not
/// participate in the round's pipeline and may be an epoch behind)
/// still receives it. The round rides in bits 4..24 (20 bits): the
/// driver bounds total LB rounds below `1 << 20`, so handoff tags never
/// alias across rounds (a stale leaver matching a future round's
/// handoff was possible under the old 16-bit field).
pub(crate) fn map_tag(lb_round: u32) -> u32 {
    debug_assert!(lb_round < 1 << 20, "LB round {lb_round} overflows the map-tag field");
    CTRL_NS | ((lb_round & 0x000F_FFFF) << 4) | CT_MAP
}

/// Whether a control message is a final-mapping handoff ([`map_tag`]).
pub(crate) fn is_map(tag: u32) -> bool {
    kind_of(tag) == CT_MAP
}

/// Whether a control message is an epoch declaration.
pub(crate) fn is_epoch(tag: u32) -> bool {
    kind_of(tag) == CT_EPOCH
}

/// Encode an epoch declaration: `epoch`, then the cumulative failed
/// set as a counted list of world ranks.
pub(crate) fn encode_epoch(epoch: u32, failed: &[bool]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(8 + failed.len() * 4);
    wire::put_u32(&mut buf, epoch);
    let n = failed.iter().filter(|&&f| f).count();
    wire::put_u32(&mut buf, n as u32);
    for (r, &f) in failed.iter().enumerate() {
        if f {
            wire::put_u32(&mut buf, r as u32);
        }
    }
    buf
}

/// Decode [`encode_epoch`]: `(epoch, failed world ranks)`. The counted
/// length is untrusted: allocation is bounded by the frame itself and a
/// short frame returns [`wire::Truncated`] (the recovery loops treat a
/// corrupt declaration as noise, like any other stray control message).
pub(crate) fn parse_epoch(data: &[u8]) -> Result<(u32, Vec<u32>), wire::Truncated> {
    let mut r = wire::Reader::new(data);
    let epoch = r.u32()?;
    let n = r.u32()?;
    let mut ranks = Vec::with_capacity((n as usize).min(r.remaining() / 4));
    for _ in 0..n {
        ranks.push(r.u32()?);
    }
    Ok((epoch, ranks))
}

/// The deterministic failure coordinator: the lowest world rank not in
/// `failed` and not barred (`barred` marks partition rejoiners — a
/// healed minority rank must never out-elect the majority root that
/// holds the authoritative run state). Falls back to the lowest
/// non-failed rank if every survivor is barred.
pub(crate) fn elect(failed: &[bool], barred: &[bool]) -> u32 {
    if let Some(r) = (0..failed.len()).find(|&r| !failed[r] && !barred[r]) {
        return r as u32;
    }
    (0..failed.len()).find(|&r| !failed[r]).unwrap_or(0) as u32
}

/// The rank next in line after `root` under the same election rule —
/// the driver replicates per-round checkpoints to it so a root death
/// does not lose custody.
pub(crate) fn successor(failed: &[bool], barred: &[bool], root: u32) -> Option<u32> {
    (0..failed.len())
        .map(|r| r as u32)
        .find(|&r| r != root && !failed[r as usize] && !barred[r as usize])
}

/// What the recovery cycle decided about this rank.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Membership {
    /// Part of the new epoch: retry the interrupted stage on the
    /// surviving group.
    Member,
    /// Declared failed or isolated from the coordinator: exit dead.
    Excluded,
}

/// What a follower's wait for its coordinator concluded.
enum FollowerOutcome {
    /// A declaration (or exclusion) settled this rank's membership.
    Done(Membership),
    /// The coordinator never pinged nor declared within the follower
    /// window: it is dead or unreachable — mark it failed and re-elect.
    CoordinatorSilent,
}

/// Run the probe/declare/ack recovery cycle after a pipeline
/// [`CommError`]. `participants` are the world ranks of the pipeline
/// group that just failed; `failed` is the caller's cumulative failed
/// set, updated in place; `barred` marks partition rejoiners that must
/// not win the election (see [`elect`]). Each iteration elects the
/// lowest believed-alive rank: that rank coordinates, everyone else
/// follows it; a silent coordinator is marked failed and the cycle
/// re-elects. Returns [`Membership::Excluded`] — instead of panicking —
/// when this rank's side of the world loses quorum: a minority-side
/// rank exits (or enters exile, if its partition heals) rather than
/// blocking the survivors.
pub(crate) fn recover(
    comm: &mut Comm,
    plan: &FaultPlan,
    participants: &[u32],
    failed: &mut [bool],
    barred: &[bool],
) -> Membership {
    let _sr = crate::obs::span("recover", "recovery");
    comm.leave_group();
    let detect = plan.detect_timeout();
    let me = comm.world_rank();
    let world_n = comm.world_n();
    loop {
        let coord = elect(failed, barred);
        if coord == me {
            return recover_root(comm, detect, participants, failed);
        }
        match recover_follower(comm, coord, detect, failed) {
            FollowerOutcome::Done(m) => return m,
            FollowerOutcome::CoordinatorSilent => {
                failed[coord as usize] = true;
                crate::obs::counter!("epoch.elections").inc();
                crate::obs::mark("epoch.reelect", "recovery");
                let n_failed = failed.iter().filter(|&&f| f).count();
                if 2 * (world_n - n_failed) <= world_n {
                    crate::obs::mark("epoch.minority_exit", "recovery");
                    return Membership::Excluded;
                }
                // loop: re-elect; the successor may be this rank.
            }
        }
    }
}

fn recover_root(
    comm: &mut Comm,
    detect: Duration,
    participants: &[u32],
    failed: &mut [bool],
) -> Membership {
    let world_n = comm.world_n();
    let me = comm.world_rank();
    loop {
        // ---- probe the current pipeline group.
        let expect: Vec<u32> = participants
            .iter()
            .copied()
            .filter(|&p| p != me && !failed[p as usize])
            .collect();
        for &p in &expect {
            comm.send(p, ctrl(CT_PING), Vec::new());
        }
        crate::obs::counter!("epoch.heartbeats").add(expect.len() as u64);
        let mut alive = vec![false; world_n];
        let mut n_alive = 0usize;
        // difflb-lint: allow(wall-clock): failure-detection window is real time by design
        let deadline = Instant::now() + 3 * detect;
        while n_alive < expect.len() {
            let left = deadline.saturating_duration_since(Instant::now()); // difflb-lint: allow(wall-clock): same window
            if left.is_zero() {
                break;
            }
            let Ok(m) = comm.recv_ctrl(left) else { break };
            let k = kind_of(m.tag);
            if (k == CT_PONG || k == CT_FAULT)
                && expect.contains(&m.from)
                && !alive[m.from as usize]
            {
                alive[m.from as usize] = true;
                n_alive += 1;
            }
            // stale acks from an earlier cycle and duplicate fault
            // reports fall through harmlessly.
        }
        for &p in &expect {
            if !alive[p as usize] {
                failed[p as usize] = true;
            }
        }
        let n_failed = failed.iter().filter(|&&f| f).count();
        if 2 * (world_n - n_failed) <= world_n {
            // This self-elected coordinator is on the minority side of
            // a cut (or the cluster really did lose quorum): exit dead
            // instead of declaring an epoch the majority never sees.
            crate::obs::mark("epoch.minority_exit", "recovery");
            return Membership::Excluded;
        }

        // ---- declare the new epoch. Best-effort to every world rank:
        // dead endpoints drop the send, partitioned ones never see it,
        // and excluded-but-alive ranks (hang victims) learn their fate
        // from the failed set on waking.
        let target = comm.epoch() + 1;
        crate::obs::counter!("epoch.declarations").inc();
        crate::obs::mark("epoch.declare", "recovery");
        let decl = encode_epoch(target, failed);
        for r in 0..world_n as u32 {
            if r != me {
                comm.send(r, ctrl(CT_EPOCH), decl.clone());
            }
        }
        comm.set_epoch(target);

        // ---- collect acks from the surviving group members.
        let ackers: Vec<u32> =
            expect.iter().copied().filter(|&p| !failed[p as usize]).collect();
        let mut acked = vec![false; world_n];
        let mut n_acked = 0usize;
        // difflb-lint: allow(wall-clock): failure-detection window is real time by design
        let deadline = Instant::now() + 3 * detect;
        while n_acked < ackers.len() {
            let left = deadline.saturating_duration_since(Instant::now()); // difflb-lint: allow(wall-clock): same window
            if left.is_zero() {
                break;
            }
            let Ok(m) = comm.recv_ctrl(left) else { break };
            if kind_of(m.tag) == CT_EPOCH_ACK {
                let mut r = wire::Reader::new(&m.data);
                if r.u32().is_ok_and(|v| v == target)
                    && ackers.contains(&m.from)
                    && !acked[m.from as usize]
                {
                    acked[m.from as usize] = true;
                    n_acked += 1;
                }
            }
        }
        if n_acked == ackers.len() {
            // every survivor acked: the group restarts the pipeline
            crate::obs::counter!("epoch.quorum_restarts").inc();
            return Membership::Member;
        }
        // a survivor died between probe and ack: run another cycle.
    }
}

fn recover_follower(
    comm: &mut Comm,
    coord: u32,
    detect: Duration,
    failed: &mut [bool],
) -> FollowerOutcome {
    // Report the fault we observed; if the coordinator is still healthy
    // and mid-pipeline, this parks in its pending buffer until its own
    // receive errors.
    comm.send(coord, ctrl(CT_FAULT), Vec::new());
    let me = comm.world_rank() as usize;
    // difflb-lint: allow(wall-clock): failure-detection window is real time by design
    let mut deadline = Instant::now() + 8 * detect;
    loop {
        let left = deadline.saturating_duration_since(Instant::now()); // difflb-lint: allow(wall-clock): same window
        if left.is_zero() {
            // Never heard a ping or declaration from `coord`: it is
            // dead or on the far side of a cut. Hand the decision back
            // to the election loop.
            return FollowerOutcome::CoordinatorSilent;
        }
        let Ok(m) = comm.recv_ctrl(left.min(detect)) else { continue };
        match kind_of(m.tag) {
            CT_PING => {
                // Answer whoever is probing — during an election
                // cascade the active coordinator may not be the one we
                // are waiting on yet, but its declaration settles us
                // all the same.
                comm.send(m.from, ctrl(CT_PONG), Vec::new());
                deadline = Instant::now() + 8 * detect; // difflb-lint: allow(wall-clock): same window
            }
            CT_EPOCH => {
                let Ok((epoch, flist)) = parse_epoch(&m.data) else {
                    continue; // corrupt declaration: treat as noise
                };
                if epoch <= comm.epoch() {
                    continue; // stale declaration from a cycle we saw
                }
                for r in flist {
                    failed[r as usize] = true;
                }
                if failed[me] {
                    crate::obs::mark("epoch.excluded", "recovery");
                    return FollowerOutcome::Done(Membership::Excluded);
                }
                comm.set_epoch(epoch);
                let mut ack = Vec::new();
                wire::put_u32(&mut ack, epoch);
                comm.send(m.from, ctrl(CT_EPOCH_ACK), ack);
                return FollowerOutcome::Done(Membership::Member);
            }
            _ => {} // PONG/ACK echoes and early MAP handoffs: not ours
        }
    }
}

/// Adopt any epoch declarations that arrived while this rank was busy
/// (sleeping through a hang, or idle before a scheduled join): merge
/// their failed sets and jump to the newest epoch. Returns `true` if
/// this rank is now excluded. Non-epoch control traffic drained on the
/// way (stale probes) is dropped — an unanswered probe just reads as
/// "still silent", which is the truth.
pub(crate) fn catch_up(comm: &mut Comm, failed: &mut [bool]) -> bool {
    let mut newest = comm.epoch();
    for m in comm.drain_ctrl() {
        if is_epoch(m.tag) {
            let Ok((epoch, flist)) = parse_epoch(&m.data) else { continue };
            for r in flist {
                failed[r as usize] = true;
            }
            newest = newest.max(epoch);
        }
    }
    if newest > comm.epoch() {
        comm.set_epoch(newest);
    }
    failed[comm.world_rank() as usize]
}

/// [`catch_up`] for a rank the cluster must not mistake for dead: a
/// joiner polling for its first LBX broadcast while a fault fires
/// elsewhere in the same round. Besides adopting declarations, it
/// *answers* probes (so the coordinator's failure detector sees it
/// alive) and acks the newest declaration it adopted (so the
/// coordinator's ack collection completes without excluding it).
/// Returns `true` if a declaration named this rank failed — only a
/// fault of the joiner itself aborts the join.
pub(crate) fn catch_up_responsive(comm: &mut Comm, failed: &mut [bool]) -> bool {
    let me = comm.world_rank();
    let mut newest = comm.epoch();
    let mut declarer: Option<u32> = None;
    for m in comm.drain_ctrl() {
        match kind_of(m.tag) {
            CT_PING => comm.send(m.from, ctrl(CT_PONG), Vec::new()),
            CT_EPOCH => {
                let Ok((epoch, flist)) = parse_epoch(&m.data) else { continue };
                for r in flist {
                    failed[r as usize] = true;
                }
                if epoch > newest {
                    newest = epoch;
                    declarer = Some(m.from);
                }
            }
            _ => {}
        }
    }
    if newest > comm.epoch() {
        comm.set_epoch(newest);
        if !failed[me as usize] {
            if let Some(d) = declarer {
                let mut ack = Vec::new();
                wire::put_u32(&mut ack, newest);
                comm.send(d, ctrl(CT_EPOCH_ACK), ack);
            }
        }
    }
    failed[me as usize]
}

/// Send a one-off epoch declaration to `to` — the driver's "welcome
/// back" for a healed partition minority, carrying the majority's
/// current epoch and cumulative failed set so the rejoiner catches up
/// before its first LBX arrives (per-sender FIFO guarantees the order).
/// Lives here so [`CTRL_NS`] stays confined to the epoch layer.
pub(crate) fn declare_to(comm: &mut Comm, to: u32, epoch: u32, failed: &[bool]) {
    comm.send(to, ctrl(CT_EPOCH), encode_epoch(epoch, failed));
}

/// Per-round fault-injection context for [`staged_pipeline`].
pub(crate) struct FaultCtx<'a> {
    pub plan: &'a FaultPlan,
    pub lb_round: u32,
    /// Whether this round's scheduled event already fired — a pipeline
    /// retry after recovery must not replay it (a hang victim that
    /// survived exclusion would otherwise starve every retry).
    pub fired: bool,
}

impl FaultCtx<'_> {
    pub fn new(plan: &FaultPlan, lb_round: u32) -> FaultCtx<'_> {
        FaultCtx { plan, lb_round, fired: false }
    }
}

/// Execute this rank's scheduled fault at a stage entry, if any.
/// Returns `false` when the rank must exit dead (killed, or hung past
/// its exclusion).
fn fault_gate(comm: &mut Comm, ctx: &mut FaultCtx, stage: StagePoint, failed: &mut [bool]) -> bool {
    if ctx.fired {
        return true;
    }
    let me = comm.world_rank();
    let Some(ev) = ctx.plan.my_fault(me, ctx.lb_round) else { return true };
    if ev.stage != stage {
        return true;
    }
    ctx.fired = true;
    match ev.kind {
        FaultKind::Kill => false,
        FaultKind::Delay => {
            std::thread::sleep(Duration::from_millis(ctx.plan.delay_ms));
            true
        }
        FaultKind::Hang => {
            std::thread::sleep(Duration::from_millis(ctx.plan.hang_ms));
            // The cluster moved on while we slept; if it excluded us the
            // declaration names us. If detection somehow hasn't finished
            // yet, continue — our next receive errors and we rejoin the
            // recovery cycle as an ordinary follower.
            !catch_up(comm, failed)
        }
    }
}

/// [`node_pipeline`](super::node_pipeline) with fault-injection gates at
/// each stage entry, run on the current (possibly narrowed) group
/// against the restricted instance. `Ok(None)` means this rank's
/// scheduled death fired (the caller exits the node thread); `Err`
/// means a *peer's* failure starved a stage (the caller runs
/// [`recover`] and retries on the survivors).
pub(crate) fn staged_pipeline(
    comm: &mut Comm,
    inst: &Instance,
    my_cands: &[u32],
    variant: Variant,
    params: &StrategyParams,
    ctx: &mut FaultCtx<'_>,
    failed: &mut [bool],
) -> Result<Option<NodeOutcome>, CommError> {
    if !fault_gate(comm, ctx, StagePoint::Handshake, failed) {
        return Ok(None);
    }
    let adj = {
        let _s1 = crate::obs::span("stage1.handshake", "dist");
        protocol::handshake_node(
            comm,
            my_cands,
            params.neighbor_count,
            params.handshake_max_rounds,
            TAG_HANDSHAKE,
        )?
    };
    let my_load = node_load(inst, comm.rank);
    if !fault_gate(comm, ctx, StagePoint::VirtualLb, failed) {
        return Ok(None);
    }
    let s2 = {
        let _s2 = crate::obs::span("stage2.virtual", "dist");
        stage2::virtual_balance_node(
            comm,
            &adj,
            my_load,
            params.vlb_tolerance,
            params.vlb_max_iters,
            TAG_STAGE2,
        )?
    };
    if !fault_gate(comm, ctx, StagePoint::Selection, failed) {
        return Ok(None);
    }
    let s3 = {
        let _s3 = crate::obs::span("stage3.select", "dist");
        stage3::select_and_refine_node(
            comm,
            inst,
            variant,
            &s2.flow_row,
            params.overfill,
            params.refine_tolerance,
            TAG_STAGE3,
        )?
    };
    Ok(Some(NodeOutcome {
        adj,
        flow_row: s2.flow_row,
        iterations: s2.iterations,
        manifest: s3.manifest,
        migrations: s3.migrations,
        recv_bytes: s3.recv_bytes,
        full_mapping: s3.full_mapping,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::network::Cluster;

    #[test]
    fn recovery_excludes_the_silent_rank_and_advances_the_epoch() {
        let plan = {
            let mut p = FaultPlan::none();
            p.detect_ms = 100;
            p
        };
        let results = Cluster::run(3, move |rank, mut comm| {
            if rank == 2 {
                return None; // dies before answering any probe
            }
            let mut failed = vec![false; 3];
            let m = recover(&mut comm, &plan, &[0, 1, 2], &mut failed, &[false; 3]);
            Some((m, comm.epoch(), failed))
        });
        let (m0, e0, f0) = results[0].clone().expect("root result");
        let (m1, e1, f1) = results[1].clone().expect("follower result");
        assert_eq!(m0, Membership::Member);
        assert_eq!(m1, Membership::Member);
        assert_eq!((e0, e1), (1, 1));
        assert_eq!(f0, vec![false, false, true]);
        assert_eq!(f1, vec![false, false, true]);
    }

    #[test]
    fn isolated_follower_gives_up_as_excluded() {
        // No coordinator ever answers. The follower marks it failed,
        // re-elects itself — and finds 1 of 2 ranks is no quorum, so it
        // exits dead instead of blocking the cluster teardown.
        let plan = {
            let mut p = FaultPlan::none();
            p.detect_ms = 30;
            p
        };
        let results = Cluster::run(2, move |rank, mut comm| {
            if rank == 0 {
                // absorb nothing; just outlive the follower's window
                std::thread::sleep(Duration::from_millis(400));
                return None;
            }
            let mut failed = vec![false; 2];
            Some(recover(&mut comm, &plan, &[0, 1], &mut failed, &[false; 2]))
        });
        assert_eq!(results[1], Some(Membership::Excluded));
    }

    #[test]
    fn election_is_lowest_alive_and_skips_barred_ranks() {
        assert_eq!(elect(&[false, false, false], &[false; 3]), 0);
        assert_eq!(elect(&[true, false, false], &[false; 3]), 1);
        assert_eq!(elect(&[true, false, false], &[false, true, false]), 2);
        // every survivor barred: fall back to the lowest survivor
        assert_eq!(elect(&[true, false, true], &[false, true, false]), 1);
        assert_eq!(successor(&[true, false, false], &[false; 3], 1), Some(2));
        assert_eq!(successor(&[true, false, true], &[false; 3], 1), None);
    }

    #[test]
    fn survivors_elect_a_coordinator_when_rank_zero_dies() {
        // Rank 0 — the initial coordinator — dies. Ranks 1 and 2 must
        // time out on it, re-elect rank 1, and finish the cycle with
        // identical epochs and failed sets.
        let plan = {
            let mut p = FaultPlan::none();
            p.detect_ms = 60;
            p
        };
        let results = Cluster::run(3, move |rank, mut comm| {
            if rank == 0 {
                return None; // the coordinator itself is the casualty
            }
            let mut failed = vec![false; 3];
            let m = recover(&mut comm, &plan, &[0, 1, 2], &mut failed, &[false; 3]);
            Some((m, comm.epoch(), failed))
        });
        let (m1, e1, f1) = results[1].clone().expect("rank 1 result");
        let (m2, e2, f2) = results[2].clone().expect("rank 2 result");
        assert_eq!(m1, Membership::Member);
        assert_eq!(m2, Membership::Member);
        assert_eq!((e1, e2), (1, 1));
        assert_eq!(f1, vec![true, false, false]);
        assert_eq!(f2, vec![true, false, false]);
    }

    #[test]
    fn map_tag_distinguishes_rounds_past_the_old_16_bit_field() {
        assert_ne!(map_tag(0), map_tag(1 << 16), "rounds must not alias at 65536");
        assert_eq!(map_tag(3) & 0xF, CT_MAP);
        assert!(is_map(map_tag(70_000)));
        assert!(!is_epoch(map_tag(70_000)));
    }

    #[test]
    fn parse_epoch_rejects_truncated_and_lying_frames() {
        let good = encode_epoch(7, &[false, true, true]);
        assert_eq!(parse_epoch(&good), Ok((7, vec![1, 2])));
        assert!(parse_epoch(&good[..good.len() - 2]).is_err());
        assert!(parse_epoch(&[1, 0, 0, 0]).is_err(), "missing count");
        // a counted length larger than the frame must error, not OOM
        let mut lying = Vec::new();
        wire::put_u32(&mut lying, 1);
        wire::put_u32(&mut lying, u32::MAX);
        assert!(parse_epoch(&lying).is_err());
    }

    #[test]
    fn staged_pipeline_kill_dies_and_starves_the_peer() {
        let inst = crate::apps::stencil::stencil_2d(
            8,
            2,
            1,
            crate::apps::stencil::Decomposition::Tiled,
        );
        let plan = FaultPlan::parse("kill:1@0:s1").expect("plan");
        let shared = std::sync::Arc::new((inst, plan));
        let results = Cluster::run(2, move |rank, mut comm| {
            let (inst, plan) = &*shared;
            comm.set_patience(Duration::from_millis(100));
            let params = StrategyParams::default();
            let cands = super::super::build_candidates(inst, Variant::Communication, &params);
            let mut ctx = FaultCtx::new(plan, 0);
            let mut failed = vec![false; 2];
            let out = staged_pipeline(
                &mut comm,
                inst,
                &cands[rank as usize],
                Variant::Communication,
                &params,
                &mut ctx,
                &mut failed,
            );
            match out {
                Ok(Some(_)) => "completed",
                Ok(None) => "died",
                Err(_) => "starved",
            }
        });
        assert_eq!(results, vec!["starved", "died"]);
    }

    #[test]
    fn staged_pipeline_delay_is_invisible_to_the_outcome() {
        let inst = crate::apps::stencil::stencil_2d(
            8,
            2,
            2,
            crate::apps::stencil::Decomposition::Tiled,
        );
        let baseline = super::super::run_pipeline(
            &inst,
            Variant::Communication,
            StrategyParams::default(),
        )
        .assignment
        .mapping;
        let plan = FaultPlan::parse("delay:1@0:s2").expect("plan");
        let shared = std::sync::Arc::new((inst, plan));
        let mappings = Cluster::run(4, move |rank, mut comm| {
            let (inst, plan) = &*shared;
            let params = StrategyParams::default();
            let cands = super::super::build_candidates(inst, Variant::Communication, &params);
            let mut ctx = FaultCtx::new(plan, 0);
            let mut failed = vec![false; 4];
            staged_pipeline(
                &mut comm,
                inst,
                &cands[rank as usize],
                Variant::Communication,
                &params,
                &mut ctx,
                &mut failed,
            )
            .expect("delay must not break the protocol")
            .expect("no rank dies under a delay")
            .full_mapping
        });
        for m in &mappings {
            assert_eq!(m, &baseline, "a delayed rank changed the outcome");
        }
    }
}
