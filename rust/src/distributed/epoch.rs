//! Failure detection and pipeline restart: the membership-epoch
//! protocol that turns a mid-pipeline [`CommError`] into a quorum
//! restart on the surviving ranks instead of an aborted run.
//!
//! Rank 0 is the failure coordinator (leader election is out of scope —
//! [`FaultPlan::validate`] rejects plans that target it, matching the
//! stable-LB-root assumption of the paper's runtime). The protocol is a
//! standard probe/declare/ack cycle over the control namespace
//! ([`CTRL_NS`] tags bypass epoch filtering, so recovery traffic is
//! deliverable from any epoch):
//!
//! 1. **Probe** — the coordinator pings every rank of the failed
//!    pipeline group. A healthy rank is either already in its own
//!    recovery loop (its stage receive errored too — the pipeline is
//!    globally synchronized, so one silent rank starves everyone within
//!    a patience window) and answers `PONG`, or its spontaneous `FAULT`
//!    report is already parked in the coordinator's pending buffer.
//!    Ranks silent for the whole probe window are declared failed.
//! 2. **Declare** — the coordinator bumps the epoch and broadcasts
//!    `EPOCH {epoch, failed set}` to every world rank (best effort:
//!    sends to dead endpoints are dropped, sends across a partition are
//!    cut). Stamping the *cumulative* failed set makes declarations
//!    self-contained: a rank that slept through three epochs catches up
//!    from the newest one alone.
//! 3. **Ack** — surviving group members adopt the epoch (draining their
//!    pending buffers of pre-fault traffic — see [`Comm::set_epoch`])
//!    and ack. A survivor dying *between* probe and ack re-enters the
//!    cycle; an isolated rank (partition minority) never hears the
//!    declaration and exits after a bounded wait.
//!
//! [`staged_pipeline`] wraps the plain
//! [`node_pipeline`](super::node_pipeline) with [`FaultPlan`] injection
//! gates at each stage entry; the fault-free driver path never calls it,
//! so inactive plans keep the bit-identical pipeline untouched.

use std::time::{Duration, Instant};

use crate::model::Instance;
use crate::simnet::fault::{FaultKind, FaultPlan, StagePoint};
use crate::simnet::network::{Comm, CommError, CTRL_NS};
use crate::simnet::protocol;
use crate::strategies::diffusion::Variant;
use crate::strategies::StrategyParams;

use super::{node_load, stage2, stage3, wire, NodeOutcome, TAG_HANDSHAKE, TAG_STAGE2, TAG_STAGE3};

/// Control-message kinds (low byte of a [`CTRL_NS`] tag).
const CT_PING: u32 = 1;
const CT_PONG: u32 = 2;
const CT_FAULT: u32 = 3;
const CT_EPOCH: u32 = 4;
const CT_EPOCH_ACK: u32 = 5;
const CT_MAP: u32 = 6;

const fn ctrl(kind: u32) -> u32 {
    CTRL_NS | kind
}

const fn kind_of(tag: u32) -> u32 {
    tag & 0xFF
}

/// The tag carrying the final world mapping to a scheduled leaver after
/// LB round `lb_round` — control-namespace so the leaver (which did not
/// participate in the round's pipeline and may be an epoch behind)
/// still receives it.
pub(crate) fn map_tag(lb_round: u32) -> u32 {
    CTRL_NS | ((lb_round & 0xFFFF) << 8) | CT_MAP
}

/// Whether a control message is a final-mapping handoff ([`map_tag`]).
pub(crate) fn is_map(tag: u32) -> bool {
    kind_of(tag) == CT_MAP
}

/// Whether a control message is an epoch declaration.
pub(crate) fn is_epoch(tag: u32) -> bool {
    kind_of(tag) == CT_EPOCH
}

/// Encode an epoch declaration: `epoch`, then the cumulative failed
/// set as a counted list of world ranks.
pub(crate) fn encode_epoch(epoch: u32, failed: &[bool]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(8 + failed.len() * 4);
    wire::put_u32(&mut buf, epoch);
    let n = failed.iter().filter(|&&f| f).count();
    wire::put_u32(&mut buf, n as u32);
    for (r, &f) in failed.iter().enumerate() {
        if f {
            wire::put_u32(&mut buf, r as u32);
        }
    }
    buf
}

/// Decode [`encode_epoch`]: `(epoch, failed world ranks)`.
pub(crate) fn parse_epoch(data: &[u8]) -> (u32, Vec<u32>) {
    let mut r = wire::Reader::new(data);
    let epoch = r.u32();
    let n = r.u32();
    let mut ranks = Vec::with_capacity(n as usize);
    for _ in 0..n {
        ranks.push(r.u32());
    }
    (epoch, ranks)
}

/// What the recovery cycle decided about this rank.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Membership {
    /// Part of the new epoch: retry the interrupted stage on the
    /// surviving group.
    Member,
    /// Declared failed or isolated from the coordinator: exit dead.
    Excluded,
}

/// Run one probe/declare/ack recovery cycle after a pipeline
/// [`CommError`]. `participants` are the world ranks of the pipeline
/// group that just failed; `failed` is the caller's cumulative failed
/// set, updated in place. On [`Membership::Member`] the endpoint's
/// epoch has advanced and its pending buffer holds no pre-fault
/// traffic. Panics if the survivors would lose quorum — there is no
/// meaningful way to continue the run.
pub(crate) fn recover(
    comm: &mut Comm,
    plan: &FaultPlan,
    participants: &[u32],
    failed: &mut [bool],
) -> Membership {
    let _sr = crate::obs::span("recover", "recovery");
    comm.leave_group();
    let detect = plan.detect_timeout();
    if comm.world_rank() == 0 {
        recover_root(comm, detect, participants, failed)
    } else {
        recover_follower(comm, detect, failed)
    }
}

fn recover_root(
    comm: &mut Comm,
    detect: Duration,
    participants: &[u32],
    failed: &mut [bool],
) -> Membership {
    let world_n = comm.world_n();
    loop {
        // ---- probe the current pipeline group.
        let expect: Vec<u32> = participants
            .iter()
            .copied()
            .filter(|&p| p != 0 && !failed[p as usize])
            .collect();
        for &p in &expect {
            comm.send(p, ctrl(CT_PING), Vec::new());
        }
        crate::obs::counter!("epoch.heartbeats").add(expect.len() as u64);
        let mut alive = vec![false; world_n];
        let mut n_alive = 0usize;
        // difflb-lint: allow(wall-clock): failure-detection window is real time by design
        let deadline = Instant::now() + 3 * detect;
        while n_alive < expect.len() {
            let left = deadline.saturating_duration_since(Instant::now()); // difflb-lint: allow(wall-clock): same window
            if left.is_zero() {
                break;
            }
            let Ok(m) = comm.recv_ctrl(left) else { break };
            let k = kind_of(m.tag);
            if (k == CT_PONG || k == CT_FAULT)
                && expect.contains(&m.from)
                && !alive[m.from as usize]
            {
                alive[m.from as usize] = true;
                n_alive += 1;
            }
            // stale acks from an earlier cycle and duplicate fault
            // reports fall through harmlessly.
        }
        for &p in &expect {
            if !alive[p as usize] {
                failed[p as usize] = true;
            }
        }
        let n_failed = failed.iter().filter(|&&f| f).count();
        assert!(
            2 * (world_n - n_failed) > world_n,
            "quorum lost: {n_failed} of {world_n} ranks failed"
        );

        // ---- declare the new epoch. Best-effort to every world rank:
        // dead endpoints drop the send, partitioned ones never see it,
        // and excluded-but-alive ranks (hang victims) learn their fate
        // from the failed set on waking.
        let target = comm.epoch() + 1;
        crate::obs::counter!("epoch.declarations").inc();
        crate::obs::mark("epoch.declare", "recovery");
        let decl = encode_epoch(target, failed);
        for r in 1..world_n as u32 {
            comm.send(r, ctrl(CT_EPOCH), decl.clone());
        }
        comm.set_epoch(target);

        // ---- collect acks from the surviving group members.
        let ackers: Vec<u32> =
            expect.iter().copied().filter(|&p| !failed[p as usize]).collect();
        let mut acked = vec![false; world_n];
        let mut n_acked = 0usize;
        // difflb-lint: allow(wall-clock): failure-detection window is real time by design
        let deadline = Instant::now() + 3 * detect;
        while n_acked < ackers.len() {
            let left = deadline.saturating_duration_since(Instant::now()); // difflb-lint: allow(wall-clock): same window
            if left.is_zero() {
                break;
            }
            let Ok(m) = comm.recv_ctrl(left) else { break };
            if kind_of(m.tag) == CT_EPOCH_ACK {
                let mut r = wire::Reader::new(&m.data);
                if r.u32() == target
                    && ackers.contains(&m.from)
                    && !acked[m.from as usize]
                {
                    acked[m.from as usize] = true;
                    n_acked += 1;
                }
            }
        }
        if n_acked == ackers.len() {
            // every survivor acked: the group restarts the pipeline
            crate::obs::counter!("epoch.quorum_restarts").inc();
            return Membership::Member;
        }
        // a survivor died between probe and ack: run another cycle.
    }
}

fn recover_follower(comm: &mut Comm, detect: Duration, failed: &mut [bool]) -> Membership {
    // Report the fault we observed; if the coordinator is still healthy
    // and mid-pipeline, this parks in its pending buffer until its own
    // receive errors.
    comm.send(0, ctrl(CT_FAULT), Vec::new());
    let me = comm.world_rank() as usize;
    // difflb-lint: allow(wall-clock): failure-detection window is real time by design
    let mut deadline = Instant::now() + 8 * detect;
    loop {
        let left = deadline.saturating_duration_since(Instant::now()); // difflb-lint: allow(wall-clock): same window
        if left.is_zero() {
            // Never heard a declaration: we are on the wrong side of a
            // partition (or were excluded in an epoch whose declaration
            // was cut). Exit dead rather than block the survivors.
            return Membership::Excluded;
        }
        let Ok(m) = comm.recv_ctrl(left.min(detect)) else { continue };
        match kind_of(m.tag) {
            CT_PING => {
                comm.send(0, ctrl(CT_PONG), Vec::new());
                // an active coordinator is still cycling: keep waiting.
                deadline = Instant::now() + 8 * detect; // difflb-lint: allow(wall-clock): same window
            }
            CT_EPOCH => {
                let (epoch, flist) = parse_epoch(&m.data);
                if epoch <= comm.epoch() {
                    continue; // stale declaration from a cycle we saw
                }
                for r in flist {
                    failed[r as usize] = true;
                }
                if failed[me] {
                    crate::obs::mark("epoch.excluded", "recovery");
                    return Membership::Excluded;
                }
                comm.set_epoch(epoch);
                let mut ack = Vec::new();
                wire::put_u32(&mut ack, epoch);
                comm.send(0, ctrl(CT_EPOCH_ACK), ack);
                return Membership::Member;
            }
            _ => {} // PONG/ACK echoes and early MAP handoffs: not ours
        }
    }
}

/// Adopt any epoch declarations that arrived while this rank was busy
/// (sleeping through a hang, or idle before a scheduled join): merge
/// their failed sets and jump to the newest epoch. Returns `true` if
/// this rank is now excluded. Non-epoch control traffic drained on the
/// way (stale probes) is dropped — an unanswered probe just reads as
/// "still silent", which is the truth.
pub(crate) fn catch_up(comm: &mut Comm, failed: &mut [bool]) -> bool {
    let mut newest = comm.epoch();
    for m in comm.drain_ctrl() {
        if is_epoch(m.tag) {
            let (epoch, flist) = parse_epoch(&m.data);
            for r in flist {
                failed[r as usize] = true;
            }
            newest = newest.max(epoch);
        }
    }
    if newest > comm.epoch() {
        comm.set_epoch(newest);
    }
    failed[comm.world_rank() as usize]
}

/// Per-round fault-injection context for [`staged_pipeline`].
pub(crate) struct FaultCtx<'a> {
    pub plan: &'a FaultPlan,
    pub lb_round: u32,
    /// Whether this round's scheduled event already fired — a pipeline
    /// retry after recovery must not replay it (a hang victim that
    /// survived exclusion would otherwise starve every retry).
    pub fired: bool,
}

impl FaultCtx<'_> {
    pub fn new(plan: &FaultPlan, lb_round: u32) -> FaultCtx<'_> {
        FaultCtx { plan, lb_round, fired: false }
    }
}

/// Execute this rank's scheduled fault at a stage entry, if any.
/// Returns `false` when the rank must exit dead (killed, or hung past
/// its exclusion).
fn fault_gate(comm: &mut Comm, ctx: &mut FaultCtx, stage: StagePoint, failed: &mut [bool]) -> bool {
    if ctx.fired {
        return true;
    }
    let me = comm.world_rank();
    let Some(ev) = ctx.plan.my_fault(me, ctx.lb_round) else { return true };
    if ev.stage != stage {
        return true;
    }
    ctx.fired = true;
    match ev.kind {
        FaultKind::Kill => false,
        FaultKind::Delay => {
            std::thread::sleep(Duration::from_millis(ctx.plan.delay_ms));
            true
        }
        FaultKind::Hang => {
            std::thread::sleep(Duration::from_millis(ctx.plan.hang_ms));
            // The cluster moved on while we slept; if it excluded us the
            // declaration names us. If detection somehow hasn't finished
            // yet, continue — our next receive errors and we rejoin the
            // recovery cycle as an ordinary follower.
            !catch_up(comm, failed)
        }
    }
}

/// [`node_pipeline`](super::node_pipeline) with fault-injection gates at
/// each stage entry, run on the current (possibly narrowed) group
/// against the restricted instance. `Ok(None)` means this rank's
/// scheduled death fired (the caller exits the node thread); `Err`
/// means a *peer's* failure starved a stage (the caller runs
/// [`recover`] and retries on the survivors).
pub(crate) fn staged_pipeline(
    comm: &mut Comm,
    inst: &Instance,
    my_cands: &[u32],
    variant: Variant,
    params: &StrategyParams,
    ctx: &mut FaultCtx<'_>,
    failed: &mut [bool],
) -> Result<Option<NodeOutcome>, CommError> {
    if !fault_gate(comm, ctx, StagePoint::Handshake, failed) {
        return Ok(None);
    }
    let adj = {
        let _s1 = crate::obs::span("stage1.handshake", "dist");
        protocol::handshake_node(
            comm,
            my_cands,
            params.neighbor_count,
            params.handshake_max_rounds,
            TAG_HANDSHAKE,
        )?
    };
    let my_load = node_load(inst, comm.rank);
    if !fault_gate(comm, ctx, StagePoint::VirtualLb, failed) {
        return Ok(None);
    }
    let s2 = {
        let _s2 = crate::obs::span("stage2.virtual", "dist");
        stage2::virtual_balance_node(
            comm,
            &adj,
            my_load,
            params.vlb_tolerance,
            params.vlb_max_iters,
            TAG_STAGE2,
        )?
    };
    if !fault_gate(comm, ctx, StagePoint::Selection, failed) {
        return Ok(None);
    }
    let s3 = {
        let _s3 = crate::obs::span("stage3.select", "dist");
        stage3::select_and_refine_node(
            comm,
            inst,
            variant,
            &s2.flow_row,
            params.overfill,
            params.refine_tolerance,
            TAG_STAGE3,
        )?
    };
    Ok(Some(NodeOutcome {
        adj,
        flow_row: s2.flow_row,
        iterations: s2.iterations,
        manifest: s3.manifest,
        migrations: s3.migrations,
        recv_bytes: s3.recv_bytes,
        full_mapping: s3.full_mapping,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::network::Cluster;

    #[test]
    fn recovery_excludes_the_silent_rank_and_advances_the_epoch() {
        let plan = {
            let mut p = FaultPlan::none();
            p.detect_ms = 100;
            p
        };
        let results = Cluster::run(3, move |rank, mut comm| {
            if rank == 2 {
                return None; // dies before answering any probe
            }
            let mut failed = vec![false; 3];
            let m = recover(&mut comm, &plan, &[0, 1, 2], &mut failed);
            Some((m, comm.epoch(), failed))
        });
        let (m0, e0, f0) = results[0].clone().expect("root result");
        let (m1, e1, f1) = results[1].clone().expect("follower result");
        assert_eq!(m0, Membership::Member);
        assert_eq!(m1, Membership::Member);
        assert_eq!((e0, e1), (1, 1));
        assert_eq!(f0, vec![false, false, true]);
        assert_eq!(f1, vec![false, false, true]);
    }

    #[test]
    fn isolated_follower_gives_up_as_excluded() {
        // No coordinator ever answers: the follower must bound its wait
        // and exit dead instead of blocking the cluster teardown.
        let plan = {
            let mut p = FaultPlan::none();
            p.detect_ms = 30;
            p
        };
        let results = Cluster::run(2, move |rank, mut comm| {
            if rank == 0 {
                // absorb nothing; just outlive the follower's window
                std::thread::sleep(Duration::from_millis(400));
                return None;
            }
            let mut failed = vec![false; 2];
            Some(recover(&mut comm, &plan, &[0, 1], &mut failed))
        });
        assert_eq!(results[1], Some(Membership::Excluded));
    }

    #[test]
    fn staged_pipeline_kill_dies_and_starves_the_peer() {
        let inst = crate::apps::stencil::stencil_2d(
            8,
            2,
            1,
            crate::apps::stencil::Decomposition::Tiled,
        );
        let plan = FaultPlan::parse("kill:1@0:s1").expect("plan");
        let shared = std::sync::Arc::new((inst, plan));
        let results = Cluster::run(2, move |rank, mut comm| {
            let (inst, plan) = &*shared;
            comm.set_patience(Duration::from_millis(100));
            let params = StrategyParams::default();
            let cands = super::super::build_candidates(inst, Variant::Communication, &params);
            let mut ctx = FaultCtx::new(plan, 0);
            let mut failed = vec![false; 2];
            let out = staged_pipeline(
                &mut comm,
                inst,
                &cands[rank as usize],
                Variant::Communication,
                &params,
                &mut ctx,
                &mut failed,
            );
            match out {
                Ok(Some(_)) => "completed",
                Ok(None) => "died",
                Err(_) => "starved",
            }
        });
        assert_eq!(results, vec!["starved", "died"]);
    }

    #[test]
    fn staged_pipeline_delay_is_invisible_to_the_outcome() {
        let inst = crate::apps::stencil::stencil_2d(
            8,
            2,
            2,
            crate::apps::stencil::Decomposition::Tiled,
        );
        let baseline = super::super::run_pipeline(
            &inst,
            Variant::Communication,
            StrategyParams::default(),
        )
        .assignment
        .mapping;
        let plan = FaultPlan::parse("delay:1@0:s2").expect("plan");
        let shared = std::sync::Arc::new((inst, plan));
        let mappings = Cluster::run(4, move |rank, mut comm| {
            let (inst, plan) = &*shared;
            let params = StrategyParams::default();
            let cands = super::super::build_candidates(inst, Variant::Communication, &params);
            let mut ctx = FaultCtx::new(plan, 0);
            let mut failed = vec![false; 4];
            staged_pipeline(
                &mut comm,
                inst,
                &cands[rank as usize],
                Variant::Communication,
                &params,
                &mut ctx,
                &mut failed,
            )
            .expect("delay must not break the protocol")
            .expect("no rank dies under a delay")
            .full_mapping
        });
        for m in &mappings {
            assert_eq!(m, &baseline, "a delayed rank changed the outcome");
        }
    }
}
