//! Fully distributed LB runtime: the **entire** diffusion pipeline —
//! stage-1 neighbor handshake, stage-2 virtual load balancing, stage-3
//! object selection, and the §III-D hierarchical refinement — executed
//! per-node as real message-passing protocols over
//! [`simnet::Cluster`](crate::simnet::Cluster), plus a distributed
//! application driver ([`driver`]) that runs any node-partitionable
//! app ([`driver::DistApp`] — PIC and the drifting hotspot today) with
//! node-partitioned object state and realizes migrations as real
//! payload transfers.
//!
//! The paper's strategy is distributed by construction (every node
//! decides from local state inside Charm++); the sequential
//! [`Diffusion`](crate::strategies::diffusion::Diffusion) strategy is a
//! round-synchronous *model* of that execution. This module closes the
//! gap the same way diffusive-advection (arXiv:2208.07553) and
//! indivisible-load diffusion (arXiv:1308.0148) reproductions validate
//! their models: by actually exchanging the messages and asserting the
//! outcome is **bit-identical** to the model (`rust/tests/distributed.rs`
//! cross-validates assignments across seeds, node counts and both
//! variants).
//!
//! What is local and what travels (see DESIGN.md for the substitution
//! table):
//!
//! * stage 1 — [`protocol::handshake_node`]: REQ/RESP/ACK/DONE messages
//!   bound every node's degree by K;
//! * stage 2 — [`stage2::virtual_balance_node`]: per-sweep load-scalar
//!   exchange with the handshaked neighbors, transfers applied locally,
//!   global termination via a DONE-bit (+ exact moved-sum) reduction
//!   rooted at rank 0;
//! * stage 3 — [`stage3::select_and_refine_node`]: each overloaded node
//!   picks objects locally against its [`LbScratch`]
//!   (`select_*_node`, the same per-node body the sequential sweep
//!   runs) and ships `(object id, destination, bytes)` migration
//!   manifests; manifests replay in rank order so every node's replica
//!   of the object→node map passes through exactly the interim states
//!   the sequential sweep produces — that rank-ordered replay is what
//!   the bit-identity guarantee costs;
//! * refinement — [`hierarchical::assign_pes_node`]: node-local by
//!   construction (no messages), PE assignments exchanged at the end.
//!
//! The read-only problem [`Instance`] (loads, coordinates, comm graph)
//! is shared by `Arc` rather than serialized to every node: the paper's
//! runtime gives each node its local objects *and* their communication
//! edges, which is all the per-node bodies read; sharing the snapshot
//! stands in for that bootstrap without inventing wire formats for it.
//! Everything decision-carrying — loads during diffusion, transfer
//! amounts, migration manifests, PE assignments, termination bits — is
//! a real message.

pub mod driver;
pub mod epoch;
pub mod stage2;
pub mod stage3;

use std::sync::Arc;

use crate::model::{Assignment, Instance};
use crate::simnet::network::{Cluster, Comm, CommError};
use crate::simnet::protocol;
use crate::strategies::diffusion::neighbor::{self, Candidates, NeighborGraph};
use crate::strategies::diffusion::virtual_lb::Quotas;
use crate::strategies::diffusion::Variant;
use crate::strategies::{LoadBalancer, StrategyParams};

/// Tag namespaces (top byte) keeping the pipeline's protocol phases
/// disjoint on one [`Comm`] endpoint. Safe to reuse across LB rounds:
/// every phase has exact send/receive counts and a synchronized exit,
/// so no message of a finished round can linger into the next.
pub(crate) const TAG_HANDSHAKE: u32 = 0x0100_0000;
pub(crate) const TAG_STAGE2: u32 = 0x0200_0000;
pub(crate) const TAG_STAGE3: u32 = 0x0300_0000;

/// Minimal byte-level wire helpers (little-endian scalars appended to a
/// message payload). serde is unavailable offline; the protocols only
/// ever ship flat scalar records.
///
/// Decoding is length-checked: every scalar read returns
/// `Result<_, Truncated>` so a short or corrupt frame surfaces as a
/// [`CommError::Corrupt`](crate::simnet::network::CommError) at the
/// protocol layer instead of an index-out-of-bounds panic inside a node
/// thread (which would poison the whole cluster join).
pub(crate) mod wire {
    pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
        buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(buf: &mut Vec<u8>, v: f64) {
        buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
        buf.extend_from_slice(&v.to_le_bytes());
    }

    /// A frame ended before the scalar being decoded: `need` bytes were
    /// required at the cursor, only `have` remained.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub struct Truncated {
        pub need: usize,
        pub have: usize,
    }

    impl std::fmt::Display for Truncated {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "truncated frame: needed {} bytes, had {}", self.need, self.have)
        }
    }

    impl std::error::Error for Truncated {}

    /// Cursor over a received payload.
    pub struct Reader<'a> {
        buf: &'a [u8],
        pos: usize,
    }

    impl<'a> Reader<'a> {
        pub fn new(buf: &'a [u8]) -> Reader<'a> {
            Reader { buf, pos: 0 }
        }

        /// The next `n` bytes, or [`Truncated`] if the frame is short.
        fn take(&mut self, n: usize) -> Result<&'a [u8], Truncated> {
            let have = self.buf.len() - self.pos.min(self.buf.len());
            if have < n {
                return Err(Truncated { need: n, have });
            }
            let s = &self.buf[self.pos..self.pos + n];
            self.pos += n;
            Ok(s)
        }

        pub fn u32(&mut self) -> Result<u32, Truncated> {
            Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("take returned 4 bytes")))
        }

        pub fn f64(&mut self) -> Result<f64, Truncated> {
            Ok(f64::from_le_bytes(self.take(8)?.try_into().expect("take returned 8 bytes")))
        }

        pub fn u64(&mut self) -> Result<u64, Truncated> {
            Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("take returned 8 bytes")))
        }

        pub fn is_empty(&self) -> bool {
            self.pos >= self.buf.len()
        }

        /// Bytes left after the cursor — bounds `with_capacity` calls so
        /// an untrusted count can never drive allocation past the frame.
        pub fn remaining(&self) -> usize {
            self.buf.len() - self.pos.min(self.buf.len())
        }

        /// Everything after the cursor — for payloads that end in an
        /// opaque sub-encoded blob (the telemetry gather's trace bytes).
        pub fn rest(&self) -> &'a [u8] {
            &self.buf[self.pos.min(self.buf.len())..]
        }
    }
}

/// What one node's pipeline run produced (the strategy assembles these;
/// the distributed driver consumes them in place).
pub struct NodeOutcome {
    /// Stage-1 confirmed neighbors (sorted).
    pub adj: Vec<u32>,
    /// Stage-2 send quotas: this node's row of [`Quotas::flows`].
    pub flow_row: Vec<(u32, f64)>,
    /// Stage-2 sweeps executed (identical on every node).
    pub iterations: usize,
    /// Stage-3 migrations this node decided, in pick order.
    pub manifest: Vec<(u32, u32)>,
    /// Objects this node migrated away.
    pub migrations: usize,
    /// Manifest bytes that arrived *at* this node.
    pub recv_bytes: f64,
    /// The fully assembled object → PE mapping (every node holds an
    /// identical copy after the final PE-assignment exchange).
    pub full_mapping: Vec<u32>,
}

/// Candidate preference lists for a variant — the same construction the
/// sequential strategy performs in stage 1. Shared read-only input to
/// every node (each node consumes only its own row, exactly like
/// [`protocol::distributed_select_neighbors`]).
pub fn build_candidates(
    inst: &Instance,
    variant: Variant,
    params: &StrategyParams,
) -> Candidates {
    let node_map = inst.node_mapping();
    match variant {
        Variant::Communication => neighbor::comm_candidates(inst, &node_map),
        Variant::Coordinate => {
            if params.sfc_window > 0 {
                neighbor::coord_candidates_sfc(inst, &node_map, params.sfc_window)
            } else {
                neighbor::coord_candidates(inst, &node_map)
            }
        }
    }
}

/// This node's stage-2 load scalar, accumulated in object order — the
/// same left-to-right additions `Instance::node_loads_into` performs
/// for this node's slot, so the scalar is bit-equal to the sequential
/// strategy's `node_loads[rank]`. On heterogeneous topologies the sum
/// is then divided by this node's service capacity, exactly the
/// per-node `l / c` the sequential `LbScratch::load_views` computes —
/// this is the "speed vector exchange": every node derives its own
/// capacity from the shared instance's topology (the distributed app
/// driver ships the speeds inside the `.lbi` broadcast) and normalizes
/// locally before the load-scalar exchange.
pub(crate) fn node_load(inst: &Instance, rank: u32) -> f64 {
    let mut my_load = 0.0;
    for (o, &pe) in inst.mapping.iter().enumerate() {
        if inst.topo.node_of_pe(pe) == rank {
            my_load += inst.loads[o];
        }
    }
    if inst.topo.is_uniform() {
        my_load
    } else {
        my_load / inst.topo.node_capacity(rank)
    }
}

/// Stages 1 + 2 only for this node (handshake + virtual diffusion) —
/// the distributed counterpart of the sequential strategy's planning
/// phase, used by [`DistDiffusion::plan`] so intermediates don't pay
/// for a discarded stage 3.
fn node_plan(
    comm: &mut Comm,
    inst: &Instance,
    my_cands: &[u32],
    params: &StrategyParams,
) -> Result<(Vec<u32>, stage2::Stage2Out), CommError> {
    let adj = {
        let _s1 = crate::obs::span("stage1.handshake", "dist");
        protocol::handshake_node(
            comm,
            my_cands,
            params.neighbor_count,
            params.handshake_max_rounds,
            TAG_HANDSHAKE,
        )?
    };
    let my_load = node_load(inst, comm.rank);
    let s2 = {
        let _s2 = crate::obs::span("stage2.virtual", "dist");
        stage2::virtual_balance_node(
            comm,
            &adj,
            my_load,
            params.vlb_tolerance,
            params.vlb_max_iters,
            TAG_STAGE2,
        )?
    };
    Ok((adj, s2))
}

/// One node's end-to-end pipeline: handshake → virtual diffusion →
/// selection + refinement, all over `comm`. The distributed driver
/// calls this inline from its app node threads every LB round; the
/// [`DistDiffusion`] strategy spins up a dedicated cluster per
/// `rebalance`.
pub fn node_pipeline(
    comm: &mut Comm,
    inst: &Instance,
    my_cands: &[u32],
    variant: Variant,
    params: &StrategyParams,
) -> Result<NodeOutcome, CommError> {
    let (adj, s2) = node_plan(comm, inst, my_cands, params)?;
    let s3 = {
        let _s3 = crate::obs::span("stage3.select", "dist");
        stage3::select_and_refine_node(
            comm,
            inst,
            variant,
            &s2.flow_row,
            params.overfill,
            params.refine_tolerance,
            TAG_STAGE3,
        )?
    };
    Ok(NodeOutcome {
        adj,
        flow_row: s2.flow_row,
        iterations: s2.iterations,
        manifest: s3.manifest,
        migrations: s3.migrations,
        recv_bytes: s3.recv_bytes,
        full_mapping: s3.full_mapping,
    })
}

/// Assembled result of a full distributed pipeline run.
pub struct DistOutcome {
    pub neigh: NeighborGraph,
    pub quotas: Quotas,
    pub assignment: Assignment,
    /// Total objects migrated (node-level, before PE refinement).
    pub migrations: usize,
    /// Total manifest bytes shipped between nodes.
    pub moved_bytes: f64,
}

/// Run the whole pipeline on a fresh cluster of
/// `inst.topo.n_nodes` threads and assemble the per-node outcomes.
pub fn run_pipeline(inst: &Instance, variant: Variant, params: StrategyParams) -> DistOutcome {
    let n_nodes = inst.topo.n_nodes;
    let cands = Arc::new(build_candidates(inst, variant, &params));
    let shared = Arc::new(inst.clone());
    let outcomes = Cluster::run(n_nodes, move |rank, mut comm| {
        node_pipeline(&mut comm, &shared, &cands[rank as usize], variant, &params)
            .expect("pipeline protocol failed on a healthy cluster")
    });
    assemble(outcomes)
}

fn assemble(mut outcomes: Vec<NodeOutcome>) -> DistOutcome {
    let iterations = outcomes.iter().map(|o| o.iterations).max().unwrap_or(0);
    debug_assert!(outcomes.iter().all(|o| o.iterations == iterations));
    let adj: Vec<Vec<u32>> = outcomes.iter_mut().map(|o| std::mem::take(&mut o.adj)).collect();
    let flows: Vec<Vec<(u32, f64)>> =
        outcomes.iter().map(|o| o.flow_row.clone()).collect();
    let migrations = outcomes.iter().map(|o| o.migrations).sum();
    let moved_bytes = outcomes.iter().map(|o| o.recv_bytes).sum();
    let mapping = std::mem::take(&mut outcomes[0].full_mapping);
    debug_assert!(
        outcomes.iter().skip(1).all(|o| o.full_mapping == mapping),
        "nodes assembled divergent mappings"
    );
    DistOutcome {
        neigh: NeighborGraph { adj },
        quotas: Quotas { flows, iterations },
        assignment: Assignment { mapping },
        migrations,
        moved_bytes,
    }
}

/// The diffusion strategy executed as a real distributed system: every
/// `rebalance` spins up one simulated node per topology node and runs
/// the three stages + refinement as message-passing protocols. Produces
/// **bit-identical** assignments to the sequential
/// [`Diffusion`](crate::strategies::diffusion::Diffusion) strategy —
/// that equivalence is the point, and `rust/tests/distributed.rs`
/// asserts it across seeds, node counts and variants.
///
/// `params.reuse_neighbors` is ignored here: the protocol re-runs the
/// handshake every round (amortizing it across rounds is the sequential
/// strategy's optimization; the cross-validation compares against the
/// cache-off default).
pub struct DistDiffusion {
    pub variant: Variant,
    pub params: StrategyParams,
}

impl DistDiffusion {
    pub fn communication(params: StrategyParams) -> DistDiffusion {
        DistDiffusion { variant: Variant::Communication, params }
    }

    pub fn coordinate(params: StrategyParams) -> DistDiffusion {
        DistDiffusion { variant: Variant::Coordinate, params }
    }

    /// Stage-1 + stage-2 intermediate results (protocol-produced),
    /// mirroring [`Diffusion::plan`](crate::strategies::diffusion::Diffusion::plan)
    /// for cross-validation and benches. Runs only the planning stages
    /// — no stage-3 manifests or PE exchange are paid for.
    pub fn plan(&self, inst: &Instance) -> (NeighborGraph, Quotas) {
        let n_nodes = inst.topo.n_nodes;
        let params = self.params;
        let cands = Arc::new(build_candidates(inst, self.variant, &params));
        let shared = Arc::new(inst.clone());
        let outs = Cluster::run(n_nodes, move |rank, mut comm| {
            let (adj, s2) = node_plan(&mut comm, &shared, &cands[rank as usize], &params)
                .expect("planning protocol failed on a healthy cluster");
            (adj, s2.flow_row, s2.iterations)
        });
        let iterations = outs.iter().map(|o| o.2).max().unwrap_or(0);
        debug_assert!(outs.iter().all(|o| o.2 == iterations));
        let mut adj = Vec::with_capacity(n_nodes);
        let mut flows = Vec::with_capacity(n_nodes);
        for (a, row, _) in outs {
            adj.push(a);
            flows.push(row);
        }
        (NeighborGraph { adj }, Quotas { flows, iterations })
    }

    /// Full pipeline outcome including the migration totals.
    pub fn outcome(&self, inst: &Instance) -> DistOutcome {
        run_pipeline(inst, self.variant, self.params)
    }
}

impl LoadBalancer for DistDiffusion {
    fn name(&self) -> &'static str {
        match self.variant {
            Variant::Communication => "dist-diff-comm",
            Variant::Coordinate => "dist-diff-coord",
        }
    }

    fn rebalance(&self, inst: &Instance) -> Assignment {
        run_pipeline(inst, self.variant, self.params).assignment
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::diffusion::Diffusion;

    fn noisy_stencil(n_nodes_x: usize, n_nodes_y: usize, seed: u64) -> Instance {
        let mut inst = crate::apps::stencil::stencil_2d(
            24,
            n_nodes_x,
            n_nodes_y,
            crate::apps::stencil::Decomposition::Tiled,
        );
        crate::apps::stencil::inject_noise(&mut inst, 0.4, seed);
        inst
    }

    #[test]
    fn pipeline_matches_sequential_comm() {
        let inst = noisy_stencil(2, 2, 7);
        let params = StrategyParams::default();
        let seq = Diffusion::communication(params).rebalance(&inst);
        let dist = DistDiffusion::communication(params).rebalance(&inst);
        assert_eq!(seq.mapping, dist.mapping);
    }

    #[test]
    fn pipeline_matches_sequential_coord() {
        let inst = noisy_stencil(2, 2, 8);
        let params = StrategyParams::default();
        let seq = Diffusion::coordinate(params).rebalance(&inst);
        let dist = DistDiffusion::coordinate(params).rebalance(&inst);
        assert_eq!(seq.mapping, dist.mapping);
    }

    #[test]
    fn plan_matches_sequential_quotas() {
        let inst = noisy_stencil(2, 2, 9);
        let params = StrategyParams::default();
        let lb = Diffusion::communication(params);
        let (sneigh, squotas) = lb.plan(&inst);
        let (dneigh, dquotas) = DistDiffusion::communication(params).plan(&inst);
        assert_eq!(sneigh.adj, dneigh.adj);
        assert_eq!(squotas, dquotas);
    }

    #[test]
    fn pipeline_matches_sequential_on_heterogeneous_speeds() {
        let mut inst = noisy_stencil(2, 2, 10);
        inst.topo =
            inst.topo.clone().with_pe_speeds(vec![1.0, 2.0, 0.5, 1.5]);
        let params = StrategyParams::default();
        for (seq, dist) in [
            (
                Diffusion::communication(params).rebalance(&inst),
                DistDiffusion::communication(params).rebalance(&inst),
            ),
            (
                Diffusion::coordinate(params).rebalance(&inst),
                DistDiffusion::coordinate(params).rebalance(&inst),
            ),
        ] {
            assert_eq!(seq.mapping, dist.mapping);
        }
    }

    #[test]
    fn single_node_instance_is_identity() {
        let inst = crate::apps::stencil::stencil_2d(
            8,
            1,
            1,
            crate::apps::stencil::Decomposition::Tiled,
        );
        let asg = DistDiffusion::communication(StrategyParams::default()).rebalance(&inst);
        assert_eq!(asg.mapping, inst.mapping);
    }
}
