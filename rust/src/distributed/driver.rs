//! Distributed application driver: any node-partitionable [`App`]
//! executed over a [`Cluster`] — each simulated node owns the objects
//! mapped to its PEs (plus whatever payload they carry), steps only its
//! partition, ships owner-crossing payload to the new owners as real
//! messages, and every `lb_period` steps runs the full distributed LB
//! pipeline ([`node_pipeline`]) inline on the same [`Comm`] endpoints,
//! then realizes the resulting object migrations as real transfers.
//!
//! The app-specific pieces live behind two traits: [`DistApp`] (shared
//! read-only bootstrap + root-side instance assembly/verification) and
//! [`DistNode`] (one node's partition: step, payload serialization,
//! work/measured-load reporting). Everything protocol-shaped — step
//! tags, accounting gathers, the `.lbi` broadcast, migration
//! handshakes, the final verification gather — is generic and written
//! once. Implementations: PIC ([`run_pic_distributed`], particles as
//! payload) and the drifting hotspot ([`run_hotspot_distributed`],
//! analytic loads, no payload) — `tests/distributed.rs` asserts
//! seq-vs-dist bit-identity for **both**.
//!
//! Accounting mirrors the sequential driver
//! ([`crate::apps::driver::run_app`]) exactly where it is modeled:
//! per-step owner-crossing records are gathered at rank 0 as **unit
//! counts** and re-expanded into per-crossing [`DistApp::unit_bytes`]
//! records, so the root's [`TrafficRecorder`] →
//! [`CommGraph::update_from_recorder`] incremental path accumulates
//! bit-identical edge weights to the sequential app's recorder, and the
//! per-step modeled communication seconds come from the shared
//! [`account_step_comm`] arithmetic over per-pair aggregates that match
//! the sequential ones to the last bit. (This is also why crossing
//! bytes must be uniform per app — see [`DistApp::unit_bytes`].) With
//! `deterministic_loads` set, the LB instances — and therefore the
//! migration counts — are equal between the two drivers as well
//! (`tests/distributed.rs` asserts both). Compute seconds are each
//! node's *own measured* step time (genuinely parallel execution), so
//! they are reported but not comparable bit-for-bit.
//!
//! The LB instance is assembled at the elected root (the recorder's
//! home — rank 0 unless faults removed it) and
//! broadcast in the binary `.lbi` wire form ([`crate::model::lbi`] —
//! exact f64 bit patterns, varint-packed CSR, O(m) decode), and the
//! root decodes its own broadcast so every node provably balances the
//! identical problem.
//!
//! **Fault tolerance.** Under an active
//! [`FaultPlan`](crate::simnet::FaultPlan) the run survives node
//! deaths, hangs and partitions — the root included: root duties
//! follow [`epoch::elect`] (the lowest alive rank that never rejoined
//! through a heal), so killing rank 0 promotes its successor rather
//! than ending the run. Every rank checkpoints its payload to the
//! elected root *and* to the election successor before each pipeline
//! entry (the mirror is what lets roothood move without losing a dead
//! rank's payload); a starved pipeline stage triggers the [`epoch`]
//! probe/declare/ack recovery cycle, and the surviving quorum restarts
//! the round on the restricted instance ([`restrict_instance`]) — dead
//! ranks' objects are re-homed onto survivors and their checkpointed
//! payload re-enters through the elected root during the migration
//! exchange, so work is conserved exactly. A partitioned-away minority
//! whose cut is scheduled to heal enters *exile* instead of dying: it
//! sheds its payload (the survivors' custody copy is authoritative),
//! idles through the cut rounds, and rejoins at the heal round through
//! the same joiner path scheduled late joiners use — welcomed by a
//! root epoch declaration so its first instance broadcast is neither
//! stale-dropped nor parked forever. Rejoiners stay barred from root
//! election for the rest of the run. An inert plan leaves every one of
//! these paths cold: the message sequence is bit-identical to the
//! fault-unaware driver's, and so is any run whose plan never touches
//! rank 0's roothood.
//!
//! **Elasticity.** A [`ResizeSchedule`](crate::model::ResizeSchedule)
//! retires ranks (drain, then exclusion from the pipeline's target
//! set; the retiring thread ships its partition by the root's mapping
//! handoff and exits) and seeds late joiners (idle until their join
//! round, adopt the instance broadcast, enter as full participants).
//! Known limitation: a partition that isolates a scheduled leaver at
//! its own leave round strands the mapping handoff — combined
//! fault+resize chaos must not cut the root↔leaver link on exactly
//! that round.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::apps::driver::{
    account_step_comm, time_imbalance, DriverConfig, IterRecord, RunReport,
};
use crate::apps::hotspot::{self, HotspotConfig};
use crate::apps::pic::{self, PicConfig};
use crate::model::{
    rehome_mapping, restrict_instance, CommGraph, Instance, Topology, TrafficRecorder,
};
use crate::simnet::network::{Cluster, Comm, CommError, CostTracker};
use crate::strategies::diffusion::Variant;
use crate::strategies::StrategyParams;
use crate::util::stats::Summary;

use super::epoch::{self, FaultCtx, Membership};
use super::{build_candidates, node_pipeline, wire};

/// Driver tag namespaces (top byte; low 24 bits carry the step or LB
/// round index, so adjacent phases never collide — nodes can lead each
/// other by at most one step because every step is an all-to-all
/// exchange).
const TAG_STEP: u32 = 0x1000_0000;
const TAG_ACCT: u32 = 0x1100_0000;
const TAG_LBC: u32 = 0x1200_0000;
const TAG_LBX: u32 = 0x1300_0000;
const TAG_MIG: u32 = 0x1400_0000;
const TAG_CKPT: u32 = 0x1500_0000;
/// End-of-run telemetry gather: every surviving member ships its comm
/// resilience counters (and, when tracing is on, its encoded local
/// trace buffer) to the elected root, which sums them into
/// [`RunReport::obs`] and merges the trace on virtual timestamps.
/// Always sent — the counters are always-on — so the message sequence
/// is identical with telemetry enabled and disabled.
const TAG_OBS: u32 = 0x1600_0000;
const TAG_FIN: u32 = 0x1F00_0000;

/// How often a joining rank polls for the root's instance broadcast
/// while draining any epoch declarations parked during its idle phase.
const JOIN_POLL: Duration = Duration::from_millis(200);

/// Shared read-only bootstrap of a node-partitionable app — what a
/// real launcher hands every process, plus the root-side hooks.
/// The distributed counterpart of [`crate::apps::App`].
pub trait DistApp: Send + Sync + 'static {
    /// Per-node partition state.
    type Node: DistNode;

    fn name(&self) -> &'static str;
    fn topo(&self) -> Topology;
    fn n_objects(&self) -> usize;
    /// Initial object → PE mapping (every node seeds its replica from
    /// this).
    fn initial_mapping(&self) -> Vec<u32>;
    /// Static sync adjacency, as in [`crate::apps::App::neighbor_pairs`].
    fn neighbor_pairs(&self) -> Vec<(u32, u32)>;
    /// Bytes carried by one crossing unit. Must be uniform across the
    /// app: the root re-expands gathered unit counts into per-crossing
    /// records, and sums of *equal* addends are permutation-invariant —
    /// that is what keeps the root's recorder bit-identical to the
    /// sequential app's even though ranks report in rank order rather
    /// than event order.
    fn unit_bytes(&self) -> f64;
    /// Build rank `rank`'s partition owning the objects `mapping` puts
    /// on its PEs.
    fn make_node(&self, rank: u32, mapping: &[u32]) -> Self::Node;
    /// Root: assemble the LB instance from the gathered per-object work
    /// and measured loads — must replicate the sequential app's
    /// `build_instance` bit for bit (both sides call one shared
    /// assembly function; see `pic::assemble_instance` /
    /// `hotspot::assemble_instance`).
    #[allow(clippy::too_many_arguments)]
    fn assemble_instance(
        &self,
        work: &[f64],
        measured: &[f64],
        mapping: Vec<u32>,
        steps_since_lb: usize,
        recorder: &mut TrafficRecorder,
        comm_cache: &mut CommGraph,
    ) -> Instance;
    /// Root: verify the gathered final payloads (rank 0's first, then
    /// the peers' in arrival order) after `steps` completed iterations.
    /// Default: trivially ok.
    fn verify(&self, steps: usize, finals: &[Vec<u8>]) -> bool {
        let _ = (steps, finals);
        true
    }
}

/// Drain nonzero per-object measured loads into `(object, seconds)`
/// pairs, resetting the accumulator — the one implementation of
/// [`DistNode::drain_measured`] every node shares.
pub fn drain_nonzero(acc: &mut [f64], out: &mut Vec<(u32, f64)>) {
    for (c, l) in acc.iter_mut().enumerate() {
        if *l > 0.0 {
            out.push((c as u32, *l));
        }
        *l = 0.0;
    }
}

/// One node's partition of a [`DistApp`].
pub trait DistNode: Send {
    /// Advance my partition one step: serialize payload leaving for
    /// node `d` into `outbox[d]`, append directed
    /// `(from, to, unit_count)` crossing records (one per crossing
    /// event; the driver aggregates), and return the measured compute
    /// seconds. `mapping` is the current object → PE map.
    fn step(
        &mut self,
        step: usize,
        mapping: &[u32],
        outbox: &mut [Vec<u8>],
        moved: &mut Vec<(u32, u32, u32)>,
    ) -> f64;

    /// Integrate payload shipped from another node (step exchange and
    /// migration transfers use the same format).
    fn absorb(&mut self, data: &[u8]);

    /// After all arrivals are in: attribute `compute_s` to my objects
    /// (accumulating measured load) and append my partition's nonzero
    /// `(object, work)` units for this step.
    fn account(&mut self, compute_s: f64, work: &mut Vec<(u32, f64)>);

    /// Drain my accumulated measured loads since the last LB round as
    /// nonzero `(object, seconds)` pairs, resetting them.
    fn drain_measured(&mut self, out: &mut Vec<(u32, f64)>);

    /// Serialize the payload of objects I owned under `old` whose new
    /// owner is another node, into `outbox[new_owner]`, and adopt the
    /// ownership implied by `new`.
    fn emigrate(&mut self, old: &[u32], new: &[u32], outbox: &mut [Vec<u8>]);

    /// Serialize my whole partition's payload — the pre-pipeline state
    /// the root holds in custody under an active fault plan, absorbed
    /// on my behalf if I die mid-pipeline. The format must be
    /// [`DistNode::absorb`]-compatible. Default: no payload (analytic
    /// apps reconstruct state from the mapping alone).
    fn checkpoint(&self, out: &mut Vec<u8>) {
        let _ = out;
    }

    /// Final state for root verification (same format across ranks).
    fn final_payload(&self, out: &mut Vec<u8>) {
        let _ = out;
    }
}

/// Aggregate a raw `(from, to, units)` crossing log per directed pair —
/// the integer twin of `model::graph::sort_sum_merge` (stable sort,
/// left-to-right sums).
fn merge_units(v: &mut Vec<(u32, u32, u32)>) {
    v.sort_by_key(|&(f, t, _)| (f, t));
    let mut w = 0usize;
    for r in 0..v.len() {
        if w > 0 && v[w - 1].0 == v[r].0 && v[w - 1].1 == v[r].1 {
            v[w - 1].2 += v[r].2;
        } else {
            v[w] = v[r];
            w += 1;
        }
    }
    v.truncate(w);
}

/// Read-only bootstrap shared with every node thread.
struct Shared<A: DistApp> {
    app: A,
    driver: DriverConfig,
    variant: Variant,
    params: StrategyParams,
    mapping0: Vec<u32>,
    neighbor_pairs: Vec<(u32, u32)>,
}

/// Run a node-partitionable app fully distributed under the given
/// diffusion variant: one simulated node per topology node, real
/// payload exchange, the LB pipeline inline as message-passing
/// protocols.
pub fn run_app_distributed<A: DistApp>(
    app: A,
    variant: Variant,
    params: StrategyParams,
    driver: &DriverConfig,
) -> Result<RunReport> {
    anyhow::ensure!(driver.iters < (1 << 24), "iters exceeds the step tag space");
    anyhow::ensure!(
        driver.lb_period == 0 || driver.iters / driver.lb_period < (1 << 20),
        "LB rounds exceed the epoch map-tag round space"
    );
    let n_nodes = app.topo().n_nodes;
    driver.fault_plan.validate(n_nodes)?;
    driver.resize.validate(n_nodes)?;
    let plan = Arc::clone(&driver.fault_plan);
    let shared = Arc::new(Shared {
        mapping0: app.initial_mapping(),
        neighbor_pairs: app.neighbor_pairs(),
        driver: driver.clone(),
        variant,
        params,
        app,
    });
    let node_fn = move |rank, mut comm: Comm| node_main(rank, &mut comm, &shared);
    let mut reports = if plan.is_active() {
        // chaos runs: the transport itself enforces the plan's
        // partition cuts; kills and hangs fire inside the pipeline.
        Cluster::run_with_plan(n_nodes, plan, node_fn)
    } else {
        Cluster::run(n_nodes, node_fn)
    };
    // The report comes from whichever rank held root duties at the end:
    // rank 0 on any fault-free run, the elected successor when a fault
    // plan removed rank 0 mid-run.
    reports
        .into_iter()
        .flatten()
        .next()
        .ok_or_else(|| anyhow::anyhow!("no surviving rank produced a report"))
}

/// World ranks flagged in `mask`, ascending.
fn ranks_of(mask: &[bool]) -> Vec<u32> {
    mask.iter().enumerate().filter_map(|(i, &b)| b.then_some(i as u32)).collect()
}

/// Root-only accounting and LB-instance state.
struct RootState {
    recorder: TrafficRecorder,
    comm_cache: CommGraph,
    steps_since_lb: usize,
    tracker: CostTracker,
    payload: Vec<(u32, u32, f64)>,
    consumed: Vec<bool>,
    /// Global per-object work units of the latest step (the LB
    /// instance's load fallback / sizes, and the migration-bytes model).
    last_work: Vec<f64>,
    report: RunReport,
}

/// A protocol stage that came up short: which stage starved, and the
/// [`CommError`] that starved it. [`node_run`] propagates these to the
/// single fault boundary in [`node_main`] instead of panicking at the
/// receive site, so every stage's failure reaches the recovery
/// decision with its context intact.
struct StageFailure {
    stage: String,
    err: CommError,
}

impl std::fmt::Display for StageFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.stage, self.err)
    }
}

/// `map_err` adapter attaching lazy stage context to a comm failure
/// (lazy: the happy path must not pay for a `format!`).
fn at_stage(stage: impl FnOnce() -> String) -> impl FnOnce(CommError) -> StageFailure {
    move |err| StageFailure { stage: stage(), err }
}

/// The per-node driver body, wrapped around [`node_run`]'s propagated
/// stage failures. On a healthy cluster any starved stage is a
/// protocol bug and panics exactly like the old inline unwraps did.
/// Under an active fault plan the failure first consults the epoch
/// control plane: a rank the quorum has already declared dead (killed,
/// hung past its exclusion, or partitioned away) exits dead — the run
/// continues on the survivors, which hold this rank's checkpoint —
/// instead of poisoning the whole cluster with a panic.
fn node_main<A: DistApp>(rank: u32, comm: &mut Comm, sh: &Shared<A>) -> Option<RunReport> {
    match node_run(rank, comm, sh) {
        Ok(report) => report,
        Err(f) => {
            if sh.driver.fault_plan.is_active() {
                let mut failed = vec![false; sh.app.topo().n_nodes];
                if epoch::catch_up(comm, &mut failed) {
                    crate::info!("rank {rank}: declared dead at {f}; exiting");
                    return None;
                }
            }
            panic!("rank {rank}: {f}");
        }
    }
}

#[allow(clippy::too_many_lines)]
fn node_run<A: DistApp>(
    rank: u32,
    comm: &mut Comm,
    sh: &Shared<A>,
) -> Result<Option<RunReport>, StageFailure> {
    let topo = sh.app.topo();
    let n_objs = sh.app.n_objects();
    let n_nodes = topo.n_nodes;
    let ub = sh.app.unit_bytes();
    let steps_total = sh.driver.iters;
    let plan = sh.driver.fault_plan.as_ref();
    let fault_mode = plan.is_active();
    if fault_mode {
        // pipeline receives starve within the detection window instead
        // of the 30 s default, so recovery starts promptly.
        comm.set_patience(plan.detect_timeout());
    }
    let resize = &sh.driver.resize;

    // ---- membership state. `member` replays the resize schedule;
    // `failed` accumulates the crash exclusions the epoch protocol
    // declares. Both stay all-clear on a plain run, and every branch
    // below is gated on them so the fault-free message sequence is
    // bit-identical to the fault-unaware driver's.
    let mut member: Vec<bool> = resize.initial_alive(n_nodes);
    let mut failed: Vec<bool> = vec![false; n_nodes];
    let mut i_am_in = member[rank as usize];

    // ---- node-partitioned state. Ranks scheduled to join later start
    // empty: every rank re-homes the initial mapping onto the initial
    // membership identically (the sequential driver does the same).
    let mut obj_to_pe = sh.mapping0.clone();
    if member.iter().any(|&m| !m) {
        obj_to_pe = rehome_mapping(&obj_to_pe, &topo, &member);
    }
    let mut node = sh.app.make_node(rank, &obj_to_pe);
    let mut moved_units: Vec<(u32, u32, u32)> = Vec::new();
    let mut work_pairs: Vec<(u32, f64)> = Vec::new();
    let mut meas_pairs: Vec<(u32, f64)> = Vec::new();
    let mut lb_round: u32 = 0;
    // A partitioned-away rank whose cut is scheduled to heal sits out
    // rounds `[cut, heal)` instead of exiting dead; `Some(h)` holds the
    // heal round while the exile lasts.
    let mut exiled_until: Option<u32> = None;
    // The elected root for a given membership: the lowest alive rank
    // that never rejoined through a heal (a rejoiner holds neither root
    // accounting state nor checkpoint custody, so it is barred from
    // root duties for the rest of the run). A pure function of
    // replicated state — every rank computes the same answer — and
    // always 0 when no fault plan is active.
    let root_of = |failed: &[bool], member: &[bool], round: u32| -> u32 {
        if !fault_mode {
            return 0;
        }
        let rejoined = plan.rejoined_mask(n_nodes, round);
        let barred: Vec<bool> = (0..n_nodes).map(|i| !member[i] || rejoined[i]).collect();
        epoch::elect(failed, &barred)
    };

    // Root-held checkpoint custody (fault mode only): every rank's
    // latest pre-pipeline payload, absorbed at the root when that rank
    // dies — the victim takes no physics actions after checkpointing,
    // so the absorbed state is exact.
    let mut custody: Vec<Vec<u8>> = vec![Vec::new(); if fault_mode { n_nodes } else { 0 }];

    let mut root = (rank == 0).then(|| RootState {
        recorder: TrafficRecorder::new(n_objs),
        comm_cache: CommGraph::empty(n_objs),
        steps_since_lb: 0,
        tracker: CostTracker::new(n_nodes),
        payload: Vec::new(),
        consumed: Vec::new(),
        last_work: vec![0.0; n_objs],
        report: RunReport::default(),
    });

    let mut pe_time_buf: Vec<f64> = Vec::new();
    'steps: for step in 0..steps_total {
        let smask = (step as u32) & 0x00FF_FFFF;
        // Effective topology this step — the same pure function of
        // (schedule, step) the sequential driver evaluates, so every
        // root-side speed-dependent quantity matches it bit for bit.
        let eff_topo = sh.driver.speed_schedule.topo_at(&topo, step);
        // Ranks stepping this iteration: current members not failed.
        let alive: Vec<bool> = (0..n_nodes).map(|i| member[i] && !failed[i]).collect();
        let n_active = alive.iter().filter(|&&b| b).count();
        // Where this step's accounting gathers: the elected root.
        let step_root = root_of(&failed, &member, lb_round);

        // `iter` is stamped up front so a root elected mid-round still
        // labels the record it inherits correctly.
        let mut rec = IterRecord { iter: step, ..IterRecord::default() };
        if i_am_in {
            let _step_span = crate::obs::span("app.step", "dist-driver");
            // ---- step my partition; crossers leave by message.
            let mut outbox: Vec<Vec<u8>> = vec![Vec::new(); n_nodes];
            moved_units.clear();
            let push_s = node.step(step, &obj_to_pe, &mut outbox, &mut moved_units);
            for (d, buf) in outbox.into_iter().enumerate() {
                if d as u32 != rank && alive[d] {
                    comm.send(d as u32, TAG_STEP | smask, buf);
                }
            }
            // Faults fire only at pipeline stage entries, and failures
            // are resolved inside the LB round that saw them — so a
            // step exchange that comes up short is a protocol bug, not
            // a survivable fault.
            let arrivals = comm
                .recv_tagged(TAG_STEP | smask, n_active - 1, Comm::TIMEOUT)
                .map_err(at_stage(|| format!("step {step}: payload exchange")))?;
            for m in &arrivals {
                node.absorb(&m.data);
            }

            // ---- local work + measured-load attribution.
            merge_units(&mut moved_units);
            work_pairs.clear();
            node.account(push_s, &mut work_pairs);

            // ---- step accounting to root: step seconds, my per-object
            // work units, my crossing counts per directed object pair.
            let mut acct = Vec::new();
            wire::put_f64(&mut acct, push_s);
            wire::put_u32(&mut acct, work_pairs.len() as u32);
            for &(c, w) in &work_pairs {
                wire::put_u32(&mut acct, c);
                wire::put_f64(&mut acct, w);
            }
            wire::put_u32(&mut acct, moved_units.len() as u32);
            for &(f, t2, units) in &moved_units {
                wire::put_u32(&mut acct, f);
                wire::put_u32(&mut acct, t2);
                wire::put_u32(&mut acct, units);
            }

            // ---- root: assemble the iteration record the way the
            // sequential driver does, from exactly-matching aggregates.
            if rank != step_root {
                comm.send(step_root, TAG_ACCT | smask, acct);
            } else if let Some(rs) = root.as_mut() {
                let mut msgs = comm
                    .recv_tagged(TAG_ACCT | smask, n_active - 1, Comm::TIMEOUT)
                    .map_err(at_stage(|| format!("step {step}: accounting gather")))?;
                msgs.sort_by_key(|m| m.from);
                let mut work_global = vec![0.0f64; n_objs];
                let mut node_push = vec![0.0f64; n_nodes];
                // merged directed crossing records in rank order,
                // expanded back to per-crossing unit_bytes sums
                // (left-to-right, like the sequential per-step
                // aggregation).
                let mut merged_moved: Vec<(u32, u32, f64)> = Vec::new();
                for (from, data) in std::iter::once((rank, acct.as_slice()))
                    .chain(msgs.iter().map(|m| (m.from, m.data.as_slice())))
                {
                    let corrupt = |_| StageFailure {
                        stage: format!("step {step}: accounting decode"),
                        err: CommError::Corrupt { tag: TAG_ACCT | smask, from },
                    };
                    let mut r = wire::Reader::new(data);
                    node_push[from as usize] = r.f64().map_err(corrupt)?;
                    let nw = r.u32().map_err(corrupt)?;
                    for _ in 0..nw {
                        let c = r.u32().map_err(corrupt)?;
                        let w = r.f64().map_err(corrupt)?;
                        if let Some(slot) = work_global.get_mut(c as usize) {
                            *slot += w;
                        }
                    }
                    let nm = r.u32().map_err(corrupt)?;
                    for _ in 0..nm {
                        let f = r.u32().map_err(corrupt)?;
                        let t2 = r.u32().map_err(corrupt)?;
                        let units = r.u32().map_err(corrupt)?;
                        let mut bytes = 0.0f64;
                        for _ in 0..units {
                            bytes += ub;
                            rs.recorder.record(f, t2, ub);
                        }
                        merged_moved.push((f, t2, bytes));
                    }
                }
                rs.steps_since_lb += 1;

                let mut pe_work = vec![0.0f64; topo.n_pes()];
                let mut node_work = vec![0.0f64; n_nodes];
                for (o, &w) in work_global.iter().enumerate() {
                    let pe = obj_to_pe[o];
                    pe_work[pe as usize] += w;
                    node_work[topo.node_of_pe(pe) as usize] += w;
                }
                account_step_comm(
                    &topo,
                    &obj_to_pe,
                    &sh.neighbor_pairs,
                    &merged_moved,
                    &mut rs.payload,
                    &mut rs.consumed,
                    &mut rs.tracker,
                );
                let comm_times = rs.tracker.comm_times(&sh.driver.net);
                let pe_summary = Summary::of(&pe_work);
                rec = IterRecord {
                    iter: step,
                    work_max_avg: pe_summary.max_avg_ratio(),
                    time_max_avg: time_imbalance(&pe_work, &eff_topo, &mut pe_time_buf),
                    node_work,
                    compute_max_s: node_push.iter().cloned().fold(0.0, f64::max),
                    compute_avg_s: node_push.iter().sum::<f64>() / n_nodes as f64,
                    comm_max_s: comm_times.iter().cloned().fold(0.0, f64::max),
                    comm_avg_s: comm_times.iter().sum::<f64>() / n_nodes as f64,
                    ..Default::default()
                };
                rs.last_work = work_global;
            }
        }

        // ---- LB round.
        if sh.driver.lb_period > 0 && (step + 1) % sh.driver.lb_period == 0 {
            let _lb_span = crate::obs::span("lb.round", "dist-driver");
            let rmask = lb_round & 0x00FF_FFFF;
            // ---- partition heals scheduled at this round: advance the
            // fault clock first, so the lifted cut lets the rejoin
            // traffic through (`FaultPlan::validate` guarantees no
            // other cut starts at a heal round), then strike the healed
            // ranks from the failed set. Every rank replays this
            // identically from the shared plan.
            let healed_now: Vec<u32> =
                if fault_mode { plan.healed_at(lb_round) } else { Vec::new() };
            if !healed_now.is_empty() {
                comm.set_fault_round(u64::from(lb_round));
                for &h in &healed_now {
                    failed[h as usize] = false;
                }
            }
            // Scheduled membership after this round's resize events;
            // the pipeline participants are its non-failed ranks.
            let sched = resize.alive_after(lb_round as usize, n_nodes);
            let target_mask: Vec<bool> =
                (0..n_nodes).map(|i| sched[i] && !failed[i]).collect();
            let target_ranks = ranks_of(&target_mask);

            let in_exile = exiled_until.is_some_and(|h| lb_round < h);
            if in_exile || (!i_am_in && !target_mask[rank as usize]) {
                // bystander: not in yet (or exiled until a later heal),
                // not joining this round — just replay the schedule and
                // keep idling.
                member.copy_from_slice(&sched);
                lb_round += 1;
                continue;
            }
            // An exile whose heal round arrived re-enters through the
            // joiner path below, exactly like a scheduled late joiner.
            exiled_until = None;
            let joined_now = !i_am_in;

            // This round's elected root and its successor. Checkpoints
            // are mirrored at the successor so a root death inside this
            // round's pipeline does not take the custody store down
            // with it — the successor is precisely the rank the
            // election promotes.
            let round_root = root_of(&failed, &member, lb_round);
            let succ = if fault_mode {
                let rejoined = plan.rejoined_mask(n_nodes, lb_round);
                let barred: Vec<bool> =
                    (0..n_nodes).map(|i| !member[i] || rejoined[i]).collect();
                epoch::successor(&failed, &barred, round_root)
            } else {
                None
            };

            if i_am_in {
                // gather measured loads at root (deterministic mode
                // ignores them but the gather keeps the protocol
                // uniform).
                meas_pairs.clear();
                node.drain_measured(&mut meas_pairs);
                if rank != round_root {
                    let mut lbuf = Vec::new();
                    wire::put_u32(&mut lbuf, meas_pairs.len() as u32);
                    for &(c, l) in &meas_pairs {
                        wire::put_u32(&mut lbuf, c);
                        wire::put_f64(&mut lbuf, l);
                    }
                    comm.send(round_root, TAG_LBC | rmask, lbuf);
                }
                if fault_mode {
                    // pre-pipeline checkpoint: the state the root (or,
                    // if the root dies this round, its successor)
                    // absorbs on my behalf if I die this round.
                    let mut ck = Vec::new();
                    node.checkpoint(&mut ck);
                    if rank != round_root {
                        comm.send(round_root, TAG_CKPT | rmask, ck.clone());
                    }
                    if let Some(s) = succ {
                        if s != rank {
                            comm.send(s, TAG_CKPT | rmask, ck.clone());
                        }
                    }
                    custody[rank as usize] = ck;
                }
            }

            if i_am_in && !target_mask[rank as usize] {
                // ---- scheduled leave: the pipeline runs without me.
                // The root hands me the final world mapping
                // (ctrl-tagged, so epoch bumps I never saw cannot
                // strand it); I ship my whole partition to its new
                // owners and retire without a FIN — my payload now
                // lives elsewhere.
                let msg = comm
                    .recv_tagged(epoch::map_tag(lb_round), 1, Comm::TIMEOUT)
                    .map_err(at_stage(|| {
                        format!("LB {lb_round}: mapping handoff for leaver {rank}")
                    }))?
                    .pop()
                    .expect("mapping handoff");
                let corrupt = |_| StageFailure {
                    stage: format!("LB {lb_round}: mapping handoff for leaver {rank}"),
                    err: CommError::Corrupt { tag: epoch::map_tag(lb_round), from: msg.from },
                };
                let mut r = wire::Reader::new(&msg.data);
                let ep = r.u32().map_err(corrupt)?;
                let nf = r.u32().map_err(corrupt)?;
                for _ in 0..nf {
                    let f = r.u32().map_err(corrupt)? as usize;
                    if f < n_nodes {
                        failed[f] = true;
                    }
                }
                let mut new_map = Vec::with_capacity(n_objs);
                for _ in 0..n_objs {
                    new_map.push(r.u32().map_err(corrupt)?);
                }
                // adopt the current epoch so the transfers below are
                // not stale-dropped by survivors ahead of me.
                comm.set_epoch(ep);
                let old_map = std::mem::replace(&mut obj_to_pe, new_map);
                let mut sends_to = vec![false; n_nodes];
                for c in 0..n_objs {
                    if topo.node_of_pe(old_map[c]) == rank {
                        sends_to[topo.node_of_pe(obj_to_pe[c]) as usize] = true;
                    }
                }
                let mut outbox: Vec<Vec<u8>> = vec![Vec::new(); n_nodes];
                node.emigrate(&old_map, &obj_to_pe, &mut outbox);
                for (d, buf) in outbox.into_iter().enumerate() {
                    if sends_to[d] {
                        comm.send(d as u32, TAG_MIG | rmask, buf);
                    }
                }
                return Ok(None);
            }

            // ---- successor custody mirror: the election successor
            // holds a copy of every member's checkpoint, so roothood
            // can move without losing any dead rank's payload.
            if fault_mode && Some(rank) == succ {
                let cks = comm
                    .recv_tagged(TAG_CKPT | rmask, n_active - 1, Comm::TIMEOUT)
                    .map_err(at_stage(|| {
                        format!("LB {lb_round}: successor checkpoint mirror")
                    }))?;
                for m in cks {
                    custody[m.from as usize] = m.data;
                }
            }

            // difflb-lint: allow(wall-clock): measures real strategy seconds for the report, never feeds a decision
            let t_lb = Instant::now();
            let inst = if let Some(rs) = root.as_mut() {
                // full measured-load vector, gathered from every rank
                // that stepped this iteration (leavers included).
                let msgs = comm
                    .recv_tagged(TAG_LBC | rmask, n_active - 1, Comm::TIMEOUT)
                    .map_err(at_stage(|| format!("LB {lb_round}: load gather")))?;
                let mut full_loads = vec![0.0f64; n_objs];
                for &(c, l) in &meas_pairs {
                    full_loads[c as usize] += l;
                }
                for m in &msgs {
                    let corrupt = |_| StageFailure {
                        stage: format!("LB {lb_round}: load gather decode"),
                        err: CommError::Corrupt { tag: TAG_LBC | rmask, from: m.from },
                    };
                    let mut r = wire::Reader::new(&m.data);
                    let nz = r.u32().map_err(corrupt)?;
                    for _ in 0..nz {
                        let c = r.u32().map_err(corrupt)?;
                        let l = r.f64().map_err(corrupt)?;
                        if let Some(slot) = full_loads.get_mut(c as usize) {
                            *slot += l;
                        }
                    }
                }
                if fault_mode {
                    // refresh the checkpoint custody before any fault
                    // of this round can fire.
                    let cks = comm
                        .recv_tagged(TAG_CKPT | rmask, n_active - 1, Comm::TIMEOUT)
                        .map_err(at_stage(|| format!("LB {lb_round}: checkpoint gather")))?;
                    for m in cks {
                        custody[m.from as usize] = m.data;
                    }
                }
                // the one shared instance-assembly sequence — identical
                // to the sequential app's build_instance by
                // construction.
                let mut inst = sh.app.assemble_instance(
                    &rs.last_work,
                    &full_loads,
                    obj_to_pe.clone(),
                    rs.steps_since_lb,
                    &mut rs.recorder,
                    &mut rs.comm_cache,
                );
                rs.steps_since_lb = 0;
                if sh.driver.deterministic_loads {
                    // the sequential driver overwrites the same way
                    inst.loads = rs.last_work.clone();
                }
                if sh.driver.speed_schedule.is_active() || resize.is_active() {
                    // perturbed / drain-scaled speeds travel inside the
                    // .lbi broadcast, so every node balances the same
                    // effective topology (the sequential driver applies
                    // the identical override).
                    inst.topo = if resize.is_active() {
                        resize.drained_topo(&eff_topo, lb_round as usize)
                    } else {
                        eff_topo.clone()
                    };
                }
                // broadcast to the pipeline participants (joiners
                // included, leavers not); then decode our own broadcast
                // so every node provably balances the identical
                // instance.
                // ---- welcome healed rejoiners first: a one-off epoch
                // declaration carrying the majority's current epoch and
                // failed set, so the rejoiner catches up before its
                // first LBX (sent below at that same epoch) arrives —
                // per-sender FIFO keeps the order.
                for &h in &healed_now {
                    crate::obs::counter!("epoch.heals").inc();
                    crate::info!("LB {lb_round}: welcoming healed rank {h} back");
                    epoch::declare_to(comm, h, comm.epoch(), &failed);
                }
                let bytes = crate::model::encode_lbi(&inst);
                for &p in &target_ranks {
                    if p != rank {
                        comm.send(p, TAG_LBX | rmask, bytes.clone());
                    }
                }
                // decode our own broadcast: what we balance is provably
                // what everyone else decoded (the binary codec ships
                // exact f64 bit patterns — lossless by construction).
                crate::model::decode_lbi(&bytes).expect("lbi round-trip failed")
            } else {
                let data = if joined_now {
                    // ---- joining this round: epochs may have moved
                    // while I idled, so alternate between draining
                    // parked epoch declarations and polling for the
                    // broadcast.
                    // difflb-lint: allow(wall-clock): join-poll deadline bounds real waiting, not a decision input
                    let deadline = Instant::now() + Comm::TIMEOUT;
                    loop {
                        // Responsive catch-up: besides adopting parked
                        // declarations, answer probes and ack the
                        // newest epoch — a fault elsewhere in this
                        // round must not read this joiner as dead.
                        if epoch::catch_up_responsive(comm, &mut failed) {
                            return Ok(None); // declared dead while idle
                        }
                        match comm.recv_tagged(TAG_LBX | rmask, 1, JOIN_POLL) {
                            Ok(mut v) => break v.pop().expect("lbx broadcast").data,
                            Err(e) => {
                                // difflb-lint: allow(wall-clock): same join-poll deadline as above
                                if Instant::now() >= deadline {
                                    return Err(at_stage(|| {
                                        format!("join {lb_round}: instance broadcast")
                                    })(e));
                                }
                            }
                        }
                    }
                } else {
                    comm.recv_tagged(TAG_LBX | rmask, 1, Comm::TIMEOUT)
                        .map_err(at_stage(|| format!("LB {lb_round}: instance broadcast")))?
                        .pop()
                        .expect("lbx broadcast")
                        .data
                };
                crate::model::decode_lbi(&data).expect("lbi decode failed")
            };
            if joined_now {
                // the broadcast instance carries the current world
                // mapping — adopt it and enter as a full participant.
                obj_to_pe.clone_from(&inst.mapping);
                i_am_in = true;
            }

            // ---- the full distributed pipeline, inline on this comm.
            // Every node derives the candidate lists from its own parsed
            // copy of the broadcast instance — n_nodes-fold redundant
            // work, deliberately: in the real runtime each process
            // computes its own candidate view, and there is no shared
            // memory to hand rows around (the strategy-only path,
            // run_pipeline, does share them via Arc).
            let failed_at_entry = failed.clone();
            // stage2_iters: this round's stage-2 convergence count
            // (identical on every participant) — surfaced in the root's
            // per-round metrics snapshot.
            let (new_map, stage2_iters): (Vec<u32>, u32) = if target_ranks.len() == n_nodes
                && !fault_mode
            {
                // the plain path: no groups, no restriction, no epoch
                // traffic — bit-identical to the fault-unaware driver.
                let cands = build_candidates(&inst, sh.variant, &sh.params);
                let out = node_pipeline(comm, &inst, &cands[rank as usize], sh.variant, &sh.params)
                    .map_err(at_stage(|| {
                        format!("LB {lb_round}: pipeline (no fault plan)")
                    }))?;
                let iters = out.iterations as u32;
                (out.full_mapping, iters)
            } else {
                if fault_mode {
                    // activate this round's partition cuts only now:
                    // the instance broadcast above must never be
                    // severed (a cut victim is excluded inside the
                    // pipeline instead).
                    comm.set_fault_round(u64::from(lb_round));
                }
                let mut ctx = FaultCtx::new(plan, lb_round);
                loop {
                    let alive_now: Vec<bool> =
                        (0..n_nodes).map(|i| target_mask[i] && !failed[i]).collect();
                    let r = restrict_instance(&inst, &alive_now);
                    let cands = build_candidates(&r.inst, sh.variant, &sh.params);
                    let me = r
                        .nodes
                        .iter()
                        .position(|&w| w == rank)
                        .expect("participant missing from its own restriction");
                    comm.enter_group(&r.nodes);
                    let res = if fault_mode {
                        epoch::staged_pipeline(
                            comm,
                            &r.inst,
                            &cands[me],
                            sh.variant,
                            &sh.params,
                            &mut ctx,
                            &mut failed,
                        )
                    } else {
                        node_pipeline(comm, &r.inst, &cands[me], sh.variant, &sh.params)
                            .map(Some)
                    };
                    comm.leave_group();
                    match res {
                        Ok(Some(out)) => {
                            break (r.expand_mapping(&out.full_mapping), out.iterations as u32);
                        }
                        // my own scheduled kill fired, or I hung past
                        // my exclusion: exit dead, shipping nothing —
                        // the root holds my checkpoint.
                        Ok(None) => return Ok(None),
                        Err(e) => {
                            if !fault_mode {
                                return Err(at_stage(|| {
                                    format!("LB {lb_round}: pipeline (no fault plan)")
                                })(e));
                            }
                            // A rank the plan itself cuts away this
                            // round skips the election cascade — its
                            // own fault schedule is as authoritative as
                            // a kill victim's (`fault_gate` consults
                            // the same plan), and the cascade's silent-
                            // coordinator waits could outlast a short
                            // exile, tangling the heal-round welcome
                            // with a stale recovery.
                            let cut_away = plan.partitions.iter().any(|p| {
                                p.minority.contains(&rank)
                                    && p.lb_round <= lb_round
                                    && p.heal_round.map_or(true, |h| lb_round < h)
                            });
                            if cut_away {
                                if let Some(h) = plan.exile_until(rank, lb_round) {
                                    // The cut heals: enter exile
                                    // instead of dying. The survivors
                                    // absorbed my checkpoint, so my
                                    // payload copy is dropped (theirs
                                    // is authoritative), and any
                                    // failure verdicts reached while
                                    // cut off are forgotten — they are
                                    // minority guesses.
                                    crate::obs::counter!("epoch.exiles").inc();
                                    crate::obs::mark("epoch.exile_enter", "recovery");
                                    crate::info!(
                                        "rank {rank}: partitioned away at LB round \
                                         {lb_round}; exiled until round {h}"
                                    );
                                    failed.copy_from_slice(&failed_at_entry);
                                    let ghost: Vec<bool> = (0..n_nodes)
                                        .map(|i| member[i] && !failed[i] && i != rank as usize)
                                        .collect();
                                    let shed = rehome_mapping(&obj_to_pe, &topo, &ghost);
                                    let old = std::mem::replace(&mut obj_to_pe, shed);
                                    let mut junk: Vec<Vec<u8>> = vec![Vec::new(); n_nodes];
                                    node.emigrate(&old, &obj_to_pe, &mut junk);
                                    root = None;
                                    i_am_in = false;
                                    exiled_until = Some(h);
                                    member.copy_from_slice(&sched);
                                    lb_round += 1;
                                    continue 'steps;
                                }
                                crate::obs::mark("epoch.minority_exit", "recovery");
                                return Ok(None);
                            }
                            // Coordinator candidates: this round's
                            // pipeline participants, minus heal
                            // rejoiners (they never coordinate — the
                            // pre-heal majority holds the run state).
                            let rejoined = plan.rejoined_mask(n_nodes, lb_round);
                            let barred: Vec<bool> = (0..n_nodes)
                                .map(|i| !target_mask[i] || rejoined[i])
                                .collect();
                            match epoch::recover(comm, plan, &target_ranks, &mut failed, &barred)
                            {
                                Membership::Member => {} // retry on the survivors
                                Membership::Excluded => return Ok(None),
                            }
                        }
                    }
                }
            };
            let strat_s = t_lb.elapsed().as_secs_f64();
            let old_map = std::mem::replace(&mut obj_to_pe, new_map);

            // ---- post-pipeline root: re-elected over the failure
            // verdicts the pipeline just reached. It moves only when
            // the round's root died mid-pipeline; the successor then
            // holds the mirrored custody and picks up every root duty
            // below, seeding fresh accounting state (the dead root's
            // per-round history dies with it — the physics payload does
            // not).
            let root_after = root_of(&failed, &member, lb_round);
            if fault_mode && rank == root_after && root.is_none() {
                crate::obs::mark("root.takeover", "recovery");
                crate::info!("rank {rank}: taking over root duties at LB round {lb_round}");
                root = Some(RootState {
                    recorder: TrafficRecorder::new(n_objs),
                    comm_cache: CommGraph::empty(n_objs),
                    steps_since_lb: 0,
                    tracker: CostTracker::new(n_nodes),
                    payload: Vec::new(),
                    consumed: Vec::new(),
                    last_work: vec![0.0; n_objs],
                    report: RunReport::default(),
                });
            }

            // ---- hand the final world mapping to scheduled leavers,
            // together with the epoch and failed set they sat out.
            if rank == root_after {
                let leavers: Vec<u32> = (0..n_nodes)
                    .filter(|&d| member[d] && !target_mask[d] && !failed[d])
                    .map(|d| d as u32)
                    .collect();
                if !leavers.is_empty() {
                    let mut buf = Vec::new();
                    wire::put_u32(&mut buf, comm.epoch());
                    let fl = ranks_of(&failed);
                    wire::put_u32(&mut buf, fl.len() as u32);
                    for &f in &fl {
                        wire::put_u32(&mut buf, f);
                    }
                    for &pe in &obj_to_pe {
                        wire::put_u32(&mut buf, pe);
                    }
                    for d in leavers {
                        comm.send(d, epoch::map_tag(lb_round), buf.clone());
                    }
                }
            }

            // ---- root: absorb the checkpointed payload of ranks that
            // died this round — their custody copy is the authoritative
            // state (victims act on nothing after checkpointing), and
            // emigrate below routes it by the new mapping.
            if fault_mode && rank == root_after {
                for f in 0..n_nodes {
                    if failed[f] && !failed_at_entry[f] {
                        let data = std::mem::take(&mut custody[f]);
                        node.absorb(&data);
                    }
                }
            }

            // ---- realize migrations: ship my payload whose objects
            // now live elsewhere; receive my new objects' payload.
            // Leavers ship their whole partition (above), joiners only
            // receive; objects whose old owner died this round are
            // re-routed from the root, which absorbed their payload.
            let _mig_span = crate::obs::span("migrate", "dist-driver");
            let migtag = TAG_MIG | rmask;
            let mut sends_to = vec![false; n_nodes];
            let mut recv_from = vec![false; n_nodes];
            for c in 0..n_objs {
                let mut old_n = topo.node_of_pe(old_map[c]);
                if failed[old_n as usize] {
                    // a dead owner's payload re-enters from the elected
                    // root, which absorbed its checkpoint custody
                    old_n = root_after;
                }
                let new_n = topo.node_of_pe(obj_to_pe[c]);
                if old_n == new_n {
                    continue;
                }
                if old_n == rank {
                    sends_to[new_n as usize] = true;
                }
                if new_n == rank {
                    recv_from[old_n as usize] = true;
                }
            }
            let mut outbox: Vec<Vec<u8>> = vec![Vec::new(); n_nodes];
            node.emigrate(&old_map, &obj_to_pe, &mut outbox);
            for (d, buf) in outbox.into_iter().enumerate() {
                if sends_to[d] {
                    comm.send(d as u32, migtag, buf);
                }
            }
            let expect = recv_from.iter().filter(|&&b| b).count();
            let migs = comm
                .recv_tagged(migtag, expect, Comm::TIMEOUT)
                .map_err(at_stage(|| format!("LB {lb_round}: migration transfer")))?;
            for m in &migs {
                node.absorb(&m.data);
            }

            // ---- root: LB accounting, sequential-driver formulas
            // (migration payload = the instance's own per-object sizes,
            // which is exactly what the sequential apps charge).
            if let Some(rs) = root.as_mut() {
                let migrations =
                    old_map.iter().zip(&obj_to_pe).filter(|(a, b)| a != b).count();
                let mut moved_bytes = 0.0;
                for c in 0..n_objs {
                    if old_map[c] != obj_to_pe[c] {
                        moved_bytes += inst.sizes[c];
                    }
                }
                let transfer_s = sh.driver.net.inter_time(migrations as u64, moved_bytes)
                    / n_nodes.max(1) as f64;
                rec.lb_s = strat_s + transfer_s;
                rec.migrations = migrations;
                rs.report.total_migrations += migrations;
                if crate::obs::metrics_enabled() {
                    // One JSONL row per LB round, root-side — the same
                    // fields the sequential driver records, plus the
                    // root endpoint's live resilience counters.
                    crate::obs::metrics::record_round(crate::obs::MetricsSnapshot {
                        round: lb_round,
                        iter: step as u32,
                        imbalance: rec.work_max_avg,
                        time_max_avg: rec.time_max_avg,
                        migrations: migrations as u32,
                        comm_s: rec.comm_max_s,
                        lb_s: rec.lb_s,
                        stage2_iters,
                        stale_drops: comm.stale_drops(),
                        epochs: comm.epoch(),
                    });
                }
            }
            // adopt the scheduled membership for the following steps.
            member.copy_from_slice(&sched);
            lb_round += 1;
        }

        if let Some(rs) = root.as_mut() {
            if sh.driver.log_every > 0 && step % sh.driver.log_every == 0 {
                crate::info!(
                    "dist iter {step}: max/avg={:.3} comm={:.2}ms lb={:.2}ms",
                    rec.work_max_avg,
                    rec.comm_max_s * 1e3,
                    rec.lb_s * 1e3
                );
            }
            rs.report.compute_s += rec.compute_max_s;
            rs.report.comm_s += rec.comm_max_s;
            rs.report.lb_s += rec.lb_s;
            rs.report.total_s += rec.compute_max_s + rec.comm_max_s + rec.lb_s;
            rs.report.records.push(rec);
        }
    }

    // ---- final verification: gather per-node payloads at root, from
    // the end-of-run membership only (leavers shipped their payload
    // before retiring, the failed are represented by root custody).
    let root_final = root_of(&failed, &member, lb_round);
    let mut fin = Vec::new();
    node.final_payload(&mut fin);
    if rank != root_final {
        if member[rank as usize] && !failed[rank as usize] {
            comm.send(root_final, TAG_FIN, fin);
            // ---- telemetry gather: my always-on resilience counters,
            // plus my local trace buffer (encoded) when tracing is on.
            // Sent unconditionally so the message sequence does not
            // depend on whether telemetry is enabled. A rank that died
            // or left before this point never sends one — a dead
            // rank's telemetry dies with it.
            let mut ob = Vec::new();
            wire::put_u64(&mut ob, comm.stale_drops());
            wire::put_u64(&mut ob, comm.future_parks());
            wire::put_u64(&mut ob, comm.barrier_timeouts());
            wire::put_u32(&mut ob, comm.epoch());
            if crate::obs::tracing_enabled() {
                let events = crate::obs::trace::take_local();
                ob.extend_from_slice(&crate::obs::trace::encode_events(&events));
            }
            comm.send(root_final, TAG_OBS, ob);
        }
        return Ok(None);
    }
    let mut rs = root.take().expect("root state");
    let expect =
        (0..n_nodes).filter(|&i| i != rank as usize && member[i] && !failed[i]).count();
    let mut finals = Vec::with_capacity(expect + 1);
    finals.push(fin);
    let msgs = comm
        .recv_tagged(TAG_FIN, expect, Comm::TIMEOUT)
        .map_err(at_stage(|| "final gather".to_string()))?;
    for m in msgs {
        finals.push(m.data);
    }
    // ---- telemetry gather: sum the survivors' counters into the
    // per-run totals (epochs converge, so max rather than sum) and
    // absorb their trace events into the process sink — the merge on
    // virtual timestamps happens when the sink is drained for export.
    rs.report.obs = crate::obs::ObsTotals {
        stale_drops: comm.stale_drops(),
        future_parks: comm.future_parks(),
        barrier_timeouts: comm.barrier_timeouts(),
        epochs: comm.epoch(),
    };
    let obs_msgs = comm
        .recv_tagged(TAG_OBS, expect, Comm::TIMEOUT)
        .map_err(at_stage(|| "telemetry gather".to_string()))?;
    for m in &obs_msgs {
        let mut r = wire::Reader::new(&m.data);
        let (Ok(sd), Ok(fp), Ok(bt), Ok(ep)) = (r.u64(), r.u64(), r.u64(), r.u32()) else {
            crate::info!("rank {}: telemetry frame truncated; skipped", m.from);
            continue;
        };
        rs.report.obs.stale_drops += sd;
        rs.report.obs.future_parks += fp;
        rs.report.obs.barrier_timeouts += bt;
        rs.report.obs.epochs = rs.report.obs.epochs.max(ep);
        let trace_bytes = r.rest();
        if !trace_bytes.is_empty() {
            match crate::obs::trace::decode_events(trace_bytes) {
                Ok(events) => crate::obs::trace::absorb(events),
                Err(e) => {
                    crate::info!("rank {}: trace payload corrupt ({e}); skipped", m.from);
                }
            }
        }
    }
    rs.report.final_mapping = obj_to_pe;
    rs.report.verified = sh.app.verify(steps_total, &finals);
    Ok(Some(rs.report))
}

// ===================================================== PIC as DistApp

/// One particle in a node's partition.
#[derive(Debug, Clone, Copy)]
struct P {
    id: u32,
    chare: u32,
    x: f64,
    y: f64,
    vx: f64,
    vy: f64,
    q: f64,
}

fn put_particle(buf: &mut Vec<u8>, p: &P) {
    wire::put_u32(buf, p.id);
    wire::put_u32(buf, p.chare);
    wire::put_f64(buf, p.x);
    wire::put_f64(buf, p.y);
    wire::put_f64(buf, p.vx);
    wire::put_f64(buf, p.vy);
    wire::put_f64(buf, p.q);
}

/// Decode a particle payload, appending to `out`. A truncated frame
/// stops the decode at the last whole particle and surfaces as `Err` —
/// the caller decides whether that is survivable (verification will
/// catch any particle lost to a short frame).
fn read_particles(data: &[u8], out: &mut Vec<P>) -> Result<(), wire::Truncated> {
    let mut r = wire::Reader::new(data);
    while !r.is_empty() {
        out.push(P {
            id: r.u32()?,
            chare: r.u32()?,
            x: r.f64()?,
            y: r.f64()?,
            vx: r.f64()?,
            vy: r.f64()?,
            q: r.f64()?,
        });
    }
    Ok(())
}

/// PIC PRK as a node-partitionable app: particles are the payload.
pub struct PicDistApp {
    cfg: PicConfig,
    x0: Vec<f64>,
    y0: Vec<f64>,
    init_parts: Vec<P>,
    neighbor_pairs: Vec<(u32, u32)>,
}

impl PicDistApp {
    pub fn new(cfg: PicConfig) -> Result<PicDistApp> {
        anyhow::ensure!(cfg.grid % cfg.chares_x == 0, "grid must divide chares_x");
        anyhow::ensure!(cfg.grid % cfg.chares_y == 0, "grid must divide chares_y");
        let pop = pic::init::initialize(
            cfg.init,
            cfg.n_particles,
            cfg.grid,
            cfg.k,
            cfg.m,
            cfg.q,
            cfg.seed,
        );
        let mut init_parts = Vec::with_capacity(pop.x.len());
        for i in 0..pop.x.len() {
            init_parts.push(P {
                id: i as u32,
                chare: pic::chare_of_pos(&cfg, pop.x[i], pop.y[i]),
                x: pop.x[i],
                y: pop.y[i],
                vx: pop.vx[i],
                vy: pop.vy[i],
                q: pop.q[i],
            });
        }
        Ok(PicDistApp {
            neighbor_pairs: pic::chare_neighbor_pairs(&cfg),
            init_parts,
            x0: pop.x,
            y0: pop.y,
            cfg,
        })
    }
}

/// One node's PIC partition.
pub struct PicNode {
    cfg: PicConfig,
    rank: u32,
    parts: Vec<P>,
    keep: Vec<P>,
    counts: Vec<u32>,
    load_acc: Vec<f64>,
}

impl DistApp for PicDistApp {
    type Node = PicNode;

    fn name(&self) -> &'static str {
        "pic"
    }

    fn topo(&self) -> Topology {
        self.cfg.topo.clone()
    }

    fn n_objects(&self) -> usize {
        self.cfg.chares_x * self.cfg.chares_y
    }

    fn initial_mapping(&self) -> Vec<u32> {
        pic::initial_mapping(&self.cfg)
    }

    fn neighbor_pairs(&self) -> Vec<(u32, u32)> {
        self.neighbor_pairs.clone()
    }

    fn unit_bytes(&self) -> f64 {
        self.cfg.particle_bytes
    }

    fn make_node(&self, rank: u32, mapping: &[u32]) -> PicNode {
        let topo = self.cfg.topo.clone();
        let n_chares = self.n_objects();
        let parts: Vec<P> = self
            .init_parts
            .iter()
            .copied()
            .filter(|p| topo.node_of_pe(mapping[p.chare as usize]) == rank)
            .collect();
        PicNode {
            cfg: self.cfg.clone(),
            rank,
            parts,
            keep: Vec::new(),
            counts: vec![0; n_chares],
            load_acc: vec![0.0; n_chares],
        }
    }

    fn assemble_instance(
        &self,
        work: &[f64],
        measured: &[f64],
        mapping: Vec<u32>,
        steps_since_lb: usize,
        recorder: &mut TrafficRecorder,
        comm_cache: &mut CommGraph,
    ) -> Instance {
        pic::assemble_instance(
            &self.cfg,
            work,
            measured,
            mapping,
            steps_since_lb,
            &self.neighbor_pairs,
            recorder,
            comm_cache,
        )
    }

    /// Reassemble positions by particle id and run the PRK analytic
    /// verification.
    fn verify(&self, steps: usize, finals: &[Vec<u8>]) -> bool {
        let n_particles = self.x0.len();
        let mut xf = vec![f64::NAN; n_particles];
        let mut yf = vec![f64::NAN; n_particles];
        let mut seen = 0usize;
        for data in finals {
            let mut r = wire::Reader::new(data);
            while !r.is_empty() {
                // a truncated frame or an out-of-range id is a failed
                // verification, not a panic
                let (Ok(id), Ok(x), Ok(y)) = (r.u32(), r.f64(), r.f64()) else {
                    return false;
                };
                let id = id as usize;
                if id >= n_particles {
                    return false;
                }
                xf[id] = x;
                yf[id] = y;
                seen += 1;
            }
        }
        seen == n_particles
            && pic::verify::verify_positions(
                &self.x0,
                &self.y0,
                &xf,
                &yf,
                steps,
                self.cfg.k,
                self.cfg.m,
                self.cfg.grid as f64,
            )
            .is_ok()
    }
}

impl DistNode for PicNode {
    fn step(
        &mut self,
        _step: usize,
        mapping: &[u32],
        outbox: &mut [Vec<u8>],
        moved: &mut Vec<(u32, u32, u32)>,
    ) -> f64 {
        let grid = self.cfg.grid as f64;
        let topo = self.cfg.topo.clone();
        // push my partition (bit-identical per-particle math to the
        // sequential app's native backend).
        // difflb-lint: allow(wall-clock): measured compute seconds feed the report, not the mapping
        let t = Instant::now();
        for p in self.parts.iter_mut() {
            let (xn, yn, vxn, vyn) =
                pic::push::push_one(p.x, p.y, p.vx, p.vy, p.q, grid, self.cfg.q);
            p.x = xn;
            p.y = yn;
            p.vx = vxn;
            p.vy = vyn;
        }
        let push_s = t.elapsed().as_secs_f64();

        // re-bin; crossings leave for their new owner by message.
        self.keep.clear();
        for mut p in self.parts.drain(..) {
            let nc = pic::chare_of_pos(&self.cfg, p.x, p.y);
            if nc != p.chare {
                moved.push((p.chare, nc, 1));
                p.chare = nc;
            }
            let dest = topo.node_of_pe(mapping[nc as usize]);
            if dest == self.rank {
                self.keep.push(p);
            } else {
                put_particle(&mut outbox[dest as usize], &p);
            }
        }
        std::mem::swap(&mut self.parts, &mut self.keep);
        push_s
    }

    fn absorb(&mut self, data: &[u8]) {
        if read_particles(data, &mut self.parts).is_err() {
            crate::info!("rank {}: truncated particle payload; tail dropped", self.rank);
        }
    }

    fn account(&mut self, compute_s: f64, work: &mut Vec<(u32, f64)>) {
        self.counts.iter_mut().for_each(|c| *c = 0);
        for p in &self.parts {
            self.counts[p.chare as usize] += 1;
        }
        if !self.parts.is_empty() {
            let per_particle = compute_s / self.parts.len() as f64;
            for (c, &cnt) in self.counts.iter().enumerate() {
                if cnt > 0 {
                    self.load_acc[c] += cnt as f64 * per_particle;
                }
            }
        }
        for (c, &cnt) in self.counts.iter().enumerate() {
            if cnt > 0 {
                work.push((c as u32, cnt as f64));
            }
        }
    }

    fn drain_measured(&mut self, out: &mut Vec<(u32, f64)>) {
        drain_nonzero(&mut self.load_acc, out);
    }

    fn emigrate(&mut self, _old: &[u32], new: &[u32], outbox: &mut [Vec<u8>]) {
        let topo = self.cfg.topo.clone();
        self.keep.clear();
        for p in self.parts.drain(..) {
            let new_n = topo.node_of_pe(new[p.chare as usize]);
            if new_n == self.rank {
                self.keep.push(p);
            } else {
                put_particle(&mut outbox[new_n as usize], &p);
            }
        }
        std::mem::swap(&mut self.parts, &mut self.keep);
    }

    fn final_payload(&self, out: &mut Vec<u8>) {
        out.reserve(self.parts.len() * 20);
        for p in &self.parts {
            wire::put_u32(out, p.id);
            wire::put_f64(out, p.x);
            wire::put_f64(out, p.y);
        }
    }

    fn checkpoint(&self, out: &mut Vec<u8>) {
        out.reserve(self.parts.len() * 44);
        for p in &self.parts {
            put_particle(out, p);
        }
    }
}

/// Run the PIC PRK benchmark fully distributed under the given
/// diffusion variant. Native backend only (each node pushes its own
/// partition; the math is [`pic::push::push_one`] per particle, so
/// trajectories are bit-identical to the sequential app's).
pub fn run_pic_distributed(
    pic_cfg: &PicConfig,
    variant: Variant,
    params: StrategyParams,
    driver: &DriverConfig,
) -> Result<RunReport> {
    run_app_distributed(PicDistApp::new(pic_cfg.clone())?, variant, params, driver)
}

// ================================================= Hotspot as DistApp

/// One node's hotspot partition: loads are analytic in (object, step),
/// so there is no payload — the node just evaluates its own objects.
pub struct HotspotNode {
    cfg: HotspotConfig,
    rank: u32,
    /// Halo pairs (shared adjacency; this node reports pairs whose
    /// lower endpoint it owns).
    pairs: Vec<(u32, u32)>,
    owned: Vec<bool>,
    work: Vec<f64>,
    load_acc: Vec<f64>,
}

/// The drifting hotspot as a node-partitionable app.
pub struct HotspotDistApp {
    cfg: HotspotConfig,
    pairs: Vec<(u32, u32)>,
}

impl HotspotDistApp {
    pub fn new(cfg: HotspotConfig) -> Result<HotspotDistApp> {
        cfg.validate()?;
        let pairs = crate::apps::grid_neighbor_pairs(cfg.nx, cfg.ny, true);
        Ok(HotspotDistApp { pairs, cfg })
    }
}

impl DistApp for HotspotDistApp {
    type Node = HotspotNode;

    fn name(&self) -> &'static str {
        "hotspot"
    }

    fn topo(&self) -> Topology {
        self.cfg.topo.clone()
    }

    fn n_objects(&self) -> usize {
        self.cfg.nx * self.cfg.ny
    }

    fn initial_mapping(&self) -> Vec<u32> {
        crate::apps::grid_mapping(self.cfg.nx, self.cfg.ny, self.cfg.topo.n_pes(), self.cfg.decomp)
    }

    fn neighbor_pairs(&self) -> Vec<(u32, u32)> {
        self.pairs.clone()
    }

    fn unit_bytes(&self) -> f64 {
        self.cfg.halo_bytes
    }

    fn make_node(&self, rank: u32, mapping: &[u32]) -> HotspotNode {
        let topo = self.cfg.topo.clone();
        let n = self.n_objects();
        let owned: Vec<bool> =
            mapping.iter().map(|&pe| topo.node_of_pe(pe) == rank).collect();
        HotspotNode {
            cfg: self.cfg.clone(),
            rank,
            pairs: self.pairs.clone(),
            owned,
            work: vec![0.0; n],
            load_acc: vec![0.0; n],
        }
    }

    fn assemble_instance(
        &self,
        work: &[f64],
        measured: &[f64],
        mapping: Vec<u32>,
        _steps_since_lb: usize,
        recorder: &mut TrafficRecorder,
        comm_cache: &mut CommGraph,
    ) -> Instance {
        hotspot::assemble_instance(&self.cfg, work, measured, mapping, recorder, comm_cache)
    }
}

impl DistNode for HotspotNode {
    fn step(
        &mut self,
        step: usize,
        _mapping: &[u32],
        _outbox: &mut [Vec<u8>],
        moved: &mut Vec<(u32, u32, u32)>,
    ) -> f64 {
        // difflb-lint: allow(wall-clock): measured compute seconds feed the report, not the mapping
        let t = Instant::now();
        for o in 0..self.work.len() {
            if self.owned[o] {
                self.work[o] = hotspot::load_at(&self.cfg, o, step);
            }
        }
        let compute_s = t.elapsed().as_secs_f64();
        // each halo edge is reported once globally: by the owner of its
        // lower endpoint
        for &(a, b) in &self.pairs {
            if self.owned[a as usize] {
                moved.push((a, b, 1));
            }
        }
        compute_s
    }

    fn absorb(&mut self, _data: &[u8]) {}

    fn account(&mut self, compute_s: f64, work: &mut Vec<(u32, f64)>) {
        let mut total = 0.0;
        for (o, &w) in self.work.iter().enumerate() {
            if self.owned[o] {
                total += w;
            }
        }
        let per_unit = compute_s / total.max(1.0);
        for (o, &w) in self.work.iter().enumerate() {
            if self.owned[o] {
                self.load_acc[o] += w * per_unit;
                work.push((o as u32, w));
            }
        }
    }

    fn drain_measured(&mut self, out: &mut Vec<(u32, f64)>) {
        drain_nonzero(&mut self.load_acc, out);
    }

    fn emigrate(&mut self, _old: &[u32], new: &[u32], _outbox: &mut [Vec<u8>]) {
        let topo = self.cfg.topo.clone();
        for (o, own) in self.owned.iter_mut().enumerate() {
            *own = topo.node_of_pe(new[o]) == self.rank;
        }
    }
}

/// Run the drifting-hotspot workload fully distributed — the second
/// node-partitionable app proving the driver generalizes beyond PIC
/// (`tests/distributed.rs` asserts bit-identity with the sequential
/// driver for it too).
pub fn run_hotspot_distributed(
    cfg: &HotspotConfig,
    variant: Variant,
    params: StrategyParams,
    driver: &DriverConfig,
) -> Result<RunReport> {
    run_app_distributed(HotspotDistApp::new(cfg.clone())?, variant, params, driver)
}
