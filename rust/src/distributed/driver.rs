//! Distributed PIC driver: the PIC PRK benchmark executed with
//! **node-partitioned particle state** over a [`Cluster`] — each
//! simulated node owns the particles of the chares mapped to its PEs,
//! pushes only those, ships chare-crossing particles to their new
//! owners as real messages, and every `lb_period` steps runs the full
//! distributed LB pipeline ([`node_pipeline`]) inline on the same
//! [`Comm`] endpoints, then realizes the resulting chare migrations by
//! transferring the affected particles between nodes.
//!
//! Accounting mirrors the sequential driver
//! ([`crate::apps::driver::run_pic`]) exactly where it is modeled:
//! per-step chare-crossing records are gathered at rank 0 as **counts**
//! and re-expanded into per-crossing `particle_bytes` records, so the
//! root's [`TrafficRecorder`] → [`CommGraph::update_from_recorder`]
//! incremental path accumulates bit-identical edge weights to the
//! sequential app's recorder, and the per-step modeled communication
//! seconds come from the shared
//! [`account_step_comm`] arithmetic over
//! per-pair aggregates that match the sequential ones to the last bit.
//! With `deterministic_loads` set, the LB instances — and therefore the
//! migration counts — are equal between the two drivers as well
//! (`tests/distributed.rs` asserts both). Compute seconds are each
//! node's *own measured* push time (genuinely parallel execution), so
//! they are reported but not comparable bit-for-bit.
//!
//! The LB instance is assembled at rank 0 (the recorder's home) and
//! broadcast as `.lbi` text — Rust's shortest-round-trip float
//! formatting makes the serialization lossless, and the root parses its
//! own broadcast so every node provably balances the identical problem.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::apps::driver::{account_step_comm, DriverConfig, IterRecord, RunReport};
use crate::apps::pic::{self, PicConfig};
use crate::model::{CommGraph, Instance, TrafficRecorder};
use crate::simnet::network::{Cluster, Comm, CostTracker};
use crate::strategies::diffusion::Variant;
use crate::strategies::StrategyParams;
use crate::util::stats::Summary;

use super::{build_candidates, node_pipeline, wire};

/// Driver tag namespaces (top byte; low 24 bits carry the step or LB
/// round index, so adjacent phases never collide — nodes can lead each
/// other by at most one step because every step is an all-to-all
/// exchange).
const TAG_STEP: u32 = 0x1000_0000;
const TAG_ACCT: u32 = 0x1100_0000;
const TAG_LBC: u32 = 0x1200_0000;
const TAG_LBX: u32 = 0x1300_0000;
const TAG_MIG: u32 = 0x1400_0000;
const TAG_FIN: u32 = 0x1F00_0000;

/// One particle in a node's partition.
#[derive(Debug, Clone, Copy)]
struct P {
    id: u32,
    chare: u32,
    x: f64,
    y: f64,
    vx: f64,
    vy: f64,
    q: f64,
}

fn put_particle(buf: &mut Vec<u8>, p: &P) {
    wire::put_u32(buf, p.id);
    wire::put_u32(buf, p.chare);
    wire::put_f64(buf, p.x);
    wire::put_f64(buf, p.y);
    wire::put_f64(buf, p.vx);
    wire::put_f64(buf, p.vy);
    wire::put_f64(buf, p.q);
}

fn read_particles(data: &[u8], out: &mut Vec<P>) {
    let mut r = wire::Reader::new(data);
    while !r.is_empty() {
        out.push(P {
            id: r.u32(),
            chare: r.u32(),
            x: r.f64(),
            y: r.f64(),
            vx: r.f64(),
            vy: r.f64(),
            q: r.f64(),
        });
    }
}

/// Read-only bootstrap state shared with every node thread (the
/// initial conditions a real launcher would hand each process).
struct Shared {
    cfg: PicConfig,
    driver: DriverConfig,
    variant: Variant,
    params: StrategyParams,
    x0: Vec<f64>,
    y0: Vec<f64>,
    init_parts: Vec<P>,
    chare_to_pe0: Vec<u32>,
    neighbor_pairs: Vec<(u32, u32)>,
}

/// Run the PIC PRK benchmark fully distributed under the given
/// diffusion variant. Native backend only (each node pushes its own
/// partition; the math is [`pic::push::push_one`] per particle, so
/// trajectories are bit-identical to the sequential app's).
pub fn run_pic_distributed(
    pic_cfg: &PicConfig,
    variant: Variant,
    params: StrategyParams,
    driver: &DriverConfig,
) -> Result<RunReport> {
    anyhow::ensure!(pic_cfg.grid % pic_cfg.chares_x == 0, "grid must divide chares_x");
    anyhow::ensure!(pic_cfg.grid % pic_cfg.chares_y == 0, "grid must divide chares_y");
    anyhow::ensure!(driver.iters < (1 << 24), "iters exceeds the step tag space");
    let pop = pic::init::initialize(
        pic_cfg.init,
        pic_cfg.n_particles,
        pic_cfg.grid,
        pic_cfg.k,
        pic_cfg.m,
        pic_cfg.q,
        pic_cfg.seed,
    );
    let mut init_parts = Vec::with_capacity(pop.x.len());
    for i in 0..pop.x.len() {
        init_parts.push(P {
            id: i as u32,
            chare: pic::chare_of_pos(pic_cfg, pop.x[i], pop.y[i]),
            x: pop.x[i],
            y: pop.y[i],
            vx: pop.vx[i],
            vy: pop.vy[i],
            q: pop.q[i],
        });
    }
    let shared = Arc::new(Shared {
        cfg: pic_cfg.clone(),
        driver: driver.clone(),
        variant,
        params,
        chare_to_pe0: pic::initial_mapping(pic_cfg),
        neighbor_pairs: pic::chare_neighbor_pairs(pic_cfg),
        init_parts,
        x0: pop.x,
        y0: pop.y,
    });
    let n_nodes = pic_cfg.topo.n_nodes;
    let mut reports =
        Cluster::run(n_nodes, move |rank, mut comm| node_main(rank, &mut comm, &shared));
    Ok(reports.swap_remove(0).expect("rank 0 produces the report"))
}

/// Root-only accounting and LB-instance state.
struct RootState {
    recorder: TrafficRecorder,
    comm_cache: CommGraph,
    steps_since_lb: usize,
    tracker: CostTracker,
    payload: Vec<(u32, u32, f64)>,
    consumed: Vec<bool>,
    /// Global per-chare particle counts of the latest step (the LB
    /// instance's load fallback / sizes, and the migration-bytes model).
    last_counts: Vec<u32>,
    report: RunReport,
}

#[allow(clippy::too_many_lines)]
fn node_main(rank: u32, comm: &mut Comm, sh: &Shared) -> Option<RunReport> {
    let cfg = &sh.cfg;
    let topo = cfg.topo;
    let grid = cfg.grid as f64;
    let pb = cfg.particle_bytes;
    let n_chares = cfg.chares_x * cfg.chares_y;
    let n_nodes = topo.n_nodes;
    let steps_total = sh.driver.iters;

    // ---- node-partitioned state.
    let mut chare_to_pe = sh.chare_to_pe0.clone();
    let mut parts: Vec<P> = sh
        .init_parts
        .iter()
        .copied()
        .filter(|p| topo.node_of_pe(chare_to_pe[p.chare as usize]) == rank)
        .collect();
    let mut load_acc = vec![0.0f64; n_chares];
    let mut counts = vec![0u32; n_chares];
    let mut moved_log: Vec<(u32, u32, f64)> = Vec::new();
    let mut keep: Vec<P> = Vec::new();
    let mut lb_round: u32 = 0;

    let mut root = (rank == 0).then(|| RootState {
        recorder: TrafficRecorder::new(n_chares),
        comm_cache: CommGraph::empty(n_chares),
        steps_since_lb: 0,
        tracker: CostTracker::new(n_nodes),
        payload: Vec::new(),
        consumed: Vec::new(),
        last_counts: vec![0; n_chares],
        report: RunReport::default(),
    });

    for step in 0..steps_total {
        let smask = (step as u32) & 0x00FF_FFFF;

        // ---- push my partition (bit-identical per-particle math).
        let t = Instant::now();
        for p in parts.iter_mut() {
            let (xn, yn, vxn, vyn) =
                pic::push::push_one(p.x, p.y, p.vx, p.vy, p.q, grid, cfg.q);
            p.x = xn;
            p.y = yn;
            p.vx = vxn;
            p.vy = vyn;
        }
        let push_s = t.elapsed().as_secs_f64();

        // ---- re-bin; crossings leave for their new owner by message.
        moved_log.clear();
        let mut outbox: Vec<Vec<u8>> = vec![Vec::new(); n_nodes];
        keep.clear();
        for mut p in parts.drain(..) {
            let nc = pic::chare_of_pos(cfg, p.x, p.y);
            if nc != p.chare {
                // one unit per crossing; aggregated to counts below
                moved_log.push((p.chare, nc, 1.0));
                p.chare = nc;
            }
            let dest = topo.node_of_pe(chare_to_pe[nc as usize]);
            if dest == rank {
                keep.push(p);
            } else {
                put_particle(&mut outbox[dest as usize], &p);
            }
        }
        std::mem::swap(&mut parts, &mut keep);
        for (d, buf) in outbox.into_iter().enumerate() {
            if d as u32 != rank {
                comm.send(d as u32, TAG_STEP | smask, buf);
            }
        }
        let arrivals = comm.recv_tagged(TAG_STEP | smask, n_nodes - 1, Comm::TIMEOUT);
        assert_eq!(arrivals.len(), n_nodes - 1, "step {step}: particle exchange incomplete");
        for m in &arrivals {
            read_particles(&m.data, &mut parts);
        }

        // ---- local load attribution (measured, per-node).
        counts.iter_mut().for_each(|c| *c = 0);
        for p in &parts {
            counts[p.chare as usize] += 1;
        }
        if !parts.is_empty() {
            let per_particle = push_s / parts.len() as f64;
            for (c, &cnt) in counts.iter().enumerate() {
                if cnt > 0 {
                    load_acc[c] += cnt as f64 * per_particle;
                }
            }
        }

        // ---- step accounting to root: push seconds, my per-chare
        // particle counts, my crossing counts per directed chare pair.
        crate::model::graph::sort_sum_merge(&mut moved_log);
        let mut acct = Vec::new();
        wire::put_f64(&mut acct, push_s);
        let nz = counts.iter().filter(|&&c| c > 0).count();
        wire::put_u32(&mut acct, nz as u32);
        for (c, &cnt) in counts.iter().enumerate() {
            if cnt > 0 {
                wire::put_u32(&mut acct, c as u32);
                wire::put_u32(&mut acct, cnt);
            }
        }
        wire::put_u32(&mut acct, moved_log.len() as u32);
        for &(f, t2, units) in &moved_log {
            wire::put_u32(&mut acct, f);
            wire::put_u32(&mut acct, t2);
            wire::put_u32(&mut acct, units as u32);
        }

        // ---- root: assemble the iteration record the way the
        // sequential driver does, from exactly-matching aggregates.
        let mut rec = IterRecord::default();
        if root.is_none() {
            comm.send(0, TAG_ACCT | smask, acct);
        } else if let Some(rs) = root.as_mut() {
            let mut msgs = comm.recv_tagged(TAG_ACCT | smask, n_nodes - 1, Comm::TIMEOUT);
            assert_eq!(msgs.len(), n_nodes - 1, "step {step}: accounting gather incomplete");
            msgs.sort_by_key(|m| m.from);
            let mut chare_counts = vec![0u32; n_chares];
            let mut node_push = vec![0.0f64; n_nodes];
            // merged directed crossing records in rank order, expanded
            // back to per-crossing particle_bytes sums (left-to-right,
            // like the sequential per-step aggregation).
            let mut merged_moved: Vec<(u32, u32, f64)> = Vec::new();
            for (from, data) in std::iter::once((0u32, acct.as_slice()))
                .chain(msgs.iter().map(|m| (m.from, m.data.as_slice())))
            {
                let mut r = wire::Reader::new(data);
                node_push[from as usize] = r.f64();
                let nz = r.u32();
                for _ in 0..nz {
                    let c = r.u32();
                    let cnt = r.u32();
                    chare_counts[c as usize] += cnt;
                }
                let nm = r.u32();
                for _ in 0..nm {
                    let f = r.u32();
                    let t2 = r.u32();
                    let units = r.u32();
                    let mut bytes = 0.0f64;
                    for _ in 0..units {
                        bytes += pb;
                        rs.recorder.record(f, t2, pb);
                    }
                    merged_moved.push((f, t2, bytes));
                }
            }
            rs.steps_since_lb += 1;

            let mut pe_counts = vec![0usize; topo.n_pes()];
            let mut node_particles = vec![0usize; n_nodes];
            for (c, &cnt) in chare_counts.iter().enumerate() {
                let pe = chare_to_pe[c] as usize;
                pe_counts[pe] += cnt as usize;
                node_particles[topo.node_of_pe(pe as u32) as usize] += cnt as usize;
            }
            account_step_comm(
                &topo,
                &chare_to_pe,
                &sh.neighbor_pairs,
                &merged_moved,
                &mut rs.payload,
                &mut rs.consumed,
                &mut rs.tracker,
            );
            let comm_times = rs.tracker.comm_times(&sh.driver.net);
            let pe_summary =
                Summary::of(&pe_counts.iter().map(|&c| c as f64).collect::<Vec<_>>());
            rec = IterRecord {
                iter: step,
                particles_max_avg: pe_summary.max_avg_ratio(),
                node_particles,
                compute_max_s: node_push.iter().cloned().fold(0.0, f64::max),
                compute_avg_s: node_push.iter().sum::<f64>() / n_nodes as f64,
                comm_max_s: comm_times.iter().cloned().fold(0.0, f64::max),
                comm_avg_s: comm_times.iter().sum::<f64>() / n_nodes as f64,
                ..Default::default()
            };
            rs.last_counts = chare_counts;
        }

        // ---- LB round.
        if sh.driver.lb_period > 0 && (step + 1) % sh.driver.lb_period == 0 {
            let rmask = lb_round & 0x00FF_FFFF;
            // gather measured loads at root (deterministic mode ignores
            // them but the gather keeps the protocol uniform).
            if rank != 0 {
                let mut lbuf = Vec::new();
                let nz = load_acc.iter().filter(|&&l| l > 0.0).count();
                wire::put_u32(&mut lbuf, nz as u32);
                for (c, &l) in load_acc.iter().enumerate() {
                    if l > 0.0 {
                        wire::put_u32(&mut lbuf, c as u32);
                        wire::put_f64(&mut lbuf, l);
                    }
                }
                comm.send(0, TAG_LBC | rmask, lbuf);
            }
            let t_lb = Instant::now();
            let inst = if let Some(rs) = root.as_mut() {
                // full measured-load vector
                let msgs = comm.recv_tagged(TAG_LBC | rmask, n_nodes - 1, Comm::TIMEOUT);
                assert_eq!(msgs.len(), n_nodes - 1, "LB {lb_round}: load gather incomplete");
                let mut full_loads = load_acc.clone();
                for m in &msgs {
                    let mut r = wire::Reader::new(&m.data);
                    let nz = r.u32();
                    for _ in 0..nz {
                        let c = r.u32();
                        full_loads[c as usize] += r.f64();
                    }
                }
                // the one shared instance-assembly sequence (sync
                // traffic, incremental comm-graph refresh, load
                // fallback) — identical to the sequential app's
                // build_instance by construction.
                let mut inst = pic::assemble_instance(
                    cfg,
                    &rs.last_counts,
                    &full_loads,
                    chare_to_pe.clone(),
                    rs.steps_since_lb,
                    &sh.neighbor_pairs,
                    &mut rs.recorder,
                    &mut rs.comm_cache,
                );
                rs.steps_since_lb = 0;
                if sh.driver.deterministic_loads {
                    // the sequential driver overwrites the same way
                    inst.loads = rs.last_counts.iter().map(|&c| c as f64).collect();
                }
                // broadcast; then parse our own broadcast so every node
                // provably balances the identical instance.
                let text = inst.to_lbi();
                for p in 1..n_nodes as u32 {
                    comm.send(p, TAG_LBX | rmask, text.clone().into_bytes());
                }
                // parse our own broadcast: what we balance is provably
                // what everyone else parsed (the format is lossless —
                // Rust float formatting round-trips exactly).
                Instance::from_lbi(&text).expect("lbi round-trip failed")
            } else {
                let msgs = comm.recv_tagged(TAG_LBX | rmask, 1, Comm::TIMEOUT);
                assert_eq!(msgs.len(), 1, "LB {lb_round}: instance broadcast missing");
                let text = std::str::from_utf8(&msgs[0].data).expect("lbi not utf-8");
                Instance::from_lbi(text).expect("lbi parse failed")
            };
            load_acc.iter_mut().for_each(|l| *l = 0.0);

            // ---- the full distributed pipeline, inline on this comm.
            // Every node derives the candidate lists from its own parsed
            // copy of the broadcast instance — n_nodes-fold redundant
            // work, deliberately: in the real runtime each process
            // computes its own candidate view, and there is no shared
            // memory to hand rows around (the strategy-only path,
            // run_pipeline, does share them via Arc).
            let cands = build_candidates(&inst, sh.variant, &sh.params);
            let outcome =
                node_pipeline(comm, &inst, &cands[rank as usize], sh.variant, &sh.params);
            let strat_s = t_lb.elapsed().as_secs_f64();
            let old_map = std::mem::replace(&mut chare_to_pe, outcome.full_mapping);

            // ---- realize migrations: ship my particles whose chares
            // now live elsewhere; receive my new chares' particles.
            let migtag = TAG_MIG | rmask;
            let mut sends_to = vec![false; n_nodes];
            let mut recv_from = vec![false; n_nodes];
            for c in 0..n_chares {
                let old_n = topo.node_of_pe(old_map[c]);
                let new_n = topo.node_of_pe(chare_to_pe[c]);
                if old_n == new_n {
                    continue;
                }
                if old_n == rank {
                    sends_to[new_n as usize] = true;
                }
                if new_n == rank {
                    recv_from[old_n as usize] = true;
                }
            }
            let mut outbox: Vec<Vec<u8>> = vec![Vec::new(); n_nodes];
            keep.clear();
            for p in parts.drain(..) {
                let new_n = topo.node_of_pe(chare_to_pe[p.chare as usize]);
                if new_n == rank {
                    keep.push(p);
                } else {
                    put_particle(&mut outbox[new_n as usize], &p);
                }
            }
            std::mem::swap(&mut parts, &mut keep);
            for (d, buf) in outbox.into_iter().enumerate() {
                if sends_to[d] {
                    comm.send(d as u32, migtag, buf);
                }
            }
            let expect = recv_from.iter().filter(|&&b| b).count();
            let migs = comm.recv_tagged(migtag, expect, Comm::TIMEOUT);
            assert_eq!(migs.len(), expect, "LB {lb_round}: migration transfer incomplete");
            for m in &migs {
                read_particles(&m.data, &mut parts);
            }

            // ---- root: LB accounting, sequential-driver formulas.
            if let Some(rs) = root.as_mut() {
                let migrations =
                    old_map.iter().zip(&chare_to_pe).filter(|(a, b)| a != b).count();
                let mut moved_bytes = 0.0;
                for (c, &cnt) in rs.last_counts.iter().enumerate() {
                    if old_map[c] != chare_to_pe[c] {
                        moved_bytes += cnt as f64 * pb;
                    }
                }
                let transfer_s = sh.driver.net.inter_time(migrations as u64, moved_bytes)
                    / n_nodes.max(1) as f64;
                rec.lb_s = strat_s + transfer_s;
                rec.migrations = migrations;
                rs.report.total_migrations += migrations;
            }
            lb_round += 1;
        }

        if let Some(rs) = root.as_mut() {
            if sh.driver.log_every > 0 && step % sh.driver.log_every == 0 {
                crate::info!(
                    "dist iter {step}: max/avg={:.3} comm={:.2}ms lb={:.2}ms",
                    rec.particles_max_avg,
                    rec.comm_max_s * 1e3,
                    rec.lb_s * 1e3
                );
            }
            rs.report.compute_s += rec.compute_max_s;
            rs.report.comm_s += rec.comm_max_s;
            rs.report.lb_s += rec.lb_s;
            rs.report.total_s += rec.compute_max_s + rec.comm_max_s + rec.lb_s;
            rs.report.records.push(rec);
        }
    }

    // ---- final verification: gather positions by particle id.
    if rank != 0 {
        let mut fin = Vec::with_capacity(parts.len() * 20);
        for p in &parts {
            wire::put_u32(&mut fin, p.id);
            wire::put_f64(&mut fin, p.x);
            wire::put_f64(&mut fin, p.y);
        }
        comm.send(0, TAG_FIN, fin);
        return None;
    }
    let mut rs = root.take().expect("root state");
    let n_particles = sh.x0.len();
    let mut xf = vec![f64::NAN; n_particles];
    let mut yf = vec![f64::NAN; n_particles];
    let mut seen = 0usize;
    for p in &parts {
        xf[p.id as usize] = p.x;
        yf[p.id as usize] = p.y;
        seen += 1;
    }
    let msgs = comm.recv_tagged(TAG_FIN, n_nodes - 1, Comm::TIMEOUT);
    assert_eq!(msgs.len(), n_nodes - 1, "final gather incomplete");
    for m in &msgs {
        let mut r = wire::Reader::new(&m.data);
        while !r.is_empty() {
            let id = r.u32() as usize;
            xf[id] = r.f64();
            yf[id] = r.f64();
            seen += 1;
        }
    }
    rs.report.verified = seen == n_particles
        && pic::verify::verify_positions(
            &sh.x0,
            &sh.y0,
            &xf,
            &yf,
            steps_total,
            cfg.k,
            cfg.m,
            grid,
        )
        .is_ok();
    Some(rs.report)
}
