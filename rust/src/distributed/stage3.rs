//! Stage 3 as a real protocol — distributed object selection with
//! migration manifests (paper §III-C), followed by the node-local
//! hierarchical refinement (§III-D) and a PE-assignment exchange.
//!
//! Every node keeps a replica of the object → node map and picks its
//! own outgoing objects with the *same per-node body* the sequential
//! strategy runs ([`select_comm_node`] / [`select_coord_node`]), against
//! its own [`LbScratch`]. The decisions it makes depend on migrations
//! other nodes performed earlier in the round (an arrived object must
//! not be forwarded — the single-hop constraint — and a peer that moved
//! changes every neighbor's bytes-to-target score), so manifests replay
//! in **rank order**: node `r` selects only after applying the
//! manifests of ranks `< r`, then broadcasts its own `(object id,
//! destination, bytes)` manifest — the receivers learn their arrivals,
//! later ranks update their replicas, and every replica walks through
//! exactly the interim states of the sequential sweep. That rank-
//! ordered wavefront is the price of bit-identical assignments; the
//! paper's concurrent stage 3 corresponds to dropping the ordering,
//! which the equivalence tests would immediately flag.
//!
//! Refinement needs no messages at all — each node splits its final
//! member set over its own PEs — and the resulting `(object, PE)` pairs
//! are exchanged so every node ends the round holding the complete new
//! mapping (the driver routes particles with it; the strategy returns
//! it as the `Assignment`).

use crate::model::Instance;
use crate::simnet::network::{Comm, CommError};
use crate::strategies::diffusion::hierarchical;
use crate::strategies::diffusion::object_selection::{
    self, quota_floor, select_comm_node, select_coord_node,
};
use crate::strategies::diffusion::scratch::LbScratch;
use crate::strategies::diffusion::Variant;

use super::wire;

/// Manifest broadcast from rank `r` uses `tag_base | r`; the
/// PE-assignment broadcast uses `tag_base | PE_BIT | r`.
const PE_BIT: u32 = 0x0080_0000;

/// One node's stage-3 + refinement result.
pub struct Stage3Out {
    /// Migrations this node decided, in pick order.
    pub manifest: Vec<(u32, u32)>,
    /// Objects this node migrated away (`manifest.len()`).
    pub migrations: usize,
    /// Manifest bytes whose destination is this node.
    pub recv_bytes: f64,
    /// The complete object → PE mapping after refinement (identical on
    /// every node).
    pub full_mapping: Vec<u32>,
}

/// Run this node's object selection + refinement. `flow_row` is the
/// node's stage-2 quota row; `tag_base` must leave the low 24 bits
/// clear. A peer failing mid-protocol surfaces as `Err`; the
/// epoch/restart layer owns the recovery decision.
pub fn select_and_refine_node(
    comm: &mut Comm,
    inst: &Instance,
    variant: Variant,
    flow_row: &[(u32, f64)],
    overfill: f64,
    refine_tol: f64,
    tag_base: u32,
) -> Result<Stage3Out, CommError> {
    debug_assert_eq!(tag_base & 0x00FF_FFFF, 0, "tag_base clobbers rank bits");
    let rank = comm.rank as usize;
    let n_nodes = comm.n;
    debug_assert_eq!(n_nodes, inst.topo.n_nodes, "cluster size != topology nodes");
    let n_objects = inst.n_objects();
    let floor = quota_floor(inst);

    // Replica of the object → node map; `scratch.moved` and the SoA
    // index are set up from the pre-LB state exactly like the
    // sequential sweep's (the SoA stays the *initial* index — arrivals
    // are excluded from pools via the moved flags, not re-indexed).
    let mut node_map = inst.node_mapping();
    // par_tasks = 1: node threads are already the parallelism; don't
    // fan scoring out onto the global worker pool from n_nodes threads
    // at once (the chunking is decision-neutral either way —
    // perf_refactor.rs).
    let mut scratch = LbScratch { par_tasks: Some(1), ..LbScratch::default() };
    scratch.moved.resize(n_objects, false);
    scratch.build_soa(inst, &node_map, n_nodes);
    if variant == Variant::Coordinate {
        object_selection::init_centroid_state(inst, &node_map, &mut scratch);
    }

    let mut recv_bytes = 0.0;
    // ---- Wavefront in: manifests of lower-ranked nodes, rank order.
    for h in 0..rank {
        let msgs = comm.recv_tagged(tag_base | h as u32, 1, comm.patience())?;
        recv_bytes += apply_manifest(
            inst,
            variant,
            &msgs[0].data,
            &mut node_map,
            &mut scratch,
            rank as u32,
        )
        .map_err(|_| CommError::Corrupt { tag: tag_base | h as u32, from: msgs[0].from })?;
    }

    // ---- Local picks against the synchronized replica.
    let mut manifest: Vec<(u32, u32)> = Vec::new();
    let migrations = match variant {
        Variant::Communication => select_comm_node(
            inst,
            &mut node_map,
            rank,
            flow_row,
            floor,
            overfill,
            &mut scratch,
            Some(&mut manifest),
        ),
        Variant::Coordinate => select_coord_node(
            inst,
            &mut node_map,
            rank,
            flow_row,
            floor,
            overfill,
            &mut scratch,
            Some(&mut manifest),
        ),
    };
    debug_assert_eq!(migrations, manifest.len());

    // ---- Broadcast my manifest (empty manifests included: receive
    // counts stay deterministic).
    let mut buf = Vec::with_capacity(manifest.len() * 16);
    for &(o, dest) in &manifest {
        wire::put_u32(&mut buf, o);
        wire::put_u32(&mut buf, dest);
        wire::put_f64(&mut buf, inst.sizes[o as usize]);
    }
    for p in 0..n_nodes as u32 {
        if p as usize != rank {
            comm.send(p, tag_base | rank as u32, buf.clone());
        }
    }

    // ---- Wavefront out: manifests of higher-ranked nodes complete the
    // final map (refinement needs to know this node's arrivals from
    // *every* rank).
    for h in rank + 1..n_nodes {
        let msgs = comm.recv_tagged(tag_base | h as u32, 1, comm.patience())?;
        recv_bytes += apply_manifest(
            inst,
            variant,
            &msgs[0].data,
            &mut node_map,
            &mut scratch,
            rank as u32,
        )
        .map_err(|_| CommError::Corrupt { tag: tag_base | h as u32, from: msgs[0].from })?;
    }

    // ---- Hierarchical refinement (§III-D): node-local, no messages.
    // Rebuild the SoA on the final map: this rank's members arrive as
    // one contiguous ascending-id slice (the order assign_pes_node's
    // contract demands) without scanning all objects per node.
    scratch.build_soa(inst, &node_map, n_nodes);
    let members = &scratch.soa_objs[scratch.soa_node(rank)];
    let pe_assign = {
        let _sr = crate::obs::span("refine.pes", "dist");
        hierarchical::assign_pes_node(inst, rank as u32, members, refine_tol)
    };

    // ---- PE-assignment exchange: every node assembles the complete
    // new mapping (the driver routes with it; the strategy returns it).
    let mut pbuf = Vec::with_capacity(pe_assign.len() * 8);
    for &(o, pe) in &pe_assign {
        wire::put_u32(&mut pbuf, o);
        wire::put_u32(&mut pbuf, pe);
    }
    for p in 0..n_nodes as u32 {
        if p as usize != rank {
            comm.send(p, tag_base | PE_BIT | rank as u32, pbuf.clone());
        }
    }
    let mut full_mapping = vec![u32::MAX; n_objects];
    for &(o, pe) in &pe_assign {
        full_mapping[o as usize] = pe;
    }
    for h in 0..n_nodes {
        if h == rank {
            continue;
        }
        let msgs = comm.recv_tagged(tag_base | PE_BIT | h as u32, 1, comm.patience())?;
        let corrupt =
            |_| CommError::Corrupt { tag: tag_base | PE_BIT | h as u32, from: msgs[0].from };
        let mut r = wire::Reader::new(&msgs[0].data);
        while !r.is_empty() {
            let o = r.u32().map_err(corrupt)?;
            let pe = r.u32().map_err(corrupt)?;
            full_mapping[o as usize] = pe;
        }
    }
    debug_assert!(
        full_mapping.iter().all(|&pe| pe != u32::MAX),
        "an object fell through the PE exchange"
    );
    Ok(Stage3Out { manifest, migrations, recv_bytes, full_mapping })
}

/// Replay one node's manifest into this node's replica (and centroid
/// state for the coord variant — the same per-migration update the
/// picking loop performs inline, in the same order). Returns the bytes
/// destined for this node, or [`wire::Truncated`] on a short frame
/// (the caller maps it to `CommError::Corrupt`).
fn apply_manifest(
    inst: &Instance,
    variant: Variant,
    data: &[u8],
    node_map: &mut [u32],
    scratch: &mut LbScratch,
    my_rank: u32,
) -> Result<f64, wire::Truncated> {
    let mut r = wire::Reader::new(data);
    let mut arrived = 0.0;
    while !r.is_empty() {
        let o = r.u32()?;
        let dest = r.u32()?;
        let bytes = r.f64()?;
        let from = node_map[o as usize];
        node_map[o as usize] = dest;
        scratch.moved[o as usize] = true;
        if variant == Variant::Coordinate {
            object_selection::apply_migration_to_centroids(inst, from, dest, o, scratch);
        }
        if dest == my_rank {
            arrived += bytes;
        }
    }
    Ok(arrived)
}
