//! PJRT execution engine: loads the AOT HLO-text artifacts produced by
//! `python -m compile.aot` and runs them on the embedded CPU PJRT
//! client. Python never runs here — the Rust binary is self-contained
//! once `artifacts/` exists.
//!
//! HLO **text** (not serialized proto) is the interchange format: the
//! crate's xla_extension 0.5.1 rejects jax≥0.5 protos with 64-bit
//! instruction ids, while the text parser reassigns ids (see
//! /opt/xla-example/README.md and python/compile/aot.py).
//!
//! The real engine requires the `xla` bindings, which are unavailable
//! in offline builds; it is therefore gated behind the `pjrt` cargo
//! feature. Without the feature an API-compatible stub [`Engine`]
//! reports PJRT as unavailable at construction time, so every caller
//! (`Backend::Pjrt` setup, `difflb check`, the pjrt benches/tests)
//! degrades to the native backend or a skip.

pub mod manifest;

#[cfg(feature = "pjrt")]
mod pjrt_engine;
#[cfg(feature = "pjrt")]
pub use pjrt_engine::Engine;

pub use manifest::{ArtifactMeta, Manifest};

/// Particle state-of-arrays batch processed by the PIC kernel.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PicBatch {
    pub x: Vec<f64>,
    pub y: Vec<f64>,
    pub vx: Vec<f64>,
    pub vy: Vec<f64>,
    pub q: Vec<f64>,
}

impl PicBatch {
    pub fn len(&self) -> usize {
        self.x.len()
    }

    pub fn is_empty(&self) -> bool {
        self.x.is_empty()
    }

    pub fn with_capacity(n: usize) -> PicBatch {
        PicBatch {
            x: Vec::with_capacity(n),
            y: Vec::with_capacity(n),
            vx: Vec::with_capacity(n),
            vy: Vec::with_capacity(n),
            q: Vec::with_capacity(n),
        }
    }

    /// Append an inert padding particle (q = 0 ⇒ zero force, stays put).
    pub fn push_pad(&mut self) {
        self.x.push(0.5);
        self.y.push(0.5);
        self.vx.push(0.0);
        self.vy.push(0.0);
        self.q.push(0.0);
    }
}

/// Stub engine compiled when the `pjrt` feature is off: constructing it
/// always fails, so `Manifest`-gated call sites (tests, benches, the
/// `auto` backend) skip the PJRT path cleanly.
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    pub fn new() -> anyhow::Result<Engine> {
        Engine::with_manifest(Manifest::load_default()?)
    }

    pub fn with_manifest(_manifest: Manifest) -> anyhow::Result<Engine> {
        anyhow::bail!(
            "difflb was built without the `pjrt` feature; \
             rebuild with `--features pjrt` (requires the xla bindings) \
             or use the native backend"
        )
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn pic_push(&self, _state: &mut PicBatch, _l: f64, _q: f64) -> anyhow::Result<()> {
        anyhow::bail!("PJRT engine unavailable (built without `pjrt`)")
    }

    pub fn stencil_step(
        &self,
        _grid: &[f64],
        _rows: usize,
        _cols: usize,
        _alpha: f64,
    ) -> anyhow::Result<Vec<f64>> {
        anyhow::bail!("PJRT engine unavailable (built without `pjrt`)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // engine tests requiring real artifacts live in rust/tests/
    // (integration); here we only exercise batch helpers.

    #[test]
    fn pad_particles_are_inert_shape() {
        let mut b = PicBatch::with_capacity(2);
        b.push_pad();
        b.push_pad();
        assert_eq!(b.len(), 2);
        assert_eq!(b.q, vec![0.0, 0.0]);
        assert_eq!(b.x, vec![0.5, 0.5]);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_engine_reports_unavailable() {
        let err = Engine::with_manifest(Manifest::parse("", "arts".into()).unwrap())
            .err()
            .expect("stub must fail");
        assert!(format!("{err}").contains("pjrt"), "{err}");
    }
}
