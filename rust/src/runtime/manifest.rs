//! Artifact manifest parsing.
//!
//! `python -m compile.aot` writes `artifacts/manifest.txt` with one
//! `key=value` record per line describing each lowered HLO artifact;
//! the engine uses it to pick executables by logical kind + shape
//! instead of hard-coding file names.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Metadata for one AOT artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    /// "pic_push" or "stencil".
    pub kind: String,
    /// pic_push: particle-batch size.
    pub n: usize,
    /// pic_push: fused steps per invocation.
    pub steps: usize,
    /// stencil: grid shape.
    pub rows: usize,
    pub cols: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Default artifacts directory: `$DIFFLB_ARTIFACTS` or `artifacts/`.
    pub fn default_dir() -> PathBuf {
        std::env::var("DIFFLB_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn load_default() -> Result<Manifest> {
        Manifest::load(Self::default_dir())
    }

    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {} (run `make artifacts` first)", path.display())
        })?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let mut artifacts = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut kv: HashMap<&str, &str> = HashMap::new();
            for tok in line.split_whitespace() {
                let (k, v) = tok
                    .split_once('=')
                    .with_context(|| format!("manifest line {}: bad token '{tok}'", lineno + 1))?;
                kv.insert(k, v);
            }
            let get = |k: &str| -> Result<&str> {
                kv.get(k)
                    .copied()
                    .with_context(|| format!("manifest line {}: missing '{k}'", lineno + 1))
            };
            let num = |k: &str| -> usize {
                kv.get(k).and_then(|v| v.parse().ok()).unwrap_or(0)
            };
            let name = get("name")?.to_string();
            let file = dir.join(get("file")?);
            if artifacts.iter().any(|a: &ArtifactMeta| a.name == name) {
                bail!("duplicate artifact '{name}'");
            }
            artifacts.push(ArtifactMeta {
                name,
                file,
                kind: get("kind")?.to_string(),
                n: num("n"),
                steps: num("steps"),
                rows: num("rows"),
                cols: num("cols"),
            });
        }
        Ok(Manifest { artifacts, dir })
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Single-step pic_push batch sizes, ascending.
    pub fn pic_batch_sizes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == "pic_push" && a.steps == 1)
            .map(|a| a.n)
            .collect();
        v.sort_unstable();
        v
    }

    /// The single-step pic_push artifact with batch size exactly `n`.
    pub fn pic_for_batch(&self, n: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.kind == "pic_push" && a.steps == 1 && a.n == n)
    }

    /// A fused-epoch pic_push artifact for `steps`, if one was lowered.
    pub fn pic_epoch(&self, steps: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.kind == "pic_push" && a.steps == steps)
    }

    pub fn stencil_for(&self, rows: usize, cols: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.kind == "stencil" && a.rows == rows && a.cols == cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
name=pic_push_n1024 file=pic_push_n1024.hlo.txt kind=pic_push n=1024 steps=1
name=pic_push_n8192 file=pic_push_n8192.hlo.txt kind=pic_push n=8192 steps=1
name=pic_push_epoch5_n65536 file=e5.hlo.txt kind=pic_push n=65536 steps=5
name=stencil_256x256 file=stencil_256x256.hlo.txt kind=stencil rows=256 cols=256
";

    #[test]
    fn parses_and_queries() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("arts")).unwrap();
        assert_eq!(m.artifacts.len(), 4);
        assert_eq!(m.pic_batch_sizes(), vec![1024, 8192]);
        assert_eq!(m.pic_for_batch(1024).unwrap().name, "pic_push_n1024");
        assert!(m.pic_for_batch(4096).is_none());
        assert_eq!(m.pic_epoch(5).unwrap().n, 65536);
        assert_eq!(m.stencil_for(256, 256).unwrap().rows, 256);
        assert!(m.by_name("pic_push_n8192").unwrap().file.starts_with("arts"));
    }

    #[test]
    fn rejects_duplicates_and_bad_tokens() {
        let dup = format!("{SAMPLE}name=pic_push_n1024 file=x kind=pic_push n=1 steps=1\n");
        assert!(Manifest::parse(&dup, PathBuf::from(".")).is_err());
        assert!(Manifest::parse("name", PathBuf::from(".")).is_err());
    }
}
