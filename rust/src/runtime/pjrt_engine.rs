//! The real PJRT engine (requires the `xla` bindings — `pjrt` feature).
//!
//! Moved verbatim out of `runtime::mod` when the feature gate was
//! introduced; see the module docs there for the HLO-text rationale.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::{Manifest, PicBatch};

/// Lazily-compiled PJRT executables keyed by artifact name.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl Engine {
    /// Create an engine over the default artifacts directory.
    pub fn new() -> Result<Engine> {
        Engine::with_manifest(Manifest::load_default()?)
    }

    pub fn with_manifest(manifest: Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        crate::info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Engine { client, manifest, executables: Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (once) and cache the executable for `name`.
    fn ensure_compiled(&self, name: &str) -> Result<()> {
        let mut cache = self.executables.lock().unwrap();
        if cache.contains_key(name) {
            return Ok(());
        }
        let meta = self
            .manifest
            .by_name(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?;
        let path = meta.file.to_string_lossy().to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        crate::debug!("compiled artifact {name} from {path}");
        cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute the named pic_push artifact on exactly its batch size.
    fn run_pic_artifact(&self, name: &str, b: &PicBatch, l: f64, q: f64) -> Result<PicBatch> {
        self.ensure_compiled(name)?;
        let cache = self.executables.lock().unwrap();
        let exe = cache.get(name).unwrap();
        let args = [
            xla::Literal::vec1(&b.x),
            xla::Literal::vec1(&b.y),
            xla::Literal::vec1(&b.vx),
            xla::Literal::vec1(&b.vy),
            xla::Literal::vec1(&b.q),
            xla::Literal::vec1(&[l, q]),
        ];
        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (xo, yo, vxo, vyo) = result.to_tuple4()?;
        Ok(PicBatch {
            x: xo.to_vec::<f64>()?,
            y: yo.to_vec::<f64>()?,
            vx: vxo.to_vec::<f64>()?,
            vy: vyo.to_vec::<f64>()?,
            q: b.q.clone(),
        })
    }

    /// One PIC step over an arbitrary-size batch: chunks into the
    /// largest available artifact batch sizes and pads the tail with
    /// inert particles. State is updated in place.
    pub fn pic_push(&self, state: &mut PicBatch, l: f64, q: f64) -> Result<()> {
        let sizes = self.manifest.pic_batch_sizes();
        anyhow::ensure!(!sizes.is_empty(), "no pic_push artifacts in manifest");
        let n = state.len();
        let mut out = PicBatch::with_capacity(n);
        let mut start = 0;
        while start < n {
            let left = n - start;
            // largest artifact batch <= left, else the smallest one (pad)
            let bs = *sizes.iter().rev().find(|&&s| s <= left).unwrap_or(&sizes[0]);
            let take = left.min(bs);
            let mut chunk = PicBatch {
                x: state.x[start..start + take].to_vec(),
                y: state.y[start..start + take].to_vec(),
                vx: state.vx[start..start + take].to_vec(),
                vy: state.vy[start..start + take].to_vec(),
                q: state.q[start..start + take].to_vec(),
            };
            for _ in take..bs {
                chunk.push_pad();
            }
            let name = self.manifest.pic_for_batch(bs).unwrap().name.clone();
            let pushed = self.run_pic_artifact(&name, &chunk, l, q)?;
            out.x.extend_from_slice(&pushed.x[..take]);
            out.y.extend_from_slice(&pushed.y[..take]);
            out.vx.extend_from_slice(&pushed.vx[..take]);
            out.vy.extend_from_slice(&pushed.vy[..take]);
            out.q.extend_from_slice(&chunk.q[..take]);
            start += take;
        }
        *state = out;
        Ok(())
    }

    /// One stencil sweep via the `rows x cols` artifact (exact shape).
    pub fn stencil_step(&self, grid: &[f64], rows: usize, cols: usize, alpha: f64) -> Result<Vec<f64>> {
        anyhow::ensure!(grid.len() == rows * cols, "grid shape mismatch");
        let meta = self
            .manifest
            .stencil_for(rows, cols)
            .with_context(|| format!("no stencil artifact for {rows}x{cols}"))?;
        let name = meta.name.clone();
        self.ensure_compiled(&name)?;
        let cache = self.executables.lock().unwrap();
        let exe = cache.get(&name).unwrap();
        let args = [
            xla::Literal::vec1(grid).reshape(&[rows as i64, cols as i64])?,
            xla::Literal::vec1(&[alpha]),
        ];
        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f64>()?)
    }
}
