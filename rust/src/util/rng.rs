//! Deterministic pseudo-random number generation.
//!
//! `crates.io` is unreachable in the build environment, so this is a
//! from-scratch substrate: a small, fast, seedable generator
//! (xoshiro256++ seeded via SplitMix64) plus the handful of
//! distributions the workloads need (uniform, normal, exponential,
//! geometric-column placement for PIC PRK). Everything in the repo that
//! uses randomness threads one of these through explicitly, so every
//! experiment is reproducible from a single `u64` seed.

/// xoshiro256++ generator. Deterministic, seedable, fast, no deps.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed; distinct seeds give independent
    /// streams (SplitMix64 expansion avoids correlated lanes).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent child stream (for per-node / per-chare rngs).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3])).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. Debiased via rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // (0, 1]
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        -u.ln() / lambda
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
