//! Tiny leveled logger (log/env_logger are unavailable offline).
//!
//! Level is process-global, settable programmatically or via
//! `DIFFLB_LOG=error|warn|info|debug|trace`. Macros mirror the `log`
//! crate's so call sites read idiomatically.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info
static INIT: std::sync::Once = std::sync::Once::new();

pub fn init_from_env() {
    INIT.call_once(|| {
        // timestamps share the telemetry epoch, so log lines and trace
        // events line up on one clock
        crate::obs::epoch();
        if let Ok(v) = std::env::var("DIFFLB_LOG") {
            set_level(match v.to_ascii_lowercase().as_str() {
                "error" => Level::Error,
                "warn" => Level::Warn,
                "info" => Level::Info,
                "debug" => Level::Debug,
                "trace" => Level::Trace,
                _ => Level::Info,
            });
        }
    });
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Seconds since the shared process epoch (logger + telemetry).
pub fn elapsed() -> f64 {
    crate::obs::epoch().elapsed().as_secs_f64()
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments) {
    init_from_env();
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        // inside a simnet node body, attribute the line to its rank so
        // interleaved 16-node chaos output stays readable
        match crate::obs::rank() {
            Some(r) => eprintln!("[{:>9.3}s {tag} r{r} {module}] {msg}", elapsed()),
            None => eprintln!("[{:>9.3}s {tag} {module}] {msg}", elapsed()),
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
