//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! subcommands, and auto-generated `--help`. Typed getters parse on
//! access with uniform error messages. This is deliberately tiny but
//! covers everything the `difflb` CLI, examples, and bench binaries use.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declarative option spec for help text + validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub program: String,
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

/// Parser with declared options (for help/validation).
#[derive(Debug, Clone)]
pub struct Parser {
    pub about: &'static str,
    pub subcommands: Vec<(&'static str, &'static str)>,
    pub specs: Vec<OptSpec>,
}

impl Parser {
    pub fn new(about: &'static str) -> Self {
        Parser { about, subcommands: Vec::new(), specs: Vec::new() }
    }

    pub fn subcommand(mut self, name: &'static str, help: &'static str) -> Self {
        self.subcommands.push((name, help));
        self
    }

    pub fn opt(mut self, name: &'static str, default: Option<&'static str>, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, default, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self, program: &str) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{}\n", self.about);
        let _ = writeln!(s, "USAGE: {program} [SUBCOMMAND] [OPTIONS]");
        if !self.subcommands.is_empty() {
            let _ = writeln!(s, "\nSUBCOMMANDS:");
            for (name, help) in &self.subcommands {
                let _ = writeln!(s, "  {name:<18} {help}");
            }
        }
        if !self.specs.is_empty() {
            let _ = writeln!(s, "\nOPTIONS:");
            for spec in &self.specs {
                let d = spec
                    .default
                    .map(|d| format!(" [default: {d}]"))
                    .unwrap_or_default();
                let key = if spec.is_flag {
                    format!("--{}", spec.name)
                } else {
                    format!("--{} <v>", spec.name)
                };
                let _ = writeln!(s, "  {key:<22} {}{d}", spec.help);
            }
        }
        s
    }

    /// Parse `std::env::args()`-style input. On `--help`, prints usage and
    /// exits. Unknown `--options` are an error when specs are declared.
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut args = Args {
            program: argv.first().cloned().unwrap_or_else(|| "difflb".into()),
            ..Default::default()
        };
        // seed defaults
        for spec in &self.specs {
            if let Some(d) = spec.default {
                args.opts.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 1;
        let known = |name: &str| self.specs.iter().find(|s| s.name == name);
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                print!("{}", self.usage(&args.program));
                std::process::exit(0);
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = known(&key);
                if !self.specs.is_empty() && spec.is_none() {
                    return Err(format!("unknown option --{key} (see --help)"));
                }
                let is_flag = spec.map(|s| s.is_flag).unwrap_or(false);
                if is_flag {
                    if inline_val.is_some() {
                        return Err(format!("--{key} is a flag, takes no value"));
                    }
                    args.flags.push(key);
                } else if let Some(v) = inline_val {
                    args.opts.insert(key, v);
                } else {
                    i += 1;
                    let v = argv
                        .get(i)
                        .ok_or_else(|| format!("--{key} expects a value"))?;
                    args.opts.insert(key, v.clone());
                }
            } else if args.subcommand.is_none()
                && args.positional.is_empty()
                && self.subcommands.iter().any(|(n, _)| n == a)
            {
                args.subcommand = Some(a.clone());
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// Convenience: parse the real process arguments, exiting on error.
    pub fn parse_env(&self) -> Args {
        let argv: Vec<String> = std::env::args().collect();
        match self.parse(&argv) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("error: {e}");
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> String {
        self.get(name)
            .unwrap_or_else(|| panic!("missing required option --{name}"))
            .to_string()
    }

    pub fn parse_as<T: std::str::FromStr>(&self, name: &str) -> T
    where
        T::Err: std::fmt::Display,
    {
        let raw = self
            .get(name)
            .unwrap_or_else(|| panic!("missing required option --{name}"));
        raw.parse::<T>()
            .unwrap_or_else(|e| panic!("--{name}={raw}: {e}"))
    }

    pub fn usize(&self, name: &str) -> usize {
        self.parse_as(name)
    }

    pub fn u64(&self, name: &str) -> u64 {
        self.parse_as(name)
    }

    pub fn f64(&self, name: &str) -> f64 {
        self.parse_as(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        std::iter::once("prog".to_string())
            .chain(s.iter().map(|x| x.to_string()))
            .collect()
    }

    fn parser() -> Parser {
        Parser::new("test")
            .subcommand("run", "run it")
            .opt("count", Some("4"), "how many")
            .opt("name", None, "a name")
            .flag("verbose", "chatty")
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = parser()
            .parse(&argv(&["run", "--count", "7", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.usize("count"), 7);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults_and_equals_syntax() {
        let a = parser().parse(&argv(&["--name=x"])).unwrap();
        assert_eq!(a.usize("count"), 4);
        assert_eq!(a.str("name"), "x");
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parser().parse(&argv(&["--nope", "1"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parser().parse(&argv(&["--count"])).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(parser().parse(&argv(&["--verbose=1"])).is_err());
    }

    #[test]
    fn usage_mentions_everything() {
        let u = parser().usage("prog");
        assert!(u.contains("--count"));
        assert!(u.contains("run"));
        assert!(u.contains("[default: 4]"));
    }
}
