//! Miniature property-based testing harness (proptest is unavailable).
//!
//! A property is a closure over a [`Gen`] (seeded RNG + size hints). The
//! runner executes `cases` random cases; on failure it retries the same
//! case with progressively smaller size hints (a lightweight stand-in
//! for shrinking) and reports the failing seed so the case can be
//! replayed exactly:
//!
//! ```ignore
//! prop::check("load is conserved", 200, |g| {
//!     let loads = g.vec_f64(1.0, 100.0, 1..64);
//!     let out = diffuse(&loads);
//!     prop::assert_close(out.iter().sum(), loads.iter().sum(), 1e-9)
//! });
//! ```

use super::rng::Rng;

/// Generator handed to each property case: seeded RNG + size scale.
pub struct Gen {
    pub rng: Rng,
    pub size: usize,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Gen {
        Gen { rng: Rng::new(seed), size, seed }
    }

    /// Integer in `[lo, hi)`, hi scaled down by the current size factor
    /// during shrink retries.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let span = (hi - lo).max(1);
        let scaled = lo + span.min(self.size.max(1));
        self.rng.range(lo, scaled.max(lo + 1).min(hi).max(lo + 1))
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Vec of f64 with length drawn from `len_lo..len_hi`.
    pub fn vec_f64(&mut self, lo: f64, hi: f64, len_lo: usize, len_hi: usize) -> Vec<f64> {
        let n = self.usize_in(len_lo, len_hi);
        (0..n).map(|_| self.rng.uniform(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, lo: usize, hi: usize, len: usize) -> Vec<usize> {
        (0..len).map(|_| self.rng.range(lo, hi)).collect()
    }
}

/// Outcome of one property case.
pub type CaseResult = Result<(), String>;

/// Run `cases` random cases of `property`. Panics (test failure) with
/// the failing seed + message on the first counterexample. Honors
/// `DIFFLB_PROP_SEED` to replay a specific seed.
pub fn check(name: &str, cases: u64, property: impl Fn(&mut Gen) -> CaseResult) {
    let base = match std::env::var("DIFFLB_PROP_SEED") {
        Ok(s) => s.parse::<u64>().expect("DIFFLB_PROP_SEED must be u64"),
        Err(_) => 0xD1FF_1B00,
    };
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9));
        let mut g = Gen::new(seed, 64);
        if let Err(msg) = property(&mut g) {
            // "shrink": retry same seed at smaller sizes to report the
            // smallest size that still fails.
            let mut smallest = (64usize, msg.clone());
            for size in [32, 16, 8, 4, 2, 1] {
                let mut g = Gen::new(seed, size);
                if let Err(m) = property(&mut g) {
                    smallest = (size, m);
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed}, min size {}):\n  {}\n\
                 replay with DIFFLB_PROP_SEED={seed}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Property helper: assert two floats are within tolerance.
pub fn assert_close(a: f64, b: f64, tol: f64) -> CaseResult {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{a} !≈ {b} (tol {tol})"))
    }
}

/// Property helper: boolean condition with message.
pub fn assert_that(cond: bool, msg: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        // interior mutability not needed: use a Cell
        let counter = std::cell::Cell::new(0u64);
        check("sum symmetric", 50, |g| {
            counter.set(counter.get() + 1);
            let a = g.f64_in(-5.0, 5.0);
            let b = g.f64_in(-5.0, 5.0);
            assert_close(a + b, b + a, 1e-15)
        });
        count += counter.get();
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check("always fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn gen_respects_bounds() {
        check("bounds", 100, |g| {
            let v = g.vec_f64(1.0, 2.0, 1, 10);
            assert_that(
                !v.is_empty() && v.len() < 10 && v.iter().all(|x| (1.0..2.0).contains(x)),
                format!("bad vec {v:?}"),
            )
        });
    }
}
