//! Result/series writers: CSV files and output-directory management.
//!
//! Bench binaries write the series behind every paper figure as CSV into
//! `out/` so they can be re-plotted; tables print to stdout via
//! [`crate::util::bench::Table`] and are also mirrored to CSV here.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Default output directory for bench/example artifacts.
pub fn out_dir() -> PathBuf {
    let p = std::env::var("DIFFLB_OUT").unwrap_or_else(|_| "out".to_string());
    PathBuf::from(p)
}

/// Ensure `out/` exists and return `out/<name>`.
pub fn out_path(name: &str) -> Result<PathBuf> {
    let dir = out_dir();
    std::fs::create_dir_all(&dir).with_context(|| format!("creating {}", dir.display()))?;
    Ok(dir.join(name))
}

/// Incremental CSV writer.
pub struct CsvWriter {
    file: std::io::BufWriter<std::fs::File>,
    cols: usize,
    pub path: PathBuf,
}

impl CsvWriter {
    pub fn create(path: impl AsRef<Path>, headers: &[&str]) -> Result<CsvWriter> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        let f = std::fs::File::create(&path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut w = CsvWriter { file: std::io::BufWriter::new(f), cols: headers.len(), path };
        writeln!(w.file, "{}", headers.join(","))?;
        Ok(w)
    }

    pub fn row(&mut self, cells: &[&dyn std::fmt::Display]) -> Result<()> {
        anyhow::ensure!(cells.len() == self.cols, "csv row arity mismatch");
        let line = cells.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(",");
        writeln!(self.file, "{line}")?;
        Ok(())
    }

    pub fn row_f64(&mut self, cells: &[f64]) -> Result<()> {
        let refs: Vec<String> = cells.iter().map(|c| format!("{c}")).collect();
        anyhow::ensure!(refs.len() == self.cols, "csv row arity mismatch");
        writeln!(self.file, "{}", refs.join(","))?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.file.flush()?;
        Ok(())
    }
}

/// Parse a simple CSV (no quoting) back into rows — used by tests to
/// round-trip bench outputs.
pub fn read_csv(path: impl AsRef<Path>) -> Result<(Vec<String>, Vec<Vec<String>>)> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    let mut lines = text.lines();
    let headers = lines
        .next()
        .context("empty csv")?
        .split(',')
        .map(|s| s.to_string())
        .collect();
    let rows = lines
        .filter(|l| !l.trim().is_empty())
        .map(|l| l.split(',').map(|s| s.to_string()).collect())
        .collect();
    Ok((headers, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_round_trip() {
        let dir = std::env::temp_dir().join("difflb_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["iter", "value"]).unwrap();
            w.row(&[&1, &2.5]).unwrap();
            w.row_f64(&[2.0, 3.5]).unwrap();
            w.flush().unwrap();
        }
        let (h, rows) = read_csv(&path).unwrap();
        assert_eq!(h, vec!["iter", "value"]);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0], vec!["1", "2.5"]);
    }

    #[test]
    fn arity_mismatch_errors() {
        let dir = std::env::temp_dir().join("difflb_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let mut w = CsvWriter::create(dir.join("u.csv"), &["a", "b"]).unwrap();
        assert!(w.row(&[&1]).is_err());
    }
}
