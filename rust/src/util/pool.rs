//! Persistent worker-thread pool shared by every parallel hot path.
//!
//! The seed code spawned **scoped threads per call** (`native_push`
//! spawned `threads` OS threads every PIC step — ~50-100 µs of spawn +
//! join overhead per step, dwarfing the push itself at small batch
//! sizes; see EXPERIMENTS.md §Perf). This module keeps one
//! process-wide pool of workers alive and hands them borrowed closures,
//! so steady-state parallel sections cost two condvar signals instead
//! of `threads` thread spawns.
//!
//! Design constraints, in order:
//!
//! 1. **Determinism** — the pool never decides *what* a task computes,
//!    only *when*. Callers partition work into fixed chunks derived
//!    from the task count alone, so results are bit-identical for any
//!    worker count (including zero workers, where everything runs
//!    inline on the caller).
//! 2. **Borrowed data** — tasks may borrow from the caller's stack.
//!    [`ThreadPool::scoped`] erases the lifetime internally and is
//!    sound because it always blocks until every submitted task
//!    finished (a drop guard waits even when a task panics).
//! 3. **No dependencies** — std only (crossbeam/rayon are unavailable
//!    offline): an `mpsc` channel feeds workers, a mutex+condvar latch
//!    tracks completion.
//!
//! Tasks must never block on other tasks (they are opaque closures run
//! to completion); the pool is for data-parallel fan-out, not a general
//! executor. Do NOT call `scoped` from inside a pool task: if every
//! worker sits in an inner `wait()` there is no one left to run the
//! inner jobs and the pool deadlocks. Every current call site
//! (native_push, stage-1 candidate fill, stage-3 scoring) is a leaf
//! parallel section.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A task queued to the workers: a lifetime-erased boxed closure plus
/// the latch of the scope it belongs to.
struct Job {
    run: Box<dyn FnOnce() + Send + 'static>,
    latch: Arc<Latch>,
}

/// Completion latch for one `scoped` call.
struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

impl Latch {
    fn new(count: usize) -> Latch {
        Latch {
            remaining: Mutex::new(count),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        }
    }

    fn complete_one(&self) {
        let mut left = self.remaining.lock().unwrap();
        *left -= 1;
        if *left == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) {
        let mut left = self.remaining.lock().unwrap();
        while *left > 0 {
            left = self.done.wait(left).unwrap();
        }
    }
}

/// Waits for the latch on drop, so a panic unwinding through the caller
/// cannot free borrowed stack data while workers still reference it.
struct WaitGuard<'a>(&'a Latch);

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        self.0.wait();
    }
}

/// The persistent pool.
pub struct ThreadPool {
    /// Mutex-wrapped so `ThreadPool` is `Sync` on every supported
    /// toolchain (`mpsc::Sender` only became `Sync` in rustc 1.72);
    /// enqueueing is a few ns, contention is irrelevant next to task
    /// runtime.
    tx: Mutex<Sender<Job>>,
    workers: usize,
}

impl ThreadPool {
    /// Spawn a pool with `workers` background threads. `0` is valid:
    /// every task then runs inline on the caller.
    pub fn new(workers: usize) -> ThreadPool {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        for i in 0..workers {
            let rx = Arc::clone(&rx);
            std::thread::Builder::new()
                .name(format!("difflb-pool-{i}"))
                .spawn(move || loop {
                    // hold the receiver lock only while dequeueing
                    let job = match rx.lock().unwrap().recv() {
                        Ok(job) => job,
                        Err(_) => return, // pool dropped
                    };
                    if catch_unwind(AssertUnwindSafe(job.run)).is_err() {
                        job.latch.panicked.store(true, Ordering::SeqCst);
                    }
                    job.latch.complete_one();
                })
                .expect("spawning pool worker");
        }
        ThreadPool { tx: Mutex::new(tx), workers }
    }

    /// Number of background workers (callers typically chunk work into
    /// `threads()`-ish pieces; the exact chunking must depend only on
    /// caller-supplied parameters when determinism across machines
    /// matters).
    pub fn threads(&self) -> usize {
        self.workers
    }

    /// Run every task to completion, in parallel across the workers,
    /// blocking until all are done. The first task runs on the calling
    /// thread (the caller would otherwise idle in `wait`), the rest are
    /// queued. Panics in any task propagate to the caller as a single
    /// panic **after** all tasks finished.
    pub fn scoped<'env>(&self, mut tasks: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        if tasks.is_empty() {
            return;
        }
        if self.workers == 0 || tasks.len() == 1 {
            for t in tasks {
                t();
            }
            return;
        }
        let queued_total = tasks.len() - 1;
        let latch = Arc::new(Latch::new(queued_total));
        let first = tasks.remove(0);
        let mut send_failed = false;
        {
            // From here on, queued closures may borrow 'env data; the
            // guard guarantees they all finish before this block exits,
            // which is what makes the lifetime erasure below sound.
            let guard = WaitGuard(&latch);
            {
                // A poisoned lock only means some thread panicked while
                // *enqueueing*; the sender itself is still sound.
                let tx = self.tx.lock().unwrap_or_else(|e| e.into_inner());
                let mut sent = 0usize;
                for task in tasks {
                    // SAFETY: the task is only executed before `guard`
                    // is dropped, i.e. strictly inside 'env.
                    let erased: Box<dyn FnOnce() + Send + 'static> =
                        unsafe { std::mem::transmute(task) };
                    if tx.send(Job { run: erased, latch: Arc::clone(&latch) }).is_err() {
                        // Workers gone (channel closed). Balance the
                        // latch for every job that will never run so
                        // the guard's wait() cannot hang, then report
                        // below once the sent jobs drained.
                        send_failed = true;
                        for _ in sent..queued_total {
                            latch.complete_one();
                        }
                        break;
                    }
                    sent += 1;
                }
            }
            first();
            drop(guard); // waits
        }
        if send_failed {
            panic!("thread-pool workers disappeared while enqueueing");
        }
        if latch.panicked.load(Ordering::SeqCst) {
            panic!("thread-pool task panicked");
        }
    }

}

/// The process-global pool, sized to the machine (one worker per
/// available core; the caller thread participates too, so parallel
/// sections use `threads() + 1` lanes at full fan-out). Sized once at
/// first use; `DIFFLB_THREADS` caps it for experiments.
pub fn global() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
        let cap = std::env::var("DIFFLB_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(cores);
        // workers = lanes - 1: the scoped caller always runs one task.
        ThreadPool::new(cap.min(cores).saturating_sub(1))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn scoped_runs_all_tasks_with_borrows() {
        let pool = ThreadPool::new(3);
        let mut data = vec![0u64; 17];
        {
            let chunks: Vec<&mut [u64]> = data.chunks_mut(5).collect();
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (i, c) in chunks.into_iter().enumerate() {
                tasks.push(Box::new(move || {
                    for (j, v) in c.iter_mut().enumerate() {
                        *v = (i * 5 + j) as u64;
                    }
                }));
            }
            pool.scoped(tasks);
        }
        assert_eq!(data, (0..17).collect::<Vec<u64>>());
    }

    /// Chunked fan-out like the production call sites (native_push,
    /// candidate fill): split `n` marks into `n_tasks` ranges, bump
    /// each exactly once.
    fn mark_in_chunks(pool: &ThreadPool, marks: &[AtomicUsize], n_tasks: usize) {
        let n = marks.len();
        let chunk = n.div_ceil(n_tasks);
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for slice in marks.chunks(chunk) {
            tasks.push(Box::new(move || {
                for m in slice {
                    m.fetch_add(1, Ordering::SeqCst);
                }
            }));
        }
        pool.scoped(tasks);
    }

    #[test]
    fn chunked_fanout_covers_exactly_once_any_worker_count() {
        for workers in [0usize, 1, 2, 7] {
            let pool = ThreadPool::new(workers);
            for n in [1usize, 5, 16, 33] {
                for tasks in [1usize, 2, 4, 8] {
                    let marks: Vec<AtomicUsize> =
                        (0..n).map(|_| AtomicUsize::new(0)).collect();
                    mark_in_chunks(&pool, &marks, tasks);
                    assert!(
                        marks.iter().all(|m| m.load(Ordering::SeqCst) == 1),
                        "workers={workers} n={n} tasks={tasks}"
                    );
                }
            }
        }
    }

    #[test]
    fn panic_propagates_after_all_tasks_finish() {
        let pool = ThreadPool::new(2);
        let done = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(|| panic!("boom")),
                Box::new(|| {
                    done.fetch_add(1, Ordering::SeqCst);
                }),
                Box::new(|| {
                    done.fetch_add(1, Ordering::SeqCst);
                }),
            ];
            pool.scoped(tasks);
        }));
        assert!(result.is_err());
        assert_eq!(done.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn global_pool_is_usable() {
        let pool = global();
        let marks: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        mark_in_chunks(pool, &marks, pool.threads() + 1);
        assert!(marks.iter().all(|m| m.load(Ordering::SeqCst) == 1));
    }
}
