//! Summary statistics and small numeric helpers shared across the
//! metrics, benches, and load-balancing code.

/// Online/summary statistics over a slice of samples.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub std: f64,
    pub sum: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { n: 0, min: 0.0, max: 0.0, mean: 0.0, std: 0.0, sum: 0.0 };
        }
        let n = xs.len() as f64;
        let sum: f64 = xs.iter().sum();
        let mean = sum / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        Summary {
            n: xs.len(),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            mean,
            std: var.sqrt(),
            sum,
        }
    }

    /// Max-to-average ratio — the paper's load-imbalance metric (§II).
    pub fn max_avg_ratio(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.max / self.mean
        }
    }

    /// Population coefficient of variation.
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.std / self.mean
        }
    }
}

/// Percentile over unsorted data (nearest-rank on a sorted copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Exponential moving average accumulator (used for chare load history).
#[derive(Debug, Clone, Copy)]
pub struct Ema {
    pub alpha: f64,
    pub value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ema { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> f64 {
        self.value.unwrap_or(0.0)
    }
}

/// `a ≈ b` within absolute and relative tolerance.
pub fn approx_eq(a: f64, b: f64, atol: f64, rtol: f64) -> bool {
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!(approx_eq(s.mean, 2.5, 1e-12, 0.0));
        assert!(approx_eq(s.max_avg_ratio(), 1.6, 1e-12, 0.0));
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.max_avg_ratio(), 0.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!(approx_eq(percentile(&xs, 50.0), 50.0, 1.0, 0.0));
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..30 {
            e.update(10.0);
        }
        assert!(approx_eq(e.get(), 10.0, 1e-6, 0.0));
    }
}
