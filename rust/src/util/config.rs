//! Layered configuration system (serde/toml are unavailable offline).
//!
//! Supports an INI/TOML-subset file format:
//!
//! ```text
//! # comment
//! [section]
//! key = value          # values: string, number, bool
//! list = 1, 2, 3       # comma-separated
//! ```
//!
//! Lookups are by `"section.key"`. A [`Config`] can be layered: file <
//! overrides (e.g. CLI `--set section.key=value`), later layers win.
//! Typed getters parse on access; `get_or` supplies defaults so configs
//! stay minimal.
//!
//! Because `get_or` silently falls back to its default, a typo'd key
//! (`lb.neighbours`) would otherwise vanish without a trace. Every
//! getter therefore records the keys it actually resolved;
//! [`Config::unread_keys`] reports the set-but-never-read remainder,
//! which the coordinator surfaces as a warning (or an error under
//! `run.strict_config`).

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

#[derive(Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
    /// Keys successfully resolved by [`Config::get`] at least once —
    /// interior-mutable so read tracking doesn't infect every getter
    /// signature with `&mut`; a `Mutex` (not `RefCell`) keeps `Config`
    /// `Sync` for shared-reference use across threads.
    accessed: Mutex<BTreeSet<String>>,
}

impl Clone for Config {
    fn clone(&self) -> Config {
        Config {
            values: self.values.clone(),
            accessed: Mutex::new(self.accessed.lock().unwrap().clone()),
        }
    }
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse the INI-subset text format. Keys outside any section land in
    /// the "" section and are addressed without a dot.
    pub fn from_str(text: &str) -> Result<Config> {
        let mut cfg = Config::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            if key.ends_with('.') || key.starts_with('.') || k.trim().is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            cfg.values.insert(key, unquote(v.trim()).to_string());
        }
        Ok(cfg)
    }

    pub fn from_file(path: impl AsRef<Path>) -> Result<Config> {
        let p = path.as_ref();
        let text = std::fs::read_to_string(p)
            .with_context(|| format!("reading config {}", p.display()))?;
        Config::from_str(&text).with_context(|| format!("parsing {}", p.display()))
    }

    /// Overlay `other` on top of `self` (other wins). Read-tracking
    /// merges too: a key either layer already resolved stays read.
    pub fn layered(mut self, other: &Config) -> Config {
        for (k, v) in &other.values {
            self.values.insert(k.clone(), v.clone());
        }
        let mut seen = self.accessed.lock().unwrap();
        seen.extend(other.accessed.lock().unwrap().iter().cloned());
        drop(seen);
        self
    }

    /// Apply a `section.key=value` override string (CLI `--set`).
    pub fn set_kv(&mut self, kv: &str) -> Result<()> {
        let (k, v) = kv
            .split_once('=')
            .with_context(|| format!("override '{kv}' must be key=value"))?;
        self.values.insert(k.trim().to_string(), v.trim().to_string());
        Ok(())
    }

    pub fn set(&mut self, key: &str, value: impl ToString) {
        self.values.insert(key.to_string(), value.to_string());
    }

    /// Raw lookup. Records the key as read on a hit — the basis of
    /// [`Config::unread_keys`] typo detection (every typed getter
    /// funnels through here).
    pub fn get(&self, key: &str) -> Option<&str> {
        let v = self.values.get(key).map(|s| s.as_str());
        if v.is_some() {
            self.accessed.lock().unwrap().insert(key.to_string());
        }
        v
    }

    /// Keys that were set (file, `--set`, or [`Config::set`]) but never
    /// resolved by any getter — almost always typos, since `get_or`
    /// silently defaults on a miss.
    pub fn unread_keys(&self) -> Vec<String> {
        let seen = self.accessed.lock().unwrap();
        self.values.keys().filter(|k| !seen.contains(k.as_str())).cloned().collect()
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key).with_context(|| format!("missing config key '{key}'"))
    }

    pub fn parse<T: std::str::FromStr>(&self, key: &str) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.require(key)?;
        raw.parse::<T>()
            .map_err(|e| anyhow::anyhow!("config {key}={raw}: {e}"))
    }

    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => default,
            Some(raw) => raw
                .parse::<T>()
                .unwrap_or_else(|e| panic!("config {key}={raw}: {e}")),
        }
    }

    pub fn get_bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            None => default,
            Some("true") | Some("1") | Some("yes") | Some("on") => true,
            Some("false") | Some("0") | Some("no") | Some("off") => false,
            Some(v) => panic!("config {key}={v}: expected a boolean"),
        }
    }

    /// Comma-separated list of T.
    pub fn get_list<T: std::str::FromStr>(&self, key: &str) -> Result<Vec<T>>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.require(key)?;
        raw.split(',')
            .map(|s| {
                s.trim()
                    .parse::<T>()
                    .map_err(|e| anyhow::anyhow!("config {key} element '{s}': {e}"))
            })
            .collect()
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside quotes.
    let mut in_quote = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_quote = !in_quote,
            '#' if !in_quote => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(v: &str) -> &str {
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        &v[1..v.len() - 1]
    } else {
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top comment
seed = 42
[app]
name = "pic prk"   # trailing comment
grid = 1000
rho = 0.9
modes = 1, 2, 3
verbose = true
"#;

    #[test]
    fn parse_and_typed_get() {
        let c = Config::from_str(SAMPLE).unwrap();
        assert_eq!(c.parse::<u64>("seed").unwrap(), 42);
        assert_eq!(c.require("app.name").unwrap(), "pic prk");
        assert_eq!(c.parse::<usize>("app.grid").unwrap(), 1000);
        assert!((c.parse::<f64>("app.rho").unwrap() - 0.9).abs() < 1e-12);
        assert_eq!(c.get_list::<u32>("app.modes").unwrap(), vec![1, 2, 3]);
        assert!(c.get_bool_or("app.verbose", false));
        assert_eq!(c.get_or::<usize>("app.missing", 7), 7);
    }

    #[test]
    fn layering_and_overrides() {
        let base = Config::from_str("[a]\nx = 1\ny = 2").unwrap();
        let mut over = Config::new();
        over.set_kv("a.x=10").unwrap();
        let merged = base.layered(&over);
        assert_eq!(merged.parse::<i32>("a.x").unwrap(), 10);
        assert_eq!(merged.parse::<i32>("a.y").unwrap(), 2);
    }

    #[test]
    fn unread_keys_flags_typos() {
        let c = Config::from_str("[lb]\nstrategy = x\nneighbours = 4").unwrap();
        assert_eq!(c.unread_keys().len(), 2);
        assert_eq!(c.get("lb.strategy"), Some("x"));
        // the typo'd key stays unread no matter how often the real one
        // is resolved; misses don't mark anything
        assert!(c.get("lb.neighbors").is_none());
        assert_eq!(c.unread_keys(), vec!["lb.neighbours".to_string()]);
        // clones and layers carry the read set along
        let over = Config::from_str("[lb]\nseed = 9").unwrap();
        let merged = c.clone().layered(&over);
        assert_eq!(merged.unread_keys(), vec!["lb.neighbours", "lb.seed"]);
    }

    #[test]
    fn errors_are_informative() {
        assert!(Config::from_str("[oops").is_err());
        assert!(Config::from_str("justakey").is_err());
        let c = Config::from_str("x = notanumber").unwrap();
        assert!(c.parse::<i32>("x").is_err());
        assert!(c.require("nope").is_err());
    }
}
