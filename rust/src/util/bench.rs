//! Micro/bench harness (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with mean/median/p99 reporting and
//! a tabular reporter used by every `cargo bench` target to print the
//! paper's tables. Benches are `harness = false` binaries that call
//! [`time_fn`] / [`Table`].

use std::time::{Duration, Instant};

use super::stats::percentile;

/// Timing result for one benchmark case.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
    /// Sample standard deviation of the per-iteration times — the
    /// noise figure `tools/bench_gate.py` uses to widen its regression
    /// tolerance on jittery paths instead of flagging scheduler noise.
    pub std_s: f64,
}

impl Timing {
    pub fn per_iter(&self) -> Duration {
        Duration::from_secs_f64(self.mean_s)
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>10} iters  mean {:>12}  median {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            fmt_duration(self.mean_s),
            fmt_duration(self.median_s),
            fmt_duration(self.p99_s),
        )
    }
}

pub fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Time `f` with automatic iteration count targeting ~`budget` total
/// runtime (default 2s), after `warmup` runs. Returns per-iteration
/// statistics. A `black_box`-style sink prevents the optimizer from
/// deleting the workload: have `f` return a value.
pub fn time_fn<T>(name: &str, budget: Duration, mut f: impl FnMut() -> T) -> Timing {
    // Warmup + calibration.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let one = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget.as_secs_f64() / one) as usize).clamp(5, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
        / (samples.len().max(2) - 1) as f64;
    Timing {
        name: name.to_string(),
        iters,
        mean_s: mean,
        median_s: percentile(&samples, 50.0),
        p99_s: percentile(&samples, 99.0),
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        std_s: var.sqrt(),
    }
}

/// Quick one-shot wall-clock measurement (for end-to-end runs where a
/// single execution is already seconds long).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// Machine-readable bench report writer (`BENCH_*.json`): collects
/// [`Timing`]s plus optional throughput figures and serializes a stable
/// JSON document (hand-rolled — serde is unavailable offline), so the
/// perf trajectory of every hot path is diffable across PRs.
#[derive(Debug, Default, Clone)]
pub struct JsonReport {
    entries: Vec<String>,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl JsonReport {
    pub fn new() -> JsonReport {
        JsonReport::default()
    }

    /// Record one timed path; `throughput` is an optional
    /// `(unit, value)` pair, e.g. `("Mparticles/s", 12.3)`.
    pub fn add(&mut self, t: &Timing, throughput: Option<(&str, f64)>) {
        let mut obj = format!(
            "{{\"name\": \"{}\", \"iters\": {}, \"mean_ns\": {:.1}, \"p50_ns\": {:.1}, \
             \"p99_ns\": {:.1}, \"min_ns\": {:.1}, \"std_ns\": {:.1}",
            json_escape(&t.name),
            t.iters,
            t.mean_s * 1e9,
            t.median_s * 1e9,
            t.p99_s * 1e9,
            t.min_s * 1e9,
            t.std_s * 1e9,
        );
        // {:.3} would render inf/NaN bare, which is invalid JSON — a
        // zero-duration path (coarse timer) must not corrupt the file.
        if let Some((unit, value)) = throughput.filter(|&(_, v)| v.is_finite()) {
            obj.push_str(&format!(
                ", \"throughput\": {{\"unit\": \"{}\", \"value\": {:.3}}}",
                json_escape(unit),
                value
            ));
        }
        obj.push('}');
        self.entries.push(obj);
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn render(&self, label: &str) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"schema\": \"difflb-bench-v1\",\n");
        s.push_str(&format!("  \"label\": \"{}\",\n", json_escape(label)));
        s.push_str("  \"paths\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            s.push_str("    ");
            s.push_str(e);
            if i + 1 < self.entries.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push_str("  ]\n}\n");
        s
    }

    pub fn write(&self, path: impl AsRef<std::path::Path>, label: &str) -> std::io::Result<()> {
        std::fs::write(path, self.render(label))
    }
}

/// Aligned text table, used by bench binaries to print paper tables.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("+");
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<w$} ", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Also emit CSV for downstream plotting.
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        s.push_str(&self.headers.join(","));
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_of_trivial_fn() {
        let t = time_fn("noop", Duration::from_millis(20), || 1 + 1);
        assert!(t.iters >= 5);
        assert!(t.mean_s >= 0.0);
        assert!(t.report().contains("noop"));
    }

    #[test]
    fn json_report_renders_valid_shape() {
        let mut r = JsonReport::new();
        let t = time_fn("path \"a\"", Duration::from_millis(5), || 1 + 1);
        r.add(&t, Some(("Mops/s", 12.5)));
        r.add(&t, None);
        r.add(&t, Some(("Mops/s", f64::INFINITY))); // dropped: invalid JSON
        assert_eq!(r.len(), 3);
        let s = r.render("unit-test");
        assert!(!s.contains("inf"), "non-finite throughput leaked: {s}");
        assert!(s.contains("\"schema\": \"difflb-bench-v1\""));
        assert!(s.contains("\"label\": \"unit-test\""));
        assert!(s.contains("\"std_ns\""), "noise figure missing: {s}");
        assert!(s.contains("path \\\"a\\\""));
        assert!(s.contains("\"throughput\": {\"unit\": \"Mops/s\", \"value\": 12.500}"));
        // braces balance (cheap well-formedness check without a parser)
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }

    #[test]
    fn table_rendering_aligns() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer-name".into(), "2.5".into()]);
        let r = t.render();
        assert!(r.contains("demo"));
        assert!(r.contains("longer-name"));
        assert_eq!(t.to_csv().lines().count(), 3);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
