//! Micro/bench harness (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with mean/median/p99 reporting and
//! a tabular reporter used by every `cargo bench` target to print the
//! paper's tables. Benches are `harness = false` binaries that call
//! [`time_fn`] / [`Table`].

use std::time::{Duration, Instant};

use super::stats::percentile;

/// Timing result for one benchmark case.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
}

impl Timing {
    pub fn per_iter(&self) -> Duration {
        Duration::from_secs_f64(self.mean_s)
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>10} iters  mean {:>12}  median {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            fmt_duration(self.mean_s),
            fmt_duration(self.median_s),
            fmt_duration(self.p99_s),
        )
    }
}

pub fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Time `f` with automatic iteration count targeting ~`budget` total
/// runtime (default 2s), after `warmup` runs. Returns per-iteration
/// statistics. A `black_box`-style sink prevents the optimizer from
/// deleting the workload: have `f` return a value.
pub fn time_fn<T>(name: &str, budget: Duration, mut f: impl FnMut() -> T) -> Timing {
    // Warmup + calibration.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let one = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget.as_secs_f64() / one) as usize).clamp(5, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    Timing {
        name: name.to_string(),
        iters,
        mean_s: mean,
        median_s: percentile(&samples, 50.0),
        p99_s: percentile(&samples, 99.0),
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

/// Quick one-shot wall-clock measurement (for end-to-end runs where a
/// single execution is already seconds long).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// Aligned text table, used by bench binaries to print paper tables.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("+");
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<w$} ", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Also emit CSV for downstream plotting.
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        s.push_str(&self.headers.join(","));
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_of_trivial_fn() {
        let t = time_fn("noop", Duration::from_millis(20), || 1 + 1);
        assert!(t.iters >= 5);
        assert!(t.mean_s >= 0.0);
        assert!(t.report().contains("noop"));
    }

    #[test]
    fn table_rendering_aligns() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer-name".into(), "2.5".into()]);
        let r = t.render();
        assert!(r.contains("demo"));
        assert!(r.contains("longer-name"));
        assert_eq!(t.to_csv().lines().count(), 3);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
