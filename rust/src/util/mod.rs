//! Dependency-free substrates: everything a production framework would
//! normally pull from crates.io, built in-repo because the build
//! environment is offline (see DESIGN.md "Environment constraints").

pub mod args;
pub mod bench;
pub mod config;
pub mod io;
pub mod logging;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
