//! # difflb — Communication-Aware Diffusion Load Balancing
//!
//! Full reproduction of "Communication-Aware Diffusion Load Balancing
//! for Persistently Interacting Objects" (Taylor, Chandrasekar, Kale):
//! an over-decomposed object runtime, the three-stage diffusion
//! strategy (+ coordinate variant), the comparison baselines, a
//! distributed message-passing simulation substrate — including a
//! [`distributed`] runtime that executes the **whole** LB pipeline and
//! node-partitionable applications as per-node protocols over real
//! message channels — and a unified [`apps::App`] trait with a single
//! generic driver ([`apps::driver::run_app`]) behind every workload:
//! PIC PRK (compute hot paths as AOT-compiled JAX/Pallas kernels
//! through PJRT), noisy stencils, streamline particle advection, and a
//! drifting load hotspot. Benches regenerate every table and figure of
//! the paper. See DESIGN.md for the system map.

pub mod apps;
pub mod coordinator;
pub mod distributed;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod simnet;
pub mod strategies;
pub mod util;
pub mod viz;
