//! GreedyLB baseline: global re-assignment, heaviest object to the
//! least-loaded PE (classic Charm++ GreedyLB). Produces near-perfect
//! balance, ignores both locality and migration cost — the upper bound
//! on balance quality and the lower bound on locality.
//!
//! Speed-aware: the heap orders PEs by normalized time (`load/speed`),
//! so fast PEs absorb proportionally more objects. Uniform topologies
//! divide by exactly 1.0 — bit-identical to the homogeneous baseline.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::model::{Assignment, Instance};
use crate::strategies::LoadBalancer;

pub struct Greedy;

/// Min-heap entry over (load, pe).
#[derive(Debug, Clone, Copy)]
struct PeEntry {
    load: f64,
    pe: u32,
}
impl PartialEq for PeEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for PeEntry {}
impl PartialOrd for PeEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for PeEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want min-load first
        other.load.total_cmp(&self.load).then(other.pe.cmp(&self.pe))
    }
}

impl LoadBalancer for Greedy {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn rebalance(&self, inst: &Instance) -> Assignment {
        let mut order: Vec<u32> = (0..inst.n_objects() as u32).collect();
        order.sort_by(|&a, &b| {
            inst.loads[b as usize].total_cmp(&inst.loads[a as usize]).then(a.cmp(&b))
        });
        let mut heap: BinaryHeap<PeEntry> =
            (0..inst.topo.n_pes() as u32).map(|pe| PeEntry { load: 0.0, pe }).collect();
        let mut mapping = vec![0u32; inst.n_objects()];
        for o in order {
            let mut top = heap.pop().unwrap();
            mapping[o as usize] = top.pe;
            top.load += inst.loads[o as usize] / inst.topo.pe_speed(top.pe);
            heap.push(top);
        }
        Assignment { mapping }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{evaluate, CommGraph, Topology};

    #[test]
    fn near_perfect_balance() {
        let n = 64;
        let loads: Vec<f64> = (0..n).map(|i| 1.0 + (i % 5) as f64).collect();
        let inst = Instance::new(
            loads,
            vec![[0.0; 2]; n],
            CommGraph::empty(n),
            vec![0; n],
            Topology::flat(8),
        );
        let asg = Greedy.rebalance(&inst);
        let m = evaluate(&inst, &asg);
        assert!(m.max_avg_pe < 1.1, "max/avg {}", m.max_avg_pe);
    }

    #[test]
    fn fast_pe_absorbs_proportionally_more_work() {
        // 2 PEs at speeds [1, 3], 8 unit objects: time-LPT alternates
        // against normalized times, landing 6 on the fast PE (times
        // [2, 2]) instead of the homogeneous 4/4 split.
        let n = 8;
        let inst = Instance::new(
            vec![1.0; n],
            vec![[0.0; 2]; n],
            CommGraph::empty(n),
            vec![0; n],
            Topology::flat(2).with_pe_speeds(vec![1.0, 3.0]),
        );
        let asg = Greedy.rebalance(&inst);
        let loads = inst.pe_loads(&asg.mapping);
        assert_eq!(loads, vec![2.0, 6.0], "{loads:?}");
        let times = inst.pe_times(&asg.mapping);
        assert!((times[0] - times[1]).abs() < 1e-12, "{times:?}");
    }

    #[test]
    fn lpt_on_equal_loads_is_round_robin_balanced() {
        let inst = Instance::new(
            vec![1.0; 8],
            vec![[0.0; 2]; 8],
            CommGraph::empty(8),
            vec![0; 8],
            Topology::flat(4),
        );
        let asg = Greedy.rebalance(&inst);
        let loads = inst.pe_loads(&asg.mapping);
        assert_eq!(loads, vec![2.0; 4]);
    }
}
