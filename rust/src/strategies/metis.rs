//! METIS-like multilevel k-way graph partitioner, from scratch.
//!
//! The paper uses METIS as a from-scratch partitioning baseline
//! (Table II): best communication locality, but it re-partitions
//! without regard to the current placement, so nearly every object
//! migrates. Classic multilevel scheme (Karypis & Kumar '96):
//! heavy-edge-matching coarsening → recursive-bisection initial
//! partition via greedy region growing → projection with k-way
//! boundary (FM-style) refinement at every level.
//!
//! Heterogeneity: like real METIS's `tpwgts`, each part can carry a
//! **target fraction** of the total vertex weight. [`Metis::rebalance`]
//! sets the fractions proportional to PE speeds, so a 2x-fast PE's part
//! is grown, refined, and balance-repaired toward 2x the weight. With
//! `targets == None` (uniform topologies) every code path below is the
//! exact homogeneous original.

use std::collections::BTreeMap;

use crate::model::{Assignment, Instance};
use crate::strategies::{LoadBalancer, StrategyParams};
use crate::util::rng::Rng;

pub struct Metis {
    pub params: StrategyParams,
}

/// One level of the multilevel hierarchy (adjacency-list graph with
/// vertex weights).
#[derive(Debug, Clone)]
pub(crate) struct LevelGraph {
    pub n: usize,
    pub adj: Vec<Vec<(u32, f64)>>,
    pub vwts: Vec<f64>,
}

impl LevelGraph {
    pub fn from_instance(inst: &Instance) -> LevelGraph {
        let n = inst.n_objects();
        let mut adj = vec![Vec::new(); n];
        for (a, b, w) in inst.graph.edges() {
            adj[a as usize].push((b, w));
            adj[b as usize].push((a, w));
        }
        LevelGraph { n, adj, vwts: inst.loads.clone() }
    }

    pub fn total_vwt(&self) -> f64 {
        self.vwts.iter().sum()
    }
}

/// Heavy-edge matching: returns (coarse graph, fine→coarse map).
pub(crate) fn coarsen(g: &LevelGraph, rng: &mut Rng) -> (LevelGraph, Vec<u32>) {
    let mut order: Vec<u32> = (0..g.n as u32).collect();
    rng.shuffle(&mut order);
    let mut matched = vec![u32::MAX; g.n];
    let mut coarse_of = vec![u32::MAX; g.n];
    let mut next = 0u32;
    for &v in &order {
        let v = v as usize;
        if matched[v] != u32::MAX {
            continue;
        }
        // heaviest unmatched neighbor
        let mut best: Option<(u32, f64)> = None;
        for &(u, w) in &g.adj[v] {
            if matched[u as usize] == u32::MAX
                && best.map(|(_, bw)| w > bw).unwrap_or(true)
            {
                best = Some((u, w));
            }
        }
        match best {
            Some((u, _)) => {
                matched[v] = u;
                matched[u as usize] = v as u32;
                coarse_of[v] = next;
                coarse_of[u as usize] = next;
            }
            None => {
                matched[v] = v as u32;
                coarse_of[v] = next;
            }
        }
        next += 1;
    }
    let cn = next as usize;
    let mut vwts = vec![0.0; cn];
    for v in 0..g.n {
        vwts[coarse_of[v] as usize] += g.vwts[v];
    }
    let mut edge_map: BTreeMap<(u32, u32), f64> = BTreeMap::new();
    for v in 0..g.n {
        let cv = coarse_of[v];
        for &(u, w) in &g.adj[v] {
            let cu = coarse_of[u as usize];
            if cv < cu {
                *edge_map.entry((cv, cu)).or_insert(0.0) += w;
            }
        }
    }
    let mut adj = vec![Vec::new(); cn];
    // BTreeMap drains in key order — the sort the HashMap version
    // needed here is now the container's iteration contract.
    let pairs: Vec<((u32, u32), f64)> = edge_map.into_iter().collect();
    for ((a, b), w) in pairs {
        adj[a as usize].push((b, w));
        adj[b as usize].push((a, w));
    }
    (LevelGraph { n: cn, adj, vwts }, coarse_of)
}

/// Greedy graph-growing bisection: grow a region from a peripheral seed
/// until it holds `frac` of the total vertex weight. Returns side flags.
pub(crate) fn grow_bisection(g: &LevelGraph, frac: f64, rng: &mut Rng) -> Vec<bool> {
    let total = g.total_vwt();
    let target = total * frac;
    // pseudo-peripheral seed: BFS twice from a random start
    if g.n == 0 {
        return Vec::new();
    }
    let start = rng.range(0, g.n);
    let far = bfs_farthest(g, start);
    let seed = bfs_farthest(g, far);

    let mut in_a = vec![false; g.n];
    let mut gain: Vec<f64> = vec![0.0; g.n];
    let mut in_frontier = vec![false; g.n];
    let mut frontier: Vec<u32> = Vec::new();
    let mut wa = 0.0;

    let add = |v: usize,
                   in_a: &mut Vec<bool>,
                   wa: &mut f64,
                   frontier: &mut Vec<u32>,
                   in_frontier: &mut Vec<bool>,
                   gain: &mut Vec<f64>| {
        in_a[v] = true;
        *wa += g.vwts[v];
        for &(u, w) in &g.adj[v] {
            let u = u as usize;
            if !in_a[u] {
                gain[u] += w;
                if !in_frontier[u] {
                    in_frontier[u] = true;
                    frontier.push(u as u32);
                }
            }
        }
    };
    add(seed, &mut in_a, &mut wa, &mut frontier, &mut in_frontier, &mut gain);

    while wa < target {
        // best-gain frontier vertex; fall back to any unassigned vertex
        // (disconnected graphs).
        frontier.retain(|&u| !in_a[u as usize]);
        let pick = frontier
            .iter()
            .cloned()
            .max_by(|&a, &b| gain[a as usize].total_cmp(&gain[b as usize]).then(b.cmp(&a)))
            .map(|u| u as usize)
            .or_else(|| (0..g.n).find(|&v| !in_a[v]));
        match pick {
            Some(v) => {
                in_frontier[v] = false;
                add(v, &mut in_a, &mut wa, &mut frontier, &mut in_frontier, &mut gain)
            }
            None => break,
        }
    }
    in_a
}

fn bfs_farthest(g: &LevelGraph, start: usize) -> usize {
    let mut dist = vec![u32::MAX; g.n];
    dist[start] = 0;
    let mut queue = std::collections::VecDeque::from([start]);
    let mut last = start;
    while let Some(v) = queue.pop_front() {
        last = v;
        for &(u, _) in &g.adj[v] {
            let u = u as usize;
            if dist[u] == u32::MAX {
                dist[u] = dist[v] + 1;
                queue.push_back(u);
            }
        }
    }
    last
}

/// Recursive bisection into `k` parts (ids `part_base..part_base+k`).
/// `targets`, when given, holds every part's weight fraction (summing
/// to 1 over all parts); the split point divides weight proportionally
/// to the two halves' summed fractions instead of by part count.
fn recursive_bisect(
    g: &LevelGraph,
    vertices: &[u32],
    k: usize,
    part_base: u32,
    part: &mut [u32],
    rng: &mut Rng,
    targets: Option<&[f64]>,
) {
    if k == 1 {
        for &v in vertices {
            part[v as usize] = part_base;
        }
        return;
    }
    if vertices.len() <= k {
        // fewer vertices than parts: round-robin, some parts stay empty
        for (i, &v) in vertices.iter().enumerate() {
            part[v as usize] = part_base + i as u32;
        }
        return;
    }
    // subgraph over `vertices`
    let mut local_id = BTreeMap::new();
    for (i, &v) in vertices.iter().enumerate() {
        local_id.insert(v, i as u32);
    }
    let sub = LevelGraph {
        n: vertices.len(),
        adj: vertices
            .iter()
            .map(|&v| {
                g.adj[v as usize]
                    .iter()
                    .filter_map(|&(u, w)| local_id.get(&u).map(|&lu| (lu, w)))
                    .collect()
            })
            .collect(),
        vwts: vertices.iter().map(|&v| g.vwts[v as usize]).collect(),
    };
    let k1 = k / 2;
    let k2 = k - k1;
    let frac = match targets {
        None => k1 as f64 / k as f64,
        Some(t) => {
            let base = part_base as usize;
            let a: f64 = t[base..base + k1].iter().sum();
            let all: f64 = t[base..base + k].iter().sum();
            if all > 0.0 { a / all } else { k1 as f64 / k as f64 }
        }
    };
    if vertices.is_empty() {
        return;
    }
    let mut side = if sub.n > 1 {
        let mut s = grow_bisection(&sub, frac, rng);
        refine_bisection(&sub, &mut s, frac, 4);
        s
    } else {
        vec![true; sub.n]
    };
    let mut side_a: Vec<u32> = vertices
        .iter()
        .enumerate()
        .filter(|(i, _)| side[*i])
        .map(|(_, &v)| v)
        .collect();
    let mut side_b: Vec<u32> = vertices
        .iter()
        .enumerate()
        .filter(|(i, _)| !side[*i])
        .map(|(_, &v)| v)
        .collect();
    // degenerate bisection (e.g. all weight on one vertex): fall back to
    // a proportional count split so every part gets vertices
    if (side_a.is_empty() && k1 > 0) || (side_b.is_empty() && k2 > 0) {
        let cut = ((vertices.len() as f64 * frac).round() as usize).clamp(
            usize::from(k1 > 0),
            vertices.len() - usize::from(k2 > 0),
        );
        side_a = vertices[..cut].to_vec();
        side_b = vertices[cut..].to_vec();
        side.clear();
    }
    recursive_bisect(g, &side_a, k1, part_base, part, rng, targets);
    recursive_bisect(g, &side_b, k2, part_base + k1 as u32, part, rng, targets);
}

/// FM-style bisection refinement: greedy positive-gain boundary swaps
/// under a weight tolerance.
fn refine_bisection(g: &LevelGraph, side: &mut [bool], frac: f64, passes: usize) {
    let total = g.total_vwt();
    let target_a = total * frac;
    let tol = total * 0.03;
    let mut wa: f64 = (0..g.n).filter(|&v| side[v]).map(|v| g.vwts[v]).sum();
    for _ in 0..passes {
        let mut improved = false;
        for v in 0..g.n {
            let (mut internal, mut external) = (0.0, 0.0);
            for &(u, w) in &g.adj[v] {
                if side[u as usize] == side[v] {
                    internal += w;
                } else {
                    external += w;
                }
            }
            let gain = external - internal;
            if gain <= 0.0 {
                continue;
            }
            let new_wa = if side[v] { wa - g.vwts[v] } else { wa + g.vwts[v] };
            if (new_wa - target_a).abs() <= (wa - target_a).abs() + tol {
                side[v] = !side[v];
                wa = new_wa;
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }
}

/// K-way boundary refinement: move boundary vertices to the adjacent
/// part with max positive gain when balance allows. With `targets`,
/// each part's weight cap is proportional to its target fraction
/// (`total * t[p] * btol`) instead of the uniform `total / k * btol`.
pub(crate) fn kway_refine(
    g: &LevelGraph,
    part: &mut [u32],
    k: usize,
    btol: f64,
    passes: usize,
    targets: Option<&[f64]>,
) {
    let total = g.total_vwt();
    let uniform_max = total / k as f64 * btol;
    let max_wt = |p: usize| match targets {
        None => uniform_max,
        Some(t) => total * t[p] * btol,
    };
    let mut wts = vec![0.0; k];
    for v in 0..g.n {
        wts[part[v] as usize] += g.vwts[v];
    }
    for _ in 0..passes {
        let mut moves = 0;
        for v in 0..g.n {
            let pv = part[v];
            let mut conn: BTreeMap<u32, f64> = BTreeMap::new();
            for &(u, w) in &g.adj[v] {
                *conn.entry(part[u as usize]).or_insert(0.0) += w;
            }
            let own = conn.get(&pv).cloned().unwrap_or(0.0);
            let mut cands: Vec<(u32, f64)> =
                conn.iter().filter(|(&p, _)| p != pv).map(|(&p, &w)| (p, w)).collect();
            cands.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
            if let Some(&(p, w)) = cands.first() {
                let gain = w - own;
                if gain > 0.0 && wts[p as usize] + g.vwts[v] <= max_wt(p as usize) {
                    wts[pv as usize] -= g.vwts[v];
                    wts[p as usize] += g.vwts[v];
                    part[v] = p;
                    moves += 1;
                }
            }
        }
        if moves == 0 {
            break;
        }
    }
}

/// Balance-repair pass: while a part exceeds the tolerance, move the
/// vertex with the least cut damage from the heaviest part to the
/// lightest (real METIS enforces the balance constraint similarly
/// during refinement). With `targets`, "heaviest"/"lightest" are judged
/// relative to each part's target weight (`wts[p] / (total * t[p])`)
/// and the cap is per-part, mirroring [`kway_refine`].
pub(crate) fn rebalance_parts(
    g: &LevelGraph,
    part: &mut [u32],
    k: usize,
    btol: f64,
    targets: Option<&[f64]>,
) {
    let total = g.total_vwt();
    let avg = total / k as f64;
    let target_wt = |p: usize| match targets {
        None => avg,
        Some(t) => total * t[p],
    };
    let max_wt = |p: usize| target_wt(p) * btol;
    // relative fill of a part vs its target (plain weight when uniform)
    let fill = |wts: &[f64], p: usize| match targets {
        None => wts[p],
        Some(t) => wts[p] / (total * t[p]).max(f64::MIN_POSITIVE),
    };
    let mut wts = vec![0.0; k];
    for v in 0..g.n {
        wts[part[v] as usize] += g.vwts[v];
    }
    for _ in 0..4 * g.n {
        let hi = (0..k)
            .max_by(|&a, &b| fill(&wts, a).total_cmp(&fill(&wts, b)))
            .unwrap();
        let hi_w = wts[hi];
        if hi_w <= max_wt(hi) {
            break;
        }
        let lo = (0..k)
            .min_by(|&a, &b| fill(&wts, a).total_cmp(&fill(&wts, b)))
            .unwrap();
        // vertex on hi with minimal (cut increase, weight distance)
        let mut best: Option<(f64, usize)> = None;
        for v in 0..g.n {
            if part[v] as usize != hi || g.vwts[v] <= 0.0 {
                continue;
            }
            let mut to_lo = 0.0;
            let mut local = 0.0;
            for &(u, w) in &g.adj[v] {
                if part[u as usize] as usize == hi {
                    local += w;
                } else if part[u as usize] as usize == lo {
                    to_lo += w;
                }
            }
            let damage = local - to_lo;
            if best.map(|(d, _)| damage < d).unwrap_or(true) {
                best = Some((damage, v));
            }
        }
        let Some((_, v)) = best else { break };
        wts[hi] -= g.vwts[v];
        wts[lo] += g.vwts[v];
        part[v] = lo as u32;
    }
}

/// Full multilevel pipeline over an instance, producing a PE-level
/// partition vector. `targets` (fractions summing to 1, one per part)
/// skews every stage toward proportional part weights — `None` is the
/// homogeneous original, code path for code path.
pub(crate) fn partition(
    inst: &Instance,
    k: usize,
    btol: f64,
    seed: u64,
    targets: Option<&[f64]>,
) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    let mut levels: Vec<(LevelGraph, Vec<u32>)> = Vec::new();
    let mut g = LevelGraph::from_instance(inst);
    let coarse_target = (4 * k).max(64);
    while g.n > coarse_target {
        let (cg, map) = coarsen(&g, &mut rng);
        if cg.n as f64 > g.n as f64 * 0.95 {
            break; // no shrinkage (e.g. edgeless graph)
        }
        levels.push((g, map));
        g = cg;
    }
    // initial partition on coarsest
    let mut part = vec![0u32; g.n];
    let all: Vec<u32> = (0..g.n as u32).collect();
    recursive_bisect(&g, &all, k, 0, &mut part, &mut rng, targets);
    kway_refine(&g, &mut part, k, btol, 6, targets);
    rebalance_parts(&g, &mut part, k, btol, targets);
    // uncoarsen
    while let Some((fine, map)) = levels.pop() {
        let mut fpart = vec![0u32; fine.n];
        for v in 0..fine.n {
            fpart[v] = part[map[v] as usize];
        }
        part = fpart;
        kway_refine(&fine, &mut part, k, btol, 4, targets);
        rebalance_parts(&fine, &mut part, k, btol, targets);
    }
    part
}

/// Per-PE target fractions proportional to speed (left-to-right sums,
/// reproducible everywhere), or `None` on uniform topologies.
pub(crate) fn speed_targets(inst: &Instance) -> Option<Vec<f64>> {
    let speeds = inst.topo.pe_speeds()?;
    let total: f64 = speeds.iter().sum();
    Some(speeds.iter().map(|&s| s / total).collect())
}

impl LoadBalancer for Metis {
    fn name(&self) -> &'static str {
        "metis"
    }

    fn rebalance(&self, inst: &Instance) -> Assignment {
        let k = inst.topo.n_pes();
        let targets = speed_targets(inst);
        let mapping = partition(
            inst,
            k,
            self.params.balance_tolerance,
            self.params.seed,
            targets.as_deref(),
        );
        Assignment { mapping }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{evaluate, metrics, CommGraph, Topology};
    use crate::strategies::tests::small_instance;

    fn grid_instance(side: usize, pes: usize) -> Instance {
        let n = side * side;
        let mut edges = Vec::new();
        for r in 0..side {
            for c in 0..side {
                let o = (r * side + c) as u32;
                if c + 1 < side {
                    edges.push((o, o + 1, 10.0));
                }
                if r + 1 < side {
                    edges.push((o, o + side as u32, 10.0));
                }
            }
        }
        Instance::new(
            vec![1.0; n],
            (0..n).map(|i| [(i % side) as f64, (i / side) as f64]).collect(),
            CommGraph::from_edges(n, &edges),
            vec![0; n],
            Topology::flat(pes),
        )
    }

    #[test]
    fn partitions_are_total_and_balanced() {
        let inst = grid_instance(16, 8);
        let m = Metis { params: StrategyParams::default() };
        let asg = m.rebalance(&inst);
        let loads = inst.pe_loads(&asg.mapping);
        assert!(loads.iter().all(|&l| l > 0.0), "empty part: {loads:?}");
        let metrics = evaluate(&inst, &asg);
        assert!(metrics.max_avg_pe < 1.35, "max/avg {}", metrics.max_avg_pe);
    }

    #[test]
    fn locality_beats_scatter() {
        let inst = grid_instance(16, 4);
        let m = Metis { params: StrategyParams::default() }.rebalance(&inst);
        let s = crate::strategies::random::Scatter { seed: 2 }.rebalance(&inst);
        let rm = metrics::comm_split_pes(&inst, &m.mapping).ratio();
        let rs = metrics::comm_split_pes(&inst, &s.mapping).ratio();
        assert!(rm < rs * 0.5, "metis {rm} vs scatter {rs}");
    }

    #[test]
    fn kway_refine_reduces_cut() {
        let inst = grid_instance(12, 4);
        let g = LevelGraph::from_instance(&inst);
        // bad initial partition: random assignment
        let mut rng = Rng::new(17);
        let mut part: Vec<u32> = (0..g.n as u32).map(|_| rng.below(4) as u32).collect();
        let cut_before = cut(&g, &part);
        kway_refine(&g, &mut part, 4, 1.05, 8, None);
        let cut_after = cut(&g, &part);
        assert!(cut_after < cut_before, "{cut_after} !< {cut_before}");
    }

    fn cut(g: &LevelGraph, part: &[u32]) -> f64 {
        let mut c = 0.0;
        for v in 0..g.n {
            for &(u, w) in &g.adj[v] {
                if part[v] != part[u as usize] {
                    c += w;
                }
            }
        }
        c / 2.0
    }

    #[test]
    fn coarsening_preserves_total_weight() {
        let inst = small_instance(4);
        let g = LevelGraph::from_instance(&inst);
        let (cg, map) = coarsen(&g, &mut Rng::new(3));
        assert!(cg.n < g.n);
        assert!((cg.total_vwt() - g.total_vwt()).abs() < 1e-9);
        assert!(map.iter().all(|&c| (c as usize) < cg.n));
    }

    #[test]
    fn speed_targets_skew_part_weights() {
        // 4 PEs, one 3x faster: its part should end up clearly heavier
        // than the slowest parts (speed fractions are [1/6, 1/6, 1/6,
        // 1/2] over 256 unit-load vertices).
        let mut inst = grid_instance(16, 4);
        inst.topo = Topology::flat(4).with_pe_speeds(vec![1.0, 1.0, 1.0, 3.0]);
        let asg = Metis { params: StrategyParams::default() }.rebalance(&inst);
        let loads = inst.pe_loads(&asg.mapping);
        let fast = loads[3];
        let slow_max = loads[..3].iter().cloned().fold(0.0, f64::max);
        assert!(
            fast > slow_max * 1.5,
            "fast part {fast} not heavier than slow parts {loads:?}"
        );
        // and the time split is tighter than the raw-work split
        let times = inst.pe_times(&asg.mapping);
        let ratio = |v: &[f64]| {
            let avg = v.iter().sum::<f64>() / v.len() as f64;
            v.iter().cloned().fold(0.0, f64::max) / avg
        };
        assert!(ratio(&times) < ratio(&loads), "times {times:?} loads {loads:?}");
    }

    #[test]
    fn handles_edgeless_graph() {
        let n = 32;
        let inst = Instance::new(
            vec![1.0; n],
            vec![[0.0; 2]; n],
            CommGraph::empty(n),
            vec![0; n],
            Topology::flat(4),
        );
        let asg = Metis { params: StrategyParams::default() }.rebalance(&inst);
        let loads = inst.pe_loads(&asg.mapping);
        // all parts get some objects even with no edges
        assert!(loads.iter().filter(|&&l| l > 0.0).count() >= 3, "{loads:?}");
    }
}
