//! GreedyRefine baseline (Charm++'s GreedyRefineLB): keep objects where
//! they are unless their PE is overloaded; shed load from overloaded
//! PEs into a pool, then place the pool greedily onto the least-loaded
//! PEs. Produces the best max/avg of the compared strategies at the
//! price of locality — exactly the Table II / Fig 5-6 profile.
//!
//! Speed-aware: overload is judged — and the pool placed — in
//! normalized time (`load/speed`), so a "fast" PE is only overloaded
//! when its *time* exceeds the average time. Uniform topologies divide
//! by exactly 1.0, keeping the homogeneous decisions bit-identical.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::model::{Assignment, Instance};
use crate::strategies::{LoadBalancer, StrategyParams};

pub struct GreedyRefine {
    pub params: StrategyParams,
}

#[derive(Debug, Clone, Copy)]
struct MinPe {
    load: f64,
    pe: u32,
}
impl PartialEq for MinPe {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for MinPe {}
impl PartialOrd for MinPe {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for MinPe {
    fn cmp(&self, other: &Self) -> Ordering {
        other.load.total_cmp(&self.load).then(other.pe.cmp(&self.pe))
    }
}

impl LoadBalancer for GreedyRefine {
    fn name(&self) -> &'static str {
        "greedy-refine"
    }

    fn rebalance(&self, inst: &Instance) -> Assignment {
        let n_pes = inst.topo.n_pes();
        let mut mapping = inst.mapping.clone();
        // Normalized time per PE (division by exactly 1.0 on uniform
        // topologies — a bitwise no-op).
        let spd = |pe: usize| inst.topo.pe_speed(pe as u32);
        let mut pe_loads = inst.pe_loads(&mapping);
        for (pe, l) in pe_loads.iter_mut().enumerate() {
            *l /= spd(pe);
        }
        let avg: f64 = pe_loads.iter().sum::<f64>() / n_pes as f64;
        let threshold = avg * (1.0 + self.params.refine_tolerance);

        // Objects per PE, heaviest last (so pop() sheds heaviest first).
        let mut per_pe: Vec<Vec<u32>> = vec![Vec::new(); n_pes];
        for (o, &pe) in mapping.iter().enumerate() {
            per_pe[pe as usize].push(o as u32);
        }
        for objs in &mut per_pe {
            objs.sort_by(|&a, &b| {
                inst.loads[a as usize].total_cmp(&inst.loads[b as usize]).then(a.cmp(&b))
            });
        }

        // Shed from overloaded PEs: heaviest object that doesn't push the
        // PE below average; otherwise the lightest that gets it under.
        let mut pool: Vec<u32> = Vec::new();
        for pe in 0..n_pes {
            while pe_loads[pe] > threshold {
                // find heaviest object whose time <= pe_time - avg
                let headroom = pe_loads[pe] - avg;
                let pos = per_pe[pe]
                    .iter()
                    .rposition(|&o| inst.loads[o as usize] / spd(pe) <= headroom);
                let idx = match pos {
                    Some(i) => i,
                    // nothing fits exactly: shed the lightest object
                    None if !per_pe[pe].is_empty() => 0,
                    None => break,
                };
                let o = per_pe[pe].remove(idx);
                pe_loads[pe] -= inst.loads[o as usize] / spd(pe);
                pool.push(o);
            }
        }

        // Place the pool: heaviest first onto the least-loaded PE.
        pool.sort_by(|&a, &b| {
            inst.loads[b as usize].total_cmp(&inst.loads[a as usize]).then(a.cmp(&b))
        });
        let mut heap: BinaryHeap<MinPe> = pe_loads
            .iter()
            .enumerate()
            .map(|(pe, &load)| MinPe { load, pe: pe as u32 })
            .collect();
        for o in pool {
            let mut top = heap.pop().unwrap();
            mapping[o as usize] = top.pe;
            top.load += inst.loads[o as usize] / spd(top.pe as usize);
            heap.push(top);
        }
        Assignment { mapping }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{evaluate, CommGraph, Topology};

    fn imbalanced_instance() -> Instance {
        // PE0 heavily overloaded, PEs 1-3 light.
        let n = 32;
        let loads = vec![1.0; n];
        let mapping: Vec<u32> = (0..n).map(|i| if i < 20 { 0 } else { 1 + (i % 3) as u32 }).collect();
        Instance::new(
            loads,
            vec![[0.0; 2]; n],
            CommGraph::empty(n),
            mapping,
            Topology::flat(4),
        )
    }

    #[test]
    fn balances_overload() {
        let inst = imbalanced_instance();
        let lb = GreedyRefine { params: StrategyParams::default() };
        let m = evaluate(&inst, &lb.rebalance(&inst));
        assert!(m.max_avg_pe <= 1.05, "max/avg {}", m.max_avg_pe);
    }

    #[test]
    fn balanced_input_untouched() {
        let n = 16;
        let mapping: Vec<u32> = (0..n as u32).map(|i| i % 4).collect();
        let inst = Instance::new(
            vec![1.0; n],
            vec![[0.0; 2]; n],
            CommGraph::empty(n),
            mapping.clone(),
            Topology::flat(4),
        );
        let lb = GreedyRefine { params: StrategyParams::default() };
        let asg = lb.rebalance(&inst);
        assert_eq!(asg.migrations(&inst), 0);
    }

    #[test]
    fn slow_pe_counts_as_overloaded_in_time() {
        // Equal raw work per PE, but PE 0 runs at half speed: its time
        // is 2x the others', so refine must shed from it even though
        // raw loads are perfectly balanced.
        let n = 16;
        let mapping: Vec<u32> = (0..n as u32).map(|i| i % 4).collect();
        let inst = Instance::new(
            vec![1.0; n],
            vec![[0.0; 2]; n],
            CommGraph::empty(n),
            mapping,
            Topology::flat(4).with_pe_speeds(vec![0.5, 1.0, 1.0, 1.0]),
        );
        let lb = GreedyRefine { params: StrategyParams::default() };
        let asg = lb.rebalance(&inst);
        assert!(asg.migrations(&inst) > 0, "time-overloaded PE not refined");
        let before = inst.pe_times(&inst.mapping);
        let after = inst.pe_times(&asg.mapping);
        let max = |v: &[f64]| v.iter().cloned().fold(0.0, f64::max);
        assert!(max(&after) < max(&before), "{before:?} -> {after:?}");
    }

    #[test]
    fn migrates_less_than_greedy() {
        let inst = imbalanced_instance();
        let refine = GreedyRefine { params: StrategyParams::default() }.rebalance(&inst);
        let greedy = crate::strategies::greedy::Greedy.rebalance(&inst);
        assert!(refine.migrations(&inst) <= greedy.migrations(&inst));
    }
}
