//! Load-balancing strategies.
//!
//! All strategies implement [`LoadBalancer`]: a pure function from an
//! [`Instance`] to an [`Assignment`], so they are directly comparable in
//! the simulation harness (paper §V) and pluggable into the app driver
//! (paper §VI). The paper's contribution is [`diffusion`]; the rest are
//! the comparison baselines of Table II.

pub mod diffusion;
pub mod greedy;
pub mod greedy_refine;
pub mod metis;
pub mod parmetis;
pub mod random;

use anyhow::{bail, Result};

use crate::model::{Assignment, Instance};
use crate::util::config::Config;

/// Single source of truth for the strategy tunables: each row declares
/// the field, its type, its default, the config key it reads from (all
/// under section `lb`), and which typed [`Config`] getter resolves it.
/// The macro expands the struct, `Default`, **and**
/// [`StrategyParams::from_config`] from the same list — adding a
/// tunable here cannot silently miss the config path (the hand-copied
/// `params_from_config` this replaces once could).
macro_rules! strategy_params {
    ($($(#[$meta:meta])* $field:ident : $ty:ty = $default:expr, $key:literal via $getter:ident;)+) => {
        /// Tunables shared across strategies; every field has a
        /// sensible default so configs/CLIs only set what they study.
        /// Declared through the `strategy_params!` macro so the struct,
        /// its defaults, and [`StrategyParams::from_config`] stay in
        /// lockstep.
        #[derive(Debug, Clone, Copy)]
        pub struct StrategyParams {
            $($(#[$meta])* pub $field: $ty,)+
        }

        impl Default for StrategyParams {
            fn default() -> Self {
                StrategyParams { $($field: $default,)+ }
            }
        }

        impl StrategyParams {
            /// Resolve every tunable from a config (section `lb`),
            /// falling back to the declared defaults.
            pub fn from_config(cfg: &Config) -> StrategyParams {
                let d = StrategyParams::default();
                StrategyParams { $($field: cfg.$getter($key, d.$field),)+ }
            }

            /// The config keys the tunables read — one per field, for
            /// docs and tests.
            pub const CONFIG_KEYS: &[&str] = &[$($key,)+];
        }
    };
}

strategy_params! {
    /// Desired neighbor-graph vertex degree K (paper §III-A).
    neighbor_count: usize = 4, "lb.neighbors" via get_or;
    /// Handshake round bound (paper §III-A step 5).
    handshake_max_rounds: usize = 32, "lb.handshake_rounds" via get_or;
    /// Virtual-LB neighborhood convergence threshold: relative load
    /// deviation within a neighborhood considered "balanced" (§III-B).
    vlb_tolerance: f64 = 0.05, "lb.vlb_tolerance" via get_or;
    /// Virtual-LB iteration bound.
    vlb_max_iters: usize = 200, "lb.vlb_max_iters" via get_or;
    /// Object selection may exceed a quota by up to this fraction of the
    /// candidate object's load (§III-C "more objects than initially...").
    overfill: f64 = 0.5, "lb.overfill" via get_or;
    /// GreedyRefine overload tolerance above average.
    refine_tolerance: f64 = 0.02, "lb.refine_tolerance" via get_or;
    /// METIS partition imbalance allowance (1.0 = perfect).
    balance_tolerance: f64 = 1.03, "lb.balance_tolerance" via get_or;
    /// ParMETIS-style migration-vs-edge-cut tradeoff (higher = more
    /// willing to migrate; mirrors ParMETIS `itr`).
    itr: f64 = 1000.0, "lb.itr" via get_or;
    /// Coordinate variant: when > 0, use the Morton-curve (SFC)
    /// neighbor search with this window instead of the quadratic
    /// all-pairs sort (paper §VII future work).
    sfc_window: usize = 0, "lb.sfc_window" via get_or;
    /// Reuse the stage-1 neighbor graph across LB rounds instead of
    /// reconstructing it every time (paper §III-A future work).
    reuse_neighbors: bool = false, "lb.reuse_neighbors" via get_bool_or;
    /// Seed for any randomized tie-breaking (coarsening visit order...).
    seed: u64 = 0xD1FF, "lb.seed" via get_or;
}

/// A dynamic load-balancing strategy.
pub trait LoadBalancer: Send + Sync {
    fn name(&self) -> &'static str;
    /// Compute a new object → PE mapping for the instance.
    fn rebalance(&self, inst: &Instance) -> Assignment;
}

/// Names accepted by [`make`] (and the CLI / config system). The
/// `dist-` variants run the diffusion pipeline as real message-passing
/// protocols over `simnet` (see `crate::distributed`) and produce
/// bit-identical assignments to their sequential counterparts.
pub const AVAILABLE: &[&str] = &[
    "none",
    "diff-comm",
    "diff-coord",
    "dist-diff-comm",
    "dist-diff-coord",
    "greedy",
    "greedy-refine",
    "metis",
    "parmetis",
    "scatter",
];

/// No-op strategy (baseline "no load balancing").
pub struct NoLb;

impl LoadBalancer for NoLb {
    fn name(&self) -> &'static str {
        "none"
    }

    fn rebalance(&self, inst: &Instance) -> Assignment {
        Assignment::unchanged(inst)
    }
}

/// Construct a strategy by name.
pub fn make(name: &str, params: StrategyParams) -> Result<Box<dyn LoadBalancer>> {
    Ok(match name {
        "none" => Box::new(NoLb),
        "diff-comm" => Box::new(diffusion::Diffusion::communication(params)),
        "diff-coord" => Box::new(diffusion::Diffusion::coordinate(params)),
        "dist-diff-comm" => Box::new(crate::distributed::DistDiffusion::communication(params)),
        "dist-diff-coord" => Box::new(crate::distributed::DistDiffusion::coordinate(params)),
        "greedy" => Box::new(greedy::Greedy),
        "greedy-refine" => Box::new(greedy_refine::GreedyRefine { params }),
        "metis" => Box::new(metis::Metis { params }),
        "parmetis" => Box::new(parmetis::ParMetis { params }),
        "scatter" => Box::new(random::Scatter { seed: params.seed }),
        other => bail!("unknown strategy '{other}' (available: {AVAILABLE:?})"),
    })
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::model::{CommGraph, Topology};

    pub(crate) fn small_instance(n_pes: usize) -> Instance {
        // 16 objects in a 4x4 grid with 5-point stencil edges, loads
        // varied, initially packed on PE 0.
        let side = 4;
        let mut edges = Vec::new();
        for r in 0..side {
            for c in 0..side {
                let o = (r * side + c) as u32;
                let right = (r * side + (c + 1) % side) as u32;
                let down = (((r + 1) % side) * side + c) as u32;
                edges.push((o, right, 100.0));
                edges.push((o, down, 100.0));
            }
        }
        let graph = CommGraph::from_edges(side * side, &edges);
        let loads: Vec<f64> = (0..side * side).map(|i| 1.0 + (i % 3) as f64).collect();
        let coords: Vec<[f64; 2]> =
            (0..side * side).map(|i| [(i % side) as f64, (i / side) as f64]).collect();
        let mapping = vec![0u32; side * side];
        Instance::new(loads, coords, graph, mapping, Topology::flat(n_pes))
    }

    #[test]
    fn registry_builds_every_strategy() {
        for name in AVAILABLE {
            let s = make(name, StrategyParams::default()).unwrap();
            assert_eq!(&s.name(), name);
        }
        assert!(make("bogus", StrategyParams::default()).is_err());
    }

    #[test]
    fn every_strategy_produces_valid_mapping() {
        let inst = small_instance(4);
        for name in AVAILABLE {
            let s = make(name, StrategyParams::default()).unwrap();
            let asg = s.rebalance(&inst);
            assert_eq!(asg.mapping.len(), inst.n_objects(), "{name}");
            assert!(
                asg.mapping.iter().all(|&pe| (pe as usize) < inst.topo.n_pes()),
                "{name} produced out-of-range PE"
            );
        }
    }

    #[test]
    fn nolb_never_migrates() {
        let inst = small_instance(4);
        let asg = NoLb.rebalance(&inst);
        assert_eq!(asg.migrations(&inst), 0);
    }

    #[test]
    fn params_from_config_reads_every_declared_key() {
        // Set every declared key to a distinguishable value and check
        // from_config leaves none unread — the macro guarantees the
        // struct and the config path can't drift apart.
        let mut cfg = Config::new();
        for &key in StrategyParams::CONFIG_KEYS {
            let v = if key == "lb.reuse_neighbors" { "true" } else { "7" };
            cfg.set(key, v);
        }
        let p = StrategyParams::from_config(&cfg);
        assert!(cfg.unread_keys().is_empty(), "unread: {:?}", cfg.unread_keys());
        assert_eq!(p.neighbor_count, 7);
        assert_eq!(p.vlb_max_iters, 7);
        assert!(p.reuse_neighbors);
        assert_eq!(p.seed, 7);
        // defaults survive an empty config
        let d = StrategyParams::from_config(&Config::new());
        assert_eq!(d.neighbor_count, StrategyParams::default().neighbor_count);
    }
}
