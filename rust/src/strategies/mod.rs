//! Load-balancing strategies.
//!
//! All strategies implement [`LoadBalancer`]: a pure function from an
//! [`Instance`] to an [`Assignment`], so they are directly comparable in
//! the simulation harness (paper §V) and pluggable into the app driver
//! (paper §VI). The paper's contribution is [`diffusion`]; the rest are
//! the comparison baselines of Table II.

pub mod diffusion;
pub mod greedy;
pub mod greedy_refine;
pub mod metis;
pub mod parmetis;
pub mod random;

use anyhow::{bail, Result};

use crate::model::{Assignment, Instance};

/// Tunables shared across strategies; every field has a sensible
/// default so configs/CLIs only set what they study.
#[derive(Debug, Clone, Copy)]
pub struct StrategyParams {
    /// Desired neighbor-graph vertex degree K (paper §III-A).
    pub neighbor_count: usize,
    /// Handshake round bound (paper §III-A step 5).
    pub handshake_max_rounds: usize,
    /// Virtual-LB neighborhood convergence threshold: relative load
    /// deviation within a neighborhood considered "balanced" (§III-B).
    pub vlb_tolerance: f64,
    /// Virtual-LB iteration bound.
    pub vlb_max_iters: usize,
    /// Object selection may exceed a quota by up to this fraction of the
    /// candidate object's load (§III-C "more objects than initially...").
    pub overfill: f64,
    /// GreedyRefine overload tolerance above average.
    pub refine_tolerance: f64,
    /// METIS partition imbalance allowance (1.0 = perfect).
    pub balance_tolerance: f64,
    /// ParMETIS-style migration-vs-edge-cut tradeoff (higher = more
    /// willing to migrate; mirrors ParMETIS `itr`).
    pub itr: f64,
    /// Coordinate variant: when > 0, use the Morton-curve (SFC)
    /// neighbor search with this window instead of the quadratic
    /// all-pairs sort (paper §VII future work).
    pub sfc_window: usize,
    /// Reuse the stage-1 neighbor graph across LB rounds instead of
    /// reconstructing it every time (paper §III-A future work).
    pub reuse_neighbors: bool,
    /// Seed for any randomized tie-breaking (coarsening visit order...).
    pub seed: u64,
}

impl Default for StrategyParams {
    fn default() -> Self {
        StrategyParams {
            neighbor_count: 4,
            handshake_max_rounds: 32,
            vlb_tolerance: 0.05,
            vlb_max_iters: 200,
            overfill: 0.5,
            refine_tolerance: 0.02,
            balance_tolerance: 1.03,
            itr: 1000.0,
            sfc_window: 0,
            reuse_neighbors: false,
            seed: 0xD1FF,
        }
    }
}

/// A dynamic load-balancing strategy.
pub trait LoadBalancer: Send + Sync {
    fn name(&self) -> &'static str;
    /// Compute a new object → PE mapping for the instance.
    fn rebalance(&self, inst: &Instance) -> Assignment;
}

/// Names accepted by [`make`] (and the CLI / config system). The
/// `dist-` variants run the diffusion pipeline as real message-passing
/// protocols over `simnet` (see `crate::distributed`) and produce
/// bit-identical assignments to their sequential counterparts.
pub const AVAILABLE: &[&str] = &[
    "none",
    "diff-comm",
    "diff-coord",
    "dist-diff-comm",
    "dist-diff-coord",
    "greedy",
    "greedy-refine",
    "metis",
    "parmetis",
    "scatter",
];

/// No-op strategy (baseline "no load balancing").
pub struct NoLb;

impl LoadBalancer for NoLb {
    fn name(&self) -> &'static str {
        "none"
    }

    fn rebalance(&self, inst: &Instance) -> Assignment {
        Assignment::unchanged(inst)
    }
}

/// Construct a strategy by name.
pub fn make(name: &str, params: StrategyParams) -> Result<Box<dyn LoadBalancer>> {
    Ok(match name {
        "none" => Box::new(NoLb),
        "diff-comm" => Box::new(diffusion::Diffusion::communication(params)),
        "diff-coord" => Box::new(diffusion::Diffusion::coordinate(params)),
        "dist-diff-comm" => Box::new(crate::distributed::DistDiffusion::communication(params)),
        "dist-diff-coord" => Box::new(crate::distributed::DistDiffusion::coordinate(params)),
        "greedy" => Box::new(greedy::Greedy),
        "greedy-refine" => Box::new(greedy_refine::GreedyRefine { params }),
        "metis" => Box::new(metis::Metis { params }),
        "parmetis" => Box::new(parmetis::ParMetis { params }),
        "scatter" => Box::new(random::Scatter { seed: params.seed }),
        other => bail!("unknown strategy '{other}' (available: {AVAILABLE:?})"),
    })
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::model::{CommGraph, Topology};

    pub(crate) fn small_instance(n_pes: usize) -> Instance {
        // 16 objects in a 4x4 grid with 5-point stencil edges, loads
        // varied, initially packed on PE 0.
        let side = 4;
        let mut edges = Vec::new();
        for r in 0..side {
            for c in 0..side {
                let o = (r * side + c) as u32;
                let right = (r * side + (c + 1) % side) as u32;
                let down = (((r + 1) % side) * side + c) as u32;
                edges.push((o, right, 100.0));
                edges.push((o, down, 100.0));
            }
        }
        let graph = CommGraph::from_edges(side * side, &edges);
        let loads: Vec<f64> = (0..side * side).map(|i| 1.0 + (i % 3) as f64).collect();
        let coords: Vec<[f64; 2]> =
            (0..side * side).map(|i| [(i % side) as f64, (i / side) as f64]).collect();
        let mapping = vec![0u32; side * side];
        Instance::new(loads, coords, graph, mapping, Topology::flat(n_pes))
    }

    #[test]
    fn registry_builds_every_strategy() {
        for name in AVAILABLE {
            let s = make(name, StrategyParams::default()).unwrap();
            assert_eq!(&s.name(), name);
        }
        assert!(make("bogus", StrategyParams::default()).is_err());
    }

    #[test]
    fn every_strategy_produces_valid_mapping() {
        let inst = small_instance(4);
        for name in AVAILABLE {
            let s = make(name, StrategyParams::default()).unwrap();
            let asg = s.rebalance(&inst);
            assert_eq!(asg.mapping.len(), inst.n_objects(), "{name}");
            assert!(
                asg.mapping.iter().all(|&pe| (pe as usize) < inst.topo.n_pes()),
                "{name} produced out-of-range PE"
            );
        }
    }

    #[test]
    fn nolb_never_migrates() {
        let inst = small_instance(4);
        let asg = NoLb.rebalance(&inst);
        assert_eq!(asg.migrations(&inst), 0);
    }
}
