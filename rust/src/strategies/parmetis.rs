//! ParMETIS-like adaptive repartitioner.
//!
//! Reproduces the baseline's qualitative behaviour (Table II): starts
//! from the *current* partition, diffuses load excess across the part
//! quotient graph (multi-hop, unlike the paper's diffusion), then picks
//! boundary objects to realize the flows, trading edge-cut gain against
//! migration volume via the `itr` knob (mirroring ParMETIS's
//! itr parameter: high `itr` = redistribution is cheap = migrate more
//! freely; low `itr` = hold objects back unless the cut gain is large).
//! As the paper notes (§V-C), tuning it is finicky — that comes through
//! here too. On heterogeneous topologies the quotient-graph diffusion
//! runs over normalized part times (`load/speed`) and moves are
//! charged by the time they free at their source PE.

use std::collections::BTreeMap;

use crate::model::{Assignment, Instance};
use crate::strategies::{LoadBalancer, StrategyParams};

pub struct ParMetis {
    pub params: StrategyParams,
}

/// Unconstrained (multi-hop) diffusion of part loads toward the mean on
/// the quotient graph (sparse rows of `(peer, bytes)`, sorted by peer —
/// the [`crate::model::GroupTraffic`] row layout, which also makes the
/// sweep order, and hence the f64 flow sums, deterministic where the
/// old HashMap rows were not); returns per-ordered-pair flows.
fn diffuse_flows(
    part_loads: &[f64],
    quotient: &[Vec<(u32, f64)>],
    tol: f64,
    max_iters: usize,
) -> Vec<BTreeMap<u32, f64>> {
    let k = part_loads.len();
    let mut cur = part_loads.to_vec();
    let avg = cur.iter().sum::<f64>() / k as f64;
    let mut flows: Vec<BTreeMap<u32, f64>> = vec![BTreeMap::new(); k];
    let deg_max = quotient.iter().map(|q| q.len()).max().unwrap_or(1).max(1);
    let alpha = 1.0 / (deg_max as f64 + 1.0);
    for _ in 0..max_iters {
        let snapshot = cur.clone();
        let mut moved = 0.0;
        for i in 0..k {
            for &(j, _) in &quotient[i] {
                let j = j as usize;
                let diff = snapshot[i] - snapshot[j];
                if diff > 0.0 {
                    let amt = alpha * diff;
                    cur[i] -= amt;
                    cur[j] += amt;
                    *flows[i].entry(j as u32).or_insert(0.0) += amt;
                    moved += amt;
                }
            }
        }
        let max = cur.iter().cloned().fold(0.0, f64::max);
        if max / avg <= 1.0 + tol || moved < avg * 1e-6 {
            break;
        }
    }
    // net out opposing flows
    for i in 0..k {
        let peers: Vec<u32> = flows[i].keys().cloned().collect();
        for j in peers {
            if (j as usize) <= i {
                continue;
            }
            let fij = flows[i].get(&j).cloned().unwrap_or(0.0);
            let fji = flows[j as usize].get(&(i as u32)).cloned().unwrap_or(0.0);
            let net = fij - fji;
            if net >= 0.0 {
                flows[i].insert(j, net);
                flows[j as usize].remove(&(i as u32));
            } else {
                flows[j as usize].insert(i as u32, -net);
                flows[i].remove(&j);
            }
        }
    }
    flows
}

impl LoadBalancer for ParMetis {
    fn name(&self) -> &'static str {
        "parmetis"
    }

    fn rebalance(&self, inst: &Instance) -> Assignment {
        let k = inst.topo.n_pes();
        let mut mapping = inst.mapping.clone();
        // Speed-aware: diffuse normalized part *times* and charge each
        // realized move by the time it frees at its source PE. Uniform
        // topologies skip the normalization entirely (legacy bit path).
        let uniform = inst.topo.is_uniform();
        let mut part_loads = inst.pe_loads(&mapping);
        if !uniform {
            for (pe, l) in part_loads.iter_mut().enumerate() {
                *l /= inst.topo.pe_speed(pe as u32);
            }
        }
        // Quotient graph over parts (CSR rows, diagonal dropped).
        // Parts with no traffic get a ring edge so load can still
        // circulate.
        let gt = inst.graph.group_traffic(&mapping, k);
        let mut quotient: Vec<Vec<(u32, f64)>> = (0..k)
            .map(|i| gt.iter_row(i).filter(|&(j, _)| j as usize != i).collect())
            .collect();
        for i in 0..k {
            if quotient[i].is_empty() && k > 1 {
                let j = ((i + 1) % k) as u32;
                quotient[i].push((j, 0.0));
                if !quotient[j as usize].iter().any(|&(p, _)| p as usize == i) {
                    quotient[j as usize].push((i as u32, 0.0));
                }
            }
        }
        let flows = diffuse_flows(&part_loads, &quotient, 0.02, 200);

        // Realize flows: per source part, per target (desc amount),
        // choose objects maximizing cut gain minus migration penalty.
        let itr = self.params.itr.max(1e-6);
        let avg_size = inst.sizes.iter().sum::<f64>() / inst.n_objects().max(1) as f64;
        // normalize cut-gain scores by the average per-object traffic so
        // the itr cutoff is dimensionless (workload independent)
        let avg_obj_bytes = (2.0 * inst.graph.total_bytes() / inst.n_objects().max(1) as f64)
            .max(f64::MIN_POSITIVE);
        let mut moved = vec![false; inst.n_objects()];
        for i in 0..k {
            let mut targets: Vec<(u32, f64)> = flows[i].iter().map(|(&j, &a)| (j, a)).collect();
            targets.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
            for (j, quota) in targets {
                if quota <= 0.0 {
                    continue;
                }
                let mut remaining = quota;
                // candidates on part i scored by cut gain − migration penalty
                let mut cands: Vec<(f64, u32)> = (0..inst.n_objects() as u32)
                    .filter(|&o| mapping[o as usize] == i as u32 && !moved[o as usize])
                    .map(|o| {
                        let mut to_j = 0.0;
                        let mut local = 0.0;
                        for (&p, &w) in inst
                            .graph
                            .neighbors(o as usize)
                            .iter()
                            .zip(inst.graph.weights(o as usize))
                        {
                            let pp = mapping[p as usize];
                            if pp == j {
                                to_j += w;
                            } else if pp == i as u32 {
                                local += w;
                            }
                        }
                        // dimensionless cut gain minus migration penalty;
                        // the penalty shrinks as itr grows
                        let penalty = inst.sizes[o as usize] / avg_size / itr;
                        ((to_j - local) / avg_obj_bytes - penalty, o)
                    })
                    .collect();
                cands.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
                for (score, o) in cands {
                    if remaining <= 0.0 {
                        break;
                    }
                    // low itr: only near-cut-neutral moves pass; high itr:
                    // balance wins and even cut-worsening moves go through
                    if score < -itr {
                        break;
                    }
                    let load = if uniform {
                        inst.loads[o as usize]
                    } else {
                        inst.loads[o as usize] / inst.topo.pe_speed(i as u32)
                    };
                    if load * 0.5 > remaining {
                        continue;
                    }
                    mapping[o as usize] = j;
                    moved[o as usize] = true;
                    remaining -= load;
                }
            }
        }
        Assignment { mapping }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{evaluate, CommGraph, Topology};
    use crate::strategies::diffusion::tests::stencil_instance;

    #[test]
    fn diffuse_flows_conserve() {
        let loads = vec![10.0, 1.0, 1.0, 1.0];
        let mut quotient: Vec<Vec<(u32, f64)>> = vec![Vec::new(); 4];
        for i in 0..4u32 {
            quotient[i as usize].push(((i + 1) % 4, 1.0));
            quotient[i as usize].push(((i + 3) % 4, 1.0));
        }
        let flows = diffuse_flows(&loads, &quotient, 0.02, 500);
        let mut after = loads.clone();
        for (i, f) in flows.iter().enumerate() {
            for (&j, &a) in f {
                after[i] -= a;
                after[j as usize] += a;
            }
        }
        assert!((after.iter().sum::<f64>() - 13.0).abs() < 1e-9);
        let avg = 13.0 / 4.0;
        let max = after.iter().cloned().fold(0.0, f64::max);
        assert!(max / avg < 1.2, "max/avg {}", max / avg);
    }

    #[test]
    fn improves_balance_with_modest_migrations() {
        let mut inst = stencil_instance(24, 4, 4, 0.0, 1);
        // overload mod-7 pattern like Table II
        for (o, l) in inst.loads.iter_mut().enumerate() {
            let pe = inst.mapping[o] % 7;
            if pe == 1 || pe == 2 {
                *l *= 3.0;
            } else if pe == 3 {
                *l *= 0.3;
            }
        }
        let before = evaluate(&inst, &Assignment::unchanged(&inst));
        let lb = ParMetis { params: StrategyParams::default() };
        let after = evaluate(&inst, &lb.rebalance(&inst));
        assert!(after.max_avg_pe < before.max_avg_pe);
        assert!(after.migration_pct < 60.0, "{}", after.migration_pct);
    }

    #[test]
    fn itr_controls_migration_volume() {
        let mut inst = stencil_instance(24, 4, 4, 0.0, 2);
        for (o, l) in inst.loads.iter_mut().enumerate() {
            if inst.mapping[o] == 0 {
                *l *= 5.0;
            }
        }
        let mut lo = StrategyParams::default();
        lo.itr = 0.05;
        let mut hi = StrategyParams::default();
        hi.itr = 10_000.0;
        let m_lo = ParMetis { params: lo }.rebalance(&inst).migrations(&inst);
        let m_hi = ParMetis { params: hi }.rebalance(&inst).migrations(&inst);
        assert!(m_lo <= m_hi, "itr low {m_lo} > high {m_hi}");
    }

    #[test]
    fn heterogeneous_speeds_shift_time_not_raw_work() {
        // Raw loads perfectly balanced over 4 PEs, but PE 0 runs at
        // half speed: time diffusion must move work off it.
        let n = 64;
        let inst = Instance::new(
            vec![1.0; n],
            vec![[0.0; 2]; n],
            CommGraph::empty(n),
            (0..n as u32).map(|i| i / 16).collect(),
            Topology::flat(4).with_pe_speeds(vec![0.5, 1.0, 1.0, 1.0]),
        );
        let asg = ParMetis { params: StrategyParams::default() }.rebalance(&inst);
        let before = inst.pe_times(&inst.mapping);
        let after = inst.pe_times(&asg.mapping);
        let max = |v: &[f64]| v.iter().cloned().fold(0.0, f64::max);
        assert!(max(&after) < max(&before), "{before:?} -> {after:?}");
    }

    #[test]
    fn empty_graph_still_balances_via_ring() {
        let n = 32;
        let inst = Instance::new(
            (0..n).map(|i| if i < 8 { 4.0 } else { 1.0 }).collect(),
            vec![[0.0; 2]; n],
            CommGraph::empty(n),
            (0..n as u32).map(|i| i / 8).collect(),
            Topology::flat(4),
        );
        let before = evaluate(&inst, &Assignment::unchanged(&inst));
        let after = evaluate(
            &inst,
            &ParMetis { params: StrategyParams::default() }.rebalance(&inst),
        );
        assert!(after.max_avg_pe < before.max_avg_pe);
    }
}
