//! Hierarchical within-process refinement (paper §III-D).
//!
//! The three diffusion stages operate at node (process) granularity;
//! this pass refines the node-level decision into a PE-level mapping:
//! objects staying on their node keep their PE, arrivals go to the
//! least-loaded PE, and a bounded load-only refinement evens out the
//! PEs inside each node. Until this point migrations exist only as
//! proxy tokens — the app moves real objects once, afterwards.
//!
//! Heterogeneity: every PE-level accumulator here is **normalized time**
//! (`load / pe_speed`) — a fast PE absorbs proportionally more work.
//! On uniform topologies every speed is exactly 1.0 and IEEE-754
//! guarantees `x / 1.0 == x` bitwise, so the homogeneous behavior is
//! unchanged to the last bit (locked by `rust/tests/hetero_identity.rs`).

use super::scratch::LbScratch;
use crate::model::Instance;

/// Produce the PE-level mapping realizing `new_node_map`.
pub fn assign_pes(inst: &Instance, new_node_map: &[u32], tol: f64) -> Vec<u32> {
    let mut scratch = LbScratch::default();
    assign_pes_with(inst, new_node_map, tol, &mut scratch)
}

/// [`assign_pes`] against a caller-owned [`LbScratch`] — the hot path
/// `Diffusion::rebalance` uses. Member lists come from the scratch's
/// sorted-by-node SoA index rebuilt on `new_node_map`: one counting-
/// sort pass over all objects replaces the seed's per-node full-object
/// scan (`O(n_objects * n_nodes)` → `O(n_objects + n_nodes)`), and each
/// node's members arrive as one contiguous ascending-id slice — exactly
/// the order [`assign_pes_node`]'s contract demands, so the refinement
/// decisions are bit-identical to the scan-built lists.
pub fn assign_pes_with(
    inst: &Instance,
    new_node_map: &[u32],
    tol: f64,
    scratch: &mut LbScratch,
) -> Vec<u32> {
    let ppn = inst.topo.pes_per_node;
    if ppn == 1 {
        // node == PE
        return new_node_map.to_vec();
    }
    scratch.build_soa(inst, new_node_map, inst.topo.n_nodes);
    let mut mapping = vec![0u32; inst.n_objects()];
    for node in 0..inst.topo.n_nodes as u32 {
        let members = &scratch.soa_objs[scratch.soa_node(node as usize)];
        for (o, pe) in assign_pes_node(inst, node, members, tol) {
            mapping[o as usize] = pe;
        }
    }
    mapping
}

/// PE refinement for **one** node's member set, returning `(object,
/// absolute PE)` pairs — per-node body shared by [`assign_pes`] and the
/// distributed pipeline, where every node refines only its own members
/// (this stage needs no inter-node communication at all: it reads the
/// member list, the old mapping and the loads). `members` must be in
/// ascending object order, as produced by scanning objects 0..n — the
/// LPT tie-break and refinement visit order depend on it.
pub fn assign_pes_node(
    inst: &Instance,
    node: u32,
    members: &[u32],
    tol: f64,
) -> Vec<(u32, u32)> {
    let ppn = inst.topo.pes_per_node;
    if ppn == 1 {
        let pe = inst.topo.pes_of_node(node).start;
        return members.iter().map(|&o| (o, pe)).collect();
    }
    let pe_range = inst.topo.pes_of_node(node);
    let pe_lo = pe_range.start;
    // Per-local-PE speed lookup (exactly 1.0 on uniform topologies —
    // the divisions below are then bitwise no-ops). A closure, not a
    // collected Vec: this runs once per node per rebalance and must
    // not add allocations to the zero-allocation pipeline.
    let spd = |local: usize| inst.topo.pe_speed(pe_lo + local as u32);
    // pe_loads holds normalized time per PE.
    let mut pe_loads = vec![0.0f64; ppn];
    let mut placed: Vec<(u32, usize)> = Vec::with_capacity(members.len());

    // Stayers keep their PE.
    let mut arrivals: Vec<u32> = Vec::new();
    for &o in members {
        let old_pe = inst.mapping[o as usize];
        if inst.topo.node_of_pe(old_pe) == node {
            let local = (old_pe - pe_lo) as usize;
            pe_loads[local] += inst.loads[o as usize] / spd(local);
            placed.push((o, local));
        } else {
            arrivals.push(o);
        }
    }
    // Arrivals: LPT — heaviest first onto the least-time-loaded PE.
    arrivals.sort_by(|&a, &b| {
        inst.loads[b as usize].total_cmp(&inst.loads[a as usize]).then(a.cmp(&b))
    });
    for o in arrivals {
        let (local, _) = pe_loads
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .unwrap();
        pe_loads[local] += inst.loads[o as usize] / spd(local);
        placed.push((o, local));
    }

    refine_within(&mut placed, &mut pe_loads, &inst.loads, &spd, tol);

    placed.into_iter().map(|(o, local)| (o, pe_lo + local as u32)).collect()
}

/// Bounded time-only refinement: repeatedly move the best-fitting object
/// from the most-time-loaded PE to the least-time-loaded PE while it
/// reduces the spread, up to an iteration bound. `pe_loads` are
/// normalized times and `spd` the per-local-PE speed lookup: an
/// object's cost is `load / speed` at whichever PE holds it, so the
/// same object frees `l / spd(max)` leaving the hot PE and adds
/// `l / spd(min)` arriving at the cold one (equal on uniform
/// topologies, where both divisors are exactly 1.0).
fn refine_within(
    placed: &mut [(u32, usize)],
    pe_loads: &mut [f64],
    loads: &[f64],
    spd: &impl Fn(usize) -> f64,
    tol: f64,
) {
    let n_pes = pe_loads.len();
    if n_pes < 2 {
        return;
    }
    let avg: f64 = pe_loads.iter().sum::<f64>() / n_pes as f64;
    for _ in 0..64 {
        let (max_pe, &max_load) = pe_loads
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap();
        let (min_pe, &min_load) = pe_loads
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .unwrap();
        if max_load <= avg * (1.0 + tol) || max_pe == min_pe {
            break;
        }
        let gap = max_load - min_load;
        // object on max_pe with outgoing time closest to gap/2 (and
        // incoming time strictly < gap so the move improves the spread)
        let mut best: Option<(usize, f64)> = None; // (index in placed, |dt - gap/2|)
        for (idx, &(o, pe)) in placed.iter().enumerate() {
            if pe != max_pe {
                continue;
            }
            let dt_out = loads[o as usize] / spd(max_pe);
            let dt_in = loads[o as usize] / spd(min_pe);
            if dt_out <= 0.0 || dt_in >= gap {
                continue;
            }
            let score = (dt_out - gap / 2.0).abs();
            if best.map(|(_, s)| score < s).unwrap_or(true) {
                best = Some((idx, score));
            }
        }
        let Some((idx, _)) = best else { break };
        let (o, _) = placed[idx];
        placed[idx] = (o, min_pe);
        pe_loads[max_pe] -= loads[o as usize] / spd(max_pe);
        pe_loads[min_pe] += loads[o as usize] / spd(min_pe);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{CommGraph, Instance, Topology};

    fn inst_2nodes_2pes(loads: Vec<f64>, mapping: Vec<u32>) -> Instance {
        let n = loads.len();
        Instance::new(
            loads,
            vec![[0.0; 2]; n],
            CommGraph::empty(n),
            mapping,
            Topology::new(2, 2),
        )
    }

    #[test]
    fn flat_topology_is_identity() {
        let inst = Instance::new(
            vec![1.0, 2.0],
            vec![[0.0; 2]; 2],
            CommGraph::empty(2),
            vec![0, 1],
            Topology::flat(2),
        );
        let pes = assign_pes(&inst, &[1, 0], 0.02);
        assert_eq!(pes, vec![1, 0]);
    }

    #[test]
    fn stayers_keep_pe_arrivals_fill_least_loaded() {
        // node 0 has PEs 0,1; obj0 on pe0, obj1 on pe1. obj2 arrives
        // from node 1; must land on the lighter PE (pe1).
        let inst = inst_2nodes_2pes(vec![5.0, 1.0, 2.0, 1.0], vec![0, 1, 2, 3]);
        let node_map = vec![0, 0, 0, 1];
        let pes = assign_pes(&inst, &node_map, 0.5); // loose tol: no refine
        assert_eq!(pes[0], 0);
        assert_eq!(pes[1], 1);
        assert_eq!(pes[2], 1); // least-loaded at arrival time
        assert_eq!(pes[3], 3); // stayer on node 1 keeps its PE
    }

    #[test]
    fn refinement_evens_out_pes() {
        // all 4 objects on pe0 of node 0; refinement must spread them
        // over pe0/pe1.
        let inst = inst_2nodes_2pes(vec![2.0, 2.0, 2.0, 2.0], vec![0, 0, 0, 0]);
        let node_map = vec![0, 0, 0, 0];
        let pes = assign_pes(&inst, &node_map, 0.02);
        let l0: f64 = pes.iter().zip(&inst.loads).filter(|(&p, _)| p == 0).map(|(_, l)| l).sum();
        let l1: f64 = pes.iter().zip(&inst.loads).filter(|(&p, _)| p == 1).map(|(_, l)| l).sum();
        assert_eq!(l0, 4.0);
        assert_eq!(l1, 4.0);
    }

    #[test]
    fn refinement_balances_time_on_heterogeneous_pes() {
        // One node with PEs at speeds [1, 2]; six unit objects start on
        // the slow PE (times [6, 0]). Time-aware refinement sheds until
        // the slow PE drops under the (initial-placement) average time
        // of 3: three moves, times [3, 1.5] — strictly better in time
        // than any raw-work split would indicate, and deterministic.
        let inst = Instance::new(
            vec![1.0; 6],
            vec![[0.0; 2]; 6],
            CommGraph::empty(6),
            vec![0; 6],
            Topology::new(1, 2).with_pe_speeds(vec![1.0, 2.0]),
        );
        let pes = assign_pes(&inst, &[0, 0, 0, 0, 0, 0], 0.02);
        let on_fast = pes.iter().filter(|&&p| p == 1).count();
        assert_eq!(on_fast, 3, "slow PE sheds down to the time average: {pes:?}");
    }

    #[test]
    fn arrivals_prefer_the_least_time_loaded_pe() {
        // Node 0: PEs 0 (speed 1) and 1 (speed 4). Objects 0 and 1 stay
        // on PEs 0 and 1 with equal raw loads (times 2.0 vs 0.5); the
        // arriving object 2 must land on the fast PE.
        let inst = Instance::new(
            vec![2.0, 2.0, 1.0, 1.0],
            vec![[0.0; 2]; 4],
            CommGraph::empty(4),
            vec![0, 1, 2, 3],
            Topology::new(2, 2).with_pe_speeds(vec![1.0, 4.0, 1.0, 1.0]),
        );
        let pes = assign_pes(&inst, &[0, 0, 0, 1], 0.9); // loose tol: no refine
        assert_eq!(pes[0], 0);
        assert_eq!(pes[1], 1);
        assert_eq!(pes[2], 1, "arrival must pick the PE with the least time");
    }

    #[test]
    fn respects_node_boundaries() {
        let inst = inst_2nodes_2pes(vec![1.0; 4], vec![0, 0, 2, 2]);
        let node_map = vec![0, 1, 1, 0];
        let pes = assign_pes(&inst, &node_map, 0.02);
        for (o, &pe) in pes.iter().enumerate() {
            assert_eq!(inst.topo.node_of_pe(pe), node_map[o]);
        }
    }
}
