//! Hierarchical within-process refinement (paper §III-D).
//!
//! The three diffusion stages operate at node (process) granularity;
//! this pass refines the node-level decision into a PE-level mapping:
//! objects staying on their node keep their PE, arrivals go to the
//! least-loaded PE, and a bounded load-only refinement evens out the
//! PEs inside each node. Until this point migrations exist only as
//! proxy tokens — the app moves real objects once, afterwards.

use crate::model::Instance;

/// Produce the PE-level mapping realizing `new_node_map`.
pub fn assign_pes(inst: &Instance, new_node_map: &[u32], tol: f64) -> Vec<u32> {
    let ppn = inst.topo.pes_per_node;
    if ppn == 1 {
        // node == PE
        return new_node_map.to_vec();
    }
    let mut mapping = vec![0u32; inst.n_objects()];
    for node in 0..inst.topo.n_nodes as u32 {
        let members: Vec<u32> = (0..inst.n_objects() as u32)
            .filter(|&o| new_node_map[o as usize] == node)
            .collect();
        for (o, pe) in assign_pes_node(inst, node, &members, tol) {
            mapping[o as usize] = pe;
        }
    }
    mapping
}

/// PE refinement for **one** node's member set, returning `(object,
/// absolute PE)` pairs — per-node body shared by [`assign_pes`] and the
/// distributed pipeline, where every node refines only its own members
/// (this stage needs no inter-node communication at all: it reads the
/// member list, the old mapping and the loads). `members` must be in
/// ascending object order, as produced by scanning objects 0..n — the
/// LPT tie-break and refinement visit order depend on it.
pub fn assign_pes_node(
    inst: &Instance,
    node: u32,
    members: &[u32],
    tol: f64,
) -> Vec<(u32, u32)> {
    let ppn = inst.topo.pes_per_node;
    if ppn == 1 {
        let pe = inst.topo.pes_of_node(node).start;
        return members.iter().map(|&o| (o, pe)).collect();
    }
    let pe_range = inst.topo.pes_of_node(node);
    let pe_lo = pe_range.start;
    let mut pe_loads = vec![0.0f64; ppn];
    let mut placed: Vec<(u32, usize)> = Vec::with_capacity(members.len());

    // Stayers keep their PE.
    let mut arrivals: Vec<u32> = Vec::new();
    for &o in members {
        let old_pe = inst.mapping[o as usize];
        if inst.topo.node_of_pe(old_pe) == node {
            let local = (old_pe - pe_lo) as usize;
            pe_loads[local] += inst.loads[o as usize];
            placed.push((o, local));
        } else {
            arrivals.push(o);
        }
    }
    // Arrivals: LPT — heaviest first onto the least-loaded PE.
    arrivals.sort_by(|&a, &b| {
        inst.loads[b as usize]
            .partial_cmp(&inst.loads[a as usize])
            .unwrap()
            .then(a.cmp(&b))
    });
    for o in arrivals {
        let (local, _) = pe_loads
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        pe_loads[local] += inst.loads[o as usize];
        placed.push((o, local));
    }

    refine_within(&mut placed, &mut pe_loads, &inst.loads, tol);

    placed.into_iter().map(|(o, local)| (o, pe_lo + local as u32)).collect()
}

/// Bounded load-only refinement: repeatedly move the best-fitting object
/// from the most-loaded PE to the least-loaded PE while it reduces the
/// spread, up to an iteration bound.
fn refine_within(
    placed: &mut [(u32, usize)],
    pe_loads: &mut [f64],
    loads: &[f64],
    tol: f64,
) {
    let n_pes = pe_loads.len();
    if n_pes < 2 {
        return;
    }
    let avg: f64 = pe_loads.iter().sum::<f64>() / n_pes as f64;
    for _ in 0..64 {
        let (max_pe, &max_load) = pe_loads
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let (min_pe, &min_load) = pe_loads
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        if max_load <= avg * (1.0 + tol) || max_pe == min_pe {
            break;
        }
        let gap = max_load - min_load;
        // object on max_pe with load closest to gap/2 (strictly < gap so
        // the move improves the spread)
        let mut best: Option<(usize, f64)> = None; // (index in placed, |load - gap/2|)
        for (idx, &(o, pe)) in placed.iter().enumerate() {
            if pe != max_pe {
                continue;
            }
            let l = loads[o as usize];
            if l <= 0.0 || l >= gap {
                continue;
            }
            let score = (l - gap / 2.0).abs();
            if best.map(|(_, s)| score < s).unwrap_or(true) {
                best = Some((idx, score));
            }
        }
        let Some((idx, _)) = best else { break };
        let (o, _) = placed[idx];
        placed[idx] = (o, min_pe);
        pe_loads[max_pe] -= loads[o as usize];
        pe_loads[min_pe] += loads[o as usize];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{CommGraph, Instance, Topology};

    fn inst_2nodes_2pes(loads: Vec<f64>, mapping: Vec<u32>) -> Instance {
        let n = loads.len();
        Instance::new(
            loads,
            vec![[0.0; 2]; n],
            CommGraph::empty(n),
            mapping,
            Topology::new(2, 2),
        )
    }

    #[test]
    fn flat_topology_is_identity() {
        let inst = Instance::new(
            vec![1.0, 2.0],
            vec![[0.0; 2]; 2],
            CommGraph::empty(2),
            vec![0, 1],
            Topology::flat(2),
        );
        let pes = assign_pes(&inst, &[1, 0], 0.02);
        assert_eq!(pes, vec![1, 0]);
    }

    #[test]
    fn stayers_keep_pe_arrivals_fill_least_loaded() {
        // node 0 has PEs 0,1; obj0 on pe0, obj1 on pe1. obj2 arrives
        // from node 1; must land on the lighter PE (pe1).
        let inst = inst_2nodes_2pes(vec![5.0, 1.0, 2.0, 1.0], vec![0, 1, 2, 3]);
        let node_map = vec![0, 0, 0, 1];
        let pes = assign_pes(&inst, &node_map, 0.5); // loose tol: no refine
        assert_eq!(pes[0], 0);
        assert_eq!(pes[1], 1);
        assert_eq!(pes[2], 1); // least-loaded at arrival time
        assert_eq!(pes[3], 3); // stayer on node 1 keeps its PE
    }

    #[test]
    fn refinement_evens_out_pes() {
        // all 4 objects on pe0 of node 0; refinement must spread them
        // over pe0/pe1.
        let inst = inst_2nodes_2pes(vec![2.0, 2.0, 2.0, 2.0], vec![0, 0, 0, 0]);
        let node_map = vec![0, 0, 0, 0];
        let pes = assign_pes(&inst, &node_map, 0.02);
        let l0: f64 = pes.iter().zip(&inst.loads).filter(|(&p, _)| p == 0).map(|(_, l)| l).sum();
        let l1: f64 = pes.iter().zip(&inst.loads).filter(|(&p, _)| p == 1).map(|(_, l)| l).sum();
        assert_eq!(l0, 4.0);
        assert_eq!(l1, 4.0);
    }

    #[test]
    fn respects_node_boundaries() {
        let inst = inst_2nodes_2pes(vec![1.0; 4], vec![0, 0, 2, 2]);
        let node_map = vec![0, 1, 1, 0];
        let pes = assign_pes(&inst, &node_map, 0.02);
        for (o, &pe) in pes.iter().enumerate() {
            assert_eq!(inst.topo.node_of_pe(pe), node_map[o]);
        }
    }
}
