//! Communication-aware diffusion load balancing — the paper's
//! contribution (§III), plus the coordinate-based variant (§IV).
//!
//! Pipeline: [`neighbor`] (stage 1, handshake over comm volume or
//! centroid distance) → [`virtual_lb`] (stage 2, single-hop first-order
//! diffusion of load magnitudes) → [`object_selection`] (stage 3,
//! locality-preserving picks) → [`hierarchical`] (within-process PE
//! refinement, §III-D).

pub mod hierarchical;
pub mod neighbor;
pub mod object_selection;
pub mod scratch;
pub mod virtual_lb;

use crate::model::{Assignment, Instance};
use crate::strategies::{LoadBalancer, StrategyParams};
use scratch::LbScratch;

/// Which signal drives neighbor selection + object picks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Application communication graph (paper §III).
    Communication,
    /// Object coordinates as a proxy for communication (paper §IV).
    Coordinate,
}

/// The diffusion strategy.
pub struct Diffusion {
    pub variant: Variant,
    pub params: StrategyParams,
    /// Cached stage-1 result when `params.reuse_neighbors` is set
    /// (paper §III-A future work: node-level communication patterns
    /// persist across LB rounds, so the handshake can be amortized).
    cache: std::sync::Mutex<Option<neighbor::NeighborGraph>>,
    /// Reusable workspace: after the first rebalance warms its
    /// capacities, the comm-variant `rebalance()`'s loops run out of
    /// recycled buffers — no per-object or per-(node, neighbor)
    /// transient allocations remain, and the remaining sorts are
    /// unstable (in-place) ones (see [`scratch`]). Behind a Mutex
    /// because `LoadBalancer` takes `&self`; uncontended lock cost is
    /// noise next to the avoided allocations.
    scratch: std::sync::Mutex<LbScratch>,
}

impl Diffusion {
    pub fn communication(params: StrategyParams) -> Diffusion {
        Diffusion {
            variant: Variant::Communication,
            params,
            cache: std::sync::Mutex::new(None),
            scratch: std::sync::Mutex::new(LbScratch::default()),
        }
    }

    pub fn coordinate(params: StrategyParams) -> Diffusion {
        Diffusion {
            variant: Variant::Coordinate,
            params,
            cache: std::sync::Mutex::new(None),
            scratch: std::sync::Mutex::new(LbScratch::default()),
        }
    }

    /// Drop the cached neighbor graph (e.g. after topology changes).
    pub fn invalidate_neighbors(&self) {
        *self.cache.lock().unwrap() = None;
    }

    /// Expose the stage-1 + stage-2 intermediate results (used by the
    /// benches to report neighbor-graph/quota statistics and by
    /// simnet's distributed execution for cross-validation).
    ///
    /// Ownership note: the returned `Quotas` carries the scratch's
    /// recycled flow rows away with it, so a `plan()` call re-warms
    /// that one buffer on the next round. Only `rebalance()` — the hot
    /// path — hands the rows back; `plan()` callers are diagnostics
    /// and can afford the n-row allocation.
    pub fn plan(&self, inst: &Instance) -> (neighbor::NeighborGraph, virtual_lb::Quotas) {
        let mut scratch = self.scratch.lock().unwrap();
        self.plan_locked(inst, &mut scratch)
    }

    /// Stage 1 + 2 against the already-locked scratch (rebalance holds
    /// the lock across all three stages; the Mutex is not reentrant).
    fn plan_locked(
        &self,
        inst: &Instance,
        scratch: &mut LbScratch,
    ) -> (neighbor::NeighborGraph, virtual_lb::Quotas) {
        scratch.load_views(inst);
        let node_map = std::mem::take(&mut scratch.node_map);
        let cached = if self.params.reuse_neighbors {
            self.cache.lock().unwrap().clone().filter(|g| g.n() == inst.topo.n_nodes)
        } else {
            None
        };
        let neigh = match cached {
            Some(g) => g,
            None => {
                let _s1 = crate::obs::span("stage1.neighbors", "diffusion");
                let g = match self.variant {
                    Variant::Communication => {
                        neighbor::comm_candidates_into(inst, &node_map, scratch);
                        neighbor::select_neighbors(
                            &scratch.candidates,
                            self.params.neighbor_count,
                            self.params.handshake_max_rounds,
                        )
                    }
                    Variant::Coordinate => {
                        let candidates = if self.params.sfc_window > 0 {
                            neighbor::coord_candidates_sfc(inst, &node_map, self.params.sfc_window)
                        } else {
                            neighbor::coord_candidates(inst, &node_map)
                        };
                        neighbor::select_neighbors(
                            &candidates,
                            self.params.neighbor_count,
                            self.params.handshake_max_rounds,
                        )
                    }
                };
                if self.params.reuse_neighbors {
                    *self.cache.lock().unwrap() = Some(g.clone());
                }
                g
            }
        };
        // Stage-2 input: raw node work on uniform topologies (the exact
        // pre-heterogeneity arithmetic); per-node normalized time
        // (work / capacity, filled by load_views) on heterogeneous ones
        // — so the fixed point equalizes *time* and its quotas are in
        // time units, which stage 3 consumes by charging each migrated
        // object `load / capacity(sender)`.
        let node_loads = std::mem::take(&mut scratch.node_loads);
        let node_time = std::mem::take(&mut scratch.node_time);
        let lb_input: &[f64] =
            if inst.topo.is_uniform() { &node_loads } else { &node_time };
        let quotas = {
            let _s2 = crate::obs::span("stage2.virtual", "diffusion");
            virtual_lb::virtual_balance_with(
                &neigh,
                lb_input,
                self.params.vlb_tolerance,
                self.params.vlb_max_iters,
                scratch,
            )
        };
        // sampled into the per-round MetricsSnapshot by the driver
        crate::obs::gauge!("lb.stage2_iters").set(quotas.iterations as f64);
        scratch.node_map = node_map;
        scratch.node_loads = node_loads;
        scratch.node_time = node_time;
        (neigh, quotas)
    }
}

impl LoadBalancer for Diffusion {
    fn name(&self) -> &'static str {
        match self.variant {
            Variant::Communication => "diff-comm",
            Variant::Coordinate => "diff-coord",
        }
    }

    fn rebalance(&self, inst: &Instance) -> Assignment {
        let mut guard = self.scratch.lock().unwrap();
        let scratch = &mut *guard;
        let (_neigh, quotas) = self.plan_locked(inst, scratch);
        // node_map was filled by plan_locked's load_views and is still
        // the pre-LB object -> node view; take it out so stage 3 can
        // borrow the scratch alongside it.
        let mut node_map = std::mem::take(&mut scratch.node_map);
        {
            let _s3 = crate::obs::span("stage3.select", "diffusion");
            match self.variant {
                Variant::Communication => {
                    object_selection::select_comm_with(
                        inst,
                        &mut node_map,
                        &quotas,
                        self.params.overfill,
                        scratch,
                    );
                }
                Variant::Coordinate => {
                    object_selection::select_coord_with(
                        inst,
                        &mut node_map,
                        &quotas,
                        self.params.overfill,
                        scratch,
                    );
                }
            }
        }
        let mapping = {
            let _s4 = crate::obs::span("refine.pes", "diffusion");
            // reuses the scratch's SoA arrays, rebuilt on the post-LB
            // node map (stage 3 left them indexed on the pre-LB one)
            hierarchical::assign_pes_with(inst, &node_map, self.params.refine_tolerance, scratch)
        };
        scratch.node_map = node_map;
        // recycle the quota rows for the next round
        scratch.flows_pool = quotas.flows;
        Assignment { mapping }
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::model::{evaluate, CommGraph, Instance, Topology};
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// 2D stencil instance: side x side objects tiled over a px x py
    /// processor grid, with multiplicative load noise.
    pub fn stencil_instance(side: usize, px: usize, py: usize, noise: f64, seed: u64) -> Instance {
        let n = side * side;
        let mut edges = Vec::new();
        for r in 0..side {
            for c in 0..side {
                let o = (r * side + c) as u32;
                edges.push((o, (r * side + (c + 1) % side) as u32, 64.0));
                edges.push((o, ((r + 1) % side * side + c) as u32, 64.0));
            }
        }
        let graph = CommGraph::from_edges(n, &edges);
        let mut rng = Rng::new(seed);
        let loads: Vec<f64> =
            (0..n).map(|_| 1.0 * (1.0 + noise * (2.0 * rng.f64() - 1.0))).collect();
        let coords: Vec<[f64; 2]> =
            (0..n).map(|i| [(i % side) as f64, (i / side) as f64]).collect();
        // tiled decomposition onto px x py
        let tile_w = side / px;
        let tile_h = side / py;
        let mapping: Vec<u32> = (0..n)
            .map(|i| {
                let (c, r) = (i % side, i / side);
                ((r / tile_h).min(py - 1) * px + (c / tile_w).min(px - 1)) as u32
            })
            .collect();
        Instance::new(loads, coords, graph, mapping, Topology::flat(px * py))
    }

    #[test]
    fn comm_diffusion_improves_balance_and_keeps_locality() {
        let inst = stencil_instance(24, 4, 4, 0.4, 42);
        let before = evaluate(&inst, &crate::model::Assignment::unchanged(&inst));
        let lb = Diffusion::communication(StrategyParams::default());
        let asg = lb.rebalance(&inst);
        let after = evaluate(&inst, &asg);
        assert!(after.max_avg_node < before.max_avg_node, "{} !< {}", after.max_avg_node, before.max_avg_node);
        // locality not destroyed: ext/int stays within 2x of initial
        assert!(after.comm_nodes.ratio() < before.comm_nodes.ratio() * 2.0 + 0.05);
        // migrations are incremental, not wholesale
        assert!(after.migration_pct < 50.0, "{}%", after.migration_pct);
    }

    #[test]
    fn coord_diffusion_improves_balance() {
        let inst = stencil_instance(24, 4, 4, 0.4, 43);
        let before = evaluate(&inst, &crate::model::Assignment::unchanged(&inst));
        let lb = Diffusion::coordinate(StrategyParams::default());
        let asg = lb.rebalance(&inst);
        let after = evaluate(&inst, &asg);
        assert!(after.max_avg_node < before.max_avg_node);
    }

    #[test]
    fn deterministic_given_seed() {
        let inst = stencil_instance(16, 4, 4, 0.4, 7);
        let lb = Diffusion::communication(StrategyParams::default());
        assert_eq!(lb.rebalance(&inst).mapping, lb.rebalance(&inst).mapping);
    }

    #[test]
    fn single_hop_property() {
        // every migrated object lands on a stage-1 neighbor of its
        // original node — the paper's single-hop guarantee end to end.
        prop::check("diffusion single-hop", 15, |g| {
            let side = 8 + 4 * g.usize_in(0, 3);
            let inst = stencil_instance(side, 4, 4, 0.6, g.seed);
            let lb = Diffusion::communication(StrategyParams::default());
            let (neigh, _) = lb.plan(&inst);
            let asg = lb.rebalance(&inst);
            for o in 0..inst.n_objects() {
                let from = inst.topo.node_of_pe(inst.mapping[o]);
                let to = inst.topo.node_of_pe(asg.mapping[o]);
                if from != to && !neigh.adj[from as usize].contains(&to) {
                    return Err(format!("object {o} hopped {from}->{to} (not neighbors)"));
                }
            }
            Ok(())
        });
    }
}

#[cfg(test)]
mod reuse_tests {
    use super::*;
    use crate::strategies::diffusion::tests::stencil_instance;

    #[test]
    fn reuse_caches_neighbor_graph() {
        let inst = stencil_instance(16, 4, 4, 0.4, 3);
        let params = StrategyParams { reuse_neighbors: true, ..Default::default() };
        let lb = Diffusion::communication(params);
        let (g1, _) = lb.plan(&inst);
        let (g2, _) = lb.plan(&inst);
        assert_eq!(g1.adj, g2.adj);
        lb.invalidate_neighbors();
        let (g3, _) = lb.plan(&inst);
        assert_eq!(g1.adj, g3.adj); // same instance -> same graph anyway
    }

    #[test]
    fn reused_graph_still_balances() {
        let mut inst = stencil_instance(24, 4, 4, 0.4, 4);
        let params = StrategyParams { reuse_neighbors: true, ..Default::default() };
        let lb = Diffusion::communication(params);
        for round in 0..3 {
            let before = crate::model::evaluate_mapping(&inst, &inst.mapping);
            let asg = lb.rebalance(&inst);
            let after = crate::model::evaluate_mapping(&inst, &asg.mapping);
            assert!(
                after.max_avg_node <= before.max_avg_node + 1e-9,
                "round {round}: {} -> {}",
                before.max_avg_node,
                after.max_avg_node
            );
            inst.mapping = asg.mapping;
            crate::apps::stencil::inject_noise(&mut inst, 0.2, 100 + round);
        }
    }

    #[test]
    fn sfc_variant_end_to_end() {
        let inst = stencil_instance(24, 4, 4, 0.4, 5);
        let params = StrategyParams { sfc_window: 6, ..Default::default() };
        let lb = Diffusion::coordinate(params);
        let before = crate::model::evaluate_mapping(&inst, &inst.mapping);
        let after = crate::model::evaluate_mapping(&inst, &lb.rebalance(&inst).mapping);
        assert!(after.max_avg_node < before.max_avg_node);
    }
}
