//! Reusable workspace for the diffusion pipeline ("LbScratch").
//!
//! The seed allocated per call in every stage: stage 1 built a fresh
//! dense traffic matrix and fresh candidate rows, stage 2 kept net
//! flows in a `HashMap<(u32,u32), f64>`, and stage 3 built a
//! `HashMap<u32, f64>` plus a `BinaryHeap` **per (node, neighbor)
//! pair** — thousands of transient maps per rebalance on the 9216-
//! object workload. Diffusive LB only pays off when the balancer is
//! cheap relative to the work it moves (Demiralp et al. 2022; Demirel &
//! Sbalzarini 2012), so the whole pipeline now threads one [`LbScratch`]
//! through the stages: dense per-object arrays with **epoch tags**
//! replace the hash maps (an entry is valid iff its tag equals the
//! current epoch, so "clearing" is a single counter increment), heaps
//! and index vectors are recycled, and the hot-loop sorts are unstable
//! (in-place, no merge buffer). After the first rebalance warms the
//! capacities, a comm-variant `rebalance()` performs no transient heap
//! allocation in its per-object or per-(node, neighbor) loops. (Paths
//! that *must* sort stably for bit-identical f64 sums —
//! `model::graph::sort_sum_merge` — still pay the stable sort's merge
//! buffer; that is per app step / LB round, not per object.)
//!
//! Every replacement is value-identical to the seed's hash-based code
//! (dense lookup vs hash lookup of the same f64; same `BinaryHeap`
//! type, same push order), so strategy decisions are bit-identical —
//! `rust/tests/perf_refactor.rs` locks that in.

use crate::model::Instance;

/// Reusable buffers for one diffusion strategy instance. Obtain via
/// `LbScratch::default()`; every buffer sizes itself lazily against the
/// instance it is used with, so one scratch serves instances of
/// changing size (re-warming capacities when the problem grows).
#[derive(Debug, Default)]
pub struct LbScratch {
    // ---------------------------------------------------- shared views
    /// Object -> node mapping (derived from the PE mapping).
    pub node_map: Vec<u32>,
    /// Per-node load totals.
    pub node_loads: Vec<f64>,
    /// Per-node service capacities (sum of PE speeds) — filled only on
    /// heterogeneous topologies.
    pub node_caps: Vec<f64>,
    /// Per-node normalized times (`node_loads / node_caps`) — the
    /// stage-2 input on heterogeneous topologies. Uniform topologies
    /// never touch this (stage 2 consumes `node_loads` directly, the
    /// exact pre-heterogeneity path).
    pub node_time: Vec<f64>,
    // ------------------------------------------------------- stage 1
    /// Dense node-to-node traffic matrix (`n_nodes^2`).
    pub traffic: Vec<f64>,
    /// Candidate preference rows, outer and inner capacity reused.
    pub candidates: Vec<Vec<u32>>,
    /// Per-task (peers, rest) buffers for pool-parallel candidate
    /// construction; one slot per worker lane so tasks never share.
    pub stage1_bufs: Vec<(Vec<(u32, f64)>, Vec<u32>)>,
    // ------------------------------------------------------- stage 2
    /// Load originating at each node still held there.
    pub own: Vec<f64>,
    /// Load received virtually (never forwarded).
    pub recv: Vec<f64>,
    /// `own + recv` snapshot per sweep.
    pub cur: Vec<f64>,
    /// CSR offsets into `net` for the neighbor graph's adjacency.
    pub net_offsets: Vec<u32>,
    /// Symmetrized adjacency rows, used for net-flow slots only when
    /// the caller hands virtual_lb an asymmetric graph (stage 1 always
    /// produces symmetric ones, so the hot path never fills this).
    pub sym_adj: Vec<Vec<u32>>,
    /// Signed net flow per directed adjacency slot (see virtual_lb).
    pub net: Vec<f64>,
    /// Planned sends of the current sweep.
    pub sends: Vec<(u32, u32, f64)>,
    /// Recycled storage for `Quotas::flows` (rows keep capacity).
    pub flows_pool: Vec<Vec<(u32, f64)>>,
    // ------------------------------------------------------- stage 3
    /// Dense per-object bytes-to-target accumulator.
    pub bytes_to_j: Vec<f64>,
    /// Epoch tag per object; `bytes_to_j[o]` is valid iff
    /// `epoch[o] == cur_epoch`.
    pub epoch: Vec<u32>,
    pub cur_epoch: u32,
    /// Per-pool-position `(key, tie, valid)` scoring buffer; positions
    /// are chunk-splittable for pool-parallel scoring where object ids
    /// are not.
    pub scores: Vec<(f64, f64, bool)>,
    /// Coord variant: per-node centroid sums / counts.
    pub csums: Vec<[f64; 2]>,
    pub ccounts: Vec<usize>,
    /// Recycled `BinaryHeap` backing storage.
    pub heap: Vec<super::object_selection::Entry>,
    // ------------------------------------------- sorted-by-node SoA
    // Per-node object storage in structure-of-arrays layout: node `i`
    // owns slots `soa_offsets[i]..soa_offsets[i+1]`, each slot holding
    // one object in ascending id order (counting sort is stable), with
    // its load, migration bytes, and CSR comm-row bounds gathered into
    // parallel arrays. Replaces the seed-era `Vec<Vec<u32>>` by-node
    // index: stage-3 candidate scans and §III-D refinement now walk
    // contiguous memory, and the rebuild is a single allocation-free
    // counting-sort pass per LB round (see [`Self::build_soa`]).
    /// Per-node slot ranges, length `n_nodes + 1`.
    pub soa_offsets: Vec<u32>,
    /// Object id per slot, ascending within each node's range.
    pub soa_objs: Vec<u32>,
    /// `inst.loads[soa_objs[s]]` per slot.
    pub soa_loads: Vec<f64>,
    /// `inst.sizes[soa_objs[s]]` (migration bytes) per slot.
    pub soa_sizes: Vec<f64>,
    /// `(row_start, row_end)` into the comm graph's CSR arrays per slot.
    pub soa_rows: Vec<(u32, u32)>,
    /// Counting-sort write cursors (build_soa scratch).
    soa_cursor: Vec<u32>,
    /// Current node's candidate pool.
    pub pool: Vec<u32>,
    /// Sorted (neighbor, quota) targets of the current node.
    pub targets: Vec<(u32, f64)>,
    /// Per-object migrated flag for the current rebalance.
    pub moved: Vec<bool>,
    /// Parallel-scoring chunk-count override (tests sweep this to prove
    /// thread-count independence); `None` = size to the global pool.
    pub par_tasks: Option<usize>,
}

impl LbScratch {
    /// Fill `node_map`/`node_loads` from the instance (allocation-free
    /// once warm) and return the number of nodes. On heterogeneous
    /// topologies also fills `node_caps` and `node_time` — the
    /// speed-normalized stage-2 load scalars (`work / capacity`, the
    /// division performed per node exactly as the distributed stage-2
    /// setup performs it locally).
    pub fn load_views(&mut self, inst: &Instance) -> usize {
        inst.node_mapping_into(&mut self.node_map);
        inst.node_loads_into(&mut self.node_loads);
        if !inst.topo.is_uniform() {
            self.node_caps.clear();
            self.node_caps
                .extend((0..inst.topo.n_nodes as u32).map(|n| inst.topo.node_capacity(n)));
            self.node_time.clear();
            let (nt, nl, nc) = (&mut self.node_time, &self.node_loads, &self.node_caps);
            nt.extend(nl.iter().zip(nc).map(|(l, c)| l / c));
        }
        inst.topo.n_nodes
    }

    /// Advance the stage-3 epoch, resizing the tag arrays on first use
    /// (or when the instance grew). On counter wrap every tag resets —
    /// a once-per-4-billion-phases O(n) cost.
    pub fn next_epoch(&mut self, n_objects: usize) -> u32 {
        if self.epoch.len() < n_objects {
            self.epoch.resize(n_objects, 0);
            self.bytes_to_j.resize(n_objects, 0.0);
        }
        self.cur_epoch = match self.cur_epoch.checked_add(1) {
            Some(e) => e,
            None => {
                self.epoch.iter_mut().for_each(|e| *e = 0);
                1
            }
        };
        self.cur_epoch
    }

    /// Rebuild the sorted-by-node SoA object storage for `node_map` —
    /// one counting-sort pass, allocation-free once warm. Placing
    /// objects `0..n` in order keeps each node's slot range in
    /// ascending object id order, the exact order the seed's
    /// `Vec<Vec<u32>>` index produced, so every pool iteration (and
    /// therefore every stage-3 decision) is bit-identical to it.
    pub fn build_soa(&mut self, inst: &Instance, node_map: &[u32], n_nodes: usize) {
        let n = node_map.len();
        debug_assert_eq!(n, inst.n_objects());
        self.soa_offsets.clear();
        self.soa_offsets.resize(n_nodes + 1, 0);
        for &nm in node_map {
            self.soa_offsets[nm as usize + 1] += 1;
        }
        for i in 0..n_nodes {
            self.soa_offsets[i + 1] += self.soa_offsets[i];
        }
        self.soa_objs.clear();
        self.soa_objs.resize(n, 0);
        self.soa_loads.clear();
        self.soa_loads.resize(n, 0.0);
        self.soa_sizes.clear();
        self.soa_sizes.resize(n, 0.0);
        self.soa_rows.clear();
        self.soa_rows.resize(n, (0, 0));
        self.soa_cursor.clear();
        self.soa_cursor.extend_from_slice(&self.soa_offsets[..n_nodes]);
        let offsets = &inst.graph.offsets;
        for (o, &nm) in node_map.iter().enumerate() {
            let s = self.soa_cursor[nm as usize] as usize;
            self.soa_objs[s] = o as u32;
            self.soa_loads[s] = inst.loads[o];
            self.soa_sizes[s] = inst.sizes[o];
            self.soa_rows[s] = (offsets[o], offsets[o + 1]);
            self.soa_cursor[nm as usize] += 1;
        }
    }

    /// Node `i`'s slot range in the SoA arrays.
    #[inline]
    pub fn soa_node(&self, i: usize) -> std::ops::Range<usize> {
        self.soa_offsets[i] as usize..self.soa_offsets[i + 1] as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{CommGraph, Topology};

    #[test]
    fn views_match_instance_helpers() {
        let inst = Instance::new(
            vec![1.0, 2.0, 3.0, 4.0],
            vec![[0.0; 2]; 4],
            CommGraph::empty(4),
            vec![0, 1, 2, 3],
            Topology::new(2, 2),
        );
        let mut s = LbScratch::default();
        let n_nodes = s.load_views(&inst);
        assert_eq!(n_nodes, 2);
        assert_eq!(s.node_map, inst.node_mapping());
        assert_eq!(s.node_loads, inst.node_loads(&inst.mapping));
        // reuse with no stale state
        s.load_views(&inst);
        assert_eq!(s.node_loads, vec![3.0, 7.0]);
        // uniform topology leaves the weighted buffers untouched
        assert!(s.node_time.is_empty() && s.node_caps.is_empty());
    }

    #[test]
    fn weighted_views_normalize_by_capacity() {
        let inst = Instance::new(
            vec![1.0, 2.0, 3.0, 4.0],
            vec![[0.0; 2]; 4],
            CommGraph::empty(4),
            vec![0, 1, 2, 3],
            Topology::new(2, 2).with_pe_speeds(vec![1.0, 2.0, 1.0, 3.0]),
        );
        let mut s = LbScratch::default();
        s.load_views(&inst);
        assert_eq!(s.node_loads, vec![3.0, 7.0]);
        assert_eq!(s.node_caps, vec![3.0, 4.0]);
        assert_eq!(s.node_time, vec![1.0, 1.75]);
    }

    #[test]
    fn epochs_invalidate_without_clearing() {
        let mut s = LbScratch::default();
        let e1 = s.next_epoch(8);
        s.bytes_to_j[3] = 42.0;
        s.epoch[3] = e1;
        let e2 = s.next_epoch(8);
        assert_ne!(e1, e2);
        assert_ne!(s.epoch[3], e2); // entry from e1 now invalid
    }

    #[test]
    fn soa_groups_ascending_and_rebuilds_clean() {
        let inst = Instance::new(
            vec![1.0, 2.0, 3.0, 4.0],
            vec![[0.0; 2]; 4],
            CommGraph::from_edges(4, &[(0, 2, 5.0), (1, 3, 7.0)]),
            vec![0, 1, 0, 1],
            Topology::flat(2),
        );
        let mut s = LbScratch::default();
        s.build_soa(&inst, &[0, 1, 0, 1], 2);
        assert_eq!(&s.soa_objs[s.soa_node(0)], &[0, 2]);
        assert_eq!(&s.soa_objs[s.soa_node(1)], &[1, 3]);
        assert_eq!(&s.soa_loads[s.soa_node(0)], &[1.0, 3.0]);
        assert_eq!(&s.soa_sizes[s.soa_node(1)], &[1.0, 1.0]);
        // comm-row bounds match the graph's CSR offsets per slot
        for (s_idx, &o) in s.soa_objs.iter().enumerate() {
            let (lo, hi) = s.soa_rows[s_idx];
            assert_eq!(lo, inst.graph.offsets[o as usize]);
            assert_eq!(hi, inst.graph.offsets[o as usize + 1]);
        }
        // rebuild with every object on node 1: no stale state
        s.build_soa(&inst, &[1, 1, 1, 1], 2);
        assert!(s.soa_node(0).is_empty());
        assert_eq!(&s.soa_objs[s.soa_node(1)], &[0, 1, 2, 3]);
        assert_eq!(&s.soa_loads[s.soa_node(1)], &[1.0, 2.0, 3.0, 4.0]);
    }
}
