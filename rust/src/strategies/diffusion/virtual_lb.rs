//! Stage 2 — virtual load balancing (paper §III-B).
//!
//! First-order diffusion (Cybenko '89; Hu & Blake '99) restricted to the
//! stage-1 neighbor graph, exchanging only load *magnitudes*: nodes
//! iteratively plan transfers `alpha * (L_i - L_j)` along edges until
//! every neighborhood's load spread falls below a threshold, under the
//! paper's **single-hop constraint** — load received virtually is never
//! forwarded, so real objects later move at most one edge from their
//! home node. Output: net per-edge send quotas.

use std::collections::HashMap;

use super::neighbor::NeighborGraph;

/// Net planned transfers: `flows[i]` maps neighbor j to the (positive)
/// amount node i should send to j.
#[derive(Debug, Clone, PartialEq)]
pub struct Quotas {
    pub flows: Vec<HashMap<u32, f64>>,
    /// Iterations the fixed-point ran for (reported as strategy cost).
    pub iterations: usize,
}

impl Quotas {
    pub fn empty(n: usize) -> Quotas {
        Quotas { flows: vec![HashMap::new(); n], iterations: 0 }
    }

    /// Total load node i is asked to send.
    pub fn outgoing(&self, i: usize) -> f64 {
        self.flows[i].values().sum()
    }

    /// Resulting virtual load vector when all quotas execute.
    pub fn apply(&self, loads: &[f64]) -> Vec<f64> {
        let mut out = loads.to_vec();
        for (i, flow) in self.flows.iter().enumerate() {
            for (&j, &amt) in flow {
                out[i] -= amt;
                out[j as usize] += amt;
            }
        }
        out
    }
}

/// Run the fixed-point. `tol` is the neighborhood relative-spread
/// threshold; iteration stops when every neighborhood satisfies it (or
/// `max_iters`).
pub fn virtual_balance(
    neigh: &NeighborGraph,
    loads: &[f64],
    tol: f64,
    max_iters: usize,
) -> Quotas {
    let n = loads.len();
    assert_eq!(neigh.n(), n);
    let global_avg = loads.iter().sum::<f64>() / n.max(1) as f64;
    if global_avg <= 0.0 {
        return Quotas::empty(n);
    }

    // First-order scheme constant: 1/(max_degree + 1) guarantees
    // convergence on arbitrary neighbor graphs (Cybenko).
    let alpha = 1.0 / (neigh.max_degree() as f64 + 1.0);

    // own[i]: load originating at i still held at i (may be sent).
    // recv[i]: load received virtually (may NOT be forwarded).
    let mut own = loads.to_vec();
    let mut recv = vec![0.0; n];
    // net signed flow per ordered pair (i, j) with i < j: >0 means i->j.
    let mut net: HashMap<(u32, u32), f64> = HashMap::new();
    let mut iterations = 0;

    for iter in 0..max_iters {
        iterations = iter + 1;
        let cur: Vec<f64> = own.iter().zip(&recv).map(|(o, r)| o + r).collect();

        // Plan this sweep's sends; cap each node's total send at its
        // remaining own load (single-hop constraint).
        let mut sends: Vec<(usize, u32, f64)> = Vec::new();
        for i in 0..n {
            let mut want = 0.0;
            let mut per: Vec<(u32, f64)> = Vec::new();
            for &j in &neigh.adj[i] {
                let diff = cur[i] - cur[j as usize];
                if diff > 0.0 {
                    let amt = alpha * diff;
                    per.push((j, amt));
                    want += amt;
                }
            }
            if want <= 0.0 {
                continue;
            }
            let scale = if want > own[i] { own[i] / want } else { 1.0 };
            if scale <= 0.0 {
                continue;
            }
            for (j, amt) in per {
                sends.push((i, j, amt * scale));
            }
        }

        let mut moved = 0.0;
        for (i, j, amt) in sends {
            own[i] -= amt;
            recv[j as usize] += amt;
            let key = if (i as u32) < j { (i as u32, j) } else { (j, i as u32) };
            let sign = if (i as u32) < j { 1.0 } else { -1.0 };
            *net.entry(key).or_insert(0.0) += sign * amt;
            moved += amt;
        }

        if converged(neigh, &own, &recv, global_avg, tol) || moved <= tol * global_avg * 1e-3 {
            break;
        }
    }

    // Fold signed pair flows into per-node positive send quotas. Cancel
    // opposing flows so object selection never ping-pongs objects.
    let mut flows: Vec<HashMap<u32, f64>> = vec![HashMap::new(); n];
    for ((a, b), f) in net {
        if f > 1e-12 {
            flows[a as usize].insert(b, f);
        } else if f < -1e-12 {
            flows[b as usize].insert(a, -f);
        }
    }
    Quotas { flows, iterations }
}

/// Every neighborhood (node + its neighbors) has relative load spread
/// below `tol` (measured against the global average so empty-ish
/// neighborhoods don't divide by ~0).
fn converged(neigh: &NeighborGraph, own: &[f64], recv: &[f64], global_avg: f64, tol: f64) -> bool {
    let cur = |i: usize| own[i] + recv[i];
    for i in 0..neigh.n() {
        if neigh.adj[i].is_empty() {
            continue;
        }
        let mut lo = cur(i);
        let mut hi = cur(i);
        for &j in &neigh.adj[i] {
            lo = lo.min(cur(j as usize));
            hi = hi.max(cur(j as usize));
        }
        if (hi - lo) / global_avg > tol {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::diffusion::neighbor::NeighborGraph;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn ring(n: usize, k: usize) -> NeighborGraph {
        // symmetric ring where each node connects to k/2 hops each side
        let h = (k / 2).max(1);
        let adj = (0..n)
            .map(|i| {
                let mut a: Vec<u32> = Vec::new();
                for d in 1..=h {
                    a.push(((i + d) % n) as u32);
                    a.push(((i + n - d) % n) as u32);
                }
                a.sort_unstable();
                a.dedup();
                a
            })
            .collect();
        NeighborGraph { adj }
    }

    #[test]
    fn balances_single_hotspot_with_enough_neighbors() {
        let n = 16;
        let mut loads = vec![1.0; n];
        loads[0] = 10.0;
        let g = ring(n, 4);
        let q = virtual_balance(&g, &loads, 0.05, 500);
        let out = q.apply(&loads);
        let avg = out.iter().sum::<f64>() / n as f64;
        let max = out.iter().cloned().fold(0.0, f64::max);
        // single-hop: node 0 can only shed to its 4 neighbors, so the
        // neighborhood equalizes around (10+4)/5.
        assert!(max / avg < 2.5, "max/avg {}", max / avg);
        // conservation
        let total: f64 = out.iter().sum();
        assert!((total - loads.iter().sum::<f64>()).abs() < 1e-9);
    }

    #[test]
    fn no_neighbors_no_flows() {
        let g = NeighborGraph { adj: vec![vec![], vec![]] };
        let q = virtual_balance(&g, &[10.0, 1.0], 0.05, 100);
        assert_eq!(q.outgoing(0), 0.0);
        assert_eq!(q.apply(&[10.0, 1.0]), vec![10.0, 1.0]);
    }

    #[test]
    fn quotas_only_on_edges_and_single_hop() {
        let n = 12;
        let mut rng = Rng::new(5);
        let loads: Vec<f64> = (0..n).map(|_| rng.uniform(0.5, 8.0)).collect();
        let g = ring(n, 2);
        let q = virtual_balance(&g, &loads, 0.02, 500);
        for i in 0..n {
            for &j in q.flows[i].keys() {
                assert!(g.adj[i].contains(&j), "flow on non-edge {i}->{j}");
            }
            // single-hop: cannot send more than original load
            assert!(q.outgoing(i) <= loads[i] + 1e-9, "node {i} oversends");
        }
    }

    #[test]
    fn conservation_property() {
        prop::check("virtual lb conserves load", 50, |g| {
            let n = g.usize_in(2, 32);
            let loads: Vec<f64> = (0..n).map(|_| g.f64_in(0.1, 20.0)).collect();
            let k = g.usize_in(2, 6);
            let graph = ring(n, k);
            let q = virtual_balance(&graph, &loads, 0.05, 300);
            let out = q.apply(&loads);
            prop::assert_that(
                out.iter().all(|&l| l >= -1e-9),
                "negative virtual load",
            )?;
            prop::assert_close(out.iter().sum::<f64>(), loads.iter().sum::<f64>(), 1e-9)
        });
    }

    #[test]
    fn imbalance_never_worsens() {
        prop::check("virtual lb does not worsen max/avg", 40, |g| {
            let n = g.usize_in(3, 24);
            let loads: Vec<f64> = (0..n).map(|_| g.f64_in(0.1, 10.0)).collect();
            let graph = ring(n, 4);
            let q = virtual_balance(&graph, &loads, 0.05, 300);
            let out = q.apply(&loads);
            let ratio = |v: &[f64]| {
                let avg = v.iter().sum::<f64>() / v.len() as f64;
                v.iter().cloned().fold(0.0, f64::max) / avg
            };
            prop::assert_that(
                ratio(&out) <= ratio(&loads) + 1e-6,
                format!("worsened {} -> {}", ratio(&loads), ratio(&out)),
            )
        });
    }
}
