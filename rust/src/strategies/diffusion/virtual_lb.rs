//! Stage 2 — virtual load balancing (paper §III-B).
//!
//! First-order diffusion (Cybenko '89; Hu & Blake '99) restricted to the
//! stage-1 neighbor graph, exchanging only load *magnitudes*: nodes
//! iteratively plan transfers `alpha * (L_i - L_j)` along edges until
//! every neighborhood's load spread falls below a threshold, under the
//! paper's **single-hop constraint** — load received virtually is never
//! forwarded, so real objects later move at most one edge from their
//! home node. Output: net per-edge send quotas.
//!
//! Perf: the fixed-point's state (own/recv/cur vectors, the per-sweep
//! send list, and the net pair flows — previously a
//! `HashMap<(u32,u32), f64>`) lives in [`LbScratch`]; net flows are
//! indexed by a small CSR over the neighbor graph's adjacency, so a
//! sweep is pure array arithmetic. Accumulation order per pair is
//! chronological, exactly like the old entry-API accumulation, so the
//! resulting quotas are bit-identical.

use super::neighbor::NeighborGraph;
use super::scratch::LbScratch;

/// Net planned transfers: `flows[i]` lists `(j, amount)` pairs — the
/// (positive) load node i should send to neighbor j — sorted by `j`.
#[derive(Debug, Clone, PartialEq)]
pub struct Quotas {
    pub flows: Vec<Vec<(u32, f64)>>,
    /// Iterations the fixed-point ran for (reported as strategy cost).
    pub iterations: usize,
}

impl Quotas {
    pub fn empty(n: usize) -> Quotas {
        Quotas { flows: vec![Vec::new(); n], iterations: 0 }
    }

    /// Total load node i is asked to send.
    pub fn outgoing(&self, i: usize) -> f64 {
        self.flows[i].iter().map(|&(_, a)| a).sum()
    }

    /// Planned send from `i` to `j` (0.0 when none).
    pub fn flow(&self, i: usize, j: u32) -> f64 {
        match self.flows[i].binary_search_by_key(&j, |&(p, _)| p) {
            Ok(idx) => self.flows[i][idx].1,
            Err(_) => 0.0,
        }
    }

    /// Resulting virtual load vector when all quotas execute.
    pub fn apply(&self, loads: &[f64]) -> Vec<f64> {
        let mut out = loads.to_vec();
        for (i, flow) in self.flows.iter().enumerate() {
            for &(j, amt) in flow {
                out[i] -= amt;
                out[j as usize] += amt;
            }
        }
        out
    }
}

/// Run the fixed-point. `tol` is the neighborhood relative-spread
/// threshold; iteration stops when every neighborhood satisfies it (or
/// `max_iters`).
pub fn virtual_balance(
    neigh: &NeighborGraph,
    loads: &[f64],
    tol: f64,
    max_iters: usize,
) -> Quotas {
    let mut scratch = LbScratch::default();
    virtual_balance_with(neigh, loads, tol, max_iters, &mut scratch)
}

/// [`virtual_balance`] against a caller-owned [`LbScratch`]. The
/// returned `Quotas` takes its row storage from `scratch.flows_pool`;
/// hand it back (`scratch.flows_pool = quotas.flows`) to keep the
/// steady state allocation-free.
///
/// Pair flows are stored once, in the smaller endpoint's adjacency
/// row. Stage 1 always produces symmetric graphs, and that hot path
/// indexes `neigh.adj` directly; an asymmetric `neigh` (constructible
/// because `adj` is a pub field) is handled gracefully by building a
/// symmetrized slot adjacency in the scratch — same quotas the seed's
/// HashMap accumulator produced, just a cold copy.
pub fn virtual_balance_with(
    neigh: &NeighborGraph,
    loads: &[f64],
    tol: f64,
    max_iters: usize,
    scratch: &mut LbScratch,
) -> Quotas {
    let n = loads.len();
    assert_eq!(neigh.n(), n);
    let mut flows = std::mem::take(&mut scratch.flows_pool);
    for row in flows.iter_mut() {
        row.clear();
    }
    if flows.len() != n {
        flows.truncate(n);
        flows.resize_with(n, Vec::new);
    }
    let global_avg = loads.iter().sum::<f64>() / n.max(1) as f64;
    if global_avg <= 0.0 {
        return Quotas { flows, iterations: 0 };
    }

    // First-order scheme constant: 1/(max_degree + 1) guarantees
    // convergence on arbitrary neighbor graphs (Cybenko).
    let alpha = 1.0 / (neigh.max_degree() as f64 + 1.0);

    // Slot adjacency: neigh.adj itself when symmetric (the stage-1
    // guarantee — no copy), else a symmetrized closure so every pair a
    // send can travel has a slot in its smaller endpoint's row.
    let symmetric = neigh.is_symmetric();
    if !symmetric {
        for row in scratch.sym_adj.iter_mut() {
            row.clear();
        }
        if scratch.sym_adj.len() != n {
            scratch.sym_adj.truncate(n);
            scratch.sym_adj.resize_with(n, Vec::new);
        }
        for i in 0..n {
            for &j in &neigh.adj[i] {
                if !scratch.sym_adj[i].contains(&j) {
                    scratch.sym_adj[i].push(j);
                }
                if !scratch.sym_adj[j as usize].contains(&(i as u32)) {
                    scratch.sym_adj[j as usize].push(i as u32);
                }
            }
        }
    }
    let slot_adj: &[Vec<u32>] = if symmetric { &neigh.adj } else { &scratch.sym_adj };

    // CSR over the slot adjacency: net[net_offsets[i] + idx] is the
    // signed flow of the unordered pair (i, slot_adj[i][idx]), stored
    // at the smaller endpoint's row only (>0 means smaller-id sends).
    scratch.net_offsets.clear();
    scratch.net_offsets.push(0);
    for row in slot_adj {
        let last = *scratch.net_offsets.last().unwrap();
        scratch.net_offsets.push(last + row.len() as u32);
    }
    let slots = *scratch.net_offsets.last().unwrap() as usize;
    scratch.net.clear();
    scratch.net.resize(slots, 0.0);

    // own[i]: load originating at i still held at i (may be sent).
    // recv[i]: load received virtually (may NOT be forwarded).
    scratch.own.clear();
    scratch.own.extend_from_slice(loads);
    scratch.recv.clear();
    scratch.recv.resize(n, 0.0);
    let mut iterations = 0;

    for iter in 0..max_iters {
        iterations = iter + 1;
        scratch.cur.clear();
        {
            let (cur, own, recv) = (&mut scratch.cur, &scratch.own, &scratch.recv);
            cur.extend(own.iter().zip(recv).map(|(o, r)| o + r));
        }

        // Plan this sweep's sends; cap each node's total send at its
        // remaining own load (single-hop constraint).
        scratch.sends.clear();
        for i in 0..n {
            let mut want = 0.0;
            for &j in &neigh.adj[i] {
                let diff = scratch.cur[i] - scratch.cur[j as usize];
                if diff > 0.0 {
                    want += alpha * diff;
                }
            }
            if want <= 0.0 {
                continue;
            }
            let scale = if want > scratch.own[i] { scratch.own[i] / want } else { 1.0 };
            if scale <= 0.0 {
                continue;
            }
            for &j in &neigh.adj[i] {
                let diff = scratch.cur[i] - scratch.cur[j as usize];
                if diff > 0.0 {
                    let amt = alpha * diff;
                    scratch.sends.push((i as u32, j, amt * scale));
                }
            }
        }

        let mut moved = 0.0;
        {
            let (sends, own, recv, net, net_offsets) = (
                &scratch.sends,
                &mut scratch.own,
                &mut scratch.recv,
                &mut scratch.net,
                &scratch.net_offsets,
            );
            for &(i, j, amt) in sends {
                own[i as usize] -= amt;
                recv[j as usize] += amt;
                let (a, b, sign) = if i < j { (i, j, 1.0) } else { (j, i, -1.0) };
                // degree <= K: a linear scan beats any index structure
                let idx = slot_adj[a as usize]
                    .iter()
                    .position(|&x| x == b)
                    .expect("slot adjacency misses a sent-along edge");
                net[net_offsets[a as usize] as usize + idx] += sign * amt;
                moved += amt;
            }
        }

        if converged(neigh, &scratch.own, &scratch.recv, global_avg, tol)
            || moved <= tol * global_avg * 1e-3
        {
            break;
        }
    }

    // Fold signed pair flows into per-node positive send quotas. Cancel
    // opposing flows so object selection never ping-pongs objects.
    for a in 0..n {
        for (idx, &b) in slot_adj[a].iter().enumerate() {
            if (a as u32) >= b {
                continue;
            }
            let f = scratch.net[scratch.net_offsets[a] as usize + idx];
            if f > 1e-12 {
                flows[a].push((b, f));
            } else if f < -1e-12 {
                flows[b as usize].push((a as u32, -f));
            }
        }
    }
    for row in flows.iter_mut() {
        row.sort_unstable_by_key(|&(j, _)| j);
    }
    Quotas { flows, iterations }
}

/// Every neighborhood (node + its neighbors) has relative load spread
/// below `tol` (measured against the global average so empty-ish
/// neighborhoods don't divide by ~0).
fn converged(neigh: &NeighborGraph, own: &[f64], recv: &[f64], global_avg: f64, tol: f64) -> bool {
    let cur = |i: usize| own[i] + recv[i];
    for i in 0..neigh.n() {
        if neigh.adj[i].is_empty() {
            continue;
        }
        let mut lo = cur(i);
        let mut hi = cur(i);
        for &j in &neigh.adj[i] {
            lo = lo.min(cur(j as usize));
            hi = hi.max(cur(j as usize));
        }
        if (hi - lo) / global_avg > tol {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::diffusion::neighbor::NeighborGraph;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn ring(n: usize, k: usize) -> NeighborGraph {
        // symmetric ring where each node connects to k/2 hops each side
        let h = (k / 2).max(1);
        let adj = (0..n)
            .map(|i| {
                let mut a: Vec<u32> = Vec::new();
                for d in 1..=h {
                    a.push(((i + d) % n) as u32);
                    a.push(((i + n - d) % n) as u32);
                }
                a.sort_unstable();
                a.dedup();
                a
            })
            .collect();
        NeighborGraph { adj }
    }

    #[test]
    fn balances_single_hotspot_with_enough_neighbors() {
        let n = 16;
        let mut loads = vec![1.0; n];
        loads[0] = 10.0;
        let g = ring(n, 4);
        let q = virtual_balance(&g, &loads, 0.05, 500);
        let out = q.apply(&loads);
        let avg = out.iter().sum::<f64>() / n as f64;
        let max = out.iter().cloned().fold(0.0, f64::max);
        // single-hop: node 0 can only shed to its 4 neighbors, so the
        // neighborhood equalizes around (10+4)/5.
        assert!(max / avg < 2.5, "max/avg {}", max / avg);
        // conservation
        let total: f64 = out.iter().sum();
        assert!((total - loads.iter().sum::<f64>()).abs() < 1e-9);
    }

    #[test]
    fn no_neighbors_no_flows() {
        let g = NeighborGraph { adj: vec![vec![], vec![]] };
        let q = virtual_balance(&g, &[10.0, 1.0], 0.05, 100);
        assert_eq!(q.outgoing(0), 0.0);
        assert_eq!(q.apply(&[10.0, 1.0]), vec![10.0, 1.0]);
    }

    #[test]
    fn quotas_only_on_edges_and_single_hop() {
        let n = 12;
        let mut rng = Rng::new(5);
        let loads: Vec<f64> = (0..n).map(|_| rng.uniform(0.5, 8.0)).collect();
        let g = ring(n, 2);
        let q = virtual_balance(&g, &loads, 0.02, 500);
        for i in 0..n {
            for &(j, _) in &q.flows[i] {
                assert!(g.adj[i].contains(&j), "flow on non-edge {i}->{j}");
            }
            // single-hop: cannot send more than original load
            assert!(q.outgoing(i) <= loads[i] + 1e-9, "node {i} oversends");
        }
    }

    #[test]
    fn asymmetric_adjacency_is_handled_not_panicked() {
        // adj is a pub field, so callers can hand us a one-directional
        // graph; the seed's HashMap accumulator coped, and so must the
        // slot-CSR: node 1 sees node 0 as a neighbor but not vice
        // versa, so a send 1 -> 0 must land in node 0's (synthesized)
        // slot row.
        let g = NeighborGraph { adj: vec![vec![], vec![0], vec![0, 1]] };
        assert!(!g.is_symmetric());
        let loads = [1.0, 10.0, 4.0];
        let q = virtual_balance(&g, &loads, 0.05, 200);
        let out = q.apply(&loads);
        assert!((out.iter().sum::<f64>() - 15.0).abs() < 1e-9);
        assert!(q.outgoing(1) > 0.0, "overloaded node 1 must shed to 0");
    }

    #[test]
    fn flows_rows_sorted_and_queryable() {
        let n = 8;
        let mut loads = vec![1.0; n];
        loads[0] = 9.0;
        let g = ring(n, 4);
        let q = virtual_balance(&g, &loads, 0.05, 300);
        for row in &q.flows {
            assert!(row.windows(2).all(|w| w[0].0 < w[1].0), "{row:?}");
        }
        let total: f64 = (0..n).map(|i| q.outgoing(i)).sum();
        let via_flow: f64 = (0..n)
            .flat_map(|i| (0..n as u32).map(move |j| (i, j)))
            .map(|(i, j)| q.flow(i, j))
            .sum();
        assert!((total - via_flow).abs() < 1e-12);
    }

    #[test]
    fn scratch_reuse_is_identical() {
        let n = 16;
        let g = ring(n, 4);
        let mut scratch = LbScratch::default();
        let mut rng = Rng::new(17);
        for _ in 0..5 {
            let loads: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 10.0)).collect();
            let fresh = virtual_balance(&g, &loads, 0.05, 300);
            let reused = virtual_balance_with(&g, &loads, 0.05, 300, &mut scratch);
            assert_eq!(fresh, reused);
            scratch.flows_pool = reused.flows; // recycle like rebalance()
        }
    }

    #[test]
    fn conservation_property() {
        prop::check("virtual lb conserves load", 50, |g| {
            let n = g.usize_in(2, 32);
            let loads: Vec<f64> = (0..n).map(|_| g.f64_in(0.1, 20.0)).collect();
            let k = g.usize_in(2, 6);
            let graph = ring(n, k);
            let q = virtual_balance(&graph, &loads, 0.05, 300);
            let out = q.apply(&loads);
            prop::assert_that(
                out.iter().all(|&l| l >= -1e-9),
                "negative virtual load",
            )?;
            prop::assert_close(out.iter().sum::<f64>(), loads.iter().sum::<f64>(), 1e-9)
        });
    }

    #[test]
    fn imbalance_never_worsens() {
        prop::check("virtual lb does not worsen max/avg", 40, |g| {
            let n = g.usize_in(3, 24);
            let loads: Vec<f64> = (0..n).map(|_| g.f64_in(0.1, 10.0)).collect();
            let graph = ring(n, 4);
            let q = virtual_balance(&graph, &loads, 0.05, 300);
            let out = q.apply(&loads);
            let ratio = |v: &[f64]| {
                let avg = v.iter().sum::<f64>() / v.len() as f64;
                v.iter().cloned().fold(0.0, f64::max) / avg
            };
            prop::assert_that(
                ratio(&out) <= ratio(&loads) + 1e-6,
                format!("worsened {} -> {}", ratio(&loads), ratio(&out)),
            )
        });
    }
}
