//! Stage 1 — neighbor selection (paper §III-A).
//!
//! Builds the node neighbor graph along which diffusion may move load.
//! Unlike topology-driven diffusion (Lieber et al.), candidates are
//! ranked by **application communication volume** (comm variant) or by
//! **inverse centroid distance** (coordinate variant, paper §IV), and a
//! distributed handshake bounds every node's degree by K:
//!
//! 1. each node computes `l = K - confirmed` and requests its top `l/2`
//!    unconsidered candidates (integer division — faithfully to the
//!    paper, so `K = 1` sends no requests and degenerates to "no
//!    neighbors", which is exactly the behaviour Table I reports);
//! 2. a requestee rejects when `confirmed == K` or
//!    `confirmed + holds == K`, otherwise reserves a hold and accepts;
//! 3. the requester finalizes if it still has capacity (ack), otherwise
//!    cancels and the hold is released.
//!
//! The handshake here is executed round-synchronously and
//! deterministically; `simnet::protocol` runs the identical state
//! machine over real message channels and the integration tests assert
//! both produce the same pairings.

use super::scratch::LbScratch;
use crate::model::Instance;
use crate::util::pool;

/// Below this many nodes the candidate rows are filled sequentially —
/// the per-row work (one matrix-row scan + two small sorts) only
/// amortizes pool fan-out on large clusters.
const PAR_NODES_MIN: usize = 128;

/// Symmetric node neighbor graph produced by stage 1.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NeighborGraph {
    pub adj: Vec<Vec<u32>>,
}

impl NeighborGraph {
    pub fn n(&self) -> usize {
        self.adj.len()
    }

    pub fn max_degree(&self) -> usize {
        self.adj.iter().map(|a| a.len()).max().unwrap_or(0)
    }

    pub fn is_symmetric(&self) -> bool {
        self.adj.iter().enumerate().all(|(i, nbrs)| {
            nbrs.iter().all(|&j| self.adj[j as usize].contains(&(i as u32)))
        })
    }
}

/// Candidate preference lists: for every node, peers in descending
/// desirability (the order requests go out in).
pub type Candidates = Vec<Vec<u32>>;

/// Comm variant: rank peers by inter-node communication volume,
/// descending. Nodes we actually communicate with come first (that
/// prefix is what keeps the variant scalable — paper §IV note); when K
/// exceeds the communication degree, zero-communication nodes follow,
/// closest node-id first — Table I's K=8 behaviour, where "a node may
/// choose to migrate objects to a neighbor with which it has no
/// communication in an attempt to distribute load".
pub fn comm_candidates(inst: &Instance, node_map: &[u32]) -> Candidates {
    let mut scratch = LbScratch::default();
    comm_candidates_into(inst, node_map, &mut scratch);
    std::mem::take(&mut scratch.candidates)
}

/// Fill one node's preference row from its dense traffic-matrix row.
/// `peers`/`rest` are reusable per-task buffers.
fn fill_comm_row(
    i: usize,
    n_nodes: usize,
    row: &[f64],
    out: &mut Vec<u32>,
    peers: &mut Vec<(u32, f64)>,
    rest: &mut Vec<u32>,
) {
    peers.clear();
    rest.clear();
    out.clear();
    for (j, &w) in row.iter().enumerate() {
        if j == i {
            continue;
        }
        if w > 0.0 {
            peers.push((j as u32, w));
        } else {
            rest.push(j as u32);
        }
    }
    // descending volume, id tiebreak for determinism; unstable sorts
    // give the identical (total) order without the stable sort's
    // merge-buffer allocation
    peers.sort_unstable_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    rest.sort_unstable_by_key(|&j| {
        let d = (i as i64 - j as i64).unsigned_abs();
        (d.min(n_nodes as u64 - d), j)
    });
    out.extend(peers.iter().map(|&(j, _)| j));
    out.extend_from_slice(rest);
}

/// [`comm_candidates`] into `scratch.candidates`, reusing the dense
/// traffic matrix and every candidate row across LB rounds
/// (allocation-free once warm). Rows are independent — each reads one
/// matrix row and writes one output row — so on big clusters they fill
/// chunk-parallel on the global [`pool`] with per-task sort buffers;
/// the per-row result does not depend on the chunking, keeping
/// candidates bit-identical for any thread count.
pub fn comm_candidates_into(inst: &Instance, node_map: &[u32], scratch: &mut LbScratch) {
    let n_nodes = inst.topo.n_nodes;
    inst.graph.group_traffic_dense_into(node_map, n_nodes, &mut scratch.traffic);
    for row in scratch.candidates.iter_mut() {
        row.clear();
    }
    if scratch.candidates.len() != n_nodes {
        scratch.candidates.truncate(n_nodes);
        scratch.candidates.resize_with(n_nodes, Vec::new);
    }
    let n_tasks = scratch
        .par_tasks
        .unwrap_or_else(|| pool::global().threads() + 1)
        .clamp(1, n_nodes.max(1));
    if n_nodes < PAR_NODES_MIN || n_tasks == 1 {
        if scratch.stage1_bufs.is_empty() {
            scratch.stage1_bufs.push(Default::default());
        }
        let (traffic, candidates, bufs) =
            (&scratch.traffic, &mut scratch.candidates, &mut scratch.stage1_bufs);
        let (peers, rest) = &mut bufs[0];
        for (i, out) in candidates.iter_mut().enumerate() {
            fill_comm_row(i, n_nodes, &traffic[i * n_nodes..(i + 1) * n_nodes], out, peers, rest);
        }
        return;
    }
    if scratch.stage1_bufs.len() < n_tasks {
        scratch.stage1_bufs.resize_with(n_tasks, Default::default);
    }
    let chunk = n_nodes.div_ceil(n_tasks);
    let (traffic, candidates, bufs) =
        (&scratch.traffic, &mut scratch.candidates, &mut scratch.stage1_bufs);
    let traffic = &traffic[..];
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(n_tasks);
    for (t, (rows, buf)) in candidates.chunks_mut(chunk).zip(bufs.iter_mut()).enumerate() {
        let start = t * chunk;
        tasks.push(Box::new(move || {
            let (peers, rest) = buf;
            for (off, out) in rows.iter_mut().enumerate() {
                let i = start + off;
                fill_comm_row(
                    i,
                    n_nodes,
                    &traffic[i * n_nodes..(i + 1) * n_nodes],
                    out,
                    peers,
                    rest,
                );
            }
        }));
    }
    pool::global().scoped(tasks);
}

/// Space-filling-curve candidate construction for the coordinate
/// variant — the paper's §VII future-work item: instead of every node
/// sorting ALL peers by centroid distance (quadratic), nodes are
/// ordered along a Morton (Z-order) curve over their centroids and each
/// node considers a window of curve neighbors, sorted by true distance.
/// O(n log n) total, and the window preserves spatial adjacency well
/// enough that the handshake produces near-identical neighborhoods
/// (property-tested against the brute-force candidates).
pub fn coord_candidates_sfc(inst: &Instance, node_map: &[u32], window: usize) -> Candidates {
    let n_nodes = inst.topo.n_nodes;
    let centroids = centroids_of(inst, node_map, n_nodes);
    // normalize to 16-bit grid, interleave to Morton keys
    let (mut lo, mut hi) = ([f64::MAX; 2], [f64::MIN; 2]);
    for c in &centroids {
        for d in 0..2 {
            lo[d] = lo[d].min(c[d]);
            hi[d] = hi[d].max(c[d]);
        }
    }
    let scale = |v: f64, d: usize| -> u32 {
        let span = (hi[d] - lo[d]).max(1e-12);
        (((v - lo[d]) / span) * 65535.0) as u32
    };
    let mut order: Vec<(u64, u32)> = centroids
        .iter()
        .enumerate()
        .map(|(i, c)| (morton2(scale(c[0], 0), scale(c[1], 1)), i as u32))
        .collect();
    order.sort_unstable();
    let pos_of: Vec<usize> = {
        let mut pos = vec![0usize; n_nodes];
        for (rank, &(_, i)) in order.iter().enumerate() {
            pos[i as usize] = rank;
        }
        pos
    };
    (0..n_nodes)
        .map(|i| {
            let p = pos_of[i];
            let from = p.saturating_sub(window);
            let to = (p + window + 1).min(n_nodes);
            let mut peers: Vec<(u32, f64)> = order[from..to]
                .iter()
                .map(|&(_, j)| j)
                .filter(|&j| j != i as u32)
                .map(|j| {
                    let dx = centroids[i][0] - centroids[j as usize][0];
                    let dy = centroids[i][1] - centroids[j as usize][1];
                    (j, dx * dx + dy * dy)
                })
                .collect();
            peers.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
            peers.into_iter().map(|(j, _)| j).collect()
        })
        .collect()
}

/// Interleave two 16-bit values into a Morton key.
fn morton2(x: u32, y: u32) -> u64 {
    fn spread(mut v: u64) -> u64 {
        v &= 0xFFFF;
        v = (v | (v << 8)) & 0x00FF00FF;
        v = (v | (v << 4)) & 0x0F0F0F0F;
        v = (v | (v << 2)) & 0x33333333;
        v = (v | (v << 1)) & 0x55555555;
        v
    }
    spread(x as u64) | (spread(y as u64) << 1)
}

fn centroids_of(inst: &Instance, node_map: &[u32], n_nodes: usize) -> Vec<[f64; 2]> {
    let mut sums = vec![[0.0f64; 2]; n_nodes];
    let mut counts = vec![0usize; n_nodes];
    for (o, &node) in node_map.iter().enumerate() {
        sums[node as usize][0] += inst.coords[o][0];
        sums[node as usize][1] += inst.coords[o][1];
        counts[node as usize] += 1;
    }
    sums.iter()
        .zip(&counts)
        .map(|(s, &c)| {
            if c == 0 {
                [f64::MAX / 4.0, f64::MAX / 4.0]
            } else {
                [s[0] / c as f64, s[1] / c as f64]
            }
        })
        .collect()
}

/// Coordinate variant: rank ALL peers by centroid distance, ascending.
/// Quadratic in node count — reproduced as such; the paper flags this
/// as the variant's scalability limit (§IV, §VII).
pub fn coord_candidates(inst: &Instance, node_map: &[u32]) -> Candidates {
    let n_nodes = inst.topo.n_nodes;
    // node_map is a PE-level mapping's node view; recompute centroids
    // from object coords.
    let centroids = centroids_of(inst, node_map, n_nodes);
    (0..n_nodes)
        .map(|i| {
            let mut peers: Vec<(u32, f64)> = (0..n_nodes as u32)
                .filter(|&j| j != i as u32)
                .map(|j| {
                    let dx = centroids[i][0] - centroids[j as usize][0];
                    let dy = centroids[i][1] - centroids[j as usize][1];
                    (j, dx * dx + dy * dy)
                })
                .collect();
            peers.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
            peers.into_iter().map(|(j, _)| j).collect()
        })
        .collect()
}

#[derive(Debug, Clone, Default)]
struct NodeState {
    confirmed: Vec<u32>,
    holds: usize,
    cursor: usize,
    /// whether the cursor has already wrapped once (one retry sweep).
    wrapped: bool,
}

/// Run the handshake. `k` is the desired degree; `max_rounds` bounds the
/// iteration (paper step 5).
pub fn select_neighbors(candidates: &Candidates, k: usize, max_rounds: usize) -> NeighborGraph {
    let n = candidates.len();
    let mut st: Vec<NodeState> = vec![NodeState::default(); n];

    for _round in 0..max_rounds {
        // Phase A: emit requests. l/2 with integer division, per paper.
        let mut requests: Vec<(u32, u32)> = Vec::new(); // (from, to)
        for i in 0..n {
            let confirmed = st[i].confirmed.len();
            if confirmed >= k {
                continue;
            }
            let l = k - confirmed;
            // Integer division, per the paper. A node that already holds
            // some neighbors but is stuck at l = 1 (so l/2 = 0) would
            // stall forever; let it send a single request — still within
            // the paper's "prevent unnecessarily many requests" intent.
            // A node with NO progress and l = 1 (i.e. K = 1) stays
            // faithful to the l/2 rule and sends nothing (Table I).
            let want = if l / 2 == 0 && confirmed > 0 { 1 } else { l / 2 };
            let mut sent: Vec<u32> = Vec::new();
            while sent.len() < want {
                let cand = loop {
                    if st[i].cursor >= candidates[i].len() {
                        if st[i].wrapped || candidates[i].is_empty() {
                            break None;
                        }
                        st[i].wrapped = true;
                        st[i].cursor = 0;
                        continue;
                    }
                    let c = candidates[i][st[i].cursor];
                    st[i].cursor += 1;
                    // never the same peer twice in one round (a wrap can
                    // revisit the cursor position)
                    if !st[i].confirmed.contains(&c) && !sent.contains(&c) {
                        break Some(c);
                    }
                };
                match cand {
                    Some(c) => {
                        requests.push((i as u32, c));
                        sent.push(c);
                    }
                    None => break,
                }
            }
        }
        if requests.is_empty() {
            break;
        }

        // Phase B: responses. Deterministic order by (to, from) — the
        // message-arrival order of the round-synchronous network.
        requests.sort_by_key(|&(from, to)| (to, from));
        let mut accepts: Vec<(u32, u32)> = Vec::new(); // (responder, requester)
        let mut held_for: Vec<Vec<u32>> = vec![Vec::new(); n];
        for &(from, to) in &requests {
            let s = &mut st[to as usize];
            let full = s.confirmed.len() >= k || s.confirmed.len() + s.holds >= k;
            if full || s.confirmed.contains(&from) {
                continue; // reject
            }
            s.holds += 1;
            held_for[to as usize].push(from);
            accepts.push((to, from));
        }

        // Phase C-1: requester decisions. Each requester evaluates with
        // its own holds as they stood after phase B (paper step 4: "its
        // neighbor count and holds have not exceeded K in the meantime")
        // — matching the truly concurrent execution, where acks have not
        // been exchanged yet (simnet::protocol mirrors this exactly).
        let holds_b: Vec<usize> = st.iter().map(|s| s.holds).collect();
        accepts.sort_by_key(|&(resp, req)| (req, resp));
        let mut acks: Vec<(u32, u32, bool)> = Vec::new();
        for &(resp, req) in &accepts {
            // a hold we issued to `resp` itself is the same prospective
            // pairing, so it does not count against our capacity —
            // without this, mutual requests livelock at the boundary
            let same_pair = usize::from(held_for[req as usize].contains(&resp));
            let s = &mut st[req as usize];
            let confirm = s.confirmed.len() + holds_b[req as usize] - same_pair < k
                && !s.confirmed.contains(&resp);
            if confirm {
                s.confirmed.push(resp);
            }
            acks.push((resp, req, confirm));
        }
        // Phase C-2: responders process acks; a hold is released either
        // way and converts into a confirmed slot on confirm.
        acks.sort_by_key(|&(resp, req, _)| (resp, req));
        for &(resp, req, confirm) in &acks {
            let s = &mut st[resp as usize];
            s.holds -= 1;
            if confirm && s.confirmed.len() < k && !s.confirmed.contains(&req) {
                s.confirmed.push(req);
            }
        }

        if st.iter().all(|s| s.confirmed.len() >= k) {
            break;
        }
    }

    let mut adj: Vec<Vec<u32>> = st.into_iter().map(|s| s.confirmed).collect();
    for a in &mut adj {
        a.sort_unstable();
    }
    NeighborGraph { adj }
}

/// Convenience: candidates + handshake for the given variant inputs.
pub fn build(candidates: &Candidates, k: usize, max_rounds: usize) -> NeighborGraph {
    select_neighbors(candidates, k, max_rounds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    /// Ring candidates: node i prefers i-1, i+1 (wrapping), then the
    /// rest by distance.
    fn ring_candidates(n: usize) -> Candidates {
        (0..n)
            .map(|i| {
                let mut peers: Vec<(u32, usize)> = (0..n)
                    .filter(|&j| j != i)
                    .map(|j| {
                        let d = (i as isize - j as isize).unsigned_abs();
                        (j as u32, d.min(n - d))
                    })
                    .collect();
                peers.sort_by_key(|&(j, d)| (d, j));
                peers.into_iter().map(|(j, _)| j).collect()
            })
            .collect()
    }

    #[test]
    fn k1_sends_no_requests_per_paper() {
        // l/2 = 0 with integer division: K=1 degenerates to no pairings
        // (the behaviour behind Table I's 4.9 max/avg at K=1).
        let g = select_neighbors(&ring_candidates(8), 1, 32);
        assert!(g.adj.iter().all(|a| a.is_empty()));
    }

    #[test]
    fn k2_ring_pairs_up_symmetric() {
        let g = select_neighbors(&ring_candidates(8), 2, 32);
        assert!(g.is_symmetric());
        assert!(g.max_degree() <= 2);
        // every node should reach full degree on a ring with K=2
        assert!(g.adj.iter().all(|a| a.len() == 2), "{:?}", g.adj);
    }

    #[test]
    fn degree_never_exceeds_k() {
        for k in [2, 3, 4, 8] {
            let g = select_neighbors(&ring_candidates(16), k, 64);
            assert!(g.max_degree() <= k, "k={k} got {}", g.max_degree());
            assert!(g.is_symmetric());
        }
    }

    #[test]
    fn fewer_candidates_than_k() {
        // 3 nodes, K=8: degree capped by available peers.
        let g = select_neighbors(&ring_candidates(3), 8, 64);
        assert!(g.is_symmetric());
        assert!(g.max_degree() <= 2);
    }

    #[test]
    fn handshake_properties_random_candidates() {
        prop::check("handshake degree/symmetry", 60, |g| {
            let n = g.usize_in(2, 24);
            let k = g.usize_in(2, 8);
            // random preference lists
            let mut cands: Candidates = Vec::new();
            for i in 0..n {
                let mut peers: Vec<u32> =
                    (0..n as u32).filter(|&j| j != i as u32).collect();
                g.rng.shuffle(&mut peers);
                cands.push(peers);
            }
            let graph = select_neighbors(&cands, k, 64);
            prop::assert_that(graph.is_symmetric(), "not symmetric")?;
            prop::assert_that(graph.max_degree() <= k, format!("degree > {k}"))?;
            prop::assert_that(
                graph.adj.iter().all(|a| {
                    let mut s = a.clone();
                    s.dedup();
                    s.len() == a.len()
                }),
                "duplicate neighbor",
            )
        });
    }
}

#[cfg(test)]
mod sfc_tests {
    use super::*;
    use crate::strategies::diffusion::tests::stencil_instance;
    use crate::util::prop;

    #[test]
    fn morton_keys_preserve_quadrants() {
        // points in the same quadrant get closer keys than across
        assert!(morton2(0, 0) < morton2(65535, 65535));
        assert!(morton2(100, 100).abs_diff(morton2(101, 101)) < morton2(100, 100).abs_diff(morton2(60000, 60000)));
    }

    #[test]
    fn sfc_candidates_are_spatially_local() {
        let inst = stencil_instance(32, 4, 4, 0.0, 1);
        let node_map = inst.node_mapping();
        let brute = coord_candidates(&inst, &node_map);
        let sfc = coord_candidates_sfc(&inst, &node_map, 6);
        // the SFC front-of-list should overlap the brute-force
        // front-of-list heavily (same spatial neighbors)
        for i in 0..16 {
            let b: std::collections::BTreeSet<u32> = brute[i].iter().take(4).cloned().collect();
            let s: std::collections::BTreeSet<u32> = sfc[i].iter().take(4).cloned().collect();
            let overlap = b.intersection(&s).count();
            assert!(overlap >= 2, "node {i}: brute {b:?} vs sfc {s:?}");
        }
    }

    #[test]
    fn sfc_handshake_quality_close_to_brute_force() {
        prop::check("sfc vs brute handshake", 10, |g| {
            let side = 16 + 8 * g.usize_in(0, 2);
            let inst = stencil_instance(side, 4, 4, 0.4, g.seed);
            let node_map = inst.node_mapping();
            let brute = select_neighbors(&coord_candidates(&inst, &node_map), 4, 32);
            let sfc = select_neighbors(&coord_candidates_sfc(&inst, &node_map, 8), 4, 32);
            prop::assert_that(sfc.is_symmetric(), "sfc not symmetric")?;
            prop::assert_that(sfc.max_degree() <= 4, "sfc degree > K")?;
            // within the window the average degree should be comparable
            let deg = |g: &NeighborGraph| {
                g.adj.iter().map(|a| a.len()).sum::<usize>() as f64 / g.n() as f64
            };
            prop::assert_that(
                deg(&sfc) + 1.0 >= deg(&brute) - 1.0,
                format!("sfc degree {} far below brute {}", deg(&sfc), deg(&brute)),
            )
        });
    }
}
