//! Stage 3 — object selection (paper §III-C).
//!
//! Converts the virtual per-edge quotas into concrete object
//! migrations while preserving communication locality:
//!
//! * **Comm variant:** for neighbor `n`, objects leave in decreasing
//!   order of bytes communicated *with n*; whenever an object migrates,
//!   the communication picture of every object that talks to it is
//!   updated (its edges now point at the new node), so later picks see
//!   the evolving locality — this is what lets a node sanely migrate
//!   "more objects than initially communicated with a given neighbor".
//! * **Coord variant (paper §IV):** objects leave in increasing distance
//!   to the neighbor's centroid, and both centroids are updated as
//!   objects move.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap};

use super::virtual_lb::Quotas;
use crate::model::Instance;

/// Max-heap entry with f64 priority (BinaryHeap needs Ord).
#[derive(Debug, Clone, Copy)]
struct Entry {
    /// primary: larger first
    key: f64,
    /// secondary: smaller first
    tie: f64,
    obj: u32,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.key
            .partial_cmp(&other.key)
            .unwrap_or(Ordering::Equal)
            .then(other.tie.partial_cmp(&self.tie).unwrap_or(Ordering::Equal))
            .then(other.obj.cmp(&self.obj))
    }
}

/// Per-node neighbor quotas sorted descending (largest transfer first).
/// Residual quotas below 1% of the average node load are noise from the
/// fixed-point tolerance and are dropped — realizing them would migrate
/// an object per neighbor pair for no balance benefit.
fn sorted_quota(quotas: &Quotas, i: usize, floor: f64) -> Vec<(u32, f64)> {
    let mut q: Vec<(u32, f64)> =
        quotas.flows[i].iter().filter(|(_, &a)| a >= floor).map(|(&j, &a)| (j, a)).collect();
    q.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    q
}

/// Quota noise floor for an instance: 1% of the average node load.
fn quota_floor(inst: &Instance) -> f64 {
    0.01 * inst.loads.iter().sum::<f64>() / inst.topo.n_nodes.max(1) as f64
}

/// Should `o` (with `load`) migrate against `remaining` quota?
/// Allows overshooting the quota by up to `overfill * load` so a quota
/// slightly smaller than every object still moves something.
#[inline]
fn fits(load: f64, remaining: f64, overfill: f64) -> bool {
    remaining > 0.0 && load * (1.0 - overfill) <= remaining
}

/// Comm-variant selection. Mutates `node_map` (object -> node) in place
/// and returns the number of migrations performed.
pub fn select_comm(
    inst: &Instance,
    node_map: &mut [u32],
    quotas: &Quotas,
    overfill: f64,
) -> usize {
    let n_nodes = inst.topo.n_nodes;
    let floor = quota_floor(inst);
    let mut moved = vec![false; inst.n_objects()];
    let mut migrations = 0;
    // objects-by-node index built once (perf: avoids an O(n_objects)
    // scan per (node, neighbor) pair — see EXPERIMENTS.md §Perf)
    let mut by_node: Vec<Vec<u32>> = vec![Vec::new(); n_nodes];
    for (o, &nm) in node_map.iter().enumerate() {
        by_node[nm as usize].push(o as u32);
    }

    for i in 0..n_nodes {
        let targets = sorted_quota(quotas, i, floor);
        if targets.is_empty() {
            continue;
        }
        // Pool of objects currently on node i (excluding arrivals from
        // earlier nodes this round — single-hop at object granularity).
        let pool: Vec<u32> = by_node[i]
            .iter()
            .cloned()
            .filter(|&o| node_map[o as usize] == i as u32 && !moved[o as usize])
            .collect();

        for (j, quota) in targets {
            let mut remaining = quota;
            // bytes each pooled object exchanges with node j right now
            let mut bytes_to_j: HashMap<u32, f64> = HashMap::with_capacity(pool.len());
            let mut heap = BinaryHeap::with_capacity(pool.len());
            for &o in &pool {
                if moved[o as usize] || node_map[o as usize] != i as u32 {
                    continue;
                }
                let mut bj = 0.0;
                let mut local = 0.0;
                for (&p, &w) in inst
                    .graph
                    .neighbors(o as usize)
                    .iter()
                    .zip(inst.graph.weights(o as usize))
                {
                    let pn = node_map[p as usize];
                    if pn == j {
                        bj += w;
                    } else if pn == i as u32 {
                        local += w;
                    }
                }
                bytes_to_j.insert(o, bj);
                heap.push(Entry { key: bj, tie: local, obj: o });
            }

            while remaining > 1e-12 {
                let Some(top) = heap.pop() else { break };
                let o = top.obj;
                if moved[o as usize] || node_map[o as usize] != i as u32 {
                    continue;
                }
                // lazy key revalidation: migrations of earlier objects
                // may have raised this object's bytes-to-j.
                let cur = bytes_to_j[&o];
                if (cur - top.key).abs() > 1e-9 {
                    heap.push(Entry { key: cur, ..top });
                    continue;
                }
                let load = inst.loads[o as usize];
                if !fits(load, remaining, overfill) {
                    continue; // skip; a lighter object may still fit
                }
                // Migrate o: i -> j.
                node_map[o as usize] = j;
                moved[o as usize] = true;
                migrations += 1;
                remaining -= load;
                // Constraint 2: peers of o now communicate with node j.
                for (&p, &w) in inst
                    .graph
                    .neighbors(o as usize)
                    .iter()
                    .zip(inst.graph.weights(o as usize))
                {
                    if node_map[p as usize] == i as u32 && !moved[p as usize] {
                        if let Some(b) = bytes_to_j.get_mut(&p) {
                            *b += w;
                            heap.push(Entry { key: *b, tie: 0.0, obj: p });
                        }
                    }
                }
            }
        }
    }
    migrations
}

/// Coord-variant selection: distance to the target node's centroid,
/// centroids updated incrementally as objects move.
pub fn select_coord(
    inst: &Instance,
    node_map: &mut [u32],
    quotas: &Quotas,
    overfill: f64,
) -> usize {
    let n_nodes = inst.topo.n_nodes;
    // centroid state: sums + counts per node
    let mut sums = vec![[0.0f64; 2]; n_nodes];
    let mut counts = vec![0usize; n_nodes];
    for (o, &node) in node_map.iter().enumerate() {
        sums[node as usize][0] += inst.coords[o][0];
        sums[node as usize][1] += inst.coords[o][1];
        counts[node as usize] += 1;
    }
    let centroid = |sums: &Vec<[f64; 2]>, counts: &Vec<usize>, n: usize| -> [f64; 2] {
        if counts[n] == 0 {
            [0.0, 0.0]
        } else {
            [sums[n][0] / counts[n] as f64, sums[n][1] / counts[n] as f64]
        }
    };
    let dist2 = |a: [f64; 2], b: [f64; 2]| {
        let dx = a[0] - b[0];
        let dy = a[1] - b[1];
        dx * dx + dy * dy
    };

    let floor = quota_floor(inst);
    let mut moved = vec![false; inst.n_objects()];
    let mut migrations = 0;
    let mut by_node: Vec<Vec<u32>> = vec![Vec::new(); n_nodes];
    for (o, &nm) in node_map.iter().enumerate() {
        by_node[nm as usize].push(o as u32);
    }

    for i in 0..n_nodes {
        let targets = sorted_quota(quotas, i, floor);
        if targets.is_empty() {
            continue;
        }
        let pool: Vec<u32> = by_node[i]
            .iter()
            .cloned()
            .filter(|&o| node_map[o as usize] == i as u32 && !moved[o as usize])
            .collect();

        for (j, quota) in targets {
            let mut remaining = quota;
            let mut heap = BinaryHeap::with_capacity(pool.len());
            let cj = centroid(&sums, &counts, j as usize);
            for &o in &pool {
                if moved[o as usize] || node_map[o as usize] != i as u32 {
                    continue;
                }
                // max-heap: closer = higher priority = larger key
                heap.push(Entry { key: -dist2(inst.coords[o as usize], cj), tie: 0.0, obj: o });
            }
            // bounded revalidation so a drifting centroid cannot loop us
            let mut revalidations = 4 * pool.len() + 16;
            while remaining > 1e-12 {
                let Some(top) = heap.pop() else { break };
                let o = top.obj;
                if moved[o as usize] || node_map[o as usize] != i as u32 {
                    continue;
                }
                let cj = centroid(&sums, &counts, j as usize);
                let cur = -dist2(inst.coords[o as usize], cj);
                if revalidations > 0 && (cur - top.key).abs() > 1e-9 {
                    revalidations -= 1;
                    heap.push(Entry { key: cur, ..top });
                    continue;
                }
                let load = inst.loads[o as usize];
                if !fits(load, remaining, overfill) {
                    continue;
                }
                node_map[o as usize] = j;
                moved[o as usize] = true;
                migrations += 1;
                remaining -= load;
                let c = inst.coords[o as usize];
                sums[i][0] -= c[0];
                sums[i][1] -= c[1];
                counts[i] -= 1;
                sums[j as usize][0] += c[0];
                sums[j as usize][1] += c[1];
                counts[j as usize] += 1;
            }
        }
    }
    migrations
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{CommGraph, Instance, Topology};
    use crate::strategies::diffusion::virtual_lb::Quotas;

    /// 8 objects: 0-3 on node 0 (chain), 4-7 on node 1 (chain), with a
    /// bridge edge 3-4. Unit loads.
    fn two_node_instance() -> Instance {
        let edges = vec![
            (0, 1, 10.0),
            (1, 2, 10.0),
            (2, 3, 10.0),
            (3, 4, 50.0), // bridge: object 3 talks a lot to node 1
            (4, 5, 10.0),
            (5, 6, 10.0),
            (6, 7, 10.0),
        ];
        let graph = CommGraph::from_edges(8, &edges);
        let coords: Vec<[f64; 2]> = (0..8).map(|i| [i as f64, 0.0]).collect();
        Instance::new(
            vec![1.0; 8],
            coords,
            graph,
            vec![0, 0, 0, 0, 1, 1, 1, 1],
            Topology::flat(2),
        )
    }

    fn quota_0_to_1(amount: f64) -> Quotas {
        let mut q = Quotas::empty(2);
        q.flows[0].insert(1, amount);
        q
    }

    #[test]
    fn comm_picks_highest_bytes_first() {
        let inst = two_node_instance();
        let mut map = inst.node_mapping();
        let n = select_comm(&inst, &mut map, &quota_0_to_1(1.0), 0.5);
        assert_eq!(n, 1);
        // object 3 has 50 bytes to node 1 — must be chosen first.
        assert_eq!(map[3], 1);
    }

    #[test]
    fn comm_updates_patterns_after_each_pick() {
        let inst = two_node_instance();
        let mut map = inst.node_mapping();
        let n = select_comm(&inst, &mut map, &quota_0_to_1(2.0), 0.5);
        assert_eq!(n, 2);
        // after 3 moves, object 2 (edge 2-3 = 10 bytes) becomes the top
        // candidate even though it initially had 0 bytes to node 1.
        assert_eq!(map[3], 1);
        assert_eq!(map[2], 1);
        assert_eq!(map[1], 0);
    }

    #[test]
    fn quota_respected_with_overfill() {
        let inst = two_node_instance();
        let mut map = inst.node_mapping();
        // quota 2.5 with overfill 0.5: loads are 1.0, so up to 3 objects
        // (2 full + one at remaining 0.5 >= load*0.5).
        let n = select_comm(&inst, &mut map, &quota_0_to_1(2.5), 0.5);
        assert_eq!(n, 3);
        // zero overfill: exactly 2
        let mut map2 = inst.node_mapping();
        let n2 = select_comm(&inst, &mut map2, &quota_0_to_1(2.5), 0.0);
        assert_eq!(n2, 2);
    }

    #[test]
    fn migrations_only_along_quota_edges() {
        let inst = two_node_instance();
        let mut map = inst.node_mapping();
        select_comm(&inst, &mut map, &quota_0_to_1(3.0), 0.5);
        for (o, &nm) in map.iter().enumerate() {
            let orig = inst.node_mapping()[o];
            assert!(nm == orig || (orig == 0 && nm == 1), "obj {o} moved {orig}->{nm}");
        }
    }

    #[test]
    fn coord_picks_closest_to_target_centroid() {
        let inst = two_node_instance();
        let mut map = inst.node_mapping();
        let n = select_coord(&inst, &mut map, &quota_0_to_1(1.0), 0.5);
        assert_eq!(n, 1);
        // node 1 centroid is at x=5.5; object 3 (x=3) is node 0's closest
        assert_eq!(map[3], 1);
        assert_eq!(map[0], 0);
    }

    #[test]
    fn coord_moves_boundary_objects_in_order() {
        let inst = two_node_instance();
        let mut map = inst.node_mapping();
        let n = select_coord(&inst, &mut map, &quota_0_to_1(3.0), 0.5);
        assert_eq!(n, 3);
        assert_eq!(&map[..8], &[0, 1, 1, 1, 1, 1, 1, 1]);
    }

    #[test]
    fn zero_quota_moves_nothing() {
        let inst = two_node_instance();
        let mut map = inst.node_mapping();
        assert_eq!(select_comm(&inst, &mut map, &Quotas::empty(2), 0.5), 0);
        assert_eq!(select_coord(&inst, &mut map, &Quotas::empty(2), 0.5), 0);
        assert_eq!(map, inst.node_mapping());
    }
}
