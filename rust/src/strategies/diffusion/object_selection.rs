//! Stage 3 — object selection (paper §III-C).
//!
//! Converts the virtual per-edge quotas into concrete object
//! migrations while preserving communication locality:
//!
//! * **Comm variant:** for neighbor `n`, objects leave in decreasing
//!   order of bytes communicated *with n*; whenever an object migrates,
//!   the communication picture of every object that talks to it is
//!   updated (its edges now point at the new node), so later picks see
//!   the evolving locality — this is what lets a node sanely migrate
//!   "more objects than initially communicated with a given neighbor".
//! * **Coord variant (paper §IV):** objects leave in increasing distance
//!   to the neighbor's centroid, and both centroids are updated as
//!   objects move.
//!
//! Layering: [`select_comm_node`] / [`select_coord_node`] are the
//! **per-node** decision bodies. The sequential entry points
//! ([`select_comm_with`], [`select_coord_with`]) run them node by node
//! in rank order; `crate::distributed`'s stage-3 protocol runs the
//! *same* body on each simulated node against its manifest-synchronized
//! replica of the object→node map, which is what makes the distributed
//! pipeline's picks bit-identical to the sequential strategy's.
//!
//! Perf architecture: the seed built a `HashMap<u32, f64>` and a fresh
//! `BinaryHeap` per (node, neighbor) pair. Both now live in
//! [`LbScratch`]: the map became the dense `bytes_to_j` array guarded
//! by epoch tags (validity = `epoch[o] == cur_epoch`, so "clearing" is
//! one counter bump), and the heap's backing `Vec` is recycled across
//! phases. Per-phase candidate scoring is read-only over the graph and
//! chunk-parallel on the [`crate::util::pool`] when the pool of objects
//! is large; scores land in per-position slots and are pushed into the
//! heap in pool order, so heap evolution — and therefore every strategy
//! decision — is bit-identical to the sequential seed for any thread
//! count (`rust/tests/perf_refactor.rs`). Candidate pools walk the
//! scratch's sorted-by-node SoA slices (contiguous, ascending object
//! id — see [`LbScratch::build_soa`]) and the comm kernel's neighbor
//! walk accumulates branchlessly via `w * mask` adds, which keeps the
//! hot loops autovectorizable without reassociating a single f64 sum
//! (`rust/tests/simd_soa_identity.rs` pins both against frozen scalar
//! copies).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::scratch::LbScratch;
use super::virtual_lb::Quotas;
use crate::model::Instance;
use crate::util::pool;

/// Below this many pooled objects a phase scores sequentially — the
/// pool fan-out costs ~µs, which only pays off on big nodes.
const PAR_SCORE_MIN: usize = 4096;

/// Max-heap entry with f64 priority (BinaryHeap needs Ord).
#[doc(hidden)]
#[derive(Debug, Clone, Copy)]
pub struct Entry {
    /// primary: larger first
    key: f64,
    /// secondary: smaller first
    tie: f64,
    obj: u32,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // total_cmp: a NaN key (e.g. a 0/0 byte ratio upstream) must
        // not silently corrupt heap ordering the way the old
        // `partial_cmp(..).unwrap_or(Equal)` did — NaNs now sort below
        // every real key and the heap invariant survives.
        self.key
            .total_cmp(&other.key)
            .then(other.tie.total_cmp(&self.tie))
            .then(other.obj.cmp(&self.obj))
    }
}

/// One node's neighbor quota row sorted descending (largest transfer
/// first) into a reused buffer. Residual quotas below 1% of the average
/// node load are noise from the fixed-point tolerance and are dropped —
/// realizing them would migrate an object per neighbor pair for no
/// balance benefit.
fn sorted_quota_into(row: &[(u32, f64)], floor: f64, out: &mut Vec<(u32, f64)>) {
    out.clear();
    out.extend(row.iter().filter(|&&(_, a)| a >= floor).copied());
    // unstable: the id tiebreak makes the order total, and unlike the
    // stable sort it allocates no merge buffer
    out.sort_unstable_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
}

/// Quota noise floor for an instance: 1% of the average node load —
/// or, on heterogeneous topologies, 1% of the average per-node
/// normalized *time* (quotas are in time units there, see stage 2).
/// Public because every node of the distributed stage-3 protocol
/// evaluates the identical expression locally; the summation orders
/// (objects left-to-right, then nodes left-to-right) are fixed so the
/// scalar is bit-reproducible wherever it is recomputed. The
/// heterogeneous branch rescans the instance (two small allocations) —
/// deliberate: this runs once per LB round per caller, not in any
/// per-object loop, and recomputing from the instance alone is what
/// lets every distributed node evaluate it without shared scratch.
pub fn quota_floor(inst: &Instance) -> f64 {
    if inst.topo.is_uniform() {
        0.01 * inst.loads.iter().sum::<f64>() / inst.topo.n_nodes.max(1) as f64
    } else {
        let total_time: f64 = inst.node_times(&inst.mapping).iter().sum();
        0.01 * total_time / inst.topo.n_nodes.max(1) as f64
    }
}

/// Effective stage-3 cost of migrating one object off node `i`: the
/// time it frees at the sender (`load / capacity(i)`), or the raw load
/// on uniform topologies — matching the units stage 2's quotas are in.
#[inline]
fn eff_load(inst: &Instance, i: usize, load: f64) -> f64 {
    if inst.topo.is_uniform() {
        load
    } else {
        load / inst.topo.node_capacity(i as u32)
    }
}

/// Should `o` (with `load`) migrate against `remaining` quota?
/// Allows overshooting the quota by up to `overfill * load` so a quota
/// slightly smaller than every object still moves something.
#[inline]
fn fits(load: f64, remaining: f64, overfill: f64) -> bool {
    remaining > 0.0 && load * (1.0 - overfill) <= remaining
}

/// Comm-variant selection. Mutates `node_map` (object -> node) in place
/// and returns the number of migrations performed.
pub fn select_comm(
    inst: &Instance,
    node_map: &mut [u32],
    quotas: &Quotas,
    overfill: f64,
) -> usize {
    let mut scratch = LbScratch::default();
    select_comm_with(inst, node_map, quotas, overfill, &mut scratch)
}

/// [`select_comm`] against a caller-owned [`LbScratch`] — the zero-
/// allocation path `Diffusion::rebalance` uses.
pub fn select_comm_with(
    inst: &Instance,
    node_map: &mut [u32],
    quotas: &Quotas,
    overfill: f64,
    scratch: &mut LbScratch,
) -> usize {
    let n_nodes = inst.topo.n_nodes;
    let floor = quota_floor(inst);
    scratch.moved.clear();
    scratch.moved.resize(inst.n_objects(), false);
    scratch.build_soa(inst, node_map, n_nodes);
    let mut migrations = 0;
    for i in 0..n_nodes {
        migrations +=
            select_comm_node(inst, node_map, i, &quotas.flows[i], floor, overfill, scratch, None);
    }
    migrations
}

/// Comm-variant picks for **one** node `i` against its quota row —
/// the per-node body shared by the sequential sweep above and the
/// distributed stage-3 protocol. Contract: `scratch.moved` and the
/// SoA index (`scratch.build_soa`) must already reflect every migration
/// performed earlier this LB round (by lower-ranked nodes), exactly as
/// the sequential loop maintains them; `floor` comes from [`quota_floor`].
/// Each pick mutates `node_map` / `scratch.moved` and, when `manifest`
/// is given, appends `(object, destination node)` in pick order — the
/// migration manifest the protocol ships to receivers.
#[allow(clippy::too_many_arguments)]
pub fn select_comm_node(
    inst: &Instance,
    node_map: &mut [u32],
    i: usize,
    quota_row: &[(u32, f64)],
    floor: f64,
    overfill: f64,
    scratch: &mut LbScratch,
    mut manifest: Option<&mut Vec<(u32, u32)>>,
) -> usize {
    let n_objects = inst.n_objects();
    let mut migrations = 0;
    // Recycle the heap's backing storage (BinaryHeap::from on the empty
    // Vec is free and keeps capacity).
    let mut heap: BinaryHeap<Entry> = BinaryHeap::from(std::mem::take(&mut scratch.heap));
    // take/put buffers so loops below can borrow scratch freely
    let mut targets = std::mem::take(&mut scratch.targets);
    sorted_quota_into(quota_row, floor, &mut targets);
    if targets.is_empty() {
        scratch.targets = targets;
        scratch.heap = heap.into_vec();
        return 0;
    }
    // Pool of objects currently on node i (excluding arrivals from
    // earlier nodes this round — single-hop at object granularity).
    // The SoA slice holds node i's objects contiguously in ascending
    // id order — the same order the seed's by-node rows produced.
    scratch.pool.clear();
    {
        let slots = scratch.soa_node(i);
        let (pool_buf, objs, moved) =
            (&mut scratch.pool, &scratch.soa_objs[slots], &scratch.moved);
        pool_buf.extend(
            objs.iter()
                .copied()
                .filter(|&o| node_map[o as usize] == i as u32 && !moved[o as usize]),
        );
    }

    for &(j, quota) in &targets {
        let mut remaining = quota;
        let ep = scratch.next_epoch(n_objects);
        score_pool_comm(inst, node_map, i as u32, j, scratch);
        heap.clear();
        let (pool_buf, scores) =
            (std::mem::take(&mut scratch.pool), std::mem::take(&mut scratch.scores));
        for (p, &o) in pool_buf.iter().enumerate() {
            let (bj, local, valid) = scores[p];
            if !valid {
                continue;
            }
            scratch.bytes_to_j[o as usize] = bj;
            scratch.epoch[o as usize] = ep;
            heap.push(Entry { key: bj, tie: local, obj: o });
        }
        scratch.pool = pool_buf;
        scratch.scores = scores;

        while remaining > 1e-12 {
            let Some(top) = heap.pop() else { break };
            let o = top.obj;
            if scratch.moved[o as usize] || node_map[o as usize] != i as u32 {
                continue;
            }
            // lazy key revalidation: migrations of earlier objects
            // may have raised this object's bytes-to-j.
            let cur = scratch.bytes_to_j[o as usize];
            if (cur - top.key).abs() > 1e-9 {
                heap.push(Entry { key: cur, ..top });
                continue;
            }
            let load = eff_load(inst, i, inst.loads[o as usize]);
            if !fits(load, remaining, overfill) {
                continue; // skip; a lighter object may still fit
            }
            // Migrate o: i -> j.
            node_map[o as usize] = j;
            scratch.moved[o as usize] = true;
            migrations += 1;
            remaining -= load;
            if let Some(m) = manifest.as_mut() {
                m.push((o, j));
            }
            // Constraint 2: peers of o now communicate with node j.
            for (&p, &w) in inst
                .graph
                .neighbors(o as usize)
                .iter()
                .zip(inst.graph.weights(o as usize))
            {
                if node_map[p as usize] == i as u32
                    && !scratch.moved[p as usize]
                    && scratch.epoch[p as usize] == ep
                {
                    scratch.bytes_to_j[p as usize] += w;
                    heap.push(Entry {
                        key: scratch.bytes_to_j[p as usize],
                        tie: 0.0,
                        obj: p,
                    });
                }
            }
        }
    }
    scratch.targets = targets;
    heap.clear();
    scratch.heap = heap.into_vec();
    migrations
}

/// Chunk-parallel pool-scoring scaffold shared by the comm and coord
/// kernels: evaluate `score_one` for every pool position into `scores`.
/// Chunk boundaries depend only on `(pool length, n_tasks)` and each
/// slot is written by exactly one task, so the buffer's contents are
/// identical for any thread count.
fn score_pool_with(
    pool_buf: &[u32],
    scores: &mut Vec<(f64, f64, bool)>,
    n_tasks: usize,
    score_one: &(dyn Fn(usize) -> Option<(f64, f64)> + Sync),
) {
    let n = pool_buf.len();
    scores.clear();
    scores.resize(n, (0.0, 0.0, false));
    if n < PAR_SCORE_MIN || n_tasks == 1 {
        for (p, slot) in scores.iter_mut().enumerate() {
            if let Some((key, tie)) = score_one(pool_buf[p] as usize) {
                *slot = (key, tie, true);
            }
        }
        return;
    }
    let chunk = n.div_ceil(n_tasks);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(n_tasks);
    for (t, sc) in scores.chunks_mut(chunk).enumerate() {
        let start = t * chunk;
        tasks.push(Box::new(move || {
            for (off, slot) in sc.iter_mut().enumerate() {
                if let Some((key, tie)) = score_one(pool_buf[start + off] as usize) {
                    *slot = (key, tie, true);
                }
            }
        }));
    }
    pool::global().scoped(tasks);
}

/// Score every pooled object's `(bytes to j, bytes kept local)` into
/// `scratch.scores` (per pool position). Pure reads over the graph and
/// `node_map`; chunk-parallel on the global pool for large pools. The
/// per-object neighbor walk is sequential either way, so each slot's
/// f64 sums are identical for any chunking.
///
/// The walk accumulates **branchlessly**: every neighbor contributes
/// `w * mask` with `mask ∈ {0.0, 1.0}`, which keeps the loop body
/// straight-line (autovectorizable — the branchy form stalled on the
/// unpredictable `pn == j` test). Adding `+0.0` leaves an f64
/// accumulator bitwise unchanged (graph weights are non-negative byte
/// counts, so neither sum can hold `-0.0`), and the left-to-right CSR
/// row order is untouched — bit-identical to the branchy seed kernel
/// for every input (`tools/crosscheck_simd.py` cross-simulates this;
/// `rust/tests/simd_soa_identity.rs` locks it against a frozen copy).
fn score_pool_comm(
    inst: &Instance,
    node_map: &[u32],
    i: u32,
    j: u32,
    scratch: &mut LbScratch,
) {
    let n_tasks = scratch
        .par_tasks
        .unwrap_or_else(|| pool::global().threads() + 1)
        .max(1);
    let (pool_buf, scores, moved) = (&scratch.pool, &mut scratch.scores, &scratch.moved);
    let score_one = |o: usize| -> Option<(f64, f64)> {
        if moved[o] || node_map[o] != i {
            return None;
        }
        let nb = inst.graph.neighbors(o);
        let wt = inst.graph.weights(o);
        let mut bj = 0.0;
        let mut local = 0.0;
        for (&p, &w) in nb.iter().zip(wt) {
            let pn = node_map[p as usize];
            bj += w * ((pn == j) as u32 as f64);
            local += w * ((pn == i) as u32 as f64);
        }
        Some((bj, local))
    };
    score_pool_with(pool_buf, scores, n_tasks, &score_one);
}

/// Coord-variant pool scoring: `-dist2` to the target centroid per
/// pool position (max-heap keys — closer is larger). Elementwise over
/// the pool, so the same chunk-parallel scaffold applies; the seed
/// scored inline in the heap-push loop, sequentially — hoisting the
/// scores into per-position slots keeps the push order (and every
/// decision) identical while making large pools data-parallel.
fn score_pool_coord(
    inst: &Instance,
    node_map: &[u32],
    i: u32,
    cj: [f64; 2],
    scratch: &mut LbScratch,
) {
    let n_tasks = scratch
        .par_tasks
        .unwrap_or_else(|| pool::global().threads() + 1)
        .max(1);
    let (pool_buf, scores, moved) = (&scratch.pool, &mut scratch.scores, &scratch.moved);
    let score_one = |o: usize| -> Option<(f64, f64)> {
        if moved[o] || node_map[o] != i {
            return None;
        }
        Some((-dist2(inst.coords[o], cj), 0.0))
    };
    score_pool_with(pool_buf, scores, n_tasks, &score_one);
}

/// Coord-variant selection: distance to the target node's centroid,
/// centroids updated incrementally as objects move.
pub fn select_coord(
    inst: &Instance,
    node_map: &mut [u32],
    quotas: &Quotas,
    overfill: f64,
) -> usize {
    let mut scratch = LbScratch::default();
    select_coord_with(inst, node_map, quotas, overfill, &mut scratch)
}

/// Initialize the coord variant's shared centroid state
/// (`scratch.csums` / `scratch.ccounts`) from an object→node map —
/// performed identically by the sequential sweep and by every node of
/// the distributed protocol before manifests replay into it.
pub fn init_centroid_state(inst: &Instance, node_map: &[u32], scratch: &mut LbScratch) {
    let n_nodes = inst.topo.n_nodes;
    scratch.csums.clear();
    scratch.csums.resize(n_nodes, [0.0f64; 2]);
    scratch.ccounts.clear();
    scratch.ccounts.resize(n_nodes, 0);
    for (o, &node) in node_map.iter().enumerate() {
        scratch.csums[node as usize][0] += inst.coords[o][0];
        scratch.csums[node as usize][1] += inst.coords[o][1];
        scratch.ccounts[node as usize] += 1;
    }
}

/// Apply one already-decided migration to the centroid state (used when
/// replaying another node's manifest in the distributed protocol; the
/// local pick loop performs the identical update inline).
pub fn apply_migration_to_centroids(
    inst: &Instance,
    from: u32,
    to: u32,
    obj: u32,
    scratch: &mut LbScratch,
) {
    let c = inst.coords[obj as usize];
    scratch.csums[from as usize][0] -= c[0];
    scratch.csums[from as usize][1] -= c[1];
    scratch.ccounts[from as usize] -= 1;
    scratch.csums[to as usize][0] += c[0];
    scratch.csums[to as usize][1] += c[1];
    scratch.ccounts[to as usize] += 1;
}

fn centroid(sums: &[[f64; 2]], counts: &[usize], n: usize) -> [f64; 2] {
    if counts[n] == 0 {
        [0.0, 0.0]
    } else {
        [sums[n][0] / counts[n] as f64, sums[n][1] / counts[n] as f64]
    }
}

fn dist2(a: [f64; 2], b: [f64; 2]) -> f64 {
    let dx = a[0] - b[0];
    let dy = a[1] - b[1];
    dx * dx + dy * dy
}

/// [`select_coord`] against a caller-owned [`LbScratch`].
pub fn select_coord_with(
    inst: &Instance,
    node_map: &mut [u32],
    quotas: &Quotas,
    overfill: f64,
    scratch: &mut LbScratch,
) -> usize {
    let n_nodes = inst.topo.n_nodes;
    init_centroid_state(inst, node_map, scratch);
    let floor = quota_floor(inst);
    scratch.moved.clear();
    scratch.moved.resize(inst.n_objects(), false);
    scratch.build_soa(inst, node_map, n_nodes);
    let mut migrations = 0;
    for i in 0..n_nodes {
        migrations +=
            select_coord_node(inst, node_map, i, &quotas.flows[i], floor, overfill, scratch, None);
    }
    migrations
}

/// Coord-variant picks for **one** node `i` — per-node body shared with
/// the distributed protocol, under the same contract as
/// [`select_comm_node`] plus current `scratch.csums` / `ccounts`
/// centroid state (see [`init_centroid_state`]).
#[allow(clippy::too_many_arguments)]
pub fn select_coord_node(
    inst: &Instance,
    node_map: &mut [u32],
    i: usize,
    quota_row: &[(u32, f64)],
    floor: f64,
    overfill: f64,
    scratch: &mut LbScratch,
    mut manifest: Option<&mut Vec<(u32, u32)>>,
) -> usize {
    let mut migrations = 0;
    let mut heap: BinaryHeap<Entry> = BinaryHeap::from(std::mem::take(&mut scratch.heap));
    let mut targets = std::mem::take(&mut scratch.targets);
    sorted_quota_into(quota_row, floor, &mut targets);
    if targets.is_empty() {
        scratch.targets = targets;
        scratch.heap = heap.into_vec();
        return 0;
    }
    scratch.pool.clear();
    {
        let slots = scratch.soa_node(i);
        let (pool_buf, objs, moved) =
            (&mut scratch.pool, &scratch.soa_objs[slots], &scratch.moved);
        pool_buf.extend(
            objs.iter()
                .copied()
                .filter(|&o| node_map[o as usize] == i as u32 && !moved[o as usize]),
        );
    }

    for &(j, quota) in &targets {
        let mut remaining = quota;
        heap.clear();
        let cj = centroid(&scratch.csums, &scratch.ccounts, j as usize);
        // max-heap: closer = higher priority = larger key. Scores land
        // in per-position slots first (chunk-parallel on big pools) and
        // push in pool order — the seed's inline sequential push order.
        score_pool_coord(inst, node_map, i as u32, cj, scratch);
        let (pool_buf, scores) =
            (std::mem::take(&mut scratch.pool), std::mem::take(&mut scratch.scores));
        for (p, &o) in pool_buf.iter().enumerate() {
            let (key, _, valid) = scores[p];
            if !valid {
                continue;
            }
            heap.push(Entry { key, tie: 0.0, obj: o });
        }
        scratch.pool = pool_buf;
        scratch.scores = scores;
        // bounded revalidation so a drifting centroid cannot loop us
        let mut revalidations = 4 * scratch.pool.len() + 16;
        while remaining > 1e-12 {
            let Some(top) = heap.pop() else { break };
            let o = top.obj;
            if scratch.moved[o as usize] || node_map[o as usize] != i as u32 {
                continue;
            }
            let cj = centroid(&scratch.csums, &scratch.ccounts, j as usize);
            let cur = -dist2(inst.coords[o as usize], cj);
            if revalidations > 0 && (cur - top.key).abs() > 1e-9 {
                revalidations -= 1;
                heap.push(Entry { key: cur, ..top });
                continue;
            }
            let load = eff_load(inst, i, inst.loads[o as usize]);
            if !fits(load, remaining, overfill) {
                continue;
            }
            node_map[o as usize] = j;
            scratch.moved[o as usize] = true;
            migrations += 1;
            remaining -= load;
            if let Some(m) = manifest.as_mut() {
                m.push((o, j));
            }
            apply_migration_to_centroids(inst, i as u32, j, o, scratch);
        }
    }
    scratch.targets = targets;
    heap.clear();
    scratch.heap = heap.into_vec();
    migrations
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{CommGraph, Instance, Topology};
    use crate::strategies::diffusion::virtual_lb::Quotas;

    /// 8 objects: 0-3 on node 0 (chain), 4-7 on node 1 (chain), with a
    /// bridge edge 3-4. Unit loads.
    fn two_node_instance() -> Instance {
        let edges = vec![
            (0, 1, 10.0),
            (1, 2, 10.0),
            (2, 3, 10.0),
            (3, 4, 50.0), // bridge: object 3 talks a lot to node 1
            (4, 5, 10.0),
            (5, 6, 10.0),
            (6, 7, 10.0),
        ];
        let graph = CommGraph::from_edges(8, &edges);
        let coords: Vec<[f64; 2]> = (0..8).map(|i| [i as f64, 0.0]).collect();
        Instance::new(
            vec![1.0; 8],
            coords,
            graph,
            vec![0, 0, 0, 0, 1, 1, 1, 1],
            Topology::flat(2),
        )
    }

    fn quota_0_to_1(amount: f64) -> Quotas {
        let mut q = Quotas::empty(2);
        q.flows[0].push((1, amount));
        q
    }

    #[test]
    fn comm_picks_highest_bytes_first() {
        let inst = two_node_instance();
        let mut map = inst.node_mapping();
        let n = select_comm(&inst, &mut map, &quota_0_to_1(1.0), 0.5);
        assert_eq!(n, 1);
        // object 3 has 50 bytes to node 1 — must be chosen first.
        assert_eq!(map[3], 1);
    }

    #[test]
    fn comm_updates_patterns_after_each_pick() {
        let inst = two_node_instance();
        let mut map = inst.node_mapping();
        let n = select_comm(&inst, &mut map, &quota_0_to_1(2.0), 0.5);
        assert_eq!(n, 2);
        // after 3 moves, object 2 (edge 2-3 = 10 bytes) becomes the top
        // candidate even though it initially had 0 bytes to node 1.
        assert_eq!(map[3], 1);
        assert_eq!(map[2], 1);
        assert_eq!(map[1], 0);
    }

    #[test]
    fn quota_respected_with_overfill() {
        let inst = two_node_instance();
        let mut map = inst.node_mapping();
        // quota 2.5 with overfill 0.5: loads are 1.0, so up to 3 objects
        // (2 full + one at remaining 0.5 >= load*0.5).
        let n = select_comm(&inst, &mut map, &quota_0_to_1(2.5), 0.5);
        assert_eq!(n, 3);
        // zero overfill: exactly 2
        let mut map2 = inst.node_mapping();
        let n2 = select_comm(&inst, &mut map2, &quota_0_to_1(2.5), 0.0);
        assert_eq!(n2, 2);
    }

    #[test]
    fn migrations_only_along_quota_edges() {
        let inst = two_node_instance();
        let mut map = inst.node_mapping();
        select_comm(&inst, &mut map, &quota_0_to_1(3.0), 0.5);
        for (o, &nm) in map.iter().enumerate() {
            let orig = inst.node_mapping()[o];
            assert!(nm == orig || (orig == 0 && nm == 1), "obj {o} moved {orig}->{nm}");
        }
    }

    #[test]
    fn coord_picks_closest_to_target_centroid() {
        let inst = two_node_instance();
        let mut map = inst.node_mapping();
        let n = select_coord(&inst, &mut map, &quota_0_to_1(1.0), 0.5);
        assert_eq!(n, 1);
        // node 1 centroid is at x=5.5; object 3 (x=3) is node 0's closest
        assert_eq!(map[3], 1);
        assert_eq!(map[0], 0);
    }

    #[test]
    fn coord_moves_boundary_objects_in_order() {
        let inst = two_node_instance();
        let mut map = inst.node_mapping();
        let n = select_coord(&inst, &mut map, &quota_0_to_1(3.0), 0.5);
        assert_eq!(n, 3);
        assert_eq!(&map[..8], &[0, 1, 1, 1, 1, 1, 1, 1]);
    }

    #[test]
    fn zero_quota_moves_nothing() {
        let inst = two_node_instance();
        let mut map = inst.node_mapping();
        assert_eq!(select_comm(&inst, &mut map, &Quotas::empty(2), 0.5), 0);
        assert_eq!(select_coord(&inst, &mut map, &Quotas::empty(2), 0.5), 0);
        assert_eq!(map, inst.node_mapping());
    }

    #[test]
    fn scratch_reuse_matches_fresh_scratch() {
        let inst = two_node_instance();
        let mut shared = LbScratch::default();
        for amount in [1.0, 2.0, 2.5, 3.0] {
            let mut m1 = inst.node_mapping();
            let mut m2 = inst.node_mapping();
            let n1 = select_comm(&inst, &mut m1, &quota_0_to_1(amount), 0.5);
            let n2 =
                select_comm_with(&inst, &mut m2, &quota_0_to_1(amount), 0.5, &mut shared);
            assert_eq!(n1, n2);
            assert_eq!(m1, m2);
        }
    }

    #[test]
    fn manifest_records_picks_in_order() {
        let inst = two_node_instance();
        let mut map = inst.node_mapping();
        let floor = quota_floor(&inst);
        let mut scratch = LbScratch::default();
        scratch.moved.resize(inst.n_objects(), false);
        scratch.build_soa(&inst, &inst.node_mapping(), 2);
        let mut manifest = Vec::new();
        let n = select_comm_node(
            &inst,
            &mut map,
            0,
            &[(1, 2.0)],
            floor,
            0.5,
            &mut scratch,
            Some(&mut manifest),
        );
        assert_eq!(n, manifest.len());
        assert_eq!(manifest, vec![(3, 1), (2, 1)]);
    }

    #[test]
    fn weighted_quota_counts_sender_time_not_raw_work() {
        // Node 0 runs at speed 2: each unit-load object frees 0.5 time
        // units when it leaves, so a time quota of 1.0 moves TWO
        // objects (a uniform topology moves one).
        let mut inst = two_node_instance();
        let mut map = inst.node_mapping();
        assert_eq!(select_comm(&inst, &mut map, &quota_0_to_1(1.0), 0.5), 1);
        inst.topo = Topology::flat(2).with_pe_speeds(vec![2.0, 1.0]);
        let mut wmap = inst.node_mapping();
        assert_eq!(select_comm(&inst, &mut wmap, &quota_0_to_1(1.0), 0.5), 2);
        // picks still follow the bytes ranking: 3 first, then 2
        assert_eq!(wmap[3], 1);
        assert_eq!(wmap[2], 1);
    }

    #[test]
    fn weighted_quota_floor_uses_normalized_time() {
        let mut inst = two_node_instance();
        // uniform: 1% of (8 total load / 2 nodes)
        assert_eq!(quota_floor(&inst), 0.01 * 8.0 / 2.0);
        // speeds [4, 1]: node times are 4/4 and 4/1 -> total 5
        inst.topo = Topology::flat(2).with_pe_speeds(vec![4.0, 1.0]);
        assert!((quota_floor(&inst) - 0.01 * 5.0 / 2.0).abs() < 1e-15);
    }

    #[test]
    fn nan_quota_keys_no_longer_corrupt_ordering() {
        // a NaN-keyed entry must sort below real keys (total_cmp), not
        // equal to everything (the old partial_cmp fallback)
        let nan = Entry { key: f64::NAN, tie: 0.0, obj: 9 };
        let real = Entry { key: 1.0, tie: 0.0, obj: 1 };
        let zero = Entry { key: 0.0, tie: 0.0, obj: 2 };
        assert_eq!(nan.cmp(&real), Ordering::Less);
        assert_eq!(nan.cmp(&zero), Ordering::Less);
        let mut h = BinaryHeap::from(vec![nan, real, zero]);
        assert_eq!(h.pop().unwrap().obj, 1);
        assert_eq!(h.pop().unwrap().obj, 2);
        assert_eq!(h.pop().unwrap().obj, 9);
    }
}
