//! Scatter baseline: uniformly random placement. The "communication
//! locality entirely disrupted" picture on the right of Fig 1 — used by
//! the visualization bench and as a worst-case locality reference.

use crate::model::{Assignment, Instance};
use crate::strategies::LoadBalancer;
use crate::util::rng::Rng;

pub struct Scatter {
    pub seed: u64,
}

impl LoadBalancer for Scatter {
    fn name(&self) -> &'static str {
        "scatter"
    }

    fn rebalance(&self, inst: &Instance) -> Assignment {
        let mut rng = Rng::new(self.seed);
        let n_pes = inst.topo.n_pes() as u64;
        let mapping = (0..inst.n_objects()).map(|_| rng.below(n_pes) as u32).collect();
        Assignment { mapping }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{metrics, CommGraph, Topology};

    #[test]
    fn scatter_destroys_locality() {
        // ring graph initially contiguous on 4 PEs
        let n = 64;
        let edges: Vec<(u32, u32, f64)> =
            (0..n as u32).map(|i| (i, (i + 1) % n as u32, 1.0)).collect();
        let inst = Instance::new(
            vec![1.0; n],
            vec![[0.0; 2]; n],
            CommGraph::from_edges(n, &edges),
            (0..n as u32).map(|i| i / 16).collect(),
            Topology::flat(4),
        );
        let before = metrics::comm_split_nodes(&inst, &inst.mapping).ratio();
        let asg = Scatter { seed: 1 }.rebalance(&inst);
        let after = metrics::comm_split_nodes(&inst, &asg.mapping).ratio();
        assert!(after > before * 3.0, "{after} !> 3*{before}");
    }

    #[test]
    fn deterministic_per_seed() {
        let inst = Instance::new(
            vec![1.0; 8],
            vec![[0.0; 2]; 8],
            CommGraph::empty(8),
            vec![0; 8],
            Topology::flat(4),
        );
        let a = Scatter { seed: 9 }.rebalance(&inst);
        let b = Scatter { seed: 9 }.rebalance(&inst);
        assert_eq!(a.mapping, b.mapping);
    }
}
