//! Scatter baseline: uniformly random placement. The "communication
//! locality entirely disrupted" picture on the right of Fig 1 — used by
//! the visualization bench and as a worst-case locality reference.
//!
//! Speed-aware variant: on heterogeneous topologies each PE is drawn
//! with probability proportional to its speed, so the *expected* time
//! per PE stays flat while locality is still maximally disrupted. The
//! uniform path keeps the legacy `below(n_pes)` draws untouched.

use crate::model::{Assignment, Instance};
use crate::strategies::LoadBalancer;
use crate::util::rng::Rng;

pub struct Scatter {
    pub seed: u64,
}

impl LoadBalancer for Scatter {
    fn name(&self) -> &'static str {
        "scatter"
    }

    fn rebalance(&self, inst: &Instance) -> Assignment {
        let mut rng = Rng::new(self.seed);
        let mapping = match inst.topo.pe_speeds() {
            None => {
                let n_pes = inst.topo.n_pes() as u64;
                (0..inst.n_objects()).map(|_| rng.below(n_pes) as u32).collect()
            }
            Some(speeds) => {
                // cumulative speed prefix; pick the first PE whose
                // cumulative share exceeds a uniform draw
                let mut cum = Vec::with_capacity(speeds.len());
                let mut total = 0.0;
                for &s in speeds {
                    total += s;
                    cum.push(total);
                }
                (0..inst.n_objects())
                    .map(|_| {
                        let u = rng.f64() * total;
                        cum.partition_point(|&c| c <= u).min(speeds.len() - 1) as u32
                    })
                    .collect()
            }
        };
        Assignment { mapping }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{metrics, CommGraph, Topology};

    #[test]
    fn scatter_destroys_locality() {
        // ring graph initially contiguous on 4 PEs
        let n = 64;
        let edges: Vec<(u32, u32, f64)> =
            (0..n as u32).map(|i| (i, (i + 1) % n as u32, 1.0)).collect();
        let inst = Instance::new(
            vec![1.0; n],
            vec![[0.0; 2]; n],
            CommGraph::from_edges(n, &edges),
            (0..n as u32).map(|i| i / 16).collect(),
            Topology::flat(4),
        );
        let before = metrics::comm_split_nodes(&inst, &inst.mapping).ratio();
        let asg = Scatter { seed: 1 }.rebalance(&inst);
        let after = metrics::comm_split_nodes(&inst, &asg.mapping).ratio();
        assert!(after > before * 3.0, "{after} !> 3*{before}");
    }

    #[test]
    fn weighted_scatter_follows_speed_shares() {
        // PE 1 is 4x faster than PE 0: it should receive ~4x the
        // objects (binomial p=0.8 over 4000 draws — a >6-sigma margin).
        let n = 4000;
        let inst = Instance::new(
            vec![1.0; n],
            vec![[0.0; 2]; n],
            CommGraph::empty(n),
            vec![0; n],
            Topology::flat(2).with_pe_speeds(vec![1.0, 4.0]),
        );
        let asg = Scatter { seed: 3 }.rebalance(&inst);
        let on_fast = asg.mapping.iter().filter(|&&p| p == 1).count();
        assert!((3000..3500).contains(&on_fast), "fast PE got {on_fast}/4000");
        // in-range always
        assert!(asg.mapping.iter().all(|&p| p < 2));
    }

    #[test]
    fn deterministic_per_seed() {
        let inst = Instance::new(
            vec![1.0; 8],
            vec![[0.0; 2]; 8],
            CommGraph::empty(8),
            vec![0; 8],
            Topology::flat(4),
        );
        let a = Scatter { seed: 9 }.rebalance(&inst);
        let b = Scatter { seed: 9 }.rebalance(&inst);
        assert_eq!(a.mapping, b.mapping);
    }
}
