//! Binary `.lbi` codec — the wire form of an [`Instance`].
//!
//! The distributed driver broadcasts the instance to every rank at each
//! LB round; the text format ([`Instance::to_lbi`]) pays float
//! formatting + parsing and an O(m log m) re-sort on every decode. This
//! codec writes a single-pass preallocated buffer instead:
//!
//! * scalars are LEB128 varints (object counts, PE ids, CSR partner
//!   counts, delta-encoded neighbor ids — all small in practice);
//! * every f64 travels as its exact `to_bits` pattern, little-endian —
//!   lossless by construction, no shortest-round-trip formatting;
//! * the comm graph ships as varint-packed CSR upper-triangle rows
//!   (per object: partner count, ascending gap-encoded partners, weight
//!   bits), so the decoder rebuilds the canonical `(a, b)`-sorted edge
//!   list by concatenation and hands it to
//!   [`CommGraph::from_canonical_edges`] — the O(m log m) sort of
//!   `from_edges` disappears from the decode path.
//!
//! `encode(decode(bytes)) == bytes` for any encoder-produced input: the
//! encoder is a pure function of the instance and the decoder
//! reconstructs every field exactly (locked by the round-trip property
//! test in `rust/tests/simd_soa_identity.rs`).
//!
//! Sizes and (when telemetry is on) durations are observed via
//! [`crate::obs`] histograms; the bytes produced never depend on the
//! telemetry flags (`tests/apps_conformance.rs` locks that).

use anyhow::{bail, Result};

use super::graph::CommGraph;
use super::instance::Instance;
use super::topology::Topology;

/// `b"LBI"` + format version.
const MAGIC: [u8; 4] = [b'L', b'B', b'I', 1];
/// Header flag: a PE speed vector follows the header.
const FLAG_SPEEDS: u8 = 1 << 0;

fn put_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

fn put_f64_bits(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}

/// Byte cursor with explicit truncation errors (a short broadcast must
/// surface as `Err`, never a panic in the driver's receive path).
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn varint(&mut self) -> Result<u64> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let Some(&byte) = self.buf.get(self.pos) else {
                bail!("lbi: truncated varint at byte {}", self.pos);
            };
            self.pos += 1;
            if shift >= 64 || (shift == 63 && byte > 1) {
                bail!("lbi: varint overflows u64 at byte {}", self.pos);
            }
            v |= u64::from(byte & 0x7f) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    fn f64_bits(&mut self) -> Result<f64> {
        let Some(bytes) = self.buf.get(self.pos..self.pos + 8) else {
            bail!("lbi: truncated f64 at byte {}", self.pos);
        };
        self.pos += 8;
        Ok(f64::from_bits(u64::from_le_bytes(bytes.try_into().unwrap())))
    }

    fn byte(&mut self) -> Result<u8> {
        let Some(&b) = self.buf.get(self.pos) else {
            bail!("lbi: truncated header at byte {}", self.pos);
        };
        self.pos += 1;
        Ok(b)
    }
}

/// Encode `inst` into the binary `.lbi` wire form.
pub fn encode_lbi(inst: &Instance) -> Vec<u8> {
    let _s = crate::obs::span("lbi.encode", "model");
    let timed = crate::obs::metrics_enabled() || crate::obs::tracing_enabled();
    let t0 = if timed { crate::obs::now_us() } else { 0 };

    let n = inst.n_objects();
    let m = inst.graph.nbrs.len() / 2;
    // header ≤ 20 B; 4×8 B of float bits + ≤5 B of mapping varint per
    // object; ≤5 B gap varint + 8 B weight bits per edge + 1-byte row
    // counts. Exact enough that growth is the rare case.
    let mut buf = Vec::with_capacity(
        20 + inst.topo.n_pes() * 8 + n * (4 * 8 + 5 + 1) + m * (5 + 8),
    );
    buf.extend_from_slice(&MAGIC);
    let speeds = inst.topo.pe_speeds();
    buf.push(if speeds.is_some() { FLAG_SPEEDS } else { 0 });
    put_varint(&mut buf, n as u64);
    put_varint(&mut buf, inst.topo.n_nodes as u64);
    put_varint(&mut buf, inst.topo.pes_per_node as u64);
    if let Some(speeds) = speeds {
        for &v in speeds {
            put_f64_bits(&mut buf, v);
        }
    }
    for &l in &inst.loads {
        put_f64_bits(&mut buf, l);
    }
    for c in &inst.coords {
        put_f64_bits(&mut buf, c[0]);
        put_f64_bits(&mut buf, c[1]);
    }
    for &s in &inst.sizes {
        put_f64_bits(&mut buf, s);
    }
    for &pe in &inst.mapping {
        put_varint(&mut buf, u64::from(pe));
    }
    // Upper-triangle CSR: row o lists partners b > o in ascending order
    // (CSR rows are ascending, so they are the row's tail — found by
    // partition point, no scan state). Gaps are `b - prev - 1` with
    // `prev` starting at `o`: strictly ascending partners make every
    // gap non-negative.
    for o in 0..n {
        let row = inst.graph.offsets[o] as usize..inst.graph.offsets[o + 1] as usize;
        let nbrs = &inst.graph.nbrs[row.clone()];
        let split = nbrs.partition_point(|&b| b <= o as u32);
        put_varint(&mut buf, (nbrs.len() - split) as u64);
        let mut prev = o as u32;
        for (&b, &w) in nbrs[split..].iter().zip(&inst.graph.bytes[row][split..]) {
            put_varint(&mut buf, u64::from(b - prev - 1));
            put_f64_bits(&mut buf, w);
            prev = b;
        }
    }

    crate::obs::histogram!("lbi.encode.bytes").observe(buf.len() as u64);
    if timed {
        crate::obs::histogram!("lbi.encode.us").observe(crate::obs::now_us() - t0);
    }
    buf
}

/// Decode an [`encode_lbi`] payload. Any malformed or truncated input
/// returns `Err` (the distributed receive path must never panic on
/// wire bytes).
pub fn decode_lbi(data: &[u8]) -> Result<Instance> {
    let _s = crate::obs::span("lbi.decode", "model");
    let timed = crate::obs::metrics_enabled() || crate::obs::tracing_enabled();
    let t0 = if timed { crate::obs::now_us() } else { 0 };

    if data.len() < MAGIC.len() || data[..3] != MAGIC[..3] {
        bail!("lbi: bad magic");
    }
    if data[3] != MAGIC[3] {
        bail!("lbi: unsupported version {}", data[3]);
    }
    let mut c = Cursor { buf: data, pos: MAGIC.len() };
    let flags = c.byte()?;
    if flags & !FLAG_SPEEDS != 0 {
        bail!("lbi: unknown flags {flags:#x}");
    }
    let n = usize::try_from(c.varint()?)?;
    let n_nodes = usize::try_from(c.varint()?)?;
    let ppn = usize::try_from(c.varint()?)?;
    if n_nodes == 0 || ppn == 0 {
        bail!("lbi: empty topology ({n_nodes} nodes x {ppn} pes)");
    }
    let mut topo = Topology::new(n_nodes, ppn);
    if flags & FLAG_SPEEDS != 0 {
        let mut speeds = Vec::with_capacity(topo.n_pes());
        for _ in 0..topo.n_pes() {
            let v = c.f64_bits()?;
            if !v.is_finite() || v <= 0.0 {
                bail!("lbi: speeds must be finite and positive");
            }
            speeds.push(v);
        }
        topo = topo.with_pe_speeds(speeds);
    }
    let mut loads = Vec::with_capacity(n);
    for _ in 0..n {
        loads.push(c.f64_bits()?);
    }
    let mut coords = Vec::with_capacity(n);
    for _ in 0..n {
        coords.push([c.f64_bits()?, c.f64_bits()?]);
    }
    let mut sizes = Vec::with_capacity(n);
    for _ in 0..n {
        sizes.push(c.f64_bits()?);
    }
    let mut mapping = Vec::with_capacity(n);
    for _ in 0..n {
        mapping.push(u32::try_from(c.varint()?)?);
    }
    // Rows concatenate straight into the canonical (a, b)-sorted merged
    // edge list: `a` ascends across rows, `b` ascends within one.
    let mut edges: Vec<(u32, u32, f64)> = Vec::new();
    for o in 0..n {
        let k = usize::try_from(c.varint()?)?;
        let mut prev = o as u32;
        for _ in 0..k {
            let gap = u32::try_from(c.varint()?)?;
            let b = prev
                .checked_add(gap)
                .and_then(|x| x.checked_add(1))
                .filter(|&b| (b as usize) < n);
            let Some(b) = b else {
                bail!("lbi: edge partner out of range in row {o}");
            };
            edges.push((o as u32, b, c.f64_bits()?));
            prev = b;
        }
    }
    if c.pos != data.len() {
        bail!("lbi: {} trailing bytes", data.len() - c.pos);
    }
    let graph = CommGraph::from_canonical_edges(n, &edges);
    let inst = Instance { loads, coords, sizes, graph, mapping, topo };
    inst.validate()?;

    crate::obs::histogram!("lbi.decode.bytes").observe(data.len() as u64);
    if timed {
        crate::obs::histogram!("lbi.decode.us").observe(crate::obs::now_us() - t0);
    }
    Ok(inst)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Topology;

    fn sample() -> Instance {
        let graph = CommGraph::from_edges(
            5,
            &[(0, 1, 8.0), (1, 2, 4.5), (2, 3, 2.25), (0, 4, 1.0), (3, 4, 0.125)],
        );
        let mut inst = Instance::new(
            vec![1.0, 2.0, 3.5, 4.0, 0.5],
            vec![[0.0, 0.0], [1.0, 0.5], [2.0, 1.0], [3.0, 1.5], [4.0, 2.0]],
            graph,
            vec![0, 1, 2, 3, 0],
            Topology::new(2, 2),
        );
        inst.sizes = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        inst
    }

    #[test]
    fn round_trips_exactly() {
        let inst = sample();
        let bytes = encode_lbi(&inst);
        let back = decode_lbi(&bytes).unwrap();
        assert_eq!(back.loads, inst.loads);
        assert_eq!(back.coords, inst.coords);
        assert_eq!(back.sizes, inst.sizes);
        assert_eq!(back.mapping, inst.mapping);
        assert_eq!(back.graph, inst.graph);
        assert_eq!(back.topo, inst.topo);
        // the decoder is exact, so re-encoding is byte-stable
        assert_eq!(encode_lbi(&back), bytes);
    }

    #[test]
    fn round_trips_speeds_and_odd_floats() {
        let mut inst = sample();
        inst.topo = inst.topo.clone().with_pe_speeds(vec![1.0, 2.5, 0.75, 1.0 / 3.0]);
        inst.loads[0] = f64::MIN_POSITIVE; // subnormal boundary
        inst.coords[1] = [-0.0, 1e-300];
        let bytes = encode_lbi(&inst);
        let back = decode_lbi(&bytes).unwrap();
        assert_eq!(back.topo, inst.topo);
        assert_eq!(back.loads[0].to_bits(), inst.loads[0].to_bits());
        assert_eq!(back.coords[1][0].to_bits(), inst.coords[1][0].to_bits());
        assert_eq!(encode_lbi(&back), bytes);
    }

    #[test]
    fn agrees_with_text_format() {
        let inst = sample();
        let via_bin = decode_lbi(&encode_lbi(&inst)).unwrap();
        let via_text = Instance::from_lbi(&inst.to_lbi()).unwrap();
        assert_eq!(via_bin.loads, via_text.loads);
        assert_eq!(via_bin.graph, via_text.graph);
        assert_eq!(via_bin.mapping, via_text.mapping);
        assert_eq!(via_bin.topo, via_text.topo);
    }

    #[test]
    fn malformed_inputs_error_not_panic() {
        assert!(decode_lbi(b"").is_err());
        assert!(decode_lbi(b"NOP\x01").is_err());
        assert!(decode_lbi(&[b'L', b'B', b'I', 9]).is_err(), "future version");
        let good = encode_lbi(&sample());
        for cut in [5, good.len() / 2, good.len() - 1] {
            assert!(decode_lbi(&good[..cut]).is_err(), "truncated at {cut}");
        }
        let mut trailing = good.clone();
        trailing.push(0);
        assert!(decode_lbi(&trailing).is_err());
        // flip a varint-region byte: decoder must reject, not panic
        let mut bad = good;
        let last = bad.len() - 1;
        bad[last] ^= 0xff;
        let _ = decode_lbi(&bad); // Err or a different valid instance — never a panic
    }

    #[test]
    fn varint_boundaries() {
        let mut buf = Vec::new();
        for v in [0u64, 1, 127, 128, 16_383, 16_384, u64::from(u32::MAX), u64::MAX] {
            buf.clear();
            put_varint(&mut buf, v);
            let mut c = Cursor { buf: &buf, pos: 0 };
            assert_eq!(c.varint().unwrap(), v);
            assert_eq!(c.pos, buf.len());
        }
    }
}
