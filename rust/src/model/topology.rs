//! Execution topology: nodes (processes) × PEs (threads within a
//! process), mirroring the paper's hierarchy (§III-D): the diffusion
//! stages operate at node granularity, the hierarchical pass refines
//! across PEs inside a node. With `pes_per_node = 1` (the paper's
//! "one process per core" study mode) nodes and PEs coincide.

/// Node/PE topology. PEs are numbered contiguously:
/// `pe = node * pes_per_node + local`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    pub n_nodes: usize,
    pub pes_per_node: usize,
}

impl Topology {
    pub fn new(n_nodes: usize, pes_per_node: usize) -> Topology {
        assert!(n_nodes > 0 && pes_per_node > 0);
        Topology { n_nodes, pes_per_node }
    }

    /// Flat topology: every PE its own node (paper's simulation setup).
    pub fn flat(n_pes: usize) -> Topology {
        Topology::new(n_pes, 1)
    }

    #[inline]
    pub fn n_pes(&self) -> usize {
        self.n_nodes * self.pes_per_node
    }

    #[inline]
    pub fn node_of_pe(&self, pe: u32) -> u32 {
        debug_assert!((pe as usize) < self.n_pes());
        pe / self.pes_per_node as u32
    }

    #[inline]
    pub fn local_of_pe(&self, pe: u32) -> u32 {
        pe % self.pes_per_node as u32
    }

    /// PEs belonging to `node`, as a range.
    #[inline]
    pub fn pes_of_node(&self, node: u32) -> std::ops::Range<u32> {
        let lo = node * self.pes_per_node as u32;
        lo..lo + self.pes_per_node as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_topology() {
        let t = Topology::flat(8);
        assert_eq!(t.n_pes(), 8);
        assert_eq!(t.node_of_pe(5), 5);
        assert_eq!(t.pes_of_node(5), 5..6);
    }

    #[test]
    fn hierarchical_topology() {
        let t = Topology::new(4, 16);
        assert_eq!(t.n_pes(), 64);
        assert_eq!(t.node_of_pe(0), 0);
        assert_eq!(t.node_of_pe(17), 1);
        assert_eq!(t.local_of_pe(17), 1);
        assert_eq!(t.pes_of_node(3), 48..64);
    }

    #[test]
    #[should_panic]
    fn zero_nodes_rejected() {
        Topology::new(0, 1);
    }
}
