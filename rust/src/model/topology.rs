//! Execution topology: nodes (processes) × PEs (threads within a
//! process), mirroring the paper's hierarchy (§III-D): the diffusion
//! stages operate at node granularity, the hierarchical pass refines
//! across PEs inside a node. With `pes_per_node = 1` (the paper's
//! "one process per core" study mode) nodes and PEs coincide.
//!
//! Heterogeneity: each PE optionally carries a **speed factor** (its
//! relative service rate — work units retired per second). The paper's
//! setup is homogeneous, but real clusters mix node generations and
//! suffer OS interference (Boulmier et al., arXiv:1909.07168 balance
//! *where load will land*, Demirel & Sbalzarini, arXiv:1308.0148
//! diffuse over non-uniform networks), so every strategy in this repo
//! balances **normalized time** `work / speed` rather than raw work.
//! A topology without speeds (`pe_speeds() == None`) is the uniform
//! fast path: all strategy arithmetic is bit-for-bit the
//! pre-heterogeneity code, which is what the frozen baselines in
//! `rust/tests/hetero_identity.rs` lock down. [`SpeedSchedule`] models
//! transient interference by perturbing the speeds per iteration.

use std::sync::Arc;

use crate::util::rng::Rng;

/// Node/PE topology. PEs are numbered contiguously:
/// `pe = node * pes_per_node + local`.
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    pub n_nodes: usize,
    pub pes_per_node: usize,
    /// Per-PE speed factors; `None` = uniform (every PE exactly 1.0).
    /// Behind an `Arc` so cloning a topology stays cheap — `Topology`
    /// used to be `Copy` and is passed around freely.
    speeds: Option<Arc<[f64]>>,
}

impl Topology {
    pub fn new(n_nodes: usize, pes_per_node: usize) -> Topology {
        assert!(n_nodes > 0 && pes_per_node > 0);
        Topology { n_nodes, pes_per_node, speeds: None }
    }

    /// Flat topology: every PE its own node (paper's simulation setup).
    pub fn flat(n_pes: usize) -> Topology {
        Topology::new(n_pes, 1)
    }

    /// Attach per-PE speed factors (`speeds.len() == n_pes()`, all
    /// finite and positive). An all-exactly-1.0 vector canonicalizes to
    /// the uniform representation, so "explicitly homogeneous" configs
    /// keep the legacy bit-exact code paths.
    pub fn with_pe_speeds(mut self, speeds: Vec<f64>) -> Topology {
        assert_eq!(speeds.len(), self.n_pes(), "pe_speeds length != n_pes");
        assert!(
            speeds.iter().all(|s| s.is_finite() && *s > 0.0),
            "pe speeds must be finite and positive"
        );
        self.speeds = if speeds.iter().all(|&s| s == 1.0) {
            None
        } else {
            Some(Arc::from(speeds.into_boxed_slice()))
        };
        self
    }

    /// The per-PE speed vector, or `None` for a uniform topology.
    #[inline]
    pub fn pe_speeds(&self) -> Option<&[f64]> {
        self.speeds.as_deref()
    }

    /// Whether every PE runs at the same (unit) speed. Strategies gate
    /// their weighted arithmetic on this so homogeneous topologies take
    /// the exact pre-heterogeneity code path.
    #[inline]
    pub fn is_uniform(&self) -> bool {
        self.speeds.is_none()
    }

    /// Speed factor of one PE (1.0 on uniform topologies).
    #[inline]
    pub fn pe_speed(&self, pe: u32) -> f64 {
        match &self.speeds {
            None => 1.0,
            Some(s) => s[pe as usize],
        }
    }

    /// A node's total service capacity: the sum of its PEs' speeds
    /// (left-to-right over the node's PE range, so the scalar is
    /// reproducible everywhere it is recomputed — the distributed
    /// stage-2 protocol evaluates the identical expression per node).
    #[inline]
    pub fn node_capacity(&self, node: u32) -> f64 {
        match &self.speeds {
            None => self.pes_per_node as f64,
            Some(s) => {
                let r = self.pes_of_node(node);
                let mut cap = 0.0;
                for pe in r {
                    cap += s[pe as usize];
                }
                cap
            }
        }
    }

    #[inline]
    pub fn n_pes(&self) -> usize {
        self.n_nodes * self.pes_per_node
    }

    #[inline]
    pub fn node_of_pe(&self, pe: u32) -> u32 {
        debug_assert!((pe as usize) < self.n_pes());
        pe / self.pes_per_node as u32
    }

    #[inline]
    pub fn local_of_pe(&self, pe: u32) -> u32 {
        pe % self.pes_per_node as u32
    }

    /// PEs belonging to `node`, as a range.
    #[inline]
    pub fn pes_of_node(&self, node: u32) -> std::ops::Range<u32> {
        let lo = node * self.pes_per_node as u32;
        lo..lo + self.pes_per_node as u32
    }
}

/// Time-varying speed noise: models OS interference / transient
/// slowdowns by multiplicatively perturbing each PE's base speed with a
/// deterministic per-(epoch, PE) draw. `noise = 0` disables the
/// schedule entirely — [`SpeedSchedule::topo_at`] then returns the base
/// topology unchanged, preserving bit-identity with noise-free runs.
///
/// The perturbation is a pure function of `(seed, iter / period, pe)`,
/// so the sequential driver and the distributed driver's root compute
/// identical effective topologies without exchanging anything beyond
/// the instance broadcast (which carries the speeds in its `.lbi`
/// text).
#[derive(Debug, Clone)]
pub struct SpeedSchedule {
    /// Relative perturbation amplitude: effective speed is
    /// `base * (1 + noise * u)` with `u` uniform in `[-1, 1)`.
    pub noise: f64,
    /// Redraw the perturbation every `period` iterations (1 = every
    /// iteration).
    pub period: usize,
    pub seed: u64,
}

impl SpeedSchedule {
    /// The inert schedule (no noise).
    pub fn none() -> SpeedSchedule {
        SpeedSchedule { noise: 0.0, period: 1, seed: 0x5EED }
    }

    #[inline]
    pub fn is_active(&self) -> bool {
        self.noise > 0.0
    }

    /// Effective topology at iteration `iter`. Inactive schedules hand
    /// back a clone of `base` (cheap: the speed vector is `Arc`-shared).
    pub fn topo_at(&self, base: &Topology, iter: usize) -> Topology {
        if !self.is_active() {
            return base.clone();
        }
        let epoch = iter / self.period.max(1);
        let n = base.n_pes();
        let mut speeds = Vec::with_capacity(n);
        for pe in 0..n as u32 {
            let mut rng = Rng::new(
                self.seed
                    ^ (epoch as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    ^ (u64::from(pe)).wrapping_mul(0xD1B5_4A32_D192_ED03),
            );
            let u = 2.0 * rng.f64() - 1.0;
            // clamp away from zero so a deep spike cannot produce a
            // non-positive speed (with_pe_speeds would reject it)
            let s = (base.pe_speed(pe) * (1.0 + self.noise * u)).max(1e-3);
            speeds.push(s);
        }
        base.clone().with_pe_speeds(speeds)
    }
}

impl Default for SpeedSchedule {
    fn default() -> SpeedSchedule {
        SpeedSchedule::none()
    }
}

/// One planned membership change: `node` joins or leaves the active set
/// at LB round `lb_round` (the change is part of that round's
/// rebalance — a leaver still ships its objects during the round, a
/// joiner receives its first objects from it).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ResizeEvent {
    pub node: u32,
    pub join: bool,
    pub lb_round: usize,
}

/// Planned elasticity: a schedule of node join/leave events keyed to LB
/// rounds, shared by every rank (membership is a pure function of the
/// round index, so the distributed runtime needs no agreement protocol
/// for it — unlike failures, which are *unplanned* and go through the
/// epoch layer).
///
/// The world topology is fixed at `n_nodes`; a "joining" node is a
/// world rank that starts inactive (no objects, no traffic) and is
/// seeded at its join round, a "leaving" node is drained — its speeds
/// are scaled to `1e-3` for the `drain` rounds before departure so
/// diffusion bleeds its load away — and then excluded, shipping
/// whatever remains during its leave round. Node 0 hosts the LB root
/// and never leaves.
///
/// An empty schedule is inert: every membership query returns all-alive
/// and [`ResizeSchedule::drained_topo`] is the identity, preserving
/// bit-identity with resize-free runs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ResizeSchedule {
    pub events: Vec<ResizeEvent>,
    /// How many LB rounds before a leave the node spends draining
    /// (speed × 1e-3). 0 = drop the load all at once at the leave
    /// round.
    pub drain: usize,
}

impl ResizeSchedule {
    /// The inert schedule (no events).
    pub fn none() -> ResizeSchedule {
        ResizeSchedule { events: Vec::new(), drain: 1 }
    }

    #[inline]
    pub fn is_active(&self) -> bool {
        !self.events.is_empty()
    }

    /// Membership before any LB round has run: a node whose earliest
    /// event is a join starts inactive, everyone else starts active.
    pub fn initial_alive(&self, n_nodes: usize) -> Vec<bool> {
        let mut alive = vec![true; n_nodes];
        for node in 0..n_nodes as u32 {
            if let Some(first) = self
                .events
                .iter()
                .filter(|e| e.node == node)
                .min_by_key(|e| e.lb_round)
            {
                if first.join {
                    alive[node as usize] = false;
                }
            }
        }
        alive
    }

    /// Membership after the events of LB rounds `0..=lb_round` have
    /// applied (events within a round apply in schedule order).
    pub fn alive_after(&self, lb_round: usize, n_nodes: usize) -> Vec<bool> {
        let mut alive = self.initial_alive(n_nodes);
        let mut idx: Vec<usize> = (0..self.events.len())
            .filter(|&i| self.events[i].lb_round <= lb_round)
            .collect();
        idx.sort_by_key(|&i| (self.events[i].lb_round, i));
        for i in idx {
            let e = &self.events[i];
            alive[e.node as usize] = e.join;
        }
        alive
    }

    /// Membership entering LB round `lb_round` (before its events).
    pub fn alive_before(&self, lb_round: usize, n_nodes: usize) -> Vec<bool> {
        match lb_round.checked_sub(1) {
            Some(prev) => self.alive_after(prev, n_nodes),
            None => self.initial_alive(n_nodes),
        }
    }

    /// The effective topology for LB round `lb_round`: nodes in their
    /// drain window (the `drain` rounds preceding a leave) have their
    /// PE speeds scaled to `1e-3` so the diffusion stages bleed their
    /// load off before the hard exclusion. Identity when nothing is
    /// draining.
    pub fn drained_topo(&self, base: &Topology, lb_round: usize) -> Topology {
        let draining: Vec<u32> = self
            .events
            .iter()
            .filter(|e| {
                !e.join
                    && lb_round < e.lb_round
                    && lb_round + self.drain >= e.lb_round
            })
            .map(|e| e.node)
            .collect();
        if draining.is_empty() {
            return base.clone();
        }
        let mut speeds: Vec<f64> =
            (0..base.n_pes() as u32).map(|pe| base.pe_speed(pe)).collect();
        for node in draining {
            for pe in base.pes_of_node(node) {
                speeds[pe as usize] *= 1e-3;
            }
        }
        base.clone().with_pe_speeds(speeds)
    }

    /// Sanity-check against a world size: node 0 never leaves (it hosts
    /// the LB root), every event targets a real node, and each node has
    /// at most one event — the distributed runtime retires a leaver's
    /// thread and seeds a joiner's once; re-joining a departed rank
    /// would need thread resurrection (pure membership replay via
    /// [`ResizeSchedule::alive_after`] supports it, the runtime does
    /// not).
    pub fn validate(&self, n_nodes: usize) -> anyhow::Result<()> {
        for (i, e) in self.events.iter().enumerate() {
            if e.node as usize >= n_nodes {
                anyhow::bail!("resize event targets node {} of {n_nodes}", e.node);
            }
            if e.node == 0 {
                anyhow::bail!("resize schedule touches node 0 (the LB root must stay)");
            }
            if self.events[..i].iter().any(|p| p.node == e.node) {
                anyhow::bail!("node {} has more than one resize event", e.node);
            }
        }
        Ok(())
    }

    /// Parse a schedule spec: comma-separated `leave:NODE@ROUND` /
    /// `join:NODE@ROUND` events, e.g. `leave:2@3,join:5@7`.
    pub fn parse(spec: &str) -> anyhow::Result<ResizeSchedule> {
        let mut sched = ResizeSchedule::none();
        for seg in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (kind, rest) = seg
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("resize event '{seg}' missing ':'"))?;
            let join = match kind {
                "join" => true,
                "leave" => false,
                other => anyhow::bail!("unknown resize kind '{other}' in '{seg}'"),
            };
            let (node_s, round_s) = rest
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("resize event '{seg}' missing '@ROUND'"))?;
            let node: u32 = node_s
                .parse()
                .map_err(|e| anyhow::anyhow!("bad node in '{seg}': {e}"))?;
            let lb_round: usize = round_s
                .parse()
                .map_err(|e| anyhow::anyhow!("bad round in '{seg}': {e}"))?;
            sched.events.push(ResizeEvent { node, join, lb_round });
        }
        Ok(sched)
    }
}

impl Default for ResizeSchedule {
    fn default() -> ResizeSchedule {
        ResizeSchedule::none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_topology() {
        let t = Topology::flat(8);
        assert_eq!(t.n_pes(), 8);
        assert_eq!(t.node_of_pe(5), 5);
        assert_eq!(t.pes_of_node(5), 5..6);
        assert!(t.is_uniform());
        assert_eq!(t.pe_speed(3), 1.0);
        assert_eq!(t.node_capacity(5), 1.0);
    }

    #[test]
    fn hierarchical_topology() {
        let t = Topology::new(4, 16);
        assert_eq!(t.n_pes(), 64);
        assert_eq!(t.node_of_pe(0), 0);
        assert_eq!(t.node_of_pe(17), 1);
        assert_eq!(t.local_of_pe(17), 1);
        assert_eq!(t.pes_of_node(3), 48..64);
        assert_eq!(t.node_capacity(2), 16.0);
    }

    #[test]
    #[should_panic]
    fn zero_nodes_rejected() {
        Topology::new(0, 1);
    }

    #[test]
    fn speeds_attach_and_aggregate() {
        let t = Topology::new(2, 2).with_pe_speeds(vec![1.0, 2.0, 0.5, 1.5]);
        assert!(!t.is_uniform());
        assert_eq!(t.pe_speed(1), 2.0);
        assert_eq!(t.node_capacity(0), 3.0);
        assert_eq!(t.node_capacity(1), 2.0);
        assert_eq!(t.pe_speeds().unwrap(), &[1.0, 2.0, 0.5, 1.5]);
    }

    #[test]
    fn unit_speeds_canonicalize_to_uniform() {
        let t = Topology::flat(4).with_pe_speeds(vec![1.0; 4]);
        assert!(t.is_uniform());
        assert!(t.pe_speeds().is_none());
        assert_eq!(t, Topology::flat(4));
    }

    #[test]
    #[should_panic]
    fn wrong_speed_length_rejected() {
        Topology::flat(4).with_pe_speeds(vec![1.0; 3]);
    }

    #[test]
    #[should_panic]
    fn non_positive_speed_rejected() {
        Topology::flat(2).with_pe_speeds(vec![1.0, 0.0]);
    }

    #[test]
    fn schedule_inactive_is_identity() {
        let base = Topology::flat(4).with_pe_speeds(vec![1.0, 2.0, 1.0, 0.5]);
        let sched = SpeedSchedule::none();
        assert_eq!(sched.topo_at(&base, 0), base);
        assert_eq!(sched.topo_at(&base, 17), base);
    }

    #[test]
    fn resize_membership_replays_events() {
        let s = ResizeSchedule::parse("leave:2@3,join:4@5,join:2@7").unwrap();
        assert!(s.is_active());
        s.validate(6).unwrap();
        // node 4's first event is a join: it starts inactive
        assert_eq!(s.initial_alive(6), vec![true, true, true, true, false, true]);
        assert_eq!(s.alive_before(3, 6), s.initial_alive(6));
        assert_eq!(s.alive_after(3, 6), vec![true, true, false, true, false, true]);
        assert_eq!(s.alive_after(5, 6), vec![true, true, false, true, true, true]);
        // node 2 rejoins at round 7
        assert_eq!(s.alive_after(7, 6), vec![true; 6]);
    }

    #[test]
    fn resize_inert_schedule_is_identity() {
        let s = ResizeSchedule::none();
        assert!(!s.is_active());
        assert_eq!(s.initial_alive(4), vec![true; 4]);
        assert_eq!(s.alive_after(10, 4), vec![true; 4]);
        let base = Topology::new(4, 2).with_pe_speeds(vec![1.0, 2.0, 0.5, 1.5, 1.0, 1.0, 3.0, 0.25]);
        assert_eq!(s.drained_topo(&base, 0), base);
    }

    #[test]
    fn resize_drain_scales_the_leaver() {
        let s = ResizeSchedule { drain: 2, ..ResizeSchedule::parse("leave:1@4").unwrap() };
        let base = Topology::new(3, 1);
        // rounds 2 and 3 are the drain window; 4 is the exclusion round
        assert_eq!(s.drained_topo(&base, 1), base);
        let d = s.drained_topo(&base, 2);
        assert_eq!(d.pe_speed(1), 1e-3);
        assert_eq!(d.pe_speed(0), 1.0);
        assert_eq!(s.drained_topo(&base, 3).pe_speed(1), 1e-3);
        assert_eq!(s.drained_topo(&base, 4), base, "excluded, not drained");
    }

    #[test]
    fn resize_validate_rejects_bad_schedules() {
        assert!(ResizeSchedule::parse("leave:0@2").unwrap().validate(4).is_err());
        assert!(ResizeSchedule::parse("join:0@2").unwrap().validate(4).is_err());
        assert!(ResizeSchedule::parse("leave:9@2").unwrap().validate(4).is_err());
        assert!(ResizeSchedule::parse("shrink:1@2").is_err());
        assert!(ResizeSchedule::parse("leave:1").is_err());
        // rejoin needs thread resurrection: one event per node
        assert!(ResizeSchedule::parse("leave:2@3,join:2@7").unwrap().validate(4).is_err());
        assert!(ResizeSchedule::parse("leave:2@3,join:3@5").unwrap().validate(4).is_ok());
    }

    #[test]
    fn schedule_is_deterministic_and_varies() {
        let base = Topology::flat(8);
        let sched = SpeedSchedule { noise: 0.3, period: 2, seed: 42 };
        let a = sched.topo_at(&base, 4);
        let b = sched.topo_at(&base, 4);
        assert_eq!(a, b, "same iter must give the same speeds");
        // same epoch (period 2): iters 4 and 5 agree
        assert_eq!(a, sched.topo_at(&base, 5));
        // different epoch: speeds change
        assert_ne!(a, sched.topo_at(&base, 6));
        // perturbed but positive and bounded
        let s = a.pe_speeds().unwrap();
        assert!(s.iter().all(|&v| v > 0.0 && (0.69..=1.31).contains(&v)));
    }
}
