//! The paper's evaluation metrics (§II problem definition):
//! (1) load imbalance = max/avg processor load,
//! (2) communication cost = external / internal bytes,
//! (3) migration cost = objects moved (count and %),
//! (4) strategy cost = wall-clock of computing the mapping.

use super::instance::{Assignment, Instance};
use crate::util::stats::Summary;

/// Communication split under a mapping, at some grouping granularity.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommSplit {
    /// Bytes on edges whose endpoints share a group (node).
    pub internal: f64,
    /// Bytes on edges crossing groups.
    pub external: f64,
}

impl CommSplit {
    /// The paper's external/internal ratio; 0 when nothing is internal
    /// and nothing is external, +inf when only external traffic exists.
    pub fn ratio(&self) -> f64 {
        if self.internal == 0.0 {
            if self.external == 0.0 {
                0.0
            } else {
                f64::INFINITY
            }
        } else {
            self.external / self.internal
        }
    }
}

/// Communication split at **node** granularity (the paper's inter-node
/// vs intra-node bytes).
pub fn comm_split_nodes(inst: &Instance, mapping: &[u32]) -> CommSplit {
    let mut internal = 0.0;
    let mut external = 0.0;
    for (a, b, w) in inst.graph.edges() {
        let na = inst.topo.node_of_pe(mapping[a as usize]);
        let nb = inst.topo.node_of_pe(mapping[b as usize]);
        if na == nb {
            internal += w;
        } else {
            external += w;
        }
    }
    CommSplit { internal, external }
}

/// Communication split at **PE** granularity.
pub fn comm_split_pes(inst: &Instance, mapping: &[u32]) -> CommSplit {
    let mut internal = 0.0;
    let mut external = 0.0;
    for (a, b, w) in inst.graph.edges() {
        if mapping[a as usize] == mapping[b as usize] {
            internal += w;
        } else {
            external += w;
        }
    }
    CommSplit { internal, external }
}

/// Full evaluation of an assignment against the paper's four metrics.
#[derive(Debug, Clone)]
pub struct LbMetrics {
    pub max_avg_pe: f64,
    pub max_avg_node: f64,
    /// max/avg of per-PE normalized time (`work / speed`) — equal to
    /// `max_avg_pe` on uniform topologies, the quantity heterogeneous
    /// strategies actually balance otherwise.
    pub time_max_avg_pe: f64,
    /// max/avg of per-node normalized time (`work / node capacity`).
    pub time_max_avg_node: f64,
    pub comm_nodes: CommSplit,
    pub comm_pes: CommSplit,
    pub migrations: usize,
    pub migration_pct: f64,
    /// Bytes that must move to realize the migrations.
    pub migration_bytes: f64,
    /// Wall-clock seconds spent inside the strategy (filled by caller).
    pub strategy_s: f64,
}

pub fn evaluate(inst: &Instance, asg: &Assignment) -> LbMetrics {
    evaluate_mapping(inst, &asg.mapping)
}

pub fn evaluate_mapping(inst: &Instance, mapping: &[u32]) -> LbMetrics {
    let pe = Summary::of(&inst.pe_loads(mapping));
    let node = Summary::of(&inst.node_loads(mapping));
    // uniform topologies: times are definitionally (and bitwise) the
    // raw loads — skip the two extra scans/allocations
    let (time_pe_ratio, time_node_ratio) = if inst.topo.is_uniform() {
        (pe.max_avg_ratio(), node.max_avg_ratio())
    } else {
        (
            Summary::of(&inst.pe_times(mapping)).max_avg_ratio(),
            Summary::of(&inst.node_times(mapping)).max_avg_ratio(),
        )
    };
    let migrations = mapping
        .iter()
        .zip(&inst.mapping)
        .filter(|(a, b)| a != b)
        .count();
    let migration_bytes: f64 = mapping
        .iter()
        .zip(&inst.mapping)
        .enumerate()
        .filter(|(_, (a, b))| a != b)
        .map(|(o, _)| inst.sizes[o])
        .sum();
    LbMetrics {
        max_avg_pe: pe.max_avg_ratio(),
        max_avg_node: node.max_avg_ratio(),
        time_max_avg_pe: time_pe_ratio,
        time_max_avg_node: time_node_ratio,
        comm_nodes: comm_split_nodes(inst, mapping),
        comm_pes: comm_split_pes(inst, mapping),
        migrations,
        migration_pct: 100.0 * migrations as f64 / inst.n_objects().max(1) as f64,
        migration_bytes,
        strategy_s: 0.0,
    }
}

impl std::fmt::Display for LbMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "max/avg(pe)={:.3} t-max/avg(pe)={:.3} max/avg(node)={:.3} ext/int={:.4} \
             migr={} ({:.1}%) lb={:.1}ms",
            self.max_avg_pe,
            self.time_max_avg_pe,
            self.max_avg_node,
            self.comm_nodes.ratio(),
            self.migrations,
            self.migration_pct,
            self.strategy_s * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::graph::CommGraph;
    use crate::model::topology::Topology;

    fn inst() -> Instance {
        // 4 objects in a path 0-1-2-3, loads 1..4, two PEs on one node +
        // two separate single-PE nodes? Keep it simple: 2 nodes x 2 PEs.
        let graph = CommGraph::from_edges(4, &[(0, 1, 10.0), (1, 2, 20.0), (2, 3, 30.0)]);
        Instance::new(
            vec![1.0, 1.0, 1.0, 1.0],
            vec![[0.0, 0.0]; 4],
            graph,
            vec![0, 1, 2, 3], // one object per PE
            Topology::new(2, 2),
        )
    }

    #[test]
    fn comm_splits() {
        let i = inst();
        // nodes: {pe0,pe1}=node0 has objs 0,1; {pe2,pe3}=node1 has 2,3.
        let n = comm_split_nodes(&i, &i.mapping);
        assert_eq!(n.internal, 40.0); // 0-1 and 2-3
        assert_eq!(n.external, 20.0); // 1-2
        let p = comm_split_pes(&i, &i.mapping);
        assert_eq!(p.internal, 0.0);
        assert_eq!(p.external, 60.0);
        assert!((n.ratio() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn ratio_edge_cases() {
        assert_eq!(CommSplit { internal: 0.0, external: 0.0 }.ratio(), 0.0);
        assert_eq!(CommSplit { internal: 0.0, external: 5.0 }.ratio(), f64::INFINITY);
    }

    #[test]
    fn evaluate_counts_migrations() {
        let i = inst();
        let asg = Assignment { mapping: vec![0, 1, 2, 2] };
        let m = evaluate(&i, &asg);
        assert_eq!(m.migrations, 1);
        assert_eq!(m.migration_pct, 25.0);
        assert_eq!(m.migration_bytes, 1.0);
        // node loads become [2, 2] -> balanced
        assert!((m.max_avg_node - 1.0).abs() < 1e-12);
        // pe loads [1,1,2,0] -> max/avg = 2
        assert!((m.max_avg_pe - 2.0).abs() < 1e-12);
        // uniform topology: time metrics coincide with the raw ones
        assert_eq!(m.time_max_avg_pe, m.max_avg_pe);
        assert_eq!(m.time_max_avg_node, m.max_avg_node);
    }

    #[test]
    fn time_metrics_follow_speeds() {
        let mut i = inst();
        // pe2 runs 2x as fast: raw loads [1,1,1,1] -> times [1,1,0.5,1]
        i.topo = i.topo.clone().with_pe_speeds(vec![1.0, 1.0, 2.0, 1.0]);
        let m = evaluate_mapping(&i, &i.mapping);
        assert_eq!(m.max_avg_pe, 1.0);
        let expect = 1.0 / (3.5 / 4.0);
        assert!((m.time_max_avg_pe - expect).abs() < 1e-12, "{}", m.time_max_avg_pe);
    }
}
