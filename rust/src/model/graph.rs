//! Sparse weighted object-communication graph (CSR).
//!
//! Vertices are migratable objects; an undirected edge `(a, b, bytes)`
//! records how many bytes the two objects exchanged since the last load
//! balancing step (paper §II problem definition). CSR keeps the hot
//! strategy loops (per-object neighbor scans during object selection)
//! cache-friendly.

use std::collections::HashMap;

/// Compressed-sparse-row undirected graph with f64 edge weights.
#[derive(Debug, Clone, PartialEq)]
pub struct CommGraph {
    /// Number of vertices (objects).
    pub n: usize,
    /// CSR row offsets, length `n + 1`.
    pub offsets: Vec<u32>,
    /// Column indices (neighbor object ids), length = 2 * #edges.
    pub nbrs: Vec<u32>,
    /// Edge weights in bytes, parallel to `nbrs`.
    pub bytes: Vec<f64>,
}

impl CommGraph {
    /// Empty graph over `n` objects.
    pub fn empty(n: usize) -> CommGraph {
        CommGraph { n, offsets: vec![0; n + 1], nbrs: Vec::new(), bytes: Vec::new() }
    }

    /// Build from an undirected edge list; parallel edges are merged by
    /// summing weights, self-loops are dropped.
    pub fn from_edges(n: usize, edges: &[(u32, u32, f64)]) -> CommGraph {
        let mut merged: HashMap<(u32, u32), f64> = HashMap::with_capacity(edges.len());
        for &(a, b, w) in edges {
            assert!((a as usize) < n && (b as usize) < n, "edge out of range");
            if a == b {
                continue;
            }
            let key = if a < b { (a, b) } else { (b, a) };
            *merged.entry(key).or_insert(0.0) += w;
        }
        let mut degree = vec![0u32; n];
        for &(a, b) in merged.keys() {
            degree[a as usize] += 1;
            degree[b as usize] += 1;
        }
        let mut offsets = vec![0u32; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + degree[i];
        }
        let m2 = offsets[n] as usize;
        let mut nbrs = vec![0u32; m2];
        let mut bytes = vec![0.0; m2];
        let mut cursor = offsets[..n].to_vec();
        let mut pairs: Vec<(&(u32, u32), &f64)> = merged.iter().collect();
        // Deterministic layout regardless of hash order.
        pairs.sort_by_key(|(k, _)| **k);
        for (&(a, b), &w) in pairs {
            let ca = cursor[a as usize] as usize;
            nbrs[ca] = b;
            bytes[ca] = w;
            cursor[a as usize] += 1;
            let cb = cursor[b as usize] as usize;
            nbrs[cb] = a;
            bytes[cb] = w;
            cursor[b as usize] += 1;
        }
        CommGraph { n, offsets, nbrs, bytes }
    }

    /// Neighbor ids of object `o`.
    #[inline]
    pub fn neighbors(&self, o: usize) -> &[u32] {
        &self.nbrs[self.offsets[o] as usize..self.offsets[o + 1] as usize]
    }

    /// Edge weights of object `o`, parallel to [`Self::neighbors`].
    #[inline]
    pub fn weights(&self, o: usize) -> &[f64] {
        &self.bytes[self.offsets[o] as usize..self.offsets[o + 1] as usize]
    }

    #[inline]
    pub fn degree(&self, o: usize) -> usize {
        (self.offsets[o + 1] - self.offsets[o]) as usize
    }

    /// Number of undirected edges.
    pub fn edge_count(&self) -> usize {
        self.nbrs.len() / 2
    }

    /// Total bytes over undirected edges (each edge once).
    pub fn total_bytes(&self) -> f64 {
        self.bytes.iter().sum::<f64>() / 2.0
    }

    /// Total bytes object `o` exchanges with all neighbors.
    pub fn object_bytes(&self, o: usize) -> f64 {
        self.weights(o).iter().sum()
    }

    /// Iterate undirected edges once as `(a, b, w)` with `a < b`.
    pub fn edges(&self) -> impl Iterator<Item = (u32, u32, f64)> + '_ {
        (0..self.n).flat_map(move |a| {
            self.neighbors(a)
                .iter()
                .zip(self.weights(a))
                .filter(move |(&b, _)| (a as u32) < b)
                .map(move |(&b, &w)| (a as u32, b, w))
        })
    }

    /// Dense variant of [`Self::group_traffic`]: an `n_groups x n_groups`
    /// symmetric matrix (diagonal = intra-group bytes). Preferred on the
    /// strategy hot path when `n_groups` is moderate — HashMap probing
    /// dominated stage-1 candidate construction (EXPERIMENTS.md §Perf).
    pub fn group_traffic_dense(&self, group: &[u32], n_groups: usize) -> Vec<f64> {
        assert_eq!(group.len(), self.n);
        let mut m = vec![0.0f64; n_groups * n_groups];
        for (a, b, w) in self.edges() {
            let ga = group[a as usize] as usize;
            let gb = group[b as usize] as usize;
            if ga == gb {
                m[ga * n_groups + ga] += w;
            } else {
                m[ga * n_groups + gb] += w;
                m[gb * n_groups + ga] += w;
            }
        }
        m
    }

    /// Aggregate object-level traffic to group-level (e.g. node-level)
    /// traffic under `group[o]`: returns per-group sparse rows
    /// `group -> (peer_group -> bytes)`, diagonal = intra-group bytes
    /// (each undirected edge counted once on the diagonal, once per
    /// direction off-diagonal so rows are symmetric views).
    pub fn group_traffic(&self, group: &[u32], n_groups: usize) -> Vec<HashMap<u32, f64>> {
        assert_eq!(group.len(), self.n);
        let mut rows: Vec<HashMap<u32, f64>> = vec![HashMap::new(); n_groups];
        for (a, b, w) in self.edges() {
            let ga = group[a as usize];
            let gb = group[b as usize];
            if ga == gb {
                *rows[ga as usize].entry(ga).or_insert(0.0) += w;
            } else {
                *rows[ga as usize].entry(gb).or_insert(0.0) += w;
                *rows[gb as usize].entry(ga).or_insert(0.0) += w;
            }
        }
        rows
    }
}

/// Incremental edge accumulator used by the apps to record traffic
/// between LB steps, then freeze into a [`CommGraph`].
#[derive(Debug, Clone, Default)]
pub struct TrafficRecorder {
    edges: HashMap<(u32, u32), f64>,
    n: usize,
}

impl TrafficRecorder {
    pub fn new(n: usize) -> Self {
        TrafficRecorder { edges: HashMap::new(), n }
    }

    /// Record `bytes` of traffic between objects `a` and `b`.
    #[inline]
    pub fn record(&mut self, a: u32, b: u32, bytes: f64) {
        if a == b {
            return;
        }
        let key = if a < b { (a, b) } else { (b, a) };
        *self.edges.entry(key).or_insert(0.0) += bytes;
    }

    pub fn is_empty(&self) -> bool {
        self.edges.is_empty()
    }

    /// Freeze into a CSR graph and clear the recorder.
    pub fn take_graph(&mut self) -> CommGraph {
        let edges: Vec<(u32, u32, f64)> =
            self.edges.drain().map(|((a, b), w)| (a, b, w)).collect();
        CommGraph::from_edges(self.n, &edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> CommGraph {
        CommGraph::from_edges(4, &[(0, 1, 10.0), (1, 2, 20.0), (2, 0, 30.0)])
    }

    #[test]
    fn csr_shape_and_symmetry() {
        let g = triangle();
        assert_eq!(g.edge_count(), 3);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(3), 0);
        assert_eq!(g.total_bytes(), 60.0);
        // symmetry: weight(a->b) == weight(b->a)
        for (a, b, w) in g.edges() {
            let pos = g.neighbors(b as usize).iter().position(|&x| x == a).unwrap();
            assert_eq!(g.weights(b as usize)[pos], w);
        }
    }

    #[test]
    fn parallel_edges_merge_self_loops_drop() {
        let g = CommGraph::from_edges(2, &[(0, 1, 5.0), (1, 0, 7.0), (0, 0, 99.0)]);
        assert_eq!(g.edge_count(), 1);
        assert_eq!(g.total_bytes(), 12.0);
    }

    #[test]
    fn group_traffic_aggregates() {
        let g = triangle();
        // objects 0,1 -> group 0; 2,3 -> group 1
        let rows = g.group_traffic(&[0, 0, 1, 1], 2);
        assert_eq!(rows[0][&0], 10.0); // intra edge 0-1
        assert_eq!(rows[0][&1], 50.0); // 1-2 and 2-0 cross
        assert_eq!(rows[1][&0], 50.0);
        assert!(!rows[1].contains_key(&1));
    }

    #[test]
    fn recorder_round_trip() {
        let mut r = TrafficRecorder::new(3);
        r.record(0, 1, 4.0);
        r.record(1, 0, 6.0);
        r.record(2, 2, 50.0); // self, ignored
        let g = r.take_graph();
        assert_eq!(g.edge_count(), 1);
        assert_eq!(g.total_bytes(), 10.0);
        assert!(r.is_empty());
    }

    #[test]
    fn deterministic_construction() {
        let e = vec![(0u32, 1u32, 1.0), (1, 2, 2.0), (0, 2, 3.0), (2, 3, 4.0)];
        let g1 = CommGraph::from_edges(4, &e);
        let mut rev = e.clone();
        rev.reverse();
        let g2 = CommGraph::from_edges(4, &rev);
        assert_eq!(g1, g2);
    }
}
