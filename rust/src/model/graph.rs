//! Sparse weighted object-communication graph (CSR).
//!
//! Vertices are migratable objects; an undirected edge `(a, b, bytes)`
//! records how many bytes the two objects exchanged since the last load
//! balancing step (paper §II problem definition). CSR keeps the hot
//! strategy loops (per-object neighbor scans during object selection)
//! cache-friendly.
//!
//! Construction is hash-free: edge lists are canonicalized, stably
//! sorted and sum-merged, which is both faster than the seed's
//! `HashMap<(u32,u32), f64>` merge (no probing, no per-entry
//! allocation) and produces the identical graph — the stable sort
//! preserves each key's input accumulation order, so even the f64 sums
//! are bit-equal to the old entry-API accumulation.

/// Compressed-sparse-row undirected graph with f64 edge weights.
#[derive(Debug, Clone, PartialEq)]
pub struct CommGraph {
    /// Number of vertices (objects).
    pub n: usize,
    /// CSR row offsets, length `n + 1`.
    pub offsets: Vec<u32>,
    /// Column indices (neighbor object ids), length = 2 * #edges.
    pub nbrs: Vec<u32>,
    /// Edge weights in bytes, parallel to `nbrs`.
    pub bytes: Vec<f64>,
}

/// Stably sort `(key_a, key_b, value)` entries by key and sum-merge
/// adjacent duplicates in place. This is the shared primitive behind
/// every flat accumulation path in the codebase (graph construction,
/// group-traffic aggregation, the apps' per-step crosser logs): the
/// **stable** sort keeps each key's values in input order, so the f64
/// sums accumulate left-to-right exactly like the seed's HashMap
/// entry-API did — that ordering is what the bit-identical claims
/// rest on. Keep every merge on this helper.
pub fn sort_sum_merge(entries: &mut Vec<(u32, u32, f64)>) {
    entries.sort_by_key(|&(a, b, _)| (a, b));
    let mut w = 0usize;
    for r in 0..entries.len() {
        if w > 0 && entries[w - 1].0 == entries[r].0 && entries[w - 1].1 == entries[r].1 {
            entries[w - 1].2 += entries[r].2;
        } else {
            entries[w] = entries[r];
            w += 1;
        }
    }
    entries.truncate(w);
}

/// Canonicalize (`a < b`), stably sort and sum-merge an edge list in
/// place; drops self-loops. After return `edges` holds each undirected
/// edge once, sorted by `(a, b)`.
fn canonical_merge(edges: &mut Vec<(u32, u32, f64)>) {
    for e in edges.iter_mut() {
        if e.0 > e.1 {
            std::mem::swap(&mut e.0, &mut e.1);
        }
    }
    edges.retain(|e| e.0 != e.1);
    sort_sum_merge(edges);
}

impl CommGraph {
    /// Empty graph over `n` objects.
    pub fn empty(n: usize) -> CommGraph {
        CommGraph { n, offsets: vec![0; n + 1], nbrs: Vec::new(), bytes: Vec::new() }
    }

    /// Build from an undirected edge list; parallel edges are merged by
    /// summing weights, self-loops are dropped.
    pub fn from_edges(n: usize, edges: &[(u32, u32, f64)]) -> CommGraph {
        for &(a, b, _) in edges {
            assert!((a as usize) < n && (b as usize) < n, "edge out of range");
        }
        let mut canon = edges.to_vec();
        canonical_merge(&mut canon);
        let mut g = CommGraph::empty(n);
        let mut cursor = Vec::new();
        g.refill_from_merged(&canon, &mut cursor);
        g
    }

    /// Build from an edge list that is **already canonical**: `a < b`,
    /// strictly sorted by `(a, b)`, no duplicates, no self-loops — the
    /// order [`Self::edges`] yields and the `.lbi` binary codec
    /// preserves on the wire. Skips `canonical_merge`'s sort entirely,
    /// which is what makes the distributed `.lbi` decode O(m) instead
    /// of O(m log m). Panics (in checked form) on non-canonical input.
    pub fn from_canonical_edges(n: usize, merged: &[(u32, u32, f64)]) -> CommGraph {
        for w in merged.windows(2) {
            assert!((w[0].0, w[0].1) < (w[1].0, w[1].1), "edges not strictly (a,b)-sorted");
        }
        for &(a, b, _) in merged {
            assert!(a < b && (b as usize) < n, "edge not canonical or out of range");
        }
        let mut g = CommGraph::empty(n);
        let mut cursor = Vec::new();
        g.refill_from_merged(merged, &mut cursor);
        g
    }

    /// Rebuild this graph's CSR arrays from a canonical merged edge
    /// list (sorted by `(a, b)`, unique, self-loop free), reusing the
    /// existing allocations. `cursor` is caller-provided scratch.
    fn refill_from_merged(&mut self, merged: &[(u32, u32, f64)], cursor: &mut Vec<u32>) {
        let n = self.n;
        self.offsets.clear();
        self.offsets.resize(n + 1, 0);
        for &(a, b, _) in merged {
            self.offsets[a as usize + 1] += 1;
            self.offsets[b as usize + 1] += 1;
        }
        for i in 0..n {
            self.offsets[i + 1] += self.offsets[i];
        }
        let m2 = self.offsets[n] as usize;
        self.nbrs.clear();
        self.nbrs.resize(m2, 0);
        self.bytes.clear();
        self.bytes.resize(m2, 0.0);
        cursor.clear();
        cursor.extend_from_slice(&self.offsets[..n]);
        // Iterating merged in (a, b) order fills every row in ascending
        // neighbor order: row i first receives partners a' < i (as the
        // `b` endpoint), then partners b > i (as the `a` endpoint) —
        // the same deterministic layout the seed produced.
        for &(a, b, w) in merged {
            let ca = cursor[a as usize] as usize;
            self.nbrs[ca] = b;
            self.bytes[ca] = w;
            cursor[a as usize] += 1;
            let cb = cursor[b as usize] as usize;
            self.nbrs[cb] = a;
            self.bytes[cb] = w;
            cursor[b as usize] += 1;
        }
    }

    /// Refresh this graph from everything `rec` accumulated since its
    /// last drain, draining the recorder. Equivalent to
    /// `*self = rec.take_graph()` but allocation-free at steady state:
    /// when the communication *structure* is unchanged (same neighbor
    /// sets — the common case for persistently interacting objects
    /// between LB rounds), only the weight array is overwritten; a
    /// structural change falls back to refilling the CSR arrays in
    /// place (row lengths shift, so offsets/nbrs must be rewritten, but
    /// capacity is reused). Returns `true` when the structure changed.
    pub fn update_from_recorder(&mut self, rec: &mut TrafficRecorder) -> bool {
        assert_eq!(self.n, rec.n(), "recorder/graph vertex count mismatch");
        rec.merge();
        let n = self.n;
        let TrafficRecorder { ref merged, ref mut cursor, .. } = *rec;

        // Fast path: verify the merged edge stream matches the current
        // adjacency structure while overwriting weights.
        let mut same = self.offsets.len() == n + 1 && self.nbrs.len() == 2 * merged.len();
        if same {
            cursor.clear();
            cursor.extend_from_slice(&self.offsets[..n]);
            'walk: for &(a, b, w) in merged.iter() {
                let (a, b) = (a as usize, b as usize);
                let ca = cursor[a] as usize;
                let cb = cursor[b] as usize;
                if ca >= self.offsets[a + 1] as usize
                    || cb >= self.offsets[b + 1] as usize
                    || self.nbrs[ca] != b as u32
                    || self.nbrs[cb] != a as u32
                {
                    same = false;
                    break 'walk;
                }
                self.bytes[ca] = w;
                self.bytes[cb] = w;
                cursor[a] += 1;
                cursor[b] += 1;
            }
        }
        if !same {
            self.refill_from_merged(merged, cursor);
        }
        rec.clear_round();
        !same
    }

    /// Neighbor ids of object `o`.
    #[inline]
    pub fn neighbors(&self, o: usize) -> &[u32] {
        &self.nbrs[self.offsets[o] as usize..self.offsets[o + 1] as usize]
    }

    /// Edge weights of object `o`, parallel to [`Self::neighbors`].
    #[inline]
    pub fn weights(&self, o: usize) -> &[f64] {
        &self.bytes[self.offsets[o] as usize..self.offsets[o + 1] as usize]
    }

    #[inline]
    pub fn degree(&self, o: usize) -> usize {
        (self.offsets[o + 1] - self.offsets[o]) as usize
    }

    /// Number of undirected edges.
    pub fn edge_count(&self) -> usize {
        self.nbrs.len() / 2
    }

    /// Total bytes over undirected edges (each edge once).
    pub fn total_bytes(&self) -> f64 {
        self.bytes.iter().sum::<f64>() / 2.0
    }

    /// Total bytes object `o` exchanges with all neighbors.
    pub fn object_bytes(&self, o: usize) -> f64 {
        self.weights(o).iter().sum()
    }

    /// Iterate undirected edges once as `(a, b, w)` with `a < b`.
    pub fn edges(&self) -> impl Iterator<Item = (u32, u32, f64)> + '_ {
        (0..self.n).flat_map(move |a| {
            self.neighbors(a)
                .iter()
                .zip(self.weights(a))
                .filter(move |(&b, _)| (a as u32) < b)
                .map(move |(&b, &w)| (a as u32, b, w))
        })
    }

    /// Dense variant of [`Self::group_traffic`]: an `n_groups x n_groups`
    /// symmetric matrix (diagonal = intra-group bytes). Preferred on the
    /// strategy hot path when `n_groups` is moderate — HashMap probing
    /// dominated stage-1 candidate construction (EXPERIMENTS.md §Perf).
    pub fn group_traffic_dense(&self, group: &[u32], n_groups: usize) -> Vec<f64> {
        let mut m = vec![0.0f64; n_groups * n_groups];
        self.group_traffic_dense_into(group, n_groups, &mut m);
        m
    }

    /// [`Self::group_traffic_dense`] into a caller-owned buffer
    /// (resized/zeroed here), so repeated LB rounds reuse one matrix.
    pub fn group_traffic_dense_into(&self, group: &[u32], n_groups: usize, m: &mut Vec<f64>) {
        assert_eq!(group.len(), self.n);
        m.clear();
        m.resize(n_groups * n_groups, 0.0);
        for (a, b, w) in self.edges() {
            let ga = group[a as usize] as usize;
            let gb = group[b as usize] as usize;
            if ga == gb {
                m[ga * n_groups + ga] += w;
            } else {
                m[ga * n_groups + gb] += w;
                m[gb * n_groups + ga] += w;
            }
        }
    }

    /// Aggregate object-level traffic to group-level (e.g. node-level)
    /// traffic under `group[o]`: sparse symmetric rows in CSR layout
    /// (diagonal entry = intra-group bytes, present only when nonzero —
    /// each undirected edge counted once on the diagonal, once per
    /// direction off-diagonal so rows are symmetric views). The seed
    /// returned `Vec<HashMap<u32, f64>>` here; the CSR rows aggregate
    /// via the same sort-merge as graph construction and keep the
    /// quotient-graph consumers (ParMETIS baseline, future hierarchical
    /// levels) allocation-light and cache-friendly.
    pub fn group_traffic(&self, group: &[u32], n_groups: usize) -> GroupTraffic {
        assert_eq!(group.len(), self.n);
        let mut entries: Vec<(u32, u32, f64)> = Vec::with_capacity(2 * self.edge_count());
        for (a, b, w) in self.edges() {
            let ga = group[a as usize];
            let gb = group[b as usize];
            if ga == gb {
                entries.push((ga, ga, w));
            } else {
                entries.push((ga, gb, w));
                entries.push((gb, ga, w));
            }
        }
        // stable sort keeps per-cell accumulation in edge-iteration
        // order (bit-equal sums to the old HashMap accumulation)
        sort_sum_merge(&mut entries);
        let mut offsets = vec![0u32; n_groups + 1];
        for &(g, _, _) in &entries {
            offsets[g as usize + 1] += 1;
        }
        for i in 0..n_groups {
            offsets[i + 1] += offsets[i];
        }
        let peers = entries.iter().map(|&(_, p, _)| p).collect();
        let bytes = entries.iter().map(|&(_, _, v)| v).collect();
        GroupTraffic { n_groups, offsets, peers, bytes }
    }
}

/// Group-level traffic matrix in CSR form, produced by
/// [`CommGraph::group_traffic`]. Rows are sorted by peer id; the
/// diagonal (intra-group bytes) appears as a `peer == group` entry.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupTraffic {
    pub n_groups: usize,
    /// Row offsets, length `n_groups + 1`.
    pub offsets: Vec<u32>,
    /// Peer-group ids, sorted within each row.
    pub peers: Vec<u32>,
    /// Bytes, parallel to `peers`.
    pub bytes: Vec<f64>,
}

impl GroupTraffic {
    /// `(peer ids, bytes)` of group `g`'s row (includes the diagonal
    /// entry when intra-group traffic exists).
    #[inline]
    pub fn row(&self, g: usize) -> (&[u32], &[f64]) {
        let lo = self.offsets[g] as usize;
        let hi = self.offsets[g + 1] as usize;
        (&self.peers[lo..hi], &self.bytes[lo..hi])
    }

    /// Bytes between `g` and `peer` (0.0 when absent).
    pub fn get(&self, g: usize, peer: u32) -> f64 {
        let (peers, bytes) = self.row(g);
        match peers.binary_search(&peer) {
            Ok(i) => bytes[i],
            Err(_) => 0.0,
        }
    }

    /// Iterate `(peer, bytes)` over group `g`'s row.
    pub fn iter_row(&self, g: usize) -> impl Iterator<Item = (u32, f64)> + '_ {
        let (peers, bytes) = self.row(g);
        peers.iter().copied().zip(bytes.iter().copied())
    }
}

/// Incremental edge accumulator used by the apps to record traffic
/// between LB steps, then freeze into a [`CommGraph`].
///
/// `record` appends to a flat per-round edge log — no hashing, no
/// allocation once the log's capacity has warmed up — and freezing
/// sort-merges the log (stable, so f64 accumulation order matches the
/// seed's HashMap recorder bit-for-bit). For round-over-round use,
/// [`CommGraph::update_from_recorder`] refreshes an existing graph in
/// place instead of building a fresh one.
#[derive(Debug, Clone, Default)]
pub struct TrafficRecorder {
    n: usize,
    /// Raw per-record log, canonicalized to `a < b` on append.
    log: Vec<(u32, u32, f64)>,
    /// Merged scratch (one entry per distinct edge), reused per round.
    merged: Vec<(u32, u32, f64)>,
    /// CSR fill cursor scratch, reused per round.
    cursor: Vec<u32>,
    /// Log length that triggers an in-place compaction (adaptive:
    /// a multiple of the distinct-edge count observed last time).
    compact_at: usize,
}

/// First compaction threshold; afterwards adaptive (8x distinct edges).
const RECORDER_COMPACT_MIN: usize = 4096;

impl TrafficRecorder {
    pub fn new(n: usize) -> Self {
        TrafficRecorder { n, compact_at: RECORDER_COMPACT_MIN, ..Default::default() }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Record `bytes` of traffic between objects `a` and `b`.
    ///
    /// Amortized O(1): appends to the flat log; when the log outgrows
    /// a multiple of the distinct-edge count it is sum-merged in place,
    /// so memory stays O(distinct edges) over arbitrarily long LB
    /// periods (the seed's HashMap bound) while keeping the hot append
    /// hash-free. Compaction preserves the freeze result bit-for-bit:
    /// each edge's pre-compaction prefix sum equals the same
    /// left-to-right partial sum the final merge would have computed.
    #[inline]
    pub fn record(&mut self, a: u32, b: u32, bytes: f64) {
        if a == b {
            return;
        }
        debug_assert!((a as usize) < self.n && (b as usize) < self.n);
        self.log.push(if a < b { (a, b, bytes) } else { (b, a, bytes) });
        // `.max(MIN)` also covers `Default`-built recorders (compact_at 0)
        if self.log.len() >= self.compact_at.max(RECORDER_COMPACT_MIN) {
            sort_sum_merge(&mut self.log);
            self.compact_at = (self.log.len() * 8).max(RECORDER_COMPACT_MIN);
        }
    }

    /// No traffic recorded since the last freeze.
    pub fn is_empty(&self) -> bool {
        self.log.is_empty()
    }

    /// Sort-merge the log into `self.merged`.
    fn merge(&mut self) {
        self.merged.clear();
        self.merged.extend_from_slice(&self.log);
        canonical_merge(&mut self.merged);
    }

    fn clear_round(&mut self) {
        self.log.clear();
        self.merged.clear();
    }

    /// Freeze into a CSR graph and clear the recorder.
    pub fn take_graph(&mut self) -> CommGraph {
        self.merge();
        let mut g = CommGraph::empty(self.n);
        let TrafficRecorder { ref merged, ref mut cursor, .. } = *self;
        g.refill_from_merged(merged, cursor);
        self.clear_round();
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> CommGraph {
        CommGraph::from_edges(4, &[(0, 1, 10.0), (1, 2, 20.0), (2, 0, 30.0)])
    }

    #[test]
    fn canonical_constructor_matches_from_edges() {
        let g = triangle();
        let canon: Vec<(u32, u32, f64)> = g.edges().collect();
        assert_eq!(CommGraph::from_canonical_edges(4, &canon), g);
        // empty edge list is trivially canonical
        assert_eq!(CommGraph::from_canonical_edges(3, &[]), CommGraph::empty(3));
    }

    #[test]
    #[should_panic(expected = "not strictly")]
    fn canonical_constructor_rejects_unsorted() {
        CommGraph::from_canonical_edges(4, &[(1, 2, 1.0), (0, 1, 1.0)]);
    }

    #[test]
    fn csr_shape_and_symmetry() {
        let g = triangle();
        assert_eq!(g.edge_count(), 3);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(3), 0);
        assert_eq!(g.total_bytes(), 60.0);
        // symmetry: weight(a->b) == weight(b->a)
        for (a, b, w) in g.edges() {
            let pos = g.neighbors(b as usize).iter().position(|&x| x == a).unwrap();
            assert_eq!(g.weights(b as usize)[pos], w);
        }
    }

    #[test]
    fn rows_are_sorted_ascending() {
        let g = CommGraph::from_edges(
            5,
            &[(4, 0, 1.0), (0, 2, 2.0), (3, 0, 3.0), (0, 1, 4.0)],
        );
        assert_eq!(g.neighbors(0), &[1, 2, 3, 4]);
        assert_eq!(g.weights(0), &[4.0, 2.0, 3.0, 1.0]);
    }

    #[test]
    fn parallel_edges_merge_self_loops_drop() {
        let g = CommGraph::from_edges(2, &[(0, 1, 5.0), (1, 0, 7.0), (0, 0, 99.0)]);
        assert_eq!(g.edge_count(), 1);
        assert_eq!(g.total_bytes(), 12.0);
    }

    #[test]
    fn group_traffic_aggregates() {
        let g = triangle();
        // objects 0,1 -> group 0; 2,3 -> group 1
        let rows = g.group_traffic(&[0, 0, 1, 1], 2);
        assert_eq!(rows.get(0, 0), 10.0); // intra edge 0-1
        assert_eq!(rows.get(0, 1), 50.0); // 1-2 and 2-0 cross
        assert_eq!(rows.get(1, 0), 50.0);
        assert_eq!(rows.get(1, 1), 0.0);
        assert_eq!(rows.row(1).0, &[0]); // no diagonal entry for group 1
    }

    #[test]
    fn group_traffic_matches_dense() {
        let g = triangle();
        let group = [0u32, 1, 1, 0];
        let sparse = g.group_traffic(&group, 2);
        let dense = g.group_traffic_dense(&group, 2);
        for ga in 0..2 {
            for gb in 0..2u32 {
                assert_eq!(sparse.get(ga, gb), dense[ga * 2 + gb as usize], "{ga},{gb}");
            }
        }
    }

    #[test]
    fn recorder_round_trip() {
        let mut r = TrafficRecorder::new(3);
        r.record(0, 1, 4.0);
        r.record(1, 0, 6.0);
        r.record(2, 2, 50.0); // self, ignored
        let g = r.take_graph();
        assert_eq!(g.edge_count(), 1);
        assert_eq!(g.total_bytes(), 10.0);
        assert!(r.is_empty());
    }

    #[test]
    fn recorder_compaction_bounds_memory_and_preserves_sums() {
        let mut r = TrafficRecorder::new(4);
        let rounds = RECORDER_COMPACT_MIN * 3;
        for k in 0..rounds {
            r.record(0, 1, 1.0);
            r.record((k % 3) as u32, 3, 2.0);
        }
        // in-place compaction keeps the log at O(distinct edges), not
        // O(records): 4 distinct edges recorded ~25k times
        assert!(r.log.len() < RECORDER_COMPACT_MIN * 2, "log grew to {}", r.log.len());
        let g = r.take_graph();
        assert_eq!(g.edge_count(), 4);
        assert_eq!(g.total_bytes(), rounds as f64 * 3.0);
    }

    #[test]
    fn deterministic_construction() {
        let e = vec![(0u32, 1u32, 1.0), (1, 2, 2.0), (0, 2, 3.0), (2, 3, 4.0)];
        let g1 = CommGraph::from_edges(4, &e);
        let mut rev = e.clone();
        rev.reverse();
        let g2 = CommGraph::from_edges(4, &rev);
        assert_eq!(g1, g2);
    }

    #[test]
    fn incremental_update_equals_fresh_build() {
        // Round 1 establishes structure; round 2 changes only weights
        // (fast path); round 3 changes the edge set (rebuild path).
        let rounds: [&[(u32, u32, f64)]; 3] = [
            &[(0, 1, 1.0), (1, 2, 2.0), (0, 1, 0.5)],
            &[(1, 2, 9.0), (0, 1, 4.0)],
            &[(2, 3, 7.0), (0, 1, 1.0)],
        ];
        let mut inc = CommGraph::empty(4);
        let mut rec = TrafficRecorder::new(4);
        let mut fresh_rec = TrafficRecorder::new(4);
        for (i, edges) in rounds.iter().enumerate() {
            for &(a, b, w) in *edges {
                rec.record(a, b, w);
                fresh_rec.record(a, b, w);
            }
            let structural = inc.update_from_recorder(&mut rec);
            let fresh = fresh_rec.take_graph();
            assert_eq!(inc, fresh, "round {i}");
            // round 1: empty -> structure change; round 2 (same edges,
            // new weights): fast path; round 3: new edge appears
            assert_eq!(structural, i != 1, "round {i}");
        }
        assert!(rec.is_empty());
    }

    #[test]
    fn update_matches_take_graph_on_randomized_rounds() {
        use crate::util::rng::Rng;
        let n = 24;
        let mut rng = Rng::new(0xBEEF);
        let mut inc = CommGraph::empty(n);
        let mut rec = TrafficRecorder::new(n);
        for _round in 0..10 {
            let mut fresh_rec = TrafficRecorder::new(n);
            // persistent backbone + occasional churn
            for i in 0..n as u32 {
                let j = (i + 1) % n as u32;
                let w = rng.uniform(1.0, 5.0);
                rec.record(i, j, w);
                fresh_rec.record(i, j, w);
            }
            if rng.chance(0.4) {
                let a = rng.below(n as u64) as u32;
                let b = rng.below(n as u64) as u32;
                rec.record(a, b, 3.0);
                fresh_rec.record(a, b, 3.0);
            }
            inc.update_from_recorder(&mut rec);
            assert_eq!(inc, fresh_rec.take_graph());
        }
    }
}
