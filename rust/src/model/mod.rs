//! Core data model: migratable objects, their communication graph, the
//! node/PE topology, problem instances, and the paper's cost metrics.

pub mod graph;
pub mod instance;
pub mod lbi;
pub mod metrics;
pub mod topology;

pub use graph::{CommGraph, GroupTraffic, TrafficRecorder};
pub use lbi::{decode_lbi, encode_lbi};
pub use instance::{rehome_mapping, restrict_instance, Assignment, Instance, Restriction};
pub use metrics::{evaluate, evaluate_mapping, CommSplit, LbMetrics};
pub use topology::{ResizeEvent, ResizeSchedule, SpeedSchedule, Topology};
