//! Core data model: migratable objects, their communication graph, the
//! node/PE topology, problem instances, and the paper's cost metrics.

pub mod graph;
pub mod instance;
pub mod metrics;
pub mod topology;

pub use graph::{CommGraph, GroupTraffic, TrafficRecorder};
pub use instance::{Assignment, Instance};
pub use metrics::{evaluate, evaluate_mapping, CommSplit, LbMetrics};
pub use topology::{SpeedSchedule, Topology};
