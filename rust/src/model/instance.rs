//! A load-balancing problem instance — the exact interface the paper's
//! simulation infrastructure consumes (§V): per-object loads,
//! coordinates, and communication edges, plus the current
//! object-to-processor mapping. Strategies map an [`Instance`] to an
//! [`Assignment`]; they never see the application.
//!
//! Instances round-trip through a plain-text `.lbi` format so workloads
//! captured from the apps can be re-balanced offline (the paper's
//! "easily generated for any Charm++ application" input files).

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::graph::CommGraph;
use super::topology::Topology;

/// One load-balancing problem.
#[derive(Debug, Clone)]
pub struct Instance {
    /// Per-object computational load (seconds, or any consistent unit).
    pub loads: Vec<f64>,
    /// Per-object logical coordinates (coordinate variant input; zeros
    /// when the app provides none).
    pub coords: Vec<[f64; 2]>,
    /// Per-object migration size in bytes (proxy for migration cost).
    pub sizes: Vec<f64>,
    /// Object communication graph.
    pub graph: CommGraph,
    /// Current object → PE mapping.
    pub mapping: Vec<u32>,
    pub topo: Topology,
}

/// A strategy's output: the new object → PE mapping.
#[derive(Debug, Clone, PartialEq)]
pub struct Assignment {
    pub mapping: Vec<u32>,
}

impl Instance {
    /// Build with uniform object sizes and validation.
    pub fn new(
        loads: Vec<f64>,
        coords: Vec<[f64; 2]>,
        graph: CommGraph,
        mapping: Vec<u32>,
        topo: Topology,
    ) -> Instance {
        let n = loads.len();
        let sizes = vec![1.0; n];
        let inst = Instance { loads, coords, sizes, graph, mapping, topo };
        inst.validate().expect("invalid instance");
        inst
    }

    pub fn n_objects(&self) -> usize {
        self.loads.len()
    }

    pub fn validate(&self) -> Result<()> {
        let n = self.loads.len();
        if self.coords.len() != n || self.mapping.len() != n || self.sizes.len() != n {
            bail!("instance arrays disagree on n ({n})");
        }
        if self.graph.n != n {
            bail!("graph has {} vertices, expected {n}", self.graph.n);
        }
        let n_pes = self.topo.n_pes() as u32;
        if let Some(&bad) = self.mapping.iter().find(|&&pe| pe >= n_pes) {
            bail!("mapping references PE {bad} >= {n_pes}");
        }
        if self.loads.iter().any(|l| !l.is_finite() || *l < 0.0) {
            bail!("loads must be finite and non-negative");
        }
        Ok(())
    }

    /// Object → node mapping derived from the PE mapping.
    pub fn node_mapping(&self) -> Vec<u32> {
        let mut out = Vec::new();
        self.node_mapping_into(&mut out);
        out
    }

    /// [`Self::node_mapping`] into a reused buffer — the strategy hot
    /// paths call this once per LB round, so the allocation is hoisted
    /// into their scratch space.
    pub fn node_mapping_into(&self, out: &mut Vec<u32>) {
        out.clear();
        out.extend(self.mapping.iter().map(|&pe| self.topo.node_of_pe(pe)));
    }

    /// Per-node loads under the instance's own mapping, into a reused
    /// buffer (accumulates in object order, matching
    /// [`Self::node_loads`] bit-for-bit).
    pub fn node_loads_into(&self, out: &mut Vec<f64>) {
        out.clear();
        out.resize(self.topo.n_nodes, 0.0);
        for (o, &pe) in self.mapping.iter().enumerate() {
            out[self.topo.node_of_pe(pe) as usize] += self.loads[o];
        }
    }

    /// Per-PE total loads.
    pub fn pe_loads(&self, mapping: &[u32]) -> Vec<f64> {
        let mut loads = vec![0.0; self.topo.n_pes()];
        for (o, &pe) in mapping.iter().enumerate() {
            loads[pe as usize] += self.loads[o];
        }
        loads
    }

    /// Per-PE normalized times (`work / speed`) — the heterogeneous
    /// balance signal. On uniform topologies the division is by exactly
    /// 1.0, so the result is bitwise the raw loads.
    pub fn pe_times(&self, mapping: &[u32]) -> Vec<f64> {
        let mut times = self.pe_loads(mapping);
        if !self.topo.is_uniform() {
            for (pe, t) in times.iter_mut().enumerate() {
                *t /= self.topo.pe_speed(pe as u32);
            }
        }
        times
    }

    /// Per-node normalized times (`work / node capacity`).
    pub fn node_times(&self, mapping: &[u32]) -> Vec<f64> {
        let mut times = self.node_loads(mapping);
        if !self.topo.is_uniform() {
            for (node, t) in times.iter_mut().enumerate() {
                *t /= self.topo.node_capacity(node as u32);
            }
        }
        times
    }

    /// Per-node total loads.
    pub fn node_loads(&self, mapping: &[u32]) -> Vec<f64> {
        let mut loads = vec![0.0; self.topo.n_nodes];
        for (o, &pe) in mapping.iter().enumerate() {
            loads[self.topo.node_of_pe(pe) as usize] += self.loads[o];
        }
        loads
    }

    /// Per-node object lists under `mapping`.
    pub fn node_objects(&self, mapping: &[u32]) -> Vec<Vec<u32>> {
        let mut objs = vec![Vec::new(); self.topo.n_nodes];
        for (o, &pe) in mapping.iter().enumerate() {
            objs[self.topo.node_of_pe(pe) as usize].push(o as u32);
        }
        objs
    }

    /// Centroid (mean coordinate) of each node's objects. Nodes with no
    /// objects get the global centroid (paper's coord variant init).
    pub fn node_centroids(&self, mapping: &[u32]) -> Vec<[f64; 2]> {
        let mut sums = vec![[0.0f64; 2]; self.topo.n_nodes];
        let mut counts = vec![0usize; self.topo.n_nodes];
        for (o, &pe) in mapping.iter().enumerate() {
            let node = self.topo.node_of_pe(pe) as usize;
            sums[node][0] += self.coords[o][0];
            sums[node][1] += self.coords[o][1];
            counts[node] += 1;
        }
        let n = self.n_objects().max(1) as f64;
        let global = [
            self.coords.iter().map(|c| c[0]).sum::<f64>() / n,
            self.coords.iter().map(|c| c[1]).sum::<f64>() / n,
        ];
        sums.iter()
            .zip(&counts)
            .map(|(s, &c)| if c == 0 { global } else { [s[0] / c as f64, s[1] / c as f64] })
            .collect()
    }

    // ----------------------------------------------------------- .lbi io

    /// Serialize to the `.lbi` text format.
    ///
    /// Single-pass writer into one preallocated `String`: every line
    /// used to be its own `format!` allocation (an n+m allocation
    /// serialize — the distributed driver broadcasts this every LB
    /// round), now `write!` appends in place and the buffer is sized
    /// once from a per-line estimate. Output bytes are unchanged —
    /// `write!` and `format!` share the same formatting machinery.
    /// For the wire itself see [`super::lbi`]'s binary codec; this text
    /// form remains the on-disk / human-debuggable format.
    pub fn to_lbi(&self) -> String {
        use std::fmt::Write as _;
        let (n, m) = (self.n_objects(), self.graph.nbrs.len() / 2);
        // ~64 B/object line and ~32 B/edge line covers typical float
        // widths; a long tail just regrows once.
        let mut s = String::with_capacity(96 + n * 64 + m * 32);
        s.push_str("# difflb instance v1\n");
        let _ = writeln!(
            s,
            "header objects {n} nodes {} pes_per_node {}",
            self.topo.n_nodes, self.topo.pes_per_node
        );
        // Heterogeneous topologies carry their PE speed vector; Rust's
        // shortest-round-trip float formatting keeps the line lossless,
        // which the distributed driver's `.lbi` broadcast relies on.
        if let Some(speeds) = self.topo.pe_speeds() {
            s.push_str("speeds");
            for v in speeds {
                let _ = write!(s, " {v}");
            }
            s.push('\n');
        }
        for o in 0..n {
            let _ = writeln!(
                s,
                "object {o} load {} pe {} x {} y {} size {}",
                self.loads[o], self.mapping[o], self.coords[o][0], self.coords[o][1], self.sizes[o]
            );
        }
        for (a, b, w) in self.graph.edges() {
            let _ = writeln!(s, "edge {a} {b} {w}");
        }
        s
    }

    pub fn from_lbi(text: &str) -> Result<Instance> {
        let mut n = 0usize;
        let mut topo = Topology::flat(1);
        let mut loads = Vec::new();
        let mut coords = Vec::new();
        let mut sizes = Vec::new();
        let mut mapping = Vec::new();
        let mut edges = Vec::new();
        let mut speeds: Option<Vec<f64>> = None;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            let ctx = || format!("lbi line {}", lineno + 1);
            match toks[0] {
                "header" => {
                    // header objects N nodes M pes_per_node P
                    if toks.len() != 7 {
                        bail!("{}: malformed header", ctx());
                    }
                    n = toks[2].parse().with_context(ctx)?;
                    topo = Topology::new(
                        toks[4].parse().with_context(ctx)?,
                        toks[6].parse().with_context(ctx)?,
                    );
                    loads = vec![0.0; n];
                    coords = vec![[0.0; 2]; n];
                    sizes = vec![1.0; n];
                    mapping = vec![0; n];
                }
                "object" => {
                    if toks.len() != 12 {
                        bail!("{}: malformed object line", ctx());
                    }
                    let id: usize = toks[1].parse().with_context(ctx)?;
                    if id >= n {
                        bail!("{}: object id {id} >= {n}", ctx());
                    }
                    loads[id] = toks[3].parse().with_context(ctx)?;
                    mapping[id] = toks[5].parse().with_context(ctx)?;
                    coords[id][0] = toks[7].parse().with_context(ctx)?;
                    coords[id][1] = toks[9].parse().with_context(ctx)?;
                    sizes[id] = toks[11].parse().with_context(ctx)?;
                }
                "speeds" => {
                    // speeds s0 s1 ... s_{n_pes-1}; the length check
                    // happens after the loop against the final
                    // topology, so a speeds line placed before the
                    // header still errors (bail) instead of tripping
                    // with_pe_speeds' assert against the placeholder
                    // topology
                    let parsed: Result<Vec<f64>> = toks[1..]
                        .iter()
                        .map(|t| t.parse::<f64>().map_err(|e| anyhow::anyhow!("{}: {e}", ctx())))
                        .collect();
                    let parsed = parsed?;
                    if parsed.iter().any(|s| !s.is_finite() || *s <= 0.0) {
                        bail!("{}: speeds must be finite and positive", ctx());
                    }
                    speeds = Some(parsed);
                }
                "edge" => {
                    if toks.len() != 4 {
                        bail!("{}: malformed edge line", ctx());
                    }
                    edges.push((
                        toks[1].parse().with_context(ctx)?,
                        toks[2].parse().with_context(ctx)?,
                        toks[3].parse().with_context(ctx)?,
                    ));
                }
                other => bail!("{}: unknown record '{other}'", ctx()),
            }
        }
        if let Some(s) = speeds {
            if s.len() != topo.n_pes() {
                bail!("speeds record has {} entries for {} PEs", s.len(), topo.n_pes());
            }
            topo = topo.with_pe_speeds(s);
        }
        let graph = CommGraph::from_edges(n, &edges);
        let inst = Instance { loads, coords, sizes, graph, mapping, topo };
        inst.validate()?;
        Ok(inst)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_lbi())
            .with_context(|| format!("writing {}", path.as_ref().display()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Instance> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Instance::from_lbi(&text)
    }
}

/// Re-home every object mapped to a dead/inactive node onto the next
/// alive node cyclically after it, keeping the object's local PE slot.
/// A pure world-space remap: deterministic in `(mapping, alive)`, so
/// every rank of the distributed runtime computes it identically
/// without exchanging a byte (the epoch layer and the resize path both
/// rely on that). Panics if no node is alive.
pub fn rehome_mapping(mapping: &[u32], topo: &Topology, alive: &[bool]) -> Vec<u32> {
    debug_assert_eq!(alive.len(), topo.n_nodes);
    assert!(alive.iter().any(|&a| a), "rehome_mapping: no alive node");
    let ppn = topo.pes_per_node as u32;
    let n = topo.n_nodes as u32;
    mapping
        .iter()
        .map(|&pe| {
            let node = topo.node_of_pe(pe);
            if alive[node as usize] {
                return pe;
            }
            let mut adopter = node;
            for d in 1..=n {
                let c = (node + d) % n;
                if alive[c as usize] {
                    adopter = c;
                    break;
                }
            }
            adopter * ppn + topo.local_of_pe(pe)
        })
        .collect()
}

/// An [`Instance`] restricted to the alive subset of nodes, with the
/// translation table back to world ranks. The restricted instance has
/// a dense topology (`nodes.len()` nodes, same `pes_per_node`, the
/// survivors' speed slices); objects of dead nodes are re-homed via
/// [`rehome_mapping`] before densification. Object-level data (loads,
/// coords, sizes, graph) carries over unchanged — restriction never
/// creates or destroys work, which is what the chaos tests'
/// work-conservation assertions check.
#[derive(Debug, Clone)]
pub struct Restriction {
    pub inst: Instance,
    /// Survivor world node ids, ascending: dense node `i` is world node
    /// `nodes[i]`.
    pub nodes: Vec<u32>,
}

impl Restriction {
    /// Translate a PE of the restricted topology back to the world PE.
    pub fn to_world_pe(&self, sub_pe: u32) -> u32 {
        let ppn = self.inst.topo.pes_per_node as u32;
        self.nodes[(sub_pe / ppn) as usize] * ppn + sub_pe % ppn
    }

    /// Translate a whole restricted mapping back to world PEs. By
    /// construction the result only references survivor PEs — a dead
    /// node can never reappear in an expanded assignment.
    pub fn expand_mapping(&self, sub_mapping: &[u32]) -> Vec<u32> {
        sub_mapping.iter().map(|&pe| self.to_world_pe(pe)).collect()
    }
}

/// Restrict `inst` to the nodes flagged alive (see [`Restriction`]).
pub fn restrict_instance(inst: &Instance, alive: &[bool]) -> Restriction {
    let world = rehome_mapping(&inst.mapping, &inst.topo, alive);
    let nodes: Vec<u32> =
        (0..inst.topo.n_nodes as u32).filter(|&n| alive[n as usize]).collect();
    let ppn = inst.topo.pes_per_node;
    let mut dense = vec![u32::MAX; inst.topo.n_nodes];
    for (i, &w) in nodes.iter().enumerate() {
        dense[w as usize] = i as u32;
    }
    let mapping: Vec<u32> = world
        .iter()
        .map(|&pe| {
            dense[inst.topo.node_of_pe(pe) as usize] * ppn as u32
                + inst.topo.local_of_pe(pe)
        })
        .collect();
    let topo = if inst.topo.is_uniform() {
        Topology::new(nodes.len(), ppn)
    } else {
        let mut speeds = Vec::with_capacity(nodes.len() * ppn);
        for &w in &nodes {
            for pe in inst.topo.pes_of_node(w) {
                speeds.push(inst.topo.pe_speed(pe));
            }
        }
        Topology::new(nodes.len(), ppn).with_pe_speeds(speeds)
    };
    let restricted = Instance {
        loads: inst.loads.clone(),
        coords: inst.coords.clone(),
        sizes: inst.sizes.clone(),
        graph: inst.graph.clone(),
        mapping,
        topo,
    };
    Restriction { inst: restricted, nodes }
}

impl Assignment {
    /// Identity assignment (no migration).
    pub fn unchanged(inst: &Instance) -> Assignment {
        Assignment { mapping: inst.mapping.clone() }
    }

    /// Number of objects whose PE changed relative to `inst`.
    pub fn migrations(&self, inst: &Instance) -> usize {
        self.mapping
            .iter()
            .zip(&inst.mapping)
            .filter(|(a, b)| a != b)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub fn tiny_instance() -> Instance {
        let graph = CommGraph::from_edges(4, &[(0, 1, 8.0), (1, 2, 4.0), (2, 3, 2.0)]);
        Instance::new(
            vec![1.0, 2.0, 3.0, 4.0],
            vec![[0.0, 0.0], [1.0, 0.0], [2.0, 0.0], [3.0, 0.0]],
            graph,
            vec![0, 0, 1, 1],
            Topology::flat(2),
        )
    }

    #[test]
    fn derived_views() {
        let inst = tiny_instance();
        assert_eq!(inst.pe_loads(&inst.mapping), vec![3.0, 7.0]);
        assert_eq!(inst.node_loads(&inst.mapping), vec![3.0, 7.0]);
        // buffered variants agree and clear stale contents
        let mut nm = vec![9u32; 10];
        inst.node_mapping_into(&mut nm);
        assert_eq!(nm, inst.node_mapping());
        let mut nl = vec![1.0; 1];
        inst.node_loads_into(&mut nl);
        assert_eq!(nl, vec![3.0, 7.0]);
        assert_eq!(inst.node_objects(&inst.mapping)[1], vec![2, 3]);
        let c = inst.node_centroids(&inst.mapping);
        assert_eq!(c[0], [0.5, 0.0]);
        assert_eq!(c[1], [2.5, 0.0]);
    }

    #[test]
    fn lbi_round_trip() {
        let inst = tiny_instance();
        let text = inst.to_lbi();
        let back = Instance::from_lbi(&text).unwrap();
        assert_eq!(back.loads, inst.loads);
        assert_eq!(back.mapping, inst.mapping);
        assert_eq!(back.coords, inst.coords);
        assert_eq!(back.graph, inst.graph);
        assert_eq!(back.topo, inst.topo);
    }

    #[test]
    fn validation_catches_bad_mapping() {
        let mut inst = tiny_instance();
        inst.mapping[0] = 99;
        assert!(inst.validate().is_err());
    }

    #[test]
    fn migrations_counted() {
        let inst = tiny_instance();
        let mut a = Assignment::unchanged(&inst);
        assert_eq!(a.migrations(&inst), 0);
        a.mapping[0] = 1;
        assert_eq!(a.migrations(&inst), 1);
    }

    #[test]
    fn malformed_lbi_rejected() {
        assert!(Instance::from_lbi("object 0").is_err());
        assert!(Instance::from_lbi("header objects 1 nodes 1 pes_per_node 1\nbogus x").is_err());
        // wrong-length or non-positive speed vectors are rejected too
        assert!(Instance::from_lbi(
            "header objects 1 nodes 2 pes_per_node 1\nspeeds 1.0\nobject 0 load 1 pe 0 x 0 y 0 size 1"
        )
        .is_err());
        assert!(Instance::from_lbi(
            "header objects 1 nodes 2 pes_per_node 1\nspeeds 1.0 -2.0\nobject 0 load 1 pe 0 x 0 y 0 size 1"
        )
        .is_err());
        // a speeds record BEFORE the header must error, not panic
        // (the length is checked against the final topology)
        assert!(Instance::from_lbi(
            "speeds 2.0\nheader objects 1 nodes 2 pes_per_node 1\nobject 0 load 1 pe 0 x 0 y 0 size 1"
        )
        .is_err());
    }

    #[test]
    fn lbi_round_trips_pe_speeds() {
        let mut inst = tiny_instance();
        inst.topo = inst.topo.clone().with_pe_speeds(vec![1.0, 2.5]);
        let back = Instance::from_lbi(&inst.to_lbi()).unwrap();
        assert_eq!(back.topo, inst.topo);
        assert_eq!(back.topo.pe_speeds().unwrap(), &[1.0, 2.5]);
        // uniform topologies serialize no speeds line at all
        let plain = tiny_instance();
        assert!(!plain.to_lbi().contains("speeds"));
    }

    #[test]
    fn rehome_adopts_cyclically_and_preserves_survivors() {
        let topo = Topology::new(4, 2);
        let mapping = vec![0, 3, 4, 5, 7]; // nodes 0, 1, 2, 2, 3
        // node 2 dead: its objects adopt node 3, same local slot
        let out = rehome_mapping(&mapping, &topo, &[true, true, false, true]);
        assert_eq!(out, vec![0, 3, 6, 7, 7]);
        // nodes 2 and 3 dead: adoption wraps to node 0
        let out = rehome_mapping(&mapping, &topo, &[true, true, false, false]);
        assert_eq!(out, vec![0, 3, 0, 1, 1]);
    }

    #[test]
    fn restriction_densifies_and_round_trips() {
        let mut inst = tiny_instance(); // 2 flat nodes, mapping [0,0,1,1]
        inst.topo = Topology::flat(3);
        inst.mapping = vec![0, 1, 2, 1];
        let r = restrict_instance(&inst, &[true, false, true]);
        assert_eq!(r.nodes, vec![0, 2]);
        assert_eq!(r.inst.topo.n_nodes, 2);
        // node 1's objects adopt node 2 (dense index 1)
        assert_eq!(r.inst.mapping, vec![0, 1, 1, 1]);
        assert_eq!(r.to_world_pe(0), 0);
        assert_eq!(r.to_world_pe(1), 2);
        assert_eq!(r.expand_mapping(&r.inst.mapping), vec![0, 2, 2, 2]);
        // object-level data is untouched: work is conserved
        assert_eq!(r.inst.loads, inst.loads);
        assert_eq!(r.inst.sizes, inst.sizes);
        r.inst.validate().unwrap();
    }

    #[test]
    fn restriction_carries_survivor_speeds() {
        let mut inst = tiny_instance();
        inst.topo = Topology::flat(3).with_pe_speeds(vec![1.0, 2.0, 0.5]);
        inst.mapping = vec![0, 0, 1, 2];
        let r = restrict_instance(&inst, &[true, false, true]);
        assert_eq!(r.inst.topo.pe_speeds().unwrap(), &[1.0, 0.5]);
    }

    #[test]
    fn time_views_normalize_by_speed() {
        let mut inst = tiny_instance();
        // uniform: times are bitwise the loads
        assert_eq!(inst.pe_times(&inst.mapping), inst.pe_loads(&inst.mapping));
        assert_eq!(inst.node_times(&inst.mapping), inst.node_loads(&inst.mapping));
        inst.topo = inst.topo.clone().with_pe_speeds(vec![1.0, 2.0]);
        // loads [3, 7] over speeds [1, 2] -> times [3, 3.5]
        assert_eq!(inst.pe_times(&inst.mapping), vec![3.0, 3.5]);
        assert_eq!(inst.node_times(&inst.mapping), vec![3.0, 3.5]);
    }
}
