//! Threaded message-passing cluster + α–β communication cost model.
//!
//! [`Cluster::run`] spawns one OS thread per simulated node and hands
//! each a [`Comm`] endpoint (send/recv/barrier over std mpsc channels) —
//! enough to execute genuinely distributed protocols (the full LB
//! pipeline in [`crate::distributed`] and the stage-1 handshake in
//! [`super::protocol`]) without any external runtime.
//!
//! [`NetModel`] converts message/byte counts into seconds the way the
//! strong-scaling analysis needs: `t = α·msgs + β·bytes`, with
//! intra-node traffic discounted (shared memory vs NIC).

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

/// A message between simulated nodes: (source, tag, payload).
#[derive(Debug, Clone, PartialEq)]
pub struct Msg {
    pub from: u32,
    pub tag: u32,
    pub data: Vec<u8>,
}

/// Why a blocking receive returned without a message. A dead peer set
/// (every sender endpoint dropped) is a *distinct* outcome from a slow
/// one: protocols treat [`RecvError::Disconnected`] as fatal
/// immediately instead of burning the full timeout waiting for a
/// message that can never arrive.
///
/// Scope caveat: inside a [`Cluster`], every node holds sender clones
/// to every inbox (including its own loopback), so `Disconnected`
/// fires only when the *whole* cluster is torn down — a single dead
/// peer among survivors still surfaces as `Timeout` (detecting that
/// would need per-pair channels or heartbeats). The distinct outcome
/// matters for endpoints whose senders genuinely all dropped, e.g.
/// teardown races and embedding `Comm` outside `Cluster::run`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecvError {
    /// No message arrived within the timeout; peers may just be slow.
    Timeout,
    /// All sender endpoints are gone — nothing can ever arrive.
    Disconnected,
}

/// Per-node communication endpoint.
pub struct Comm {
    pub rank: u32,
    pub n: usize,
    senders: Vec<Sender<Msg>>,
    inbox: Receiver<Msg>,
    /// Out-of-phase messages put aside by [`Comm::recv_tagged`]: a fast
    /// peer may already be sending the next protocol phase while this
    /// node still drains the current one.
    pending: Vec<Msg>,
}

impl Comm {
    /// Default patience for protocol receives: long enough that only a
    /// genuine deadlock (not scheduler jitter) trips it.
    pub const TIMEOUT: Duration = Duration::from_secs(30);

    /// Build an endpoint from raw channel halves (used by [`Cluster`]
    /// and by unit tests that need to simulate dead peers).
    fn new(rank: u32, n: usize, senders: Vec<Sender<Msg>>, inbox: Receiver<Msg>) -> Comm {
        Comm { rank, n, senders, inbox, pending: Vec::new() }
    }

    pub fn send(&self, to: u32, tag: u32, data: Vec<u8>) {
        // a dropped peer ends the protocol; ignore send failures then
        let _ = self.senders[to as usize].send(Msg { from: self.rank, tag, data });
    }

    /// Blocking receive with timeout. [`RecvError::Disconnected`] means
    /// every sender endpoint (including this node's own loopback) has
    /// been dropped — the cluster is gone, not merely slow.
    pub fn recv(&self, timeout: Duration) -> Result<Msg, RecvError> {
        match self.inbox.recv_timeout(timeout) {
            Ok(m) => Ok(m),
            Err(RecvTimeoutError::Timeout) => Err(RecvError::Timeout),
            Err(RecvTimeoutError::Disconnected) => Err(RecvError::Disconnected),
        }
    }

    /// Receive exactly `count` messages (or fewer on timeout /
    /// disconnect). Messages parked by [`Comm::recv_tagged`] are not
    /// consulted — this is the raw in-arrival-order primitive.
    pub fn recv_n(&self, count: usize, timeout: Duration) -> Vec<Msg> {
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            match self.recv(timeout) {
                Ok(m) => out.push(m),
                Err(_) => break,
            }
        }
        out
    }

    /// Receive exactly `count` messages carrying `tag`, parking any
    /// other tag in the pending buffer for a later `recv_tagged` (a
    /// fast peer may already be sending the next phase while we drain
    /// this one). Returns short only on [`RecvError::Timeout`]; a
    /// disconnected cluster panics — with every sender gone the
    /// outstanding messages can never arrive, so the protocol fails
    /// fast instead of pretending the phase merely timed out.
    pub fn recv_tagged(&mut self, tag: u32, count: usize, timeout: Duration) -> Vec<Msg> {
        let mut out = Vec::with_capacity(count);
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].tag == tag && out.len() < count {
                out.push(self.pending.remove(i));
            } else {
                i += 1;
            }
        }
        while out.len() < count {
            match self.recv(timeout) {
                Ok(m) if m.tag == tag => out.push(m),
                Ok(m) => self.pending.push(m),
                Err(RecvError::Timeout) => break,
                Err(RecvError::Disconnected) => panic!(
                    "simnode {}: cluster disconnected with {} message(s) of tag {tag:#x} \
                     still outstanding",
                    self.rank,
                    count - out.len()
                ),
            }
        }
        out
    }

    /// All-to-all barrier: returns once every rank has entered a
    /// `barrier` call with the same `tag`. The tag must be unique per
    /// logical barrier (reusing one across two consecutive barriers
    /// lets a fast rank's second announcement satisfy a slow rank's
    /// first wait). Panics — rather than deadlocks — when a peer dies
    /// or the wait exceeds [`Comm::TIMEOUT`].
    pub fn barrier(&mut self, tag: u32) {
        for p in 0..self.n as u32 {
            if p != self.rank {
                self.send(p, tag, Vec::new());
            }
        }
        let want = self.n - 1;
        let got = self.recv_tagged(tag, want, Self::TIMEOUT);
        assert_eq!(
            got.len(),
            want,
            "simnode {}: barrier {tag:#x} timed out with {}/{want} peers arrived",
            self.rank,
            got.len()
        );
    }
}

/// A set of simulated nodes executing a closure per rank on real
/// threads.
pub struct Cluster;

impl Cluster {
    /// Run `f(rank, comm)` on `n` threads; returns the per-rank results
    /// in rank order. Panics in workers propagate.
    pub fn run<T, F>(n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(u32, Comm) -> T + Send + Sync + Clone + 'static,
    {
        let mut senders = Vec::with_capacity(n);
        let mut inboxes = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel::<Msg>();
            senders.push(tx);
            inboxes.push(rx);
        }
        let mut handles = Vec::with_capacity(n);
        for (rank, inbox) in inboxes.into_iter().enumerate() {
            let comm = Comm::new(rank as u32, n, senders.clone(), inbox);
            let f = f.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("simnode-{rank}"))
                    .spawn(move || f(rank as u32, comm))
                    .expect("spawn simnode"),
            );
        }
        drop(senders);
        handles.into_iter().map(|h| h.join().expect("simnode panicked")).collect()
    }
}

/// α–β network model with intra-node discount.
#[derive(Debug, Clone, Copy)]
pub struct NetModel {
    /// Per-message latency (seconds).
    pub alpha: f64,
    /// Per-byte cost (seconds/byte) across nodes.
    pub beta: f64,
    /// Intra-node traffic costs `intra_factor` × the inter-node beta
    /// (shared-memory transfer), with no alpha.
    pub intra_factor: f64,
}

impl Default for NetModel {
    fn default() -> Self {
        // ~2µs latency, ~25 GB/s effective inter-node bandwidth,
        // intra-node ~10x cheaper: Slingshot-ish numbers for a
        // Perlmutter-flavored simulation.
        NetModel { alpha: 2e-6, beta: 1.0 / 25e9, intra_factor: 0.1 }
    }
}

impl NetModel {
    pub fn inter_time(&self, msgs: u64, bytes: f64) -> f64 {
        self.alpha * msgs as f64 + self.beta * bytes
    }

    pub fn intra_time(&self, bytes: f64) -> f64 {
        self.beta * self.intra_factor * bytes
    }
}

/// Accumulates per-node traffic for one app iteration and converts it
/// to per-node communication time under a [`NetModel`].
#[derive(Debug, Clone)]
pub struct CostTracker {
    pub n_nodes: usize,
    pub inter_msgs: Vec<u64>,
    pub inter_bytes: Vec<f64>,
    pub intra_bytes: Vec<f64>,
}

impl CostTracker {
    pub fn new(n_nodes: usize) -> CostTracker {
        CostTracker {
            n_nodes,
            inter_msgs: vec![0; n_nodes],
            inter_bytes: vec![0.0; n_nodes],
            intra_bytes: vec![0.0; n_nodes],
        }
    }

    /// Record `bytes` moving from `from` to `to` (node indices); both
    /// endpoints pay (send + receive overlap is not modeled).
    pub fn record(&mut self, from: u32, to: u32, bytes: f64) {
        if from == to {
            self.intra_bytes[from as usize] += bytes;
        } else {
            self.inter_msgs[from as usize] += 1;
            self.inter_msgs[to as usize] += 1;
            self.inter_bytes[from as usize] += bytes;
            self.inter_bytes[to as usize] += bytes;
        }
    }

    /// Per-node communication seconds under `model`.
    pub fn comm_times(&self, model: &NetModel) -> Vec<f64> {
        (0..self.n_nodes)
            .map(|i| {
                model.inter_time(self.inter_msgs[i], self.inter_bytes[i])
                    + model.intra_time(self.intra_bytes[i])
            })
            .collect()
    }

    pub fn reset(&mut self) {
        self.inter_msgs.iter_mut().for_each(|x| *x = 0);
        self.inter_bytes.iter_mut().for_each(|x| *x = 0.0);
        self.intra_bytes.iter_mut().for_each(|x| *x = 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_all_to_all_exchange() {
        let results = Cluster::run(4, |rank, comm| {
            for to in 0..4u32 {
                if to != rank {
                    comm.send(to, 7, vec![rank as u8]);
                }
            }
            let msgs = comm.recv_n(3, Duration::from_secs(5));
            let mut froms: Vec<u32> = msgs.iter().map(|m| m.from).collect();
            froms.sort_unstable();
            froms
        });
        for (rank, froms) in results.iter().enumerate() {
            let expect: Vec<u32> = (0..4u32).filter(|&r| r as usize != rank).collect();
            assert_eq!(froms, &expect);
        }
    }

    #[test]
    fn recv_timeout_is_distinct_from_disconnect() {
        // Live cluster, no traffic: plain Timeout (never Disconnected —
        // each node's own loopback sender keeps its inbox alive).
        let r = Cluster::run(2, |_rank, comm| comm.recv(Duration::from_millis(10)));
        assert_eq!(r, vec![Err(RecvError::Timeout), Err(RecvError::Timeout)]);
    }

    #[test]
    fn recv_reports_dead_peers_immediately() {
        // Hand-built endpoint whose every sender has been dropped: the
        // receive must fail fast with Disconnected, not burn a timeout.
        let (tx, rx) = channel::<Msg>();
        drop(tx);
        let dead = Comm::new(1, 2, Vec::new(), rx);
        let t = std::time::Instant::now();
        assert_eq!(dead.recv(Duration::from_secs(30)), Err(RecvError::Disconnected));
        assert!(t.elapsed() < Duration::from_secs(5), "burned the timeout");
    }

    #[test]
    #[should_panic(expected = "cluster disconnected")]
    fn recv_tagged_panics_on_dead_cluster() {
        let (tx, rx) = channel::<Msg>();
        drop(tx);
        let mut dead = Comm::new(0, 2, Vec::new(), rx);
        dead.recv_tagged(0x42, 1, Duration::from_secs(30));
    }

    #[test]
    fn net_model_costs() {
        let m = NetModel { alpha: 1e-6, beta: 1e-9, intra_factor: 0.1 };
        assert!((m.inter_time(10, 1e6) - (1e-5 + 1e-3)).abs() < 1e-12);
        assert!((m.intra_time(1e6) - 1e-4).abs() < 1e-12);
    }

    #[test]
    fn tracker_attributes_both_endpoints() {
        let mut t = CostTracker::new(3);
        t.record(0, 1, 100.0);
        t.record(2, 2, 50.0);
        assert_eq!(t.inter_msgs, vec![1, 1, 0]);
        assert_eq!(t.inter_bytes, vec![100.0, 100.0, 0.0]);
        assert_eq!(t.intra_bytes, vec![0.0, 0.0, 50.0]);
        let times = t.comm_times(&NetModel::default());
        assert!(times[0] > 0.0 && times[0] == times[1] && times[2] > 0.0);
        t.reset();
        assert_eq!(t.inter_bytes, vec![0.0; 3]);
    }

    #[test]
    fn recv_tagged_buffers_out_of_phase() {
        let results = Cluster::run(2, |rank, mut comm| {
            let peer = 1 - rank;
            // send three phases out of order
            comm.send(peer, 3, vec![30]);
            comm.send(peer, 1, vec![10]);
            comm.send(peer, 2, vec![20]);
            // drain in canonical phase order
            let a = comm.recv_tagged(1, 1, Duration::from_secs(5));
            let b = comm.recv_tagged(2, 1, Duration::from_secs(5));
            let c = comm.recv_tagged(3, 1, Duration::from_secs(5));
            (a[0].data.clone(), b[0].data.clone(), c[0].data.clone())
        });
        for r in results {
            assert_eq!(r, (vec![10], vec![20], vec![30]));
        }
    }

    #[test]
    fn barrier_holds_until_all_arrive() {
        // Every rank announces "pre" to rank 0 before entering the
        // barrier; once rank 0's barrier completes, all announcements
        // must already be in flight — observable with a tiny timeout.
        let results = Cluster::run(4, |rank, mut comm| {
            comm.send(0, 0x50, vec![rank as u8]);
            if rank == 2 {
                std::thread::sleep(Duration::from_millis(50)); // straggler
            }
            comm.barrier(0x60);
            if rank == 0 {
                let pre = comm.recv_tagged(0x50, 4, Duration::from_secs(5));
                pre.len()
            } else {
                0
            }
        });
        assert_eq!(results[0], 4);
    }
}
